//! Multi-tenant fleet demo: the L4 serving fabric end-to-end, no
//! artifacts required.
//!
//! Builds a synthetic tenant registry (one slice-filling ResNet-18 plus
//! compact CNNs with distinct QoS contracts), places every replica across
//! the fleet with the endurance-aware wear-leveling placer, then runs the
//! deterministic fleet simulation: seeded multi-tenant traffic, a
//! drain → program → rewarm campaign per tenant interleaved mid-run, and
//! a final report with per-tenant p50/p99, throughput, energy, per-bank
//! wear, and campaign downtime. Run:
//!   cargo run --release --example fleet_serving [requests_per_tenant]

use nvm_in_cache::cache::addr::Geometry;
use nvm_in_cache::fleet::{EndurancePlacer, FleetSim, FleetSimConfig, ModelRegistry};

fn main() -> nvm_in_cache::Result<()> {
    let requests: usize = std::env::args()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .unwrap_or(400);

    // Show the placement on its own first: who lands where, and how much
    // endurance headroom the policy demands.
    let registry = ModelRegistry::synthetic(3);
    let placer = EndurancePlacer::new(Geometry::default(), 4);
    let placement = placer.place(&registry)?;
    println!("placement across {} slices:", placement.slices_used());
    for r in &placement.replicas {
        println!(
            "  tenant {} ({}) replica {} → slice {} slots {}..{} ({} banks)",
            r.tenant,
            registry.tenants[r.tenant].name,
            r.replica,
            r.slice,
            r.start_slot,
            r.start_slot + r.layout.slots_used,
            r.banks().len(),
        );
    }
    println!(
        "endurance policy: min window {:.2}, headroom for {:.0} campaigns\n",
        placer.policy.min_window, placer.policy.planned_campaigns
    );

    // The full simulation (traffic + campaigns + live Server pass).
    let config = FleetSimConfig {
        requests_per_tenant: requests,
        live_serving: true,
        ..FleetSimConfig::default()
    };
    let report = FleetSim::run(&config)?;
    print!("{}", report.render());
    Ok(())
}
