//! Quickstart: the 6T-2R bit-cell and sub-array in five minutes.
//!
//! Walks the paper's §III story at the API level: program a weight, verify
//! SRAM mode still works, run the two-cycle PIM dot-product while holding
//! cache data, scale up to a full 128×512 sub-array MAC, then run a whole
//! CNN batch end-to-end through the `Runtime` seam (StubRuntime — no
//! artifacts or external dependencies needed).
//!
//! Run: `cargo run --release --example quickstart`

use nvm_in_cache::array::SubArray;
use nvm_in_cache::cell::timing::EnergyLedger;
use nvm_in_cache::cell::{BitCell, PimParams, Side};
use nvm_in_cache::consts::{ARRAY_ROWS, ARRAY_WORDS};
use nvm_in_cache::device::Corner;
use nvm_in_cache::nn::resnet::test_params;
use nvm_in_cache::pim::transfer::TransferModel;
use nvm_in_cache::runtime::{ModelVariant, Runtime, StubRuntime};
use nvm_in_cache::util::rng::Pcg64;

fn main() {
    println!("=== 1. One 6T-2R bit-cell ===");
    let mut cell = BitCell::new(Corner::TT);
    let mut ledger = EnergyLedger::new();

    // NVM programming (§III-A): two 4 ns LRS cycles, one per side.
    let left = cell.program_lrs(Side::Left, &mut ledger);
    let right = cell.program_lrs(Side::Right, &mut ledger);
    println!(
        "programmed weight bit = 1: left {:?} ({} pulse), right {:?} ({} pulse)",
        left.state, left.pulses, right.state, right.pulses
    );

    // SRAM mode is unaffected (§III-B).
    cell.sram_write(true, &mut ledger);
    assert!(cell.sram_read(&mut ledger));
    cell.sram_write(false, &mut ledger);
    assert!(!cell.sram_read(&mut ledger));
    println!("SRAM write/read still works with the RRAMs programmed ✓");

    // PIM mode (§III-C): dot-product while the latch holds data.
    cell.sram_write(true, &mut ledger);
    let out = cell.pim_dot_product(true, &PimParams::default(), &mut ledger);
    println!(
        "PIM IA=1 × w=1: i_left = {:.1} µA, i_right = {:.2} µA, product = {}, retained = {}",
        out.i_left * 1e6,
        out.i_right * 1e6,
        out.product,
        out.retained
    );
    assert!(out.retained && cell.sram_read(&mut ledger));

    println!("\n=== 2. A full 128×512 sub-array MAC (§IV) ===");
    let mut rng = Pcg64::seeded(7);
    let mut sa = SubArray::new(Corner::TT);
    let weights: Vec<u8> = (0..ARRAY_ROWS * ARRAY_WORDS)
        .map(|_| rng.below(16) as u8)
        .collect();
    sa.load_weights(&weights);
    // Scatter cache data — it must survive.
    for row in 0..ARRAY_ROWS {
        let mut line = [0u8; 64];
        for b in line.iter_mut() {
            *b = rng.next_u64() as u8;
        }
        sa.sram_write_row(row, &line);
    }
    let snapshot = sa.sram_snapshot();
    let ia: Vec<u8> = (0..ARRAY_ROWS).map(|_| rng.below(16) as u8).collect();
    let estimates = sa.pim_mac_4b(&ia, None);
    assert_eq!(sa.sram_snapshot(), snapshot, "cache data retained");
    let exact = sa.exact_mac(&ia, 0);
    println!(
        "word 0: analog estimate {:.0} vs exact {} (ADC LSB = {:.1})",
        estimates[0],
        exact,
        1920.0 / 63.0
    );
    println!("cache data retained across the whole MAC ✓");

    println!("\n=== 3. The analog transfer curve (§V-C) ===");
    let tm = TransferModel::tt();
    for w in [0u32, 4, 8, 12, 15] {
        let mac = (w * ARRAY_ROWS as u32) as f64;
        let v = tm.sampled_voltage(mac);
        let code = tm.adc_code(v, true);
        println!("  weight {w:>2} → {:.1} mV → code {code}", v * 1e3);
    }

    println!("\n=== 4. A CNN batch through the Runtime seam (§V-E) ===");
    // The serving stack programs against the `Runtime` trait; the in-tree
    // StubRuntime backend routes variants through the digital-exact ResNet
    // forward + ADC emulation. Synthetic weights here — swap in
    // `load_variant(&ArtifactDir::open("artifacts")?, …)` for the trained
    // ones.
    let batch = 4;
    let mut rt = StubRuntime::new(batch);
    // load_variant_params is the compile step: each network is compiled
    // into a weight program once (at the depth the variant reads — these
    // fp32/emulation variants skip the 4-bit bank packing); every forward
    // below is pure prepared execution (see ARCHITECTURE.md §program).
    rt.load_variant_params(ModelVariant::Baseline, test_params(8, 10, 1))
        .expect("compile baseline");
    rt.load_variant_params(ModelVariant::Pim, test_params(8, 10, 1))
        .expect("compile pim");
    println!("runtime backend: {}", rt.platform());
    let images: Vec<f32> = (0..batch * 16 * 16 * 3).map(|_| rng.f64() as f32).collect();
    let base = rt
        .classify(ModelVariant::Baseline, &images, (16, 16, 3), 10, None)
        .expect("baseline classify");
    let pim = rt
        .classify(ModelVariant::Pim, &images, (16, 16, 3), 10, None)
        .expect("pim classify");
    println!("fp32 baseline predictions : {base:?}");
    println!("PIM-emulated predictions  : {pim:?}");
    let agree = base.iter().zip(&pim).filter(|(a, b)| a == b).count();
    println!("agreement under 6-bit ADC quantization: {agree}/{batch}");

    println!("\nenergy so far: {:.2} pJ over {:.1} ns of op time",
        ledger.total_energy() * 1e12, ledger.total_time() * 1e9);
    println!("\nNext: `repro figures --all`, `repro table2`, `repro e2e`.");
}
