//! End-to-end driver (EXPERIMENTS.md E10 / Table II): load the trained
//! model artifacts, run batched inference over the full test set through
//! the `Runtime` seam, and report Table II side-by-side with the paper —
//! proving all the layers compose (quantized kernel math → model →
//! runtime/coordinator).
//!
//! Requires the trained artifacts (see python/compile/aot.py). Run:
//!   cargo run --release --example resnet_pim_e2e

use std::time::Instant;

use nvm_in_cache::nn::Dataset;
use nvm_in_cache::runtime::{default_runtime, ArtifactDir, ModelVariant, Runtime};

fn eval(
    rt: &dyn Runtime,
    ds: &Dataset,
    variant: ModelVariant,
    batch: usize,
) -> nvm_in_cache::Result<(f64, f64)> {
    let mut correct = 0usize;
    let mut total = 0usize;
    let mut infer_s = 0.0;
    let mut start = 0usize;
    let mut batch_idx = 0u32;
    while start < ds.n {
        let take = batch.min(ds.n - start);
        let (x, labels) = ds.batch(start, take);
        let mut images = x.data.clone();
        images.resize(batch * ds.h * ds.w * ds.c, 0.0);
        batch_idx += 1;
        let key = Some([0x5EED, batch_idx]);
        let t = Instant::now();
        let preds = rt.classify(variant, &images, (ds.h, ds.w, ds.c), 10, key)?;
        infer_s += t.elapsed().as_secs_f64();
        for (p, l) in preds.iter().zip(labels.iter()) {
            correct += (p == l) as usize;
            total += 1;
        }
        start += take;
    }
    Ok((correct as f64 / total as f64, total as f64 / infer_s))
}

fn main() -> nvm_in_cache::Result<()> {
    let dir = match ArtifactDir::open("artifacts") {
        Ok(d) => d,
        Err(e) => {
            println!("NOTE: {e}");
            println!("this driver needs the trained artifacts; try the artifact-free");
            println!("`cargo run --release --example quickstart` instead.");
            return Ok(());
        }
    };
    let ds = Dataset::load(&dir.path("dataset.bin")?)?;
    let batch = dir.eval_batch();
    let mut rt = default_runtime(batch)?;
    println!(
        "platform {} | test set {} images ({}×{}×{}) | batch {}",
        rt.platform(),
        ds.n,
        ds.h,
        ds.w,
        ds.c,
        batch
    );

    let rows: Vec<(&str, ModelVariant, &str, Option<f64>)> = vec![
        ("Baseline (no ADC nonlinearity or noise)", ModelVariant::Baseline, "baseline", Some(91.84)),
        ("ADC nonlinearity only (fine-tuned)", ModelVariant::Pim, "pim_finetuned", Some(91.55)),
        ("ADC nonlinearity + noise (fine-tuned)", ModelVariant::PimNoise, "pim_finetuned_noise", Some(91.27)),
    ];

    println!("\nTable II — measured through the runtime backend:");
    println!(
        "{:<44} {:>9} {:>9} {:>8} {:>9}",
        "configuration", "measured", "manifest", "paper", "img/s"
    );
    for (name, variant, key, paper) in rows {
        let t = Instant::now();
        rt.load_variant(&dir, variant)?;
        let compile = t.elapsed().as_secs_f64();
        let (acc, ips) = eval(&rt, &ds, variant, batch)?;
        let manifest = dir.manifest.accuracy(key).unwrap_or(f64::NAN);
        println!(
            "{:<44} {:>8.2}% {:>8.2}% {:>7.2}% {:>9.1}   (compile {compile:.1}s)",
            name,
            acc * 100.0,
            manifest * 100.0,
            paper.unwrap_or(f64::NAN),
            ips
        );
    }

    // The hardware-true variant (pallas block pipeline) — the honest-ADC
    // ablation row.
    let t = Instant::now();
    rt.load_variant(&dir, ModelVariant::PimHw)?;
    let compile = t.elapsed().as_secs_f64();
    // Subset: the interpret-lowered kernel HLO is slow on CPU.
    let n_sub = 200.min(ds.n);
    let sub = Dataset {
        images: ds.batch(0, n_sub).0,
        labels: ds.labels[..n_sub].to_vec(),
        n: n_sub,
        h: ds.h,
        w: ds.w,
        c: ds.c,
    };
    let (acc_hw, ips) = eval(&rt, &sub, ModelVariant::PimHw, batch)?;
    println!(
        "{:<44} {:>8.2}% {:>8.2}% {:>7} {:>9.1}   (compile {compile:.1}s, n={n_sub})",
        "Hardware-true block pipeline (ablation)",
        acc_hw * 100.0,
        dir.manifest.accuracy("pim_hw_finetuned").unwrap_or(f64::NAN) * 100.0,
        "—",
        ips
    );

    println!(
        "\nAll layers composed: quantized kernel math → model → runtime ({}).",
        rt.platform()
    );
    Ok(())
}
