//! Serving demo: the L3 coordinator end-to-end — router, dynamic batcher,
//! bank scheduler, metrics — with the PIM model variant executed through
//! the `Runtime` seam (StubRuntime by default).
//!
//! Simulates an open-loop arrival process of single-image inference
//! requests, serves them through the batched PIM path, and reports latency
//! percentiles, batching efficiency, and the simulated hardware
//! throughput/energy of the underlying 6T-2R arrays.
//!
//! Requires the trained artifacts (weights_ft.bin + dataset.bin; see
//! python/compile/aot.py). Run:
//!   cargo run --release --example pim_serving [n_requests] [threads]
//!
//! `threads` sizes the pim::parallel worker pool the executor tiles each
//! batch's matmuls over (default 1; predictions are bit-identical at any
//! width — see PERFORMANCE.md).

use std::time::Duration;

use nvm_in_cache::cache::addr::Geometry;
use nvm_in_cache::cache::controller::PimIntegration;
use nvm_in_cache::coordinator::server::{Executor, RuntimeExecutor};
use nvm_in_cache::coordinator::{
    BankScheduler, BatcherConfig, InferenceRequest, Router, Server, ServerConfig,
};
use nvm_in_cache::nn::Dataset;
use nvm_in_cache::pim::parallel::Parallelism;
use nvm_in_cache::runtime::{default_runtime_par, ArtifactDir, ModelVariant};
use nvm_in_cache::util::rng::Pcg64;

fn main() -> nvm_in_cache::Result<()> {
    let n_requests: usize = std::env::args()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .unwrap_or(400);
    let par = Parallelism::threads(
        std::env::args().nth(2).and_then(|s| s.parse().ok()).unwrap_or(1),
    );
    let dir = match ArtifactDir::open("artifacts") {
        Ok(d) => d,
        Err(e) => {
            println!("NOTE: {e}");
            println!("this demo needs the trained artifacts; try the artifact-free");
            println!("`cargo run --release --example quickstart` instead.");
            return Ok(());
        }
    };
    let ds = Dataset::load(&dir.path("dataset.bin")?)?;
    let dims = (ds.h, ds.w, ds.c);
    let batch = dir.eval_batch();

    // Bank scheduler: the network placed on a full LLC slice, retained mode.
    let scheduler = BankScheduler::new(
        BankScheduler::resnet18_layers(16),
        Geometry::default(),
        PimIntegration::Retained,
    )
    .expect("placement fits");
    println!(
        "network placed on {} sub-array slots ({:.1}% of the slice), {} weight bits resident",
        scheduler.layout.slots_used,
        scheduler.layout.occupancy() * 100.0,
        scheduler.weight_bits_resident()
    );

    // A router stands in front (single replica here; the structure is the
    // multi-slice deployment's).
    let mut router = Router::new(1);

    let dir2 = ArtifactDir::open(dir.root.clone())?;
    let server = Server::start(
        Box::new(move || {
            let mut rt = default_runtime_par(dir2.eval_batch(), par)?;
            rt.load_variant(&dir2, ModelVariant::Pim)?;
            Ok(Box::new(RuntimeExecutor {
                runtime: rt,
                variant: ModelVariant::Pim,
                dims,
                n_classes: 10,
                key_counter: 0,
                parallelism: par,
            }) as Box<dyn Executor>)
        }),
        Some(scheduler),
        ServerConfig {
            batcher: BatcherConfig::sized(batch, Duration::from_millis(4)),
        },
    );

    println!("submitting {n_requests} requests (open loop)…");
    let stride = ds.h * ds.w * ds.c;
    let mut rng = Pcg64::seeded(99);
    let replica = router.route();
    for i in 0..n_requests {
        let idx = rng.below(ds.n);
        let img = ds.images.data[idx * stride..(idx + 1) * stride].to_vec();
        let mut req = InferenceRequest::new(i as u64, img);
        req.id = (i as u64) << 16 | idx as u64; // encode ground truth index
        server.submit(req);
        // Light pacing so the batcher sees an arrival process rather than
        // one giant burst.
        if i % 64 == 63 {
            std::thread::sleep(Duration::from_millis(2));
        }
    }
    let mut correct = 0usize;
    let mut hw_lat = 0.0f64;
    for _ in 0..n_requests {
        let r = server
            .responses
            .recv_timeout(Duration::from_secs(600))
            .map_err(|e| nvm_in_cache::Error::Runtime(e.to_string()))?;
        let idx = (r.id & 0xFFFF) as usize;
        correct += (r.predicted == ds.labels[idx]) as usize;
        hw_lat += r.hw_latency_s;
    }
    router.complete(replica, hw_lat);
    let m = server.shutdown();

    println!("\naccuracy over served traffic: {:.2}%", 100.0 * correct as f64 / n_requests as f64);
    println!("{}", m.report());
    println!(
        "simulated per-image hardware latency: {:.2} µs (ADC-bound bit-serial pipeline)",
        hw_lat / n_requests as f64 * 1e6
    );
    Ok(())
}
