//! Cache-data retention demo + flush/reload comparison (the paper's
//! architectural headline, §I contribution 4 and Table I's "Cache Data
//! Retention" row).
//!
//! Scenario: an LLC slice is serving a working set while a PIM inference
//! campaign runs in the same banks. In `Retained` mode (this paper) the
//! working set survives and keeps hitting; in `FlushReload` mode (prior 6T
//! SRAM PIM, refs [22]/[23]) every campaign evicts it — we quantify the
//! hit-rate, latency, and energy cost of that difference.
//!
//! Run: `cargo run --release --example cache_retention`

use nvm_in_cache::cache::addr::{Address, Geometry};
use nvm_in_cache::cache::controller::{CacheController, PimIntegration};
use nvm_in_cache::util::rng::Pcg64;

fn run(mode: PimIntegration) -> (f64, f64, f64, u64) {
    let geom = Geometry::tiny();
    let mut ctl = CacheController::new(geom, mode);
    let mut rng = Pcg64::seeded(11);

    // Working set: 192 lines, zipf-ish re-reference pattern.
    let working_set: Vec<Address> = (0..192u64).map(|i| Address::new(i * 64)).collect();
    for a in &working_set {
        ctl.read(*a);
    }
    // Program weights once (both modes pay this).
    for bank in 0..geom.banks_per_slice {
        ctl.program_campaign(bank, 0, vec![7u8; 128 * 128]);
    }
    ctl.slice.hits = 0;
    ctl.slice.misses = 0;

    // Interleave cache traffic with PIM campaigns.
    let mut total_latency = 0.0;
    let mut total_energy = 0.0;
    let mut lines_moved = 0u64;
    for round in 0..50 {
        // A burst of cache traffic over the working set.
        for _ in 0..64 {
            let a = working_set[rng.below(working_set.len())];
            ctl.read(a);
        }
        // A PIM campaign in a rotating bank.
        let stats = ctl.pim_campaign(round % geom.banks_per_slice, 0, 16);
        total_latency += stats.latency;
        total_energy += stats.energy;
        lines_moved += stats.lines_moved;
    }
    (ctl.slice.hit_rate(), total_latency, total_energy, lines_moved)
}

fn main() {
    println!("PIM + cache coexistence: 50 campaigns × 16 MACs, 3200 cache reads\n");
    let (hit_r, lat_r, en_r, moved_r) = run(PimIntegration::Retained);
    let (hit_f, lat_f, en_f, moved_f) = run(PimIntegration::FlushReload);

    println!("{:<26} {:>12} {:>14}", "", "Retained", "FlushReload");
    println!("{:<26} {:>11.1}% {:>13.1}%", "cache hit rate", hit_r * 100.0, hit_f * 100.0);
    println!("{:<26} {:>10.2} µs {:>12.2} µs", "PIM campaign latency", lat_r * 1e6, lat_f * 1e6);
    println!("{:<26} {:>10.2} nJ {:>12.2} nJ", "PIM campaign energy", en_r * 1e9, en_f * 1e9);
    println!("{:<26} {:>12} {:>14}", "cache lines moved", moved_r, moved_f);
    println!(
        "\nflush/reload costs {:.2}× latency and {:.2}× energy for the same MACs,",
        lat_f / lat_r,
        en_f / en_r
    );
    println!("and degrades the co-resident working set's hit rate by {:.1} points —",
        (hit_r - hit_f) * 100.0);
    println!("the overhead the 6T-2R compute-on-powerline scheme eliminates.");

    assert!(moved_r == 0, "retained mode must move nothing");
    assert!(hit_r > hit_f, "retention must preserve locality");
    assert!(lat_f > lat_r && en_f > en_r);
}
