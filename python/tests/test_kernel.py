"""L1 kernel correctness: the Pallas pim_mac kernel vs the pure-jnp oracle.

This is the CORE correctness signal for the compute hot-spot: hypothesis
sweeps shapes and integer ranges; the kernel must match ref.pim_mac to
float-accumulation tolerance (well below one ADC LSB).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile import hw_model as hw
from compile.kernels import pim_mac as pk
from compile.kernels import ref

LSB = hw.MAC_FULLSCALE / hw.ADC_CODES


def rand_int_mat(rng, m, n):
    return jnp.asarray(rng.integers(0, 16, (m, n)).astype(np.float32))


@settings(max_examples=12, deadline=None)
@given(
    m=st.integers(1, 200),
    k=st.integers(1, 300),
    n=st.integers(1, 150),
    seed=st.integers(0, 2**31),
)
def test_pallas_matches_ref_any_shape(m, k, n, seed):
    rng = np.random.default_rng(seed)
    a = rand_int_mat(rng, m, k)
    w = rand_int_mat(rng, k, n)
    got = pk.pim_mac_padded(a, w)
    want = ref.pim_mac(a, w)
    np.testing.assert_allclose(got, want, atol=0.05, rtol=0)


@pytest.mark.parametrize("corner", ["SS", "TT", "FF"])
def test_pallas_matches_ref_all_corners(corner):
    rng = np.random.default_rng(7)
    a = rand_int_mat(rng, 130, 260)
    w = rand_int_mat(rng, 260, 70)
    got = pk.pim_mac_padded(a, w, corner)
    want = ref.pim_mac(a, w, corner)
    np.testing.assert_allclose(got, want, atol=0.05, rtol=0)


def test_tile_aligned_exact_grid():
    rng = np.random.default_rng(3)
    a = rand_int_mat(rng, 256, 256)
    w = rand_int_mat(rng, 256, 256)
    got = pk.pim_mac_pallas(a, w)
    want = ref.pim_mac(a, w)
    np.testing.assert_allclose(got, want, atol=0.05, rtol=0)


def test_zero_padding_is_noop():
    """Padding K with zero rows must not change the quantized result —
    the hardware property that unused rows source no current."""
    rng = np.random.default_rng(5)
    a = rand_int_mat(rng, 64, 100)
    w = rand_int_mat(rng, 100, 30)
    unpadded = ref.pim_mac(a, w)
    a_pad = jnp.pad(a, ((0, 0), (0, 28)))
    w_pad = jnp.pad(w, ((0, 28), (0, 0)))
    padded = ref.pim_mac(a_pad, w_pad)
    np.testing.assert_allclose(unpadded, padded, atol=1e-5)


def test_quantization_error_bounded():
    """The kernel's deviation from the exact digital MAC is bounded by the
    recombined ADC quantization error."""
    rng = np.random.default_rng(9)
    a = rand_int_mat(rng, 32, 128)
    w = rand_int_mat(rng, 128, 32)
    est = ref.pim_mac(a, w)
    exact = ref.exact_mac(a, w)
    # Per plane ≤ ~1.5 LSB systematic+quant; recombined ×(1+2+4+8)=15.
    bound = 1.5 * LSB * 15
    assert float(jnp.max(jnp.abs(est - exact))) <= bound


@given(mac=st.integers(0, hw.MAC_FULLSCALE))
@settings(max_examples=60, deadline=None)
def test_adc_transfer_monotone_pointwise(mac):
    if mac == 0:
        return
    lo = ref.adc_transfer(jnp.float32(mac - 1))
    hi = ref.adc_transfer(jnp.float32(mac))
    assert float(hi) >= float(lo)


def test_transfer_endpoints_span_code_range():
    # MAC = 0 converts to code 1 (the S&H zero level sits one step inside
    # V_REFP — the systematic offset the digital post-processing removes);
    # full scale reaches code 63. f32 epsilon slack on the bound.
    assert float(ref.adc_transfer(jnp.float32(0.0))) <= LSB + 1e-3
    assert float(ref.adc_transfer(jnp.float32(hw.MAC_FULLSCALE))) >= hw.MAC_FULLSCALE - 1e-3


def test_transfer_continuous_brackets_quantized():
    """The continuous transfer is the rounding-free envelope of the
    quantized one."""
    macs = jnp.arange(0.0, 1921.0, 37.0)
    cont = ref.transfer_continuous(macs)
    quant = ref.adc_transfer(macs)
    assert float(jnp.max(jnp.abs(cont - quant))) <= LSB * 0.5 + 1e-6


def test_ff_corner_compresses():
    macs = jnp.arange(0.0, 1921.0, 64.0)
    tt = ref.transfer_continuous(macs, "TT")
    ff = ref.transfer_continuous(macs, "FF")
    # FF saturates harder at high MAC: its normalized curve bends below TT
    # mid-range after matching at the origin.
    mid = len(macs) // 2
    assert float(ff[mid]) > float(tt[mid]), "FF draws more current mid-range"


def test_vmem_tile_budget():
    """Structural L1 check (EXPERIMENTS.md §Perf): one grid step's buffers
    fit comfortably in a 16 MiB VMEM with double-buffering headroom."""
    bytes_per_step = (
        pk.TILE_M * pk.TILE_K * 4 + pk.TILE_K * pk.TILE_N * 4 + pk.TILE_M * pk.TILE_N * 4
    )
    assert bytes_per_step * 2 < 16 * 1024 * 1024 * 0.25
