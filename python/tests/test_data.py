"""Dataset generator tests: determinism, format, learnability signals."""

import numpy as np

from compile import data


def test_deterministic():
    a_img, a_lab = data.make_split(50, 123)
    b_img, b_lab = data.make_split(50, 123)
    np.testing.assert_array_equal(a_img, b_img)
    np.testing.assert_array_equal(a_lab, b_lab)


def test_different_seeds_differ():
    a_img, _ = data.make_split(20, 1)
    b_img, _ = data.make_split(20, 2)
    assert not np.array_equal(a_img, b_img)


def test_shapes_and_range():
    img, lab = data.make_split(30, 7)
    assert img.shape == (30, 16, 16, 3)
    assert img.dtype == np.float32
    assert lab.shape == (30,)
    assert float(img.min()) >= 0.0 and float(img.max()) <= 1.0
    assert lab.max() < data.N_CLASSES


def test_all_classes_present():
    _, lab = data.make_split(500, 11)
    assert len(np.unique(lab)) == data.N_CLASSES


def test_classes_are_separable():
    """Class-conditional structure must exist: per-class mean images should
    differ far more across classes than the within-class sem."""
    img, lab = data.make_split(600, 5)
    means = np.stack([img[lab == c].mean(axis=0) for c in range(data.N_CLASSES)])
    across = np.std(means, axis=0).mean()
    assert across > 0.02, f"class means indistinguishable: {across}"


def test_train_test_disjoint_seeds():
    (xtr, _), (xte, _) = data.train_test(100, 50, seed=9)
    # No identical images across splits.
    flat_tr = xtr.reshape(len(xtr), -1)
    flat_te = xte.reshape(len(xte), -1)
    for row in flat_te[:10]:
        assert not np.any(np.all(np.isclose(flat_tr, row, atol=1e-7), axis=1))


def test_dataset_bin_format(tmp_path):
    img, lab = data.make_split(8, 3)
    p = tmp_path / "d.bin"
    data.write_dataset_bin(str(p), img, lab)
    raw = p.read_bytes()
    header = np.frombuffer(raw[:20], np.uint32)
    assert header[0] == 0x4E564D43
    assert tuple(header[1:]) == (8, 16, 16, 3)
    back = np.frombuffer(raw[20 : 20 + img.size * 4], "<f4").reshape(img.shape)
    np.testing.assert_allclose(back, img, rtol=1e-6)
    assert raw[20 + img.size * 4 :] == lab.tobytes()
