"""L2 model tests: shapes, quantizers, emulation, STE gradients, noise."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile import model
from compile import hw_model as hw


@pytest.fixture(scope="module")
def params():
    return model.init_params(jax.random.PRNGKey(0), width=8)


@pytest.fixture(scope="module")
def x():
    rng = np.random.default_rng(1)
    return jnp.asarray(rng.random((4, 16, 16, 3)).astype(np.float32))


@pytest.mark.parametrize("mode", ["baseline", "pim", "pim_hw"])
def test_forward_shapes(params, x, mode):
    logits = model.forward(params, x, mode)
    assert logits.shape == (4, 10)
    assert bool(jnp.all(jnp.isfinite(logits)))


@pytest.mark.parametrize("mode", ["pim_noise", "pim_hw_noise"])
def test_noise_modes_need_key_and_are_deterministic(params, x, mode):
    with pytest.raises(AssertionError):
        model.forward(params, x, mode)
    k = jax.random.PRNGKey(7)
    a = model.forward(params, x, mode, key=k, sigma_codes=0.3)
    b = model.forward(params, x, mode, key=k, sigma_codes=0.3)
    c = model.forward(params, x, mode, key=jax.random.PRNGKey(8), sigma_codes=0.3)
    np.testing.assert_array_equal(a, b)
    assert not np.array_equal(np.asarray(a), np.asarray(c))


def test_param_count_resnet18_width16():
    p = model.init_params(jax.random.PRNGKey(0), width=16)
    n = model.param_count(p)
    # ResNet-18 topology at width 16 ≈ 0.7 M params.
    assert 6e5 < n < 8e5, n


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 2**31))
def test_quant_act_bounds(seed):
    rng = np.random.default_rng(seed)
    a = jnp.asarray(rng.random((5, 7)).astype(np.float32) * rng.uniform(0.1, 10))
    q, s = model.quant_act(a)
    assert float(jnp.min(q)) >= 0 and float(jnp.max(q)) <= 15
    err = jnp.abs(q * s - a)
    assert float(jnp.max(err)) <= float(s) * 0.5 + 1e-5


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 2**31))
def test_quant_weight_per_column(seed):
    rng = np.random.default_rng(seed)
    w = jnp.asarray((rng.random((9, 4)) - 0.5).astype(np.float32))
    pos, neg, s = model.quant_weight(w)
    assert s.shape == (1, 4)
    # Banks disjoint, reconstruction within half a step per column.
    assert float(jnp.max(pos * neg)) == 0.0
    recon = (pos - neg) * s
    assert float(jnp.max(jnp.abs(recon - w))) <= float(jnp.max(s)) * 0.5 + 1e-6


def test_adc_emulate_monotone_and_bounded():
    emu = model.make_adc_emulate("TT")
    y = jnp.linspace(-3.0, 3.0, 301)
    z = np.asarray(emu(y))
    assert np.all(np.diff(z) >= -1e-6), "emulation must be monotone"
    assert np.max(np.abs(z)) <= 3.0 * (32.0 / 31.0) + 1e-5


def test_adc_emulate_ste_gradient():
    emu = model.make_adc_emulate("TT")
    g = jax.grad(lambda y: jnp.sum(emu(y)))(jnp.ones((5,)) * 0.7)
    np.testing.assert_allclose(np.asarray(g), 1.0)  # straight-through


def test_pim_matmul_ste_gradient_is_dense():
    mm = model.make_pim_matmul("TT")
    a = jnp.abs(jnp.asarray(np.random.default_rng(0).random((6, 16)).astype(np.float32)))
    w = jnp.asarray((np.random.default_rng(1).random((16, 3)) - 0.5).astype(np.float32))
    ga = jax.grad(lambda a: jnp.sum(mm(a, w)))(a)
    # STE backward: d/da sum(a @ w) = row-broadcast of sum_j w.
    expect = jnp.broadcast_to(jnp.sum(w, axis=1), (6, 16))
    np.testing.assert_allclose(np.asarray(ga), np.asarray(expect), rtol=1e-5)


def test_noise_sigma_out_formula():
    # σ_out² = σ² · LSB² · 2 · blocks · Σ4^b — check against brute force.
    k = 300
    sigma = 0.4
    lsb = hw.MAC_FULLSCALE / hw.ADC_CODES
    blocks = (k + hw.N_ROWS - 1) // hw.N_ROWS
    plane = sum(4.0**b for b in range(hw.ACT_BITS))
    expect = sigma * lsb * np.sqrt(2 * blocks * plane)
    got = model.noise_sigma_out(k, sigma)
    np.testing.assert_allclose(got, expect, rtol=1e-12)


def test_pim_mode_close_to_baseline(params, x):
    """The §V-E emulation is a mild perturbation (the basis of the paper's
    small Table II deltas)."""
    base = model.forward(params, x, "baseline")
    pim = model.forward(params, x, "pim")
    rel = float(jnp.mean(jnp.abs(base - pim)) / (jnp.mean(jnp.abs(base)) + 1e-9))
    assert rel < 0.5, rel


def test_weights_bin_roundtrip(tmp_path, params):
    path = tmp_path / "w.bin"
    model.write_weights_bin(str(path), params)
    raw = path.read_bytes()
    assert raw[:4] == (0x4E564D57).to_bytes(4, "little")
    leaves = model.flatten_params(params)
    # count field matches
    assert int.from_bytes(raw[4:8], "little") == len(leaves)
