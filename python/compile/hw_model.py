"""Shared hardware model constants and the analog transfer function.

This file is the *single source of truth on the Python side* for the 6T-2R
PIM analog pipeline:

    weight-sum (integer MAC)  ->  powerline current  ->  sampled voltage
    ->  6-bit SAR ADC code    ->  inverse-mapped MAC estimate

It mirrors ``rust/src/pim/transfer.rs`` (the Rust side is the authoritative
circuit-derived model; the constants here are the same closed-form fit).
Cross-language agreement is enforced by ``rust/tests/runtime_crosscheck.rs``
which runs the AOT-exported kernel HLO against the Rust engine.

Paper anchors (Section V):
  * sub-array: 128 rows, 4-bit words, WCC ratio 8:4:2:1  -> per-plane MAC
    range 0 .. 128*15 = 1920;
  * 6-bit SAR ADC, calibrated refs giving ~4 ADC codes per weight step and
    the full 0..63 code range (Fig. 12);
  * FF-corner compression of the current curve (Fig. 11a), modeled as
    line-loading saturation I_eff = I/(1 + I*R_load/V_swing).
"""

import numpy as np

# ---- device / array constants (paper Section V) ----
VDD = 0.8
R_LRS = 25.0e3
R_HRS = 1.2e6
N_ROWS = 128
WORD_BITS = 4
ACT_BITS = 4
ADC_BITS = 6
ADC_CODES = (1 << ADC_BITS) - 1  # 63
MAC_FULLSCALE = N_ROWS * (2**WORD_BITS - 1)  # 1920 per bit-plane

# ---- analog path (matches rust pim/transfer.rs `TransferModel::default`) ----
# WCC reference the active powerline is pulled to during sampling.
V_REF = 0.30
# Per-cell LRS unit current at TT: (VDD - V_REF) / (R_LRS + R_FETS).
R_FETS_TT = 6.0e3
I_UNIT_TT = (VDD - V_REF) / (R_LRS + R_FETS_TT)  # ~16.1 uA
# HRS leakage current ratio (ON/OFF ~ 43x within the stack).
I_HRS_RATIO = (R_LRS + R_FETS_TT) / (R_HRS + R_FETS_TT)
# Line/WCC input loading that compresses large currents (FF-corner knob):
# effective series resistance seen by the summed column current before the
# mirror. FF's stronger drive raises both the unit current and the mirror's
# input-stage droop, hence the larger value (Fig. 11a).
R_LOAD = {"SS": 0.6, "TT": 0.8, "FF": 3.2}  # ohms
# Transimpedance of the WCC mirror + sample cap (V per A).
# Calibrated so the sampled voltage spans [~0.092, ~0.655] V over the
# full per-plane MAC range at TT (Fig. 12 calibrated refs 90/660 mV).
V_SAMP_MAX = 0.655  # at MAC = 0
V_SAMP_MIN = 0.092  # at MAC = MAC_FULLSCALE

# Calibrated / uncalibrated ADC references (Fig. 12).
V_REFP_CAL = 0.660
V_REFN_CAL = 0.090
V_REF_UNCAL = 0.800


def line_current(mac, corner: str = "TT"):
    """Powerline current (A) for an integer weighted MAC value per plane.

    ``mac`` may be a numpy/jax array. The corner scales the unit current
    (drive strength) and the loading compression, reproducing Fig. 11(a):
    TT/SS near-linear, FF visibly compressive.
    """
    scale = {"SS": 0.80, "TT": 1.00, "FF": 1.25}[corner]
    i_ideal = mac * I_UNIT_TT * scale
    # Background HRS leakage of the remaining (inactive/HRS) cells is
    # folded into the offset V_SAMP_MAX calibration, so it is omitted here.
    v_swing = VDD - V_REF
    # Self-loading: the summed current drops part of the swing across the
    # line + mirror input stage, compressing large MACs (worst at FF).
    denom = 1.0 + i_ideal * R_LOAD[corner] / v_swing
    return i_ideal / denom


def sampled_voltage(mac, corner: str = "TT"):
    """Sample-and-hold output voltage: V = V0 - R_ti * I (paper: VDD - MAC).

    The transimpedance R_ti is fixed by the TT calibration (the WCC/S&H is
    trimmed once, at the typical corner), so SS/FF shift and bend the curve
    exactly as in Fig. 10.
    """
    i = line_current(mac, corner)
    i_fs_tt = line_current(float(MAC_FULLSCALE), "TT")
    r_ti = (V_SAMP_MAX - V_SAMP_MIN) / i_fs_tt
    return V_SAMP_MAX - r_ti * i


def adc_code(v, calibrated: bool = True):
    """6-bit SAR ADC: uniform quantization between the references.

    Returns the *post-processing inverted* code (monotone increasing with
    MAC), matching Fig. 12's transfer curves.
    """
    if calibrated:
        lo, hi = V_REFN_CAL, V_REFP_CAL
    else:
        lo, hi = 0.0, V_REF_UNCAL
    x = (v - lo) / (hi - lo)
    code = np.clip(np.round(x * ADC_CODES), 0, ADC_CODES)
    return ADC_CODES - code  # invert: V = VDD - MAC


def mac_estimate_from_code(code):
    """Inverse linear mapping of an ADC code back to the MAC dynamic range
    (Section V-E: 'values were inversely mapped back to their original
    dynamic range')."""
    return code * (MAC_FULLSCALE / ADC_CODES)


def transfer_polynomial(degree: int = 3, corner: str = "TT"):
    """Least-squares polynomial fit of mac -> sampled voltage, i.e. the
    'curve-fitted polynomial derived from simulation' of Section V-E."""
    mac = np.arange(0, MAC_FULLSCALE + 1, 16, dtype=np.float64)
    v = sampled_voltage(mac, corner)
    return np.polyfit(mac, v, degree)[::-1]  # ascending coefficients


# Default Monte-Carlo noise sigma on the sampled voltage (V), matching the
# Rust variation model's 128-row output spread (Fig. 13a). Scaled to the
# activation dynamic range in the model per Section V-E.
SIGMA_V_MC = 2.4e-3
