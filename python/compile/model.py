"""L2: ResNet-18-topology CNN whose convolutions execute on the PIM MAC.

Three forward variants (Table II):
  * ``baseline``  — fp32 dense convolutions (the paper's 91.84% row);
  * ``pim``       — every conv/fc routed through the 6T-2R analog pipeline:
                    4-bit activation/weight quantization, positive/negative
                    weight banks (§IV-C), per-128-row-block 6-bit ADC with
                    the fitted nonlinear transfer (§V-E);
  * ``pim_noise`` — ``pim`` + the Monte-Carlo-derived Gaussian ADC noise.

Architecture: ResNet-18 BasicBlock topology [2,2,2,2], base width 16
(CIFAR-style 3x3 stem, no max-pool), GroupNorm instead of BatchNorm so the
network is a pure function of (params, x) — required for clean AOT export.

Training uses the straight-through estimator: the PIM forward is exact, the
backward is the dense-matmul gradient (``pim_matmul``'s custom_vjp).
"""

import functools

import jax
import jax.numpy as jnp
import numpy as np

from . import hw_model as hw
from .kernels import pim_mac as pk
from .kernels import ref

# ---------------------------------------------------------------- quantizers


def quant_act(a):
    """Unsigned 4-bit activation quantization (post-ReLU inputs >= 0).
    Returns (int levels in [0,15], scale). Dynamic per-tensor scale."""
    s = jax.lax.stop_gradient(jnp.maximum(jnp.max(a), 1e-6) / 15.0)
    q = jnp.clip(jnp.round(a / s), 0, 15)
    return q, s


def quant_weight(w):
    """Signed 4-bit weight quantization with *per-output-column* scales
    (the digital rescale after the subtractor is per column, so per-channel
    scaling is free in this architecture), split into positive/negative
    banks (§IV-C: 'separate memory banks are designated for each').
    w: [K, N] -> (pos [K,N], neg [K,N], scale [1,N])."""
    s = jax.lax.stop_gradient(
        jnp.maximum(jnp.max(jnp.abs(w), axis=0, keepdims=True), 1e-6) / 15.0
    )
    q = jnp.clip(jnp.round(w / s), -15, 15)
    return jnp.maximum(q, 0.0), jnp.maximum(-q, 0.0), s


def n_k_blocks(k: int) -> int:
    return (k + hw.N_ROWS - 1) // hw.N_ROWS


def noise_sigma_out(k: int, sigma_codes: float) -> float:
    """Equivalent output-referred ADC-noise sigma.

    Per-conversion code noise n ~ N(0, sigma) enters each (block, bit-plane)
    partial sum; the digital recombination sums 2^b-weighted independent
    Gaussians over 4 planes x n_blocks x {pos, neg} banks, so the exact
    equivalent is a single Gaussian with
        sigma_out = sigma * LSB * sqrt(2 * n_blocks * sum_b 4^b).
    (Distribution-exact, so we inject it once on the output — this keeps the
    custom_vjp forward deterministic.)
    """
    lsb = hw.MAC_FULLSCALE / hw.ADC_CODES
    plane_gain = sum(4.0**b for b in range(hw.ACT_BITS))  # 85
    return sigma_codes * lsb * np.sqrt(2.0 * n_k_blocks(k) * plane_gain)


# 6-bit signed ADC output range (paper §V-E: "6-bit signed output range").
ADC_SIGNED_MAX = 31.0


def make_adc_emulate(corner: str = "TT"):
    """Paper-faithful Table II emulation (§V-E): per-layer activations are
    mapped into the 6-bit signed range, passed through the curve-fitted
    nonlinear transfer, quantized, and inversely mapped back. Straight-
    through gradients for fine-tuning.

    This is the methodology the paper itself used for the accuracy study;
    the *hardware-true* per-block/per-plane pipeline is `make_pim_matmul`
    (mode 'pim_hw'), reported as an extra ablation in EXPERIMENTS.md.
    """

    @jax.custom_vjp
    def emulate(y):
        s = jax.lax.stop_gradient(
            jnp.maximum(jnp.max(jnp.abs(y)), 1e-6) / ADC_SIGNED_MAX
        )
        u = y / s  # in [-31, 31]
        mac = jnp.abs(u) * (hw.MAC_FULLSCALE / ADC_SIGNED_MAX)
        u_nl = jnp.sign(u) * ref.transfer_continuous(mac, corner) * (
            ADC_SIGNED_MAX / hw.MAC_FULLSCALE
        )
        code = jnp.clip(jnp.round(u_nl), -ADC_SIGNED_MAX - 1, ADC_SIGNED_MAX)
        return code * s

    def fwd(y):
        return emulate(y), None

    def bwd(_, g):
        return (g,)

    emulate.defvjp(fwd, bwd)
    return emulate


def make_pim_matmul(corner: str = "TT", use_pallas: bool = False):
    """Build the STE-wrapped quantized PIM matmul.

    Forward: exact analog-pipeline simulation (pallas kernel or jnp oracle —
    numerically interchangeable, pytest-enforced). Backward: dense matmul
    gradients (straight-through).
    """
    mac = pk.pim_mac_padded if use_pallas else functools.partial(ref.pim_mac)

    @jax.custom_vjp
    def pim_matmul(a, w):
        aq, sa = quant_act(a)
        wp, wn, sw = quant_weight(w)
        pos = mac(aq, wp, corner)
        neg = mac(aq, wn, corner)
        return (pos - neg) * (sa * sw)

    def fwd(a, w):
        return pim_matmul(a, w), (a, w)

    def bwd(res, g):
        a, w = res
        return g @ w.T, a.T @ g

    pim_matmul.defvjp(fwd, bwd)
    return pim_matmul


# ------------------------------------------------------------------- layers


def group_norm(x, gamma, beta, groups: int = 8, eps: float = 1e-5):
    """GroupNorm over NHWC (stateless BatchNorm stand-in)."""
    n, h, w, c = x.shape
    g = min(groups, c)
    xg = x.reshape(n, h, w, g, c // g)
    mean = xg.mean(axis=(1, 2, 4), keepdims=True)
    var = xg.var(axis=(1, 2, 4), keepdims=True)
    xg = (xg - mean) / jnp.sqrt(var + eps)
    return xg.reshape(n, h, w, c) * gamma + beta


def conv2d(x, w, stride: int, pim_mm=None, key=None, sigma_codes=None):
    """3x3/1x1 'same' convolution.

    Dense path: lax.conv. PIM path: im2col -> pim_matmul (each patch row is
    a wordline activation vector; K = kh*kw*cin splits into 128-row
    sub-array blocks exactly as the IFM-reuse mapping lays them out).
    """
    kh, kw, cin, cout = w.shape
    if pim_mm is None:
        return jax.lax.conv_general_dilated(
            x, w, (stride, stride), "SAME", dimension_numbers=("NHWC", "HWIO", "NHWC")
        )
    patches = jax.lax.conv_general_dilated_patches(
        x, (kh, kw), (stride, stride), "SAME", dimension_numbers=("NHWC", "HWIO", "NHWC")
    )  # [N, H', W', cin*kh*kw]
    n, ho, wo, kdim = patches.shape
    a2d = patches.reshape(n * ho * wo, kdim)
    # conv_general_dilated_patches emits features as cin*kh*kw (channel-major);
    # reorder the weight tensor to match.
    w2d = jnp.transpose(w, (2, 0, 1, 3)).reshape(kdim, cout)
    out = pim_mm(a2d, w2d)
    if sigma_codes is not None and key is not None:
        sig = noise_sigma_out(kdim, sigma_codes)
        # Scale by the dequantization scales the same way the signal is
        # (per-column weight scales broadcast over the output columns).
        aq_s = jax.lax.stop_gradient(jnp.maximum(jnp.max(a2d), 1e-6) / 15.0)
        w_s = jax.lax.stop_gradient(
            jnp.maximum(jnp.max(jnp.abs(w2d), axis=0, keepdims=True), 1e-6) / 15.0
        )
        out = out + jax.random.normal(key, out.shape) * (sig * aq_s * w_s)
    return out.reshape(n, ho, wo, cout)


# ------------------------------------------------------------------ network

STAGES = (2, 2, 2, 2)  # ResNet-18 BasicBlock counts


def init_params(key, width: int = 16, n_classes: int = 10):
    """He-initialized parameter pytree (nested dicts)."""
    params = {}

    def conv_init(key, kh, kw, cin, cout):
        fan_in = kh * kw * cin
        return jax.random.normal(key, (kh, kw, cin, cout)) * np.sqrt(2.0 / fan_in)

    keys = iter(jax.random.split(key, 200))
    params["stem"] = {
        "w": conv_init(next(keys), 3, 3, 3, width),
        "gamma": jnp.ones((width,)),
        "beta": jnp.zeros((width,)),
    }
    cin = width
    for s, nblocks in enumerate(STAGES):
        cout = width * (2**s)
        stride = 1 if s == 0 else 2
        for b in range(nblocks):
            st = stride if b == 0 else 1
            blk = {
                "w1": conv_init(next(keys), 3, 3, cin, cout),
                "g1": jnp.ones((cout,)),
                "b1": jnp.zeros((cout,)),
                "w2": conv_init(next(keys), 3, 3, cout, cout),
                "g2": jnp.ones((cout,)),
                "b2": jnp.zeros((cout,)),
            }
            if st != 1 or cin != cout:
                blk["wd"] = conv_init(next(keys), 1, 1, cin, cout)
            params[f"s{s}b{b}"] = blk
            cin = cout
    params["fc"] = {
        "w": jax.random.normal(next(keys), (cin, n_classes)) * np.sqrt(1.0 / cin),
        "b": jnp.zeros((n_classes,)),
    }
    return params


def forward(
    params,
    x,
    mode: str = "baseline",
    key=None,
    corner: str = "TT",
    sigma_codes: float | None = None,
    use_pallas: bool = False,
):
    """Model forward pass. x: [N,16,16,3] in [0,1]. Returns logits [N,10].

    Modes:
      'baseline'     — dense fp32;
      'pim'          — the paper's §V-E Table II emulation: exact conv, then
                       per-layer 6-bit-signed ADC transfer (nonlinearity +
                       quantization), inverse-mapped back;
      'pim_noise'    — 'pim' + Gaussian ADC noise scaled to the dynamic
                       range (σ in code units);
      'pim_hw'       — the hardware-true pipeline: 4-bit quantized matmuls
                       with per-128-row-block, per-bit-plane 6-bit ADC
                       conversions (the L1 pallas kernel path);
      'pim_hw_noise' — 'pim_hw' + per-conversion noise.
    """
    pim_mm, emu, sigma = None, None, None
    if mode == "baseline":
        pass
    elif mode in ("pim", "pim_noise"):
        emu = make_adc_emulate(corner)
        if mode == "pim_noise":
            sigma = sigma_codes if sigma_codes is not None else 0.5
            assert key is not None, "pim_noise requires a PRNG key"
    elif mode in ("pim_hw", "pim_hw_noise"):
        pim_mm = make_pim_matmul(corner, use_pallas)
        if mode == "pim_hw_noise":
            sigma = sigma_codes if sigma_codes is not None else 0.5
            assert key is not None, "pim_hw_noise requires a PRNG key"
    else:
        raise ValueError(mode)

    nkeys = 64
    keys = list(jax.random.split(key, nkeys)) if key is not None else [None] * nkeys
    ki = iter(keys)
    hw_sigma = sigma if pim_mm is not None else None

    def post(y, k):
        """ADC emulation applied at each layer output (emu modes)."""
        if emu is None:
            return y
        z = emu(y)
        if sigma is not None and k is not None:
            s = jax.lax.stop_gradient(
                jnp.maximum(jnp.max(jnp.abs(y)), 1e-6) / ADC_SIGNED_MAX
            )
            z = z + jax.random.normal(k, y.shape) * (sigma * s)
        return z

    p = params["stem"]
    h = post(conv2d(x, p["w"], 1, pim_mm, next(ki), hw_sigma), next(ki))
    h = jax.nn.relu(group_norm(h, p["gamma"], p["beta"]))
    cin = h.shape[-1]
    width = cin
    for s, nblocks in enumerate(STAGES):
        cout = width * (2**s)
        stride = 1 if s == 0 else 2
        for b in range(nblocks):
            st = stride if b == 0 else 1
            blk = params[f"s{s}b{b}"]
            idn = h
            h = post(conv2d(h, blk["w1"], st, pim_mm, next(ki), hw_sigma), next(ki))
            h = jax.nn.relu(group_norm(h, blk["g1"], blk["b1"]))
            h = post(conv2d(h, blk["w2"], 1, pim_mm, next(ki), hw_sigma), next(ki))
            h = group_norm(h, blk["g2"], blk["b2"])
            if "wd" in blk:
                idn = post(conv2d(idn, blk["wd"], st, pim_mm, next(ki), hw_sigma), next(ki))
            h = jax.nn.relu(h + idn)
    h = h.mean(axis=(1, 2))  # global average pool
    fc = params["fc"]
    if pim_mm is not None:
        logits = pim_mm(jax.nn.relu(h), fc["w"]) + fc["b"]
        if hw_sigma is not None:
            sig = noise_sigma_out(h.shape[-1], hw_sigma)
            a_s = jnp.maximum(jnp.max(jax.nn.relu(h)), 1e-6) / 15.0
            w_s = jnp.maximum(
                jnp.max(jnp.abs(fc["w"]), axis=0, keepdims=True), 1e-6
            ) / 15.0
            logits = logits + jax.random.normal(next(ki), logits.shape) * (
                sig * a_s * w_s
            )
    else:
        logits = post(h @ fc["w"], next(ki)) + fc["b"]
    return logits


def param_count(params) -> int:
    return int(sum(np.prod(p.shape) for p in jax.tree_util.tree_leaves(params)))


# Flat, deterministic parameter ordering for weights.bin (rust reads this).
def flatten_params(params):
    """Returns [(name, array)] sorted lexicographically by name."""
    leaves = []

    def rec(prefix, node):
        if isinstance(node, dict):
            for k in sorted(node):
                rec(f"{prefix}/{k}" if prefix else k, node[k])
        else:
            leaves.append((prefix, np.asarray(node)))

    rec("", params)
    return leaves


def write_weights_bin(path: str, params):
    """weights.bin: u32 magic 'NVMW', u32 count, then per tensor:
    u32 name_len, name bytes, u32 ndim, u32 dims..., f32 data."""
    leaves = flatten_params(params)
    with open(path, "wb") as f:
        np.array([0x4E564D57, len(leaves)], np.uint32).tofile(f)
        for name, arr in leaves:
            nb = name.encode()
            np.array([len(nb)], np.uint32).tofile(f)
            f.write(nb)
            np.array([arr.ndim], np.uint32).tofile(f)
            np.array(arr.shape, np.uint32).tofile(f)
            arr.astype("<f4").tofile(f)
