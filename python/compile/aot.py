"""AOT export: train, fine-tune, and lower everything to HLO text.

Python runs ONLY here (``make artifacts``). Outputs (see DESIGN.md §5):

    artifacts/
      manifest.txt            key=value metadata + Table II accuracies
      model_baseline.hlo.txt  fp32 fwd      f32[B,16,16,3] -> (f32[B,10],)
      model_pim.hlo.txt       PIM fwd (pallas kernel inlined, fine-tuned w)
      model_pim_noise.hlo.txt PIM fwd + ADC noise; extra input u32[2] key
      pim_mac.hlo.txt         standalone L1 kernel tile (a,w f32[128,128])
      weights.bin / weights_ft.bin
      dataset.bin             test split for the Rust e2e driver
      loss_curve.csv          training + fine-tune loss curves

HLO *text* is the interchange format (not serialized protos): jax >= 0.5
emits 64-bit instruction ids which xla_extension 0.5.1 rejects; the text
parser reassigns ids (see /opt/xla-example/README.md).
"""

import argparse
import os
import time

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import data, hw_model, model, train
from .kernels import pim_mac as pk

EVAL_BATCH = 50


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    # print_large_constants: the default printer elides big literals as
    # `{...}`, which silently drops the baked-in weights — the Rust side
    # would compile a garbage model.
    return comp.as_hlo_text(print_large_constants=True)


def export_fn(fn, example_args, path: str):
    lowered = jax.jit(fn).lower(*example_args)
    text = to_hlo_text(lowered)
    with open(path, "w") as f:
        f.write(text)
    print(f"  wrote {path} ({len(text) / 1e6:.2f} MB)", flush=True)


# ---- checkpoint (so `make artifacts` never retrains unnecessarily) ----


def save_checkpoint(path, params, params_ft, results, base_curve, ft_curve):
    flat = {f"base::{n}": a for n, a in model.flatten_params(params)}
    flat.update({f"ft::{n}": a for n, a in model.flatten_params(params_ft)})
    flat["curve_base"] = np.asarray(base_curve, np.float64)
    flat["curve_ft"] = np.asarray(ft_curve, np.float64)
    flat["results_keys"] = np.array(
        [k for k in results if k != "noise_sweep"], dtype=object
    )
    flat["results_vals"] = np.array(
        [float(results[k]) for k in results if k != "noise_sweep"]
    )
    sweep = results.get("noise_sweep", {})
    flat["sweep_sigmas"] = np.array(sorted(sweep))
    flat["sweep_accs"] = np.array([sweep[s] for s in sorted(sweep)])
    np.savez(path, **flat, allow_pickle=True)


def load_checkpoint(path):
    z = np.load(path, allow_pickle=True)

    def unflatten(prefix):
        params = {}
        for key in z.files:
            if not key.startswith(prefix):
                continue
            name = key[len(prefix):]
            parts = name.split("/")
            node = params
            for p in parts[:-1]:
                node = node.setdefault(p, {})
            node[parts[-1]] = jnp.asarray(z[key])
        return params

    results = dict(zip(list(z["results_keys"]), [float(v) for v in z["results_vals"]]))
    results["noise_sweep"] = dict(
        zip([float(s) for s in z["sweep_sigmas"]], [float(a) for a in z["sweep_accs"]])
    )
    base_curve = [(int(a), float(b)) for a, b in z["curve_base"]]
    ft_curve = [(int(a), float(b)) for a, b in z["curve_ft"]]
    return unflatten("base::"), unflatten("ft::"), results, base_curve, ft_curve


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--quick", action="store_true", help="tiny run for smoke tests")
    ap.add_argument("--retrain", action="store_true", help="ignore cached checkpoint")
    ap.add_argument("--seed", type=int, default=42)
    args = ap.parse_args()
    os.makedirs(args.out, exist_ok=True)

    if args.quick:
        n_train, n_test, be, fe = 600, 200, 2, 1
    else:
        n_train, n_test, be, fe = 4000, 1000, 15, 6

    t0 = time.time()
    ckpt = os.path.join(args.out, "checkpoint.npz")
    if os.path.exists(ckpt) and not args.retrain:
        print(f"[aot] reusing cached training checkpoint {ckpt}", flush=True)
        params, params_ft, results, base_curve, ft_curve = load_checkpoint(ckpt)
        (_, _), (xte, yte) = data.train_test(n_train, n_test)
    else:
        print(f"[aot] training protocol (train={n_train} test={n_test})", flush=True)
        results, params, params_ft, (base_curve, ft_curve), splits = train.run_full_protocol(
            n_train=n_train, n_test=n_test, baseline_epochs=be, ft_epochs=fe, seed=args.seed
        )
        (_, _), (xte, yte) = splits
        save_checkpoint(ckpt, params, params_ft, results, base_curve, ft_curve)

    # ---- binary artifacts ----
    model.write_weights_bin(os.path.join(args.out, "weights.bin"), params)
    model.write_weights_bin(os.path.join(args.out, "weights_ft.bin"), params_ft)
    data.write_dataset_bin(os.path.join(args.out, "dataset.bin"), xte, yte)
    with open(os.path.join(args.out, "loss_curve.csv"), "w") as f:
        f.write("phase,step,loss\n")
        for it, l in base_curve:
            f.write(f"baseline,{it},{l}\n")
        for it, l in ft_curve:
            f.write(f"finetune,{it},{l}\n")

    # ---- HLO exports ----
    b = min(EVAL_BATCH, n_test)
    x_spec = jax.ShapeDtypeStruct((b, data.IMG, data.IMG, data.CHANNELS), jnp.float32)
    key_spec = jax.ShapeDtypeStruct((2,), jnp.uint32)

    print("[aot] lowering model variants to HLO text", flush=True)
    export_fn(
        lambda x: (model.forward(params, x, "baseline"),),
        (x_spec,),
        os.path.join(args.out, "model_baseline.hlo.txt"),
    )
    # Table II emulation variant (§V-E methodology).
    export_fn(
        lambda x: (model.forward(params_ft, x, "pim"),),
        (x_spec,),
        os.path.join(args.out, "model_pim.hlo.txt"),
    )
    # Hardware-true variant: every conv/fc routed through the L1 pallas
    # kernel so the kernel lowers into the same HLO (three-layer stack).
    export_fn(
        lambda x: (model.forward(params_ft, x, "pim_hw", use_pallas=True),),
        (x_spec,),
        os.path.join(args.out, "model_pim_hw.hlo.txt"),
    )
    sigma = float(results.get("sigma_codes", 0.1))
    export_fn(
        lambda x, key: (
            model.forward(
                params_ft,
                x,
                "pim_noise",
                key=jax.random.wrap_key_data(key, impl="threefry2x32"),
                sigma_codes=sigma,
                use_pallas=True,
            ),
        ),
        (x_spec, key_spec),
        os.path.join(args.out, "model_pim_noise.hlo.txt"),
    )
    # Standalone L1 kernel tile for the Rust cross-check.
    tile_spec = jax.ShapeDtypeStruct((128, 128), jnp.float32)
    export_fn(
        lambda a, w: (pk.pim_mac_pallas(a, w, "TT"),),
        (tile_spec, tile_spec),
        os.path.join(args.out, "pim_mac.hlo.txt"),
    )

    # ---- manifest ----
    poly = hw_model.transfer_polynomial(3, "TT")
    lines = {
        "seed": args.seed,
        "quick": int(args.quick),
        "n_train": n_train,
        "n_test": n_test,
        "eval_batch": b,
        "img": data.IMG,
        "channels": data.CHANNELS,
        "n_classes": data.N_CLASSES,
        "param_count": model.param_count(params),
        "acc_baseline": f"{results['baseline']:.4f}",
        "acc_pim_no_finetune": f"{results['pim_no_finetune']:.4f}",
        "acc_pim_noise_no_finetune": f"{results.get('pim_noise_no_finetune', -1):.4f}",
        "acc_pim_finetuned": f"{results['pim_finetuned']:.4f}",
        "acc_pim_finetuned_noise": f"{results['pim_finetuned_noise']:.4f}",
        "acc_pim_hw_no_finetune": f"{results.get('pim_hw_no_finetune', -1):.4f}",
        "acc_pim_hw_finetuned": f"{results.get('pim_hw_finetuned', -1):.4f}",
        "sigma_codes": sigma,
        "noise_sweep": ";".join(
            f"{s}:{a:.4f}" for s, a in sorted(results.get("noise_sweep", {}).items())
        ),
        "adc_bits": hw_model.ADC_BITS,
        "mac_fullscale": hw_model.MAC_FULLSCALE,
        "transfer_poly_tt": ",".join(f"{c:.8e}" for c in poly),
        "build_seconds": f"{time.time() - t0:.0f}",
    }
    with open(os.path.join(args.out, "manifest.txt"), "w") as f:
        for k, v in lines.items():
            f.write(f"{k}={v}\n")
    print(f"[aot] done in {time.time() - t0:.0f}s; results: {results}", flush=True)


if __name__ == "__main__":
    main()
