"""Deterministic synthetic CIFAR-like dataset.

Substitution for CIFAR-10 (DESIGN.md §2: no network/dataset access in this
environment): 10 visually distinct classes of 16x16 RGB images built from
class-conditional oriented gratings + colored blobs, with per-sample phase,
position, amplitude jitter and additive noise. Difficulty is tuned so a
small ResNet lands in the low-90s — the same regime as the paper's
ResNet-18/CIFAR-10 baseline (91.84%) — making the Table II accuracy *deltas*
meaningful.
"""

import numpy as np

IMG = 16
CHANNELS = 3
N_CLASSES = 10


def make_split(n: int, seed: int):
    """Generate `n` (image, label) pairs. Returns (images [n,16,16,3] f32 in
    [0,1], labels [n] uint8)."""
    rng = np.random.default_rng(seed)
    labels = rng.integers(0, N_CLASSES, n).astype(np.uint8)
    images = np.zeros((n, IMG, IMG, CHANNELS), np.float32)
    yy, xx = np.meshgrid(np.arange(IMG), np.arange(IMG), indexing="ij")
    for i in range(n):
        c = int(labels[i])
        # Class-conditional grating: orientation 18°·c, frequency 2 + c%3.
        theta = np.deg2rad(18.0 * c + rng.normal(0, 4.0))
        freq = (2.0 + (c % 3)) * (1.0 + rng.normal(0, 0.05))
        phase = rng.uniform(0, 2 * np.pi)
        u = np.cos(theta) * xx + np.sin(theta) * yy
        grating = 0.5 + 0.5 * np.sin(2 * np.pi * freq * u / IMG + phase)
        # Class-conditional color tint (RGB phases around the hue wheel).
        tint = np.array(
            [
                0.55 + 0.45 * np.cos(2 * np.pi * (c / N_CLASSES + k / 3.0))
                for k in range(3)
            ]
        )
        # A class-positioned soft blob (second, redundant cue).
        bx = (c % 4) * 4 + 2 + rng.normal(0, 0.8)
        by = (c // 4) * 5 + 2 + rng.normal(0, 0.8)
        blob = np.exp(-(((xx - bx) ** 2 + (yy - by) ** 2) / (2 * 2.5**2)))
        base = 0.65 * grating + 0.25 * blob
        img = base[..., None] * tint[None, None, :]
        # Amplitude jitter + noise: this is what keeps the task non-trivial.
        img *= rng.uniform(0.7, 1.1)
        img += rng.normal(0, 0.55, img.shape)
        images[i] = np.clip(img, 0.0, 1.0)
    return images, labels


def train_test(n_train: int = 4000, n_test: int = 1000, seed: int = 1234):
    """The canonical splits used by training, AOT export, and the Rust e2e
    example (dataset.bin)."""
    xtr, ytr = make_split(n_train, seed)
    xte, yte = make_split(n_test, seed + 1)
    return (xtr, ytr), (xte, yte)


def write_dataset_bin(path: str, images: np.ndarray, labels: np.ndarray):
    """dataset.bin layout (little-endian):
    u32 magic 0x4E564D43 ('NVMC'), u32 n, u32 h, u32 w, u32 c,
    then n*h*w*c f32 images, then n u8 labels."""
    n, h, w, c = images.shape
    with open(path, "wb") as f:
        np.array([0x4E564D43, n, h, w, c], np.uint32).tofile(f)
        images.astype("<f4").tofile(f)
        labels.astype(np.uint8).tofile(f)
