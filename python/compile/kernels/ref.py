"""Pure-jnp oracle for the PIM MAC kernel.

This is the *functional specification* of the 6T-2R analog MAC pipeline:
bit-serial 4-bit activations x 4-bit weights over 128-row sub-array tiles,
with WCC 8:4:2:1 weighting (== the integer weight value), the nonlinear
analog transfer curve, 6-bit SAR ADC quantization per (tile x bit-plane),
and digital shift-add recombination.

The Pallas kernel (`pim_mac.py`) must match this exactly (pytest enforces
equality); the Rust engine (`rust/src/pim/engine.rs`) must match it to
within 1 ADC LSB per partial sum (enforced by runtime_crosscheck).
"""

import jax
import jax.numpy as jnp

from .. import hw_model as hw

CORNER_SCALE = {"SS": 0.80, "TT": 1.00, "FF": 1.25}


def adc_transfer(mac, corner: str = "TT", calibrated: bool = True):
    """jnp version of the analog+ADC pipeline.

    mac -> powerline current -> sampled voltage -> 6-bit code (inverted)
    -> MAC estimate (inverse linear mapping back to the dynamic range).
    """
    scale = CORNER_SCALE[corner]
    i_unit = hw.I_UNIT_TT
    v_swing = hw.VDD - hw.V_REF
    i_ideal = mac * i_unit * scale
    i = i_ideal / (1.0 + i_ideal * hw.R_LOAD[corner] / v_swing)
    i_fs_tt_ideal = hw.MAC_FULLSCALE * i_unit
    i_fs_tt = i_fs_tt_ideal / (1.0 + i_fs_tt_ideal * hw.R_LOAD["TT"] / v_swing)
    r_ti = (hw.V_SAMP_MAX - hw.V_SAMP_MIN) / i_fs_tt
    v = hw.V_SAMP_MAX - r_ti * i
    if calibrated:
        lo, hi = hw.V_REFN_CAL, hw.V_REFP_CAL
    else:
        lo, hi = 0.0, hw.V_REF_UNCAL
    x = (v - lo) / (hi - lo)
    code = jnp.clip(jnp.round(x * hw.ADC_CODES), 0, hw.ADC_CODES)
    code = hw.ADC_CODES - code  # post-processing inversion (V = VDD - MAC)
    return code * (hw.MAC_FULLSCALE / hw.ADC_CODES)


def transfer_continuous(mac, corner: str = "TT"):
    """Continuous (un-rounded) analog transfer: MAC -> equivalent MAC after
    the nonlinear compression, *without* ADC rounding. Used by the
    paper-faithful Table II emulation (Section V-E), where the 6-bit signed
    quantization is applied separately at the activation level."""
    scale = CORNER_SCALE[corner]
    i_unit = hw.I_UNIT_TT
    v_swing = hw.VDD - hw.V_REF
    i_ideal = mac * i_unit * scale
    i = i_ideal / (1.0 + i_ideal * hw.R_LOAD[corner] / v_swing)
    i_fs_tt_ideal = hw.MAC_FULLSCALE * i_unit
    i_fs_tt = i_fs_tt_ideal / (1.0 + i_fs_tt_ideal * hw.R_LOAD["TT"] / v_swing)
    r_ti = (hw.V_SAMP_MAX - hw.V_SAMP_MIN) / i_fs_tt
    v = hw.V_SAMP_MAX - r_ti * i
    x = (v - hw.V_REFN_CAL) / (hw.V_REFP_CAL - hw.V_REFN_CAL)
    return (1.0 - x) * hw.MAC_FULLSCALE


def pim_mac_block(a_block, w_block, corner: str = "TT", noise_sigma_codes=None, key=None):
    """One 128-row sub-array block MAC with per-bit-plane ADC quantization.

    a_block: [M, K<=128] integer-valued activations in [0, 15].
    w_block: [K, N] integer-valued weights in [0, 15].
    Returns the dequantized MAC estimate [M, N], float32.

    noise_sigma_codes: optional Gaussian sigma (ADC-code units) injected on
    each conversion, modeling the Monte-Carlo spread of Section V-E.
    """
    a = a_block.astype(jnp.float32)
    w = w_block.astype(jnp.float32)
    acc = jnp.zeros((a.shape[0], w.shape[1]), jnp.float32)
    for b in range(hw.ACT_BITS):
        a_bit = jnp.floor(a / (2.0**b)) % 2.0
        mac = a_bit @ w  # per-plane integer MAC in [0, 1920]
        est = adc_transfer(mac, corner)
        if noise_sigma_codes is not None and key is not None:
            key, sub = jax.random.split(key)
            noise = jax.random.normal(sub, mac.shape) * noise_sigma_codes
            est = est + noise * (hw.MAC_FULLSCALE / hw.ADC_CODES)
        acc = acc + (2.0**b) * est
    return acc


def pim_mac(a, w, corner: str = "TT", noise_sigma_codes=None, key=None):
    """Full PIM matmul: splits K into 128-row sub-array blocks (each with
    its own WCC+ADC conversion chain), accumulates partial sums digitally —
    exactly the hardware mapping of Section IV.

    a: [M, K] integer-valued activations in [0, 15]; w: [K, N] in [0, 15].
    """
    m, k = a.shape
    k2, n = w.shape
    assert k == k2, (a.shape, w.shape)
    acc = jnp.zeros((m, n), jnp.float32)
    for k0 in range(0, k, hw.N_ROWS):
        k1 = min(k0 + hw.N_ROWS, k)
        blk_key = None
        if key is not None:
            key, blk_key = jax.random.split(key)
        acc = acc + pim_mac_block(
            a[:, k0:k1], w[k0:k1, :], corner, noise_sigma_codes, blk_key
        )
    return acc


def exact_mac(a, w):
    """The ideal digital MAC (no quantization) — the 'infinite-ADC' bound."""
    return a.astype(jnp.float32) @ w.astype(jnp.float32)
