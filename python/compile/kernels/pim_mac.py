"""L1 Pallas kernel: the 6T-2R analog PIM MAC hot-spot.

Hardware adaptation (DESIGN.md §1): the 128×128 6T-2R sub-array maps onto a
128×128 MXU-friendly tile. The grid iterates (M-tiles, N-tiles, K-blocks);
each K-block of 128 rows corresponds to one physical sub-array whose
partial sum is ADC-quantized *before* digital accumulation — the defining
numerical property of the paper's pipeline. The 4-bit input activations are
processed bit-serially inside the kernel (4 planes, shift-add recombined),
matching §IV-B, and the 4-bit weight columns arrive pre-weighted 8:4:2:1 as
the integer weight value (the WCC weighting).

The kernel is lowered with ``interpret=True`` (CPU PJRT cannot execute
Mosaic custom-calls); on a real TPU the same BlockSpec tiling feeds the MXU
with one sub-array-shaped tile per step.

VMEM budget per grid step (bf16/f32 on TPU, estimate recorded in
EXPERIMENTS.md §Perf): a-tile 128×128×4 B + w-tile 128×128×4 B + acc
128×128×4 B ≈ 192 KiB — comfortably inside the ~16 MiB VMEM, leaving room
for double-buffering the HBM→VMEM pipeline.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .. import hw_model as hw
from . import ref

# Tile sizes: the sub-array geometry. K-tile MUST be 128 (one sub-array).
TILE_M = 128
TILE_K = hw.N_ROWS  # 128 rows per analog accumulation
TILE_N = 128


def _adc_transfer_inline(mac, corner: str):
    """The analog transfer + 6-bit ADC, inlined for the kernel body.

    Identical math to `ref.adc_transfer` (kept in one place there; repeated
    here only because pallas kernels cannot call through module-level
    closures that capture tracers — the constants are all Python floats, so
    this stays exactly equal bit-for-bit)."""
    return ref.adc_transfer(mac, corner)


def _kernel(a_ref, w_ref, o_ref, *, corner: str, act_bits: int):
    """One grid step: (m, n, k) tile of the bit-serial quantized MAC."""
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    a = a_ref[...].astype(jnp.float32)  # [TILE_M, TILE_K] ints in [0, 15]
    w = w_ref[...].astype(jnp.float32)  # [TILE_K, TILE_N] ints in [0, 15]
    acc = jnp.zeros(o_ref.shape, jnp.float32)
    # Bit-serial input: one analog MAC + ADC conversion per bit-plane
    # (paper §IV-B: four cycles for 4-bit IA, LSB..MSB).
    for b in range(act_bits):
        a_bit = jnp.floor(a / (2.0**b)) % 2.0
        mac = jnp.dot(a_bit, w)  # powerline current accumulation
        est = _adc_transfer_inline(mac, corner)  # WCC + S&H + SAR ADC
        acc += (2.0**b) * est  # digital shift-add
    o_ref[...] += acc


@functools.partial(jax.jit, static_argnames=("corner",))
def pim_mac_pallas(a, w, corner: str = "TT"):
    """Quantized PIM matmul via the Pallas kernel.

    a: [M, K] float32 with integer values in [0, 15] (4-bit activations).
    w: [K, N] float32 with integer values in [0, 15] (4-bit weights,
       WCC-weighted). M, K, N must be multiples of the 128 tile sizes
       (callers pad; the model layer handles padding).
    Returns [M, N] float32 dequantized MAC estimates.
    """
    m, k = a.shape
    k2, n = w.shape
    assert k == k2, (a.shape, w.shape)
    assert m % TILE_M == 0 and k % TILE_K == 0 and n % TILE_N == 0, (
        f"shapes must be tile-aligned, got {a.shape} @ {w.shape}"
    )
    grid = (m // TILE_M, n // TILE_N, k // TILE_K)
    kernel = functools.partial(_kernel, corner=corner, act_bits=hw.ACT_BITS)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((TILE_M, TILE_K), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((TILE_K, TILE_N), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((TILE_M, TILE_N), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=True,  # CPU-PJRT path; real-TPU lowering is compile-only
    )(a, w)


def pad_to_tiles(x, tile_m, tile_n):
    """Zero-pad a 2-D array up to tile multiples (zeros are exact no-ops in
    the PIM pipeline: a zero activation row contributes no current)."""
    m, n = x.shape
    pm = (-m) % tile_m
    pn = (-n) % tile_n
    if pm or pn:
        x = jnp.pad(x, ((0, pm), (0, pn)))
    return x


def pim_mac_padded(a, w, corner: str = "TT"):
    """Tile-aligned wrapper: pads, runs the kernel, crops.

    NOTE on exactness vs the hardware: padding K with zero *rows* adds
    zero-current rows to a sub-array block; since blocks are quantized
    independently, a padded final block quantizes the same MAC value as a
    short physical block — identical results.
    """
    m, k = a.shape
    _, n = w.shape
    a_p = pad_to_tiles(a, TILE_M, TILE_K)
    w_p = pad_to_tiles(w, TILE_K, TILE_N)
    out = pim_mac_pallas(a_p, w_p, corner)
    return out[:m, :n]
