"""Pure-jnp oracle for the quantized transformer block on PIM banks.

This is the *functional specification* of the attention workload mapping
(ARCHITECTURE.md section pim/attn): the weight-stationary matmuls of a
pre-norm transformer block — fused QKV projection, attention output
projection W_O, both FFN layers, and the mean-pool classifier head — run
through the 4-bit PIM MAC pipeline (`ref.pim_mac`, one pos and one neg
bank per weight matrix), while the *dynamic* matmuls Q.K^T and A.V —
activation x activation, which would cost an RRAM write campaign per
request if banked — stay exact digital in every mode.

The Rust straight-line witness (`rust/src/pim/attn.rs::spec_attn`)
restates this choreography scalar-for-scalar against the exact ADC LUT;
`CompiledTransformer` must match *it* bit-for-bit (enforced by
`rust/tests/transformer_parity.rs`). This file is the cross-language
doc-spec of the same block, mirroring `ref.py`'s role for the MAC core.
"""

import jax.numpy as jnp

from . import ref

ACT_LEVELS = 15.0  # 4-bit unsigned activation codes
W_LEVELS = 15.0  # 4-bit weight magnitude per pos/neg bank


def quantize_acts(a):
    """Per-tensor unsigned 4-bit activation quantization
    (`rust/src/pim/quant.rs::quantize_acts`): scale = max/15 (floored at
    1e-6), codes = round(a/scale) clipped to [0, 15]. The PIM path clips
    inputs at zero *before* this (unsigned lanes — the ReLU-before-bank
    convention), which the callers below apply explicitly."""
    scale = jnp.maximum(jnp.max(a), 1e-6) / ACT_LEVELS
    return jnp.clip(jnp.round(a / scale), 0.0, ACT_LEVELS), scale


def quantize_weights(w):
    """Signed weights to pos/neg 4-bit banks with per-column scales
    (`quant.rs::quantize_weights`): s[j] = max_i |w[i,j]| / 15,
    q = clip(round(w/s), -15, 15), split by sign."""
    scale = jnp.maximum(jnp.max(jnp.abs(w), axis=0), 1e-6) / W_LEVELS
    q = jnp.clip(jnp.round(w / scale), -W_LEVELS, W_LEVELS)
    return jnp.maximum(q, 0.0), jnp.maximum(-q, 0.0), scale


def bank_linear(x, w, b, corner: str = "TT"):
    """One weight-stationary linear on prepared banks: clip the input at
    zero, quantize, run pos and neg banks through the full per-bit-plane
    ADC pipeline, recombine as (pos - neg) * a_scale * w_scale[j], add
    the digital fp32 bias. Mirrors `pim::program::spec_matmul` plus the
    bias placement of `spec_attn`'s `mm`."""
    qa, a_scale = quantize_acts(jnp.maximum(x, 0.0))
    pos, neg, w_scale = quantize_weights(w)
    mac = ref.pim_mac(qa, pos, corner) - ref.pim_mac(qa, neg, corner)
    return mac * a_scale * w_scale[None, :] + b[None, :]


def layer_norm(x, gamma, beta, eps: float = 1e-5):
    """Row-wise layer norm over the last axis (`nn/transformer.rs`),
    population variance, then gamma/beta affine."""
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean((x - mu) ** 2, axis=-1, keepdims=True)
    return (x - mu) / jnp.sqrt(var + eps) * gamma + beta


def attn_context(qkv, n_heads, causal: bool):
    """Multi-head scaled-dot-product attention from a fused QKV buffer
    [S, 3D] — the *dynamic* core (`pim/attn.rs::attn_context`): per head,
    scores = Q.K^T / sqrt(d_h) (exact digital — both operands change per
    request), optional causal -inf mask, row softmax, context = A.V,
    heads re-concatenated. No quantization, no banks, no noise draws."""
    s, d3 = qkv.shape
    d = d3 // 3
    dh = d // n_heads
    out = []
    for h in range(n_heads):
        q = qkv[:, h * dh : (h + 1) * dh]
        k = qkv[:, d + h * dh : d + (h + 1) * dh]
        v = qkv[:, 2 * d + h * dh : 2 * d + (h + 1) * dh]
        scores = (q @ k.T) / jnp.sqrt(float(dh))
        if causal:
            mask = jnp.triu(jnp.ones((s, s), bool), k=1)
            scores = jnp.where(mask, -jnp.inf, scores)
        a = jnp.exp(scores - jnp.max(scores, axis=-1, keepdims=True))
        a = a / jnp.sum(a, axis=-1, keepdims=True)
        out.append(a @ v)
    return jnp.concatenate(out, axis=-1)


def transformer_block(h, p, n_heads, causal: bool, corner: str = "TT"):
    """One pre-norm block on a [S, D] sequence: LN -> fused QKV (banks)
    -> attention (digital) -> W_O (banks) -> residual; LN -> FF1 (banks)
    -> ReLU -> FF2 (banks) -> residual. `p` holds g1/b1, wqkv/bqkv,
    wo/bo, g2/b2, wf1/bf1, wf2/bf2 — the `t{i}/...` parameter names of
    `nn::transformer::test_tfm_params`."""
    a = layer_norm(h, p["g1"], p["b1"])
    qkv = bank_linear(a, p["wqkv"], p["bqkv"], corner)
    ctx = attn_context(qkv, n_heads, causal)
    h = h + bank_linear(ctx, p["wo"], p["bo"], corner)
    f = layer_norm(h, p["g2"], p["b2"])
    f = jnp.maximum(bank_linear(f, p["wf1"], p["bf1"], corner), 0.0)
    return h + bank_linear(f, p["wf2"], p["bf2"], corner)


def transformer_forward(x, blocks, head_w, head_b, n_heads, causal=False, corner="TT"):
    """The full classifier on one [S, D] sequence: stacked blocks, mean
    pool over the sequence axis, bank linear head with digital bias —
    the jnp restatement of `spec_attn` (hardware-true, noiseless)."""
    h = x
    for p in blocks:
        h = transformer_block(h, p, n_heads, causal, corner)
    pooled = jnp.mean(h, axis=0, keepdims=True)
    return bank_linear(pooled, head_w, head_b, corner)
