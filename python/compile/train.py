"""Training + hardware-aware fine-tuning (build-time only).

Reproduces the Table II protocol on the substituted dataset/model
(DESIGN.md §2):
  1. train the fp32 baseline;
  2. fine-tune with the PIM forward (ADC nonlinearity active, STE
     gradients) — 'task-aware adaptation' (§V-E);
  3. evaluate four configurations: baseline, PIM without fine-tune
     (the paper's '~77%' row), PIM fine-tuned, PIM fine-tuned + noise.

Optimizer: SGD + momentum with cosine annealing (the paper fine-tunes with
SGD, lr 0.001, cosine schedule; we scale epochs/lr to the smaller setup).
"""

import functools
import time

import jax
import jax.numpy as jnp
import numpy as np

from . import data, model


def cross_entropy(logits, labels):
    logp = jax.nn.log_softmax(logits)
    return -jnp.mean(logp[jnp.arange(labels.shape[0]), labels])


def make_step(mode: str, lr_schedule, momentum: float = 0.9, wd: float = 5e-4):
    """One jitted SGD-momentum step for the given forward mode."""

    def loss_fn(params, x, y):
        logits = model.forward(params, x, mode)
        l2 = sum(jnp.sum(p * p) for p in jax.tree_util.tree_leaves(params))
        return cross_entropy(logits, y) + wd * l2, logits

    @jax.jit
    def step(params, vel, x, y, it):
        (loss, logits), grads = jax.value_and_grad(loss_fn, has_aux=True)(params, x, y)
        # Global-norm gradient clipping: the STE forward/backward mismatch
        # can produce occasional large gradients during fine-tuning.
        gnorm = jnp.sqrt(
            sum(jnp.sum(g * g) for g in jax.tree_util.tree_leaves(grads)) + 1e-12
        )
        clip = jnp.minimum(1.0, 5.0 / gnorm)
        lr = lr_schedule(it)
        vel = jax.tree_util.tree_map(
            lambda v, g: momentum * v - lr * clip * g, vel, grads
        )
        params = jax.tree_util.tree_map(lambda p, v: p + v, params, vel)
        acc = jnp.mean(jnp.argmax(logits, -1) == y)
        return params, vel, loss, acc

    return step


def cosine_lr(base: float, total_steps: int):
    def sched(it):
        return base * 0.5 * (1.0 + jnp.cos(jnp.pi * it / total_steps))

    return sched


def evaluate(params, x, y, mode: str, batch: int = 100, key=None, sigma_codes=None):
    """Test accuracy under a forward mode."""
    fwd = jax.jit(
        functools.partial(model.forward, mode=mode, sigma_codes=sigma_codes),
        static_argnames=(),
    )
    correct = 0
    for i in range(0, len(x), batch):
        xb = jnp.asarray(x[i : i + batch])
        kb = None
        if key is not None:
            key, kb = jax.random.split(key)
        logits = fwd(params, xb, key=kb) if "noise" in mode else fwd(params, xb)
        correct += int(jnp.sum(jnp.argmax(logits, -1) == jnp.asarray(y[i : i + batch])))
    return correct / len(x)


def train(
    params,
    xtr,
    ytr,
    mode: str,
    epochs: int,
    base_lr: float,
    batch: int = 100,
    seed: int = 0,
    log_prefix: str = "",
    log_every: int = 10,
):
    """Run SGD for `epochs`; returns updated params and the loss curve."""
    n = len(xtr)
    steps_per_epoch = n // batch
    total = steps_per_epoch * epochs
    step = make_step(mode, cosine_lr(base_lr, total))
    vel = jax.tree_util.tree_map(jnp.zeros_like, params)
    rng = np.random.default_rng(seed)
    losses = []
    it = 0
    for ep in range(epochs):
        perm = rng.permutation(n)
        ep_loss, ep_acc = 0.0, 0.0
        for s in range(steps_per_epoch):
            idx = perm[s * batch : (s + 1) * batch]
            params, vel, loss, acc = step(
                params, vel, jnp.asarray(xtr[idx]), jnp.asarray(ytr[idx]), it
            )
            ep_loss += float(loss)
            ep_acc += float(acc)
            it += 1
            if it % log_every == 0:
                losses.append((it, float(loss)))
        print(
            f"{log_prefix}epoch {ep + 1}/{epochs}: loss={ep_loss / steps_per_epoch:.4f} "
            f"train_acc={ep_acc / steps_per_epoch:.4f}",
            flush=True,
        )
    return params, losses


def run_full_protocol(
    n_train: int = 4000,
    n_test: int = 1000,
    baseline_epochs: int = 15,
    ft_epochs: int = 6,
    seed: int = 42,
    sigma_codes: float = 0.5,
):
    """The complete Table II protocol. Returns (results dict, params
    (baseline), params_ft, loss curves, dataset splits)."""
    (xtr, ytr), (xte, yte) = data.train_test(n_train, n_test)
    params = model.init_params(jax.random.PRNGKey(seed))
    t0 = time.time()
    params, base_curve = train(
        params, xtr, ytr, "baseline", baseline_epochs, 0.05, log_prefix="[base] "
    )
    acc_base = evaluate(params, xte, yte, "baseline")
    acc_pim_noft = evaluate(params, xte, yte, "pim")
    # The paper's "~77 % without fine-tuning" row is the *deployed*
    # condition: ADC nonlinearity + noise, un-adapted weights.
    acc_pim_noise_noft = evaluate(
        params, xte, yte, "pim_noise", key=jax.random.PRNGKey(3), sigma_codes=sigma_codes
    )
    acc_hw_noft = evaluate(params, xte, yte, "pim_hw")
    print(
        f"[base] test acc={acc_base:.4f}  pim-no-ft={acc_pim_noft:.4f} "
        f"pim-noise-no-ft={acc_pim_noise_noft:.4f} pim-hw-no-ft={acc_hw_noft:.4f}",
        flush=True,
    )

    params_ft, ft_curve = train(
        params, xtr, ytr, "pim", ft_epochs, 0.002, log_prefix="[ft]   "
    )
    acc_pim_ft = evaluate(params_ft, xte, yte, "pim")
    # The hardware-true block-level pipeline, evaluated on the same
    # fine-tuned weights — the "how harsh is the real analog path"
    # ablation row (EXPERIMENTS.md E10).
    acc_hw_ft = evaluate(params_ft, xte, yte, "pim_hw")
    # Calibrate the injected ADC-noise sigma: the paper's Fig. 13 MC spread
    # maps to ~0.27 code/conversion on *their* testbed; on ours the
    # positive/negative-bank recombination amplifies code noise, so we pick
    # the largest sigma from a sweep whose accuracy cost stays within ~1 %
    # (recorded per-sigma in the manifest for the ablation bench).
    sweep = {}
    for sc in (sigma_codes, 0.25, 0.1, 0.05, 0.02):
        if sc in sweep:
            continue
        sweep[sc] = evaluate(
            params_ft, xte, yte, "pim_noise", key=jax.random.PRNGKey(7), sigma_codes=sc
        )
    chosen = max(
        (sc for sc, acc in sweep.items() if acc_pim_ft - acc <= 0.01),
        default=min(sweep),
    )
    acc_pim_noise = sweep[chosen]
    print(
        f"[ft]   pim-ft={acc_pim_ft:.4f}  noise sweep={sweep}  chosen sigma={chosen} "
        f"({time.time() - t0:.0f}s total)",
        flush=True,
    )
    results = {
        "baseline": acc_base,
        "pim_no_finetune": acc_pim_noft,
        "pim_noise_no_finetune": acc_pim_noise_noft,
        "pim_finetuned": acc_pim_ft,
        "pim_finetuned_noise": acc_pim_noise,
        "pim_hw_no_finetune": acc_hw_noft,
        "pim_hw_finetuned": acc_hw_ft,
        "sigma_codes": chosen,
        "noise_sweep": sweep,
    }
    return results, params, params_ft, (base_curve, ft_curve), ((xtr, ytr), (xte, yte))
