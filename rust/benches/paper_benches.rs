//! `cargo bench` — regenerates every paper table/figure with timing, plus
//! the ablation benches (DESIGN.md A1–A3). Custom harness (criterion is
//! unavailable offline): warmup + adaptive iterations, mean/p50/p95.
//!
//! Output doubles as the reproduction log: each section prints the same
//! rows/series the paper reports.

use std::path::PathBuf;

use nvm_in_cache::array::SubArray;
use nvm_in_cache::cache::addr::Geometry;
use nvm_in_cache::cache::controller::PimIntegration;
use nvm_in_cache::consts::{ARRAY_ROWS, ARRAY_WORDS, T_ADC_CONVERSION};
use nvm_in_cache::coordinator::BankScheduler;
use nvm_in_cache::device::Corner;
use nvm_in_cache::figures;
use nvm_in_cache::mapping::bit_serial::BitSerialSchedule;
use nvm_in_cache::perf::MacroModel;
use nvm_in_cache::pim::PimEngine;
use nvm_in_cache::util::bench::Bencher;
use nvm_in_cache::util::rng::Pcg64;

fn out_dir() -> PathBuf {
    let d = PathBuf::from("results/bench");
    std::fs::create_dir_all(&d).unwrap();
    d
}

fn main() {
    let mut b = Bencher::default();
    let out = out_dir();

    println!("=== Figure/table regeneration (E1–E9, E11) ===");
    b.bench("fig9a_rram_iv_sweep", || figures::device_figs::fig9a_rram_iv(&out).unwrap());
    b.bench("fig9bcd_snm_butterflies", || figures::device_figs::fig9bcd_snm(&out).unwrap());
    b.bench("section_vb_scalars", || figures::device_figs::section_vb_scalars(&out).unwrap());
    b.bench("fig10_weight_voltage", || figures::linearity::fig10_weight_voltage(&out).unwrap());
    b.bench("fig11_weight_current", || figures::linearity::fig11_weight_current(&out).unwrap());
    b.bench("fig12_adc_transfer", || figures::linearity::fig12_adc_transfer(&out).unwrap());
    b.bench("fig13_monte_carlo_64", || {
        figures::linearity::fig13_monte_carlo(&out, 64).unwrap()
    });
    b.bench("fig14_scaling", || figures::scaling::fig14_scaling(&out).unwrap());
    b.bench("table1_comparison", || figures::tables::table1(&out, Some(0.919)).unwrap());

    println!("\n=== E8: macro model headline (Table I row) ===");
    let h = MacroModel::default().headline();
    println!(
        "  {:.2} GOPS raw | {:.2} TOPS/W raw | {:.4} TOPS norm | {:.1} TOPS/W norm | {:.2} TOPS/mm²",
        h.ops_per_s / 1e9,
        h.ops_per_w / 1e12,
        h.norm_ops_per_s / 1e12,
        h.norm_ops_per_w / 1e12,
        h.norm_tops_per_mm2
    );
    println!(
        "  paper:  25.60 GOPS | 30.73 TOPS/W | 0.4096 TOPS | 491.8 TOPS/W | 4.37 TOPS/mm²"
    );

    println!("\n=== Hot path: PIM engine matmul (simulator throughput) ===");
    let mut rng = Pcg64::seeded(1);
    for (m, k, n) in [(64usize, 128usize, 64usize), (256, 256, 128), (1024, 128, 128)] {
        let a: Vec<f32> = (0..m * k).map(|_| rng.range(0.0, 1.0) as f32).collect();
        let w: Vec<f32> = (0..k * n).map(|_| rng.range(-0.5, 0.5) as f32).collect();
        let eng = PimEngine::tt();
        let macs = (m * k * n) as f64;
        b.bench_with_items(&format!("engine_pim_matmul_{m}x{k}x{n}"), macs, || {
            eng.pim_matmul(&a, m, k, &w, n, None)
        });
        // The execute-many half of the compile-once split: same MAC on a
        // prepared weight program (no per-call quantize/pack).
        let program = eng.prepare(&w, k, n);
        b.bench_with_items(&format!("engine_matmul_prepared_{m}x{k}x{n}"), macs, || {
            eng.matmul_prepared(&a, m, &program, None)
        });
    }

    println!("\n=== Cell-accurate sub-array full 4b MAC ===");
    let mut sa = SubArray::new(Corner::TT);
    let weights: Vec<u8> = (0..ARRAY_ROWS * ARRAY_WORDS).map(|_| rng.below(16) as u8).collect();
    sa.load_weights(&weights);
    let ia: Vec<u8> = (0..ARRAY_ROWS).map(|_| rng.below(16) as u8).collect();
    b.bench_with_items(
        "subarray_pim_mac_4b",
        (ARRAY_ROWS * ARRAY_WORDS) as f64,
        || sa.pim_mac_4b(&ia, None),
    );

    println!("\n=== A1: retention vs flush/reload (paper motivation) ===");
    for (name, mode) in [
        ("retained", PimIntegration::Retained),
        ("flush_reload", PimIntegration::FlushReload),
    ] {
        let mut sched = BankScheduler::new(
            BankScheduler::resnet18_layers(16),
            Geometry::default(),
            mode,
        )
        .unwrap();
        sched.program_network();
        let cost = sched.batch_cost(1);
        println!(
            "  {name:<13}: {:.1} µs, {:.2} µJ, {} lines moved, {:.2} TOPS/W",
            cost.latency_s * 1e6,
            cost.energy_j * 1e6,
            cost.lines_moved,
            cost.ops / cost.energy_j / 1e12
        );
        let mut s2 = BankScheduler::new(
            BankScheduler::resnet18_layers(16),
            Geometry::default(),
            mode,
        )
        .unwrap();
        s2.program_network();
        b.bench(&format!("scheduler_batch_cost_{name}"), || s2.batch_cost(1));
    }

    println!("\n=== A2: bit-serial vs ideal DAC bit-parallel (§IV-B) ===");
    // Bit-parallel would convert all 4 input bits in one window but needs a
    // 4-bit DAC per row and a wider ADC: model as 1 window vs 4, with 2.5×
    // conversion energy and 4× DAC-added area (paper's qualitative
    // argument for bit-serial).
    let serial = BitSerialSchedule::new(4, 4);
    let t_serial = serial.latency();
    let t_parallel = 2.0 * T_ADC_CONVERSION; // both sides, one plane window
    let e_rel_serial = 1.0;
    let e_rel_parallel = 2.5 / 4.0; // fewer conversions, each costlier
    println!(
        "  bit-serial:   {:.0} ns, 1.00× energy, no DAC area",
        t_serial * 1e9
    );
    println!(
        "  bit-parallel: {:.0} ns ({:.1}× faster), {:.2}× energy, +DAC area/complexity (rejected by the paper)",
        t_parallel * 1e9,
        t_serial / t_parallel,
        e_rel_parallel / e_rel_serial
    );

    println!("\n=== E12: multi-tenant fleet simulation (fleet/) ===");
    let fleet_cfg = nvm_in_cache::fleet::FleetSimConfig::bench_quick();
    let mut fleet_report = None;
    b.bench(&fleet_cfg.bench_label(), || {
        fleet_report = Some(nvm_in_cache::fleet::FleetSim::run(&fleet_cfg).unwrap());
    });
    print!("{}", fleet_report.expect("bench ran at least once").render());

    println!("\n=== A3: ADC sharing / faster ADC (§V-F future work) ===");
    for (share, rate_mult) in [(1usize, 1.0f64), (2, 1.0), (4, 1.0), (1, 2.0), (1, 4.0)] {
        // Sharing an ADC across `share` word columns divides ADC area but
        // multiplies conversion serialization; a faster ADC divides the
        // window directly.
        let t_window = T_ADC_CONVERSION * share as f64 / rate_mult;
        let steps = 8.0;
        let ops = (ARRAY_ROWS * ARRAY_WORDS) as f64 * 2.0;
        let gops = ops / (steps * t_window) / 1e9;
        let adc_area = 0.07 / share as f64 * rate_mult.sqrt(); // mm², scaling heuristic
        let density = gops / 1e3 * 16.0 / (0.03 + adc_area);
        println!(
            "  share={share} rate={rate_mult:.0}×: {:>6.1} GOPS raw, macro {:.3} mm², {:.2} norm-TOPS/mm²",
            gops,
            0.03 + adc_area,
            density
        );
    }

    println!("\n=== timing summary ===");
    b.report();
}
