//! Cross-witness differential harness for the quantized transformer
//! block on prepared banks (ISSUE 9 acceptance; EXPERIMENTS.md E17,
//! PERFORMANCE.md §11).
//!
//! Three independent witnesses pin the compiled attention program:
//!
//! * `pim::spec_attn` — the straight-line digital-exact specification of
//!   the noiseless hardware-true forward (quantized bank matmuls via
//!   `spec_matmul`, digital attention, shared layernorm/softmax).
//! * `pim::spec_attn_dense` — the dense fp32 witness the Baseline mode
//!   must reproduce (no activation clip, no quantization).
//! * The compiled program raced against **itself** across MAC kernels
//!   {BitPlane, Scalar} × threads {1, 2, 7} × execution styles (bare
//!   forward, stepped begin/step with mid-flight merging, StubRuntime
//!   serving leg), noiseless and noisy, comparing logits *and* trailing
//!   RNG state bit-for-bit.
//!
//! Plus the seeded ragged-shape sweep crossing the 64-bit plane-word and
//! 128-row block edges in the bank contraction dimensions, the
//! softmax/quantization edge cases, and the zero-prepare steady-state
//! gate. `scripts/verify.sh` re-runs this suite with `--release`.

use nvm_in_cache::nn::transformer::test_tfm_params;
use nvm_in_cache::nn::{ForwardMode, Tensor, TfmConfig, Transformer};
use nvm_in_cache::pim::engine::MacKernel;
use nvm_in_cache::pim::program::{prepare_count, ScratchPool};
use nvm_in_cache::pim::{spec_attn, spec_attn_dense, CompiledTransformer, Parallelism};
use nvm_in_cache::runtime::{ModelVariant, Runtime, StubRuntime};
use nvm_in_cache::util::rng::Pcg64;

mod common;
use common::{bits, rand_tokens, KernelGuard, THREADS};

/// A trimmed geometry (8 tokens, d_model 32, 4 heads, d_ff 64, 2 blocks)
/// so the full kernel × thread × mode matrix stays fast in debug builds.
fn small_cfg() -> TfmConfig {
    TfmConfig { seq_len: 8, d_model: 32, n_heads: 4, d_ff: 64, ..TfmConfig::tiny() }
}

fn small_transformer(seed: u64) -> Transformer {
    let cfg = small_cfg();
    Transformer::new(test_tfm_params(cfg, seed), cfg)
}

/// Run one compiled forward under a given kernel, returning logit bits
/// and the trailing RNG fingerprint.
fn run_with_kernel(
    prog: &CompiledTransformer,
    x: &Tensor,
    mode: ForwardMode,
    seed: u64,
    kernel: MacKernel,
    threads: usize,
) -> (Vec<u32>, u64) {
    let _guard = match kernel {
        MacKernel::Scalar => Some(KernelGuard::scalar()),
        MacKernel::BitPlane => None,
    };
    let mut scratch = ScratchPool::new();
    let run = prog.forward_run(x, mode, seed, Parallelism::threads(threads), &mut scratch);
    let fp = run.rng_fingerprint();
    (bits(&run.into_logits().data), fp)
}

/// The tentpole matrix: the compiled transformer is bit-identical —
/// logits and trailing RNG state — across MAC kernels {BitPlane, Scalar}
/// × threads {1, 2, 7}, noiseless and noisy, and the noiseless
/// hardware-true result equals the straight-line `spec_attn`
/// specification bit-for-bit.
#[test]
fn compiled_bit_identical_across_kernels_threads_and_matches_spec() {
    let tfm = small_transformer(42);
    let prog = tfm.compile().unwrap();
    assert!(prog.fully_prepared());
    let mut rng = Pcg64::seeded(1000);
    let x = rand_tokens(&mut rng, 2, prog.cfg.seq_len, prog.cfg.d_model);
    let spec = bits(&spec_attn(&tfm, &x).unwrap().data);
    for mode in [ForwardMode::PimHw, ForwardMode::PimHwNoise(0.4)] {
        let reference = run_with_kernel(&prog, &x, mode, 7, MacKernel::BitPlane, 1);
        for kernel in [MacKernel::BitPlane, MacKernel::Scalar] {
            for t in THREADS {
                let got = run_with_kernel(&prog, &x, mode, 7, kernel, t);
                assert_eq!(got.0, reference.0, "{mode:?} {kernel:?} t={t}: logits");
                assert_eq!(got.1, reference.1, "{mode:?} {kernel:?} t={t}: rng state");
            }
        }
        if mode == ForwardMode::PimHw {
            assert_eq!(reference.0, spec, "noiseless hardware vs spec_attn");
        }
    }
}

/// The dense fp32 witness: Baseline-mode execution (through the same
/// prepared program — the dense weights ride along) reproduces
/// `spec_attn_dense` bit-for-bit at every thread count, and the
/// emulated-ADC modes are cross-thread deterministic on the dense-only
/// compilation too.
#[test]
fn baseline_matches_dense_fp32_witness() {
    let tfm = small_transformer(43);
    let prepared = tfm.compile().unwrap();
    let dense = CompiledTransformer::compile_dense(&tfm).unwrap();
    assert!(!dense.fully_prepared());
    let mut rng = Pcg64::seeded(1100);
    let x = rand_tokens(&mut rng, 2, dense.cfg.seq_len, dense.cfg.d_model);
    let witness = bits(&spec_attn_dense(&tfm, &x).unwrap().data);
    let mut scratch = ScratchPool::new();
    for t in THREADS {
        let par = Parallelism::threads(t);
        for prog in [&prepared, &dense] {
            let got = prog.forward_par(&x, ForwardMode::Baseline, 3, par, &mut scratch);
            assert_eq!(bits(&got.data), witness, "Baseline t={t} vs dense witness");
        }
    }
    // Emulated modes (Pim, PimNoise) run the dense digital path + the
    // §V-E post-ADC step; they must be thread-count invariant.
    for mode in [ForwardMode::Pim, ForwardMode::PimNoise(0.4)] {
        let want = dense.forward_par(&x, mode, 3, Parallelism::serial(), &mut scratch);
        for t in [2usize, 7] {
            let got = dense.forward_par(&x, mode, 3, Parallelism::threads(t), &mut scratch);
            assert_eq!(bits(&got.data), bits(&want.data), "{mode:?} t={t}");
        }
    }
}

/// Stepped execution: group A (batch 2) runs two boundaries, group B
/// (batch 1) merges mid-flight, both interleave to completion — logits
/// and RNG fingerprints bit-identical to solo drains, noiseless and
/// noisy, with zero weight prepares across every boundary step.
#[test]
fn stepped_begin_step_merging_bit_identical_and_prepare_free() {
    let tfm = small_transformer(44);
    let prog = tfm.compile().unwrap();
    assert_eq!(prog.boundaries(), prog.cfg.n_blocks + 1, "one boundary per block + head");
    let mut rng = Pcg64::seeded(1200);
    let xa = rand_tokens(&mut rng, 2, prog.cfg.seq_len, prog.cfg.d_model);
    let xb = rand_tokens(&mut rng, 1, prog.cfg.seq_len, prog.cfg.d_model);
    let par = Parallelism::threads(2);
    for mode in [ForwardMode::PimHw, ForwardMode::PimHwNoise(0.4)] {
        let mut scratch = ScratchPool::new();
        let solo_a = prog.forward_run(&xa, mode, 21, par, &mut scratch);
        let solo_b = prog.forward_run(&xb, mode, 22, par, &mut scratch);
        let before = prepare_count();
        let mut run_a = prog.begin(&xa, 21);
        let mut done_a = prog.step(&mut run_a, mode, par, &mut scratch);
        // B merges while A is mid-flight.
        let mut run_b = prog.begin(&xb, 22);
        let mut done_b = false;
        while !done_a || !done_b {
            if !done_a {
                done_a = prog.step(&mut run_a, mode, par, &mut scratch);
            }
            if !done_b {
                done_b = prog.step(&mut run_b, mode, par, &mut scratch);
            }
        }
        assert_eq!(prepare_count(), before, "{mode:?}: stepped execution prepared");
        assert_eq!(run_a.rng_fingerprint(), solo_a.rng_fingerprint(), "{mode:?}: A rng");
        assert_eq!(run_b.rng_fingerprint(), solo_b.rng_fingerprint(), "{mode:?}: B rng");
        assert_eq!(
            bits(&run_a.into_logits().data),
            bits(&solo_a.into_logits().data),
            "{mode:?}: A logits"
        );
        assert_eq!(
            bits(&run_b.into_logits().data),
            bits(&solo_b.into_logits().data),
            "{mode:?}: B logits"
        );
    }
}

/// The StubRuntime serving leg: `load_transformer_params` +
/// `forward_transformer` returns logits bit-identical to the compiled
/// program (hardware-true variant) and to the dense fp32 witness
/// (Baseline variant), on both kernels, at every thread count, with a
/// prepare-free steady state after load.
#[test]
fn stub_runtime_transformer_leg_matches_compiled_across_kernels() {
    let batch = 2;
    let tfm = small_transformer(45);
    let prog = tfm.compile().unwrap();
    let cfg = prog.cfg;
    let mut rng = Pcg64::seeded(1300);
    let x = rand_tokens(&mut rng, batch, cfg.seq_len, cfg.d_model);
    let mut scratch = ScratchPool::new();
    let want_base = bits(&spec_attn_dense(&tfm, &x).unwrap().data);

    let run = |kernel: MacKernel, threads: usize| -> (Vec<u32>, Vec<u32>, bool) {
        let _guard = match kernel {
            MacKernel::Scalar => Some(KernelGuard::scalar()),
            MacKernel::BitPlane => None,
        };
        let mut rt = StubRuntime::new(batch);
        rt.load_transformer_params(ModelVariant::PimHw, &tfm).unwrap();
        rt.load_transformer_params(ModelVariant::Baseline, &tfm).unwrap();
        rt.set_parallelism(Parallelism::threads(threads));
        let steady = prepare_count();
        let hw = rt.forward_transformer(ModelVariant::PimHw, &x.data, None).unwrap();
        let base = rt.forward_transformer(ModelVariant::Baseline, &x.data, None).unwrap();
        (bits(&hw), bits(&base), prepare_count() == steady)
    };
    for t in THREADS {
        let par = Parallelism::threads(t);
        // The stub seeds unkeyed requests with 0 (`seed_from_key(None)`).
        let want_hw =
            bits(&prog.forward_par(&x, ForwardMode::PimHw, 0, par, &mut scratch).data);
        let simd = run(MacKernel::BitPlane, t);
        let scalar = run(MacKernel::Scalar, t);
        assert_eq!(simd.0, want_hw, "t={t}: stub PimHw vs compiled");
        assert_eq!(simd.0, scalar.0, "t={t}: stub PimHw SIMD vs scalar");
        assert_eq!(simd.1, want_base, "t={t}: stub Baseline vs dense witness");
        assert_eq!(simd.1, scalar.1, "t={t}: stub Baseline SIMD vs scalar");
        assert!(simd.2 && scalar.2, "t={t}: stub serving must be prepare-free");
    }
}

/// Seeded proptest-style sweep over ragged (seq_len, d_model, n_heads,
/// d_ff) geometries whose bank contraction dimensions cross the 64-bit
/// plane-word edge (63/64/65) and the 128-row block edge (127/128/129,
/// plus a ragged second block at 144), causal and bidirectional,
/// noiseless-vs-spec and noisy self-consistency at random thread counts.
/// Every case's index is in the assert message, so a failure replays.
#[test]
fn prop_ragged_shapes_cross_word_and_block_edges() {
    // (seq_len, d_model, n_heads, d_ff, causal)
    const CASES: [(usize, usize, usize, usize, bool); 8] = [
        (1, 8, 1, 63, true), // single-token causal sequence
        (2, 16, 2, 64, false),
        (3, 24, 3, 65, true),
        (5, 40, 5, 127, false),
        (4, 48, 4, 128, true),
        (6, 64, 4, 129, false),
        (7, 72, 8, 144, true),
        (9, 56, 7, 80, false),
    ];
    for (i, &(seq_len, d_model, n_heads, d_ff, causal)) in CASES.iter().enumerate() {
        let mut rng = Pcg64::seeded(5000 + i as u64);
        let cfg =
            TfmConfig { seq_len, d_model, n_heads, d_ff, causal, ..TfmConfig::tiny() };
        let tfm = Transformer::new(test_tfm_params(cfg, 200 + i as u64), cfg);
        let prog = tfm.compile().unwrap();
        let n = 1 + i % 2;
        let x = rand_tokens(&mut rng, n, seq_len, d_model);
        let threads = 1 + rng.below(7) as usize;
        let par = Parallelism::threads(threads);
        let mut scratch = ScratchPool::new();
        let ctx = format!("case {i}: s={seq_len} d={d_model} h={n_heads} ff={d_ff} t={threads}");

        let spec = spec_attn(&tfm, &x).unwrap();
        let got = prog.forward_par(&x, ForwardMode::PimHw, 9, par, &mut scratch);
        assert_eq!(got.shape, vec![n, cfg.n_classes], "{ctx}: logit shape");
        assert_eq!(bits(&got.data), bits(&spec.data), "{ctx}: PimHw vs spec");

        let dense = bits(&spec_attn_dense(&tfm, &x).unwrap().data);
        let base = prog.forward_par(&x, ForwardMode::Baseline, 9, par, &mut scratch);
        assert_eq!(bits(&base.data), dense, "{ctx}: Baseline vs dense witness");

        let noisy = ForwardMode::PimHwNoise(0.5);
        let a = prog.forward_run(&x, noisy, 9, par, &mut scratch);
        let b = prog.forward_run(&x, noisy, 9, Parallelism::serial(), &mut scratch);
        assert_eq!(a.rng_fingerprint(), b.rng_fingerprint(), "{ctx}: noisy rng");
        assert_eq!(
            bits(&a.into_logits().data),
            bits(&b.into_logits().data),
            "{ctx}: noisy logits threaded vs serial"
        );
    }
}

/// Softmax/quantization edge cases at the whole-pipeline level: an
/// all-equal token batch (uniform attention), a saturating
/// large-magnitude batch (activation quantization at full scale), and a
/// NaN-poisoned batch (the softmax uniform fallback + NaN→0 activation
/// quantization) must all keep compiled-vs-spec parity bit-for-bit —
/// the edge handling lives in shared helpers, so the witnesses cannot
/// drift apart silently.
#[test]
fn edge_case_inputs_keep_compiled_and_spec_in_lockstep() {
    let tfm = small_transformer(46);
    let prog = tfm.compile().unwrap();
    let cfg = prog.cfg;
    let elems = 2 * cfg.input_elems();
    let mut scratch = ScratchPool::new();
    let cases: [(&str, Vec<f32>); 3] = [
        ("all-equal tokens", vec![0.25; elems]),
        ("saturating magnitudes", (0..elems).map(|j| ((j % 7) as f32 - 3.0) * 1e4).collect()),
        (
            "NaN-poisoned batch",
            (0..elems).map(|j| if j % 97 == 0 { f32::NAN } else { 0.1 }).collect(),
        ),
    ];
    for (name, data) in cases {
        let x = Tensor::from_vec(&[2, cfg.seq_len, cfg.d_model], data);
        let spec = spec_attn(&tfm, &x).unwrap();
        let got = prog.forward_par(&x, ForwardMode::PimHw, 5, Parallelism::threads(2), &mut scratch);
        assert_eq!(bits(&got.data), bits(&spec.data), "{name}: compiled vs spec");
        if name != "NaN-poisoned batch" {
            assert!(got.data.iter().all(|v| v.is_finite()), "{name}: logits must stay finite");
        }
    }
}

/// Saturation vs the 16-bit recombination lanes (PERFORMANCE.md §8):
/// the bank-resident contraction dimensions of the standard transformer
/// geometries stay within whole 128-row blocks whose worst-case
/// bit-plane MAC is `MAC_FULLSCALE` = 15 · 128 = 1920 ≪ 2¹⁶, and a
/// saturating forward agrees across kernels — the packed accumulator
/// cannot wrap even when every lane hits its ceiling.
#[test]
fn saturating_attention_respects_the_16_bit_lane_ceiling() {
    use nvm_in_cache::consts::ARRAY_ROWS;
    use nvm_in_cache::pim::transfer::MAC_FULLSCALE;
    assert_eq!(MAC_FULLSCALE as usize, 15 * ARRAY_ROWS);
    assert!(MAC_FULLSCALE as usize <= u16::MAX as usize);
    // The standard tenants' bank contractions (d_model, d_ff): all split
    // into ≤128-row blocks by the engine, so the per-block ceiling above
    // is the binding one for every transformer matmul.
    for cfg in [TfmConfig::tiny(), TfmConfig::base()] {
        assert!(cfg.d_model <= 2 * ARRAY_ROWS && cfg.d_ff <= 2 * ARRAY_ROWS);
    }
    let tfm = small_transformer(47);
    let prog = tfm.compile().unwrap();
    let cfg = prog.cfg;
    // Alternating ±full-scale tokens: layernorm maps these to ±1-ish
    // values, so after the positive activation clip and per-tensor
    // quantization half the lanes sit at code 15 — the densest
    // popcount population a real activation tensor can produce.
    let x = Tensor::from_vec(
        &[1, cfg.seq_len, cfg.d_model],
        (0..cfg.input_elems()).map(|j| if j % 2 == 0 { 1e3 } else { -1e3 }).collect(),
    );
    let simd = run_with_kernel(&prog, &x, ForwardMode::PimHw, 1, MacKernel::BitPlane, 2);
    let scalar = run_with_kernel(&prog, &x, ForwardMode::PimHw, 1, MacKernel::Scalar, 2);
    assert_eq!(simd, scalar, "saturated forward must agree across kernels");
    assert_eq!(simd.0, bits(&spec_attn(&tfm, &x).unwrap().data), "saturated vs spec");
}

/// The zero-prepare steady state and the untouched-seed fingerprint: a
/// compiled transformer serves every mode without preparing, a noiseless
/// hardware run draws nothing from its RNG (fingerprint == the seeded
/// stream's first word), and a noisy run does draw.
#[test]
fn steady_state_prepare_free_and_noiseless_rng_untouched() {
    let tfm = small_transformer(48);
    let prog = tfm.compile().unwrap();
    let mut rng = Pcg64::seeded(1500);
    let x = rand_tokens(&mut rng, 2, prog.cfg.seq_len, prog.cfg.d_model);
    let mut scratch = ScratchPool::new();
    let steady = prepare_count();
    for mode in [
        ForwardMode::Baseline,
        ForwardMode::Pim,
        ForwardMode::PimNoise(0.3),
        ForwardMode::PimHw,
        ForwardMode::PimHwNoise(0.3),
    ] {
        for _ in 0..2 {
            prog.forward_par(&x, mode, 77, Parallelism::threads(2), &mut scratch);
        }
    }
    assert_eq!(prepare_count(), steady, "steady-state serving must never prepare");

    let quiet = prog.forward_run(&x, ForwardMode::PimHw, 77, Parallelism::serial(), &mut scratch);
    assert_eq!(
        quiet.rng_fingerprint(),
        Pcg64::seeded(77).next_u64(),
        "noiseless hardware run must not consume RNG"
    );
    let noisy =
        prog.forward_run(&x, ForwardMode::PimHwNoise(0.3), 77, Parallelism::serial(), &mut scratch);
    assert_ne!(quiet.rng_fingerprint(), noisy.rng_fingerprint(), "noisy run must draw");
}
