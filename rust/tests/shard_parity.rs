//! Differential suite pinning model-parallel sharded execution
//! bit-identical to the solo forward path (ISSUE 8 acceptance;
//! EXPERIMENTS.md E16).
//!
//! Three angles:
//!
//! * Fixed shard counts {2, 3} × thread counts {1, 2, 7}, noiseless
//!   (`PimHw`) and noisy (`PimHwNoise`), logits *and* trailing RNG
//!   fingerprints compared bit-for-bit against `CompiledNet::forward_run`.
//! * Proptest-style randomized cut points: seeded random strictly
//!   increasing cut sets must conserve the outputs regardless of where
//!   the pipeline is severed (the seed is in every assert message, so a
//!   failure is replayable).
//! * Placer invariants on the default wide fleet: the over-capacity
//!   tenant shards across distinct slices, fitting tenants stay
//!   replica-parallel, and per-bank wear stays within the endurance
//!   budget for every placed segment.

use nvm_in_cache::cache::addr::Geometry;
use nvm_in_cache::fleet::{EndurancePlacer, EndurancePolicy, ModelRegistry};
use nvm_in_cache::nn::resnet::test_params;
use nvm_in_cache::nn::{ForwardMode, ResNet, Tensor};
use nvm_in_cache::pim::program::{CompiledNet, ScratchPool};
use nvm_in_cache::pim::{Parallelism, ShardedExecutor};
use nvm_in_cache::util::rng::Pcg64;

mod common;
use common::{rand_image as rand_input, THREADS};

fn tiny_net() -> CompiledNet {
    ResNet::new(test_params(8, 10, 3)).compile().unwrap()
}

/// Assert one pipelined run equals its solo reference, bits and RNG.
fn assert_run_matches_solo(
    net: &CompiledNet,
    inputs: &[(Tensor, u64)],
    runs: Vec<nvm_in_cache::pim::program::InflightRun>,
    mode: ForwardMode,
    par: Parallelism,
    ctx: &str,
) {
    let mut scratch = ScratchPool::new();
    for (i, ((x, seed), run)) in inputs.iter().zip(runs).enumerate() {
        let solo = net.forward_run(x, mode, *seed, par, &mut scratch);
        assert_eq!(
            run.rng_fingerprint(),
            solo.rng_fingerprint(),
            "RNG stream diverged at micro-batch {i} ({ctx})"
        );
        let (a, b) = (run.into_logits(), solo.into_logits());
        assert_eq!(a.shape, b.shape, "shape diverged at micro-batch {i} ({ctx})");
        let eq = a.data.iter().zip(b.data.iter()).all(|(x, y)| x.to_bits() == y.to_bits());
        assert!(eq, "logits diverged at micro-batch {i} ({ctx})");
    }
}

/// The tentpole parity matrix: shard counts {2, 3} × threads {1, 2, 7},
/// noiseless and noisy, every micro-batch bit-identical to solo.
#[test]
fn sharded_pipeline_bit_identical_across_shards_and_threads() {
    let net = tiny_net();
    assert!(net.boundaries() >= 3, "test net must admit a 3-way split");
    let mut rng = Pcg64::seeded(2024);
    let inputs: Vec<(Tensor, u64)> =
        (0..4).map(|i| (rand_input(&mut rng, 1 + (i % 2)), 5000 + i as u64)).collect();
    for shards in [2usize, 3] {
        let ex = ShardedExecutor::balanced(&net, shards).unwrap();
        for threads in THREADS {
            let par = Parallelism::threads(threads);
            for mode in [ForwardMode::PimHw, ForwardMode::PimHwNoise(0.4)] {
                let mut scratch = ScratchPool::new();
                let (runs, trace) = ex.forward_pipelined(&inputs, mode, par, &mut scratch);
                assert_eq!(
                    trace.max_concurrent, shards,
                    "pipeline never reached steady state at {shards} shards"
                );
                assert_eq!(
                    trace.len(),
                    inputs.len() + shards - 1,
                    "pipelining must take m + s − 1 ticks, not m · s"
                );
                let ctx = format!("{shards} shards, {threads} threads, {mode:?}");
                assert_run_matches_solo(&net, &inputs, runs, mode, par, &ctx);
            }
        }
    }
}

/// The degenerate single-shard executor (no cuts) is exactly the solo
/// forward — the baseline the pipeline harness is anchored to.
#[test]
fn single_shard_executor_degenerates_to_solo() {
    let net = tiny_net();
    let ex = ShardedExecutor::new(&net, &[]).unwrap();
    let mut rng = Pcg64::seeded(7);
    let inputs = vec![(rand_input(&mut rng, 2), 71u64), (rand_input(&mut rng, 1), 72u64)];
    let par = Parallelism::threads(2);
    let mut scratch = ScratchPool::new();
    let (runs, trace) =
        ex.forward_pipelined(&inputs, ForwardMode::PimHwNoise(0.4), par, &mut scratch);
    assert_eq!(trace.max_concurrent, 1, "one shard cannot overlap");
    assert_run_matches_solo(
        &net,
        &inputs,
        runs,
        ForwardMode::PimHwNoise(0.4),
        par,
        "degenerate single shard",
    );
}

/// Proptest-style: random strictly increasing cut sets conserve the
/// outputs. Every case's seed appears in the assert context, so any
/// failure replays with a one-line filter.
#[test]
fn random_cut_points_conserve_outputs() {
    const CASES: u64 = 12;
    let net = tiny_net();
    let b = net.boundaries();
    for case in 0..CASES {
        let mut rng = Pcg64::seeded(0xC0DE + case);
        // 1..=3 cuts drawn without replacement from 1..b, sorted.
        let n_cuts = 1 + (rng.below(3) as usize).min(b - 2);
        let mut cuts: Vec<usize> = Vec::new();
        while cuts.len() < n_cuts {
            let c = 1 + rng.below((b - 1) as u64) as usize;
            if !cuts.contains(&c) {
                cuts.push(c);
            }
        }
        cuts.sort_unstable();
        let ex = ShardedExecutor::new(&net, &cuts).unwrap();
        let batch = 1 + rng.below(2) as usize;
        let inputs = vec![(rand_input(&mut rng, batch), 9000 + case)];
        let par = Parallelism::threads(2);
        let mut scratch = ScratchPool::new();
        let (runs, _) =
            ex.forward_pipelined(&inputs, ForwardMode::PimHwNoise(0.4), par, &mut scratch);
        let ctx = format!("case {case}, cuts {cuts:?}, batch {batch}");
        assert_run_matches_solo(&net, &inputs, runs, ForwardMode::PimHwNoise(0.4), par, &ctx);
    }
}

/// Placer invariants on the default wide fleet (the `repro fleet-sim`
/// configuration): the over-capacity tenant becomes a chain of segments
/// on distinct slices, every fitting tenant stays replica-parallel, no
/// slice overflows, and wear stays inside the endurance budget.
#[test]
fn placer_invariants_hold_for_the_wide_fleet() {
    let geom = Geometry::default();
    let reg = ModelRegistry::synthetic_with_wide(3);
    let placement = EndurancePlacer::new(geom, 8).place(&reg).unwrap();
    let capacity = geom.banks_per_slice * geom.subarrays_per_bank;

    // Fitting tenants are untouched by the shard machinery.
    for t in 0..3 {
        assert_eq!(placement.tenant_shards(t), 1, "tenant {t} must stay replica-parallel");
        assert!(placement.tenant_replicas(t).iter().all(|r| r.n_shards == 1));
    }

    // The wide tenant shards, and each replica's chain spreads across
    // distinct slices covering the layer list contiguously.
    let wide = reg
        .tenants
        .iter()
        .find(|t| t.name == "resnet18-w24")
        .expect("wide tenant present")
        .id;
    let shards = placement.tenant_shards(wide);
    assert!(shards >= 2, "over-capacity tenant must shard");
    for replica in 0..reg.tenants[wide].replicas {
        let chain = placement.replica_chain(wide, replica);
        assert_eq!(chain.len(), shards);
        let mut slices = std::collections::HashSet::new();
        let mut next_layer = 0;
        for (k, seg) in chain.iter().enumerate() {
            assert_eq!(seg.shard, k, "chain out of order");
            assert!(slices.insert(seg.slice), "chain segments must land on distinct slices");
            assert_eq!(seg.layer_range.0, next_layer, "segments must tile the layer list");
            next_layer = seg.layer_range.1.max(next_layer);
        }
        assert_eq!(next_layer, reg.tenants[wide].layers().len());
    }

    // Physical sanity: no slice overflows, and the post-initial-programming
    // wear of every slice — shard segments included — is within budget.
    for (s, &used) in placement.slots_used.iter().enumerate() {
        assert!(used <= capacity, "slice {s} overcommitted: {used}/{capacity}");
    }
    let policy = EndurancePolicy::default();
    for (s, w) in placement.wear.iter().enumerate() {
        assert!(w.within(&policy), "slice {s} outside the endurance window");
    }
}
