//! Continuous-batching serving tests: batcher merge-cut invariants under
//! seeded adversarial schedules (hand-rolled randomized harness — the
//! proptest crate is unavailable offline, see DESIGN.md §2), bit-parity
//! of merged stepped execution against solo forwards, and the front-door
//! simulator's determinism, knee ordering, and M/D/c analytic pin.

use std::time::{Duration, Instant};

use nvm_in_cache::coordinator::batcher::{BatchMode, Batcher, BatcherConfig};
use nvm_in_cache::coordinator::frontdoor::{
    self, ArrivalProcess, Discipline, FrontDoor, FrontDoorConfig, OverloadPolicy, TenantClass,
};
use nvm_in_cache::coordinator::request::InferenceRequest;
use nvm_in_cache::nn::resnet::test_params;
use nvm_in_cache::nn::{ForwardMode, ResNet, Tensor};
use nvm_in_cache::pim::parallel::Parallelism;
use nvm_in_cache::pim::program::{self, ScratchPool};
use nvm_in_cache::util::rng::Pcg64;

const CASES: u64 = 40;

fn req(id: u64, tenant: u32) -> InferenceRequest {
    InferenceRequest::new(id, vec![0.0; 4]).with_tenant(tenant)
}

/// One seeded adversarial schedule: interleaved pushes (random tenants)
/// and merge cuts (random room). Returns the cut sequence as id lists.
fn adversarial_cuts(seed: u64) -> Vec<Vec<(u64, u32)>> {
    let mut rng = Pcg64::seeded(0xbad5eed ^ seed);
    let max_batch = 1 + rng.below(6);
    let mut b = Batcher::new(BatcherConfig::continuous(max_batch, Duration::from_millis(1)));
    assert_eq!(b.config.mode, BatchMode::Continuous);
    let now = Instant::now();
    let mut next_id = 0u64;
    let mut cuts = Vec::new();
    for _ in 0..200 {
        if rng.below(2) == 0 {
            for _ in 0..rng.below(4) {
                b.push(req(next_id, rng.below(3) as u32));
                next_id += 1;
            }
        } else {
            let room = rng.below(8);
            let pending_before = b.pending();
            if let Some(cut) = b.take_merge(now, room) {
                assert!(cut.len() <= room, "cut {} exceeds room {room}", cut.len());
                assert!(
                    cut.len() <= b.config.max_batch,
                    "cut {} exceeds max_batch {}",
                    cut.len(),
                    b.config.max_batch
                );
                assert_eq!(
                    cut.len(),
                    pending_before.min(room).min(b.config.max_batch),
                    "merge cut must take everything the caps allow"
                );
                cuts.push(cut.requests.iter().map(|r| (r.id, r.tenant)).collect());
            } else {
                assert!(
                    room == 0 || pending_before == 0,
                    "take_merge may only decline when room or queue is empty"
                );
            }
        }
    }
    // Drain the tail so conservation can be checked end-to-end.
    while let Some(cut) = b.take_merge(now, usize::MAX) {
        cuts.push(cut.requests.iter().map(|r| (r.id, r.tenant)).collect());
    }
    assert_eq!(b.pending(), 0);
    let drained: u64 = cuts.iter().map(|c| c.len() as u64).sum();
    assert_eq!(drained, next_id, "no request lost or duplicated");
    cuts
}

/// Property: under any schedule of pushes and merge cuts, per-tenant FIFO
/// order is preserved, no cut exceeds `max_batch` or `room`, nothing is
/// lost, and the whole schedule is deterministic per seed.
#[test]
fn prop_continuous_merge_cut_invariants() {
    for seed in 0..CASES {
        let cuts = adversarial_cuts(seed);
        // Global FIFO across cuts implies per-tenant FIFO; check the
        // stronger global property directly on ids.
        let flat: Vec<u64> = cuts.iter().flatten().map(|&(id, _)| id).collect();
        let mut sorted = flat.clone();
        sorted.sort_unstable();
        assert_eq!(flat, sorted, "seed {seed}: merge cuts reordered requests");
        // Per-tenant FIFO, stated independently.
        for tenant in 0..3u32 {
            let per: Vec<u64> = cuts
                .iter()
                .flatten()
                .filter(|&&(_, t)| t == tenant)
                .map(|&(id, _)| id)
                .collect();
            assert!(
                per.windows(2).all(|w| w[0] < w[1]),
                "seed {seed}: tenant {tenant} order violated"
            );
        }
        // Determinism: the same seed yields the identical cut sequence.
        assert_eq!(cuts, adversarial_cuts(seed), "seed {seed}: schedule not deterministic");
    }
}

/// Property: whenever the merge cut has any room, the oldest request is
/// in it — continuous mode can never starve the queue front past a
/// boundary with spare capacity (the deadline-flush guarantee).
#[test]
fn prop_continuous_never_starves_front() {
    for seed in 0..CASES {
        let mut rng = Pcg64::seeded(0xf1f0 ^ seed);
        let mut b = Batcher::new(BatcherConfig::continuous(4, Duration::from_secs(10)));
        let now = Instant::now();
        let mut next_id = 0u64;
        for _ in 0..100 {
            for _ in 0..1 + rng.below(3) {
                b.push(req(next_id, 0));
                next_id += 1;
            }
            let front = next_id - b.pending() as u64;
            let room = 1 + rng.below(6);
            let cut = b.take_merge(now, room).expect("non-empty queue, positive room");
            assert_eq!(
                cut.requests[0].id, front,
                "seed {seed}: oldest request must ride the first available boundary"
            );
        }
    }
}

/// Merged stepped execution is bit-identical to solo forwards — across
/// thread counts and in the noisy hardware mode — and performs zero
/// weight prepares at any layer boundary.
#[test]
fn merged_stepped_execution_matches_solo_bitwise() {
    let net = ResNet::new(test_params(16, 10, 1));
    let prog = net.compile().unwrap();
    let dims = 16 * 16 * 3;
    let mut rng = Pcg64::seeded(77);
    let ta = Tensor::from_vec(&[2, 16, 16, 3], (0..2 * dims).map(|_| rng.f64() as f32).collect());
    let tb = Tensor::from_vec(&[1, 16, 16, 3], (0..dims).map(|_| rng.f64() as f32).collect());
    for threads in [1usize, 2, 7] {
        let par = Parallelism::threads(threads);
        for mode in [ForwardMode::PimHw, ForwardMode::PimHwNoise(0.4)] {
            let mut scratch = ScratchPool::new();
            let solo_a = prog.forward_par(&ta, mode, 5, par, &mut scratch);
            let solo_b = prog.forward_par(&tb, mode, 6, par, &mut scratch);
            let prepares = program::prepare_count();
            let mut run_a = prog.begin(&ta, 5);
            let mut done_a = prog.step(&mut run_a, mode, par, &mut scratch);
            // B merges while A is one boundary deep.
            let mut run_b = prog.begin(&tb, 6);
            let mut done_b = false;
            while !done_a || !done_b {
                if !done_a {
                    done_a = prog.step(&mut run_a, mode, par, &mut scratch);
                }
                if !done_b {
                    done_b = prog.step(&mut run_b, mode, par, &mut scratch);
                }
            }
            assert_eq!(
                program::prepare_count(),
                prepares,
                "continuous merging must stay prepare-free (t{threads}, {mode:?})"
            );
            assert_eq!(
                run_a.into_logits(),
                solo_a,
                "merged group A diverged from solo (t{threads}, {mode:?})"
            );
            assert_eq!(
                run_b.into_logits(),
                solo_b,
                "merged group B diverged from solo (t{threads}, {mode:?})"
            );
        }
    }
}

fn toy_door(discipline: Discipline) -> FrontDoor {
    let mut cfg = FrontDoorConfig::for_network(vec![5e-4; 5], 3);
    cfg.discipline = discipline;
    cfg.requests = 1500;
    FrontDoor::new(cfg)
}

/// The front-door sweep is a pure function of (config, seed): two runs
/// serialize identically, and the continuous knee sits at or beyond the
/// drain knee in absolute offered rate.
#[test]
fn frontdoor_sweep_deterministic_and_knee_ordered() {
    let fractions = [0.3, 0.7, 0.95, 1.1];
    let drain = toy_door(Discipline::DrainBatch).sweep(&fractions);
    let cont = toy_door(Discipline::Continuous).sweep(&fractions);
    assert_eq!(
        cont.to_json().to_string(),
        toy_door(Discipline::Continuous).sweep(&fractions).to_json().to_string()
    );
    assert!(
        cont.knee_rps >= drain.knee_rps,
        "continuous knee {} vs drain knee {}",
        cont.knee_rps,
        drain.knee_rps
    );
    assert!(cont.capacity_rps > drain.capacity_rps);
    // Above its knee the pipeline really co-schedules requests.
    assert!(cont.points.last().unwrap().mean_batch > 1.0);
}

/// Validation-mode simulator vs closed-form M/D/c at a second
/// (c, rho) point than the in-module test.
#[test]
fn frontdoor_matches_mdc_analytics() {
    let cc = frontdoor::queueing_crosscheck(1e-3, 2, 0.7, 10_000, 7);
    assert!(
        cc.within(0.10),
        "sim p50/p99 {}/{} vs analytic {}/{}",
        cc.sim_p50_s,
        cc.sim_p99_s,
        cc.analytic_p50_s,
        cc.analytic_p99_s
    );
}

/// Deadline shedding under overload: requests that cannot meet the QoS
/// deadline are rejected at admission, bounding the served tail.
#[test]
fn frontdoor_shed_policy_protects_deadline() {
    let mut cfg = FrontDoorConfig::for_network(vec![5e-4; 5], 3);
    cfg.discipline = Discipline::Continuous;
    cfg.policy = OverloadPolicy::Shed;
    cfg.requests = 1500;
    cfg.classes = vec![TenantClass {
        name: "strict".into(),
        weight: 1.0,
        deadline_s: 4.0 * cfg.service_total_s(),
    }];
    cfg.arrival = ArrivalProcess::Burst {
        base_rps: 1.0,
        burst_mult: 6.0,
        period_s: 0.2,
        duty: 0.3,
    };
    let door = FrontDoor::new(cfg);
    let p = door.run_point_at(1.4 * door.capacity_rps());
    assert!(p.shed > 0, "bursty overload must shed");
    assert!(p.served > 0, "but not everything");
    let bound = 5.0 * door.config.service_total_s();
    assert!(p.latency.p99 <= bound + 1e-9, "p99 {} vs bound {bound}", p.latency.p99);
}
