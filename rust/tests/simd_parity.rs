//! Differential harness for the word-wide bit-plane SIMD MAC kernel
//! (PERFORMANCE.md §8, EXPERIMENTS.md E14).
//!
//! The contract: [`MacKernel::BitPlane`] — the AND/popcount kernel over
//! transposed bit-plane bitmaps — is a pure *cost* optimization. Every
//! output it produces must be **bit-identical** to the historical scalar
//! kernel, to the independent straight-line specification
//! (`pim::program::spec_matmul`), and to the resurrected PR-4 network
//! choreography (`common::historical_forward`): noiseless and noisy, at
//! threads {1, 2, 7}, at every execution layer (raw engine, compiled
//! ResNet in all five forward modes, StubRuntime serving path), including
//! the caller's trailing RNG state. The scalar kernel stays alive behind
//! [`PimEngine::with_kernel`] / [`MacKernel::set_thread_default`]
//! precisely so this suite can race the two implementations forever.
//!
//! `scripts/verify.sh` additionally runs this suite with `--release`,
//! where u64 lane-packing bugs actually surface.

use nvm_in_cache::consts::{ARRAY_ROWS, ARRAY_WORDS};
use nvm_in_cache::nn::resnet::test_params;
use nvm_in_cache::nn::{ForwardMode, ResNet, Tensor};
use nvm_in_cache::pim::engine::MacKernel;
use nvm_in_cache::pim::parallel::Parallelism;
use nvm_in_cache::pim::program::{spec_matmul, ScratchPool};
use nvm_in_cache::pim::quant::QuantizedActs;
use nvm_in_cache::pim::transfer::{TransferModel, MAC_FULLSCALE};
use nvm_in_cache::pim::PimEngine;
use nvm_in_cache::runtime::{ModelVariant, Runtime, StubRuntime};
use nvm_in_cache::util::rng::Pcg64;

mod common;
use common::{bits, historical_forward, rand_mat, KernelGuard, THREADS};

/// Engine level, noiseless: SIMD vs scalar vs the independent
/// straight-line spec, over ragged multi-block/multi-tile shapes and a
/// shared prepared program, at every thread count.
#[test]
fn engine_simd_scalar_spec_bit_identical() {
    let mut rng = Pcg64::seeded(900);
    for &(m, k, n) in &[(5usize, 300usize, 157usize), (1, 128, 128), (3, 45, 31)] {
        let a = rand_mat(&mut rng, m * k, 0.0, 1.0);
        let w = rand_mat(&mut rng, k * n, -0.5, 0.5);
        let spec = spec_matmul(&a, m, k, &w, n);
        let simd = PimEngine::tt();
        assert_eq!(simd.kernel, MacKernel::BitPlane, "SIMD kernel is the default");
        let scalar = simd.clone().with_kernel(MacKernel::Scalar);
        let program = simd.prepare(&w, k, n);
        for t in THREADS {
            let par = Parallelism::threads(t);
            let got_simd = simd.par_matmul_prepared(&a, m, &program, None, par);
            let got_scalar = scalar.par_matmul_prepared(&a, m, &program, None, par);
            assert_eq!(bits(&got_simd), bits(&got_scalar), "m={m} k={k} n={n} t={t}");
            assert_eq!(bits(&got_simd), bits(&spec), "m={m} k={k} n={n} t={t} vs spec");
        }
    }
}

/// Engine level, noisy: identical outputs **and** identical trailing RNG
/// state — the SIMD kernel must not change how many draws happen or in
/// what order, at any thread count.
#[test]
fn engine_noisy_bit_identical_including_rng_state() {
    let mut rng = Pcg64::seeded(905);
    let (m, k, n) = (4, 300, 157);
    let a = rand_mat(&mut rng, m * k, 0.0, 1.0);
    let w = rand_mat(&mut rng, k * n, -0.5, 0.5);
    let simd = PimEngine::tt().with_noise(0.5);
    let scalar = simd.clone().with_kernel(MacKernel::Scalar);
    let program = simd.prepare(&w, k, n);
    for t in THREADS {
        let par = Parallelism::threads(t);
        let mut r1 = Pcg64::seeded(31);
        let x = simd.par_matmul_prepared(&a, m, &program, Some(&mut r1), par);
        let mut r2 = Pcg64::seeded(31);
        let y = scalar.par_matmul_prepared(&a, m, &program, Some(&mut r2), par);
        assert_eq!(bits(&x), bits(&y), "threads={t}");
        assert_eq!(r1.next_u64(), r2.next_u64(), "trailing rng state diverged at t={t}");
    }
}

/// Exhaustive small-shape sweep: every (m, n) ∈ 1..=9 × k ∈ {1..=9} ∪
/// values crossing the 64-bit plane-word boundary (63, 64, 65, 127) and
/// the 128-row block boundary (128, 129, …, 257) — SIMD vs scalar vs
/// spec, noiseless, bit-for-bit. This is where ragged last words, ragged
/// last blocks, and word/block boundary interactions live.
#[test]
fn exhaustive_small_shapes_cross_word_and_block_boundaries() {
    let ks: Vec<usize> = (1..=9)
        .chain([63, 64, 65, 127, 128, 129, 191, 192, 193, 255, 256, 257])
        .collect();
    let simd = PimEngine::tt();
    let scalar = PimEngine::tt().with_kernel(MacKernel::Scalar);
    let mut rng = Pcg64::seeded(910);
    for m in 1..=9usize {
        for &k in &ks {
            for n in 1..=9usize {
                let a = rand_mat(&mut rng, m * k, 0.0, 2.0);
                let w = rand_mat(&mut rng, k * n, -1.0, 1.0);
                let got_simd = simd.pim_matmul(&a, m, k, &w, n, None);
                let got_scalar = scalar.pim_matmul(&a, m, k, &w, n, None);
                let spec = spec_matmul(&a, m, k, &w, n);
                assert_eq!(bits(&got_simd), bits(&got_scalar), "m={m} k={k} n={n}");
                assert_eq!(bits(&got_simd), bits(&spec), "m={m} k={k} n={n} vs spec");
            }
        }
    }
}

/// Saturation: all-15 activations × all-15 weights over full 128-row
/// blocks is the worst-case popcount accumulation — every bit-plane lane
/// reaches its ceiling (15 · 128 = 1920 = `MAC_FULLSCALE`) in every
/// block. Both kernels must agree with each other and with the closed
/// form, proving the 16-bit lanes hold the ceiling without wrapping.
#[test]
fn saturated_full_blocks_hit_lane_ceiling_without_wrap() {
    let tm = TransferModel::tt();
    let lut_top = tm.quantize_mac(MAC_FULLSCALE as f64, true) as f32;
    // Same f32 expression shape as the engine's plane recombination.
    let block = lut_top + 2.0 * lut_top + 4.0 * lut_top + 8.0 * lut_top;
    for blocks in [1usize, 2] {
        let (m, k, n) = (2, blocks * ARRAY_ROWS, ARRAY_WORDS + 2); // ragged tile
        let qa = QuantizedActs { data: vec![15u8; m * k], m, k, scale: 1.0 };
        let bank = vec![15u8; k * n];
        let simd = PimEngine::tt();
        let scalar = PimEngine::tt().with_kernel(MacKernel::Scalar);
        let got_simd = simd.bank_mac(&qa, &bank, n, None);
        let got_scalar = scalar.bank_mac(&qa, &bank, n, None);
        assert_eq!(bits(&got_simd), bits(&got_scalar), "blocks={blocks}");
        let mut want = 0.0f32;
        for _ in 0..blocks {
            want += block; // unit-order shift-add reduce
        }
        assert!(
            got_simd.iter().all(|&v| v == want),
            "blocks={blocks}: expected {want} everywhere, got {got_simd:?}"
        );
    }
}

/// The recombination lanes are 16 bits wide; a k-block may never produce
/// a bit-plane MAC above `u16::MAX`. The engine enforces this at compile
/// time (const assert) and per unit (debug_assert); this pins the two
/// numbers the invariant hangs on, so a future geometry change fails
/// loudly here too instead of silently wrapping the packed accumulator.
#[test]
fn k_block_mac_fits_the_16_bit_recombination_lanes() {
    assert!(ARRAY_ROWS * 15 <= u16::MAX as usize);
    assert!(ARRAY_ROWS % 64 == 0, "blocks must align with 64-bit plane words");
    // The worst case really is reachable: MAC_FULLSCALE == 15 · rows.
    assert_eq!(MAC_FULLSCALE as usize, ARRAY_ROWS * 15);
}

/// Network level: the compiled ResNet forward (all five modes) and the
/// resurrected historical choreography, run on both kernels via the
/// thread-default seam (the layers construct their own engines
/// internally), must produce identical logits at every thread count.
#[test]
fn resnet_all_modes_bit_identical_across_kernels() {
    let net = ResNet::new(test_params(8, 10, 42));
    let program = net.compile().unwrap();
    let mut rng = Pcg64::seeded(920);
    let x = Tensor::from_vec(
        &[2, 16, 16, 3],
        (0..2 * 16 * 16 * 3).map(|_| rng.f64() as f32).collect(),
    );
    let mut scratch = ScratchPool::new();
    for mode in [
        ForwardMode::Baseline,
        ForwardMode::Pim,
        ForwardMode::PimNoise(0.4),
        ForwardMode::PimHw,
        ForwardMode::PimHwNoise(0.4),
    ] {
        for t in THREADS {
            let par = Parallelism::threads(t);
            let simd_compiled = program.forward_par(&x, mode, 7, par, &mut scratch);
            let simd_hist = historical_forward(&net, &x, mode, 7, par);
            assert_eq!(
                bits(&simd_compiled.data),
                bits(&simd_hist.data),
                "{mode:?} t={t}: compiled vs historical (SIMD)"
            );
            // The compiled program holds no engine — forwards construct
            // theirs at call time, so the guard alone flips the kernel.
            let (scalar_compiled, scalar_hist) = {
                let _guard = KernelGuard::scalar();
                (
                    program.forward_par(&x, mode, 7, par, &mut scratch),
                    historical_forward(&net, &x, mode, 7, par),
                )
            };
            assert_eq!(
                bits(&simd_compiled.data),
                bits(&scalar_compiled.data),
                "{mode:?} t={t}: SIMD vs scalar (compiled)"
            );
            assert_eq!(
                bits(&simd_hist.data),
                bits(&scalar_hist.data),
                "{mode:?} t={t}: SIMD vs scalar (historical)"
            );
        }
    }
}

/// Runtime level: the StubRuntime serving path (cached compiled
/// programs) returns identical logits on both kernels, for both the
/// hardware-true and baseline variants, at every thread count.
#[test]
fn stub_runtime_bit_identical_across_kernels() {
    let batch = 2;
    let params = test_params(8, 10, 21);
    let mut rng = Pcg64::seeded(930);
    let images: Vec<f32> = (0..batch * 16 * 16 * 3).map(|_| rng.f64() as f32).collect();
    let run = |kernel: MacKernel, threads: usize| -> (Vec<u32>, Vec<u32>) {
        let _guard = match kernel {
            MacKernel::Scalar => Some(KernelGuard::scalar()),
            MacKernel::BitPlane => None,
        };
        let mut rt = StubRuntime::new(batch);
        rt.load_variant_params(ModelVariant::PimHw, params.clone()).unwrap();
        rt.load_variant_params(ModelVariant::Baseline, params.clone()).unwrap();
        rt.set_parallelism(Parallelism::threads(threads));
        let hw = rt.forward(ModelVariant::PimHw, &images, (16, 16, 3), None).unwrap();
        let base = rt.forward(ModelVariant::Baseline, &images, (16, 16, 3), None).unwrap();
        (bits(&hw), bits(&base))
    };
    for t in THREADS {
        let simd = run(MacKernel::BitPlane, t);
        let scalar = run(MacKernel::Scalar, t);
        assert_eq!(simd, scalar, "threads={t}");
    }
}
