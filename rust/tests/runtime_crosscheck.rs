//! Cross-language runtime checks: the AOT-exported HLO artifacts executed
//! through PJRT must agree with the Rust-native implementations.
//!
//! These tests need `make artifacts` to have run; they skip (pass with a
//! notice) when the artifact directory is absent so `cargo test` stays
//! green on a fresh checkout.

use nvm_in_cache::nn::{Dataset, ForwardMode, ResNet, Tensor};
use nvm_in_cache::pim::quant::QuantizedActs;
use nvm_in_cache::pim::transfer::{ADC_CODES, MAC_FULLSCALE};
use nvm_in_cache::pim::PimEngine;
use nvm_in_cache::runtime::{ArtifactDir, ModelVariant, Runtime};
use nvm_in_cache::util::rng::Pcg64;

fn artifacts() -> Option<ArtifactDir> {
    match ArtifactDir::open("artifacts") {
        Ok(d) => Some(d),
        Err(_) => {
            eprintln!("NOTE: artifacts/ missing — run `make artifacts`; skipping");
            None
        }
    }
}

/// The L1 pallas kernel HLO, executed via PJRT, must match the Rust
/// engine's LUT math on random integer tiles to well below one ADC LSB.
#[test]
fn pim_mac_kernel_hlo_matches_engine() {
    let Some(dir) = artifacts() else { return };
    let mut rt = Runtime::new(1).expect("pjrt cpu client");
    rt.load_kernel(&dir, "pim_mac.hlo.txt").expect("kernel compiles");
    let eng = PimEngine::tt();
    let mut rng = Pcg64::seeded(77);
    for case in 0..3 {
        let a_int: Vec<u8> = (0..128 * 128).map(|_| rng.below(16) as u8).collect();
        let w_int: Vec<u8> = (0..128 * 128).map(|_| rng.below(16) as u8).collect();
        let a_f: Vec<f32> = a_int.iter().map(|&x| x as f32).collect();
        let w_f: Vec<f32> = w_int.iter().map(|&x| x as f32).collect();
        let hlo_out = rt.pim_mac_tile(&a_f, &w_f).expect("kernel runs");
        let rust_out = eng.bank_mac(
            &QuantizedActs { data: a_int, m: 128, k: 128, scale: 1.0 },
            &w_int,
            128,
            None,
        );
        let lsb = MAC_FULLSCALE as f32 / ADC_CODES as f32;
        let mut max_err = 0.0f32;
        for (h, r) in hlo_out.iter().zip(rust_out.iter()) {
            max_err = max_err.max((h - r).abs());
        }
        assert!(
            max_err < 0.1 * lsb,
            "case {case}: kernel-vs-engine max err {max_err} (LSB {lsb})"
        );
    }
}

/// The baseline model HLO must match the Rust-native fp32 forward on the
/// real weights — layout, GroupNorm, padding: everything.
#[test]
fn baseline_model_hlo_matches_native() {
    let Some(dir) = artifacts() else { return };
    let batch = dir.eval_batch();
    let mut rt = Runtime::new(batch).expect("pjrt");
    rt.load_variant(&dir, ModelVariant::Baseline).expect("compiles");
    let ds = Dataset::load(&dir.path("dataset.bin").unwrap()).unwrap();
    let net = ResNet::load(&dir.path("weights.bin").unwrap()).unwrap();
    let (x, _) = ds.batch(0, batch);
    let hlo_logits = rt
        .forward(ModelVariant::Baseline, &x.data, (ds.h, ds.w, ds.c), None)
        .unwrap();
    let native = net.forward(&x, ForwardMode::Baseline, 0).unwrap();
    assert_eq!(hlo_logits.len(), native.len());
    let scale = native.data.iter().map(|v| v.abs()).fold(0.0f32, f32::max);
    let max_err = hlo_logits
        .iter()
        .zip(&native.data)
        .map(|(a, b)| (a - b).abs())
        .fold(0.0f32, f32::max);
    assert!(
        max_err < 5e-3 * scale.max(1.0),
        "baseline logits diverge: max err {max_err}, scale {scale}"
    );
    // And the predictions agree exactly.
    let hlo_preds: Vec<u8> = hlo_logits
        .chunks(10)
        .map(|r| r.iter().enumerate().max_by(|a, b| a.1.partial_cmp(b.1).unwrap()).unwrap().0 as u8)
        .collect();
    let native_preds = net.classify(&x, ForwardMode::Baseline, 0).unwrap();
    assert_eq!(hlo_preds, native_preds);
}

/// Table II through PJRT must reproduce the manifest accuracies (same
/// dataset, same weights — exact for deterministic variants).
#[test]
fn table2_via_pjrt_matches_manifest() {
    let Some(dir) = artifacts() else { return };
    let ds = Dataset::load(&dir.path("dataset.bin").unwrap()).unwrap();
    let batch = dir.eval_batch();
    let mut rt = Runtime::new(batch).expect("pjrt");
    for (variant, key) in [
        (ModelVariant::Baseline, "baseline"),
        (ModelVariant::Pim, "pim_finetuned"),
    ] {
        rt.load_variant(&dir, variant).expect("compiles");
        let mut correct = 0usize;
        let mut total = 0usize;
        let mut start = 0usize;
        while start < ds.n {
            let take = batch.min(ds.n - start);
            let (x, labels) = ds.batch(start, take);
            let mut images = x.data.clone();
            images.resize(batch * ds.h * ds.w * ds.c, 0.0);
            let preds = rt
                .classify(variant, &images, (ds.h, ds.w, ds.c), 10, None)
                .unwrap();
            for (p, l) in preds.iter().zip(labels.iter()) {
                correct += (p == l) as usize;
                total += 1;
            }
            start += take;
        }
        let acc = correct as f64 / total as f64;
        let expected = dir.manifest.accuracy(key).expect("manifest accuracy");
        assert!(
            (acc - expected).abs() < 0.005,
            "{variant:?}: PJRT acc {acc:.4} vs manifest {expected:.4}"
        );
        println!("{variant:?}: {acc:.4} (manifest {expected:.4}) ✓");
    }
}

/// The noise variant is deterministic in the key and perturbs predictions
/// only slightly at the calibrated sigma.
#[test]
fn noise_variant_deterministic_and_mild() {
    let Some(dir) = artifacts() else { return };
    let ds = Dataset::load(&dir.path("dataset.bin").unwrap()).unwrap();
    let batch = dir.eval_batch();
    let mut rt = Runtime::new(batch).expect("pjrt");
    rt.load_variant(&dir, ModelVariant::PimNoise).expect("compiles");
    let (x, _) = ds.batch(0, batch);
    let a = rt
        .forward(ModelVariant::PimNoise, &x.data, (ds.h, ds.w, ds.c), Some([1, 2]))
        .unwrap();
    let b = rt
        .forward(ModelVariant::PimNoise, &x.data, (ds.h, ds.w, ds.c), Some([1, 2]))
        .unwrap();
    let c = rt
        .forward(ModelVariant::PimNoise, &x.data, (ds.h, ds.w, ds.c), Some([3, 4]))
        .unwrap();
    assert_eq!(a, b, "same key ⇒ identical logits");
    assert_ne!(a, c, "different key ⇒ different noise");
    // Noise is mild: logit perturbation well below the logit scale.
    let scale = a.iter().map(|v| v.abs()).fold(0.0f32, f32::max);
    let mean_d: f32 =
        a.iter().zip(&c).map(|(x, y)| (x - y).abs()).sum::<f32>() / a.len() as f32;
    assert!(mean_d < 0.5 * scale, "noise too large: {mean_d} vs {scale}");
}

/// Native Rust PIM-emulation accuracy lands near the manifest number — the
/// three implementations (JAX, PJRT-HLO, Rust-native) of the §V-E pipeline
/// agree at the accuracy level.
#[test]
fn native_pim_accuracy_near_manifest() {
    let Some(dir) = artifacts() else { return };
    let ds = Dataset::load(&dir.path("dataset.bin").unwrap()).unwrap();
    let net = ResNet::load(&dir.path("weights_ft.bin").unwrap()).unwrap();
    // Subset for speed (native conv is the slow path).
    let n = 200.min(ds.n);
    let (x, labels) = ds.batch(0, n);
    let x = Tensor::from_vec(&[n, ds.h, ds.w, ds.c], x.data);
    let preds = net.classify(&x, ForwardMode::Pim, 0).unwrap();
    let acc = preds
        .iter()
        .zip(labels.iter())
        .filter(|(p, l)| p == l)
        .count() as f64
        / n as f64;
    let expected = dir.manifest.accuracy("pim_finetuned").unwrap();
    assert!(
        (acc - expected).abs() < 0.06,
        "native PIM acc {acc:.3} vs manifest {expected:.3} (subset n={n})"
    );
}
