//! Runtime-backend cross-checks: any `Runtime` implementation must agree
//! with the Rust-native ground truth (`nn::ResNet` + `pim::PimEngine`).
//!
//! The first group runs unconditionally against the in-tree `StubRuntime`
//! (synthetic weights, no artifacts needed) and pins the trait contract:
//! tile layout, batch shapes, noise keying. The second group needs the
//! trained artifacts (weights/dataset/manifest, produced by
//! `python/compile/aot.py`); those tests skip (pass with a notice) when
//! the artifact directory is absent so `cargo test` stays green on a
//! fresh checkout.

use nvm_in_cache::nn::resnet::test_params;
use nvm_in_cache::nn::{Dataset, ForwardMode, ResNet, Tensor};
use nvm_in_cache::pim::quant::QuantizedActs;
use nvm_in_cache::pim::transfer::{ADC_CODES, MAC_FULLSCALE};
use nvm_in_cache::pim::PimEngine;
use nvm_in_cache::runtime::{default_runtime, ArtifactDir, ModelVariant, Runtime, StubRuntime};
use nvm_in_cache::util::rng::Pcg64;

fn artifacts() -> Option<ArtifactDir> {
    match ArtifactDir::open("artifacts") {
        Ok(d) => Some(d),
        Err(_) => {
            eprintln!("NOTE: artifacts/ missing — see python/compile/aot.py; skipping");
            None
        }
    }
}

// ---------------------------------------------------------------------------
// Contract tests (no artifacts required)
// ---------------------------------------------------------------------------

/// The runtime's MAC-tile kernel must match the engine's LUT math on
/// random integer tiles to well below one ADC LSB — the same bound the
/// original PJRT-executed pallas kernel was held to. The load-before-use
/// contract is part of the check.
#[test]
fn runtime_mac_tile_matches_engine() {
    let mut rt = StubRuntime::new(1);
    let a_probe = vec![1.0f32; 128 * 128];
    assert!(rt.pim_mac_tile(&a_probe, &a_probe).is_err(), "must load first");
    rt.load_kernel_emulated("pim_mac.hlo.txt").expect("known kernel");
    let eng = PimEngine::tt();
    let mut rng = Pcg64::seeded(77);
    for case in 0..3 {
        let a_int: Vec<u8> = (0..128 * 128).map(|_| rng.below(16) as u8).collect();
        let w_int: Vec<u8> = (0..128 * 128).map(|_| rng.below(16) as u8).collect();
        let a_f: Vec<f32> = a_int.iter().map(|&x| x as f32).collect();
        let w_f: Vec<f32> = w_int.iter().map(|&x| x as f32).collect();
        let tile_out = rt.pim_mac_tile(&a_f, &w_f).expect("kernel runs");
        let rust_out = eng.bank_mac(
            &QuantizedActs { data: a_int, m: 128, k: 128, scale: 1.0 },
            &w_int,
            128,
            None,
        );
        let lsb = MAC_FULLSCALE as f32 / ADC_CODES as f32;
        let mut max_err = 0.0f32;
        for (h, r) in tile_out.iter().zip(rust_out.iter()) {
            max_err = max_err.max((h - r).abs());
        }
        assert!(
            max_err < 0.1 * lsb,
            "case {case}: kernel-vs-engine max err {max_err} (LSB {lsb})"
        );
    }
}

/// A batch routed through the `Runtime` trait must reproduce the native
/// forward exactly — layout, GroupNorm, padding: everything. (Synthetic
/// weights; the artifact-gated variant below repeats this on the trained
/// ones.)
#[test]
fn runtime_forward_matches_native() {
    let batch = 2;
    let params = test_params(8, 10, 21);
    let net = ResNet::new(params.clone());
    let mut rt = StubRuntime::new(batch);
    rt.load_variant_params(ModelVariant::Baseline, params).unwrap();
    let mut rng = Pcg64::seeded(22);
    let images: Vec<f32> = (0..batch * 16 * 16 * 3).map(|_| rng.f64() as f32).collect();
    let rt_logits = rt
        .forward(ModelVariant::Baseline, &images, (16, 16, 3), None)
        .unwrap();
    let x = Tensor::from_vec(&[batch, 16, 16, 3], images.clone());
    let native = net.forward(&x, ForwardMode::Baseline, 0).unwrap();
    assert_eq!(rt_logits, native.data, "trait path must be bit-identical");
    // Predictions agree too (via the trait's default classify).
    let rt_preds = rt
        .classify(ModelVariant::Baseline, &images, (16, 16, 3), 10, None)
        .unwrap();
    let native_preds = net.classify(&x, ForwardMode::Baseline, 0).unwrap();
    assert_eq!(rt_preds, native_preds);
}

/// The noise variant is deterministic in the key and perturbs logits only
/// mildly at the calibrated sigma.
#[test]
fn noise_variant_deterministic_and_mild() {
    let batch = 1;
    let mut rt = StubRuntime::new(batch);
    rt.load_variant_params(ModelVariant::PimNoise, test_params(8, 10, 23)).unwrap();
    let mut rng = Pcg64::seeded(24);
    let images: Vec<f32> = (0..batch * 16 * 16 * 3).map(|_| rng.f64() as f32).collect();
    let a = rt
        .forward(ModelVariant::PimNoise, &images, (16, 16, 3), Some([1, 2]))
        .unwrap();
    let b = rt
        .forward(ModelVariant::PimNoise, &images, (16, 16, 3), Some([1, 2]))
        .unwrap();
    let c = rt
        .forward(ModelVariant::PimNoise, &images, (16, 16, 3), Some([3, 4]))
        .unwrap();
    assert_eq!(a, b, "same key ⇒ identical logits");
    assert_ne!(a, c, "different key ⇒ different noise");
    // Noise is mild: logit perturbation well below the logit scale.
    let scale = a.iter().map(|v| v.abs()).fold(0.0f32, f32::max);
    let mean_d: f32 =
        a.iter().zip(&c).map(|(x, y)| (x - y).abs()).sum::<f32>() / a.len() as f32;
    assert!(mean_d < 0.5 * scale, "noise too large: {mean_d} vs {scale}");
}

// ---------------------------------------------------------------------------
// Artifact-gated tests (trained weights + dataset + manifest)
// ---------------------------------------------------------------------------

/// The default runtime loaded from artifacts must match the Rust-native
/// fp32 forward on the real weights.
#[test]
fn baseline_model_matches_native() {
    let Some(dir) = artifacts() else { return };
    let batch = dir.eval_batch();
    let mut rt = default_runtime(batch).expect("runtime");
    rt.load_variant(&dir, ModelVariant::Baseline).expect("loads");
    let ds = Dataset::load(&dir.path("dataset.bin").unwrap()).unwrap();
    let net = ResNet::load(&dir.path("weights.bin").unwrap()).unwrap();
    let (x, _) = ds.batch(0, batch);
    let rt_logits = rt
        .forward(ModelVariant::Baseline, &x.data, (ds.h, ds.w, ds.c), None)
        .unwrap();
    let native = net.forward(&x, ForwardMode::Baseline, 0).unwrap();
    assert_eq!(rt_logits.len(), native.len());
    let scale = native.data.iter().map(|v| v.abs()).fold(0.0f32, f32::max);
    let max_err = rt_logits
        .iter()
        .zip(&native.data)
        .map(|(a, b)| (a - b).abs())
        .fold(0.0f32, f32::max);
    assert!(
        max_err < 5e-3 * scale.max(1.0),
        "baseline logits diverge: max err {max_err}, scale {scale}"
    );
    // And the predictions agree exactly.
    let rt_preds = rt
        .classify(ModelVariant::Baseline, &x.data, (ds.h, ds.w, ds.c), 10, None)
        .unwrap();
    let native_preds = net.classify(&x, ForwardMode::Baseline, 0).unwrap();
    assert_eq!(rt_preds, native_preds);
}

/// Table II through the runtime must reproduce the manifest accuracies
/// (same dataset, same weights — exact for deterministic variants).
#[test]
fn table2_via_runtime_matches_manifest() {
    let Some(dir) = artifacts() else { return };
    let ds = Dataset::load(&dir.path("dataset.bin").unwrap()).unwrap();
    let batch = dir.eval_batch();
    let mut rt = default_runtime(batch).expect("runtime");
    for (variant, key) in [
        (ModelVariant::Baseline, "baseline"),
        (ModelVariant::Pim, "pim_finetuned"),
    ] {
        rt.load_variant(&dir, variant).expect("loads");
        let mut correct = 0usize;
        let mut total = 0usize;
        let mut start = 0usize;
        while start < ds.n {
            let take = batch.min(ds.n - start);
            let (x, labels) = ds.batch(start, take);
            let mut images = x.data.clone();
            images.resize(batch * ds.h * ds.w * ds.c, 0.0);
            let preds = rt
                .classify(variant, &images, (ds.h, ds.w, ds.c), 10, None)
                .unwrap();
            for (p, l) in preds.iter().zip(labels.iter()) {
                correct += (p == l) as usize;
                total += 1;
            }
            start += take;
        }
        let acc = correct as f64 / total as f64;
        let expected = dir.manifest.accuracy(key).expect("manifest accuracy");
        assert!(
            (acc - expected).abs() < 0.005,
            "{variant:?}: runtime acc {acc:.4} vs manifest {expected:.4}"
        );
        println!("{variant:?}: {acc:.4} (manifest {expected:.4}) ✓");
    }
}

/// Native Rust PIM-emulation accuracy lands near the manifest number — the
/// implementations (training pipeline vs. Rust-native) of the §V-E
/// pipeline agree at the accuracy level.
#[test]
fn native_pim_accuracy_near_manifest() {
    let Some(dir) = artifacts() else { return };
    let ds = Dataset::load(&dir.path("dataset.bin").unwrap()).unwrap();
    let net = ResNet::load(&dir.path("weights_ft.bin").unwrap()).unwrap();
    // Subset for speed (native conv is the slow path).
    let n = 200.min(ds.n);
    let (x, labels) = ds.batch(0, n);
    let x = Tensor::from_vec(&[n, ds.h, ds.w, ds.c], x.data);
    let preds = net.classify(&x, ForwardMode::Pim, 0).unwrap();
    let acc = preds
        .iter()
        .zip(labels.iter())
        .filter(|(p, l)| p == l)
        .count() as f64
        / n as f64;
    let expected = dir.manifest.accuracy("pim_finetuned").unwrap();
    assert!(
        (acc - expected).abs() < 0.06,
        "native PIM acc {acc:.3} vs manifest {expected:.3} (subset n={n})"
    );
}
