//! Helpers shared by the parity harnesses (`program_parity.rs`,
//! `simd_parity.rs`, `shard_parity.rs`, `transformer_parity.rs`):
//! deterministic matrix/tensor generation, f32 → bit-pattern views, the
//! standard thread-sweep table, the scalar-kernel RAII guard, and the
//! resurrected PR-4 `ResNet::forward_par` body that serves as the
//! historical network-choreography reference. (Cargo only builds files
//! directly under `tests/` as test binaries, so this directory module is
//! shared, not a test crate of its own.)
#![allow(dead_code)] // each test binary uses its own subset

use nvm_in_cache::nn::{ForwardMode, ResNet, Tensor};
use nvm_in_cache::pim::engine::MacKernel;
use nvm_in_cache::pim::parallel::Parallelism;
use nvm_in_cache::pim::PimEngine;
use nvm_in_cache::util::rng::Pcg64;

/// Thread counts every parity claim is checked at (serial, the smallest
/// real pool, and an uneven count that exercises remainder tiling).
pub const THREADS: [usize; 3] = [1, 2, 7];

/// Restores the thread-default kernel on drop, so a failing assertion
/// inside a scalar-forced section cannot leak `Scalar` into later code
/// on the same thread.
pub struct KernelGuard;

impl KernelGuard {
    pub fn scalar() -> KernelGuard {
        MacKernel::set_thread_default(MacKernel::Scalar);
        KernelGuard
    }
}

impl Drop for KernelGuard {
    fn drop(&mut self) {
        MacKernel::set_thread_default(MacKernel::BitPlane);
    }
}

pub fn rand_mat(rng: &mut Pcg64, len: usize, lo: f64, hi: f64) -> Vec<f32> {
    (0..len).map(|_| rng.range(lo, hi) as f32).collect()
}

pub fn bits(v: &[f32]) -> Vec<u32> {
    v.iter().map(|x| x.to_bits()).collect()
}

/// A random `[n, 16, 16, 3]` image batch — the CNN test-input shape.
pub fn rand_image(rng: &mut Pcg64, n: usize) -> Tensor {
    Tensor::from_vec(&[n, 16, 16, 3], (0..n * 16 * 16 * 3).map(|_| rng.f64() as f32).collect())
}

/// A random `[n, seq_len, d_model]` token batch — the transformer
/// test-input shape.
pub fn rand_tokens(rng: &mut Pcg64, n: usize, seq_len: usize, d_model: usize) -> Tensor {
    Tensor::from_vec(
        &[n, seq_len, d_model],
        (0..n * seq_len * d_model).map(|_| rng.f64() as f32).collect(),
    )
}

/// The pre-refactor (PR 4) `ResNet::forward_par` body, resurrected
/// verbatim as the **historical reference** — built from the public
/// one-shot layer APIs only, no `CompiledNet`. This independently
/// restates the network choreography the compiled forward must
/// reproduce: per-layer RNG forks (`rng_opt`), §V-E `post` placement,
/// the downsample-only fork, and the fc bias deferred past `post`.
/// (Engine-level fidelity of the one-shot layers it calls is pinned
/// separately by `spec_matmul` parity.)
pub fn historical_forward(
    net: &ResNet,
    x: &Tensor,
    mode: ForwardMode,
    seed: u64,
    par: Parallelism,
) -> Tensor {
    use nvm_in_cache::nn::layers;
    use nvm_in_cache::nn::resnet::STAGES;
    use nvm_in_cache::pim::TransferModel;

    let engine = match mode {
        ForwardMode::PimHw => Some(PimEngine::tt().with_parallelism(par)),
        ForwardMode::PimHwNoise(sigma) => {
            Some(PimEngine::tt().with_noise(sigma).with_parallelism(par))
        }
        _ => None,
    };
    let emu_sigma: Option<Option<f64>> = match mode {
        ForwardMode::Pim => Some(None),
        ForwardMode::PimNoise(s) => Some(Some(s)),
        _ => None,
    };
    let transfer = TransferModel::tt();
    let mut rng = Pcg64::seeded(seed);
    let hw_noise = matches!(mode, ForwardMode::PimHwNoise(_));
    let rng_opt = |r: &mut Pcg64| -> Option<Pcg64> {
        if hw_noise {
            Some(r.fork(1))
        } else {
            None
        }
    };
    let p = &net.params;
    let eng = engine.as_ref();

    let gn = |t: &Tensor, g: &Tensor, b: &Tensor| -> Tensor {
        layers::group_norm(t, &g.data, &b.data, 1e-5)
    };
    let post = |t: Tensor, r: &mut Pcg64| -> Tensor {
        match emu_sigma {
            None => t,
            Some(sigma) => {
                let mut local = r.fork(2);
                layers::adc_emulate(&t, &transfer, sigma, Some(&mut local))
            }
        }
    };

    let mut local = rng_opt(&mut rng);
    let mut h = layers::conv2d_par(x, p.get("stem/w").unwrap(), 1, eng, local.as_mut(), par);
    h = post(h, &mut rng);
    h = gn(&h, p.get("stem/gamma").unwrap(), p.get("stem/beta").unwrap()).relu();

    for (s, &nblocks) in STAGES.iter().enumerate() {
        let stride = if s == 0 { 1 } else { 2 };
        for b in 0..nblocks {
            let st = if b == 0 { stride } else { 1 };
            let pre = format!("s{s}b{b}");
            let get = |name: &str| p.get(&format!("{pre}/{name}")).unwrap();
            let idn = h.clone();
            let mut local = rng_opt(&mut rng);
            h = layers::conv2d_par(&h, get("w1"), st, eng, local.as_mut(), par);
            h = post(h, &mut rng);
            h = gn(&h, get("g1"), get("b1")).relu();
            let mut local = rng_opt(&mut rng);
            h = layers::conv2d_par(&h, get("w2"), 1, eng, local.as_mut(), par);
            h = post(h, &mut rng);
            h = gn(&h, get("g2"), get("b2"));
            let idn = if p.tensors.contains_key(&format!("{pre}/wd")) {
                let mut local = rng_opt(&mut rng);
                let d = layers::conv2d_par(&idn, get("wd"), st, eng, local.as_mut(), par);
                post(d, &mut rng)
            } else {
                idn
            };
            h = h.add(&idn).relu();
        }
    }
    let pooled = layers::global_avg_pool(&h);
    let mut local = rng_opt(&mut rng);
    let fc_w = p.get("fc/w").unwrap();
    let fc_b = p.get("fc/b").unwrap();
    let logits =
        layers::linear_par(&pooled, fc_w, &vec![0.0; fc_b.len()], eng, local.as_mut(), par);
    let mut logits = post(logits, &mut rng);
    for n in 0..logits.shape[0] {
        for c in 0..logits.shape[1] {
            logits.data[n * logits.shape[1] + c] += fc_b.data[c];
        }
    }
    logits
}
