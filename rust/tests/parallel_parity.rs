//! Tiled-execution parity: `pim::parallel` must be a pure throughput knob.
//!
//! The contract (PERFORMANCE.md): for any thread count, every layer that
//! routes matmuls through the worker pool — the engine itself, the dense
//! baseline, the ResNet forward, the stub runtime — produces output
//! bit-identical to the serial path, noiseless and noisy alike. These
//! tests pin that contract at the integration level; the unit grids and
//! RNG-stream derivation they exercise are described in
//! `rust/src/pim/parallel.rs`.

use nvm_in_cache::nn::resnet::test_params;
use nvm_in_cache::nn::{ForwardMode, ResNet, Tensor};
use nvm_in_cache::pim::parallel::Parallelism;
use nvm_in_cache::pim::PimEngine;
use nvm_in_cache::runtime::{ModelVariant, Runtime, StubRuntime};
use nvm_in_cache::util::rng::Pcg64;

const THREADS: [usize; 3] = [1, 2, 7];

fn rand_mat(rng: &mut Pcg64, len: usize, lo: f64, hi: f64) -> Vec<f32> {
    (0..len).map(|_| rng.range(lo, hi) as f32).collect()
}

/// Acceptance: `par_matmul` output is bit-identical to the serial engine
/// for threads ∈ {1, 2, 7}, on noiseless and noisy configurations.
#[test]
fn par_matmul_bit_identical_noiseless_and_noisy() {
    let mut rng = Pcg64::seeded(100);
    // Ragged shape: k spans 3 row blocks (128 + 128 + 44), n spans 2
    // output tiles (128 + 29).
    let (m, k, n) = (6, 300, 157);
    let a = rand_mat(&mut rng, m * k, 0.0, 1.0);
    let w = rand_mat(&mut rng, k * n, -0.5, 0.5);
    for sigma in [None, Some(0.5)] {
        let eng = match sigma {
            None => PimEngine::tt(),
            Some(s) => PimEngine::tt().with_noise(s),
        };
        let mut serial_rng = sigma.map(|_| Pcg64::seeded(9));
        let serial = eng.pim_matmul(&a, m, k, &w, n, serial_rng.as_mut());
        for t in THREADS {
            let mut par_rng = sigma.map(|_| Pcg64::seeded(9));
            let par = eng.par_matmul(
                &a,
                m,
                k,
                &w,
                n,
                par_rng.as_mut(),
                Parallelism::threads(t),
            );
            let bits = |v: &[f32]| v.iter().map(|x| x.to_bits()).collect::<Vec<_>>();
            assert_eq!(bits(&serial), bits(&par), "sigma={sigma:?} threads={t}");
            // The caller-visible RNG must advance identically too, so a
            // serial and a parallel run stay interchangeable mid-stream.
            // (Probe on a clone: `serial_rng` itself must stay untouched
            // for the next thread count.)
            if let (Some(sr), Some(pr)) = (serial_rng.as_ref(), par_rng.as_mut()) {
                let mut probe = sr.clone();
                assert_eq!(probe.next_u64(), pr.next_u64(), "rng state diverged at t={t}");
            }
        }
    }
}

/// The dense fp32 baseline path is row-parallel and bit-exact as well.
#[test]
fn par_exact_matmul_bit_identical() {
    let mut rng = Pcg64::seeded(200);
    let (m, k, n) = (9, 77, 31);
    let a = rand_mat(&mut rng, m * k, -1.0, 1.0);
    let w = rand_mat(&mut rng, k * n, -1.0, 1.0);
    let serial = PimEngine::exact_matmul(&a, m, k, &w, n);
    for t in THREADS {
        let par = PimEngine::par_exact_matmul(&a, m, k, &w, n, Parallelism::threads(t));
        assert_eq!(serial, par, "threads={t}");
    }
}

/// End-to-end: the full ResNet forward (every mode, including the
/// hardware-true noisy pipeline) is bit-identical across thread counts.
#[test]
fn resnet_forward_bit_identical_across_threads() {
    let net = ResNet::new(test_params(8, 10, 42));
    let mut rng = Pcg64::seeded(300);
    let x = Tensor::from_vec(
        &[2, 16, 16, 3],
        (0..2 * 16 * 16 * 3).map(|_| rng.f64() as f32).collect(),
    );
    for mode in [
        ForwardMode::Baseline,
        ForwardMode::Pim,
        ForwardMode::PimNoise(0.4),
        ForwardMode::PimHw,
        ForwardMode::PimHwNoise(0.4),
    ] {
        let serial = net.forward(&x, mode, 7).unwrap();
        for t in THREADS {
            let par = net.forward_par(&x, mode, 7, Parallelism::threads(t)).unwrap();
            assert_eq!(serial.data, par.data, "{mode:?} threads={t}");
        }
    }
}

/// The stub runtime honors `set_parallelism` mid-flight without changing
/// a single logit (the serving stack's `RuntimeExecutor` re-applies it
/// before every batch).
#[test]
fn stub_runtime_set_parallelism_is_transparent() {
    let mut rt = StubRuntime::new(2);
    rt.load_variant_params(ModelVariant::PimHw, test_params(8, 10, 5)).unwrap();
    let mut rng = Pcg64::seeded(400);
    let images: Vec<f32> = (0..2 * 16 * 16 * 3).map(|_| rng.f64() as f32).collect();
    let baseline = rt.forward(ModelVariant::PimHw, &images, (16, 16, 3), None).unwrap();
    for t in THREADS {
        rt.set_parallelism(Parallelism::threads(t));
        let threaded = rt.forward(ModelVariant::PimHw, &images, (16, 16, 3), None).unwrap();
        assert_eq!(baseline, threaded, "threads={t}");
    }
}
