//! Fleet-layer integration tests: placement + campaign interleave
//! determinism and the acceptance criteria of the multi-tenant fleet
//! study (EXPERIMENTS.md E12).

use nvm_in_cache::fleet::{
    EndurancePlacer, FleetSim, FleetSimConfig, ModelRegistry,
};

fn config() -> FleetSimConfig {
    FleetSimConfig { requests_per_tenant: 200, ..FleetSimConfig::default() }
}

/// Acceptance: ≥3 tenants placed across ≥4 slices, ≥1 campaign interleaved
/// with live traffic, per-tenant p50/p99, throughput, wear within budget,
/// campaign downtime — all present and QoS-feasible.
#[test]
fn fleet_sim_end_to_end_acceptance() {
    let report = FleetSim::run(&config()).unwrap();
    assert!(report.tenants.len() >= 3, "≥3 tenants");
    assert!(report.slices_used >= 4, "≥4 slices: {}", report.slices_used);
    assert!(!report.campaigns.is_empty(), "≥1 programming campaign");
    assert!(report.downtime_s > 0.0, "campaign downtime reported");
    assert!(report.throughput_rps > 0.0);
    for t in &report.tenants {
        assert!(t.served > 0, "tenant {} served nothing", t.tenant);
        assert!(t.p50_s > 0.0 && t.p99_s >= t.p50_s, "tenant {} percentiles", t.tenant);
        assert!(t.p99_s <= t.deadline_s + 1e-9, "admitted traffic meets the deadline");
    }
    assert!(report.qos_ok, "QoS-feasible");
    assert!(report.wear_ok, "bank wear within the endurance budget");
    // Campaigns interleaved with traffic: reprogrammed banks carry more
    // wear than the single initial programming cycle.
    let max_wear = report.wear.iter().map(|w| w.max_cycles()).fold(0.0, f64::max);
    assert!(max_wear >= 2.0, "reprogramming recorded on top of initial: {max_wear}");
    // PR 8 acceptance: the over-capacity tenant is placed as a shard
    // chain, actually serves, and its per-hop transfer cost is visible.
    let wide = report
        .tenants
        .iter()
        .find(|t| t.name == "resnet18-w24")
        .expect("the default fleet includes the over-capacity tenant");
    assert!(wide.shards >= 2, "over-capacity tenant must run sharded");
    assert!(wide.served > 0, "the shard chain must serve traffic");
    assert!(wide.transfer_s > 0.0 && wide.transfer_energy_j > 0.0);
    // PR 9 acceptance: the default fleet is mixed CNN + transformer —
    // both standard transformer tenants serve with full per-tenant
    // attribution alongside the CNNs.
    for name in ["tfm-tiny-d64", "tfm-base-d128"] {
        let tfm = report
            .tenants
            .iter()
            .find(|t| t.name == name)
            .unwrap_or_else(|| panic!("default fleet includes {name}"));
        assert!(tfm.served > 0, "{name} must serve traffic");
        assert_eq!(tfm.shards, 1, "{name} fits one slice — replica-parallel");
        assert!(tfm.energy_j > 0.0 && tfm.ops > 0.0, "{name} attribution present");
    }
}

/// The whole run — placement, traffic, campaign interleave, wear — is
/// bit-deterministic for a fixed seed.
#[test]
fn fleet_sim_is_deterministic() {
    let a = FleetSim::run(&config()).unwrap();
    let b = FleetSim::run(&config()).unwrap();
    assert_eq!(a.tenants.len(), b.tenants.len());
    for (ta, tb) in a.tenants.iter().zip(&b.tenants) {
        assert_eq!(ta.served, tb.served, "tenant {}", ta.tenant);
        assert_eq!(ta.rejected, tb.rejected);
        assert_eq!(ta.violations, tb.violations);
        assert_eq!(ta.p50_s.to_bits(), tb.p50_s.to_bits(), "p50 must be bit-equal");
        assert_eq!(ta.p99_s.to_bits(), tb.p99_s.to_bits(), "p99 must be bit-equal");
        assert_eq!(ta.energy_j.to_bits(), tb.energy_j.to_bits());
    }
    assert_eq!(a.horizon_s.to_bits(), b.horizon_s.to_bits());
    assert_eq!(a.downtime_s.to_bits(), b.downtime_s.to_bits());
    assert_eq!(a.campaigns.len(), b.campaigns.len());
    for (ca, cb) in a.campaigns.iter().zip(&b.campaigns) {
        assert_eq!((ca.tenant, ca.replica, ca.slice), (cb.tenant, cb.replica, cb.slice));
        assert_eq!(ca.drain_s.to_bits(), cb.drain_s.to_bits());
        assert_eq!(ca.program_s.to_bits(), cb.program_s.to_bits());
    }
    for (wa, wb) in a.wear.iter().zip(&b.wear) {
        assert_eq!(wa.cycles, wb.cycles);
    }
    assert_eq!(a.to_json().to_string(), b.to_json().to_string());
}

/// Different seeds produce different traffic (the determinism above is not
/// an artifact of ignoring the seed).
#[test]
fn fleet_sim_seed_changes_traffic() {
    let a = FleetSim::run(&config()).unwrap();
    let b = FleetSim::run(&FleetSimConfig { seed: 43, ..config() }).unwrap();
    assert_ne!(
        a.horizon_s.to_bits(),
        b.horizon_s.to_bits(),
        "different seeds must give different arrival processes"
    );
}

/// Campaigns drain first: a campaign's drain time never exceeds the work
/// queued on the replica, and downtime = drain + program + rewarm.
#[test]
fn campaign_downtime_decomposes() {
    let report = FleetSim::run(&config()).unwrap();
    for c in &report.campaigns {
        assert!(c.drain_s >= 0.0);
        assert!(c.program_s > 0.0, "programming a placed network takes time");
        assert!(
            (c.downtime_s() - (c.drain_s + c.program_s + c.rewarm_s)).abs() < 1e-15
        );
    }
}

/// Placement + campaign interleave is reproducible at the placer level
/// too: same registry, same wear trajectory ⇒ same slices and offsets.
#[test]
fn placement_reproducible_across_runs() {
    let reg = ModelRegistry::synthetic(3);
    let placer = EndurancePlacer::new(
        nvm_in_cache::cache::addr::Geometry::default(),
        4,
    );
    let a = placer.place(&reg).unwrap();
    let b = placer.place(&reg).unwrap();
    let key = |p: &nvm_in_cache::fleet::FleetPlacement| -> Vec<(usize, usize, usize, usize)> {
        p.replicas
            .iter()
            .map(|r| (r.tenant, r.replica, r.slice, r.start_slot))
            .collect()
    };
    assert_eq!(key(&a), key(&b));
    assert_eq!(a.slots_used, b.slots_used);
}

/// The live serving pass (real coordinator::Server instances) moves every
/// request through the threaded stack.
#[test]
fn fleet_live_pass_serves_through_real_servers() {
    let cfg = FleetSimConfig {
        requests_per_tenant: 40,
        live_serving: true,
        ..FleetSimConfig::default()
    };
    let report = FleetSim::run(&cfg).unwrap();
    let live = report.live.expect("live summary present");
    // 3 synthetic tenants + the wide tenant + the 2 transformer tenants.
    assert_eq!(live.requests, 6 * 40);
    assert_eq!(live.responses, live.requests, "every live request answered");
    assert!(live.batches > 0 && live.batches <= live.requests);
}

/// Compile-once / execute-many across campaign rewarms: each (tenant,
/// replica) compiles its weight program exactly once, and the program is
/// reused across every rewarm segment (servers are torn down and rebuilt
/// between segments; compilations stay put).
#[test]
fn fleet_live_pass_compiles_once_per_tenant_replica() {
    // Mirror the default fleet: synthetic tenants + the wide tenant +
    // the two transformer tenants.
    let reg = ModelRegistry::synthetic_with_wide(3).with_transformers();
    let total_replicas: u64 = reg.tenants.iter().map(|t| t.replicas as u64).sum();
    let cfg = FleetSimConfig {
        requests_per_tenant: 40,
        live_serving: true,
        ..FleetSimConfig::default()
    };
    let report = FleetSim::run(&cfg).unwrap();
    let live = report.live.expect("live summary present");
    assert_eq!(
        live.compilations, total_replicas,
        "exactly one compile per (tenant, replica)"
    );
    assert_eq!(
        live.segments,
        total_replicas * FleetSim::LIVE_SEGMENTS as u64,
        "every replica served multiple rewarm segments"
    );
    assert!(
        live.compilations < live.segments,
        "programs must be reused across rewarm segments, not rebuilt per segment"
    );
    assert_eq!(live.responses, live.requests, "reuse must not drop requests");
}

/// Mixed-fleet registry round-trip: the standard CNN+transformer fleet
/// registers both families with stable ids/names, and every tenant's
/// layer stack survives the registry → placer path.
#[test]
fn mixed_registry_round_trips_both_families() {
    use nvm_in_cache::fleet::ModelFamily;
    let reg = ModelRegistry::synthetic_with_wide(3).with_transformers();
    assert_eq!(reg.len(), 6);
    let families: Vec<ModelFamily> = reg.tenants.iter().map(|t| t.family).collect();
    assert!(families.contains(&ModelFamily::Resnet18));
    assert!(families.contains(&ModelFamily::Cnn6));
    assert_eq!(
        families.iter().filter(|f| **f == ModelFamily::Transformer).count(),
        2,
        "both standard transformer tenants registered"
    );
    for t in &reg.tenants {
        assert!(!t.layers().is_empty(), "tenant {} has a layer stack", t.name);
        assert!(t.qos.deadline_s > 0.0);
    }
    // Transformer tenants carry the tighter 30 ms contract.
    let tfm = reg.tenants.iter().find(|t| t.name == "tfm-tiny-d64").unwrap();
    assert_eq!(tfm.qos.deadline_s, 0.03);
    assert_eq!(tfm.replicas, 2);
}

/// Placer budgets with both families on the board: transformer tenants
/// pack replica-parallel next to the CNNs, the wide CNN still shards,
/// no slice overflows, wear stays inside the endurance window.
#[test]
fn mixed_fleet_placer_budgets_hold_for_both_families() {
    use nvm_in_cache::fleet::EndurancePolicy;
    let geom = nvm_in_cache::cache::addr::Geometry::default();
    let reg = ModelRegistry::synthetic_with_wide(3).with_transformers();
    let placement = EndurancePlacer::new(geom, 8).place(&reg).unwrap();
    let capacity = geom.banks_per_slice * geom.subarrays_per_bank;
    for t in &reg.tenants {
        let shards = placement.tenant_shards(t.id);
        if t.name == "resnet18-w24" {
            assert!(shards >= 2, "wide CNN still shards in the mixed fleet");
        } else {
            assert_eq!(shards, 1, "{} must stay replica-parallel", t.name);
        }
    }
    for (s, &used) in placement.slots_used.iter().enumerate() {
        assert!(used <= capacity, "slice {s} overcommitted: {used}/{capacity}");
    }
    let policy = EndurancePolicy::default();
    for (s, w) in placement.wear.iter().enumerate() {
        assert!(w.within(&policy), "slice {s} outside the endurance window");
    }
}

/// Router QoS holds per family: in the default mixed fleet every
/// admitted request — CNN and transformer alike — meets its tenant's
/// deadline contract, and the transformer tenants' tighter deadline is
/// genuinely tighter than the CNNs' (the contract is not vacuous).
#[test]
fn mixed_fleet_router_qos_holds_per_family() {
    let report = FleetSim::run(&config()).unwrap();
    assert!(report.qos_ok);
    let tfm_deadline = report
        .tenants
        .iter()
        .find(|t| t.name.starts_with("tfm-"))
        .expect("transformer tenant present")
        .deadline_s;
    let cnn_deadline = report
        .tenants
        .iter()
        .find(|t| t.name == "resnet18-w16")
        .expect("cnn tenant present")
        .deadline_s;
    assert!(tfm_deadline < cnn_deadline, "transformer contract is tighter");
    for t in &report.tenants {
        assert!(
            t.p99_s <= t.deadline_s + 1e-9,
            "tenant {} p99 {} vs deadline {}",
            t.name,
            t.p99_s,
            t.deadline_s
        );
        assert_eq!(t.violations, 0, "tenant {} violated its contract", t.name);
    }
}

/// Mixed-fleet determinism: with the transformer tenants on the board
/// the whole run is still bit-deterministic, and dropping them via
/// `transformer_tenants: false` reproduces the CNN-only fleet (so the
/// flag is a clean ablation, not a different simulator).
#[test]
fn mixed_fleet_sim_is_deterministic_and_ablatable() {
    let a = FleetSim::run(&config()).unwrap();
    let b = FleetSim::run(&config()).unwrap();
    assert_eq!(a.to_json().to_string(), b.to_json().to_string());
    assert!(a.tenants.iter().any(|t| t.name.starts_with("tfm-")));
    let no_tfm = FleetSimConfig { transformer_tenants: false, ..config() };
    let c = FleetSim::run(&no_tfm).unwrap();
    assert!(c.tenants.iter().all(|t| !t.name.starts_with("tfm-")));
    assert_eq!(c.tenants.len() + 2, a.tenants.len());
}
