//! Compile-once / execute-many parity: a prepared weight program must be
//! a pure *cost* optimization.
//!
//! The contract (ARCHITECTURE.md §program, PERFORMANCE.md §amortization):
//! preparing weights once ([`PimEngine::prepare`], [`ResNet::compile`],
//! `StubRuntime::load_variant*`) and executing many times produces output
//! bit-identical to the historical one-shot path — noiseless and noisy, at
//! any thread count — and the steady-state loop performs **zero** weight
//! quantization/packing after compile (pinned via the thread-local
//! `pim::program::prepare_count` counter; each test runs on its own
//! thread, and all preparation happens on the calling thread, so the
//! counter cannot race across tests).

use nvm_in_cache::nn::resnet::test_params;
use nvm_in_cache::nn::{ForwardMode, ResNet, Tensor};
use nvm_in_cache::pim::parallel::Parallelism;
use nvm_in_cache::pim::program::{prepare_count, spec_matmul, ScratchPool};
use nvm_in_cache::pim::PimEngine;
use nvm_in_cache::runtime::{ModelVariant, Runtime, StubRuntime};
use nvm_in_cache::util::rng::Pcg64;

mod common;
use common::{bits, historical_forward, rand_mat, THREADS};

/// Acceptance: the prepared engine matmul is bit-identical to the
/// one-shot path for threads ∈ {1, 2, 7}, noiseless and noisy, advances
/// a caller RNG identically, and executes with zero prepare events.
#[test]
fn engine_prepared_bit_identical_noiseless_and_noisy() {
    let mut rng = Pcg64::seeded(500);
    // Ragged shape: k spans 3 row blocks (128 + 128 + 44), n spans 2
    // output tiles (128 + 29).
    let (m, k, n) = (5, 300, 157);
    let a = rand_mat(&mut rng, m * k, 0.0, 1.0);
    let w = rand_mat(&mut rng, k * n, -0.5, 0.5);
    for sigma in [None, Some(0.5)] {
        let eng = match sigma {
            None => PimEngine::tt(),
            Some(s) => PimEngine::tt().with_noise(s),
        };
        let program = eng.prepare(&w, k, n);
        let steady = prepare_count();
        for t in THREADS {
            let par = Parallelism::threads(t);
            let mut r1 = sigma.map(|_| Pcg64::seeded(11));
            let oneshot = eng.par_matmul(&a, m, k, &w, n, r1.as_mut(), par);
            let before = prepare_count();
            let mut r2 = sigma.map(|_| Pcg64::seeded(11));
            let prepared = eng.par_matmul_prepared(&a, m, &program, r2.as_mut(), par);
            assert_eq!(
                prepare_count(),
                before,
                "prepared execution must not prepare (sigma={sigma:?} t={t})"
            );
            assert_eq!(bits(&oneshot), bits(&prepared), "sigma={sigma:?} threads={t}");
            if let (Some(mut r1), Some(mut r2)) = (r1, r2) {
                assert_eq!(r1.next_u64(), r2.next_u64(), "rng diverged at t={t}");
            }
        }
        // The one-shot calls above prepared internally (2 banks each);
        // the prepared calls themselves contributed nothing beyond that.
        assert_eq!(
            prepare_count() - steady,
            2 * THREADS.len() as u64,
            "exactly the one-shot calls prepared"
        );
    }
}

/// The wrapper-vs-core assertions above share the prepared core on both
/// sides; this one does not: the engine (packed banks, tiled unit grid,
/// worker pool) must match the independent straight-line specification
/// (`pim::program::spec_matmul` — raw row-major banks, nested loops)
/// bit-for-bit. This is the witness that the tile-aligned layout and the
/// reduce order are actually right.
#[test]
fn engine_prepared_matches_independent_spec() {
    let mut rng = Pcg64::seeded(550);
    // Ragged in both dimensions plus single-tile and single-block cases.
    for &(m, k, n) in &[(5usize, 300usize, 157usize), (1, 128, 128), (3, 45, 31)] {
        let a = rand_mat(&mut rng, m * k, 0.0, 1.0);
        let w = rand_mat(&mut rng, k * n, -0.5, 0.5);
        let spec = spec_matmul(&a, m, k, &w, n);
        let eng = PimEngine::tt();
        let program = eng.prepare(&w, k, n);
        for t in THREADS {
            let got = eng.par_matmul_prepared(&a, m, &program, None, Parallelism::threads(t));
            assert_eq!(bits(&spec), bits(&got), "m={m} k={k} n={n} threads={t}");
        }
    }
}

/// The compiled forward vs the resurrected PR-4 forward body — the
/// network-level independent witness that the compile-once refactor
/// preserved the historical choreography bit-for-bit (RNG forks, post
/// placement, bias timing), in every mode, serial and threaded.
#[test]
fn compiled_forward_matches_historical_choreography() {
    let net = ResNet::new(test_params(8, 10, 42));
    let program = net.compile().unwrap();
    let mut rng = Pcg64::seeded(650);
    let x = Tensor::from_vec(
        &[2, 16, 16, 3],
        (0..2 * 16 * 16 * 3).map(|_| rng.f64() as f32).collect(),
    );
    let mut scratch = ScratchPool::new();
    for mode in [
        ForwardMode::Baseline,
        ForwardMode::Pim,
        ForwardMode::PimNoise(0.4),
        ForwardMode::PimHw,
        ForwardMode::PimHwNoise(0.4),
    ] {
        for t in [1usize, 3] {
            let par = Parallelism::threads(t);
            let want = historical_forward(&net, &x, mode, 7, par);
            let got = program.forward_par(&x, mode, 7, par, &mut scratch);
            assert_eq!(bits(&want.data), bits(&got.data), "{mode:?} threads={t}");
        }
    }
}

/// End-to-end: `ResNet::compile` → `CompiledNet::forward_par` matches the
/// uncompiled forward in every mode (including both noisy pipelines) at
/// every thread count, with the scratch pool reused throughout. (The
/// uncompiled forward is itself a compile-then-run wrapper now, so this
/// pins wrapper faithfulness; the independent historical witness is
/// `compiled_forward_matches_historical_choreography` above.)
#[test]
fn resnet_compiled_bit_identical_all_modes() {
    let net = ResNet::new(test_params(8, 10, 42));
    let program = net.compile().unwrap();
    assert!(program.fully_prepared());
    let mut rng = Pcg64::seeded(600);
    let x = Tensor::from_vec(
        &[2, 16, 16, 3],
        (0..2 * 16 * 16 * 3).map(|_| rng.f64() as f32).collect(),
    );
    let mut scratch = ScratchPool::new();
    for mode in [
        ForwardMode::Baseline,
        ForwardMode::Pim,
        ForwardMode::PimNoise(0.4),
        ForwardMode::PimHw,
        ForwardMode::PimHwNoise(0.4),
    ] {
        let oneshot = net.forward(&x, mode, 7).unwrap();
        let before = prepare_count();
        for t in THREADS {
            let compiled = program.forward_par(&x, mode, 7, Parallelism::threads(t), &mut scratch);
            assert_eq!(bits(&oneshot.data), bits(&compiled.data), "{mode:?} threads={t}");
        }
        assert_eq!(prepare_count(), before, "{mode:?}: compiled forwards must not prepare");
    }
}

/// The stub runtime's cached program path: logits match a fresh
/// uncompiled forward bit-for-bit, and the steady-state serving loop
/// (repeated forwards after `load_variant_params`) performs zero weight
/// preparation.
#[test]
fn stub_runtime_prepared_path_matches_and_is_prepare_free() {
    let batch = 2;
    let params = test_params(8, 10, 21);
    let net = ResNet::new(params.clone());
    let mut rt = StubRuntime::new(batch);
    rt.load_variant_params(ModelVariant::PimHw, params.clone()).unwrap();
    rt.load_variant_params(ModelVariant::Baseline, params).unwrap();
    let mut rng = Pcg64::seeded(700);
    let images: Vec<f32> = (0..batch * 16 * 16 * 3).map(|_| rng.f64() as f32).collect();
    let x = Tensor::from_vec(&[batch, 16, 16, 3], images.clone());

    // References via the one-shot path first (these may prepare — they
    // are outside the steady-state window measured below).
    let want_hw: Vec<Vec<u32>> = THREADS
        .iter()
        .map(|&t| {
            bits(&net.forward_par(&x, ForwardMode::PimHw, 0, Parallelism::threads(t)).unwrap().data)
        })
        .collect();
    let want_base = bits(&net.forward(&x, ForwardMode::Baseline, 0).unwrap().data);

    let steady = prepare_count();
    for (i, &t) in THREADS.iter().enumerate() {
        rt.set_parallelism(Parallelism::threads(t));
        let hw = rt.forward(ModelVariant::PimHw, &images, (16, 16, 3), None).unwrap();
        let base = rt.forward(ModelVariant::Baseline, &images, (16, 16, 3), None).unwrap();
        assert_eq!(bits(&hw), want_hw[i], "threads={t}");
        assert_eq!(bits(&base), want_base, "threads={t}");
    }
    assert_eq!(prepare_count(), steady, "serving loop must be prepare-free after load");
}

/// Hand-rolled proptest: prepared vs one-shot over ragged shapes — k not
/// a multiple of 128 (partial row blocks), odd n that may straddle the
/// 128-word tile edge, random thread counts, noise on or off. The
/// prepared program must never change a single bit.
#[test]
fn prop_prepared_parity_ragged_shapes() {
    use nvm_in_cache::consts::ARRAY_ROWS;
    for seed in 0..24 {
        let mut rng = Pcg64::seeded(20_000 + seed);
        let m = 1 + rng.below(5);
        let k = {
            let mut k = 1 + rng.below(320);
            if k % ARRAY_ROWS == 0 {
                k += 1;
            }
            k
        };
        let n = 1 + 2 * rng.below(80); // odd, up to 159
        let threads = 1 + rng.below(7);
        let noisy = rng.below(2) == 0;
        let a = rand_mat(&mut rng, m * k, 0.0, 2.0);
        let w = rand_mat(&mut rng, k * n, -1.0, 1.0);
        let eng = if noisy { PimEngine::tt().with_noise(0.5) } else { PimEngine::tt() };
        let par = Parallelism::threads(threads);
        let mut r1 = noisy.then(|| Pcg64::seeded(seed));
        let oneshot = eng.par_matmul(&a, m, k, &w, n, r1.as_mut(), par);
        let program = eng.prepare(&w, k, n);
        let mut r2 = noisy.then(|| Pcg64::seeded(seed));
        let prepared = eng.par_matmul_prepared(&a, m, &program, r2.as_mut(), par);
        assert_eq!(
            bits(&oneshot),
            bits(&prepared),
            "seed {seed}: m={m} k={k} n={n} threads={threads} noisy={noisy}"
        );
    }
}
