//! Compile-once / execute-many parity: a prepared weight program must be
//! a pure *cost* optimization.
//!
//! The contract (ARCHITECTURE.md §program, PERFORMANCE.md §amortization):
//! preparing weights once ([`PimEngine::prepare`], [`ResNet::compile`],
//! `StubRuntime::load_variant*`) and executing many times produces output
//! bit-identical to the historical one-shot path — noiseless and noisy, at
//! any thread count — and the steady-state loop performs **zero** weight
//! quantization/packing after compile (pinned via the thread-local
//! `pim::program::prepare_count` counter; each test runs on its own
//! thread, and all preparation happens on the calling thread, so the
//! counter cannot race across tests).

use nvm_in_cache::nn::resnet::test_params;
use nvm_in_cache::nn::{ForwardMode, ResNet, Tensor};
use nvm_in_cache::pim::parallel::Parallelism;
use nvm_in_cache::pim::program::{prepare_count, spec_matmul, ScratchPool};
use nvm_in_cache::pim::PimEngine;
use nvm_in_cache::runtime::{ModelVariant, Runtime, StubRuntime};
use nvm_in_cache::util::rng::Pcg64;

const THREADS: [usize; 3] = [1, 2, 7];

fn rand_mat(rng: &mut Pcg64, len: usize, lo: f64, hi: f64) -> Vec<f32> {
    (0..len).map(|_| rng.range(lo, hi) as f32).collect()
}

fn bits(v: &[f32]) -> Vec<u32> {
    v.iter().map(|x| x.to_bits()).collect()
}

/// Acceptance: the prepared engine matmul is bit-identical to the
/// one-shot path for threads ∈ {1, 2, 7}, noiseless and noisy, advances
/// a caller RNG identically, and executes with zero prepare events.
#[test]
fn engine_prepared_bit_identical_noiseless_and_noisy() {
    let mut rng = Pcg64::seeded(500);
    // Ragged shape: k spans 3 row blocks (128 + 128 + 44), n spans 2
    // output tiles (128 + 29).
    let (m, k, n) = (5, 300, 157);
    let a = rand_mat(&mut rng, m * k, 0.0, 1.0);
    let w = rand_mat(&mut rng, k * n, -0.5, 0.5);
    for sigma in [None, Some(0.5)] {
        let eng = match sigma {
            None => PimEngine::tt(),
            Some(s) => PimEngine::tt().with_noise(s),
        };
        let program = eng.prepare(&w, k, n);
        let steady = prepare_count();
        for t in THREADS {
            let par = Parallelism::threads(t);
            let mut r1 = sigma.map(|_| Pcg64::seeded(11));
            let oneshot = eng.par_matmul(&a, m, k, &w, n, r1.as_mut(), par);
            let before = prepare_count();
            let mut r2 = sigma.map(|_| Pcg64::seeded(11));
            let prepared = eng.par_matmul_prepared(&a, m, &program, r2.as_mut(), par);
            assert_eq!(
                prepare_count(),
                before,
                "prepared execution must not prepare (sigma={sigma:?} t={t})"
            );
            assert_eq!(bits(&oneshot), bits(&prepared), "sigma={sigma:?} threads={t}");
            if let (Some(mut r1), Some(mut r2)) = (r1, r2) {
                assert_eq!(r1.next_u64(), r2.next_u64(), "rng diverged at t={t}");
            }
        }
        // The one-shot calls above prepared internally (2 banks each);
        // the prepared calls themselves contributed nothing beyond that.
        assert_eq!(
            prepare_count() - steady,
            2 * THREADS.len() as u64,
            "exactly the one-shot calls prepared"
        );
    }
}

/// The wrapper-vs-core assertions above share the prepared core on both
/// sides; this one does not: the engine (packed banks, tiled unit grid,
/// worker pool) must match the independent straight-line specification
/// (`pim::program::spec_matmul` — raw row-major banks, nested loops)
/// bit-for-bit. This is the witness that the tile-aligned layout and the
/// reduce order are actually right.
#[test]
fn engine_prepared_matches_independent_spec() {
    let mut rng = Pcg64::seeded(550);
    // Ragged in both dimensions plus single-tile and single-block cases.
    for &(m, k, n) in &[(5usize, 300usize, 157usize), (1, 128, 128), (3, 45, 31)] {
        let a = rand_mat(&mut rng, m * k, 0.0, 1.0);
        let w = rand_mat(&mut rng, k * n, -0.5, 0.5);
        let spec = spec_matmul(&a, m, k, &w, n);
        let eng = PimEngine::tt();
        let program = eng.prepare(&w, k, n);
        for t in THREADS {
            let got = eng.par_matmul_prepared(&a, m, &program, None, Parallelism::threads(t));
            assert_eq!(bits(&spec), bits(&got), "m={m} k={k} n={n} threads={t}");
        }
    }
}

/// The pre-refactor (PR 4) `ResNet::forward_par` body, resurrected
/// verbatim as the **historical reference** — built from the public
/// one-shot layer APIs only, no `CompiledNet`. This independently
/// restates the network choreography the compiled forward must
/// reproduce: per-layer RNG forks (`rng_opt`), §V-E `post` placement,
/// the downsample-only fork, and the fc bias deferred past `post`.
/// (Engine-level fidelity of the one-shot layers it calls is pinned
/// separately by `spec_matmul` above.)
fn historical_forward(
    net: &ResNet,
    x: &Tensor,
    mode: ForwardMode,
    seed: u64,
    par: Parallelism,
) -> Tensor {
    use nvm_in_cache::nn::layers;
    use nvm_in_cache::nn::resnet::STAGES;
    use nvm_in_cache::pim::TransferModel;

    let engine = match mode {
        ForwardMode::PimHw => Some(PimEngine::tt().with_parallelism(par)),
        ForwardMode::PimHwNoise(sigma) => {
            Some(PimEngine::tt().with_noise(sigma).with_parallelism(par))
        }
        _ => None,
    };
    let emu_sigma: Option<Option<f64>> = match mode {
        ForwardMode::Pim => Some(None),
        ForwardMode::PimNoise(s) => Some(Some(s)),
        _ => None,
    };
    let transfer = TransferModel::tt();
    let mut rng = Pcg64::seeded(seed);
    let hw_noise = matches!(mode, ForwardMode::PimHwNoise(_));
    let rng_opt = |r: &mut Pcg64| -> Option<Pcg64> {
        if hw_noise {
            Some(r.fork(1))
        } else {
            None
        }
    };
    let p = &net.params;
    let eng = engine.as_ref();

    let gn = |t: &Tensor, g: &Tensor, b: &Tensor| -> Tensor {
        layers::group_norm(t, &g.data, &b.data, 1e-5)
    };
    let post = |t: Tensor, r: &mut Pcg64| -> Tensor {
        match emu_sigma {
            None => t,
            Some(sigma) => {
                let mut local = r.fork(2);
                layers::adc_emulate(&t, &transfer, sigma, Some(&mut local))
            }
        }
    };

    let mut local = rng_opt(&mut rng);
    let mut h = layers::conv2d_par(x, p.get("stem/w").unwrap(), 1, eng, local.as_mut(), par);
    h = post(h, &mut rng);
    h = gn(&h, p.get("stem/gamma").unwrap(), p.get("stem/beta").unwrap()).relu();

    for (s, &nblocks) in STAGES.iter().enumerate() {
        let stride = if s == 0 { 1 } else { 2 };
        for b in 0..nblocks {
            let st = if b == 0 { stride } else { 1 };
            let pre = format!("s{s}b{b}");
            let get = |name: &str| p.get(&format!("{pre}/{name}")).unwrap();
            let idn = h.clone();
            let mut local = rng_opt(&mut rng);
            h = layers::conv2d_par(&h, get("w1"), st, eng, local.as_mut(), par);
            h = post(h, &mut rng);
            h = gn(&h, get("g1"), get("b1")).relu();
            let mut local = rng_opt(&mut rng);
            h = layers::conv2d_par(&h, get("w2"), 1, eng, local.as_mut(), par);
            h = post(h, &mut rng);
            h = gn(&h, get("g2"), get("b2"));
            let idn = if p.tensors.contains_key(&format!("{pre}/wd")) {
                let mut local = rng_opt(&mut rng);
                let d = layers::conv2d_par(&idn, get("wd"), st, eng, local.as_mut(), par);
                post(d, &mut rng)
            } else {
                idn
            };
            h = h.add(&idn).relu();
        }
    }
    let pooled = layers::global_avg_pool(&h);
    let mut local = rng_opt(&mut rng);
    let fc_w = p.get("fc/w").unwrap();
    let fc_b = p.get("fc/b").unwrap();
    let logits =
        layers::linear_par(&pooled, fc_w, &vec![0.0; fc_b.len()], eng, local.as_mut(), par);
    let mut logits = post(logits, &mut rng);
    for n in 0..logits.shape[0] {
        for c in 0..logits.shape[1] {
            logits.data[n * logits.shape[1] + c] += fc_b.data[c];
        }
    }
    logits
}

/// The compiled forward vs the resurrected PR-4 forward body — the
/// network-level independent witness that the compile-once refactor
/// preserved the historical choreography bit-for-bit (RNG forks, post
/// placement, bias timing), in every mode, serial and threaded.
#[test]
fn compiled_forward_matches_historical_choreography() {
    let net = ResNet::new(test_params(8, 10, 42));
    let program = net.compile().unwrap();
    let mut rng = Pcg64::seeded(650);
    let x = Tensor::from_vec(
        &[2, 16, 16, 3],
        (0..2 * 16 * 16 * 3).map(|_| rng.f64() as f32).collect(),
    );
    let mut scratch = ScratchPool::new();
    for mode in [
        ForwardMode::Baseline,
        ForwardMode::Pim,
        ForwardMode::PimNoise(0.4),
        ForwardMode::PimHw,
        ForwardMode::PimHwNoise(0.4),
    ] {
        for t in [1usize, 3] {
            let par = Parallelism::threads(t);
            let want = historical_forward(&net, &x, mode, 7, par);
            let got = program.forward_par(&x, mode, 7, par, &mut scratch);
            assert_eq!(bits(&want.data), bits(&got.data), "{mode:?} threads={t}");
        }
    }
}

/// End-to-end: `ResNet::compile` → `CompiledNet::forward_par` matches the
/// uncompiled forward in every mode (including both noisy pipelines) at
/// every thread count, with the scratch pool reused throughout. (The
/// uncompiled forward is itself a compile-then-run wrapper now, so this
/// pins wrapper faithfulness; the independent historical witness is
/// `compiled_forward_matches_historical_choreography` above.)
#[test]
fn resnet_compiled_bit_identical_all_modes() {
    let net = ResNet::new(test_params(8, 10, 42));
    let program = net.compile().unwrap();
    assert!(program.fully_prepared());
    let mut rng = Pcg64::seeded(600);
    let x = Tensor::from_vec(
        &[2, 16, 16, 3],
        (0..2 * 16 * 16 * 3).map(|_| rng.f64() as f32).collect(),
    );
    let mut scratch = ScratchPool::new();
    for mode in [
        ForwardMode::Baseline,
        ForwardMode::Pim,
        ForwardMode::PimNoise(0.4),
        ForwardMode::PimHw,
        ForwardMode::PimHwNoise(0.4),
    ] {
        let oneshot = net.forward(&x, mode, 7).unwrap();
        let before = prepare_count();
        for t in THREADS {
            let compiled = program.forward_par(&x, mode, 7, Parallelism::threads(t), &mut scratch);
            assert_eq!(bits(&oneshot.data), bits(&compiled.data), "{mode:?} threads={t}");
        }
        assert_eq!(prepare_count(), before, "{mode:?}: compiled forwards must not prepare");
    }
}

/// The stub runtime's cached program path: logits match a fresh
/// uncompiled forward bit-for-bit, and the steady-state serving loop
/// (repeated forwards after `load_variant_params`) performs zero weight
/// preparation.
#[test]
fn stub_runtime_prepared_path_matches_and_is_prepare_free() {
    let batch = 2;
    let params = test_params(8, 10, 21);
    let net = ResNet::new(params.clone());
    let mut rt = StubRuntime::new(batch);
    rt.load_variant_params(ModelVariant::PimHw, params.clone()).unwrap();
    rt.load_variant_params(ModelVariant::Baseline, params).unwrap();
    let mut rng = Pcg64::seeded(700);
    let images: Vec<f32> = (0..batch * 16 * 16 * 3).map(|_| rng.f64() as f32).collect();
    let x = Tensor::from_vec(&[batch, 16, 16, 3], images.clone());

    // References via the one-shot path first (these may prepare — they
    // are outside the steady-state window measured below).
    let want_hw: Vec<Vec<u32>> = THREADS
        .iter()
        .map(|&t| {
            bits(&net.forward_par(&x, ForwardMode::PimHw, 0, Parallelism::threads(t)).unwrap().data)
        })
        .collect();
    let want_base = bits(&net.forward(&x, ForwardMode::Baseline, 0).unwrap().data);

    let steady = prepare_count();
    for (i, &t) in THREADS.iter().enumerate() {
        rt.set_parallelism(Parallelism::threads(t));
        let hw = rt.forward(ModelVariant::PimHw, &images, (16, 16, 3), None).unwrap();
        let base = rt.forward(ModelVariant::Baseline, &images, (16, 16, 3), None).unwrap();
        assert_eq!(bits(&hw), want_hw[i], "threads={t}");
        assert_eq!(bits(&base), want_base, "threads={t}");
    }
    assert_eq!(prepare_count(), steady, "serving loop must be prepare-free after load");
}

/// Hand-rolled proptest: prepared vs one-shot over ragged shapes — k not
/// a multiple of 128 (partial row blocks), odd n that may straddle the
/// 128-word tile edge, random thread counts, noise on or off. The
/// prepared program must never change a single bit.
#[test]
fn prop_prepared_parity_ragged_shapes() {
    use nvm_in_cache::consts::ARRAY_ROWS;
    for seed in 0..24 {
        let mut rng = Pcg64::seeded(20_000 + seed);
        let m = 1 + rng.below(5);
        let k = {
            let mut k = 1 + rng.below(320);
            if k % ARRAY_ROWS == 0 {
                k += 1;
            }
            k
        };
        let n = 1 + 2 * rng.below(80); // odd, up to 159
        let threads = 1 + rng.below(7);
        let noisy = rng.below(2) == 0;
        let a = rand_mat(&mut rng, m * k, 0.0, 2.0);
        let w = rand_mat(&mut rng, k * n, -1.0, 1.0);
        let eng = if noisy { PimEngine::tt().with_noise(0.5) } else { PimEngine::tt() };
        let par = Parallelism::threads(threads);
        let mut r1 = noisy.then(|| Pcg64::seeded(seed));
        let oneshot = eng.par_matmul(&a, m, k, &w, n, r1.as_mut(), par);
        let program = eng.prepare(&w, k, n);
        let mut r2 = noisy.then(|| Pcg64::seeded(seed));
        let prepared = eng.par_matmul_prepared(&a, m, &program, r2.as_mut(), par);
        assert_eq!(
            bits(&oneshot),
            bits(&prepared),
            "seed {seed}: m={m} k={k} n={n} threads={threads} noisy={noisy}"
        );
    }
}
