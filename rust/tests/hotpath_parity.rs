//! Differential harness for the hot-path runtime overhaul
//! (PERFORMANCE.md §12, EXPERIMENTS.md E18): the persistent worker pool,
//! zero-word skipping in the bit-plane MAC, and the allocation-free
//! steady state are all pure *cost* optimizations — every output must be
//! **bit-identical** to the historical behavior (spawn-per-call
//! threading, no skipping, per-call buffers), including the caller's
//! trailing RNG state, noiseless and noisy, at threads {1, 2, 7}.
//!
//! `scripts/verify.sh` additionally runs this suite with `--release`,
//! where pool memory-ordering and u64 lane bugs actually surface.

use nvm_in_cache::nn::resnet::test_params;
use nvm_in_cache::nn::{ForwardMode, ResNet};
use nvm_in_cache::pim::parallel::{self, Parallelism};
use nvm_in_cache::pim::program::{mac_alloc_count, spec_matmul, ScratchPool};
use nvm_in_cache::pim::{MacKernel, PimEngine};
use nvm_in_cache::util::rng::Pcg64;

mod common;
use common::{bits, rand_image, rand_mat, THREADS};

/// One engine, one prepared program, many sequential calls: every pooled
/// width must reproduce the serial result (values + trailing RNG state)
/// on the 1st call and the 3rd — the pool's parked workers are
/// stateless between jobs.
#[test]
fn pool_reuse_parity_sequential() {
    let mut rng = Pcg64::seeded(500);
    let (m, k, n) = (5usize, 200usize, 133usize);
    let a = rand_mat(&mut rng, m * k, 0.0, 1.0);
    let w = rand_mat(&mut rng, k * n, -0.5, 0.5);
    for sigma in [None, Some(0.4)] {
        let eng = match sigma {
            None => PimEngine::tt(),
            Some(s) => PimEngine::tt().with_noise(s),
        };
        let pw = eng.prepare(&w, k, n);
        let mut srng = sigma.map(|_| Pcg64::seeded(5));
        let want = eng.par_matmul_prepared(&a, m, &pw, srng.as_mut(), Parallelism::serial());
        let want_tail = srng.as_mut().map(|r| r.next_u64());
        for t in THREADS {
            for round in 0..3 {
                let mut r = sigma.map(|_| Pcg64::seeded(5));
                let got =
                    eng.par_matmul_prepared(&a, m, &pw, r.as_mut(), Parallelism::threads(t));
                assert_eq!(
                    bits(&want),
                    bits(&got),
                    "sigma={sigma:?} threads={t} round={round}"
                );
                assert_eq!(
                    want_tail,
                    r.as_mut().map(|x| x.next_u64()),
                    "rng diverged: sigma={sigma:?} threads={t} round={round}"
                );
            }
        }
    }
}

/// The pooled `run_units` is a drop-in for the historical
/// spawn-per-call `run_units_unpooled`, including the n_units ≤ 1 inline
/// path and remainder distribution.
#[test]
fn pooled_run_units_matches_unpooled() {
    let f = |u: usize| (u as u64).wrapping_mul(0x9E37_79B9).rotate_left(7);
    for (t, units) in [(3usize, 0usize), (3, 1), (3, 5), (4, 37), (2, 100)] {
        assert_eq!(
            parallel::run_units(t, units, f),
            parallel::run_units_unpooled(t, units, f),
            "threads={t} units={units}"
        );
    }
}

/// Concurrent callers (three OS threads, each sweeping pool widths
/// {2, 7} against the same compiled network) all see logits
/// bit-identical to the serial baseline — jobs from different callers
/// interleave on the same parked workers without cross-talk.
#[test]
fn pool_reuse_parity_interleaved_callers() {
    let net = ResNet::new(test_params(8, 10, 13));
    let prog = net.compile().unwrap();
    let mut rng = Pcg64::seeded(510);
    let x = rand_image(&mut rng, 2);
    let mode = ForwardMode::PimHwNoise(0.3);
    let want = prog.forward_par(&x, mode, 4, Parallelism::serial(), &mut ScratchPool::new());
    std::thread::scope(|s| {
        for caller in 0..3 {
            let (prog, x, want) = (&prog, &x, &want);
            s.spawn(move || {
                let mut scratch = ScratchPool::new();
                for t in [2usize, 7] {
                    let got =
                        prog.forward_par(x, mode, 4, Parallelism::threads(t), &mut scratch);
                    assert_eq!(
                        bits(&want.data),
                        bits(&got.data),
                        "caller={caller} threads={t}"
                    );
                }
            });
        }
    });
}

/// Zero-word skipping sweep: activation sparsity p ∈ {0, 0.5, 0.9, 1.0}
/// with the zero set aligned to 64-element spans (so whole packed act
/// words vanish) and *nested* across p (same span draws, growing
/// threshold). At every p the bit-plane kernel must match the scalar
/// kernel and the straight-line spec bit-for-bit, noiseless and noisy
/// (trailing RNG state included); `SkipStats` must be exactly zero at
/// p = 0, monotone nondecreasing in p, and total at p = 1.
#[test]
fn zero_skip_parity_and_stats_monotone() {
    let mut rng = Pcg64::seeded(530);
    // k = 256 is a multiple of 64, so flat 64-spans coincide with packed
    // activation words in every row.
    let (m, k, n) = (4usize, 256usize, 130usize);
    let base = rand_mat(&mut rng, m * k, 0.05, 1.0); // min 0.05 → quantizes to ≥ 1
    let w = rand_mat(&mut rng, k * n, -0.5, 0.5);
    let mut span_rng = Pcg64::seeded(71);
    let spans: Vec<f64> = (0..m * k / 64).map(|_| span_rng.f64()).collect();

    let eng = PimEngine::tt();
    let eng_scalar = PimEngine::tt().with_kernel(MacKernel::Scalar);
    let noisy = PimEngine::tt().with_noise(0.4);
    let noisy_scalar = noisy.clone().with_kernel(MacKernel::Scalar);
    let pw = eng.prepare(&w, k, n);

    let mut last_skipped = 0u64;
    let mut last_fraction = 0.0f64;
    for (pi, p) in [0.0f64, 0.5, 0.9, 1.0].into_iter().enumerate() {
        let a: Vec<f32> = base
            .iter()
            .enumerate()
            .map(|(i, &v)| if spans[i / 64] < p { 0.0 } else { v })
            .collect();

        eng.skip_stats().reset();
        let got = eng.matmul_prepared(&a, m, &pw, None);
        let visited = eng.skip_stats().words_visited();
        let skipped = eng.skip_stats().act_words_skipped();
        let fraction = eng.skip_stats().act_skip_fraction();
        assert_eq!(bits(&got), bits(&eng_scalar.matmul_prepared(&a, m, &pw, None)), "p={p}");
        assert_eq!(bits(&got), bits(&spec_matmul(&a, m, k, &w, n)), "p={p}");

        let (mut r1, mut r2) = (Pcg64::seeded(80 + pi as u64), Pcg64::seeded(80 + pi as u64));
        let noisy_bp = noisy.matmul_prepared(&a, m, &pw, Some(&mut r1));
        let noisy_sc = noisy_scalar.matmul_prepared(&a, m, &pw, Some(&mut r2));
        assert_eq!(bits(&noisy_bp), bits(&noisy_sc), "noisy p={p}");
        assert_eq!(r1.next_u64(), r2.next_u64(), "noisy rng diverged at p={p}");

        assert!(visited > 0, "p={p}");
        assert!(skipped >= last_skipped, "skips not monotone at p={p}");
        assert!(fraction >= last_fraction, "fraction not monotone at p={p}");
        match p {
            0.0 => assert_eq!(skipped, 0, "dense input must skip nothing"),
            1.0 => {
                assert_eq!(skipped, visited, "all-zero input must skip every word");
                assert!(got.iter().all(|&v| v == 0.0), "all-zero input → zero output");
            }
            _ => assert!(skipped > 0, "p={p} should zero whole spans"),
        }
        last_skipped = skipped;
        last_fraction = fraction;
    }
}

/// An all-positive weight matrix leaves the negative bank entirely
/// zero, so its precomputed plane flags mark every (tile, plane,
/// k-word) row skippable — the weight-plane half of the skip must fire
/// while the output still matches the straight-line spec.
#[test]
fn weight_plane_skip_fires_on_onesided_banks() {
    let mut rng = Pcg64::seeded(540);
    let (m, k, n) = (3usize, 130usize, 40usize);
    let a = rand_mat(&mut rng, m * k, 0.05, 1.0);
    let w = rand_mat(&mut rng, k * n, 0.05, 0.5);
    let eng = PimEngine::tt();
    let pw = eng.prepare(&w, k, n);
    eng.skip_stats().reset();
    let got = eng.matmul_prepared(&a, m, &pw, None);
    assert!(eng.skip_stats().weight_planes_skipped() > 0, "empty neg bank must be skipped");
    assert_eq!(eng.skip_stats().act_words_skipped(), 0, "dense acts skip nothing");
    assert_eq!(bits(&got), bits(&spec_matmul(&a, m, k, &w, n)));
}

/// After one warm-up forward per (mode, width), steady-state
/// `CompiledNet` execution performs zero MAC-path heap allocations —
/// the quantize/pack/pos/neg buffers all reuse `ScratchPool` capacity
/// (`mac_alloc_count`, same pattern as the `prepare_count` gate).
#[test]
fn steady_state_zero_mac_allocs() {
    let net = ResNet::new(test_params(8, 10, 17));
    let prog = net.compile().unwrap();
    let mut rng = Pcg64::seeded(520);
    let x = rand_image(&mut rng, 1);
    for mode in [ForwardMode::PimHw, ForwardMode::PimHwNoise(0.4)] {
        for t in [1usize, 2] {
            let par = Parallelism::threads(t);
            let mut scratch = ScratchPool::new();
            let _ = prog.forward_par(&x, mode, 0, par, &mut scratch);
            let before = mac_alloc_count();
            for seed in 1..4 {
                let _ = prog.forward_par(&x, mode, seed, par, &mut scratch);
            }
            assert_eq!(mac_alloc_count(), before, "{mode:?} threads={t}");
        }
    }
}

/// Each pool width spawns its workers exactly once per process. Width 11
/// is unique to this test (nothing else in the binary requests it), so
/// the per-width spawn counter must go 0 → 11 on first use and stay
/// there across reuse.
#[test]
fn pool_spawns_once_per_width() {
    assert_eq!(parallel::pool_spawned_for(11), 0, "width 11 must be untouched before this test");
    let first: Vec<u64> = parallel::run_units(11, 23, |u| (u as u64).wrapping_mul(7));
    assert_eq!(parallel::pool_spawned_for(11), 11);
    for _ in 0..5 {
        assert_eq!(first, parallel::run_units(11, 23, |u| (u as u64).wrapping_mul(7)));
    }
    assert_eq!(parallel::pool_spawned_for(11), 11, "reuse must not respawn");
}
