//! Property-based tests (hand-rolled randomized-invariant harness; the
//! proptest crate is unavailable offline — see DESIGN.md §2).
//!
//! Each property runs against many seeded random cases; failures print the
//! seed for reproduction.

use nvm_in_cache::array::SarAdc;
use nvm_in_cache::cache::lru::LruSet;
use nvm_in_cache::cache::tag::TagSet;
use nvm_in_cache::cell::timing::EnergyLedger;
use nvm_in_cache::cell::{BitCell, PimParams};
use nvm_in_cache::consts::ARRAY_ROWS;
use nvm_in_cache::coordinator::batcher::{Batcher, BatcherConfig};
use nvm_in_cache::coordinator::request::InferenceRequest;
use nvm_in_cache::coordinator::Router;
use nvm_in_cache::device::{Corner, Rram, RramState};
use nvm_in_cache::pim::quant::{quantize_acts, quantize_weights, QuantizedActs};
use nvm_in_cache::pim::transfer::TransferModel;
use nvm_in_cache::pim::PimEngine;
use nvm_in_cache::util::rng::Pcg64;

const CASES: u64 = 60;

/// Property: activation quantization error is bounded by scale/2 and the
/// reconstruction never exceeds the original max.
#[test]
fn prop_act_quantization_error_bounded() {
    for seed in 0..CASES {
        let mut rng = Pcg64::seeded(seed);
        let m = 1 + rng.below(8);
        let k = 1 + rng.below(300);
        let a: Vec<f32> = (0..m * k).map(|_| rng.range(0.0, 4.0) as f32).collect();
        let q = quantize_acts(&a, m, k);
        for (orig, lvl) in a.iter().zip(q.data.iter()) {
            let recon = *lvl as f32 * q.scale;
            assert!(
                (orig - recon).abs() <= q.scale * 0.5 + 1e-5,
                "seed {seed}: {orig} vs {recon} (scale {})",
                q.scale
            );
        }
    }
}

/// Property: pos/neg weight banks are disjoint and reconstruct the
/// quantized weight exactly, per column scale.
#[test]
fn prop_weight_banks_reconstruct() {
    for seed in 0..CASES {
        let mut rng = Pcg64::seeded(1000 + seed);
        let k = 1 + rng.below(200);
        let n = 1 + rng.below(32);
        let w: Vec<f32> = (0..k * n).map(|_| rng.range(-2.0, 2.0) as f32).collect();
        let q = quantize_weights(&w, k, n);
        for i in 0..k {
            for j in 0..n {
                let idx = i * n + j;
                assert!(q.pos[idx] == 0 || q.neg[idx] == 0, "seed {seed}");
                let recon = q.signed_at(i, j) as f32 * q.scale[j];
                assert!(
                    (w[idx] - recon).abs() <= q.scale[j] * 0.5 + 1e-5,
                    "seed {seed} ({i},{j}): {} vs {recon}",
                    w[idx]
                );
            }
        }
    }
}

/// Property: activation bit-planes round-trip — the four `bit_plane`
/// byte vectors reassemble every quantized level exactly, and the
/// word-wide transposed packing (`pack_planes`, the SIMD MAC kernel's
/// activation operand) carries exactly the same bits, over random shapes
/// whose k crosses the 64-bit plane-word boundary. Seeds are pinned so a
/// CI failure reproduces deterministically.
#[test]
fn prop_bit_plane_roundtrip_and_packed_transpose() {
    for seed in 0..CASES {
        let mut rng = Pcg64::seeded(14_000 + seed);
        let m = 1 + rng.below(6);
        let k = 1 + rng.below(200); // crosses the 64-bit word boundary
        let a: Vec<f32> = (0..m * k).map(|_| rng.range(0.0, 4.0) as f32).collect();
        let q = quantize_acts(&a, m, k);
        // Round-trip: the four planes reassemble every level.
        let planes: Vec<Vec<u8>> = (0..4u32).map(|b| q.bit_plane(b)).collect();
        for (idx, &lvl) in q.data.iter().enumerate() {
            let recon = (0..4).fold(0u8, |acc, b| acc | (planes[b][idx] << b));
            assert_eq!(recon, lvl, "seed {seed} idx {idx}");
        }
        // Transpose: every pack_planes bit equals its bit_plane byte.
        let packed = q.pack_planes();
        assert_eq!(packed.k_words(), k.div_ceil(64), "seed {seed}");
        for i in 0..m {
            for (b, plane) in planes.iter().enumerate() {
                for kk in 0..k {
                    let bit = (packed.word(i, b, kk / 64) >> (kk % 64)) & 1;
                    assert_eq!(
                        bit as u8, plane[i * k + kk],
                        "seed {seed} i={i} b={b} kk={kk}"
                    );
                }
                // Padding bits beyond k stay zero (they must AND away).
                for kk in k..packed.k_words() * 64 {
                    let bit = (packed.word(i, b, kk / 64) >> (kk % 64)) & 1;
                    assert_eq!(bit, 0, "seed {seed} i={i} b={b} pad kk={kk}");
                }
            }
        }
    }
}

/// Property: the engine's blockwise MAC is additive over K blocks — the
/// hardware decomposition invariant (each 128-row block quantized
/// independently, partial sums added digitally).
#[test]
fn prop_engine_block_additivity() {
    for seed in 0..20 {
        let mut rng = Pcg64::seeded(2000 + seed);
        let k1 = ARRAY_ROWS;
        let k2 = 1 + rng.below(ARRAY_ROWS);
        let n = 1 + rng.below(12);
        let eng = PimEngine::tt();
        let a1: Vec<u8> = (0..k1).map(|_| rng.below(16) as u8).collect();
        let a2: Vec<u8> = (0..k2).map(|_| rng.below(16) as u8).collect();
        let b1: Vec<u8> = (0..k1 * n).map(|_| rng.below(16) as u8).collect();
        let b2: Vec<u8> = (0..k2 * n).map(|_| rng.below(16) as u8).collect();
        // Whole problem.
        let mut a = a1.clone();
        a.extend_from_slice(&a2);
        let mut bank = b1.clone();
        bank.extend_from_slice(&b2);
        let whole = eng.bank_mac(
            &QuantizedActs { data: a, m: 1, k: k1 + k2, scale: 1.0 },
            &bank,
            n,
            None,
        );
        // Parts.
        let p1 = eng.bank_mac(&QuantizedActs { data: a1, m: 1, k: k1, scale: 1.0 }, &b1, n, None);
        let p2 = eng.bank_mac(&QuantizedActs { data: a2, m: 1, k: k2, scale: 1.0 }, &b2, n, None);
        for j in 0..n {
            let sum = p1[j] + p2[j];
            // f32 accumulation-order tolerance.
            let tol = 1e-3 + 1e-6 * sum.abs();
            assert!(
                (whole[j] - sum).abs() < tol,
                "seed {seed} col {j}: {} vs {sum}",
                whole[j]
            );
        }
    }
}

/// Property: tiled parallel execution is bit-identical to the serial
/// engine over ragged tile boundaries — random shapes where k is NOT a
/// multiple of 128 (partial row blocks), n is odd and may straddle the
/// 128-word output-tile edge, with noise on or off and a random thread
/// count. The worker pool must never change a single bit.
#[test]
fn prop_par_matmul_parity_ragged_tiles() {
    use nvm_in_cache::pim::parallel::Parallelism;
    for seed in 0..24 {
        let mut rng = Pcg64::seeded(13_000 + seed);
        let m = 1 + rng.below(5);
        // k in [1, 320] skipping multiples of 128 ⇒ always a ragged block.
        let k = {
            let mut k = 1 + rng.below(320);
            if k % ARRAY_ROWS == 0 {
                k += 1;
            }
            k
        };
        let n = 1 + 2 * rng.below(80); // odd, up to 159 ⇒ can straddle 128
        let threads = 2 + rng.below(6);
        let noisy = rng.below(2) == 0;
        let a: Vec<f32> = (0..m * k).map(|_| rng.range(0.0, 2.0) as f32).collect();
        let w: Vec<f32> = (0..k * n).map(|_| rng.range(-1.0, 1.0) as f32).collect();
        let eng = if noisy { PimEngine::tt().with_noise(0.5) } else { PimEngine::tt() };
        let mut serial_rng = noisy.then(|| Pcg64::seeded(seed));
        let serial = eng.pim_matmul(&a, m, k, &w, n, serial_rng.as_mut());
        let mut par_rng = noisy.then(|| Pcg64::seeded(seed));
        let par = eng.par_matmul(
            &a,
            m,
            k,
            &w,
            n,
            par_rng.as_mut(),
            Parallelism::threads(threads),
        );
        assert_eq!(
            serial, par,
            "seed {seed}: m={m} k={k} n={n} threads={threads} noisy={noisy}"
        );
    }
}

/// Property: the SAR ADC equals ideal round-to-nearest for arbitrary
/// random reference pairs (binary search correctness).
#[test]
fn prop_sar_equals_rounding_any_refs() {
    for seed in 0..CASES {
        let mut rng = Pcg64::seeded(3000 + seed);
        let lo = rng.range(0.0, 0.4);
        let hi = lo + rng.range(0.1, 0.6);
        let adc = SarAdc { v_refp: hi, v_refn: lo, cmp_offset: 0.0, cmp_noise: 0.0 };
        for _ in 0..50 {
            let v = rng.range(lo - 0.1, hi + 0.1);
            let x = ((v - lo) / (hi - lo) * 63.0).round().clamp(0.0, 63.0) as u32;
            assert_eq!(adc.convert_raw(v, None), x, "seed {seed} v={v}");
        }
    }
}

/// Property: transfer-model codes are monotone in MAC for random corners
/// and calibration settings.
#[test]
fn prop_transfer_monotone() {
    for seed in 0..12 {
        let mut rng = Pcg64::seeded(4000 + seed);
        let corner = [Corner::SS, Corner::TT, Corner::FF][rng.below(3)];
        let cal = rng.below(2) == 0;
        let m = TransferModel::new(corner);
        let mut prev = 0;
        for mac in 0..=1920u32 {
            let c = m.adc_code(m.sampled_voltage(mac as f64), cal);
            assert!(c >= prev, "seed {seed} {corner:?} mac={mac}: {c} < {prev}");
            prev = c;
        }
    }
}

/// Property: LRU + tag behave like a reference model under random traffic.
#[test]
fn prop_cache_set_reference_model() {
    for seed in 0..CASES {
        let mut rng = Pcg64::seeded(5000 + seed);
        let ways = 2 + rng.below(6);
        let mut tags = TagSet::new(ways);
        let mut lru = LruSet::new(ways);
        // Reference: vector of tags in recency order (front = MRU).
        let mut reference: Vec<u64> = Vec::new();
        for _ in 0..200 {
            let tag = rng.below(12) as u64; // small space forces conflicts
            match tags.lookup(tag) {
                Some(way) => {
                    lru.touch(way);
                    let pos = reference.iter().position(|&t| t == tag).unwrap();
                    let t = reference.remove(pos);
                    reference.insert(0, t);
                }
                None => {
                    let way = if tags.valid_count() < ways {
                        (0..ways).find(|&w| !tags.ways[w].valid).unwrap()
                    } else {
                        lru.victim()
                    };
                    if tags.ways[way].valid {
                        let evicted = tags.ways[way].tag;
                        let pos = reference.iter().position(|&t| t == evicted).unwrap();
                        assert_eq!(
                            pos,
                            reference.len() - 1,
                            "seed {seed}: evicted tag must be reference-LRU"
                        );
                        reference.pop();
                    }
                    tags.fill(way, tag);
                    lru.touch(way);
                    reference.insert(0, tag);
                }
            }
            // Invariant: resident sets agree.
            let mut resident: Vec<u64> =
                tags.ways.iter().filter(|e| e.valid).map(|e| e.tag).collect();
            resident.sort();
            let mut refs = reference.clone();
            refs.sort();
            assert_eq!(resident, refs, "seed {seed}");
        }
    }
}

/// Property: the batcher never loses, duplicates, or reorders requests.
#[test]
fn prop_batcher_conservation() {
    for seed in 0..CASES {
        let mut rng = Pcg64::seeded(6000 + seed);
        let max_batch = 1 + rng.below(10);
        let mut b = Batcher::new(BatcherConfig::sized(max_batch, std::time::Duration::ZERO));
        let n = 1 + rng.below(60);
        for i in 0..n {
            b.push(InferenceRequest::new(i as u64, vec![]));
        }
        let mut seen = Vec::new();
        let now = std::time::Instant::now();
        while let Some(batch) = b.take(now, true) {
            assert!(batch.len() <= max_batch, "seed {seed}");
            seen.extend(batch.requests.iter().map(|r| r.id));
        }
        assert_eq!(seen, (0..n as u64).collect::<Vec<_>>(), "seed {seed}");
    }
}

/// Property: the least-loaded router never starves a live replica under
/// adversarial completion orders. One "stuck" replica never completes its
/// batches; everyone else completes in an adversarial (randomly permuted)
/// order each round. The router must (a) stop piling work onto the stuck
/// replica and (b) keep the live replicas balanced.
#[test]
fn prop_router_no_starvation_adversarial_completions() {
    for seed in 0..CASES {
        let mut rng = Pcg64::seeded(11_000 + seed);
        let n = 2 + rng.below(6);
        let stuck = rng.below(n);
        let mut r = Router::new(n);
        let rounds = 40;
        let per_round = n - 1; // one batch per live replica per round
        for _ in 0..rounds {
            let mut routed: Vec<usize> = (0..per_round).map(|_| r.route()).collect();
            // Adversarial completion order: random permutation, and the
            // stuck replica's batches are simply never completed.
            rng.shuffle(&mut routed);
            for idx in routed {
                if idx != stuck {
                    r.complete(idx, 1e-3 * (1 + rng.below(5)) as f64);
                }
            }
        }
        // The stuck replica accumulated at most a bounded backlog: after
        // its first un-completed batch it always looks busier than an idle
        // live replica, so min-inflight routing avoids it.
        assert!(
            r.replicas[stuck].inflight <= 1,
            "seed {seed}: stuck replica piled up {} batches",
            r.replicas[stuck].inflight
        );
        // Every live replica kept receiving work — no starvation.
        let served: Vec<u64> =
            (0..n).filter(|&i| i != stuck).map(|i| r.replicas[i].served).collect();
        let min = *served.iter().min().unwrap();
        let max = *served.iter().max().unwrap();
        assert!(
            min as usize >= rounds / 2,
            "seed {seed}: a live replica starved: served {served:?}"
        );
        assert!(
            max - min <= rounds as u64 / 2,
            "seed {seed}: live replicas unbalanced: {served:?}"
        );
    }
}

/// Property: LRU recency order matches a reference model across mixed
/// touch/evict sequences (evict = victimize + refill, the fill path's
/// usage), for every way count.
#[test]
fn prop_lru_touch_evict_invariants() {
    for seed in 0..CASES {
        let mut rng = Pcg64::seeded(12_000 + seed);
        let ways = 1 + rng.below(8);
        let mut l = LruSet::new(ways);
        // Reference recency order, MRU at the front.
        let mut model: Vec<usize> = (0..ways).collect();
        for step in 0..300 {
            if rng.below(3) == 0 {
                // Evict: the victim must be the reference LRU; refilling
                // the way makes it MRU (what LlcSlice::fill does).
                let v = l.victim();
                assert_eq!(v, *model.last().unwrap(), "seed {seed} step {step}");
                l.touch(v);
                let x = model.pop().unwrap();
                model.insert(0, x);
            } else {
                let w = rng.below(ways);
                l.touch(w);
                let pos = model.iter().position(|&m| m == w).unwrap();
                let x = model.remove(pos);
                model.insert(0, x);
            }
            assert_eq!(l.mru(), model[0], "seed {seed} step {step}");
            assert_eq!(l.victim(), *model.last().unwrap(), "seed {seed} step {step}");
        }
    }
}

/// Property: RRAM programming converges from any random gap state, and
/// read currents remain ordered LRS > HRS afterwards.
#[test]
fn prop_rram_program_from_any_state() {
    for seed in 0..CASES {
        let mut rng = Pcg64::seeded(7000 + seed);
        let mut d = Rram::new();
        d.gap = rng.range(d.params.g_min, d.params.g_max);
        if rng.below(2) == 0 {
            d.program_pulse(1.6, 4.0e-9);
            assert_eq!(d.state(), RramState::Lrs, "seed {seed}");
        } else {
            d.program_pulse(-1.6, 4.0e-9);
            assert_eq!(d.state(), RramState::Hrs, "seed {seed}");
        }
    }
}

/// Property: PIM retention holds for every (q, weight, ia) across random
/// Monte-Carlo cell variations.
#[test]
fn prop_pim_retention_under_variation() {
    let vm = nvm_in_cache::device::VariationModel::default();
    for seed in 0..CASES {
        let mut rng = Pcg64::seeded(8000 + seed);
        let mut cell =
            BitCell::with_variation(Corner::TT, vm.sample_cell(&mut rng));
        cell.set_weight_bit(rng.below(2) == 0);
        cell.q = rng.below(2) == 0;
        let q0 = cell.q;
        let mut led = EnergyLedger::new();
        let out = cell.pim_dot_product(rng.below(2) == 0, &PimParams::default(), &mut led);
        assert!(out.retained, "seed {seed}");
        assert_eq!(cell.q, q0, "seed {seed}");
    }
}

/// Property: ledger totals are additive under merge (random op streams).
#[test]
fn prop_ledger_merge_additive() {
    use nvm_in_cache::cell::timing::OpKind;
    for seed in 0..CASES {
        let mut rng = Pcg64::seeded(9000 + seed);
        let mut a = EnergyLedger::new();
        let mut b = EnergyLedger::new();
        for _ in 0..50 {
            let kind = OpKind::ALL[rng.below(OpKind::ALL.len())];
            if rng.below(2) == 0 {
                a.record(kind);
            } else {
                b.record(kind);
            }
        }
        let (ta, ea) = (a.total_time(), a.total_energy());
        let (tb, eb) = (b.total_time(), b.total_energy());
        a.merge(&b);
        assert!((a.total_time() - (ta + tb)).abs() < 1e-18, "seed {seed}");
        assert!((a.total_energy() - (ea + eb)).abs() < 1e-24, "seed {seed}");
    }
}
