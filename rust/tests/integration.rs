//! Cross-module integration tests (no artifacts required).

use nvm_in_cache::array::SubArray;
use nvm_in_cache::cache::addr::Geometry;
use nvm_in_cache::cache::controller::{CacheController, PimIntegration};
use nvm_in_cache::cell::timing::EnergyLedger;
use nvm_in_cache::cell::{BitCell, PimParams};
use nvm_in_cache::consts::{ARRAY_ROWS, ARRAY_WORDS};
use nvm_in_cache::coordinator::{
    BankScheduler, BatcherConfig, InferenceRequest, Server, ServerConfig,
};
use nvm_in_cache::device::Corner;
use nvm_in_cache::nn::{resnet, ForwardMode, ResNet, Tensor};
use nvm_in_cache::pim::transfer::TransferModel;
use nvm_in_cache::pim::PimEngine;
use nvm_in_cache::util::rng::Pcg64;

/// The full analog stack agrees: cell-accurate sub-array ≈ fast engine ≈
/// closed-form transfer model, within ADC quantization bounds.
#[test]
fn subarray_engine_transfer_consistency() {
    let mut rng = Pcg64::seeded(42);
    let weights: Vec<u8> = (0..ARRAY_ROWS * ARRAY_WORDS)
        .map(|_| rng.below(16) as u8)
        .collect();
    let ia4: Vec<u8> = (0..ARRAY_ROWS).map(|_| rng.below(16) as u8).collect();

    // Cell-accurate sub-array.
    let mut sa = SubArray::new(Corner::TT);
    sa.load_weights(&weights);
    let sa_out = sa.pim_mac_4b(&ia4, None);

    // Fast engine path on the same integer problem (single unsigned bank).
    let eng = PimEngine::tt();
    let qa = nvm_in_cache::pim::quant::QuantizedActs {
        data: ia4.clone(),
        m: 1,
        k: ARRAY_ROWS,
        scale: 1.0,
    };
    let eng_out = eng.bank_mac(&qa, &weights, ARRAY_WORDS, None);

    // Closed-form: quantize each plane MAC.
    let tm = TransferModel::tt();
    let lsb = 1920.0 / 63.0;
    for w in (0..ARRAY_WORDS).step_by(13) {
        let mut closed = 0.0f64;
        for b in 0..4u32 {
            let mac: u32 = (0..ARRAY_ROWS)
                .filter(|&r| (ia4[r] >> b) & 1 == 1)
                .map(|r| weights[r * ARRAY_WORDS + w] as u32)
                .sum();
            closed += (1u32 << b) as f64 * tm.quantize_mac(mac as f64, true);
        }
        assert!(
            (eng_out[w] as f64 - closed).abs() < 1e-2,
            "engine vs closed at word {w}: {} vs {closed}",
            eng_out[w]
        );
        assert!(
            (sa_out[w] as f64 - closed).abs() <= 2.0 * lsb * 15.0,
            "subarray vs closed at word {w}: {} vs {closed}",
            sa_out[w]
        );
    }
}

/// PIM campaigns on the cache retain data end-to-end through controller +
/// addressed traffic.
#[test]
fn retention_end_to_end() {
    let geom = Geometry::tiny();
    let mut retained = CacheController::new(geom, PimIntegration::Retained);
    let addrs: Vec<_> = (0..32u64)
        .map(|i| nvm_in_cache::cache::Address::new(i * 64))
        .collect();
    let datas: Vec<[u8; 64]> = addrs.iter().map(|a| retained.read(*a)).collect();
    // Program weights + run campaigns in a different sub-array.
    retained.program_campaign(0, 1, vec![5u8; 128 * 128]);
    retained.pim_campaign(0, 1, 64);
    for (a, d) in addrs.iter().zip(&datas) {
        let (res, got) = retained.slice.read(*a);
        assert_eq!(res, nvm_in_cache::cache::slice::AccessResult::Hit);
        assert_eq!(got.as_ref(), Some(d));
    }
}

/// Scheduler + server end-to-end with the native executor on synthetic
/// weights: responses arrive, hardware cost is accounted.
#[test]
fn serve_with_native_executor() {
    let params = resnet::test_params(8, 10, 3);
    let scheduler = BankScheduler::new(
        BankScheduler::resnet18_layers(8),
        Geometry::default(),
        PimIntegration::Retained,
    )
    .unwrap();
    let server = Server::start(
        Box::new(move || {
            Ok(Box::new(nvm_in_cache::coordinator::server::NativeExecutor::new(
                &ResNet::new(params),
                ForwardMode::Baseline,
                (16, 16, 3),
                0,
            )?) as Box<dyn nvm_in_cache::coordinator::Executor>)
        }),
        Some(scheduler),
        ServerConfig {
            batcher: BatcherConfig::sized(4, std::time::Duration::from_millis(1)),
        },
    );
    let mut rng = Pcg64::seeded(9);
    for i in 0..8u64 {
        let img: Vec<f32> = (0..16 * 16 * 3).map(|_| rng.f64() as f32).collect();
        server.submit(InferenceRequest::new(i, img));
    }
    for _ in 0..8 {
        let r = server
            .responses
            .recv_timeout(std::time::Duration::from_secs(60))
            .expect("response");
        assert!(r.predicted < 10);
        assert!(r.hw_latency_s > 0.0, "scheduler must account hw latency");
    }
    let m = server.shutdown();
    assert_eq!(m.responses, 8);
    assert!(m.hw_energy_j > 0.0);
    assert!(m.hw_ops > 0.0);
}

/// Gated-GND discipline ablation at the cell level.
#[test]
fn gated_gnd_discipline_protects_data() {
    for q in [false, true] {
        for w in [false, true] {
            let mut good = BitCell::with_weight_bit(Corner::TT, w);
            good.q = q;
            let mut bad = good.clone();
            let mut led = EnergyLedger::new();
            let ok = good.pim_dot_product(true, &PimParams::default(), &mut led);
            assert!(ok.retained);
            let violated = bad.pim_dot_product(
                true,
                &PimParams { skip_gated_gnd: true, ..Default::default() },
                &mut led,
            );
            if q {
                assert!(!violated.retained, "q=1 must corrupt under violation");
            }
        }
    }
}

/// Conv mapping → scheduler placement → cost model chain is coherent for
/// every layer of the e2e network.
#[test]
fn mapping_chain_consistency() {
    let layers = BankScheduler::resnet18_layers(16);
    for shape in &layers {
        let m = nvm_in_cache::mapping::ConvMapping::plan(*shape);
        assert!(m.total_subarrays >= 1);
        assert!(m.mean_utilization() > 0.0 && m.mean_utilization() <= 1.0);
        assert_eq!(m.submatrices, shape.k * shape.k);
    }
    let mut sched =
        BankScheduler::new(layers, Geometry::default(), PimIntegration::Retained).unwrap();
    sched.program_network();
    let c = sched.batch_cost(2);
    assert!(c.ops > 1e6, "ResNet-18 fwd is MMACs: {}", c.ops);
    assert!(c.latency_s > 0.0 && c.energy_j > 0.0);
}

/// The native PIM path computes the same function as fp32 up to
/// quantization (finite, same shape) on a random net.
#[test]
fn native_pim_vs_baseline_predictions() {
    let net = ResNet::new(resnet::test_params(8, 10, 11));
    let mut rng = Pcg64::seeded(3);
    let x = Tensor::from_vec(
        &[4, 16, 16, 3],
        (0..4 * 16 * 16 * 3).map(|_| rng.f64() as f32).collect(),
    );
    let base = net.forward(&x, ForwardMode::Baseline, 0).unwrap();
    let pim = net.forward(&x, ForwardMode::Pim, 0).unwrap();
    assert_eq!(base.shape, pim.shape);
    assert!(pim.data.iter().all(|v| v.is_finite()));
}

/// Figures generate cleanly into a temp dir (smoke over all generators,
/// small MC count).
#[test]
fn figures_generate_all_smoke() {
    let dir = std::env::temp_dir().join("nvm_figs_integration");
    std::fs::create_dir_all(&dir).unwrap();
    nvm_in_cache::figures::generate_all(&dir, 10).unwrap();
    for f in [
        "fig9a_rram_iv.csv",
        "fig9bcd_snm.csv",
        "section_vb_scalars.csv",
        "fig10_weight_voltage.csv",
        "fig11a_weight_current.csv",
        "fig12a_adc_transfer.csv",
        "fig13_monte_carlo.csv",
        "fig14a_kernel.csv",
        "table1_comparison.csv",
    ] {
        assert!(dir.join(f).exists(), "{f} missing");
    }
}
