//! Compile-once / execute-many weight programs (the software mirror of
//! one-time RRAM programming).
//!
//! The paper's premise is that weights are **programmed once** into the
//! RRAM layer and then reused across massively parallel MACs; Neural
//! Cache and PIM-DRAM make the same split between a one-time layout
//! "program" step and cheap bit-serial execution. This module is that
//! split in software: [`PreparedWeights`] holds a weight matrix already
//! quantized into the pos/neg 4-bit banks and packed into tile-aligned
//! planes ([`PreparedBank`]), and [`CompiledNet`] holds a whole ResNet's
//! prepared layers plus the im2col/mapping descriptors and reusable
//! scratch pools — so the serving hot loop performs **zero** weight
//! quantization or packing after compile.
//!
//! Every one-shot entry point still exists ([`PimEngine::pim_matmul`],
//! [`crate::nn::ResNet::forward`], …) and is now implemented as
//! compile-then-run over this layer, so prepared output is bit-identical
//! to the historical path (pinned by `rust/tests/program_parity.rs`).
//!
//! The per-thread [`prepare_count`] counter records every bank-packing
//! event; the parity and fleet tests assert it stays flat across
//! steady-state prepared execution. Its sibling [`mac_alloc_count`]
//! records every MAC-path buffer growth (activation quantization,
//! bit-plane transpose, pos/neg bank outputs) — on the scratch-pool path
//! those buffers are borrowed from [`ScratchPool`], so a warmed-up
//! [`CompiledNet::step`] keeps this counter flat too
//! (PERFORMANCE.md §12, `rust/tests/hotpath_parity.rs`).

use std::cell::Cell;

use crate::consts::{ARRAY_ROWS, ARRAY_WORDS};
use crate::mapping::conv_mapper::{ConvMapping, ConvShape};
use crate::nn::layers;
use crate::nn::resnet::{ResNet, STAGES};
use crate::nn::{ForwardMode, Tensor};
use crate::util::rng::Pcg64;
use crate::Result;

use super::engine::MacScratch;
use super::parallel::Parallelism;
use super::quant::{quantize_acts, quantize_weights, QuantizedWeights};
use super::transfer::MAC_FULLSCALE;
use super::{PimEngine, TransferModel};

thread_local! {
    static PREPARES: Cell<u64> = const { Cell::new(0) };
}

/// Number of weight-bank packing events performed **by the calling
/// thread** so far (each [`PreparedBank::pack`], and therefore each
/// quantize-and-prepare of a weight matrix, counts its banks here).
///
/// The counter is thread-local so tests can assert the compile-once
/// contract without cross-test interference: capture it, run steady-state
/// prepared execution, and require the delta to be zero.
///
/// # Examples
///
/// ```
/// use nvm_in_cache::pim::{program, PimEngine};
///
/// let eng = PimEngine::tt();
/// let w = vec![0.25f32; 64 * 3];
/// let program_w = eng.prepare(&w, 64, 3); // packs the pos + neg banks
/// let after_compile = program::prepare_count();
///
/// let a = vec![1.0f32; 2 * 64];
/// let _ = eng.matmul_prepared(&a, 2, &program_w, None);
/// let _ = eng.matmul_prepared(&a, 2, &program_w, None);
/// assert_eq!(program::prepare_count(), after_compile, "execute-many is prepare-free");
/// ```
pub fn prepare_count() -> u64 {
    PREPARES.with(|c| c.get())
}

fn note_prepare() {
    PREPARES.with(|c| c.set(c.get() + 1));
}

thread_local! {
    static MAC_ALLOCS: Cell<u64> = const { Cell::new(0) };
}

/// Number of MAC-path buffer growths performed **by the calling thread**
/// so far. The counted sites are the per-call working buffers of a
/// prepared matmul: activation quantization
/// ([`crate::pim::quant::quantize_acts_into`]), the activation bit-plane
/// transpose ([`crate::pim::quant::QuantizedActs::pack_planes_into`]),
/// and the pos/neg bank outputs
/// ([`PimEngine::matmul_prepared_scratch`](crate::pim::engine::PimEngine)).
/// All of them run on the caller's thread (workers only fill packed lane
/// accumulators on their own stacks), so the counter is thread-local for
/// cross-test isolation, exactly like [`prepare_count`].
///
/// On the scratch-pool path those buffers live in
/// [`ScratchPool`]/[`MacScratch`] and are reused call-over-call, so after
/// a warm-up forward the counter stays **flat** — the allocation-free
/// steady-state contract (the `steady_state_zero_allocs` bench gate).
/// The subtracted per-layer output tensor and the per-step engine LUT are
/// *not* counted: both are documented, bounded allocations outside the
/// per-bank MAC loop (PERFORMANCE.md §12 audits them).
pub fn mac_alloc_count() -> u64 {
    MAC_ALLOCS.with(|c| c.get())
}

/// Tally a counted MAC-path buffer that is about to grow: `capacity` is
/// the buffer's retained capacity, `needed` the elements the call
/// requires. A reserve within capacity is free and uncounted.
pub(crate) fn note_mac_growth(capacity: usize, needed: usize) {
    if capacity < needed {
        MAC_ALLOCS.with(|c| c.set(c.get() + 1));
    }
}

/// Straight-line executable **specification** of the noiseless,
/// calibrated-TT prepared matmul — the Rust counterpart of
/// `kernels/ref.py`: raw row-major banks, nested loops in the documented
/// unit order (output row → 128-row block → 128-word tile), no
/// [`PreparedBank`], no packed accumulators, no worker pool. The engine's
/// prepared path must match this **bit-for-bit**; because the one-shot
/// entry points are wrappers over the same prepared core, this function
/// is the independent witness that the packed layout and reduce order
/// are right (`rust/tests/program_parity.rs`, and the
/// `parity_prepared_engine_bit_identical` gate in `repro bench`).
pub fn spec_matmul(a: &[f32], m: usize, k: usize, w: &[f32], n: usize) -> Vec<f32> {
    let tm = TransferModel::tt();
    let lut: Vec<f32> = (0..=MAC_FULLSCALE)
        .map(|mac| tm.quantize_mac(mac as f64, true) as f32)
        .collect();
    let qa = quantize_acts(a, m, k);
    let qw = quantize_weights(w, k, n);
    let bank_mac = |bank: &[u8]| -> Vec<f32> {
        let mut out = vec![0.0f32; m * n];
        for i in 0..m {
            for bi in 0..k.div_ceil(ARRAY_ROWS) {
                let (k0, k1) = (bi * ARRAY_ROWS, (bi * ARRAY_ROWS + ARRAY_ROWS).min(k));
                for ti in 0..n.div_ceil(ARRAY_WORDS) {
                    let (c0, c1) = (ti * ARRAY_WORDS, (ti * ARRAY_WORDS + ARRAY_WORDS).min(n));
                    for j in c0..c1 {
                        let mut planes = [0u32; 4];
                        for kk in k0..k1 {
                            let av = qa.data[i * k + kk] as u32;
                            let wv = bank[kk * n + j] as u32;
                            for (b, p) in planes.iter_mut().enumerate() {
                                *p += ((av >> b) & 1) * wv;
                            }
                        }
                        // Same f32 expression shape as the engine's
                        // plane recombination (left-associated).
                        out[i * n + j] += lut[planes[0] as usize]
                            + 2.0 * lut[planes[1] as usize]
                            + 4.0 * lut[planes[2] as usize]
                            + 8.0 * lut[planes[3] as usize];
                    }
                }
            }
        }
        out
    };
    let pos = bank_mac(&qw.pos);
    let neg = bank_mac(&qw.neg);
    pos.iter()
        .zip(neg.iter())
        .enumerate()
        .map(|(i, (p, q))| (p - q) * qa.scale * qw.scale[i % n])
        .collect()
}

/// One unsigned 4-bit weight bank packed into **two** tile-aligned
/// layouts, both built once at prepare time (the software mirror of
/// one-time RRAM programming):
///
/// * **Packed nibbles** — for each 128-word output tile, `k` rows of
///   [`ARRAY_WORDS`] bytes (the ragged last tile zero-padded). This is
///   what the historical scalar kernel reads; successive reduction rows
///   of one tile are contiguous, mirroring how a sub-array holds its own
///   128 word columns.
/// * **Transposed bit-plane bitmaps** — for each tile, each of the four
///   weight bit-planes, and each output column, ⌈k/64⌉ `u64` words whose
///   bit `r` is bit `plane` of the weight at reduction index `64·kw + r`
///   ([`Self::plane_row`]). This is what the word-wide AND/popcount
///   kernel ([`crate::pim::engine::MacKernel::BitPlane`]) reads: 64
///   reduction rows per bitwise op instead of one byte multiply-add.
///   Because [`ARRAY_ROWS`](crate::consts::ARRAY_ROWS) is a multiple of
///   64, every 128-row powerline block starts on a word boundary, and
///   padding bits (rows ≥ k, columns ≥ n) are zero in both layouts.
#[derive(Clone, Debug)]
pub struct PreparedBank {
    /// `n_tiles × k × ARRAY_WORDS` bytes, tile-major.
    data: Vec<u8>,
    /// `n_tiles × 4 × ⌈k/64⌉ × ARRAY_WORDS` words: plane-major within a
    /// tile, then reduction word, then output column.
    planes: Vec<u64>,
    /// One flag per (tile, plane, reduction word) bitmap row of `planes`:
    /// does that `ARRAY_WORDS`-wide row contain any nonzero word?
    /// Precomputed at pack time so the word-wide kernel can skip entire
    /// all-zero weight rows ([`Self::plane_any`]) — e.g. a one-sided
    /// bank (all weights ≥ 0 leaves the neg bank empty) or a sparse
    /// plane costs no AND/popcount work at all.
    plane_nonzero: Vec<bool>,
    k: usize,
    n: usize,
    k_words: usize,
}

impl PreparedBank {
    /// Pack a row-major `[k][n]` bank (values 0..=15) into tile-aligned
    /// planes — both the nibble layout and the transposed bit-plane
    /// bitmaps. Counts one prepare event ([`prepare_count`]).
    pub fn pack(bank: &[u8], k: usize, n: usize) -> PreparedBank {
        assert_eq!(bank.len(), k * n, "bank shape mismatch");
        let n_tiles = n.div_ceil(ARRAY_WORDS);
        let k_words = k.div_ceil(64);
        let mut data = vec![0u8; n_tiles * k * ARRAY_WORDS];
        let mut planes = vec![0u64; n_tiles * 4 * k_words * ARRAY_WORDS];
        for ti in 0..n_tiles {
            let c0 = ti * ARRAY_WORDS;
            let c1 = (c0 + ARRAY_WORDS).min(n);
            for kk in 0..k {
                let src = &bank[kk * n + c0..kk * n + c1];
                let dst = (ti * k + kk) * ARRAY_WORDS;
                data[dst..dst + (c1 - c0)].copy_from_slice(src);
                let (kw, bit) = (kk / 64, kk % 64);
                for (c, &v) in src.iter().enumerate() {
                    for b in 0..4usize {
                        planes[((ti * 4 + b) * k_words + kw) * ARRAY_WORDS + c] |=
                            (((v >> b) & 1) as u64) << bit;
                    }
                }
            }
        }
        let plane_nonzero = planes
            .chunks_exact(ARRAY_WORDS)
            .map(|row| row.iter().any(|&w| w != 0))
            .collect();
        note_prepare();
        PreparedBank { data, planes, plane_nonzero, k, n, k_words }
    }

    /// Reduction dimension.
    pub fn k(&self) -> usize {
        self.k
    }

    /// Output columns (before tile padding).
    pub fn n(&self) -> usize {
        self.n
    }

    /// Number of 64-bit words each per-column bit-plane bitmap spans
    /// (⌈k/64⌉).
    pub fn k_words(&self) -> usize {
        self.k_words
    }

    /// The [`ARRAY_WORDS`]-wide row of output tile `ti` at reduction
    /// index `kk` (only the tile's live columns are meaningful; the
    /// padding bytes are zero). Read by the scalar kernel.
    #[inline]
    pub fn row(&self, ti: usize, kk: usize) -> &[u8] {
        let off = (ti * self.k + kk) * ARRAY_WORDS;
        &self.data[off..off + ARRAY_WORDS]
    }

    /// The [`ARRAY_WORDS`]-wide row of bit-plane words of output tile
    /// `ti`: one `u64` per word column, whose bit `r` is bit `plane`
    /// (0 = LSB) of the weight at reduction index `64·kw + r`. Padding
    /// bits and padding columns are zero. Read by the word-wide
    /// AND/popcount kernel.
    #[inline]
    pub fn plane_row(&self, ti: usize, plane: usize, kw: usize) -> &[u64] {
        let off = ((ti * 4 + plane) * self.k_words + kw) * ARRAY_WORDS;
        &self.planes[off..off + ARRAY_WORDS]
    }

    /// Does the [`Self::plane_row`] at (`ti`, `plane`, `kw`) contain any
    /// nonzero word? Precomputed at pack time; `false` means the whole
    /// AND/popcount row can be skipped — a popcount against zero words
    /// contributes 0 to every lane, so skipping is output-neutral
    /// (the zero-skip parity harness pins this bit-for-bit).
    #[inline]
    pub fn plane_any(&self, ti: usize, plane: usize, kw: usize) -> bool {
        self.plane_nonzero[(ti * 4 + plane) * self.k_words + kw]
    }
}

/// A weight matrix compiled for execute-many use: pre-quantized into the
/// signed pos/neg split (§IV-C) with per-column scales, each bank packed
/// tile-aligned. Built once via [`PimEngine::prepare`]; executed with
/// [`PimEngine::matmul_prepared`] — bit-identical to the one-shot
/// [`PimEngine::pim_matmul`] on the same dense weights.
#[derive(Clone, Debug)]
pub struct PreparedWeights {
    /// Reduction dimension.
    pub k: usize,
    /// Output columns.
    pub n: usize,
    /// Per-column dequantization scale (length `n`).
    pub scale: Vec<f32>,
    /// Positive bank (magnitudes of w ≥ 0), tile-aligned.
    pub pos: PreparedBank,
    /// Negative bank (magnitudes of w < 0), tile-aligned.
    pub neg: PreparedBank,
}

impl PreparedWeights {
    /// Quantize and pack a dense `[k][n]` signed weight matrix.
    pub fn from_dense(w: &[f32], k: usize, n: usize) -> PreparedWeights {
        Self::from_quantized(quantize_weights(w, k, n))
    }

    /// Pack already-quantized banks.
    pub fn from_quantized(qw: QuantizedWeights) -> PreparedWeights {
        let pos = PreparedBank::pack(&qw.pos, qw.k, qw.n);
        let neg = PreparedBank::pack(&qw.neg, qw.k, qw.n);
        PreparedWeights { k: qw.k, n: qw.n, scale: qw.scale, pos, neg }
    }
}

/// Reusable per-executor scratch buffers (im2col patch matrix, ReLU
/// staging, and the MAC working set — quantized activations, bit-plane
/// transpose, pos/neg bank outputs) so steady-state prepared execution
/// allocates no fresh per-layer buffers ([`mac_alloc_count`] stays flat
/// once warm). One pool per executor/thread; forwards borrow it mutably
/// for the duration of a batch.
#[derive(Debug, Default)]
pub struct ScratchPool {
    pub(crate) patches: Vec<f32>,
    pub(crate) relu: Vec<f32>,
    pub(crate) mac: MacScratch,
}

impl ScratchPool {
    /// An empty pool (buffers grow to the largest layer on first use).
    pub fn new() -> ScratchPool {
        ScratchPool::default()
    }
}

/// One convolution layer compiled for execute-many use: the im2col-ordered
/// dense weight matrix (fp32 paths), the prepared quantized banks (the
/// hardware-true paths), and the §IV-C mapping descriptor tying the layer
/// to its sub-array tiling plan.
#[derive(Clone, Debug)]
pub struct CompiledConv {
    /// Kernel size K (square).
    pub kernel: usize,
    /// Convolution stride.
    pub stride: usize,
    /// Input channels.
    pub cin: usize,
    /// Output channels.
    pub cout: usize,
    /// Dense im2col-ordered weights `[cin·K², cout]`
    /// ([`layers::weights_to_matrix`] output, computed once).
    pub dense: Tensor,
    /// Prepared quantized banks (None when compiled dense-only).
    pub prepared: Option<PreparedWeights>,
    /// §IV-C tiling plan for the compile-time reference input width
    /// (planning metadata; execution reads the actual input shape).
    pub mapping: ConvMapping,
}

impl CompiledConv {
    /// Compile an HWIO conv weight tensor. `input_width` is the reference
    /// spatial width for the mapping descriptor; `prepare` additionally
    /// quantizes + packs the banks for the hardware-true engine path.
    pub fn compile(
        w_hwio: &Tensor,
        stride: usize,
        input_width: usize,
        prepare: bool,
    ) -> CompiledConv {
        let (kh, kw, cin, cout) =
            (w_hwio.shape[0], w_hwio.shape[1], w_hwio.shape[2], w_hwio.shape[3]);
        assert_eq!(kh, kw, "square kernels only");
        let dense = layers::weights_to_matrix(w_hwio);
        let prepared =
            prepare.then(|| PreparedWeights::from_dense(&dense.data, cin * kh * kh, cout));
        let mapping = ConvMapping::plan(ConvShape {
            k: kh,
            d: cin,
            n: cout,
            w: input_width,
            stride,
        });
        CompiledConv { kernel: kh, stride, cin, cout, dense, prepared, mapping }
    }

    /// Execute the layer: im2col into the pool's patch buffer, then the
    /// dense fp32 matmul (`engine = None`) or the prepared PIM matmul.
    /// Bit-identical to [`layers::conv2d_par`] on the original HWIO
    /// weights. Falls back to an on-the-fly prepare (counted) if the
    /// engine path is requested on a dense-only compile.
    pub fn forward(
        &self,
        x: &Tensor,
        engine: Option<&PimEngine>,
        rng: Option<&mut Pcg64>,
        par: Parallelism,
        scratch: &mut ScratchPool,
    ) -> Tensor {
        let n = x.shape[0];
        assert_eq!(x.shape[3], self.cin, "input channels vs compiled weights");
        let (rows, oh, ow) = layers::im2col_into(x, self.kernel, self.stride, &mut scratch.patches);
        let kdim = self.cin * self.kernel * self.kernel;
        let out = match engine {
            None => PimEngine::par_exact_matmul(
                &scratch.patches,
                rows,
                kdim,
                &self.dense.data,
                self.cout,
                par,
            ),
            Some(eng) => {
                let oneshot;
                let pw = match &self.prepared {
                    Some(pw) => pw,
                    None => {
                        oneshot = PreparedWeights::from_dense(&self.dense.data, kdim, self.cout);
                        &oneshot
                    }
                };
                eng.matmul_prepared_scratch(&scratch.patches, rows, pw, rng, par, &mut scratch.mac)
            }
        };
        Tensor::from_vec(&[n, oh, ow, self.cout], out)
    }
}

/// One linear (fully-connected) layer compiled for execute-many use.
/// The PIM path applies ReLU to the input first, exactly like
/// [`layers::linear_par`].
#[derive(Clone, Debug)]
pub struct CompiledLinear {
    /// Dense weights `[k, cout]`.
    pub dense: Tensor,
    /// Prepared quantized banks (None when compiled dense-only).
    pub prepared: Option<PreparedWeights>,
    /// Bias added after the matmul. May be all-zero when the caller
    /// defers the bias past a post-processing step, as the ResNet §V-E
    /// emulation does — the add still runs then, deliberately: `+= 0.0`
    /// normalizes any `-0.0` matmul output to `+0.0` exactly like the
    /// historical path did, so skipping it would break bit-identity.
    pub bias: Vec<f32>,
}

impl CompiledLinear {
    /// Compile a `[k, cout]` weight tensor plus bias.
    pub fn compile(w: &Tensor, bias: &[f32], prepare: bool) -> CompiledLinear {
        let (k, c) = (w.shape[0], w.shape[1]);
        let prepared = prepare.then(|| PreparedWeights::from_dense(&w.data, k, c));
        CompiledLinear { dense: w.clone(), prepared, bias: bias.to_vec() }
    }

    /// Execute the layer; bit-identical to [`layers::linear_par`] on the
    /// original weights and bias.
    pub fn forward(
        &self,
        x: &Tensor,
        engine: Option<&PimEngine>,
        rng: Option<&mut Pcg64>,
        par: Parallelism,
        scratch: &mut ScratchPool,
    ) -> Tensor {
        let (nr, k) = (x.shape[0], x.shape[1]);
        assert_eq!(k, self.dense.shape[0], "input features vs compiled weights");
        let c = self.dense.shape[1];
        let mut out = match engine {
            None => Tensor::from_vec(
                &[nr, c],
                PimEngine::par_exact_matmul(&x.data, nr, k, &self.dense.data, c, par),
            ),
            Some(eng) => {
                scratch.relu.clear();
                scratch.relu.extend(x.data.iter().map(|v| v.max(0.0)));
                let oneshot;
                let pw = match &self.prepared {
                    Some(pw) => pw,
                    None => {
                        oneshot = PreparedWeights::from_dense(&self.dense.data, k, c);
                        &oneshot
                    }
                };
                Tensor::from_vec(
                    &[nr, c],
                    eng.matmul_prepared_scratch(&scratch.relu, nr, pw, rng, par, &mut scratch.mac),
                )
            }
        };
        for ni in 0..nr {
            for ci in 0..c {
                out.data[ni * c + ci] += self.bias[ci];
            }
        }
        out
    }
}

/// One residual block's compiled layers + norm parameters.
#[derive(Clone, Debug)]
pub struct CompiledBlock {
    /// Parameter prefix (`s{stage}b{block}`), for reports.
    pub name: String,
    /// First 3×3 conv (carries the block's stride).
    pub w1: CompiledConv,
    /// GroupNorm gamma after w1.
    pub g1: Vec<f32>,
    /// GroupNorm beta after w1.
    pub b1: Vec<f32>,
    /// Second 3×3 conv (stride 1).
    pub w2: CompiledConv,
    /// GroupNorm gamma after w2.
    pub g2: Vec<f32>,
    /// GroupNorm beta after w2.
    pub b2: Vec<f32>,
    /// 1×1 projection on the identity path, when the block changes
    /// shape.
    pub downsample: Option<CompiledConv>,
}

/// A whole ResNet compiled for execute-many serving: every layer's
/// prepared weights + mapping descriptors, the norm parameters, and the
/// worker-pool width — pure data (`Send + Sync`), so one compiled program
/// can be shared across replicas, server threads, and campaign rewarms.
///
/// Built once via [`ResNet::compile`]; executed with
/// [`Self::forward_par`], which is bit-identical to
/// [`ResNet::forward_par`] in every [`ForwardMode`], noiseless and noisy,
/// at any thread count (`rust/tests/program_parity.rs`).
#[derive(Clone, Debug)]
pub struct CompiledNet {
    /// Stem conv.
    pub stem: CompiledConv,
    /// Stem GroupNorm gamma.
    pub stem_gamma: Vec<f32>,
    /// Stem GroupNorm beta.
    pub stem_beta: Vec<f32>,
    /// Residual blocks in execution order (stages flattened).
    pub blocks: Vec<CompiledBlock>,
    /// Final classifier (compiled with a zero bias; see [`Self::fc_bias`]).
    pub fc: CompiledLinear,
    /// The real fc bias, added after the §V-E post-ADC step exactly as
    /// the uncompiled forward does.
    pub fc_bias: Vec<f32>,
    /// Worker-pool width [`Self::forward`] and [`Self::classify`] run on
    /// (copied from the source [`ResNet`] at compile).
    pub parallelism: Parallelism,
}

/// Reference input spatial width used for the compile-time mapping
/// descriptors (the 16×16 dataset frame).
const REF_INPUT_WIDTH: usize = 16;

impl CompiledNet {
    /// Compile every layer: dense im2col weights plus prepared quantized
    /// banks, so any [`ForwardMode`] executes prepare-free.
    pub fn compile(net: &ResNet) -> Result<CompiledNet> {
        Self::compile_with(net, true)
    }

    /// Compile the dense layers only (no bank preparation) — what the
    /// one-shot fp32/emulation forwards use to avoid paying quantization
    /// they would never read.
    pub fn compile_dense(net: &ResNet) -> Result<CompiledNet> {
        Self::compile_with(net, false)
    }

    fn compile_with(net: &ResNet, prepare: bool) -> Result<CompiledNet> {
        let p = &net.params;
        let mut width = REF_INPUT_WIDTH;
        let stem = CompiledConv::compile(p.get("stem/w")?, 1, width, prepare);
        let stem_gamma = p.get("stem/gamma")?.data.clone();
        let stem_beta = p.get("stem/beta")?.data.clone();
        let mut blocks = Vec::new();
        for (s, &nblocks) in STAGES.iter().enumerate() {
            let stride = if s == 0 { 1 } else { 2 };
            for b in 0..nblocks {
                let st = if b == 0 { stride } else { 1 };
                let pre = format!("s{s}b{b}");
                let win = width;
                let w1 = CompiledConv::compile(p.get(&format!("{pre}/w1"))?, st, win, prepare);
                width = win.div_ceil(st);
                let w2 = CompiledConv::compile(p.get(&format!("{pre}/w2"))?, 1, width, prepare);
                let wd_key = format!("{pre}/wd");
                let downsample = if p.tensors.contains_key(&wd_key) {
                    Some(CompiledConv::compile(p.get(&wd_key)?, st, win, prepare))
                } else {
                    None
                };
                blocks.push(CompiledBlock {
                    name: pre.clone(),
                    w1,
                    g1: p.get(&format!("{pre}/g1"))?.data.clone(),
                    b1: p.get(&format!("{pre}/b1"))?.data.clone(),
                    w2,
                    g2: p.get(&format!("{pre}/g2"))?.data.clone(),
                    b2: p.get(&format!("{pre}/b2"))?.data.clone(),
                    downsample,
                });
            }
        }
        let fc_w = p.get("fc/w")?;
        let fc_b = p.get("fc/b")?;
        let fc = CompiledLinear::compile(fc_w, &vec![0.0; fc_b.len()], prepare);
        Ok(CompiledNet {
            stem,
            stem_gamma,
            stem_beta,
            blocks,
            fc,
            fc_bias: fc_b.data.clone(),
            parallelism: net.parallelism,
        })
    }

    /// Upgrade a dense-only compile to a fully prepared one, reusing the
    /// already-reordered dense matrices — no weights re-parse, no im2col
    /// reorder, just the bank quantize + pack per layer. Layers that
    /// already carry banks are kept as-is, so upgrading a fully prepared
    /// program is a plain clone.
    pub fn prepare_banks(&self) -> CompiledNet {
        let conv = |c: &CompiledConv| -> CompiledConv {
            let mut c = c.clone();
            if c.prepared.is_none() {
                c.prepared = Some(PreparedWeights::from_dense(
                    &c.dense.data,
                    c.dense.shape[0],
                    c.dense.shape[1],
                ));
            }
            c
        };
        let mut fc = self.fc.clone();
        if fc.prepared.is_none() {
            fc.prepared = Some(PreparedWeights::from_dense(
                &fc.dense.data,
                fc.dense.shape[0],
                fc.dense.shape[1],
            ));
        }
        CompiledNet {
            stem: conv(&self.stem),
            stem_gamma: self.stem_gamma.clone(),
            stem_beta: self.stem_beta.clone(),
            blocks: self
                .blocks
                .iter()
                .map(|b| CompiledBlock {
                    name: b.name.clone(),
                    w1: conv(&b.w1),
                    g1: b.g1.clone(),
                    b1: b.b1.clone(),
                    w2: conv(&b.w2),
                    g2: b.g2.clone(),
                    b2: b.b2.clone(),
                    downsample: b.downsample.as_ref().map(conv),
                })
                .collect(),
            fc,
            fc_bias: self.fc_bias.clone(),
            parallelism: self.parallelism,
        }
    }

    /// Total compiled conv/fc layers.
    pub fn layer_count(&self) -> usize {
        1 + self
            .blocks
            .iter()
            .map(|b| 2 + b.downsample.is_some() as usize)
            .sum::<usize>()
            + 1
    }

    /// Do all layers carry prepared banks (⇒ every mode, including the
    /// hardware-true ones, executes with zero weight preparation)?
    pub fn fully_prepared(&self) -> bool {
        let conv_ok = |c: &CompiledConv| c.prepared.is_some();
        conv_ok(&self.stem)
            && self.fc.prepared.is_some()
            && self.blocks.iter().all(|b| {
                conv_ok(&b.w1)
                    && conv_ok(&b.w2)
                    && b.downsample.as_ref().map(conv_ok).unwrap_or(true)
            })
    }

    /// Forward on [`Self::parallelism`] with a throwaway scratch pool.
    pub fn forward(&self, x: &Tensor, mode: ForwardMode, seed: u64) -> Tensor {
        self.forward_par(x, mode, seed, self.parallelism, &mut ScratchPool::new())
    }

    /// The prepared-execution forward: same layer choreography, RNG
    /// stream derivation, and f32 accumulation order as
    /// [`ResNet::forward_par`], minus all weight preparation — so logits
    /// are bit-identical to the uncompiled path in every mode at any
    /// thread count.
    ///
    /// Implemented as a full drain of the boundary-stepped execution
    /// ([`Self::begin`] / [`Self::step`]); the stepped path *is* the
    /// forward, so continuous batching cannot drift from it.
    pub fn forward_par(
        &self,
        x: &Tensor,
        mode: ForwardMode,
        seed: u64,
        par: Parallelism,
        scratch: &mut ScratchPool,
    ) -> Tensor {
        let mut run = self.begin(x, seed);
        while !self.step(&mut run, mode, par, scratch) {}
        run.into_logits()
    }

    /// Like [`Self::forward_par`] but returns the completed
    /// [`InflightRun`] instead of just its logits, so callers (the shard
    /// parity harness, `pim::shard_exec` tests) can also compare the
    /// trailing RNG state via [`InflightRun::rng_fingerprint`] — proving
    /// two execution schedules drew *exactly* the same noise stream, not
    /// merely the same outputs.
    pub fn forward_run(
        &self,
        x: &Tensor,
        mode: ForwardMode,
        seed: u64,
        par: Parallelism,
        scratch: &mut ScratchPool,
    ) -> InflightRun {
        let mut run = self.begin(x, seed);
        while !self.step(&mut run, mode, par, scratch) {}
        run
    }

    /// Number of merge boundaries in one execution: stem, each residual
    /// block, and the pool→fc head. An [`InflightRun`] is complete once
    /// [`Self::step`] has been called this many times.
    pub fn boundaries(&self) -> usize {
        self.blocks.len() + 2
    }

    /// Open an in-flight execution for one admission group. The group
    /// keeps its own activation tensor and its own RNG stream (seeded
    /// exactly like a solo [`Self::forward_par`] call), so co-resident
    /// groups never perturb each other's numerics — activation
    /// quantization scales are per-tensor, which is precisely why merged
    /// execution is per-group sub-batches rather than tensor
    /// concatenation.
    pub fn begin(&self, x: &Tensor, seed: u64) -> InflightRun {
        InflightRun { h: x.clone(), rng: Pcg64::seeded(seed), boundary: 0 }
    }

    /// Advance one in-flight run by a single boundary (stem, one residual
    /// block, or the head). Returns `true` when the run is complete and
    /// [`InflightRun::into_logits`] may be taken.
    ///
    /// The per-boundary bodies replicate the solo forward statement for
    /// statement — same engine construction, GroupNorm epsilon, §V-E
    /// post-ADC placement, and RNG fork order — so a run stepped to
    /// completion is bit-identical to [`Self::forward_par`] regardless of
    /// how many other groups were admitted between its boundaries.
    pub fn step(
        &self,
        run: &mut InflightRun,
        mode: ForwardMode,
        par: Parallelism,
        scratch: &mut ScratchPool,
    ) -> bool {
        assert!(run.boundary < self.boundaries(), "stepping a completed run");
        let engine = match mode {
            ForwardMode::PimHw => Some(PimEngine::tt().with_parallelism(par)),
            ForwardMode::PimHwNoise(sigma) => {
                Some(PimEngine::tt().with_noise(sigma).with_parallelism(par))
            }
            _ => None,
        };
        let emu_sigma: Option<Option<f64>> = match mode {
            ForwardMode::Pim => Some(None),
            ForwardMode::PimNoise(s) => Some(Some(s)),
            _ => None,
        };
        let transfer = TransferModel::tt();
        let hw_noise = matches!(mode, ForwardMode::PimHwNoise(_));
        let rng_opt = |r: &mut Pcg64| -> Option<Pcg64> {
            if hw_noise {
                Some(r.fork(1))
            } else {
                None
            }
        };
        let eng = engine.as_ref();

        let gn = |t: &Tensor, g: &[f32], b: &[f32]| -> Tensor {
            layers::group_norm(t, g, b, 1e-5)
        };
        // §V-E emulation applied at each layer output (emu modes only).
        let post = |t: Tensor, r: &mut Pcg64| -> Tensor {
            match emu_sigma {
                None => t,
                Some(sigma) => {
                    let mut local = r.fork(2);
                    layers::adc_emulate(&t, &transfer, sigma, Some(&mut local))
                }
            }
        };

        let rng = &mut run.rng;
        let nblocks = self.blocks.len();
        match run.boundary {
            0 => {
                let mut local = rng_opt(rng);
                let mut h = self.stem.forward(&run.h, eng, local.as_mut(), par, scratch);
                h = post(h, rng);
                run.h = gn(&h, &self.stem_gamma, &self.stem_beta).relu();
            }
            i if i <= nblocks => {
                let blk = &self.blocks[i - 1];
                let idn = run.h.clone();
                let mut local = rng_opt(rng);
                let mut h = blk.w1.forward(&run.h, eng, local.as_mut(), par, scratch);
                h = post(h, rng);
                h = gn(&h, &blk.g1, &blk.b1).relu();
                let mut local = rng_opt(rng);
                h = blk.w2.forward(&h, eng, local.as_mut(), par, scratch);
                h = post(h, rng);
                h = gn(&h, &blk.g2, &blk.b2);
                let idn = match &blk.downsample {
                    Some(d) => {
                        let mut local = rng_opt(rng);
                        let dd = d.forward(&idn, eng, local.as_mut(), par, scratch);
                        post(dd, rng)
                    }
                    None => idn,
                };
                run.h = h.add(&idn).relu();
            }
            _ => {
                let pooled = layers::global_avg_pool(&run.h);
                let mut local = rng_opt(rng);
                let logits = self.fc.forward(&pooled, eng, local.as_mut(), par, scratch);
                let mut logits = post(logits, rng);
                for n in 0..logits.shape[0] {
                    for c in 0..logits.shape[1] {
                        logits.data[n * logits.shape[1] + c] += self.fc_bias[c];
                    }
                }
                run.h = logits;
            }
        }
        run.boundary += 1;
        run.boundary >= self.boundaries()
    }

    /// Argmax classification over [`Self::forward_par`] logits on
    /// [`Self::parallelism`], reusing the caller's scratch pool.
    pub fn classify(
        &self,
        x: &Tensor,
        mode: ForwardMode,
        seed: u64,
        scratch: &mut ScratchPool,
    ) -> Vec<u8> {
        let logits = self.forward_par(x, mode, seed, self.parallelism, scratch);
        logits_to_classes(&logits)
    }
}

/// One admission group's in-flight [`CompiledNet`] execution, advanced a
/// boundary at a time by [`CompiledNet::step`]. This is the continuous-
/// batching seam: the server opens a run per merge group, interleaves
/// `step` calls across co-resident runs, and new groups join between
/// steps instead of waiting for the batch to drain.
#[derive(Clone, Debug)]
pub struct InflightRun {
    /// Activations after the last completed boundary (the input before
    /// the first step; the logits after the final one). Crate-visible so
    /// sibling [`SteppedProgram`] implementations (`pim::attn`) can
    /// construct and advance runs with the same representation.
    pub(crate) h: Tensor,
    /// The group's private RNG stream — forked per layer in exactly the
    /// solo-forward order, so merging never reorders noise draws.
    pub(crate) rng: Pcg64,
    /// Boundaries completed so far.
    pub(crate) boundary: usize,
}

impl InflightRun {
    /// Boundaries completed so far (0 = nothing executed yet).
    pub fn boundary(&self) -> usize {
        self.boundary
    }

    /// Batch rows (images) carried by this run.
    pub fn batch(&self) -> usize {
        self.h.shape[0]
    }

    /// Consume the run and return its logits. Only meaningful once
    /// [`CompiledNet::step`] has returned `true`.
    pub fn into_logits(self) -> Tensor {
        self.h
    }

    /// Fingerprint of the run's private RNG stream position: the next
    /// u64 the stream *would* draw (the stream itself is not advanced).
    /// Two runs with equal logits **and** equal fingerprints consumed
    /// identical noise-draw sequences — the bit-identity witness used by
    /// `rust/tests/shard_parity.rs` to pin sharded pipelined execution
    /// against the unsharded forward.
    pub fn rng_fingerprint(&self) -> u64 {
        self.rng.clone().next_u64()
    }
}

/// Per-row argmax over an `[n, classes]` logits tensor. `total_cmp`
/// ordering: a NaN logit (poisoned input) yields a defined result
/// instead of panicking the serving thread.
pub fn logits_to_classes(logits: &Tensor) -> Vec<u8> {
    let n = logits.shape[0];
    let c = logits.shape[1];
    (0..n)
        .map(|i| {
            let row = &logits.data[i * c..(i + 1) * c];
            row.iter()
                .enumerate()
                .max_by(|a, b| a.1.total_cmp(b.1))
                .unwrap()
                .0 as u8
        })
        .collect()
}

/// Boundary-stepped compiled program: the contract between a compiled
/// workload and the serving layers. Anything implementing it is served
/// unchanged by the continuous-batching executor
/// ([`crate::coordinator::server::NativeExecutor`]) and the pipelined
/// shard executor ([`crate::pim::shard_exec::ShardedExecutor`]) — both
/// are generic over this trait, defaulting to [`CompiledNet`].
///
/// Implementations: [`CompiledNet`] (the CIFAR-10 ResNet family) and
/// [`crate::pim::attn::CompiledTransformer`] (the quantized transformer
/// block family). The contract mirrors the `CompiledNet` inherent API
/// exactly: a run opened by [`Self::begin`] and advanced by
/// [`Self::step`] to [`Self::boundaries`] completions must be
/// bit-identical (logits + trailing RNG state) to a solo
/// [`Self::forward_par`] drain, so merged/pipelined execution can never
/// drift from the reference forward.
pub trait SteppedProgram: Send + Sync {
    /// Number of merge boundaries in one execution; an [`InflightRun`]
    /// is complete once [`Self::step`] has been called this many times.
    fn boundaries(&self) -> usize;

    /// Worker-pool width the program was compiled with (what
    /// [`Self::classify`] and executor defaults run on).
    fn parallelism(&self) -> Parallelism;

    /// Do all layers carry prepared banks (⇒ every mode, including the
    /// hardware-true ones, executes with zero weight preparation)?
    fn fully_prepared(&self) -> bool;

    /// Open an in-flight execution for one admission group, with the
    /// group's own activations and private RNG stream.
    fn begin(&self, x: &Tensor, seed: u64) -> InflightRun;

    /// Advance one in-flight run by a single boundary. Returns `true`
    /// when the run is complete and [`InflightRun::into_logits`] may be
    /// taken.
    fn step(
        &self,
        run: &mut InflightRun,
        mode: ForwardMode,
        par: Parallelism,
        scratch: &mut ScratchPool,
    ) -> bool;

    /// Full drain of [`Self::begin`] / [`Self::step`]: the reference
    /// forward every merged or pipelined schedule is pinned against.
    fn forward_par(
        &self,
        x: &Tensor,
        mode: ForwardMode,
        seed: u64,
        par: Parallelism,
        scratch: &mut ScratchPool,
    ) -> Tensor {
        let mut run = self.begin(x, seed);
        while !self.step(&mut run, mode, par, scratch) {}
        run.into_logits()
    }

    /// Like [`Self::forward_par`] but returns the completed
    /// [`InflightRun`], so callers can also compare the trailing RNG
    /// state via [`InflightRun::rng_fingerprint`].
    fn forward_run(
        &self,
        x: &Tensor,
        mode: ForwardMode,
        seed: u64,
        par: Parallelism,
        scratch: &mut ScratchPool,
    ) -> InflightRun {
        let mut run = self.begin(x, seed);
        while !self.step(&mut run, mode, par, scratch) {}
        run
    }

    /// Argmax classification over [`Self::forward_par`] logits on
    /// [`Self::parallelism`], reusing the caller's scratch pool.
    fn classify(
        &self,
        x: &Tensor,
        mode: ForwardMode,
        seed: u64,
        scratch: &mut ScratchPool,
    ) -> Vec<u8> {
        let logits = self.forward_par(x, mode, seed, self.parallelism(), scratch);
        logits_to_classes(&logits)
    }
}

impl SteppedProgram for CompiledNet {
    fn boundaries(&self) -> usize {
        CompiledNet::boundaries(self)
    }

    fn parallelism(&self) -> Parallelism {
        self.parallelism
    }

    fn fully_prepared(&self) -> bool {
        CompiledNet::fully_prepared(self)
    }

    fn begin(&self, x: &Tensor, seed: u64) -> InflightRun {
        CompiledNet::begin(self, x, seed)
    }

    fn step(
        &self,
        run: &mut InflightRun,
        mode: ForwardMode,
        par: Parallelism,
        scratch: &mut ScratchPool,
    ) -> bool {
        CompiledNet::step(self, run, mode, par, scratch)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nn::resnet::test_params;

    #[test]
    fn pack_is_tile_aligned_and_lossless() {
        let mut rng = Pcg64::seeded(4);
        let (k, n) = (70, 133); // ragged: 2 tiles (128 + 5)
        let bank: Vec<u8> = (0..k * n).map(|_| rng.below(16) as u8).collect();
        let pb = PreparedBank::pack(&bank, k, n);
        assert_eq!((pb.k(), pb.n()), (k, n));
        for ti in 0..n.div_ceil(ARRAY_WORDS) {
            let c0 = ti * ARRAY_WORDS;
            let c1 = (c0 + ARRAY_WORDS).min(n);
            for kk in 0..k {
                let row = pb.row(ti, kk);
                assert_eq!(&row[..c1 - c0], &bank[kk * n + c0..kk * n + c1]);
                assert!(row[c1 - c0..].iter().all(|&b| b == 0), "padding is zero");
            }
        }
    }

    #[test]
    fn pack_builds_consistent_bit_planes() {
        // The transposed bit-plane bitmaps must carry exactly the nibble
        // data, bit for bit, including zero padding in the ragged last
        // k-word and the ragged last tile.
        let mut rng = Pcg64::seeded(16);
        let (k, n) = (200, 133); // ragged: 4 k-words (3 full + 8 bits), 2 tiles
        let bank: Vec<u8> = (0..k * n).map(|_| rng.below(16) as u8).collect();
        let pb = PreparedBank::pack(&bank, k, n);
        assert_eq!(pb.k_words(), k.div_ceil(64));
        for ti in 0..n.div_ceil(ARRAY_WORDS) {
            for b in 0..4usize {
                for kw in 0..pb.k_words() {
                    let row = pb.plane_row(ti, b, kw);
                    assert_eq!(row.len(), ARRAY_WORDS);
                    for (c, &word) in row.iter().enumerate() {
                        for r in 0..64usize {
                            let (kk, j) = (kw * 64 + r, ti * ARRAY_WORDS + c);
                            let want = if kk < k && j < n {
                                (bank[kk * n + j] >> b) & 1
                            } else {
                                0
                            };
                            let got = ((word >> r) & 1) as u8;
                            assert_eq!(got, want, "ti={ti} b={b} kw={kw} c={c} r={r}");
                        }
                    }
                }
            }
        }
    }

    #[test]
    fn plane_any_matches_plane_rows() {
        // Values in {0, 1} only: planes 1..3 are all-zero everywhere, so
        // the precomputed flags must report them skippable, and plane 0
        // flags must track the actual words.
        let mut rng = Pcg64::seeded(23);
        let (k, n) = (200, 133); // ragged k-words and tiles
        let bank: Vec<u8> = (0..k * n).map(|_| rng.below(2) as u8).collect();
        let pb = PreparedBank::pack(&bank, k, n);
        let mut seen_zero = false;
        for ti in 0..n.div_ceil(ARRAY_WORDS) {
            for b in 0..4usize {
                for kw in 0..pb.k_words() {
                    let any = pb.plane_row(ti, b, kw).iter().any(|&w| w != 0);
                    assert_eq!(pb.plane_any(ti, b, kw), any, "ti={ti} b={b} kw={kw}");
                    if b > 0 {
                        assert!(!pb.plane_any(ti, b, kw), "only the LSB plane is populated");
                    }
                    seen_zero |= !any;
                }
            }
        }
        assert!(seen_zero);
    }

    #[test]
    fn steady_state_step_is_mac_alloc_free() {
        // After one warm-up forward the scratch pool's MAC buffers have
        // their high-water capacity; further steady-state forwards must
        // not grow a single counted buffer (the full harness, including
        // noisy modes and width sweeps, is rust/tests/hotpath_parity.rs).
        let net = ResNet::new(test_params(8, 10, 21));
        let program = CompiledNet::compile(&net).unwrap();
        let x = Tensor::from_vec(
            &[1, 16, 16, 3],
            (0..16 * 16 * 3).map(|i| (i % 7) as f32 * 0.1).collect(),
        );
        let mut scratch = ScratchPool::new();
        let _ =
            program.forward_par(&x, ForwardMode::PimHw, 0, Parallelism::serial(), &mut scratch);
        let before = mac_alloc_count();
        for seed in 1..3 {
            let _ = program.forward_par(
                &x,
                ForwardMode::PimHw,
                seed,
                Parallelism::serial(),
                &mut scratch,
            );
        }
        assert_eq!(mac_alloc_count(), before, "steady state must not grow MAC buffers");
    }

    #[test]
    fn prepare_counter_counts_packs_on_this_thread() {
        let before = prepare_count();
        let w = vec![0.5f32; 40 * 6];
        let _pw = PreparedWeights::from_dense(&w, 40, 6);
        assert_eq!(prepare_count(), before + 2, "pos + neg banks");
    }

    #[test]
    fn prepared_weights_mirror_quantize_weights() {
        let mut rng = Pcg64::seeded(8);
        let (k, n) = (50, 9);
        let w: Vec<f32> = (0..k * n).map(|_| rng.range(-1.0, 1.0) as f32).collect();
        let qw = quantize_weights(&w, k, n);
        let pw = PreparedWeights::from_dense(&w, k, n);
        assert_eq!(pw.scale, qw.scale);
        for kk in 0..k {
            for j in 0..n {
                assert_eq!(pw.pos.row(0, kk)[j], qw.pos[kk * n + j]);
                assert_eq!(pw.neg.row(0, kk)[j], qw.neg[kk * n + j]);
            }
        }
    }

    #[test]
    fn engine_matches_spec_bit_for_bit() {
        // The independent straight-line specification vs the packed,
        // tiled, pooled engine — prepared and one-shot alike.
        let mut rng = Pcg64::seeded(77);
        for &(m, k, n) in &[(3usize, 200usize, 133usize), (2, 128, 7), (4, 37, 129)] {
            let a: Vec<f32> = (0..m * k).map(|_| rng.range(0.0, 1.0) as f32).collect();
            let w: Vec<f32> = (0..k * n).map(|_| rng.range(-0.5, 0.5) as f32).collect();
            let spec = spec_matmul(&a, m, k, &w, n);
            let eng = PimEngine::tt();
            let program = eng.prepare(&w, k, n);
            for t in [1usize, 3] {
                let got = eng.par_matmul_prepared(&a, m, &program, None, Parallelism::threads(t));
                assert_eq!(spec, got, "m={m} k={k} n={n} t={t}");
            }
            assert_eq!(spec, eng.pim_matmul(&a, m, k, &w, n, None), "one-shot {m}x{k}x{n}");
        }
    }

    #[test]
    fn compiled_net_shape_and_preparedness() {
        let net = ResNet::new(test_params(8, 10, 1));
        let full = CompiledNet::compile(&net).unwrap();
        assert!(full.fully_prepared());
        assert_eq!(full.blocks.len(), STAGES.iter().sum::<usize>());
        // ResNet-18 at width 8: stem + 8 blocks × 2 convs + 3 downsamples
        // (s1b0, s2b0, s3b0) + fc = 21.
        assert_eq!(full.layer_count(), 21);
        let dense = CompiledNet::compile_dense(&net).unwrap();
        assert!(!dense.fully_prepared());
        assert_eq!(dense.layer_count(), full.layer_count());
    }

    #[test]
    fn prepare_banks_upgrades_dense_compile() {
        let net = ResNet::new(test_params(8, 10, 13));
        let dense = CompiledNet::compile_dense(&net).unwrap();
        assert!(!dense.fully_prepared());
        let upgraded = dense.prepare_banks();
        assert!(upgraded.fully_prepared());
        let full = CompiledNet::compile(&net).unwrap();
        let mut rng = Pcg64::seeded(14);
        let x = Tensor::from_vec(
            &[1, 16, 16, 3],
            (0..16 * 16 * 3).map(|_| rng.f64() as f32).collect(),
        );
        for mode in [ForwardMode::Baseline, ForwardMode::PimHw, ForwardMode::PimHwNoise(0.3)] {
            assert_eq!(
                full.forward(&x, mode, 2).data,
                upgraded.forward(&x, mode, 2).data,
                "{mode:?}"
            );
        }
        // Upgrading an already-full program is a plain clone: no packs.
        let before = prepare_count();
        let again = full.prepare_banks();
        assert_eq!(prepare_count(), before);
        assert!(again.fully_prepared());
    }

    #[test]
    fn compiled_forward_matches_uncompiled_all_modes() {
        let net = ResNet::new(test_params(8, 10, 3));
        let program = CompiledNet::compile(&net).unwrap();
        let mut rng = Pcg64::seeded(5);
        let x = Tensor::from_vec(
            &[2, 16, 16, 3],
            (0..2 * 16 * 16 * 3).map(|_| rng.f64() as f32).collect(),
        );
        for mode in [
            ForwardMode::Baseline,
            ForwardMode::Pim,
            ForwardMode::PimNoise(0.3),
            ForwardMode::PimHw,
            ForwardMode::PimHwNoise(0.3),
        ] {
            let want = net.forward(&x, mode, 9).unwrap();
            let got = program.forward(&x, mode, 9);
            assert_eq!(want.data, got.data, "{mode:?}");
        }
    }

    #[test]
    fn compiled_forward_is_prepare_free() {
        let net = ResNet::new(test_params(8, 10, 7));
        let program = CompiledNet::compile(&net).unwrap();
        let mut rng = Pcg64::seeded(6);
        let x = Tensor::from_vec(
            &[1, 16, 16, 3],
            (0..16 * 16 * 3).map(|_| rng.f64() as f32).collect(),
        );
        let mut scratch = ScratchPool::new();
        let before = prepare_count();
        for seed in 0..3 {
            let _ = program.forward_par(
                &x,
                ForwardMode::PimHw,
                seed,
                Parallelism::serial(),
                &mut scratch,
            );
        }
        assert_eq!(prepare_count(), before, "steady state must not prepare");
    }

    #[test]
    fn scratch_pool_reuse_is_transparent() {
        let net = ResNet::new(test_params(8, 10, 11));
        let program = CompiledNet::compile(&net).unwrap();
        let mut rng = Pcg64::seeded(12);
        let x = Tensor::from_vec(
            &[2, 16, 16, 3],
            (0..2 * 16 * 16 * 3).map(|_| rng.f64() as f32).collect(),
        );
        let fresh = program.forward(&x, ForwardMode::PimHwNoise(0.4), 3);
        let mut pool = ScratchPool::new();
        // Dirty the pool with a different mode/input first.
        let _ = program.forward_par(
            &x,
            ForwardMode::Baseline,
            0,
            Parallelism::serial(),
            &mut pool,
        );
        let reused = program.forward_par(
            &x,
            ForwardMode::PimHwNoise(0.4),
            3,
            Parallelism::serial(),
            &mut pool,
        );
        assert_eq!(fresh.data, reused.data);
    }
}
