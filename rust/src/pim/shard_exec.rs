//! Pipelined execution of one compiled program ([`SteppedProgram`]; a
//! [`CompiledNet`] by default, or a transformer via
//! [`crate::pim::attn::CompiledTransformer`]) split into boundary
//! segments across cache slices (the `pim`-side half of `fleet::shard`).
//!
//! A shard is a *residence* concept: shard K owns the prepared weight
//! banks for a contiguous range of merge boundaries (stem, residual
//! blocks, head — see [`CompiledNet::boundaries`]), living on its own
//! slice. Execution-wise, nothing new is needed beyond the PR 7 stepped
//! API: an [`InflightRun`] carries its *own* activations and its *own*
//! RNG stream (forked per layer in solo-forward order), so a run handed
//! from shard K−1 to shard K and interleaved with other micro-batches
//! draws exactly the noise stream a solo [`CompiledNet::forward_par`]
//! would have drawn. Bit-identity of the sharded pipeline is therefore
//! by construction, and `rust/tests/shard_parity.rs` pins it (outputs
//! *and* trailing RNG state) across shard counts and thread counts.
//!
//! [`ShardedExecutor::forward_pipelined`] runs the classic software
//! pipeline: on tick t, shard K executes micro-batch t−K while shard
//! K−1 executes micro-batch t−K+1. The returned [`PipelineTrace`]
//! records which (shard, micro-batch) pairs ran concurrently on each
//! tick — the witness that overlap actually happened (fill for the
//! first `shards−1` ticks, steady state at `shards` concurrent
//! segments, drain at the tail).
//!
//! The analytic cost side (what a hop between slices costs, where the
//! cut should fall, replica- vs shard-parallel placement) lives in
//! `fleet::shard`; this module is purely the numerics-preserving
//! executor. Its segment steps run on the persistent `pim::parallel`
//! pool like every other execution path, so pipelining adds no per-tick
//! thread spawns (PERFORMANCE.md §12).

use crate::nn::{ForwardMode, Tensor};
use crate::{Error, Result};

use super::parallel::Parallelism;
use super::program::{CompiledNet, InflightRun, ScratchPool, SteppedProgram};

/// One entry of a [`PipelineTrace`] tick: `(shard, micro_batch)` ran.
pub type TraceEntry = (usize, usize);

/// Record of which segments executed on which pipeline tick.
#[derive(Clone, Debug, Default)]
pub struct PipelineTrace {
    /// Per tick, the `(shard, micro_batch)` segments that executed, in
    /// ascending shard order.
    pub ticks: Vec<Vec<TraceEntry>>,
    /// Largest number of shards busy on a single tick (equals the shard
    /// count once the pipeline reaches steady state).
    pub max_concurrent: usize,
}

impl PipelineTrace {
    /// Total ticks the pipeline ran (fill + steady state + drain). For
    /// `m` micro-batches over `s` shards this is `m + s − 1` — versus
    /// `m · s` segment-times for unpipelined sequential execution.
    pub fn len(&self) -> usize {
        self.ticks.len()
    }

    /// True when no tick was recorded (no inputs).
    pub fn is_empty(&self) -> bool {
        self.ticks.is_empty()
    }
}

/// Drives per-shard [`SteppedProgram::begin`]/[`SteppedProgram::step`]
/// segments of one compiled program — any [`SteppedProgram`]
/// (a [`CompiledNet`] by default, or a
/// [`crate::pim::attn::CompiledTransformer`]) — either one segment at a
/// time ([`ShardedExecutor::step_segment`], the building block the
/// fleet's live serving path uses per slice) or as a full software
/// pipeline over a stream of micro-batches
/// ([`ShardedExecutor::forward_pipelined`]).
#[derive(Clone, Debug)]
pub struct ShardedExecutor<'a, P: SteppedProgram = CompiledNet> {
    net: &'a P,
    /// Boundary indices where a new shard begins; strictly increasing,
    /// each in `1..boundaries()`. `cuts.len() + 1` shards.
    cuts: Vec<usize>,
}

impl<'a, P: SteppedProgram> ShardedExecutor<'a, P> {
    /// Executor over explicit cut points. `cuts[i]` is the first
    /// boundary owned by shard `i+1`; an empty list is the degenerate
    /// single-shard executor (useful as a pipeline-harness baseline).
    pub fn new(net: &'a P, cuts: &[usize]) -> Result<ShardedExecutor<'a, P>> {
        let b = net.boundaries();
        for (i, &c) in cuts.iter().enumerate() {
            if c == 0 || c >= b {
                return Err(Error::Config(format!(
                    "shard cut {c} outside 1..{b} (network has {b} boundaries)"
                )));
            }
            if i > 0 && cuts[i - 1] >= c {
                return Err(Error::Config(format!(
                    "shard cuts must be strictly increasing (got {} then {c})",
                    cuts[i - 1]
                )));
            }
        }
        Ok(ShardedExecutor { net, cuts: cuts.to_vec() })
    }

    /// Executor with `n_shards` near-equal boundary segments (the last
    /// shard absorbs the remainder). Errors when the network has fewer
    /// boundaries than shards.
    pub fn balanced(net: &'a P, n_shards: usize) -> Result<ShardedExecutor<'a, P>> {
        let b = net.boundaries();
        if n_shards == 0 || n_shards > b {
            return Err(Error::Config(format!(
                "cannot split {b} boundaries into {n_shards} shards"
            )));
        }
        let cuts: Vec<usize> = (1..n_shards).map(|k| k * b / n_shards).collect();
        Self::new(net, &cuts)
    }

    /// The compiled program this executor shards.
    pub fn net(&self) -> &P {
        self.net
    }

    /// Number of shards (segments).
    pub fn shards(&self) -> usize {
        self.cuts.len() + 1
    }

    /// Half-open boundary range `[start, end)` owned by shard `k`.
    pub fn segment(&self, k: usize) -> (usize, usize) {
        assert!(k < self.shards(), "shard {k} out of range");
        let start = if k == 0 { 0 } else { self.cuts[k - 1] };
        let end = if k == self.cuts.len() { self.net.boundaries() } else { self.cuts[k] };
        (start, end)
    }

    /// Advance `run` through every boundary shard `k` owns. The run must
    /// arrive exactly at the shard's first boundary (runs flow through
    /// the chain in order); returns `true` when the whole network is
    /// complete and [`InflightRun::into_logits`] may be taken.
    pub fn step_segment(
        &self,
        k: usize,
        run: &mut InflightRun,
        mode: ForwardMode,
        par: Parallelism,
        scratch: &mut ScratchPool,
    ) -> bool {
        let (start, end) = self.segment(k);
        assert_eq!(
            run.boundary(),
            start,
            "micro-batch arrived at shard {k} with boundary {} (expected {start})",
            run.boundary()
        );
        let mut finished = false;
        while run.boundary() < end {
            finished = self.net.step(run, mode, par, scratch);
        }
        finished
    }

    /// Software-pipelined forward over a stream of `(input, seed)`
    /// micro-batches: on each tick every occupied shard advances its
    /// resident micro-batch one segment and hands it downstream, and a
    /// new micro-batch is admitted into shard 0 — so shard K runs
    /// micro-batch i while shard K−1 runs micro-batch i+1. Completed
    /// runs are returned in input order, each bit-identical (logits and
    /// RNG stream) to a solo `forward_par(x_i, mode, seed_i, …)`.
    pub fn forward_pipelined(
        &self,
        inputs: &[(Tensor, u64)],
        mode: ForwardMode,
        par: Parallelism,
        scratch: &mut ScratchPool,
    ) -> (Vec<InflightRun>, PipelineTrace) {
        let n_shards = self.shards();
        let mut slots: Vec<Option<(usize, InflightRun)>> = vec![None; n_shards];
        let mut done: Vec<Option<InflightRun>> = (0..inputs.len()).map(|_| None).collect();
        let mut next_in = 0;
        let mut trace = PipelineTrace::default();
        loop {
            // Admit the next micro-batch into the (free) head shard.
            if next_in < inputs.len() && slots[0].is_none() {
                let (x, seed) = &inputs[next_in];
                slots[0] = Some((next_in, self.net.begin(x, *seed)));
                next_in += 1;
            }
            if slots.iter().all(Option::is_none) {
                break;
            }
            // One tick: advance every occupied shard. Walking shards in
            // reverse drains downstream slots before upstream runs move
            // into them, so each run advances exactly one segment per
            // tick.
            let mut tick: Vec<TraceEntry> = Vec::new();
            for k in (0..n_shards).rev() {
                if let Some((idx, mut run)) = slots[k].take() {
                    let finished = self.step_segment(k, &mut run, mode, par, scratch);
                    tick.push((k, idx));
                    if finished {
                        done[idx] = Some(run);
                    } else {
                        slots[k + 1] = Some((idx, run));
                    }
                }
            }
            tick.reverse();
            trace.max_concurrent = trace.max_concurrent.max(tick.len());
            trace.ticks.push(tick);
        }
        let runs = done
            .into_iter()
            .map(|r| r.expect("pipeline drained: every admitted micro-batch completed"))
            .collect();
        (runs, trace)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nn::resnet::test_params;
    use crate::nn::ResNet;
    use crate::util::rng::Pcg64;

    fn tiny_net() -> CompiledNet {
        ResNet::new(test_params(8, 10, 3)).compile().unwrap()
    }

    fn rand_input(rng: &mut Pcg64, n: usize) -> Tensor {
        Tensor::from_vec(
            &[n, 16, 16, 3],
            (0..n * 16 * 16 * 3).map(|_| rng.f64() as f32).collect(),
        )
    }

    #[test]
    fn segments_tile_the_boundary_range() {
        let net = tiny_net();
        let b = net.boundaries();
        for shards in 1..=b {
            let ex = ShardedExecutor::balanced(&net, shards).unwrap();
            assert_eq!(ex.shards(), shards);
            let mut expect_start = 0;
            for k in 0..shards {
                let (s, e) = ex.segment(k);
                assert_eq!(s, expect_start);
                assert!(e > s, "shard {k} empty");
                expect_start = e;
            }
            assert_eq!(expect_start, b);
        }
    }

    #[test]
    fn invalid_cuts_rejected() {
        let net = tiny_net();
        let b = net.boundaries();
        assert!(ShardedExecutor::new(&net, &[0]).is_err());
        assert!(ShardedExecutor::new(&net, &[b]).is_err());
        assert!(ShardedExecutor::new(&net, &[2, 2]).is_err());
        assert!(ShardedExecutor::new(&net, &[3, 1]).is_err());
        assert!(ShardedExecutor::balanced(&net, 0).is_err());
        assert!(ShardedExecutor::balanced(&net, b + 1).is_err());
        assert!(ShardedExecutor::new(&net, &[]).is_ok());
    }

    #[test]
    fn pipeline_overlaps_and_matches_solo_forward() {
        let net = tiny_net();
        let ex = ShardedExecutor::balanced(&net, 2).unwrap();
        let mut rng = Pcg64::seeded(77);
        let inputs: Vec<(Tensor, u64)> =
            (0..4).map(|i| (rand_input(&mut rng, 1 + (i % 2)), 900 + i as u64)).collect();
        let par = Parallelism::threads(1);
        let mut scratch = ScratchPool::new();
        let (runs, trace) =
            ex.forward_pipelined(&inputs, ForwardMode::PimHwNoise(0.4), par, &mut scratch);
        // Steady state reached: both shards busy on some tick, and the
        // tick count is m + s − 1.
        assert_eq!(trace.max_concurrent, 2);
        assert_eq!(trace.len(), inputs.len() + ex.shards() - 1);
        for (i, ((x, seed), run)) in inputs.iter().zip(runs).enumerate() {
            let solo =
                net.forward_run(x, ForwardMode::PimHwNoise(0.4), *seed, par, &mut scratch);
            assert_eq!(run.rng_fingerprint(), solo.rng_fingerprint(), "rng diverged at {i}");
            let (a, b) = (run.into_logits(), solo.into_logits());
            assert_eq!(a.shape, b.shape);
            let eq = a.data.iter().zip(b.data.iter()).all(|(x, y)| x.to_bits() == y.to_bits());
            assert!(eq, "logits diverged at micro-batch {i}");
        }
    }
}
