//! Tiled parallel execution for the PIM hot path: a hand-rolled,
//! dependency-free worker pool (std::thread + mpsc — the same offline-build
//! constraint as `coordinator/server.rs`; rayon is unavailable).
//!
//! The engine's bank MAC factors into data-independent *units* — one per
//! (output row × 128-row block × 128-word output tile); the four activation
//! bit-planes of a unit ride together inside its packed u64 accumulator
//! (EXPERIMENTS.md §Perf). Units execute on the pool in whatever order the
//! workers grab them; the digital shift-add reduce then folds the per-unit
//! partials back in *deterministic unit order*, and every unit derives its
//! own [`crate::util::rng::Pcg64`] noise stream from its index, so the
//! result is bit-identical to the serial engine at any thread count
//! (pinned by `rust/tests/parallel_parity.rs`).

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::mpsc;

/// Worker-pool width for tiled PIM execution.
///
/// Serial by default, so every existing call path is unchanged until a
/// caller opts in (`repro bench --threads N`, `StubRuntime`'s
/// [`crate::runtime::Runtime::set_parallelism`], `fleet-sim --threads`).
///
/// # Examples
///
/// ```
/// use nvm_in_cache::pim::parallel::Parallelism;
///
/// assert_eq!(Parallelism::default().thread_count(), 1);
/// assert_eq!(Parallelism::threads(4).thread_count(), 4);
/// assert_eq!(Parallelism::threads(0).thread_count(), 1, "clamped to ≥1");
/// assert!(Parallelism::auto().thread_count() >= 1);
/// ```
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Parallelism {
    threads: usize,
}

impl Parallelism {
    /// Single-threaded execution (the default: identical to the historical
    /// serial engine in both results and scheduling).
    pub fn serial() -> Parallelism {
        Parallelism { threads: 1 }
    }

    /// Exactly `n` worker threads (clamped to ≥ 1).
    pub fn threads(n: usize) -> Parallelism {
        Parallelism { threads: n.max(1) }
    }

    /// One worker per available hardware thread.
    pub fn auto() -> Parallelism {
        Parallelism::threads(
            std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1),
        )
    }

    /// Configured worker count (≥ 1).
    pub fn thread_count(&self) -> usize {
        self.threads
    }

    /// Is this the serial configuration?
    pub fn is_serial(&self) -> bool {
        self.threads == 1
    }
}

impl Default for Parallelism {
    fn default() -> Parallelism {
        Parallelism::serial()
    }
}

/// Execute `f(0), f(1), …, f(n_units − 1)` on a pool of `threads` workers
/// and return the results **in unit order** (so any reduction over them is
/// deterministic regardless of which worker ran which unit).
///
/// Work is distributed dynamically through a shared atomic cursor; results
/// travel back over an mpsc channel. With `threads ≤ 1` (or a single unit)
/// the closure runs inline on the caller's thread — no pool, no overhead.
///
/// A panic inside `f` propagates to the caller when the scope joins.
///
/// # Examples
///
/// ```
/// use nvm_in_cache::pim::parallel::run_units;
///
/// let squares = run_units(4, 10, |u| u * u);
/// assert_eq!(squares, (0..10).map(|u| u * u).collect::<Vec<_>>());
/// ```
pub fn run_units<T, F>(threads: usize, n_units: usize, f: F) -> Vec<T>
where
    T: Send,
    F: Fn(usize) -> T + Sync,
{
    if threads <= 1 || n_units <= 1 {
        return (0..n_units).map(f).collect();
    }
    let next = AtomicUsize::new(0);
    let (tx, rx) = mpsc::channel::<(usize, T)>();
    let mut slots: Vec<Option<T>> = Vec::with_capacity(n_units);
    slots.resize_with(n_units, || None);
    std::thread::scope(|s| {
        for _ in 0..threads.min(n_units) {
            let tx = tx.clone();
            let next = &next;
            let f = &f;
            s.spawn(move || loop {
                let u = next.fetch_add(1, Ordering::Relaxed);
                if u >= n_units {
                    break;
                }
                if tx.send((u, f(u))).is_err() {
                    break;
                }
            });
        }
        drop(tx);
        for (u, value) in rx {
            slots[u] = Some(value);
        }
    });
    slots
        .into_iter()
        .map(|v| v.expect("every unit completed"))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn serial_and_parallel_agree() {
        let serial = run_units(1, 37, |u| u as u64 * 3 + 1);
        for t in [2, 3, 7, 16] {
            assert_eq!(run_units(t, 37, |u| u as u64 * 3 + 1), serial, "t={t}");
        }
    }

    #[test]
    fn results_are_in_unit_order() {
        // Make late units cheap and early units slow so completion order
        // inverts submission order — the output must still be by index.
        let out = run_units(4, 12, |u| {
            if u < 4 {
                std::thread::sleep(std::time::Duration::from_millis(3));
            }
            u
        });
        assert_eq!(out, (0..12).collect::<Vec<_>>());
    }

    #[test]
    fn more_threads_than_units() {
        assert_eq!(run_units(16, 3, |u| u + 1), vec![1, 2, 3]);
    }

    #[test]
    fn zero_units() {
        assert!(run_units(4, 0, |u| u).is_empty());
        assert!(run_units(1, 0, |u| u).is_empty());
    }

    #[test]
    fn parallelism_constructors() {
        assert!(Parallelism::serial().is_serial());
        assert!(!Parallelism::threads(3).is_serial());
        assert_eq!(Parallelism::threads(3).thread_count(), 3);
        assert!(Parallelism::auto().thread_count() >= 1);
        assert_eq!(Parallelism::default(), Parallelism::serial());
    }
}
