//! Tiled parallel execution for the PIM hot path: a hand-rolled,
//! dependency-free **persistent worker pool** (std::thread + condvar — the
//! same offline-build constraint as `coordinator/server.rs`; rayon is
//! unavailable).
//!
//! The engine's bank MAC factors into data-independent *units* — one per
//! (output row × 128-row block × 128-word output tile); the four activation
//! bit-planes of a unit ride together inside its packed u64 accumulator
//! (EXPERIMENTS.md §Perf). Units execute on the pool in whatever order the
//! workers grab them; the digital shift-add reduce then folds the per-unit
//! partials back in *deterministic unit order*, and every unit derives its
//! own [`crate::util::rng::Pcg64`] noise stream from its index, so the
//! result is bit-identical to the serial engine at any thread count
//! (pinned by `rust/tests/parallel_parity.rs` and
//! `rust/tests/hotpath_parity.rs`).
//!
//! # Pool lifecycle (PERFORMANCE.md §12)
//!
//! Workers are spawned **once per pool width**, lazily, on the first
//! [`for_units`]/[`run_units`] call at that width, and then parked on a
//! condvar between jobs — steady-state serving performs **zero** thread
//! spawns (the `pool_spawns_once` bench gate; [`pool_spawned_for`]).
//! Jobs from concurrent callers queue FIFO and drain through the same
//! atomic-cursor unit distribution the per-call-spawn implementation
//! used, so scheduling is work-stealing-free and results are unchanged.
//! The historical spawn-per-call path survives as [`run_units_unpooled`]
//! — the differential baseline the pooled path is raced against, and the
//! spawn-amortization comparand in `repro bench`.

use std::collections::VecDeque;
use std::panic::{catch_unwind, resume_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::{mpsc, Arc, Condvar, Mutex, OnceLock};

/// Worker-pool width for tiled PIM execution.
///
/// Serial by default, so every existing call path is unchanged until a
/// caller opts in (`repro bench --threads N`, `StubRuntime`'s
/// [`crate::runtime::Runtime::set_parallelism`], `fleet-sim --threads`).
/// The CLI maps `--threads 0` to [`Parallelism::auto`].
///
/// # Examples
///
/// ```
/// use nvm_in_cache::pim::parallel::Parallelism;
///
/// assert_eq!(Parallelism::default().thread_count(), 1);
/// assert_eq!(Parallelism::threads(4).thread_count(), 4);
/// assert_eq!(Parallelism::threads(0).thread_count(), 1, "clamped to ≥1");
/// assert!(Parallelism::auto().thread_count() >= 1);
/// ```
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Parallelism {
    threads: usize,
}

impl Parallelism {
    /// Single-threaded execution (the default: identical to the historical
    /// serial engine in both results and scheduling).
    pub fn serial() -> Parallelism {
        Parallelism { threads: 1 }
    }

    /// Exactly `n` worker threads (clamped to ≥ 1).
    pub fn threads(n: usize) -> Parallelism {
        Parallelism { threads: n.max(1) }
    }

    /// One worker per available hardware thread.
    pub fn auto() -> Parallelism {
        Parallelism::threads(
            std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1),
        )
    }

    /// Configured worker count (≥ 1).
    pub fn thread_count(&self) -> usize {
        self.threads
    }

    /// Is this the serial configuration?
    pub fn is_serial(&self) -> bool {
        self.threads == 1
    }
}

impl Default for Parallelism {
    fn default() -> Parallelism {
        Parallelism::serial()
    }
}

/// Type-erased pointer to a caller's `Fn(usize) + Sync` task closure.
///
/// A raw pointer (not a reference) because a retired [`Job`] may linger in
/// the queue briefly after its caller returns; it is never dereferenced
/// then — workers only call through it for claimed units `u < n_units`,
/// and the caller blocks until all of them have finished.
struct RawTask(*const (dyn Fn(usize) + Sync));

// SAFETY: the pointee is `Sync` (shared-callable from any thread), and
// `for_units` guarantees it outlives every dereference (see above).
unsafe impl Send for RawTask {}
unsafe impl Sync for RawTask {}

/// One submitted unit batch: the task, the atomic claim cursor, and the
/// completion rendezvous back to the caller.
struct Job {
    task: RawTask,
    n_units: usize,
    /// Next unclaimed unit index (the dynamic distribution cursor — the
    /// same scheme the historical spawn-per-call path used).
    cursor: AtomicUsize,
    /// Units fully executed. The release/acquire increment chain is what
    /// publishes the workers' result writes to the caller.
    done: AtomicUsize,
    finished: Mutex<bool>,
    finished_cv: Condvar,
    /// First captured worker panic, re-raised on the caller.
    panic: Mutex<Option<Box<dyn std::any::Any + Send + 'static>>>,
}

/// State shared by one pool's parked workers: the FIFO job queue and the
/// wake signal. Lives for the process (workers are detached and never
/// exit), so an `Arc` held by the registry and every worker suffices.
struct PoolShared {
    queue: Mutex<VecDeque<Arc<Job>>>,
    work_cv: Condvar,
    /// Threads ever spawned for this pool — stays equal to the width for
    /// the life of the process (the spawn-once contract).
    spawned: AtomicU64,
}

/// Pool registry: one persistent pool per distinct width ever requested.
static REGISTRY: OnceLock<Mutex<Vec<(usize, Arc<PoolShared>)>>> = OnceLock::new();

/// The persistent worker body: park on the condvar until a job is queued,
/// claim units off its atomic cursor, signal the caller when the last
/// unit completes, retire the job, repeat forever.
fn worker_loop(shared: Arc<PoolShared>) {
    loop {
        let job: Arc<Job> = {
            let mut q = shared.queue.lock().unwrap();
            loop {
                if let Some(front) = q.front() {
                    break Arc::clone(front);
                }
                q = shared.work_cv.wait(q).unwrap();
            }
        };
        loop {
            let u = job.cursor.fetch_add(1, Ordering::Relaxed);
            if u >= job.n_units {
                // Every unit is claimed: retire the job (first worker to
                // get here does it) so idle workers park on the condvar
                // instead of re-claiming a spent job.
                let mut q = shared.queue.lock().unwrap();
                if q.front().is_some_and(|f| Arc::ptr_eq(f, &job)) {
                    q.pop_front();
                }
                break;
            }
            // SAFETY: `u < n_units`, so the caller is still blocked in
            // `for_units` and the closure is alive (RawTask contract).
            let task = unsafe { &*job.task.0 };
            // A panicking unit must neither kill this pool worker nor
            // hang the caller: capture it, keep counting completions,
            // and re-raise it on the caller after the job drains.
            if let Err(p) = catch_unwind(AssertUnwindSafe(|| task(u))) {
                *job.panic.lock().unwrap() = Some(p);
            }
            if job.done.fetch_add(1, Ordering::AcqRel) + 1 == job.n_units {
                *job.finished.lock().unwrap() = true;
                job.finished_cv.notify_all();
            }
        }
    }
}

/// The persistent pool for `width` workers, spawning it on first use.
/// Subsequent calls at the same width reuse the parked workers — the
/// steady-state serving path performs zero spawns.
fn pool_for(width: usize) -> Arc<PoolShared> {
    let reg = REGISTRY.get_or_init(|| Mutex::new(Vec::new()));
    let mut pools = reg.lock().unwrap();
    if let Some((_, shared)) = pools.iter().find(|(w, _)| *w == width) {
        return Arc::clone(shared);
    }
    let shared = Arc::new(PoolShared {
        queue: Mutex::new(VecDeque::new()),
        work_cv: Condvar::new(),
        spawned: AtomicU64::new(0),
    });
    for i in 0..width {
        let s = Arc::clone(&shared);
        std::thread::Builder::new()
            .name(format!("pim-pool-{width}-{i}"))
            .spawn(move || worker_loop(s))
            .expect("spawn pim pool worker");
        shared.spawned.fetch_add(1, Ordering::Relaxed);
    }
    pools.push((width, Arc::clone(&shared)));
    shared
}

/// Threads ever spawned for the width-`width` pool (0 if that pool was
/// never created). Equal to `width` from first use onward — the
/// spawn-once observable asserted by `rust/tests/hotpath_parity.rs` and
/// the `pool_spawns_once` bench gate.
pub fn pool_spawned_for(width: usize) -> u64 {
    REGISTRY
        .get()
        .and_then(|reg| {
            reg.lock()
                .unwrap()
                .iter()
                .find(|(w, _)| *w == width)
                .map(|(_, s)| s.spawned.load(Ordering::Relaxed))
        })
        .unwrap_or(0)
}

/// Total pool threads ever spawned, across all widths (Σ of
/// [`pool_spawned_for`] over the pools that exist).
pub fn pool_spawn_count() -> u64 {
    REGISTRY
        .get()
        .map(|reg| {
            reg.lock().unwrap().iter().map(|(_, s)| s.spawned.load(Ordering::Relaxed)).sum()
        })
        .unwrap_or(0)
}

/// Execute `f(0), f(1), …, f(n_units − 1)` on the persistent pool of
/// `threads` workers, returning when every unit has run. No results are
/// collected — the callee writes wherever it likes (the engine writes
/// each unit group's disjoint output slice in place); use [`run_units`]
/// when per-unit return values are wanted.
///
/// Work is distributed dynamically through a shared atomic cursor, so
/// scheduling is identical to the historical spawn-per-call pool. With
/// `threads ≤ 1` (or ≤ 1 unit) the closure runs inline on the caller's
/// thread — no pool, no synchronization. A panic inside `f` propagates
/// to the caller after the batch drains; the pool survives.
///
/// Nested submission (calling `for_units` from inside a pooled unit) is
/// not supported — the engine's units never re-enter the pool.
pub fn for_units<F>(threads: usize, n_units: usize, f: F)
where
    F: Fn(usize) + Sync,
{
    if threads <= 1 || n_units <= 1 {
        for u in 0..n_units {
            f(u);
        }
        return;
    }
    let shared = pool_for(threads);
    let obj: &(dyn Fn(usize) + Sync) = &f;
    // SAFETY: pure lifetime erasure. This frame blocks until
    // `done == n_units`; a worker increments `done` only after its
    // `task(u)` call returns and claims stop once the cursor passes
    // `n_units`, so every dereference happens while `f` is alive. The
    // raw pointer may linger in a retired job after this returns but is
    // never dereferenced again (see [`RawTask`]).
    let task = RawTask(unsafe {
        std::mem::transmute::<&(dyn Fn(usize) + Sync), *const (dyn Fn(usize) + Sync)>(obj)
    });
    let job = Arc::new(Job {
        task,
        n_units,
        cursor: AtomicUsize::new(0),
        done: AtomicUsize::new(0),
        finished: Mutex::new(false),
        finished_cv: Condvar::new(),
        panic: Mutex::new(None),
    });
    {
        let mut q = shared.queue.lock().unwrap();
        q.push_back(Arc::clone(&job));
    }
    shared.work_cv.notify_all();
    let mut fin = job.finished.lock().unwrap();
    while !*fin {
        fin = job.finished_cv.wait(fin).unwrap();
    }
    drop(fin);
    if let Some(p) = job.panic.lock().unwrap().take() {
        resume_unwind(p);
    }
}

/// Execute `f(0), f(1), …, f(n_units − 1)` on the persistent pool of
/// `threads` workers and return the results **in unit order** (so any
/// reduction over them is deterministic regardless of which worker ran
/// which unit).
///
/// Built on [`for_units`]: each unit writes its own pre-sized slot, so
/// the only allocation is the result vector itself. With `threads ≤ 1`
/// (or a single unit) the closure runs inline on the caller's thread.
///
/// A panic inside `f` propagates to the caller when the batch drains.
///
/// # Examples
///
/// ```
/// use nvm_in_cache::pim::parallel::run_units;
///
/// let squares = run_units(4, 10, |u| u * u);
/// assert_eq!(squares, (0..10).map(|u| u * u).collect::<Vec<_>>());
/// ```
pub fn run_units<T, F>(threads: usize, n_units: usize, f: F) -> Vec<T>
where
    T: Send,
    F: Fn(usize) -> T + Sync,
{
    if threads <= 1 || n_units <= 1 {
        return (0..n_units).map(f).collect();
    }
    struct Slot<T>(std::cell::UnsafeCell<Option<T>>);
    // SAFETY: each unit index is claimed by exactly one worker (atomic
    // cursor), so slot `u` is written exactly once, with no concurrent
    // reader; the `done` release/acquire chain publishes the writes
    // before `for_units` returns.
    unsafe impl<T: Send> Sync for Slot<T> {}
    let mut slots: Vec<Slot<T>> = Vec::with_capacity(n_units);
    slots.resize_with(n_units, || Slot(std::cell::UnsafeCell::new(None)));
    for_units(threads, n_units, |u| {
        // SAFETY: exclusive writer of slot `u` (see Slot).
        unsafe { *slots[u].0.get() = Some(f(u)) };
    });
    slots
        .into_iter()
        .map(|s| s.0.into_inner().expect("every unit completed"))
        .collect()
}

/// The historical spawn-per-call implementation of [`run_units`]: scoped
/// threads + an mpsc result channel, joined before returning.
///
/// Kept alive as the **differential baseline** for the persistent pool —
/// `rust/tests/hotpath_parity.rs` races the two on identical inputs, and
/// `repro bench` measures the spawn/join overhead the pool amortizes away
/// (PERFORMANCE.md §12). Not used by any production path.
pub fn run_units_unpooled<T, F>(threads: usize, n_units: usize, f: F) -> Vec<T>
where
    T: Send,
    F: Fn(usize) -> T + Sync,
{
    if threads <= 1 || n_units <= 1 {
        return (0..n_units).map(f).collect();
    }
    let next = AtomicUsize::new(0);
    let (tx, rx) = mpsc::channel::<(usize, T)>();
    let mut slots: Vec<Option<T>> = Vec::with_capacity(n_units);
    slots.resize_with(n_units, || None);
    std::thread::scope(|s| {
        for _ in 0..threads.min(n_units) {
            let tx = tx.clone();
            let next = &next;
            let f = &f;
            s.spawn(move || loop {
                let u = next.fetch_add(1, Ordering::Relaxed);
                if u >= n_units {
                    break;
                }
                if tx.send((u, f(u))).is_err() {
                    break;
                }
            });
        }
        drop(tx);
        for (u, value) in rx {
            slots[u] = Some(value);
        }
    });
    slots
        .into_iter()
        .map(|v| v.expect("every unit completed"))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn serial_and_parallel_agree() {
        let serial = run_units(1, 37, |u| u as u64 * 3 + 1);
        for t in [2, 3, 7, 16] {
            assert_eq!(run_units(t, 37, |u| u as u64 * 3 + 1), serial, "t={t}");
        }
    }

    #[test]
    fn pooled_matches_unpooled_baseline() {
        for t in [2usize, 3, 7] {
            assert_eq!(
                run_units(t, 41, |u| (u * u) as u64),
                run_units_unpooled(t, 41, |u| (u * u) as u64),
                "t={t}"
            );
        }
    }

    #[test]
    fn results_are_in_unit_order() {
        // Make late units cheap and early units slow so completion order
        // inverts submission order — the output must still be by index.
        let out = run_units(4, 12, |u| {
            if u < 4 {
                std::thread::sleep(std::time::Duration::from_millis(3));
            }
            u
        });
        assert_eq!(out, (0..12).collect::<Vec<_>>());
    }

    #[test]
    fn more_threads_than_units() {
        assert_eq!(run_units(16, 3, |u| u + 1), vec![1, 2, 3]);
    }

    #[test]
    fn zero_units() {
        assert!(run_units(4, 0, |u| u).is_empty());
        assert!(run_units(1, 0, |u| u).is_empty());
    }

    #[test]
    fn for_units_covers_every_index_once() {
        use std::sync::atomic::AtomicU32;
        let hits: Vec<AtomicU32> = (0..53).map(|_| AtomicU32::new(0)).collect();
        for_units(4, 53, |u| {
            hits[u].fetch_add(1, Ordering::Relaxed);
        });
        assert!(hits.iter().all(|h| h.load(Ordering::Relaxed) == 1));
    }

    #[test]
    fn pool_spawns_once_per_width() {
        // Width 5 is unique to this test within this binary, so the
        // counter cannot be perturbed by sibling tests.
        let first = run_units(5, 19, |u| u as u64 + 9);
        assert_eq!(pool_spawned_for(5), 5);
        for _ in 0..4 {
            assert_eq!(run_units(5, 19, |u| u as u64 + 9), first);
            assert_eq!(pool_spawned_for(5), 5, "reuse must not respawn");
        }
        assert!(pool_spawn_count() >= 5);
    }

    #[test]
    fn concurrent_callers_share_one_pool() {
        // Several OS threads submitting to the same width concurrently:
        // jobs queue FIFO and every caller gets its own correct results.
        std::thread::scope(|s| {
            for offset in 0..4usize {
                s.spawn(move || {
                    for _ in 0..5 {
                        let got = run_units(2, 29, move |u| u * 7 + offset);
                        let want: Vec<usize> = (0..29).map(|u| u * 7 + offset).collect();
                        assert_eq!(got, want, "offset={offset}");
                    }
                });
            }
        });
    }

    #[test]
    fn panic_propagates_and_pool_survives() {
        let caught = std::panic::catch_unwind(|| {
            run_units(6, 16, |u| {
                if u == 5 {
                    panic!("unit 5 exploded");
                }
                u
            })
        });
        assert!(caught.is_err(), "worker panic must reach the caller");
        // The pool's workers caught the panic per-unit and kept running.
        assert_eq!(run_units(6, 8, |u| u + 1), (1..=8).collect::<Vec<_>>());
    }

    #[test]
    fn parallelism_constructors() {
        assert!(Parallelism::serial().is_serial());
        assert!(!Parallelism::threads(3).is_serial());
        assert_eq!(Parallelism::threads(3).thread_count(), 3);
        assert!(Parallelism::auto().thread_count() >= 1);
        assert_eq!(Parallelism::default(), Parallelism::serial());
    }
}
