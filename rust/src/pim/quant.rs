//! 4-bit quantization and the positive/negative weight-bank split (§IV-C).
//!
//! Mirrors `python/compile/model.py::quant_act` / `quant_weight`: dynamic
//! per-tensor scales, unsigned 4-bit activations (post-ReLU), signed 4-bit
//! weights split into two unsigned banks whose PIM outputs are subtracted
//! in the digital domain.

/// Quantized activation matrix (row-major [m][k], values 0..=15).
/// (`Default` is the empty matrix — the rest state of the reusable
/// scratch in [`MacScratch`](crate::pim::engine::MacScratch).)
#[derive(Clone, Debug, Default)]
pub struct QuantizedActs {
    /// Quantized levels, row-major.
    pub data: Vec<u8>,
    /// Number of rows (batch/spatial positions).
    pub m: usize,
    /// Inner (reduction) dimension.
    pub k: usize,
    /// Dequantization scale: `a ≈ data · scale`.
    pub scale: f32,
}

/// Quantized weight banks (row-major [k][n], values 0..=15 each) with
/// per-output-column scales (the digital rescale after the subtractor is
/// per column, so per-channel scaling is free — mirrors
/// `model.py::quant_weight`).
#[derive(Clone, Debug)]
pub struct QuantizedWeights {
    /// Positive bank (magnitudes of w ≥ 0), row-major [k][n].
    pub pos: Vec<u8>,
    /// Negative bank (magnitudes of w < 0), row-major [k][n].
    pub neg: Vec<u8>,
    /// Reduction dimension.
    pub k: usize,
    /// Output columns.
    pub n: usize,
    /// Per-column scale, length `n`.
    pub scale: Vec<f32>,
}

/// Quantize activations: `q = clip(round(a / s), 0, 15)`, `s = max(a)/15`.
///
/// One-shot convenience over [`quantize_acts_into`]; steady-state callers
/// ([`PimEngine::matmul_prepared_scratch`](crate::pim::engine::PimEngine::matmul_prepared_scratch))
/// reuse a scratch `QuantizedActs` instead so a warmed-up matmul
/// allocates nothing here.
pub fn quantize_acts(a: &[f32], m: usize, k: usize) -> QuantizedActs {
    let mut qa = QuantizedActs::default();
    quantize_acts_into(a, m, k, &mut qa);
    qa
}

/// [`quantize_acts`] into a caller-owned buffer: `qa.data` is cleared and
/// refilled in place, growing only when the shape exceeds its retained
/// capacity (each growth is tallied by
/// [`mac_alloc_count`](crate::pim::program::mac_alloc_count) — the
/// allocation-free-steady-state observable). Same math, same levels, same
/// scale as the one-shot path.
pub fn quantize_acts_into(a: &[f32], m: usize, k: usize, qa: &mut QuantizedActs) {
    assert_eq!(a.len(), m * k);
    let max = a.iter().cloned().fold(0.0f32, f32::max).max(1e-6);
    let scale = max / 15.0;
    super::program::note_mac_growth(qa.data.capacity(), m * k);
    qa.data.clear();
    qa.data.extend(a.iter().map(|&x| (x / scale).round().clamp(0.0, 15.0) as u8));
    qa.m = m;
    qa.k = k;
    qa.scale = scale;
}

/// Quantize signed weights into positive/negative banks with per-column
/// scales: `q = clip(round(w / s[j]), -15, 15)`, `s[j] = max_i |w[i][j]|/15`.
pub fn quantize_weights(w: &[f32], k: usize, n: usize) -> QuantizedWeights {
    assert_eq!(w.len(), k * n);
    let mut scale = vec![0.0f32; n];
    for i in 0..k {
        for (j, s) in scale.iter_mut().enumerate() {
            *s = s.max(w[i * n + j].abs());
        }
    }
    for s in scale.iter_mut() {
        *s = s.max(1e-6) / 15.0;
    }
    let mut pos = vec![0u8; k * n];
    let mut neg = vec![0u8; k * n];
    for i in 0..k {
        for j in 0..n {
            let q = (w[i * n + j] / scale[j]).round().clamp(-15.0, 15.0) as i8;
            if q >= 0 {
                pos[i * n + j] = q as u8;
            } else {
                neg[i * n + j] = (-q) as u8;
            }
        }
    }
    QuantizedWeights { pos, neg, k, n, scale }
}

/// Activation bit-planes transposed into `u64` words along the reduction
/// (k) dimension — the activation-side operand of the word-wide
/// AND/popcount MAC kernel
/// ([`MacKernel::BitPlane`](crate::pim::engine::MacKernel)). For each of
/// the `m` rows and each of the four bit-planes there are ⌈k/64⌉ words;
/// bit `r` of word `kw` holds bit `plane` of the activation level at
/// reduction index `64·kw + r` (padding bits beyond `k` are zero, so
/// they AND away against any weight bitmap). Built per matmul call by
/// [`QuantizedActs::pack_planes`] — an O(m·k) transpose amortized
/// against the O(m·k·n) MAC it feeds (and reused across calls via
/// [`QuantizedActs::pack_planes_into`] on the scratch-pool path).
/// (`Default` is the empty transpose — the scratch rest state.)
#[derive(Clone, Debug, Default)]
pub struct PackedActPlanes {
    bits: Vec<u64>,
    k_words: usize,
}

impl PackedActPlanes {
    /// Word `kw` of row `row`'s bitmap for bit-plane `plane` (0 = LSB).
    #[inline]
    pub fn word(&self, row: usize, plane: usize, kw: usize) -> u64 {
        self.bits[(row * 4 + plane) * self.k_words + kw]
    }

    /// Number of 64-bit words each per-row, per-plane bitmap spans
    /// (⌈k/64⌉).
    pub fn k_words(&self) -> usize {
        self.k_words
    }
}

impl QuantizedActs {
    /// Write bit-plane `b` (0 = LSB) into `out` as 0/1 bytes. `out` must
    /// be exactly `m · k` long — the caller owns (and reuses) the buffer,
    /// so extracting all four planes costs zero allocations.
    pub fn bit_plane_into(&self, b: u32, out: &mut [u8]) {
        assert_eq!(out.len(), self.data.len(), "bit-plane buffer must be m·k bytes");
        for (o, &v) in out.iter_mut().zip(self.data.iter()) {
            *o = (v >> b) & 1;
        }
    }

    /// Extract bit-plane `b` (0 = LSB) as freshly allocated 0/1 bytes — a
    /// thin wrapper over [`Self::bit_plane_into`], kept for the test
    /// harnesses (`rust/tests/proptests.rs` round-trips it against
    /// [`Self::pack_planes`]). No production path calls this: the engine
    /// consumes packed words, and per-plane byte extraction would
    /// allocate once per bit.
    pub fn bit_plane(&self, b: u32) -> Vec<u8> {
        let mut out = vec![0u8; self.data.len()];
        self.bit_plane_into(b, &mut out);
        out
    }

    /// Transpose the four bit-planes of every row into packed `u64`
    /// bitmaps along the reduction dimension (see [`PackedActPlanes`]
    /// for the layout). The words carry exactly the bits
    /// [`Self::bit_plane`] reports byte-wise — pinned by the round-trip
    /// property test in `rust/tests/proptests.rs`.
    ///
    /// One-shot convenience over [`Self::pack_planes_into`].
    pub fn pack_planes(&self) -> PackedActPlanes {
        let mut planes = PackedActPlanes::default();
        self.pack_planes_into(&mut planes);
        planes
    }

    /// [`Self::pack_planes`] into a caller-owned transpose: `planes.bits`
    /// is zeroed and refilled in place, growing only when the shape
    /// exceeds its retained capacity (growths are tallied by
    /// [`mac_alloc_count`](crate::pim::program::mac_alloc_count)).
    /// Clearing + zero-resizing an existing buffer produces exactly the
    /// all-zero words a fresh `vec![0u64; …]` would, so the packed result
    /// is identical to the one-shot path.
    pub fn pack_planes_into(&self, planes: &mut PackedActPlanes) {
        let k_words = self.k.div_ceil(64);
        super::program::note_mac_growth(planes.bits.capacity(), self.m * 4 * k_words);
        planes.bits.clear();
        planes.bits.resize(self.m * 4 * k_words, 0);
        planes.k_words = k_words;
        for i in 0..self.m {
            let base = i * 4 * k_words;
            for (kk, &v) in self.data[i * self.k..(i + 1) * self.k].iter().enumerate() {
                let (kw, r) = (kk / 64, kk % 64);
                for b in 0..4usize {
                    planes.bits[base + b * k_words + kw] |= (((v >> b) & 1) as u64) << r;
                }
            }
        }
    }

    /// Level at row `i`, column `j`.
    pub fn at(&self, i: usize, j: usize) -> u8 {
        self.data[i * self.k + j]
    }
}

impl QuantizedWeights {
    /// Reconstruct the signed integer weight at (i, j).
    pub fn signed_at(&self, i: usize, j: usize) -> i16 {
        self.pos[i * self.n + j] as i16 - self.neg[i * self.n + j] as i16
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn act_quantization_roundtrip() {
        let a = vec![0.0, 0.5, 1.0, 1.5];
        let q = quantize_acts(&a, 2, 2);
        assert_eq!(q.scale, 0.1);
        assert_eq!(q.data, vec![0, 5, 10, 15]);
    }

    #[test]
    fn act_clamps_nonnegative() {
        let q = quantize_acts(&[3.0, 1.0], 1, 2);
        assert_eq!(q.data, vec![15, 5]);
    }

    #[test]
    fn weight_banks_split_per_column() {
        // Column 0 holds {1.0, 0.4} → scale 1/15; column 1 {−1.0, 0} →
        // scale 1/15. Per-column quantization.
        let w = vec![1.0, -1.0, 0.4, 0.0];
        let q = quantize_weights(&w, 2, 2);
        assert_eq!(q.pos, vec![15, 0, 6, 0]);
        assert_eq!(q.neg, vec![0, 15, 0, 0]);
        assert_eq!(q.signed_at(0, 0), 15);
        assert_eq!(q.signed_at(0, 1), -15);
        assert_eq!(q.signed_at(1, 0), 6);
        // A small column gets its own fine scale.
        let w2 = vec![1.0, 0.01, 1.0, -0.01];
        let q2 = quantize_weights(&w2, 2, 2);
        assert_eq!(q2.pos[1], 15, "small column uses its own scale");
        assert_eq!(q2.neg[3], 15);
        assert!((q2.scale[1] - 0.01 / 15.0).abs() < 1e-9);
    }

    #[test]
    fn banks_are_disjoint() {
        let w: Vec<f32> = (0..100).map(|i| (i as f32 - 50.0) * 0.01).collect();
        let q = quantize_weights(&w, 10, 10);
        for i in 0..100 {
            assert!(q.pos[i] == 0 || q.neg[i] == 0, "both banks set at {i}");
            assert!(q.pos[i] <= 15 && q.neg[i] <= 15);
        }
    }

    #[test]
    fn bit_planes_reassemble() {
        let a = vec![0.0, 7.0, 15.0, 9.0];
        let q = quantize_acts(&a, 1, 4);
        let mut recon = vec![0u8; 4];
        for b in 0..4 {
            for (r, bit) in recon.iter_mut().zip(q.bit_plane(b)) {
                *r |= bit << b;
            }
        }
        assert_eq!(recon, q.data);
    }

    #[test]
    fn zero_tensor_safe() {
        let q = quantize_acts(&[0.0; 4], 2, 2);
        assert!(q.data.iter().all(|&x| x == 0));
        let w = quantize_weights(&[0.0; 4], 2, 2);
        assert!(w.pos.iter().all(|&x| x == 0));
    }

    #[test]
    fn negative_only_acts_quantize_to_zero() {
        // Activations are non-negative by contract (post-ReLU), but a
        // defensive caller may pass raw tensors: the max fold starts at
        // 0.0 and the 1e-6 floor keeps the scale positive, so every
        // negative level clamps to 0 instead of panicking or wrapping.
        let q = quantize_acts(&[-3.0, -0.5, -1e30], 1, 3);
        assert!(q.scale > 0.0 && q.scale.is_finite());
        assert_eq!(q.data, vec![0, 0, 0]);
    }

    #[test]
    fn nan_acts_quantize_to_zero_without_poisoning_scale() {
        // f32::max ignores a NaN operand, so the scale comes from the
        // finite values, and the saturating `as u8` cast sends the NaN
        // level itself to 0 rather than propagating it into the banks.
        let q = quantize_acts(&[f32::NAN, 1.0, 3.0], 1, 3);
        assert_eq!(q.scale, 3.0 / 15.0);
        assert_eq!(q.data, vec![0, 5, 15]);
        // All-NaN: the 0-start fold leaves max = 0, floored to 1e-6.
        let q = quantize_acts(&[f32::NAN; 4], 2, 2);
        assert_eq!(q.data, vec![0, 0, 0, 0]);
        assert!(q.scale > 0.0);
    }

    #[test]
    fn tiny_scale_weight_columns_collapse_instead_of_exploding() {
        // A column whose max |w| sits below the 1e-6 floor quantizes
        // through the floored scale: its levels collapse to 0 (staying in
        // 0..=15) instead of dividing by a denormal-tiny scale. Full-range
        // columns in the same matrix are unaffected.
        let w = vec![1e-12, 1.0, -1e-12, -1.0]; // [k=2][n=2]: col 0 tiny
        let q = quantize_weights(&w, 2, 2);
        assert!((q.scale[0] - 1e-6 / 15.0).abs() < 1e-12);
        assert_eq!((q.pos[0], q.neg[2]), (0, 0), "tiny column collapses to 0");
        assert_eq!((q.pos[1], q.neg[3]), (15, 15), "full column unaffected");
        assert!(q.pos.iter().chain(q.neg.iter()).all(|&v| v <= 15));
    }

    #[test]
    fn into_variants_match_oneshot_across_reuse() {
        // The scratch-borrowing variants must produce the same levels,
        // scale, and packed words as the one-shot paths even when the
        // buffers are reused across shape changes (big → small → big).
        let shapes = [(3usize, 70usize), (1, 130), (2, 64), (3, 70)];
        let mut qa = QuantizedActs::default();
        let mut planes = PackedActPlanes::default();
        let mut buf = Vec::new();
        for (round, &(m, k)) in shapes.iter().enumerate() {
            let a: Vec<f32> = (0..m * k).map(|i| ((i * 7 + round) % 16) as f32 * 0.1).collect();
            let fresh = quantize_acts(&a, m, k);
            quantize_acts_into(&a, m, k, &mut qa);
            assert_eq!(qa.data, fresh.data, "round {round}");
            assert_eq!((qa.m, qa.k, qa.scale), (fresh.m, fresh.k, fresh.scale));
            let fresh_planes = fresh.pack_planes();
            qa.pack_planes_into(&mut planes);
            assert_eq!(planes.k_words(), fresh_planes.k_words(), "round {round}");
            for i in 0..m {
                for b in 0..4usize {
                    for kw in 0..planes.k_words() {
                        assert_eq!(planes.word(i, b, kw), fresh_planes.word(i, b, kw));
                    }
                }
            }
            buf.clear();
            buf.resize(m * k, 0);
            for b in 0..4u32 {
                qa.bit_plane_into(b, &mut buf);
                assert_eq!(buf, fresh.bit_plane(b), "round {round} plane {b}");
            }
        }
    }

    #[test]
    fn pack_planes_matches_bit_plane_bytes() {
        // k = 70 crosses the 64-bit word boundary; m = 2 checks the
        // per-row stride.
        let a: Vec<f32> = (0..2 * 70).map(|i| (i % 16) as f32).collect();
        let q = quantize_acts(&a, 2, 70);
        let p = q.pack_planes();
        assert_eq!(p.k_words(), 2);
        for b in 0..4u32 {
            let plane = q.bit_plane(b);
            for i in 0..2 {
                for kk in 0..70 {
                    let bit = (p.word(i, b as usize, kk / 64) >> (kk % 64)) & 1;
                    assert_eq!(bit as u8, plane[i * 70 + kk], "i={i} b={b} kk={kk}");
                }
                // Padding bits beyond k stay zero.
                for r in 6..64 {
                    assert_eq!((p.word(i, b as usize, 1) >> r) & 1, 0);
                }
            }
        }
    }
}
