//! The PIM execution engine: quantized matmuls through the analog pipeline.
//!
//! This is the Rust-native counterpart of the L1 kernel: identical math to
//! `kernels/ref.py::pim_mac` (bit-serial planes, per-128-row-block ADC
//! quantization via [`TransferModel`], digital shift-add, pos/neg bank
//! subtraction). Used by the figure generators, the retention/serving
//! examples, the benches, and as the ground truth the PJRT-executed HLO is
//! cross-checked against.
//!
//! Hot path: integer bit-plane accumulation + an exact ADC LUT (the analog
//! transfer is a pure function of an integer MAC ≤ 1920). The inner loop
//! is a word-wide AND/popcount kernel in the Neural Cache style
//! ([`MacKernel::BitPlane`], PERFORMANCE.md §8): weights and activations
//! are transposed into per-bit-plane `u64` bitmaps along the reduction
//! dimension, so one bitwise AND + popcount covers 64 reduction rows at
//! once; the historical byte-walking kernel stays alive as
//! [`MacKernel::Scalar`] and the two are raced bit-for-bit by
//! `rust/tests/simd_parity.rs`. The word-wide fill **skips zero words**
//! on both operands — all-zero activation words (ReLU sparsity) and
//! all-zero weight bit-plane rows ([`PreparedBank::plane_any`]) cost no
//! AND/popcount work, tallied per engine by [`SkipStats`] and provably
//! output-neutral (PERFORMANCE.md §12). The work factors into
//! data-independent *units* — one per (output row × 128-row block ×
//! 128-word output tile), mirroring the sub-array organization — which
//! the engine schedules over the [`super::parallel`] **persistent worker
//! pool** as (row × tile) groups, each folding its row blocks in
//! ascending order into a disjoint output slice; that is the same
//! per-slice f32 addition order as the historical unit-order reduce, so
//! parallel output is bit-identical to serial at any width
//! (PERFORMANCE.md, `rust/tests/parallel_parity.rs`,
//! `rust/tests/hotpath_parity.rs`).
//!
//! Weight handling follows the compile-once / execute-many split of
//! [`super::program`]: [`PimEngine::prepare`] quantizes + packs a weight
//! matrix once, [`PimEngine::matmul_prepared`] executes it any number of
//! times, and the historical one-shot entry points (`pim_matmul`,
//! `bank_mac`, …) are thin prepare-then-run wrappers over the same core —
//! so prepared and one-shot output are bit-identical
//! (`rust/tests/program_parity.rs`).

use std::cell::Cell;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

use crate::consts::{ARRAY_ROWS, ARRAY_WORDS};
use crate::device::Corner;
use crate::util::rng::Pcg64;

use super::parallel::{self, Parallelism};
use super::program::{self, PreparedBank, PreparedWeights};
use super::quant::{quantize_acts_into, PackedActPlanes, QuantizedActs};
use super::transfer::{TransferModel, ADC_CODES, MAC_FULLSCALE};

// Both kernels pack the four bit-plane MACs of one k-block into the four
// 16-bit lanes of a u64; a geometry change that could overflow a lane
// (worst case: all-15 activations × all-15 weights over a full block)
// must fail the build, not wrap silently at runtime.
const _: () = assert!(
    ARRAY_ROWS * 15 <= u16::MAX as usize,
    "a full row block's bit-plane MAC must fit a 16-bit recombination lane"
);
// The word-wide kernel slices 64-row bitmap words out of 128-row blocks;
// block boundaries must land on word boundaries.
const _: () = assert!(ARRAY_ROWS % 64 == 0, "row blocks must align with 64-bit plane words");

/// Spread mask: activation nibble bit `b` → bit 16·b, so one u64
/// multiply-add accumulates all four bit-plane MACs at once (each plane
/// MAC ≤ 1920 < 2¹⁶).
const SPREAD: [u64; 16] = {
    let mut t = [0u64; 16];
    let mut v = 0usize;
    while v < 16 {
        t[v] = (v as u64 & 1)
            | ((v as u64 >> 1) & 1) << 16
            | ((v as u64 >> 2) & 1) << 32
            | ((v as u64 >> 3) & 1) << 48;
        v += 1;
    }
    t
};

thread_local! {
    static DEFAULT_KERNEL: Cell<MacKernel> = const { Cell::new(MacKernel::BitPlane) };
}

/// Selects the MAC inner-loop implementation of [`PimEngine::mac_unit`].
///
/// Both kernels compute the **same integers**: the per-(row block ×
/// bit-plane) MAC that indexes the ADC LUT. They differ only in how the
/// packed lane accumulators are filled, so noiseless and noisy outputs
/// are bit-identical at any thread count — pinned forever by the
/// differential harness `rust/tests/simd_parity.rs`, which is why the
/// scalar kernel stays alive rather than being deleted.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum MacKernel {
    /// Word-wide AND/popcount over transposed bit-plane bitmaps
    /// ([`PreparedBank::plane_row`] × [`PackedActPlanes`]): one bitwise
    /// op covers 64 reduction rows. The default; PERFORMANCE.md §8.
    #[default]
    BitPlane,
    /// The historical kernel: walk packed nibble rows byte-by-byte,
    /// accumulating `SPREAD[act] * weight` per column.
    Scalar,
}

impl MacKernel {
    /// The kernel newly constructed engines on this thread default to
    /// ([`MacKernel::BitPlane`] unless overridden).
    pub fn thread_default() -> MacKernel {
        DEFAULT_KERNEL.with(|c| c.get())
    }

    /// Override the kernel that engines constructed **on this thread**
    /// default to. This is the differential-test seam: layers that build
    /// their own engines internally (compiled networks, the stub
    /// runtime) can be rerun wholesale on the scalar kernel without any
    /// extra plumbing. Worker threads only borrow already-built engines,
    /// so the override needs to be set only on the constructing thread.
    pub fn set_thread_default(kernel: MacKernel) {
        DEFAULT_KERNEL.with(|c| c.set(kernel));
    }

    /// Does this kernel consume transposed activation bit-planes?
    pub fn uses_bit_planes(&self) -> bool {
        matches!(self, MacKernel::BitPlane)
    }
}

/// Scalar lane fill ([`MacKernel::Scalar`]): walk the packed nibble rows
/// of the unit's row block byte-by-byte, accumulating `SPREAD[act] · w`
/// into each column's packed lanes. `a_row` is the activation row
/// (length k); `packed` is the unit's `width` lane accumulators.
///
/// (Perf note, EXPERIMENTS.md §Perf: pre-widening the bank to u64 was
/// tried and reverted — 8× memory traffic lost more than the widening
/// saved. The u8 loads below widen in-register.)
fn fill_unit_scalar(
    a_row: &[u8],
    bank: &PreparedBank,
    ti: usize,
    k0: usize,
    k1: usize,
    packed: &mut [u64],
) {
    let width = packed.len();
    for kk in k0..k1 {
        let mask = SPREAD[a_row[kk] as usize];
        if mask == 0 {
            continue;
        }
        let w_row = &bank.row(ti, kk)[..width];
        for (acc, &w) in packed.iter_mut().zip(w_row) {
            *acc += mask * w as u64;
        }
    }
}

/// Word-wide AND/popcount lane fill ([`MacKernel::BitPlane`], the Neural
/// Cache formulation): for each 64-row bitmap word of the block and each
/// weight bit-plane `bw`, add `popcount(act_plane_ba & w_plane_bw) << bw`
/// into activation-plane lane `ba` — 64 reduction rows per bitwise op
/// instead of one byte multiply-add each.
///
/// Exactness: a popcount is ≤ 64, so `count << bw` ≤ 512 and each 16-bit
/// lane totals at most `15 · ARRAY_ROWS = 1920` over a full block (the
/// compile-time assert above) — no cross-lane carry, and each lane holds
/// *exactly* the integer `Σ_kk act_bit(ba,kk) · w(kk)` the scalar fill
/// computes, because `w(kk) = Σ_bw 2^bw · w_bit(bw,kk)`. Identical lane
/// integers ⇒ identical LUT lookups ⇒ bit-identical f32 output.
///
/// Zero-word skipping: an all-zero activation word (ReLU sparsity — all
/// 64 reduction rows quantized to level 0) skips the whole k-word, and
/// an all-zero weight bit-plane row ([`PreparedBank::plane_any`], e.g. a
/// one-sided bank) skips that plane's AND/popcount pass. Both skips add
/// exactly the 0 the popcounts would have added, so the lane integers —
/// and therefore outputs and per-unit RNG draws (noise is drawn at the
/// LUT tail, after the fill) — are unchanged (`zero_skip` parity in
/// `rust/tests/hotpath_parity.rs`). Returns the (visited, act-skipped,
/// plane-skipped) word tally for [`SkipStats`].
fn fill_unit_bitplane(
    pa: &PackedActPlanes,
    bank: &PreparedBank,
    i: usize,
    ti: usize,
    k0: usize,
    k1: usize,
    packed: &mut [u64],
) -> (u64, u64, u64) {
    let width = packed.len();
    // ARRAY_ROWS % 64 == 0 ⇒ k0 is word-aligned; the last word's padding
    // bits are zero in both operands.
    let (kw0, kw1) = (k0 / 64, k1.div_ceil(64));
    let (mut visited, mut act_skipped, mut planes_skipped) = (0u64, 0u64, 0u64);
    for kw in kw0..kw1 {
        visited += 1;
        let aw = [
            pa.word(i, 0, kw),
            pa.word(i, 1, kw),
            pa.word(i, 2, kw),
            pa.word(i, 3, kw),
        ];
        if aw == [0, 0, 0, 0] {
            act_skipped += 1;
            continue;
        }
        for bw in 0..4 {
            if !bank.plane_any(ti, bw, kw) {
                planes_skipped += 1;
                continue;
            }
            let w_row = &bank.plane_row(ti, bw, kw)[..width];
            for (acc, &wv) in packed.iter_mut().zip(w_row) {
                let lanes = ((aw[0] & wv).count_ones() as u64)
                    | ((aw[1] & wv).count_ones() as u64) << 16
                    | ((aw[2] & wv).count_ones() as u64) << 32
                    | ((aw[3] & wv).count_ones() as u64) << 48;
                *acc += lanes << bw;
            }
        }
    }
    (visited, act_skipped, planes_skipped)
}

/// The tiling grid one bank MAC decomposes into: `m` output rows ×
/// ⌈k/128⌉ row blocks (the 128-row powerline accumulation limit) ×
/// ⌈n/128⌉ output tiles (one sub-array's 128 word columns). Unit `u`
/// enumerates the grid with the output tile fastest, then the row block,
/// then the output row — the canonical reduce order.
struct UnitGrid {
    k: usize,
    n: usize,
    n_blocks: usize,
    n_tiles: usize,
    units: usize,
}

impl UnitGrid {
    fn new(m: usize, k: usize, n: usize) -> UnitGrid {
        let n_blocks = k.div_ceil(ARRAY_ROWS);
        let n_tiles = n.div_ceil(ARRAY_WORDS);
        UnitGrid { k, n, n_blocks, n_tiles, units: m * n_blocks * n_tiles }
    }

    /// Unit index → (output row, row block, output tile).
    fn decompose(&self, u: usize) -> (usize, usize, usize) {
        let ti = u % self.n_tiles;
        let rest = u / self.n_tiles;
        (rest / self.n_blocks, rest % self.n_blocks, ti)
    }

    /// Reduction-dimension range of row block `bi`.
    fn k_range(&self, bi: usize) -> (usize, usize) {
        (bi * ARRAY_ROWS, (bi * ARRAY_ROWS + ARRAY_ROWS).min(self.k))
    }

    /// Word-column range of output tile `ti`.
    fn c_range(&self, ti: usize) -> (usize, usize) {
        (ti * ARRAY_WORDS, (ti * ARRAY_WORDS + ARRAY_WORDS).min(self.n))
    }
}

/// Reusable per-unit scratch: packed 4-plane powerline accumulators and
/// the plane-recombined partial sums, one entry per word column of a
/// tile. Both live entirely on the stack (a tile never exceeds
/// [`ARRAY_WORDS`] columns, so this is ~2 KiB) — each worker's group
/// loop owns one and [`PimEngine::mac_unit`] overwrites the live prefix
/// unconditionally, so no heap traffic and no cross-unit state.
struct UnitScratch {
    packed: [u64; ARRAY_WORDS],
    partial: [f32; ARRAY_WORDS],
}

impl UnitScratch {
    fn new() -> UnitScratch {
        UnitScratch { packed: [0; ARRAY_WORDS], partial: [0.0; ARRAY_WORDS] }
    }
}

/// Shared base pointer of the output buffer, passed into the pooled
/// group closure.
struct SyncPtr(*mut f32);

// SAFETY: only ever used to derive non-overlapping per-group `&mut
// [f32]` windows (see `bank_mac_core_into`); the buffer outlives the
// blocking `for_units` call that uses it.
unsafe impl Send for SyncPtr {}
unsafe impl Sync for SyncPtr {}

/// Inner-loop zero-skip counters for the word-wide bit-plane kernel:
/// how many k-word groups the fill visited, how many it skipped because
/// all four activation plane words were zero (ReLU sparsity), and how
/// many weight bit-plane rows it skipped as all-zero
/// ([`PreparedBank::plane_any`]).
///
/// One instance per engine, shared by its clones (the engine holds an
/// `Arc`); workers bump it with relaxed atomics — a throughput
/// observatory, never a synchronization point. Skips are output-neutral
/// by construction (a popcount against a zero word adds 0 to every lane,
/// and noise is drawn per unit *after* the fill), so these counters can
/// only ever measure saved work, not changed results — the differential
/// contract of `rust/tests/hotpath_parity.rs` and PERFORMANCE.md §12.
#[derive(Debug, Default)]
pub struct SkipStats {
    words: AtomicU64,
    act_skipped: AtomicU64,
    planes_skipped: AtomicU64,
}

impl SkipStats {
    /// k-word groups the bit-plane fill has examined.
    pub fn words_visited(&self) -> u64 {
        self.words.load(Ordering::Relaxed)
    }

    /// Visited k-words skipped outright (all four activation plane words
    /// zero).
    pub fn act_words_skipped(&self) -> u64 {
        self.act_skipped.load(Ordering::Relaxed)
    }

    /// Weight bit-plane rows skipped as all-zero within non-skipped
    /// k-words (up to 4 per visited word).
    pub fn weight_planes_skipped(&self) -> u64 {
        self.planes_skipped.load(Ordering::Relaxed)
    }

    /// Fraction of visited k-words skipped on the activation side
    /// (0.0 when nothing has run).
    pub fn act_skip_fraction(&self) -> f64 {
        let words = self.words_visited();
        if words == 0 {
            0.0
        } else {
            self.act_words_skipped() as f64 / words as f64
        }
    }

    /// Zero all counters (e.g. before measuring one workload).
    pub fn reset(&self) {
        self.words.store(0, Ordering::Relaxed);
        self.act_skipped.store(0, Ordering::Relaxed);
        self.planes_skipped.store(0, Ordering::Relaxed);
    }

    fn record(&self, visited: u64, act_skipped: u64, planes_skipped: u64) {
        if visited != 0 {
            self.words.fetch_add(visited, Ordering::Relaxed);
        }
        if act_skipped != 0 {
            self.act_skipped.fetch_add(act_skipped, Ordering::Relaxed);
        }
        if planes_skipped != 0 {
            self.planes_skipped.fetch_add(planes_skipped, Ordering::Relaxed);
        }
    }
}

/// Reusable activation-side working set for
/// [`PimEngine::matmul_prepared_scratch`]: the quantized levels, the
/// bit-plane transpose, and the pos/neg bank outputs, all retained across
/// calls — so a warmed-up prepared matmul performs **zero** MAC-path heap
/// allocations before the subtracted output
/// ([`crate::pim::program::mac_alloc_count`]). Lives inside
/// [`crate::pim::program::ScratchPool`] on the compiled-network path; the
/// one-shot wrappers build a fresh one per call.
#[derive(Debug, Default)]
pub struct MacScratch {
    qa: QuantizedActs,
    planes: PackedActPlanes,
    pos: Vec<f32>,
    neg: Vec<f32>,
}

impl MacScratch {
    /// An empty working set (buffers grow to the largest matmul on first
    /// use, then stay).
    pub fn new() -> MacScratch {
        MacScratch::default()
    }
}

/// Engine configuration + precomputed state.
#[derive(Clone, Debug)]
pub struct PimEngine {
    /// The analog transfer model (corner-specific).
    pub transfer: TransferModel,
    /// Calibrated ADC references (Fig. 12a) vs full-VDD uncalibrated.
    pub calibrated: bool,
    /// Per-conversion ADC noise sigma in code units (None = noiseless).
    pub noise_sigma_codes: Option<f64>,
    /// Worker-pool width for [`Self::pim_matmul`] / [`Self::bank_mac`]
    /// (serial by default; [`Self::par_matmul`] overrides per call).
    pub parallelism: Parallelism,
    /// MAC inner-loop implementation (word-wide bit-plane popcount by
    /// default; both choices are bit-identical — see [`MacKernel`]).
    pub kernel: MacKernel,
    lut: Vec<f32>,
    /// Zero-skip counters, shared with clones (see [`Self::skip_stats`]).
    skip_stats: Arc<SkipStats>,
}

impl PimEngine {
    /// Engine for a corner, calibrated references, noiseless. The MAC
    /// kernel comes from [`MacKernel::thread_default`].
    pub fn new(corner: Corner) -> PimEngine {
        let transfer = TransferModel::new(corner);
        PimEngine {
            transfer,
            calibrated: true,
            noise_sigma_codes: None,
            parallelism: Parallelism::serial(),
            kernel: MacKernel::thread_default(),
            lut: transfer.quantize_lut(true),
            skip_stats: Arc::new(SkipStats::default()),
        }
    }

    /// This engine's inner-loop zero-skip counters (bit-plane kernel
    /// only; the scalar kernel predates word-level skipping and reports
    /// nothing). Note `Clone`d engines **share** the same counters — the
    /// clone copies the `Arc`, which is what the compiled-network paths
    /// want: one observatory per logical engine regardless of internal
    /// cloning.
    pub fn skip_stats(&self) -> &SkipStats {
        &self.skip_stats
    }

    /// Typical-corner engine (the common case).
    pub fn tt() -> PimEngine {
        Self::new(Corner::TT)
    }

    /// Enable per-conversion ADC noise (sigma in code units).
    pub fn with_noise(mut self, sigma_codes: f64) -> PimEngine {
        self.noise_sigma_codes = Some(sigma_codes);
        self
    }

    /// Set the worker-pool width used by [`Self::pim_matmul`] and
    /// [`Self::bank_mac`]. Output is bit-identical at any width.
    pub fn with_parallelism(mut self, par: Parallelism) -> PimEngine {
        self.parallelism = par;
        self
    }

    /// Select the MAC inner-loop kernel. Output is bit-identical across
    /// kernels (the differential contract of `rust/tests/simd_parity.rs`).
    ///
    /// # Examples
    ///
    /// ```
    /// use nvm_in_cache::pim::engine::MacKernel;
    /// use nvm_in_cache::pim::PimEngine;
    ///
    /// let a = vec![0.7f32; 3 * 150];
    /// let w = vec![0.3f32; 150 * 5];
    /// let simd = PimEngine::tt(); // default: MacKernel::BitPlane
    /// let scalar = PimEngine::tt().with_kernel(MacKernel::Scalar);
    /// assert_eq!(
    ///     simd.pim_matmul(&a, 3, 150, &w, 5, None),
    ///     scalar.pim_matmul(&a, 3, 150, &w, 5, None),
    /// );
    /// ```
    pub fn with_kernel(mut self, kernel: MacKernel) -> PimEngine {
        self.kernel = kernel;
        self
    }

    /// Switch to the uncalibrated (full-VDD reference) ADC of Fig. 12.
    pub fn uncalibrated(mut self) -> PimEngine {
        self.calibrated = false;
        self.lut = self.transfer.quantize_lut(false);
        self
    }

    /// One tile unit: powerline accumulation of the unit's row block for
    /// its word columns (all four activation bit-planes packed into the
    /// 16-bit lanes of one u64 per column), then WCC + S&H + SAR
    /// conversion into `scratch.partial` — the plane-recombined partial
    /// MAC of this (row, block, tile), ready for the shift-add reduce.
    /// Pure in `(unit, rng)`: worker scheduling cannot change it.
    ///
    /// The lane fill is kernel-selected: `pa` carries the transposed
    /// activation bitmaps for [`MacKernel::BitPlane`]
    /// ([`fill_unit_bitplane`]) and is `None` on the scalar path
    /// ([`fill_unit_scalar`]). Both fills produce the **same lane
    /// integers**, so everything downstream of the fill — LUT lookups,
    /// noise draws, recombination — is shared code and bit-identical by
    /// construction.
    fn mac_unit(
        &self,
        a: &QuantizedActs,
        pa: Option<&PackedActPlanes>,
        bank: &PreparedBank,
        grid: &UnitGrid,
        u: usize,
        rng: Option<&mut Pcg64>,
        scratch: &mut UnitScratch,
    ) {
        let (i, bi, ti) = grid.decompose(u);
        let (k0, k1) = grid.k_range(bi);
        let (c0, c1) = grid.c_range(ti);
        let width = c1 - c0;
        debug_assert!(
            (k1 - k0) * 15 <= u16::MAX as usize,
            "k-block of {} rows would overflow the 16-bit recombination lanes",
            k1 - k0
        );
        let packed = &mut scratch.packed[..width];
        let partial = &mut scratch.partial[..width];
        packed.fill(0);
        match pa {
            Some(planes) => {
                let (v, a_skip, p_skip) = fill_unit_bitplane(planes, bank, i, ti, k0, k1, packed);
                self.skip_stats.record(v, a_skip, p_skip);
            }
            None => fill_unit_scalar(&a.data[i * grid.k..(i + 1) * grid.k], bank, ti, k0, k1, packed),
        }
        match rng {
            None => {
                for (o, &p) in partial.iter_mut().zip(packed.iter()) {
                    *o = self.lut[(p & 0xFFFF) as usize]
                        + 2.0 * self.lut[((p >> 16) & 0xFFFF) as usize]
                        + 4.0 * self.lut[((p >> 32) & 0xFFFF) as usize]
                        + 8.0 * self.lut[((p >> 48) & 0xFFFF) as usize];
                }
            }
            Some(r) => {
                let lsb = MAC_FULLSCALE as f64 / ADC_CODES as f64;
                let sigma = self.noise_sigma_codes.unwrap_or(0.0) * lsb;
                for (o, &p) in partial.iter_mut().zip(packed.iter()) {
                    let mut acc = 0.0f32;
                    for b in 0..4u32 {
                        let mac = ((p >> (16 * b)) & 0xFFFF) as usize;
                        let noise = r.normal(0.0, sigma) as f32;
                        acc += (1u32 << b) as f32 * (self.lut[mac] + noise);
                    }
                    *o = acc;
                }
            }
        }
    }

    /// One unsigned bank MAC: quantized activations [m,k] × bank [k,n]
    /// (u8 weights 0..=15), with per-(128-row block × bit-plane) ADC
    /// quantization. Returns dequantized MAC estimates (integer units).
    /// Runs on [`Self::parallelism`] (serial by default); see
    /// [`Self::par_bank_mac`].
    ///
    /// One-shot convenience: packs `bank` tile-aligned on every call.
    /// Execute-many callers should pack once ([`PreparedBank::pack`]) and
    /// use [`Self::bank_mac_prepared`].
    pub fn bank_mac(
        &self,
        a: &QuantizedActs,
        bank: &[u8],
        n: usize,
        rng: Option<&mut Pcg64>,
    ) -> Vec<f32> {
        self.par_bank_mac(a, bank, n, rng, self.parallelism)
    }

    /// [`Self::bank_mac`] on an explicit worker-pool width (one-shot:
    /// packs the bank, then runs the prepared core).
    pub fn par_bank_mac(
        &self,
        a: &QuantizedActs,
        bank: &[u8],
        n: usize,
        rng: Option<&mut Pcg64>,
        par: Parallelism,
    ) -> Vec<f32> {
        assert_eq!(bank.len(), a.k * n);
        self.par_bank_mac_prepared(a, &PreparedBank::pack(bank, a.k, n), rng, par)
    }

    /// [`Self::bank_mac`] over an already-packed bank on
    /// [`Self::parallelism`] — the execute-many hot path: no weight
    /// packing, no quantization, just the tiled unit grid (plus, on the
    /// bit-plane kernel, an O(m·k) activation-plane transpose that is
    /// negligible against the O(m·k·n) MAC).
    pub fn bank_mac_prepared(
        &self,
        a: &QuantizedActs,
        bank: &PreparedBank,
        rng: Option<&mut Pcg64>,
    ) -> Vec<f32> {
        self.par_bank_mac_prepared(a, bank, rng, self.parallelism)
    }

    /// The prepared-execution core every bank-MAC path funnels into, on an
    /// explicit worker-pool width.
    ///
    /// Noise streams are derived per unit — one parent draw decorrelates
    /// successive bank calls (pos vs neg), then unit `u` reads the
    /// independent PCG stream `(seed, u)` — so neither the thread count
    /// nor the scheduling order can change a single draw, and the
    /// unit-order reduce makes the output bit-identical to serial.
    pub fn par_bank_mac_prepared(
        &self,
        a: &QuantizedActs,
        bank: &PreparedBank,
        rng: Option<&mut Pcg64>,
        par: Parallelism,
    ) -> Vec<f32> {
        let pa = self.kernel.uses_bit_planes().then(|| a.pack_planes());
        let mut out = Vec::new();
        self.bank_mac_core_into(a, pa.as_ref(), bank, rng, par, &mut out);
        out
    }

    /// The kernel-agnostic execution core: `pa` is `Some` exactly when
    /// [`Self::kernel`] is [`MacKernel::BitPlane`] (callers running both
    /// the pos and neg bank pack the activation planes once and pass them
    /// to both calls). `out` is cleared and refilled in place — the
    /// scratch-pool path reuses it call-over-call, so a warmed buffer
    /// costs zero allocations ([`program::mac_alloc_count`]).
    ///
    /// Execution fans (output row × output tile) **groups** out over the
    /// persistent worker pool; each group owns the disjoint output slice
    /// `out[i·n + c0 .. i·n + c1]` and folds its row blocks in ascending
    /// `bi` — exactly the per-slice f32 addition order of the historical
    /// unit-order reduce, with unchanged per-unit RNG indices, so the
    /// output is bit-identical to serial (and to PR 9) at any width,
    /// while partials never leave the worker's stack.
    fn bank_mac_core_into(
        &self,
        a: &QuantizedActs,
        pa: Option<&PackedActPlanes>,
        bank: &PreparedBank,
        rng: Option<&mut Pcg64>,
        par: Parallelism,
        out: &mut Vec<f32>,
    ) {
        let (m, k) = (a.m, a.k);
        assert_eq!(bank.k(), k, "prepared bank reduction dim mismatch");
        let n = bank.n();
        let grid = UnitGrid::new(m, k, n);
        let noise_seed = rng.map(|r| {
            let mut child = r.fork(0x6ba7);
            child.next_u64()
        });
        program::note_mac_growth(out.capacity(), m * n);
        out.clear();
        out.resize(m * n, 0.0);
        if grid.units == 0 {
            return;
        }
        let n_groups = m * grid.n_tiles;
        let run_group = |g: usize, out_slice: &mut [f32]| {
            let (i, ti) = (g / grid.n_tiles, g % grid.n_tiles);
            let (c0, c1) = grid.c_range(ti);
            let width = c1 - c0;
            let mut scratch = UnitScratch::new();
            for bi in 0..grid.n_blocks {
                let u = (i * grid.n_blocks + bi) * grid.n_tiles + ti;
                let mut unit_rng = noise_seed.map(|s| Pcg64::new(s, u as u64));
                self.mac_unit(a, pa, bank, &grid, u, unit_rng.as_mut(), &mut scratch);
                for (o, &p) in out_slice.iter_mut().zip(scratch.partial[..width].iter()) {
                    *o += p;
                }
            }
        };
        if par.thread_count() <= 1 || n_groups <= 1 {
            for g in 0..n_groups {
                let (i, ti) = (g / grid.n_tiles, g % grid.n_tiles);
                let (c0, c1) = grid.c_range(ti);
                run_group(g, &mut out[i * n + c0..i * n + c1]);
            }
            return;
        }
        let base = SyncPtr(out.as_mut_ptr());
        parallel::for_units(par.thread_count(), n_groups, |g| {
            let (i, ti) = (g / grid.n_tiles, g % grid.n_tiles);
            let (c0, c1) = grid.c_range(ti);
            // SAFETY: group g's window [i·n + c0, i·n + c1) is disjoint
            // from every other group's (i selects the row, ti the column
            // window), and `out` is neither read nor resized while the
            // pool runs; the pool's completion handshake publishes the
            // writes before for_units returns.
            let out_slice =
                unsafe { std::slice::from_raw_parts_mut(base.0.add(i * n + c0), c1 - c0) };
            run_group(g, out_slice);
        });
    }

    /// Compile a signed `[k,n]` weight matrix for execute-many use:
    /// quantize into the pos/neg banks and pack them tile-aligned — the
    /// software mirror of one-time RRAM programming. The result feeds
    /// [`Self::matmul_prepared`] any number of times with zero further
    /// weight work, bit-identical to [`Self::pim_matmul`].
    ///
    /// # Examples
    ///
    /// ```
    /// use nvm_in_cache::pim::PimEngine;
    ///
    /// let eng = PimEngine::tt();
    /// let a = vec![1.0f32; 2 * 200];
    /// let w = vec![0.5f32; 200 * 3];
    /// let program = eng.prepare(&w, 200, 3); // once
    /// let prepared = eng.matmul_prepared(&a, 2, &program, None); // many
    /// assert_eq!(prepared, eng.pim_matmul(&a, 2, 200, &w, 3, None));
    /// ```
    pub fn prepare(&self, w: &[f32], k: usize, n: usize) -> PreparedWeights {
        assert_eq!(w.len(), k * n);
        PreparedWeights::from_dense(w, k, n)
    }

    /// Full signed PIM matmul over a prepared weight program: quantize
    /// the activations, run both packed banks, subtract in the digital
    /// domain, rescale. Runs on [`Self::parallelism`]. This is the
    /// steady-state serving hot path — no weight quantization or packing
    /// happens here (`pim::program::prepare_count` stays flat).
    pub fn matmul_prepared(
        &self,
        a: &[f32],
        m: usize,
        pw: &PreparedWeights,
        rng: Option<&mut Pcg64>,
    ) -> Vec<f32> {
        self.par_matmul_prepared(a, m, pw, rng, self.parallelism)
    }

    /// [`Self::matmul_prepared`] on an explicit worker-pool width — a
    /// convenience over [`Self::matmul_prepared_scratch`] with a fresh
    /// working set (callers without a [`super::program::ScratchPool`]).
    pub fn par_matmul_prepared(
        &self,
        a: &[f32],
        m: usize,
        pw: &PreparedWeights,
        rng: Option<&mut Pcg64>,
        par: Parallelism,
    ) -> Vec<f32> {
        self.matmul_prepared_scratch(a, m, pw, rng, par, &mut MacScratch::new())
    }

    /// The prepared-matmul core every signed path funnels into:
    /// quantize the activations into `mac`'s buffers, transpose the
    /// bit-planes once (shared by both banks), run the pos and neg bank
    /// MACs into `mac`'s output buffers, subtract and rescale. On a
    /// warmed `mac` (the [`super::program::ScratchPool`] steady state)
    /// everything before the subtracted output reuses retained capacity —
    /// **zero MAC-path heap allocations**
    /// ([`program::mac_alloc_count`] stays flat; the subtracted output
    /// itself becomes the layer tensor, which takes the `Vec` by value,
    /// so it is the one unavoidable — and uncounted — allocation,
    /// PERFORMANCE.md §12).
    pub fn matmul_prepared_scratch(
        &self,
        a: &[f32],
        m: usize,
        pw: &PreparedWeights,
        rng: Option<&mut Pcg64>,
        par: Parallelism,
        mac: &mut MacScratch,
    ) -> Vec<f32> {
        quantize_acts_into(a, m, pw.k, &mut mac.qa);
        let pa = if self.kernel.uses_bit_planes() {
            mac.qa.pack_planes_into(&mut mac.planes);
            Some(&mac.planes)
        } else {
            None
        };
        let mut rng = rng;
        self.bank_mac_core_into(&mac.qa, pa, &pw.pos, rng.as_deref_mut(), par, &mut mac.pos);
        self.bank_mac_core_into(&mac.qa, pa, &pw.neg, rng.as_deref_mut(), par, &mut mac.neg);
        mac.pos
            .iter()
            .zip(mac.neg.iter())
            .enumerate()
            .map(|(i, (p, q))| (p - q) * mac.qa.scale * pw.scale[i % pw.n])
            .collect()
    }

    /// Full signed PIM matmul: quantize, run both banks, subtract in the
    /// digital domain, rescale. `a` is [m,k] (non-negative, e.g. post-ReLU);
    /// `w` is [k,n] signed. Runs on [`Self::parallelism`].
    ///
    /// One-shot convenience over [`Self::prepare`] +
    /// [`Self::matmul_prepared`]: re-quantizes and re-packs `w` on every
    /// call.
    pub fn pim_matmul(
        &self,
        a: &[f32],
        m: usize,
        k: usize,
        w: &[f32],
        n: usize,
        rng: Option<&mut Pcg64>,
    ) -> Vec<f32> {
        self.par_matmul(a, m, k, w, n, rng, self.parallelism)
    }

    /// [`Self::pim_matmul`] on an explicit worker-pool width. Output is
    /// bit-identical to the serial engine at any thread count.
    ///
    /// # Examples
    ///
    /// ```
    /// use nvm_in_cache::pim::{parallel::Parallelism, PimEngine};
    ///
    /// let eng = PimEngine::tt();
    /// let a = vec![1.0f32; 2 * 200]; // 200 rows: ragged 128 + 72 blocks
    /// let w = vec![0.5f32; 200 * 3];
    /// let serial = eng.pim_matmul(&a, 2, 200, &w, 3, None);
    /// let par = eng.par_matmul(&a, 2, 200, &w, 3, None, Parallelism::threads(2));
    /// assert_eq!(serial, par, "bit-identical at any thread count");
    /// ```
    // One over the clippy arity threshold: the first six parameters are
    // the established pim_matmul matmul signature, `par` is the override.
    #[allow(clippy::too_many_arguments)]
    pub fn par_matmul(
        &self,
        a: &[f32],
        m: usize,
        k: usize,
        w: &[f32],
        n: usize,
        rng: Option<&mut Pcg64>,
        par: Parallelism,
    ) -> Vec<f32> {
        assert_eq!(w.len(), k * n);
        self.par_matmul_prepared(a, m, &PreparedWeights::from_dense(w, k, n), rng, par)
    }

    /// Exact digital matmul (the "infinite ADC" bound / fp32 baseline).
    pub fn exact_matmul(a: &[f32], m: usize, k: usize, w: &[f32], n: usize) -> Vec<f32> {
        let mut out = vec![0.0f32; m * n];
        for i in 0..m {
            Self::exact_row(a, k, w, n, i, &mut out[i * n..(i + 1) * n]);
        }
        out
    }

    /// [`Self::exact_matmul`] with rows fanned out over the worker pool.
    /// Each output row is an independent unit with a fixed accumulation
    /// order, so this too is bit-identical to the serial baseline.
    pub fn par_exact_matmul(
        a: &[f32],
        m: usize,
        k: usize,
        w: &[f32],
        n: usize,
        par: Parallelism,
    ) -> Vec<f32> {
        let threads = par.thread_count().min(m);
        if threads <= 1 {
            return Self::exact_matmul(a, m, k, w, n);
        }
        let rows = parallel::run_units(threads, m, |i| {
            let mut row = vec![0.0f32; n];
            Self::exact_row(a, k, w, n, i, &mut row);
            row
        });
        let mut out = Vec::with_capacity(m * n);
        for row in rows {
            out.extend_from_slice(&row);
        }
        out
    }

    /// One exact-matmul output row (shared by the serial and tiled paths).
    fn exact_row(a: &[f32], k: usize, w: &[f32], n: usize, i: usize, out_row: &mut [f32]) {
        for kk in 0..k {
            let av = a[i * k + kk];
            if av == 0.0 {
                continue;
            }
            let w_row = &w[kk * n..kk * n + n];
            for (o, &wv) in out_row.iter_mut().zip(w_row) {
                *o += av * wv;
            }
        }
    }

    /// Ops per full MAC for throughput accounting (MAC = 2 ops).
    pub fn op_count(m: usize, k: usize, n: usize) -> u64 {
        2 * m as u64 * k as u64 * n as u64
    }

    /// Number of data-independent units one `[m,k] × [k,n]` bank MAC
    /// fans out to on the worker pool — the single source of truth for
    /// the tiling grid (`mapping::ConvMapping::engine_units` delegates
    /// here).
    pub fn unit_count(m: usize, k: usize, n: usize) -> usize {
        UnitGrid::new(m, k, n).units
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rand_mat(rng: &mut Pcg64, len: usize, lo: f64, hi: f64) -> Vec<f32> {
        (0..len).map(|_| rng.range(lo, hi) as f32).collect()
    }

    #[test]
    fn matches_exact_for_small_values() {
        // With tiny MACs the ADC LSB (30.5) dominates — instead check the
        // engine tracks the exact result within quantization error bounds
        // on a moderate problem.
        let mut rng = Pcg64::seeded(3);
        let (m, k, n) = (8, 128, 16);
        let a = rand_mat(&mut rng, m * k, 0.0, 1.0);
        let w = rand_mat(&mut rng, k * n, -0.5, 0.5);
        let eng = PimEngine::tt();
        let got = eng.pim_matmul(&a, m, k, &w, n, None);
        let want = PimEngine::exact_matmul(&a, m, k, &w, n);
        let scale = want.iter().map(|x| x.abs()).fold(0.0f32, f32::max);
        let max_err = got
            .iter()
            .zip(&want)
            .map(|(g, e)| (g - e).abs())
            .fold(0.0f32, f32::max);
        // Quantization + two-bank recombination error: bounded by a modest
        // fraction of full scale for a 1-block problem.
        assert!(max_err < 0.35 * scale, "max_err={max_err} scale={scale}");
        // And correlation with the exact result should be very high.
        let gv: Vec<f64> = got.iter().map(|&x| x as f64).collect();
        let wv: Vec<f64> = want.iter().map(|&x| x as f64).collect();
        assert!(crate::util::stats::pearson(&gv, &wv) > 0.97);
    }

    #[test]
    fn zero_activation_gives_zero() {
        let eng = PimEngine::tt();
        let a = vec![0.0f32; 2 * 128];
        let w = vec![0.3f32; 128 * 4];
        let out = eng.pim_matmul(&a, 2, 128, &w, 4, None);
        assert!(out.iter().all(|&x| x == 0.0), "{out:?}");
    }

    #[test]
    fn blockwise_quantization_matches_manual() {
        // k = 200 → blocks of 128 + 72; verify the engine quantizes each
        // block independently (the hardware property).
        let mut rng = Pcg64::seeded(9);
        let (m, k, n) = (3, 200, 5);
        let a_q: Vec<u8> = (0..m * k).map(|_| rng.below(16) as u8).collect();
        let bank: Vec<u8> = (0..k * n).map(|_| rng.below(16) as u8).collect();
        let qa = QuantizedActs { data: a_q.clone(), m, k, scale: 1.0 };
        let eng = PimEngine::tt();
        let got = eng.bank_mac(&qa, &bank, n, None);
        // Manual recomputation.
        for i in 0..m {
            for j in 0..n {
                let mut want = 0.0f32;
                for b in 0..4u32 {
                    for (k0, k1) in [(0usize, 128usize), (128, 200)] {
                        let mac: u32 = (k0..k1)
                            .filter(|&kk| (a_q[i * k + kk] >> b) & 1 == 1)
                            .map(|kk| bank[kk * n + j] as u32)
                            .sum();
                        want += (1u32 << b) as f32
                            * eng.transfer.quantize_mac(mac as f64, true) as f32;
                    }
                }
                let g = got[i * n + j];
                // f32 accumulation-order tolerance.
                let tol = 1e-3 + 1e-6 * want.abs();
                assert!((g - want).abs() < tol, "({i},{j}): {g} vs {want}");
            }
        }
    }

    #[test]
    fn noise_perturbs_but_preserves_signal() {
        let mut rng = Pcg64::seeded(5);
        let (m, k, n) = (4, 128, 8);
        let a = rand_mat(&mut rng, m * k, 0.0, 1.0);
        let w = rand_mat(&mut rng, k * n, -0.5, 0.5);
        let clean = PimEngine::tt().pim_matmul(&a, m, k, &w, n, None);
        let noisy_eng = PimEngine::tt().with_noise(0.3);
        let mut nrng = Pcg64::seeded(77);
        let noisy = noisy_eng.pim_matmul(&a, m, k, &w, n, Some(&mut nrng));
        let diff: f64 = clean
            .iter()
            .zip(&noisy)
            .map(|(c, x)| (c - x).abs() as f64)
            .sum::<f64>()
            / clean.len() as f64;
        assert!(diff > 0.0, "noise must perturb");
        let cv: Vec<f64> = clean.iter().map(|&x| x as f64).collect();
        let nv: Vec<f64> = noisy.iter().map(|&x| x as f64).collect();
        assert!(crate::util::stats::pearson(&cv, &nv) > 0.9);
    }

    #[test]
    fn noise_deterministic_with_seed() {
        let (m, k, n) = (2, 64, 3);
        let a = vec![0.5f32; m * k];
        let w = vec![0.25f32; k * n];
        let eng = PimEngine::tt().with_noise(0.5);
        let x = eng.pim_matmul(&a, m, k, &w, n, Some(&mut Pcg64::seeded(1)));
        let y = eng.pim_matmul(&a, m, k, &w, n, Some(&mut Pcg64::seeded(1)));
        assert_eq!(x, y);
    }

    #[test]
    fn par_matmul_bit_identical_to_serial() {
        // Ragged everywhere: k spans 2 blocks (128 + 72), n spans 2 tiles
        // (128 + 5). Noiseless and noisy, several thread counts.
        let mut rng = Pcg64::seeded(21);
        let (m, k, n) = (5, 200, 133);
        let a = rand_mat(&mut rng, m * k, 0.0, 1.0);
        let w = rand_mat(&mut rng, k * n, -0.5, 0.5);
        for sigma in [None, Some(0.4)] {
            let eng = match sigma {
                None => PimEngine::tt(),
                Some(s) => PimEngine::tt().with_noise(s),
            };
            let mk_rng = || sigma.map(|_| Pcg64::seeded(7));
            let mut base_rng = mk_rng();
            let serial = eng.pim_matmul(&a, m, k, &w, n, base_rng.as_mut());
            for t in [2usize, 3, 7] {
                let mut r = mk_rng();
                let par =
                    eng.par_matmul(&a, m, k, &w, n, r.as_mut(), Parallelism::threads(t));
                assert_eq!(serial, par, "sigma={sigma:?} threads={t}");
            }
        }
    }

    #[test]
    fn prepared_matmul_bit_identical_to_oneshot() {
        // Ragged shape, noiseless and noisy: the prepared program must
        // reproduce the one-shot path bit-for-bit (and advance a caller
        // RNG identically).
        let mut rng = Pcg64::seeded(61);
        let (m, k, n) = (4, 200, 133);
        let a = rand_mat(&mut rng, m * k, 0.0, 1.0);
        let w = rand_mat(&mut rng, k * n, -0.5, 0.5);
        for sigma in [None, Some(0.4)] {
            let eng = match sigma {
                None => PimEngine::tt(),
                Some(s) => PimEngine::tt().with_noise(s),
            };
            let program = eng.prepare(&w, k, n);
            let mut r1 = sigma.map(|_| Pcg64::seeded(3));
            let oneshot = eng.pim_matmul(&a, m, k, &w, n, r1.as_mut());
            let mut r2 = sigma.map(|_| Pcg64::seeded(3));
            let prepared = eng.matmul_prepared(&a, m, &program, r2.as_mut());
            assert_eq!(oneshot, prepared, "sigma={sigma:?}");
            if let (Some(mut r1), Some(mut r2)) = (r1, r2) {
                assert_eq!(r1.next_u64(), r2.next_u64(), "rng state diverged");
            }
        }
    }

    #[test]
    fn par_exact_matmul_bit_identical() {
        let mut rng = Pcg64::seeded(33);
        let (m, k, n) = (7, 50, 13);
        let a = rand_mat(&mut rng, m * k, -1.0, 1.0);
        let w = rand_mat(&mut rng, k * n, -1.0, 1.0);
        let serial = PimEngine::exact_matmul(&a, m, k, &w, n);
        for t in [2usize, 4] {
            let par = PimEngine::par_exact_matmul(&a, m, k, &w, n, Parallelism::threads(t));
            assert_eq!(serial, par, "threads={t}");
        }
    }

    #[test]
    fn engine_parallelism_config_matches_explicit() {
        let mut rng = Pcg64::seeded(55);
        let (m, k, n) = (4, 130, 6);
        let a = rand_mat(&mut rng, m * k, 0.0, 1.0);
        let w = rand_mat(&mut rng, k * n, -0.5, 0.5);
        let serial = PimEngine::tt().pim_matmul(&a, m, k, &w, n, None);
        let threaded = PimEngine::tt()
            .with_parallelism(Parallelism::threads(3))
            .pim_matmul(&a, m, k, &w, n, None);
        assert_eq!(serial, threaded);
    }

    #[test]
    fn scalar_and_bitplane_kernels_bit_identical() {
        // The full differential harness lives in
        // rust/tests/simd_parity.rs; this is the in-module smoke test on
        // a ragged shape (k = 128 + 72, n = 128 + 5), noiseless + noisy.
        let mut rng = Pcg64::seeded(71);
        let (m, k, n) = (3, 200, 133);
        let a = rand_mat(&mut rng, m * k, 0.0, 1.0);
        let w = rand_mat(&mut rng, k * n, -0.5, 0.5);
        for sigma in [None, Some(0.4)] {
            let simd = match sigma {
                None => PimEngine::tt(),
                Some(s) => PimEngine::tt().with_noise(s),
            };
            assert!(simd.kernel.uses_bit_planes(), "bit-plane kernel is the default");
            let scalar = simd.clone().with_kernel(MacKernel::Scalar);
            let mut r1 = sigma.map(|_| Pcg64::seeded(13));
            let mut r2 = sigma.map(|_| Pcg64::seeded(13));
            let x = simd.pim_matmul(&a, m, k, &w, n, r1.as_mut());
            let y = scalar.pim_matmul(&a, m, k, &w, n, r2.as_mut());
            assert_eq!(x, y, "sigma={sigma:?}");
            if let (Some(mut r1), Some(mut r2)) = (r1, r2) {
                assert_eq!(r1.next_u64(), r2.next_u64(), "rng state diverged");
            }
        }
    }

    #[test]
    fn skip_stats_shared_across_clones_and_output_neutral() {
        // All-zero activations: every k-word is act-skipped, output is
        // exactly zero, and a clone reports into the same counters.
        let (m, k, n) = (2, 128, 8);
        let a = vec![0.0f32; m * k];
        let w = vec![0.3f32; k * n];
        let eng = PimEngine::tt();
        let clone = eng.clone();
        let out = clone.pim_matmul(&a, m, k, &w, n, None);
        assert!(out.iter().all(|&x| x == 0.0));
        assert!(eng.skip_stats().act_words_skipped() > 0, "all-zero acts must skip");
        assert_eq!(eng.skip_stats().words_visited(), eng.skip_stats().act_words_skipped());
        assert_eq!(
            eng.skip_stats().act_words_skipped(),
            clone.skip_stats().act_words_skipped(),
            "clones share the Arc'd counters"
        );
        assert_eq!(eng.skip_stats().act_skip_fraction(), 1.0);
        eng.skip_stats().reset();
        assert_eq!(clone.skip_stats().words_visited(), 0);
    }

    #[test]
    fn thread_default_kernel_scopes_new_engines() {
        assert_eq!(MacKernel::thread_default(), MacKernel::BitPlane);
        MacKernel::set_thread_default(MacKernel::Scalar);
        let eng = PimEngine::tt();
        MacKernel::set_thread_default(MacKernel::BitPlane);
        assert_eq!(eng.kernel, MacKernel::Scalar);
        assert_eq!(PimEngine::tt().kernel, MacKernel::BitPlane);
    }

    #[test]
    fn uncalibrated_loses_resolution() {
        // The uncalibrated ADC wastes dynamic range ⇒ larger quantization
        // error on mid-range MACs.
        let cal = PimEngine::tt();
        let uncal = PimEngine::tt().uncalibrated();
        let mut err_cal = 0.0;
        let mut err_uncal = 0.0;
        for mac in (0..=MAC_FULLSCALE).step_by(3) {
            err_cal += (cal.transfer.quantize_mac(mac as f64, true) - mac as f64).abs();
            err_uncal +=
                (uncal.transfer.quantize_mac(mac as f64, false) - mac as f64).abs();
        }
        assert!(err_uncal > 1.3 * err_cal, "{err_uncal} vs {err_cal}");
    }
}
