//! The PIM execution engine: quantized matmuls through the analog pipeline.
//!
//! This is the Rust-native counterpart of the L1 kernel: identical math to
//! `kernels/ref.py::pim_mac` (bit-serial planes, per-128-row-block ADC
//! quantization via [`TransferModel`], digital shift-add, pos/neg bank
//! subtraction). Used by the figure generators, the retention/serving
//! examples, the benches, and as the ground truth the PJRT-executed HLO is
//! cross-checked against.
//!
//! Hot path: integer bit-plane accumulation + an exact ADC LUT (the analog
//! transfer is a pure function of an integer MAC ≤ 1920).

use crate::consts::ARRAY_ROWS;
use crate::device::Corner;
use crate::util::rng::Pcg64;

use super::quant::{quantize_acts, quantize_weights, QuantizedActs};
use super::transfer::{TransferModel, ADC_CODES, MAC_FULLSCALE};

/// Engine configuration + precomputed state.
#[derive(Clone, Debug)]
pub struct PimEngine {
    /// The analog transfer model (corner-specific).
    pub transfer: TransferModel,
    /// Calibrated ADC references (Fig. 12a) vs full-VDD uncalibrated.
    pub calibrated: bool,
    /// Per-conversion ADC noise sigma in code units (None = noiseless).
    pub noise_sigma_codes: Option<f64>,
    lut: Vec<f32>,
}

impl PimEngine {
    /// Engine for a corner, calibrated references, noiseless.
    pub fn new(corner: Corner) -> PimEngine {
        let transfer = TransferModel::new(corner);
        PimEngine {
            transfer,
            calibrated: true,
            noise_sigma_codes: None,
            lut: transfer.quantize_lut(true),
        }
    }

    /// Typical-corner engine (the common case).
    pub fn tt() -> PimEngine {
        Self::new(Corner::TT)
    }

    /// Enable per-conversion ADC noise (sigma in code units).
    pub fn with_noise(mut self, sigma_codes: f64) -> PimEngine {
        self.noise_sigma_codes = Some(sigma_codes);
        self
    }

    /// Switch to the uncalibrated (full-VDD reference) ADC of Fig. 12.
    pub fn uncalibrated(mut self) -> PimEngine {
        self.calibrated = false;
        self.lut = self.transfer.quantize_lut(false);
        self
    }

    /// One unsigned bank MAC: quantized activations [m,k] × bank [k,n]
    /// (u8 weights 0..=15), with per-(128-row block × bit-plane) ADC
    /// quantization. Returns dequantized MAC estimates (integer units).
    ///
    /// Hot-path layout (EXPERIMENTS.md §Perf): all four bit-plane MACs of
    /// a block accumulate in ONE pass over the rows, packed into a u64
    /// (each plane MAC ≤ 1920 < 2¹⁶). The activation nibble expands to a
    /// 4×16-bit spread mask via a 16-entry LUT, so the inner loop is one
    /// u64 multiply-add per (row, column) — ~3.4× over the per-plane-pass
    /// version.
    pub fn bank_mac(&self, a: &QuantizedActs, bank: &[u8], n: usize, rng: Option<&mut Pcg64>) -> Vec<f32> {
        let (m, k) = (a.m, a.k);
        assert_eq!(bank.len(), k * n);
        let lsb = MAC_FULLSCALE as f64 / ADC_CODES as f64;
        // Spread mask: nibble bit b → bit 16·b.
        let spread: [u64; 16] = {
            let mut t = [0u64; 16];
            let mut v = 0usize;
            while v < 16 {
                t[v] = (v as u64 & 1)
                    | ((v as u64 >> 1) & 1) << 16
                    | ((v as u64 >> 2) & 1) << 32
                    | ((v as u64 >> 3) & 1) << 48;
                v += 1;
            }
            t
        };
        let mut out = vec![0.0f32; m * n];
        let mut packed = vec![0u64; n];
        // (Perf note, EXPERIMENTS.md §Perf: pre-widening the bank to u64
        // was tried and reverted — 8× memory traffic lost more than the
        // widening saved. The u8 loads below widen in-register.)
        let mut local_rng = rng.map(|r| r.fork(0x6ba7));
        for i in 0..m {
            let a_row = &a.data[i * k..(i + 1) * k];
            let mut k0 = 0;
            while k0 < k {
                let k1 = (k0 + ARRAY_ROWS).min(k);
                // Powerline accumulation, all four planes at once.
                packed.iter_mut().for_each(|x| *x = 0);
                for kk in k0..k1 {
                    let mask = spread[a_row[kk] as usize];
                    if mask == 0 {
                        continue;
                    }
                    let w_row = &bank[kk * n..kk * n + n];
                    for (acc, &w) in packed.iter_mut().zip(w_row) {
                        *acc += mask * w as u64;
                    }
                }
                // WCC + S&H + SAR ADC, one conversion per word column per
                // plane; digital shift-add recombination.
                let out_row = &mut out[i * n..(i + 1) * n];
                match local_rng.as_mut() {
                    None => {
                        for (o, &p) in out_row.iter_mut().zip(packed.iter()) {
                            *o += self.lut[(p & 0xFFFF) as usize]
                                + 2.0 * self.lut[((p >> 16) & 0xFFFF) as usize]
                                + 4.0 * self.lut[((p >> 32) & 0xFFFF) as usize]
                                + 8.0 * self.lut[((p >> 48) & 0xFFFF) as usize];
                        }
                    }
                    Some(r) => {
                        let sigma = self.noise_sigma_codes.unwrap_or(0.0) * lsb;
                        for (o, &p) in out_row.iter_mut().zip(packed.iter()) {
                            for b in 0..4u32 {
                                let mac = ((p >> (16 * b)) & 0xFFFF) as usize;
                                let noise = r.normal(0.0, sigma) as f32;
                                *o += (1u32 << b) as f32 * (self.lut[mac] + noise);
                            }
                        }
                    }
                }
                k0 = k1;
            }
        }
        out
    }

    /// Full signed PIM matmul: quantize, run both banks, subtract in the
    /// digital domain, rescale. `a` is [m,k] (non-negative, e.g. post-ReLU);
    /// `w` is [k,n] signed.
    pub fn pim_matmul(
        &self,
        a: &[f32],
        m: usize,
        k: usize,
        w: &[f32],
        n: usize,
        rng: Option<&mut Pcg64>,
    ) -> Vec<f32> {
        let qa = quantize_acts(a, m, k);
        let qw = quantize_weights(w, k, n);
        let mut rng = rng;
        let pos = self.bank_mac(&qa, &qw.pos, n, rng.as_deref_mut());
        let neg = self.bank_mac(&qa, &qw.neg, n, rng.as_deref_mut());
        pos.iter()
            .zip(neg.iter())
            .enumerate()
            .map(|(i, (p, q))| (p - q) * qa.scale * qw.scale[i % n])
            .collect()
    }

    /// Exact digital matmul (the "infinite ADC" bound / fp32 baseline).
    pub fn exact_matmul(a: &[f32], m: usize, k: usize, w: &[f32], n: usize) -> Vec<f32> {
        let mut out = vec![0.0f32; m * n];
        for i in 0..m {
            for kk in 0..k {
                let av = a[i * k + kk];
                if av == 0.0 {
                    continue;
                }
                let w_row = &w[kk * n..kk * n + n];
                let out_row = &mut out[i * n..i * n + n];
                for (o, &wv) in out_row.iter_mut().zip(w_row) {
                    *o += av * wv;
                }
            }
        }
        out
    }

    /// Ops per full MAC for throughput accounting (MAC = 2 ops).
    pub fn op_count(m: usize, k: usize, n: usize) -> u64 {
        2 * m as u64 * k as u64 * n as u64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rand_mat(rng: &mut Pcg64, len: usize, lo: f64, hi: f64) -> Vec<f32> {
        (0..len).map(|_| rng.range(lo, hi) as f32).collect()
    }

    #[test]
    fn matches_exact_for_small_values() {
        // With tiny MACs the ADC LSB (30.5) dominates — instead check the
        // engine tracks the exact result within quantization error bounds
        // on a moderate problem.
        let mut rng = Pcg64::seeded(3);
        let (m, k, n) = (8, 128, 16);
        let a = rand_mat(&mut rng, m * k, 0.0, 1.0);
        let w = rand_mat(&mut rng, k * n, -0.5, 0.5);
        let eng = PimEngine::tt();
        let got = eng.pim_matmul(&a, m, k, &w, n, None);
        let want = PimEngine::exact_matmul(&a, m, k, &w, n);
        let scale = want.iter().map(|x| x.abs()).fold(0.0f32, f32::max);
        let max_err = got
            .iter()
            .zip(&want)
            .map(|(g, e)| (g - e).abs())
            .fold(0.0f32, f32::max);
        // Quantization + two-bank recombination error: bounded by a modest
        // fraction of full scale for a 1-block problem.
        assert!(max_err < 0.35 * scale, "max_err={max_err} scale={scale}");
        // And correlation with the exact result should be very high.
        let gv: Vec<f64> = got.iter().map(|&x| x as f64).collect();
        let wv: Vec<f64> = want.iter().map(|&x| x as f64).collect();
        assert!(crate::util::stats::pearson(&gv, &wv) > 0.97);
    }

    #[test]
    fn zero_activation_gives_zero() {
        let eng = PimEngine::tt();
        let a = vec![0.0f32; 2 * 128];
        let w = vec![0.3f32; 128 * 4];
        let out = eng.pim_matmul(&a, 2, 128, &w, 4, None);
        assert!(out.iter().all(|&x| x == 0.0), "{out:?}");
    }

    #[test]
    fn blockwise_quantization_matches_manual() {
        // k = 200 → blocks of 128 + 72; verify the engine quantizes each
        // block independently (the hardware property).
        let mut rng = Pcg64::seeded(9);
        let (m, k, n) = (3, 200, 5);
        let a_q: Vec<u8> = (0..m * k).map(|_| rng.below(16) as u8).collect();
        let bank: Vec<u8> = (0..k * n).map(|_| rng.below(16) as u8).collect();
        let qa = QuantizedActs { data: a_q.clone(), m, k, scale: 1.0 };
        let eng = PimEngine::tt();
        let got = eng.bank_mac(&qa, &bank, n, None);
        // Manual recomputation.
        for i in 0..m {
            for j in 0..n {
                let mut want = 0.0f32;
                for b in 0..4u32 {
                    for (k0, k1) in [(0usize, 128usize), (128, 200)] {
                        let mac: u32 = (k0..k1)
                            .filter(|&kk| (a_q[i * k + kk] >> b) & 1 == 1)
                            .map(|kk| bank[kk * n + j] as u32)
                            .sum();
                        want += (1u32 << b) as f32
                            * eng.transfer.quantize_mac(mac as f64, true) as f32;
                    }
                }
                let g = got[i * n + j];
                // f32 accumulation-order tolerance.
                let tol = 1e-3 + 1e-6 * want.abs();
                assert!((g - want).abs() < tol, "({i},{j}): {g} vs {want}");
            }
        }
    }

    #[test]
    fn noise_perturbs_but_preserves_signal() {
        let mut rng = Pcg64::seeded(5);
        let (m, k, n) = (4, 128, 8);
        let a = rand_mat(&mut rng, m * k, 0.0, 1.0);
        let w = rand_mat(&mut rng, k * n, -0.5, 0.5);
        let clean = PimEngine::tt().pim_matmul(&a, m, k, &w, n, None);
        let noisy_eng = PimEngine::tt().with_noise(0.3);
        let mut nrng = Pcg64::seeded(77);
        let noisy = noisy_eng.pim_matmul(&a, m, k, &w, n, Some(&mut nrng));
        let diff: f64 = clean
            .iter()
            .zip(&noisy)
            .map(|(c, x)| (c - x).abs() as f64)
            .sum::<f64>()
            / clean.len() as f64;
        assert!(diff > 0.0, "noise must perturb");
        let cv: Vec<f64> = clean.iter().map(|&x| x as f64).collect();
        let nv: Vec<f64> = noisy.iter().map(|&x| x as f64).collect();
        assert!(crate::util::stats::pearson(&cv, &nv) > 0.9);
    }

    #[test]
    fn noise_deterministic_with_seed() {
        let (m, k, n) = (2, 64, 3);
        let a = vec![0.5f32; m * k];
        let w = vec![0.25f32; k * n];
        let eng = PimEngine::tt().with_noise(0.5);
        let x = eng.pim_matmul(&a, m, k, &w, n, Some(&mut Pcg64::seeded(1)));
        let y = eng.pim_matmul(&a, m, k, &w, n, Some(&mut Pcg64::seeded(1)));
        assert_eq!(x, y);
    }

    #[test]
    fn uncalibrated_loses_resolution() {
        // The uncalibrated ADC wastes dynamic range ⇒ larger quantization
        // error on mid-range MACs.
        let cal = PimEngine::tt();
        let uncal = PimEngine::tt().uncalibrated();
        let mut err_cal = 0.0;
        let mut err_uncal = 0.0;
        for mac in (0..=MAC_FULLSCALE).step_by(3) {
            err_cal += (cal.transfer.quantize_mac(mac as f64, true) - mac as f64).abs();
            err_uncal +=
                (uncal.transfer.quantize_mac(mac as f64, false) - mac as f64).abs();
        }
        assert!(err_uncal > 1.3 * err_cal, "{err_uncal} vs {err_cal}");
    }
}
