//! The canonical analog transfer model: integer weighted MAC → powerline
//! current → sampled voltage → 6-bit SAR code → MAC estimate.
//!
//! CROSS-LANGUAGE CONTRACT: every constant and equation here is mirrored in
//! `python/compile/hw_model.py` (`line_current`, `sampled_voltage`,
//! `adc_code`, `mac_estimate_from_code`) and `kernels/ref.py::adc_transfer`.
//! Change one side and the runtime cross-check
//! (`rust/tests/runtime_crosscheck.rs`) will fail.
//!
//! Derivation of the compression term: the active powerline is pulled to
//! V_REF while cells source `I_cell = (VDD − v_line)/R_path`; the summed
//! current drops `I·R_LOAD` across the line + WCC input stage, so to first
//! order `I = I_ideal / (1 + I_ideal·R_LOAD/V_SWING)` — the FF corner's
//! stronger drive (larger `I_ideal`, larger mirror droop) bends the curve
//! exactly as Fig. 11(a) shows.

use crate::consts::{ADC_BITS, ARRAY_ROWS, VDD, V_REFN_CAL, V_REFP_CAL, V_REF_UNCAL};
use crate::device::Corner;

/// Max ADC code (63 for 6 bits).
pub const ADC_CODES: u32 = (1 << ADC_BITS) - 1;
/// Per-bit-plane full-scale weighted MAC: 128 rows × weight 15.
pub const MAC_FULLSCALE: u32 = (ARRAY_ROWS as u32) * 15;
/// WCC reference voltage during sampling (V) — `hw_model.V_REF`.
pub const V_REF: f64 = 0.30;
/// Series FET resistance of the cell PIM path at TT (Ω) — `R_FETS_TT`.
pub const R_FETS_TT: f64 = 6.0e3;
/// Sampled-voltage calibration span, upper end (V) — Fig. 12's 660 mV
/// reference sits just above this.
pub const V_SAMP_MAX: f64 = 0.655;
/// Sampled-voltage calibration span, lower end (V).
pub const V_SAMP_MIN: f64 = 0.092;

/// The transfer model for one corner.
///
/// # Examples
///
/// Quantize an integer MAC through the full analog pipeline (current →
/// sampled voltage → 6-bit SAR code → MAC estimate); the calibrated ADC
/// keeps the estimate within ~1.5 LSB (≈ 46 integer units) of the ideal:
///
/// ```
/// use nvm_in_cache::pim::transfer::MAC_FULLSCALE;
/// use nvm_in_cache::pim::TransferModel;
///
/// let tt = TransferModel::tt();
/// for mac in [0.0, 480.0, 960.0, MAC_FULLSCALE as f64] {
///     let estimate = tt.quantize_mac(mac, true);
///     assert!((estimate - mac).abs() < 46.0, "mac={mac} estimate={estimate}");
/// }
/// ```
#[derive(Clone, Copy, Debug)]
pub struct TransferModel {
    /// Process corner the model describes.
    pub corner: Corner,
    /// Per-cell LRS unit current (A): (VDD−V_REF)/(R_LRS+R_FETS) × drive.
    pub i_unit: f64,
    /// Line + WCC input loading (Ω).
    pub r_load: f64,
    /// Fixed transimpedance, trimmed once at TT (V/A).
    pub r_ti: f64,
}

impl TransferModel {
    /// Transfer model for a corner (TT-trimmed transimpedance).
    pub fn new(corner: Corner) -> TransferModel {
        let i_unit_tt = (VDD - V_REF) / (crate::consts::R_LRS + R_FETS_TT);
        let (scale, r_load) = match corner {
            Corner::SS => (0.80, 0.6),
            Corner::TT => (1.00, 0.8),
            Corner::FF => (1.25, 3.2),
        };
        // r_ti is fixed by the TT calibration (the S&H/WCC is trimmed at
        // the typical corner), so SS/FF curves shift/bend — Fig. 10.
        let v_swing = VDD - V_REF;
        let i_fs_tt_ideal = MAC_FULLSCALE as f64 * i_unit_tt;
        let i_fs_tt = i_fs_tt_ideal / (1.0 + i_fs_tt_ideal * 0.8 / v_swing);
        let r_ti = (V_SAMP_MAX - V_SAMP_MIN) / i_fs_tt;
        TransferModel { corner, i_unit: i_unit_tt * scale, r_load, r_ti }
    }

    /// Typical-corner model (the common case).
    pub fn tt() -> TransferModel {
        Self::new(Corner::TT)
    }

    /// Powerline current for an integer weighted MAC value (one bit-plane).
    pub fn line_current(&self, mac: f64) -> f64 {
        let v_swing = VDD - V_REF;
        let i_ideal = mac * self.i_unit;
        i_ideal / (1.0 + i_ideal * self.r_load / v_swing)
    }

    /// Sample-and-hold output voltage (V): V0 − R_ti·I ("VDD − MAC").
    pub fn sampled_voltage(&self, mac: f64) -> f64 {
        V_SAMP_MAX - self.r_ti * self.line_current(mac)
    }

    /// 6-bit SAR conversion of a sampled voltage; returns the
    /// post-processing-inverted code (monotone increasing with MAC).
    pub fn adc_code(&self, v: f64, calibrated: bool) -> u32 {
        let (lo, hi) = if calibrated {
            (V_REFN_CAL, V_REFP_CAL)
        } else {
            (0.0, V_REF_UNCAL)
        };
        let x = (v - lo) / (hi - lo);
        let code = (x * ADC_CODES as f64).round().clamp(0.0, ADC_CODES as f64) as u32;
        ADC_CODES - code
    }

    /// Inverse linear mapping of a code back to the MAC dynamic range.
    pub fn mac_estimate(&self, code: u32) -> f64 {
        code as f64 * (MAC_FULLSCALE as f64 / ADC_CODES as f64)
    }

    /// The full pipeline for one bit-plane partial sum.
    pub fn quantize_mac(&self, mac: f64, calibrated: bool) -> f64 {
        self.mac_estimate(self.adc_code(self.sampled_voltage(mac), calibrated))
    }

    /// Continuous (un-rounded) transfer: MAC → nonlinearly-compressed MAC
    /// equivalent, no ADC rounding. Mirrors `ref.transfer_continuous` —
    /// used by the §V-E Table II activation-level emulation, where the
    /// 6-bit signed quantization is applied separately.
    pub fn transfer_continuous(&self, mac: f64) -> f64 {
        let v = self.sampled_voltage(mac);
        let x = (v - V_REFN_CAL) / (V_REFP_CAL - V_REFN_CAL);
        (1.0 - x) * MAC_FULLSCALE as f64
    }

    /// Precomputed LUT over all integer MAC values [0, MAC_FULLSCALE] —
    /// the hot-path form used by [`super::engine`]. (The analog transfer is
    /// a pure function of an integer ≤ 1920, so this is exact.)
    pub fn quantize_lut(&self, calibrated: bool) -> Vec<f32> {
        (0..=MAC_FULLSCALE)
            .map(|m| self.quantize_mac(m as f64, calibrated) as f32)
            .collect()
    }

    /// Least-squares polynomial fit of mac → sampled voltage — the §V-E
    /// "curve-fitted polynomial" used by the accuracy pipeline.
    pub fn voltage_polynomial(&self, degree: usize) -> Vec<f64> {
        let macs: Vec<f64> = (0..=MAC_FULLSCALE).step_by(16).map(|m| m as f64).collect();
        let vs: Vec<f64> = macs.iter().map(|&m| self.sampled_voltage(m)).collect();
        crate::util::fit::poly_fit(&macs, &vs, degree)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::stats;

    #[test]
    fn endpoints_match_calibration() {
        let tt = TransferModel::tt();
        let v0 = tt.sampled_voltage(0.0);
        let v1 = tt.sampled_voltage(MAC_FULLSCALE as f64);
        assert!((v0 - V_SAMP_MAX).abs() < 1e-12, "v0 = {v0}");
        assert!((v1 - V_SAMP_MIN).abs() < 1e-9, "v1 = {v1}");
    }

    #[test]
    fn calibrated_adc_spans_full_code_range() {
        // Fig. 12(a): after calibration the full 6-bit space is exercised.
        let tt = TransferModel::tt();
        let c0 = tt.adc_code(tt.sampled_voltage(0.0), true);
        let c1 = tt.adc_code(tt.sampled_voltage(MAC_FULLSCALE as f64), true);
        assert!(c0 <= 1, "code at MAC=0: {c0}");
        assert!(c1 >= 62, "code at fullscale: {c1}");
    }

    #[test]
    fn uncalibrated_adc_compressed_range() {
        // Fig. 12(a): the uncalibrated ADC wastes dynamic range. The paper
        // reports raw codes 7–48 (≈65 % of range); our calibration span
        // [92, 655] mV gives raw 7–52 (≈71 %) — same qualitative
        // compression + systematic offset, see EXPERIMENTS.md E6.
        let tt = TransferModel::tt();
        let c0 = tt.adc_code(tt.sampled_voltage(0.0), false); // inverted low
        let c1 = tt.adc_code(tt.sampled_voltage(MAC_FULLSCALE as f64), false);
        let span = c1 - c0;
        assert!(c0 >= 8 && c0 <= 14, "low code = {c0}");
        assert!(c1 >= 52 && c1 <= 60, "high code = {c1}");
        assert!((span as f64) < 0.75 * ADC_CODES as f64, "span = {span}");
        // Both endpoints well inside the rails ⇒ wasted code space at both
        // ends, unlike the calibrated configuration.
        assert!(c0 > 1 && c1 < ADC_CODES);
    }

    #[test]
    fn monotone_nondecreasing_codes() {
        for corner in Corner::ALL {
            let m = TransferModel::new(corner);
            let codes: Vec<f64> = (0..=MAC_FULLSCALE)
                .map(|mac| m.adc_code(m.sampled_voltage(mac as f64), true) as f64)
                .collect();
            assert!(
                stats::is_monotonic_nondecreasing(&codes),
                "{corner:?} codes not monotone"
            );
        }
    }

    #[test]
    fn ff_corner_most_nonlinear() {
        // Fig. 11(a): FF deviates from linearity; TT/SS near-linear.
        let macs: Vec<f64> = (0..=MAC_FULLSCALE).step_by(64).map(|m| m as f64).collect();
        let nl = |c: Corner| {
            let m = TransferModel::new(c);
            let is: Vec<f64> = macs.iter().map(|&x| m.line_current(x)).collect();
            stats::nonlinearity_fraction(&macs, &is)
        };
        let (ss, tt, ff) = (nl(Corner::SS), nl(Corner::TT), nl(Corner::FF));
        assert!(ff > 2.0 * tt, "FF {ff} vs TT {tt}");
        assert!(ss <= tt * 1.05, "SS {ss} vs TT {tt}");
        assert!(tt < 0.05, "TT should be near-linear: {tt}");
    }

    #[test]
    fn four_codes_per_weight_step() {
        // Fig. 12(b): each weight increment ≈ 4 ADC codes at 128 rows.
        let tt = TransferModel::tt();
        let code = |w: u32| tt.adc_code(tt.sampled_voltage((128 * w) as f64), true);
        let steps: Vec<f64> = (1..=15).map(|w| (code(w) - code(w - 1)) as f64).collect();
        let mean = steps.iter().sum::<f64>() / steps.len() as f64;
        assert!((mean - 4.0).abs() < 0.5, "mean codes/weight = {mean}");
    }

    #[test]
    fn lut_matches_direct_eval() {
        let tt = TransferModel::tt();
        let lut = tt.quantize_lut(true);
        assert_eq!(lut.len() as u32, MAC_FULLSCALE + 1);
        for mac in [0u32, 1, 64, 777, 1920] {
            assert_eq!(lut[mac as usize], tt.quantize_mac(mac as f64, true) as f32);
        }
    }

    #[test]
    fn polynomial_fits_voltage_curve() {
        let tt = TransferModel::tt();
        let poly = tt.voltage_polynomial(3);
        let mut max_err = 0.0f64;
        for mac in (0..=MAC_FULLSCALE).step_by(32) {
            let v = tt.sampled_voltage(mac as f64);
            let p = crate::util::fit::poly_eval(&poly, mac as f64);
            max_err = max_err.max((v - p).abs());
        }
        // Fit error well under one ADC LSB (≈ 9 mV).
        assert!(max_err < 2e-3, "poly fit max err = {max_err}");
    }

    #[test]
    fn quantization_error_bounded_by_lsb() {
        let tt = TransferModel::tt();
        let lsb = MAC_FULLSCALE as f64 / ADC_CODES as f64;
        for mac in (0..=MAC_FULLSCALE).step_by(7) {
            let err = (tt.quantize_mac(mac as f64, true) - mac as f64).abs();
            // Nonlinearity adds systematic error on top of ±LSB/2; at TT the
            // total stays within ~1.5 LSB.
            assert!(err <= 1.5 * lsb, "mac={mac} err={err}");
        }
    }
}
