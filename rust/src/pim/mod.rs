//! PIM compute layer: quantization, the canonical analog transfer model,
//! and the execution engine that runs quantized CNN layers on the
//! simulated 6T-2R arrays.
//!
//! * [`transfer`] — the closed-form weight-sum → current → voltage → ADC
//!   code pipeline. This is the *cross-language contract*: the constants
//!   and equations are mirrored exactly in `python/compile/hw_model.py` /
//!   `kernels/ref.py`, and `rust/tests/runtime_crosscheck.rs` verifies the
//!   AOT-exported kernel HLO against this module.
//! * [`quant`] — 4-bit activation/weight quantization and the
//!   positive/negative weight-bank split (§IV-C).
//! * [`engine`] — the fast vectorized PIM executor (word-wide AND/popcount
//!   bit-plane matmuls + an ADC LUT, with the historical scalar kernel
//!   kept live behind the [`MacKernel`] selector and raced bit-for-bit by
//!   `rust/tests/simd_parity.rs`) used by the figures, benches, and the
//!   coordinator's non-PJRT fallback path. The bit-plane kernel skips
//!   all-zero activation/weight plane words output-neutrally and tallies
//!   them in [`SkipStats`]. See PERFORMANCE.md §8 and §12.
//! * [`parallel`] — the **persistent** worker pool the engine schedules
//!   its MAC units on: one set of parked workers per requested width,
//!   spawned lazily on first use and reused for the life of the process,
//!   with the same atomic-cursor distribution and unit-order collection
//!   as the historical spawn-per-call path — so results are bit-identical
//!   to the serial path at any thread count and steady-state dispatch
//!   spawns zero threads. See PERFORMANCE.md §12.
//! * [`program`] — the compile-once / execute-many layer: prepared weight
//!   programs ([`PreparedWeights`]) and whole compiled networks
//!   ([`CompiledNet`]) mirroring one-time RRAM programming, so the
//!   serving hot loop performs zero weight quantization/packing. See
//!   ARCHITECTURE.md §program and PERFORMANCE.md §amortization.
//! * [`attn`] — the transformer sibling of [`program`]: compiled
//!   encoder blocks ([`CompiledAttnBlock`]) and whole transformer
//!   programs ([`CompiledTransformer`]) whose weight-stationary matmuls
//!   run on prepared banks while the dynamic attention matmuls
//!   (Q·Kᵀ, A·V) execute digitally in every mode, plus the
//!   straight-line [`spec_attn`] specification the compiled path is
//!   pinned against bit-for-bit (`rust/tests/transformer_parity.rs`).
//! * [`shard_exec`] — the pipelined shard executor: drives contiguous
//!   boundary segments of one [`CompiledNet`] as a software pipeline
//!   (shard K runs micro-batch i while shard K−1 runs i+1),
//!   bit-identical to the unsharded forward because every
//!   [`program::InflightRun`] carries its own activations and RNG
//!   stream. The placement/cost half lives in `fleet::shard`. See
//!   ARCHITECTURE.md §fleet/shard and PERFORMANCE.md §10.

pub mod attn;
pub mod engine;
pub mod parallel;
pub mod program;
pub mod quant;
pub mod shard_exec;
pub mod transfer;

pub use attn::{spec_attn, spec_attn_dense, CompiledAttnBlock, CompiledTransformer};
pub use engine::{MacKernel, MacScratch, PimEngine, SkipStats};
pub use parallel::Parallelism;
pub use program::{CompiledNet, PreparedBank, PreparedWeights, ScratchPool, SteppedProgram};
pub use shard_exec::{PipelineTrace, ShardedExecutor};
pub use quant::{PackedActPlanes, QuantizedActs, QuantizedWeights};
pub use transfer::TransferModel;
