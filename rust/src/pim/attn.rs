//! Compiled transformer programs on prepared banks — the attention
//! sibling of [`super::program`].
//!
//! [`CompiledTransformer`] mirrors [`CompiledNet`](super::CompiledNet)
//! exactly: every **weight-stationary** matmul (the fused QKV
//! projection, the attention output projection, both FFN layers, and
//! the pooled classifier head) is a
//! [`CompiledLinear`] whose banks are quantized and packed once at
//! compile, so steady-state serving performs zero weight preparation.
//! The two **dynamic** attention matmuls (Q·Kᵀ and A·V) have no
//! stationary operand — both sides are produced at inference time — so
//! they execute digitally ([`PimEngine::exact_matmul`]) in *every*
//! mode, the standard mapping for weight-stationary analog PIM
//! substrates: programming attention scores into RRAM per token would
//! burn a bank write-cycle budget per inference and break the
//! zero-prepare steady state (`comparison.transformer.
//! steady_state_zero_prepares_attn` pins this).
//!
//! Execution is boundary-stepped ([`SteppedProgram`]): one boundary per
//! encoder block plus the pooled head. The RNG fork discipline per
//! boundary is the [`CompiledNet::step`](super::CompiledNet::step)
//! discipline verbatim — `fork(1)` per prepared linear in hardware-noise
//! modes, `fork(2)` per §V-E post-ADC emulation — and the dynamic
//! attention matmuls draw nothing, so logits *and* trailing RNG state
//! are bit-identical across stepped/merged/pipelined schedules
//! (`rust/tests/transformer_parity.rs`).
//!
//! [`spec_attn`] is the straight-line digital-exact specification of
//! the noiseless hardware-true forward (the transformer counterpart of
//! [`spec_matmul`]); [`spec_attn_dense`] is the fp32 witness for the
//! Baseline mode. Both share [`layer_norm`], [`softmax_rows`],
//! [`attn_context`], and [`mean_pool_seq`] with the compiled path, so a
//! parity failure always localizes to a bank matmul.

use crate::nn::layers;
use crate::nn::transformer::{layer_norm, softmax_rows, TfmConfig, Transformer};
use crate::nn::{ForwardMode, Tensor};
use crate::util::rng::Pcg64;
use crate::{Error, Result};

use super::parallel::Parallelism;
use super::program::{
    spec_matmul, CompiledLinear, InflightRun, PreparedWeights, ScratchPool, SteppedProgram,
};
use super::{PimEngine, TransferModel};

/// One encoder block's compiled layers + norm parameters.
#[derive(Clone, Debug)]
pub struct CompiledAttnBlock {
    /// Parameter prefix (`t{block}`), for reports.
    pub name: String,
    /// Fused QKV projection `[d, 3d]` (bank-resident).
    pub qkv: CompiledLinear,
    /// Attention output projection `[d, d]` (bank-resident).
    pub wo: CompiledLinear,
    /// Pre-attention layernorm gamma.
    pub g1: Vec<f32>,
    /// Pre-attention layernorm beta.
    pub b1: Vec<f32>,
    /// FFN expansion `[d, d_ff]` (bank-resident).
    pub ff1: CompiledLinear,
    /// FFN contraction `[d_ff, d]` (bank-resident).
    pub ff2: CompiledLinear,
    /// Pre-FFN layernorm gamma.
    pub g2: Vec<f32>,
    /// Pre-FFN layernorm beta.
    pub b2: Vec<f32>,
}

/// A whole transformer compiled for execute-many serving — pure data
/// (`Send + Sync`), shareable across replicas and servers like
/// [`CompiledNet`](super::CompiledNet). Built once via
/// [`Transformer::compile`]; executed via the [`SteppedProgram`]
/// surface, so [`crate::coordinator::server::NativeExecutor`] and
/// [`crate::pim::shard_exec::ShardedExecutor`] serve it unchanged.
#[derive(Clone, Debug)]
pub struct CompiledTransformer {
    /// Geometry every boundary body derives its shapes from.
    pub cfg: TfmConfig,
    /// Encoder blocks in execution order.
    pub blocks: Vec<CompiledAttnBlock>,
    /// Mean-pool classifier head (compiled with a zero bias; see
    /// [`Self::head_bias`]).
    pub head: CompiledLinear,
    /// The real head bias, added after the §V-E post-ADC step exactly
    /// as [`CompiledNet::fc_bias`](super::CompiledNet::fc_bias) is.
    pub head_bias: Vec<f32>,
    /// Worker-pool width [`Self::forward`] and [`Self::classify`] run
    /// on (copied from the source [`Transformer`] at compile) — the
    /// persistent `pim::parallel` pool for that width, reused across
    /// every prepared-bank matmul.
    pub parallelism: Parallelism,
}

impl CompiledTransformer {
    /// Compile every weight-stationary layer: dense weights plus
    /// prepared quantized banks, so any [`ForwardMode`] executes
    /// prepare-free.
    pub fn compile(t: &Transformer) -> Result<CompiledTransformer> {
        Self::compile_with(t, true)
    }

    /// Compile the dense layers only (no bank preparation) — what the
    /// one-shot fp32/emulation forwards use.
    pub fn compile_dense(t: &Transformer) -> Result<CompiledTransformer> {
        Self::compile_with(t, false)
    }

    fn compile_with(t: &Transformer, prepare: bool) -> Result<CompiledTransformer> {
        let cfg = t.cfg;
        let p = &t.params;
        let d = cfg.d_model;
        let lin = |name: &str, k: usize, n: usize, bias: &str| -> Result<CompiledLinear> {
            let w = p.get(name)?;
            if w.shape != [k, n] {
                return Err(Error::Artifact(format!(
                    "{name}: shape {:?}, expected [{k}, {n}]",
                    w.shape
                )));
            }
            let b = p.get(bias)?;
            Ok(CompiledLinear::compile(w, &b.data, prepare))
        };
        let mut blocks = Vec::with_capacity(cfg.n_blocks);
        for bi in 0..cfg.n_blocks {
            let pre = format!("t{bi}");
            blocks.push(CompiledAttnBlock {
                name: pre.clone(),
                qkv: lin(&format!("{pre}/wqkv"), d, 3 * d, &format!("{pre}/bqkv"))?,
                wo: lin(&format!("{pre}/wo"), d, d, &format!("{pre}/bo"))?,
                g1: p.get(&format!("{pre}/g1"))?.data.clone(),
                b1: p.get(&format!("{pre}/b1"))?.data.clone(),
                ff1: lin(&format!("{pre}/wf1"), d, cfg.d_ff, &format!("{pre}/bf1"))?,
                ff2: lin(&format!("{pre}/wf2"), cfg.d_ff, d, &format!("{pre}/bf2"))?,
                g2: p.get(&format!("{pre}/g2"))?.data.clone(),
                b2: p.get(&format!("{pre}/b2"))?.data.clone(),
            });
        }
        let head_w = p.get("head/w")?;
        let head_b = p.get("head/b")?;
        let head = CompiledLinear::compile(head_w, &vec![0.0; head_b.len()], prepare);
        Ok(CompiledTransformer {
            cfg,
            blocks,
            head,
            head_bias: head_b.data.clone(),
            parallelism: t.parallelism,
        })
    }

    /// Upgrade a dense-only compile to a fully prepared one (layers that
    /// already carry banks are kept as-is) — the transformer mirror of
    /// [`CompiledNet::prepare_banks`](super::CompiledNet::prepare_banks).
    pub fn prepare_banks(&self) -> CompiledTransformer {
        let lin = |l: &CompiledLinear| -> CompiledLinear {
            let mut l = l.clone();
            if l.prepared.is_none() {
                l.prepared = Some(PreparedWeights::from_dense(
                    &l.dense.data,
                    l.dense.shape[0],
                    l.dense.shape[1],
                ));
            }
            l
        };
        CompiledTransformer {
            cfg: self.cfg,
            blocks: self
                .blocks
                .iter()
                .map(|b| CompiledAttnBlock {
                    name: b.name.clone(),
                    qkv: lin(&b.qkv),
                    wo: lin(&b.wo),
                    g1: b.g1.clone(),
                    b1: b.b1.clone(),
                    ff1: lin(&b.ff1),
                    ff2: lin(&b.ff2),
                    g2: b.g2.clone(),
                    b2: b.b2.clone(),
                })
                .collect(),
            head: lin(&self.head),
            head_bias: self.head_bias.clone(),
            parallelism: self.parallelism,
        }
    }

    /// Do all weight-stationary layers carry prepared banks?
    pub fn fully_prepared(&self) -> bool {
        self.head.prepared.is_some()
            && self.blocks.iter().all(|b| {
                b.qkv.prepared.is_some()
                    && b.wo.prepared.is_some()
                    && b.ff1.prepared.is_some()
                    && b.ff2.prepared.is_some()
            })
    }

    /// Number of merge boundaries: one per encoder block plus the
    /// pooled head.
    pub fn boundaries(&self) -> usize {
        self.blocks.len() + 1
    }

    /// Forward on [`Self::parallelism`] with a throwaway scratch pool.
    pub fn forward(&self, x: &Tensor, mode: ForwardMode, seed: u64) -> Tensor {
        self.forward_par(x, mode, seed, self.parallelism, &mut ScratchPool::new())
    }

    /// The prepared-execution forward — a full drain of
    /// [`Self::begin`] / [`Self::step`], so the stepped path *is* the
    /// forward and continuous batching cannot drift from it.
    pub fn forward_par(
        &self,
        x: &Tensor,
        mode: ForwardMode,
        seed: u64,
        par: Parallelism,
        scratch: &mut ScratchPool,
    ) -> Tensor {
        let mut run = self.begin(x, seed);
        while !self.step(&mut run, mode, par, scratch) {}
        run.into_logits()
    }

    /// Like [`Self::forward_par`] but returns the completed
    /// [`InflightRun`] so callers can also compare the trailing RNG
    /// state via [`InflightRun::rng_fingerprint`].
    pub fn forward_run(
        &self,
        x: &Tensor,
        mode: ForwardMode,
        seed: u64,
        par: Parallelism,
        scratch: &mut ScratchPool,
    ) -> InflightRun {
        let mut run = self.begin(x, seed);
        while !self.step(&mut run, mode, par, scratch) {}
        run
    }

    /// Open an in-flight execution. `x` may arrive as `[n, s, d]` or as
    /// the executor's NHWC framing (`[n, s, d, 1]`) — any layout with
    /// `n·seq_len·d_model` elements reshapes to the canonical
    /// `[n, s, d]` activation tensor.
    pub fn begin(&self, x: &Tensor, seed: u64) -> InflightRun {
        let n = x.shape[0];
        let (s, d) = (self.cfg.seq_len, self.cfg.d_model);
        assert_eq!(x.data.len(), n * s * d, "input elements vs [n, seq_len, d_model]");
        InflightRun {
            h: Tensor::from_vec(&[n, s, d], x.data.clone()),
            rng: Pcg64::seeded(seed),
            boundary: 0,
        }
    }

    /// Advance one in-flight run by a single boundary (one encoder
    /// block, or the pooled head). Engine construction, layernorm
    /// epsilon, §V-E post-ADC placement, and RNG fork order replicate
    /// [`CompiledNet::step`](super::CompiledNet::step) statement for
    /// statement; the dynamic attention matmuls sit between the QKV and
    /// output-projection bank calls and draw no randomness.
    pub fn step(
        &self,
        run: &mut InflightRun,
        mode: ForwardMode,
        par: Parallelism,
        scratch: &mut ScratchPool,
    ) -> bool {
        assert!(run.boundary < self.boundaries(), "stepping a completed run");
        let engine = match mode {
            ForwardMode::PimHw => Some(PimEngine::tt().with_parallelism(par)),
            ForwardMode::PimHwNoise(sigma) => {
                Some(PimEngine::tt().with_noise(sigma).with_parallelism(par))
            }
            _ => None,
        };
        let emu_sigma: Option<Option<f64>> = match mode {
            ForwardMode::Pim => Some(None),
            ForwardMode::PimNoise(s) => Some(Some(s)),
            _ => None,
        };
        let transfer = TransferModel::tt();
        let hw_noise = matches!(mode, ForwardMode::PimHwNoise(_));
        let rng_opt = |r: &mut Pcg64| -> Option<Pcg64> {
            if hw_noise {
                Some(r.fork(1))
            } else {
                None
            }
        };
        let eng = engine.as_ref();
        // §V-E emulation applied at each bank-layer output (emu modes
        // only); the dynamic attention matmuls are digital and take no
        // post step, exactly as the residual adds and norms don't.
        let post = |t: Tensor, r: &mut Pcg64| -> Tensor {
            match emu_sigma {
                None => t,
                Some(sigma) => {
                    let mut local = r.fork(2);
                    layers::adc_emulate(&t, &transfer, sigma, Some(&mut local))
                }
            }
        };

        let rng = &mut run.rng;
        let cfg = &self.cfg;
        let (s, d) = (cfg.seq_len, cfg.d_model);
        let nblocks = self.blocks.len();
        match run.boundary {
            i if i < nblocks => {
                let blk = &self.blocks[i];
                let n = run.h.shape[0];
                let rows = n * s;
                // Attention sublayer (pre-LN).
                let a = layer_norm(&run.h.data, rows, d, &blk.g1, &blk.b1);
                let a = Tensor::from_vec(&[rows, d], a);
                let mut local = rng_opt(rng);
                let qkv = blk.qkv.forward(&a, eng, local.as_mut(), par, scratch);
                let qkv = post(qkv, rng);
                let ctx = attn_context(&qkv.data, n, cfg);
                let ctx = Tensor::from_vec(&[rows, d], ctx);
                let mut local = rng_opt(rng);
                let proj = blk.wo.forward(&ctx, eng, local.as_mut(), par, scratch);
                let proj = post(proj, rng);
                let h1: Vec<f32> =
                    run.h.data.iter().zip(proj.data.iter()).map(|(x, p)| x + p).collect();
                // FFN sublayer (pre-LN).
                let f = layer_norm(&h1, rows, d, &blk.g2, &blk.b2);
                let f = Tensor::from_vec(&[rows, d], f);
                let mut local = rng_opt(rng);
                let f = blk.ff1.forward(&f, eng, local.as_mut(), par, scratch);
                let f = post(f, rng).relu();
                let mut local = rng_opt(rng);
                let f = blk.ff2.forward(&f, eng, local.as_mut(), par, scratch);
                let f = post(f, rng);
                let out: Vec<f32> =
                    h1.iter().zip(f.data.iter()).map(|(x, p)| x + p).collect();
                run.h = Tensor::from_vec(&[n, s, d], out);
            }
            _ => {
                let n = run.h.shape[0];
                let pooled = mean_pool_seq(&run.h.data, n, s, d);
                let pooled = Tensor::from_vec(&[n, d], pooled);
                let mut local = rng_opt(rng);
                let logits = self.head.forward(&pooled, eng, local.as_mut(), par, scratch);
                let mut logits = post(logits, rng);
                let nc = logits.shape[1];
                for ni in 0..n {
                    for c in 0..nc {
                        logits.data[ni * nc + c] += self.head_bias[c];
                    }
                }
                run.h = logits;
            }
        }
        run.boundary += 1;
        run.boundary >= self.boundaries()
    }

    /// Argmax classification over [`Self::forward_par`] logits on
    /// [`Self::parallelism`], reusing the caller's scratch pool.
    pub fn classify(
        &self,
        x: &Tensor,
        mode: ForwardMode,
        seed: u64,
        scratch: &mut ScratchPool,
    ) -> Vec<u8> {
        let logits = self.forward_par(x, mode, seed, self.parallelism, scratch);
        super::program::logits_to_classes(&logits)
    }
}

impl SteppedProgram for CompiledTransformer {
    fn boundaries(&self) -> usize {
        CompiledTransformer::boundaries(self)
    }

    fn parallelism(&self) -> Parallelism {
        self.parallelism
    }

    fn fully_prepared(&self) -> bool {
        CompiledTransformer::fully_prepared(self)
    }

    fn begin(&self, x: &Tensor, seed: u64) -> InflightRun {
        CompiledTransformer::begin(self, x, seed)
    }

    fn step(
        &self,
        run: &mut InflightRun,
        mode: ForwardMode,
        par: Parallelism,
        scratch: &mut ScratchPool,
    ) -> bool {
        CompiledTransformer::step(self, run, mode, par, scratch)
    }
}

/// Multi-head scaled-dot-product attention context from a fused QKV
/// activation `[n·s, 3d]`: per (sequence, head), scores = Q·Kᵀ/√d_h
/// (digital [`PimEngine::exact_matmul`] — both operands are dynamic),
/// optional causal `-inf` mask, [`softmax_rows`], then context = A·V,
/// heads re-concatenated to `[n·s, d]`. Serial and deterministic: no
/// RNG draws, no bank prepares, no thread-count dependence — shared
/// verbatim by [`CompiledTransformer::step`] and [`spec_attn`].
pub fn attn_context(qkv: &[f32], n: usize, cfg: &TfmConfig) -> Vec<f32> {
    let (s, d, nh) = (cfg.seq_len, cfg.d_model, cfg.n_heads);
    let dh = cfg.head_dim();
    assert_eq!(qkv.len(), n * s * 3 * d);
    let scale = 1.0 / (dh as f32).sqrt();
    let mut ctx = vec![0.0f32; n * s * d];
    let mut q = vec![0.0f32; s * dh];
    let mut kt = vec![0.0f32; dh * s];
    let mut v = vec![0.0f32; s * dh];
    for b in 0..n {
        for hh in 0..nh {
            for t in 0..s {
                let base = (b * s + t) * 3 * d + hh * dh;
                for j in 0..dh {
                    q[t * dh + j] = qkv[base + j];
                    kt[j * s + t] = qkv[base + d + j];
                    v[t * dh + j] = qkv[base + 2 * d + j];
                }
            }
            let mut scores = PimEngine::exact_matmul(&q, s, dh, &kt, s);
            for sc in scores.iter_mut() {
                *sc *= scale;
            }
            if cfg.causal {
                for t in 0..s {
                    for u in t + 1..s {
                        scores[t * s + u] = f32::NEG_INFINITY;
                    }
                }
            }
            softmax_rows(&mut scores, s);
            let c = PimEngine::exact_matmul(&scores, s, s, &v, dh);
            for t in 0..s {
                for j in 0..dh {
                    ctx[(b * s + t) * d + hh * dh + j] = c[t * dh + j];
                }
            }
        }
    }
    ctx
}

/// Mean-pool the sequence axis of an `[n, s, d]` activation buffer to
/// `[n, d]` — the transformer head's
/// [`layers::global_avg_pool`] analogue, same `+= x·scale`
/// accumulation order.
pub fn mean_pool_seq(h: &[f32], n: usize, s: usize, d: usize) -> Vec<f32> {
    assert_eq!(h.len(), n * s * d);
    let scale = 1.0 / s as f32;
    let mut out = vec![0.0f32; n * d];
    for b in 0..n {
        for t in 0..s {
            for j in 0..d {
                out[b * d + j] += h[(b * s + t) * d + j] * scale;
            }
        }
    }
    out
}

/// Straight-line executable **specification** of the noiseless
/// hardware-true transformer forward — the network-level counterpart of
/// [`spec_matmul`], which it calls for every bank matmul (with the
/// unsigned-lane `max(0.0)` input clip the compiled PIM path applies).
/// Dynamic attention runs through the same [`attn_context`] as the
/// compiled path. `CompiledTransformer::forward(x, PimHw, _)` must match
/// this bit for bit at any thread count and on either MAC kernel.
pub fn spec_attn(t: &Transformer, x: &Tensor) -> Result<Tensor> {
    spec_forward(t, x, true)
}

/// The dense fp32 witness: the same straight-line choreography with
/// exact digital matmuls and no activation clip — what
/// `ForwardMode::Baseline` must match bit for bit.
pub fn spec_attn_dense(t: &Transformer, x: &Tensor) -> Result<Tensor> {
    spec_forward(t, x, false)
}

fn spec_forward(t: &Transformer, x: &Tensor, pim: bool) -> Result<Tensor> {
    let cfg = t.cfg;
    let p = &t.params;
    let n = x.shape[0];
    let (s, d) = (cfg.seq_len, cfg.d_model);
    assert_eq!(x.data.len(), n * s * d, "input elements vs [n, seq_len, d_model]");
    let rows = n * s;
    let mm = |input: &[f32], m: usize, w: &Tensor, bias: &[f32]| -> Vec<f32> {
        let (k, c) = (w.shape[0], w.shape[1]);
        let mut out = if pim {
            let clipped: Vec<f32> = input.iter().map(|v| v.max(0.0)).collect();
            spec_matmul(&clipped, m, k, &w.data, c)
        } else {
            PimEngine::exact_matmul(input, m, k, &w.data, c)
        };
        for r in 0..m {
            for j in 0..c {
                out[r * c + j] += bias[j];
            }
        }
        out
    };
    let mut h = x.data.clone();
    for bi in 0..cfg.n_blocks {
        let pre = format!("t{bi}");
        let a = layer_norm(
            &h,
            rows,
            d,
            &p.get(&format!("{pre}/g1"))?.data,
            &p.get(&format!("{pre}/b1"))?.data,
        );
        let qkv = mm(
            &a,
            rows,
            p.get(&format!("{pre}/wqkv"))?,
            &p.get(&format!("{pre}/bqkv"))?.data,
        );
        let ctx = attn_context(&qkv, n, &cfg);
        let proj =
            mm(&ctx, rows, p.get(&format!("{pre}/wo"))?, &p.get(&format!("{pre}/bo"))?.data);
        let h1: Vec<f32> = h.iter().zip(proj.iter()).map(|(x, p)| x + p).collect();
        let f = layer_norm(
            &h1,
            rows,
            d,
            &p.get(&format!("{pre}/g2"))?.data,
            &p.get(&format!("{pre}/b2"))?.data,
        );
        let mut f = mm(
            &f,
            rows,
            p.get(&format!("{pre}/wf1"))?,
            &p.get(&format!("{pre}/bf1"))?.data,
        );
        for v in f.iter_mut() {
            *v = v.max(0.0);
        }
        let f = mm(
            &f,
            rows,
            p.get(&format!("{pre}/wf2"))?,
            &p.get(&format!("{pre}/bf2"))?.data,
        );
        h = h1.iter().zip(f.iter()).map(|(x, p)| x + p).collect();
    }
    let pooled = mean_pool_seq(&h, n, s, d);
    let head_w = p.get("head/w")?;
    let head_b = p.get("head/b")?;
    let nc = head_b.len();
    // The compiled head carries a zero bias (the real bias lands after
    // the §V-E post step); the `+= 0.0` is kept to normalize any `-0.0`
    // matmul output identically.
    let mut logits = mm(&pooled, n, head_w, &vec![0.0; nc]);
    for r in 0..n {
        for j in 0..nc {
            logits[r * nc + j] += head_b.data[j];
        }
    }
    Ok(Tensor::from_vec(&[n, nc], logits))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nn::transformer::test_tfm_params;
    use crate::pim::program::prepare_count;

    fn tiny_cfg() -> TfmConfig {
        TfmConfig { seq_len: 4, d_model: 16, n_heads: 2, d_ff: 32, ..TfmConfig::tiny() }
    }

    fn tiny_tfm(seed: u64) -> Transformer {
        let cfg = tiny_cfg();
        Transformer::new(test_tfm_params(cfg, seed), cfg)
    }

    fn rand_x(n: usize, cfg: TfmConfig, seed: u64) -> Tensor {
        let mut rng = Pcg64::seeded(seed);
        Tensor::from_vec(
            &[n, cfg.seq_len, cfg.d_model],
            (0..n * cfg.input_elems()).map(|_| rng.f64() as f32).collect(),
        )
    }

    #[test]
    fn compiled_pimhw_matches_spec_bit_for_bit() {
        let t = tiny_tfm(1);
        let prog = t.compile().unwrap();
        assert!(prog.fully_prepared());
        let x = rand_x(2, t.cfg, 9);
        let got = prog.forward(&x, ForwardMode::PimHw, 7);
        let want = spec_attn(&t, &x).unwrap();
        assert_eq!(got.shape, want.shape);
        for (g, w) in got.data.iter().zip(want.data.iter()) {
            assert_eq!(g.to_bits(), w.to_bits());
        }
    }

    #[test]
    fn compiled_baseline_matches_dense_witness_bit_for_bit() {
        let t = tiny_tfm(2);
        let prog = CompiledTransformer::compile_dense(&t).unwrap();
        let x = rand_x(2, t.cfg, 10);
        let got = prog.forward(&x, ForwardMode::Baseline, 0);
        let want = spec_attn_dense(&t, &x).unwrap();
        for (g, w) in got.data.iter().zip(want.data.iter()) {
            assert_eq!(g.to_bits(), w.to_bits());
        }
    }

    #[test]
    fn steady_state_execution_is_prepare_free() {
        let t = tiny_tfm(3);
        let prog = t.compile().unwrap();
        let x = rand_x(1, t.cfg, 11);
        let _ = prog.forward(&x, ForwardMode::PimHw, 0);
        let before = prepare_count();
        for seed in 0..3 {
            let _ = prog.forward(&x, ForwardMode::PimHw, seed);
            let _ = prog.forward(&x, ForwardMode::PimHwNoise(0.4), seed);
        }
        assert_eq!(prepare_count(), before, "attention serving must not re-prepare");
    }

    #[test]
    fn noiseless_run_draws_no_rng_fingerprint_is_seed() {
        let t = tiny_tfm(4);
        let prog = t.compile().unwrap();
        let x = rand_x(1, t.cfg, 12);
        let mut scratch = ScratchPool::new();
        let run = prog.forward_run(&x, ForwardMode::PimHw, 77, Parallelism::serial(), &mut scratch);
        assert_eq!(run.rng_fingerprint(), Pcg64::seeded(77).next_u64());
    }

    #[test]
    fn causal_mask_only_attends_backwards() {
        let mut cfg = tiny_cfg();
        cfg.causal = true;
        // A causal context for token t must be independent of tokens
        // after t: perturb only the last token's K/V lanes and check
        // every earlier position's context is untouched.
        let qkv: Vec<f32> = {
            let mut rng = Pcg64::seeded(14);
            (0..cfg.seq_len * 3 * cfg.d_model).map(|_| rng.f64() as f32).collect()
        };
        let base = attn_context(&qkv, 1, &cfg);
        let mut poked = qkv.clone();
        // Perturb only the last token's K and V lanes.
        let last = (cfg.seq_len - 1) * 3 * cfg.d_model;
        for v in poked[last + cfg.d_model..last + 3 * cfg.d_model].iter_mut() {
            *v += 1.0;
        }
        let got = attn_context(&poked, 1, &cfg);
        let d = cfg.d_model;
        assert_eq!(&base[..(cfg.seq_len - 1) * d], &got[..(cfg.seq_len - 1) * d]);
        // And without the mask the earlier positions *do* move.
        cfg.causal = false;
        let open = attn_context(&poked, 1, &cfg);
        assert_ne!(&base[..d], &open[..d]);
    }

    #[test]
    fn mean_pool_matches_manual_mean() {
        let h: Vec<f32> = (0..2 * 3 * 4).map(|i| i as f32).collect();
        let p = mean_pool_seq(&h, 2, 3, 4);
        assert_eq!(p.len(), 8);
        assert!((p[0] - (0.0 + 4.0 + 8.0) / 3.0).abs() < 1e-6);
        assert!((p[7] - (15.0 + 19.0 + 23.0) / 3.0).abs() < 1e-6);
    }
}
