//! Minimal CSV emitter for figure data.
//!
//! Every figure generator in [`crate::figures`] writes its series through a
//! [`CsvWriter`], so the paper's plots can be regenerated from the emitted
//! files with any plotting tool.

use std::fmt::Write as _;
use std::fs;
use std::path::Path;

/// In-memory CSV table, written out atomically at the end.
pub struct CsvWriter {
    header: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl CsvWriter {
    /// A table with the given column headers.
    pub fn new<S: Into<String>>(columns: Vec<S>) -> Self {
        CsvWriter {
            header: columns.into_iter().map(Into::into).collect(),
            rows: Vec::new(),
        }
    }

    /// Append a row of f64 cells (the common case for figure data).
    pub fn row_f64(&mut self, cells: &[f64]) {
        assert_eq!(cells.len(), self.header.len(), "column count mismatch");
        self.rows
            .push(cells.iter().map(|c| format_num(*c)).collect());
    }

    /// Append a row of preformatted cells.
    pub fn row<S: Into<String>>(&mut self, cells: Vec<S>) {
        assert_eq!(cells.len(), self.header.len(), "column count mismatch");
        self.rows.push(cells.into_iter().map(Into::into).collect());
    }

    /// Number of data rows appended so far.
    pub fn n_rows(&self) -> usize {
        self.rows.len()
    }

    /// Render to a CSV string.
    pub fn to_string(&self) -> String {
        let mut out = String::new();
        let _ = writeln!(out, "{}", self.header.join(","));
        for row in &self.rows {
            let _ = writeln!(out, "{}", row.join(","));
        }
        out
    }

    /// Write to `path`, creating parent directories.
    pub fn write(&self, path: &Path) -> std::io::Result<()> {
        if let Some(parent) = path.parent() {
            fs::create_dir_all(parent)?;
        }
        fs::write(path, self.to_string())
    }
}

/// Compact numeric formatting: integers stay integral, small/large values go
/// to scientific notation, everything else keeps 6 significant digits.
pub fn format_num(x: f64) -> String {
    if x == 0.0 {
        return "0".to_string();
    }
    if x.fract() == 0.0 && x.abs() < 1e15 {
        return format!("{}", x as i64);
    }
    let mag = x.abs();
    if !(1e-4..1e7).contains(&mag) {
        format!("{x:.6e}")
    } else {
        let s = format!("{x:.6}");
        // Trim trailing zeros but keep at least one decimal.
        let trimmed = s.trim_end_matches('0').trim_end_matches('.');
        trimmed.to_string()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_header_and_rows() {
        let mut w = CsvWriter::new(vec!["a", "b"]);
        w.row_f64(&[1.0, 2.5]);
        w.row(vec!["x", "y"]);
        let s = w.to_string();
        assert_eq!(s, "a,b\n1,2.5\nx,y\n");
        assert_eq!(w.n_rows(), 2);
    }

    #[test]
    #[should_panic(expected = "column count mismatch")]
    fn rejects_wrong_arity() {
        let mut w = CsvWriter::new(vec!["a"]);
        w.row_f64(&[1.0, 2.0]);
    }

    #[test]
    fn num_formatting() {
        assert_eq!(format_num(0.0), "0");
        assert_eq!(format_num(42.0), "42");
        assert_eq!(format_num(2.5), "2.5");
        assert_eq!(format_num(1.23e-9), "1.230000e-9");
    }

    #[test]
    fn writes_file() {
        let mut w = CsvWriter::new(vec!["v"]);
        w.row_f64(&[3.0]);
        let path = std::env::temp_dir().join("nvm_csv_test/out.csv");
        w.write(&path).unwrap();
        assert_eq!(std::fs::read_to_string(&path).unwrap(), "v\n3\n");
    }
}
