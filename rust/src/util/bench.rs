//! Micro-benchmark harness (criterion is unavailable offline).
//!
//! Measures wall-clock over warmup + timed iterations, reports mean / p50 /
//! p95 / min and derived throughput. Used both by `cargo bench`
//! (`rust/benches/paper_benches.rs`, `harness = false`) and by the CLI
//! `repro bench` path.

use std::time::Instant;

use super::json::Json;
use super::stats::Summary;

/// One benchmark measurement.
#[derive(Clone, Debug)]
pub struct BenchResult {
    /// Benchmark name (report key).
    pub name: String,
    /// Per-iteration wall time, seconds.
    pub times: Vec<f64>,
    /// Summary statistics over [`Self::times`].
    pub summary: Summary,
    /// Work items per iteration (for throughput reporting), if meaningful.
    pub items_per_iter: Option<f64>,
}

impl BenchResult {
    /// Mean per-iteration wall time, seconds.
    pub fn mean_s(&self) -> f64 {
        self.summary.mean
    }

    /// Items per second, if `items_per_iter` was set.
    pub fn throughput(&self) -> Option<f64> {
        self.items_per_iter.map(|n| n / self.summary.mean)
    }

    /// Render a single fixed-width report line.
    pub fn report_line(&self) -> String {
        let t = |s: f64| format_time(s);
        let base = format!(
            "{:<44} mean {:>10}  p50 {:>10}  p95 {:>10}  min {:>10}  (n={})",
            self.name,
            t(self.summary.mean),
            t(self.summary.p50),
            t(self.summary.p95),
            t(self.summary.min),
            self.summary.n
        );
        match self.throughput() {
            Some(tp) => format!("{base}  {:.3e} items/s", tp),
            None => base,
        }
    }

    /// Machine-readable record (for the `BENCH_*.json` perf trajectory).
    pub fn to_json(&self) -> Json {
        let mut pairs = vec![
            ("name", Json::Str(self.name.clone())),
            ("n", Json::Num(self.summary.n as f64)),
            ("mean_s", Json::Num(self.summary.mean)),
            ("p50_s", Json::Num(self.summary.p50)),
            ("p95_s", Json::Num(self.summary.p95)),
            ("min_s", Json::Num(self.summary.min)),
        ];
        if let Some(tp) = self.throughput() {
            pairs.push(("items_per_s", Json::Num(tp)));
        }
        Json::obj(pairs)
    }
}

/// Format seconds with an adaptive unit.
pub fn format_time(s: f64) -> String {
    if s < 1e-6 {
        format!("{:.1} ns", s * 1e9)
    } else if s < 1e-3 {
        format!("{:.2} µs", s * 1e6)
    } else if s < 1.0 {
        format!("{:.2} ms", s * 1e3)
    } else {
        format!("{:.3} s", s)
    }
}

/// Benchmark runner with adaptive iteration count.
pub struct Bencher {
    /// Target total measurement time per benchmark, seconds.
    pub target_s: f64,
    /// Hard cap on iterations.
    pub max_iters: usize,
    /// Minimum iterations (for stable percentiles).
    pub min_iters: usize,
    /// Accumulated results, in run order.
    pub results: Vec<BenchResult>,
}

impl Default for Bencher {
    fn default() -> Self {
        Bencher { target_s: 1.0, max_iters: 1000, min_iters: 10, results: Vec::new() }
    }
}

impl Bencher {
    /// A faster, less precise configuration for smoke runs and tests.
    pub fn quick() -> Self {
        Bencher { target_s: 0.2, max_iters: 100, min_iters: 5, results: Vec::new() }
    }

    /// Run `f` repeatedly; the closure should return something observable to
    /// prevent dead-code elimination (we `black_box` it).
    pub fn bench<T, F: FnMut() -> T>(&mut self, name: &str, mut f: F) -> &BenchResult {
        self.bench_items(name, None, &mut f)
    }

    /// Like [`Self::bench`], additionally recording per-iteration item count
    /// so the report includes throughput.
    pub fn bench_with_items<T, F: FnMut() -> T>(
        &mut self,
        name: &str,
        items: f64,
        mut f: F,
    ) -> &BenchResult {
        self.bench_items(name, Some(items), &mut f)
    }

    fn bench_items<T>(
        &mut self,
        name: &str,
        items: Option<f64>,
        f: &mut dyn FnMut() -> T,
    ) -> &BenchResult {
        // Warmup: one untimed call + estimate the per-iter cost.
        let t0 = Instant::now();
        std::hint::black_box(f());
        let est = t0.elapsed().as_secs_f64().max(1e-9);
        let iters = ((self.target_s / est) as usize)
            .clamp(self.min_iters, self.max_iters);
        let mut times = Vec::with_capacity(iters);
        for _ in 0..iters {
            let t = Instant::now();
            std::hint::black_box(f());
            times.push(t.elapsed().as_secs_f64());
        }
        let summary = Summary::of(&times);
        self.results.push(BenchResult {
            name: name.to_string(),
            times,
            summary,
            items_per_iter: items,
        });
        self.results.last().unwrap()
    }

    /// Print the full report.
    pub fn report(&self) {
        for r in &self.results {
            println!("{}", r.report_line());
        }
    }

    /// All results as a JSON array (see [`BenchResult::to_json`]).
    pub fn to_json(&self) -> Json {
        Json::Arr(self.results.iter().map(|r| r.to_json()).collect())
    }

    /// Deterministic workload descriptors only — the `comparison` section
    /// of the `BENCH_*.json` trajectory. Every field is a pure function of
    /// the benchmark definitions (name + per-iteration item count; the
    /// adaptive iteration count and all timings are wall-clock-dependent
    /// and belong in [`Self::to_json`]), and object keys serialize sorted,
    /// so trajectory files diff cleanly across PRs.
    ///
    /// # Examples
    ///
    /// ```
    /// use nvm_in_cache::util::bench::Bencher;
    ///
    /// let mut b = Bencher::quick();
    /// b.bench_with_items("add", 1.0, || 1 + 1);
    /// let stable = b.comparison_json().to_string();
    /// assert!(stable.contains("\"name\":\"add\""));
    /// assert!(!stable.contains("mean_s"), "no wall-clock fields");
    /// ```
    pub fn comparison_json(&self) -> Json {
        Json::Arr(
            self.results
                .iter()
                .map(|r| {
                    let mut pairs = vec![("name", Json::Str(r.name.clone()))];
                    if let Some(items) = r.items_per_iter {
                        pairs.push(("items_per_iter", Json::Num(items)));
                    }
                    Json::obj(pairs)
                })
                .collect(),
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measures_something() {
        let mut b = Bencher { target_s: 0.02, max_iters: 50, min_iters: 5, results: vec![] };
        let r = b.bench("spin", || {
            let mut acc = 0u64;
            for i in 0..1000u64 {
                acc = acc.wrapping_add(i * i);
            }
            acc
        });
        assert!(r.summary.n >= 5);
        assert!(r.summary.mean > 0.0);
        assert!(r.summary.min <= r.summary.p50);
    }

    #[test]
    fn throughput_reported() {
        let mut b = Bencher::quick();
        let r = b.bench_with_items("items", 100.0, || 1 + 1);
        assert!(r.throughput().unwrap() > 0.0);
        assert!(r.report_line().contains("items/s"));
    }

    #[test]
    fn json_record_roundtrips() {
        let mut b = Bencher::quick();
        b.bench_with_items("tiny", 10.0, || 2 + 2);
        let arr = b.to_json();
        let s = arr.to_string();
        let back = Json::parse(&s).unwrap();
        let rec = &back.as_arr().unwrap()[0];
        assert_eq!(rec.get("name").unwrap().as_str(), Some("tiny"));
        assert!(rec.get("mean_s").unwrap().as_f64().unwrap() > 0.0);
        assert!(rec.get("items_per_s").unwrap().as_f64().unwrap() > 0.0);
    }

    #[test]
    fn comparison_json_is_run_invariant() {
        // Two runs of the same benchmark definitions must serialize the
        // comparison section byte-identically (BENCH_*.json diffability).
        let run = || {
            let mut b = Bencher::quick();
            b.bench_with_items("mac", 64.0, || (0..64u64).sum::<u64>());
            b.bench("plain", || 7 * 6);
            b.comparison_json().to_string()
        };
        let a = run();
        assert_eq!(a, run());
        assert!(!a.contains("_s\""), "no timing fields leak: {a}");
        assert!(!a.contains("\"n\""), "no adaptive iteration count: {a}");
    }

    #[test]
    fn time_formatting() {
        assert_eq!(format_time(5e-9), "5.0 ns");
        assert_eq!(format_time(2.5e-6), "2.50 µs");
        assert_eq!(format_time(3.0e-3), "3.00 ms");
        assert_eq!(format_time(2.0), "2.000 s");
    }
}
