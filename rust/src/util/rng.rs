//! Deterministic PRNG (PCG64) + Gaussian sampling (Box–Muller).
//!
//! Every stochastic element of the simulator (Monte-Carlo device variation,
//! ADC comparator offset, noise injection, workload generation) draws from
//! a seeded [`Pcg64`], so every figure and test is bit-reproducible.

/// PCG-XSL-RR 128/64 generator (O'Neill 2014).
///
/// # Examples
///
/// Same (seed, stream) ⇒ identical draws; distinct streams are
/// independent — the property the tiled PIM engine uses to give every
/// execution unit its own noise stream (`pim::parallel`):
///
/// ```
/// use nvm_in_cache::util::rng::Pcg64;
///
/// let mut a = Pcg64::new(42, 7);
/// let mut b = Pcg64::new(42, 7);
/// assert_eq!(a.next_u64(), b.next_u64());
///
/// let mut other_stream = Pcg64::new(42, 8);
/// assert_ne!(a.next_u64(), other_stream.next_u64());
/// ```
#[derive(Clone, Debug)]
pub struct Pcg64 {
    state: u128,
    inc: u128,
    /// Cached second Box–Muller variate.
    spare: Option<f64>,
}

const PCG_MULT: u128 = 0x2360_ed05_1fc6_5da4_4385_df64_9fcc_f645;

impl Pcg64 {
    /// Create a generator from a seed and a stream id. Distinct streams are
    /// statistically independent — used to decouple e.g. device variation
    /// from workload generation.
    pub fn new(seed: u64, stream: u64) -> Self {
        let mut rng = Pcg64 {
            state: 0,
            inc: ((stream as u128) << 1) | 1,
            spare: None,
        };
        rng.state = rng.state.wrapping_mul(PCG_MULT).wrapping_add(rng.inc);
        rng.state = rng.state.wrapping_add(seed as u128);
        rng.state = rng.state.wrapping_mul(PCG_MULT).wrapping_add(rng.inc);
        rng
    }

    /// Default stream.
    pub fn seeded(seed: u64) -> Self {
        Self::new(seed, 0xda3e_39cb_94b9_5bdb)
    }

    /// Next raw 64-bit output.
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_mul(PCG_MULT).wrapping_add(self.inc);
        let rot = (self.state >> 122) as u32;
        let xsl = ((self.state >> 64) as u64) ^ (self.state as u64);
        xsl.rotate_right(rot)
    }

    /// Uniform in `[0, 1)`.
    pub fn f64(&mut self) -> f64 {
        // 53 random mantissa bits.
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in `[lo, hi)`.
    pub fn range(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.f64()
    }

    /// Uniform integer in `[0, n)` (Lemire-style rejection-free for our use).
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        (self.next_u64() % n as u64) as usize
    }

    /// Standard normal variate via Box–Muller (cached pair).
    pub fn gaussian(&mut self) -> f64 {
        if let Some(z) = self.spare.take() {
            return z;
        }
        // Rejection-free polar-less form; u1 in (0,1].
        let u1 = 1.0 - self.f64();
        let u2 = self.f64();
        let r = (-2.0 * u1.ln()).sqrt();
        let theta = 2.0 * std::f64::consts::PI * u2;
        self.spare = Some(r * theta.sin());
        r * theta.cos()
    }

    /// Normal with given mean / standard deviation.
    pub fn normal(&mut self, mean: f64, sigma: f64) -> f64 {
        mean + sigma * self.gaussian()
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }

    /// Fork an independent child stream (for per-thread RNGs).
    pub fn fork(&mut self, tag: u64) -> Pcg64 {
        Pcg64::new(self.next_u64() ^ tag, tag.wrapping_mul(0x9e37_79b9_7f4a_7c15) | 1)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_across_instances() {
        let mut a = Pcg64::seeded(42);
        let mut b = Pcg64::seeded(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn streams_differ() {
        let mut a = Pcg64::new(42, 1);
        let mut b = Pcg64::new(42, 2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 2);
    }

    #[test]
    fn uniform_mean_and_range() {
        let mut rng = Pcg64::seeded(7);
        let n = 20_000;
        let mut sum = 0.0;
        for _ in 0..n {
            let x = rng.f64();
            assert!((0.0..1.0).contains(&x));
            sum += x;
        }
        let mean = sum / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn gaussian_moments() {
        let mut rng = Pcg64::seeded(11);
        let n = 50_000;
        let xs: Vec<f64> = (0..n).map(|_| rng.gaussian()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.03, "var={var}");
    }

    #[test]
    fn below_in_bounds() {
        let mut rng = Pcg64::seeded(3);
        for _ in 0..1000 {
            assert!(rng.below(7) < 7);
        }
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut rng = Pcg64::seeded(9);
        let mut v: Vec<u32> = (0..100).collect();
        rng.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(v, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn fork_decorrelates() {
        let mut root = Pcg64::seeded(5);
        let mut a = root.fork(1);
        let mut b = root.fork(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 2);
    }
}
