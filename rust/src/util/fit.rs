//! Least-squares fitting: linear and polynomial.
//!
//! The paper characterizes the 6T-2R array's analog transfer function with a
//! "curve-fitted polynomial derived from simulation and SPICE measurements"
//! (§V-E); `poly_fit` is that step for our simulated array, and the fitted
//! coefficients are what the accuracy pipeline (Table II) applies during
//! forward propagation.

/// Ordinary least-squares line `y = slope·x + intercept`.
pub fn linear_fit(xs: &[f64], ys: &[f64]) -> (f64, f64) {
    assert_eq!(xs.len(), ys.len());
    assert!(!xs.is_empty());
    let n = xs.len() as f64;
    let sx: f64 = xs.iter().sum();
    let sy: f64 = ys.iter().sum();
    let sxx: f64 = xs.iter().map(|x| x * x).sum();
    let sxy: f64 = xs.iter().zip(ys).map(|(x, y)| x * y).sum();
    let denom = n * sxx - sx * sx;
    if denom.abs() < 1e-300 {
        return (0.0, sy / n);
    }
    let slope = (n * sxy - sx * sy) / denom;
    (slope, (sy - slope * sx) / n)
}

/// Least-squares polynomial fit of given `degree`; returns coefficients
/// `c[0] + c[1]·x + … + c[degree]·x^degree`. Solved via normal equations
/// with Gaussian elimination + partial pivoting (well-conditioned for the
/// low degrees ≤ 5 we use).
pub fn poly_fit(xs: &[f64], ys: &[f64], degree: usize) -> Vec<f64> {
    assert_eq!(xs.len(), ys.len());
    assert!(xs.len() > degree, "need more points than coefficients");
    let m = degree + 1;
    // Normal matrix A (m×m) and rhs b.
    let mut a = vec![vec![0.0f64; m]; m];
    let mut b = vec![0.0f64; m];
    // Power sums S_k = Σ x^k for k = 0..2·degree.
    let mut s = vec![0.0f64; 2 * degree + 1];
    for &x in xs {
        let mut p = 1.0;
        for sk in s.iter_mut() {
            *sk += p;
            p *= x;
        }
    }
    for (i, row) in a.iter_mut().enumerate() {
        for (j, cell) in row.iter_mut().enumerate() {
            *cell = s[i + j];
        }
    }
    for (&x, &y) in xs.iter().zip(ys) {
        let mut p = 1.0;
        for bi in b.iter_mut() {
            *bi += p * y;
            p *= x;
        }
    }
    solve_linear(&mut a, &mut b)
}

/// Evaluate a polynomial with coefficients in ascending order (Horner).
pub fn poly_eval(coeffs: &[f64], x: f64) -> f64 {
    coeffs.iter().rev().fold(0.0, |acc, &c| acc * x + c)
}

/// Solve `A x = b` in place via Gaussian elimination with partial pivoting.
pub fn solve_linear(a: &mut [Vec<f64>], b: &mut [f64]) -> Vec<f64> {
    let n = b.len();
    for col in 0..n {
        // Partial pivot.
        let pivot = (col..n)
            .max_by(|&i, &j| a[i][col].abs().partial_cmp(&a[j][col].abs()).unwrap())
            .unwrap();
        a.swap(col, pivot);
        b.swap(col, pivot);
        let diag = a[col][col];
        assert!(diag.abs() > 1e-300, "singular normal matrix");
        for row in col + 1..n {
            let factor = a[row][col] / diag;
            for k in col..n {
                a[row][k] -= factor * a[col][k];
            }
            b[row] -= factor * b[col];
        }
    }
    let mut x = vec![0.0; n];
    for row in (0..n).rev() {
        let mut acc = b[row];
        for k in row + 1..n {
            acc -= a[row][k] * x[k];
        }
        x[row] = acc / a[row][row];
    }
    x
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn linear_exact() {
        let xs = [0.0, 1.0, 2.0, 3.0];
        let ys = [1.0, 3.0, 5.0, 7.0];
        let (m, c) = linear_fit(&xs, &ys);
        assert!((m - 2.0).abs() < 1e-12);
        assert!((c - 1.0).abs() < 1e-12);
    }

    #[test]
    fn poly_recovers_cubic() {
        let truth = [0.5, -1.0, 2.0, 0.25]; // 0.5 - x + 2x² + 0.25x³
        let xs: Vec<f64> = (0..20).map(|i| i as f64 * 0.3 - 3.0).collect();
        let ys: Vec<f64> = xs.iter().map(|&x| poly_eval(&truth, x)).collect();
        let fit = poly_fit(&xs, &ys, 3);
        for (f, t) in fit.iter().zip(truth.iter()) {
            assert!((f - t).abs() < 1e-8, "fit={fit:?}");
        }
    }

    #[test]
    fn poly_eval_horner() {
        assert_eq!(poly_eval(&[1.0, 2.0, 3.0], 2.0), 1.0 + 4.0 + 12.0);
        assert_eq!(poly_eval(&[], 5.0), 0.0);
    }

    #[test]
    fn solve_identity() {
        let mut a = vec![vec![1.0, 0.0], vec![0.0, 1.0]];
        let mut b = vec![3.0, 4.0];
        assert_eq!(solve_linear(&mut a, &mut b), vec![3.0, 4.0]);
    }

    #[test]
    fn solve_needs_pivot() {
        // First pivot is zero — exercises row swapping.
        let mut a = vec![vec![0.0, 1.0], vec![2.0, 0.0]];
        let mut b = vec![5.0, 6.0];
        let x = solve_linear(&mut a, &mut b);
        assert!((x[0] - 3.0).abs() < 1e-12);
        assert!((x[1] - 5.0).abs() < 1e-12);
    }
}
