//! Tiny JSON value type + serializer/parser (serde is unavailable offline).
//!
//! Used for the artifact manifest sidecars and the machine-readable result
//! summaries (`results/*.json`). Supports the JSON subset we emit: objects,
//! arrays, strings, finite numbers, booleans, null.

use std::collections::BTreeMap;
use std::fmt::Write as _;

/// A JSON value.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    /// `null`.
    Null,
    /// `true` / `false`.
    Bool(bool),
    /// A finite number.
    Num(f64),
    /// A string.
    Str(String),
    /// An array.
    Arr(Vec<Json>),
    /// An object (sorted keys for deterministic output).
    Obj(BTreeMap<String, Json>),
}

impl Json {
    /// Build an object from key/value pairs.
    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    /// Build a numeric array.
    pub fn arr_f64(xs: &[f64]) -> Json {
        Json::Arr(xs.iter().map(|&x| Json::Num(x)).collect())
    }

    /// Object field lookup (None for non-objects / missing keys).
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// Numeric value, if this is a number.
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }

    /// String value, if this is a string.
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    /// Array items, if this is an array.
    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    /// Serialize (compact).
    pub fn to_string(&self) -> String {
        let mut s = String::new();
        self.write(&mut s);
        s
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(x) => {
                if x.fract() == 0.0 && x.abs() < 1e15 {
                    let _ = write!(out, "{}", *x as i64);
                } else {
                    let _ = write!(out, "{x}");
                }
            }
            Json::Str(s) => {
                out.push('"');
                for c in s.chars() {
                    match c {
                        '"' => out.push_str("\\\""),
                        '\\' => out.push_str("\\\\"),
                        '\n' => out.push_str("\\n"),
                        '\t' => out.push_str("\\t"),
                        c if (c as u32) < 0x20 => {
                            let _ = write!(out, "\\u{:04x}", c as u32);
                        }
                        c => out.push(c),
                    }
                }
                out.push('"');
            }
            Json::Arr(items) => {
                out.push('[');
                for (i, item) in items.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    item.write(out);
                }
                out.push(']');
            }
            Json::Obj(map) => {
                out.push('{');
                for (i, (k, v)) in map.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    Json::Str(k.clone()).write(out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }

    /// Parse a JSON document.
    pub fn parse(text: &str) -> Result<Json, String> {
        let bytes = text.as_bytes();
        let mut pos = 0usize;
        let v = parse_value(bytes, &mut pos)?;
        skip_ws(bytes, &mut pos);
        if pos != bytes.len() {
            return Err(format!("trailing data at byte {pos}"));
        }
        Ok(v)
    }
}

fn skip_ws(b: &[u8], pos: &mut usize) {
    while *pos < b.len() && matches!(b[*pos], b' ' | b'\t' | b'\n' | b'\r') {
        *pos += 1;
    }
}

fn parse_value(b: &[u8], pos: &mut usize) -> Result<Json, String> {
    skip_ws(b, pos);
    if *pos >= b.len() {
        return Err("unexpected end".into());
    }
    match b[*pos] {
        b'n' => expect_lit(b, pos, "null").map(|_| Json::Null),
        b't' => expect_lit(b, pos, "true").map(|_| Json::Bool(true)),
        b'f' => expect_lit(b, pos, "false").map(|_| Json::Bool(false)),
        b'"' => parse_string(b, pos).map(Json::Str),
        b'[' => {
            *pos += 1;
            let mut items = Vec::new();
            skip_ws(b, pos);
            if *pos < b.len() && b[*pos] == b']' {
                *pos += 1;
                return Ok(Json::Arr(items));
            }
            loop {
                items.push(parse_value(b, pos)?);
                skip_ws(b, pos);
                match b.get(*pos) {
                    Some(b',') => *pos += 1,
                    Some(b']') => {
                        *pos += 1;
                        return Ok(Json::Arr(items));
                    }
                    _ => return Err(format!("expected , or ] at byte {pos}")),
                }
            }
        }
        b'{' => {
            *pos += 1;
            let mut map = BTreeMap::new();
            skip_ws(b, pos);
            if *pos < b.len() && b[*pos] == b'}' {
                *pos += 1;
                return Ok(Json::Obj(map));
            }
            loop {
                skip_ws(b, pos);
                let key = parse_string(b, pos)?;
                skip_ws(b, pos);
                if b.get(*pos) != Some(&b':') {
                    return Err(format!("expected : at byte {pos}"));
                }
                *pos += 1;
                map.insert(key, parse_value(b, pos)?);
                skip_ws(b, pos);
                match b.get(*pos) {
                    Some(b',') => *pos += 1,
                    Some(b'}') => {
                        *pos += 1;
                        return Ok(Json::Obj(map));
                    }
                    _ => return Err(format!("expected , or }} at byte {pos}")),
                }
            }
        }
        _ => parse_number(b, pos),
    }
}

fn expect_lit(b: &[u8], pos: &mut usize, lit: &str) -> Result<(), String> {
    if b[*pos..].starts_with(lit.as_bytes()) {
        *pos += lit.len();
        Ok(())
    } else {
        Err(format!("expected `{lit}` at byte {pos}"))
    }
}

fn parse_string(b: &[u8], pos: &mut usize) -> Result<String, String> {
    if b.get(*pos) != Some(&b'"') {
        return Err(format!("expected string at byte {pos}"));
    }
    *pos += 1;
    let mut s = String::new();
    while *pos < b.len() {
        match b[*pos] {
            b'"' => {
                *pos += 1;
                return Ok(s);
            }
            b'\\' => {
                *pos += 1;
                match b.get(*pos) {
                    Some(b'"') => s.push('"'),
                    Some(b'\\') => s.push('\\'),
                    Some(b'/') => s.push('/'),
                    Some(b'n') => s.push('\n'),
                    Some(b't') => s.push('\t'),
                    Some(b'r') => s.push('\r'),
                    Some(b'u') => {
                        let hex = std::str::from_utf8(&b[*pos + 1..*pos + 5])
                            .map_err(|e| e.to_string())?;
                        let code = u32::from_str_radix(hex, 16).map_err(|e| e.to_string())?;
                        s.push(char::from_u32(code).ok_or("bad \\u escape")?);
                        *pos += 4;
                    }
                    _ => return Err("bad escape".into()),
                }
                *pos += 1;
            }
            c if c < 0x80 => {
                s.push(c as char);
                *pos += 1;
            }
            _ => {
                // Multi-byte UTF-8: find the char boundary.
                let rest = std::str::from_utf8(&b[*pos..]).map_err(|e| e.to_string())?;
                let ch = rest.chars().next().unwrap();
                s.push(ch);
                *pos += ch.len_utf8();
            }
        }
    }
    Err("unterminated string".into())
}

fn parse_number(b: &[u8], pos: &mut usize) -> Result<Json, String> {
    let start = *pos;
    while *pos < b.len()
        && matches!(b[*pos], b'-' | b'+' | b'.' | b'e' | b'E' | b'0'..=b'9')
    {
        *pos += 1;
    }
    let s = std::str::from_utf8(&b[start..*pos]).map_err(|e| e.to_string())?;
    s.parse::<f64>()
        .map(Json::Num)
        .map_err(|_| format!("bad number `{s}` at byte {start}"))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_object() {
        let v = Json::obj(vec![
            ("name", Json::Str("6T-2R".into())),
            ("rows", Json::Num(128.0)),
            ("tops", Json::Num(0.4096)),
            ("ok", Json::Bool(true)),
            ("series", Json::arr_f64(&[1.0, 2.0, 3.0])),
        ]);
        let s = v.to_string();
        let back = Json::parse(&s).unwrap();
        assert_eq!(v, back);
    }

    #[test]
    fn parse_nested() {
        let v = Json::parse(r#"{"a": [1, {"b": "x\ny"}], "c": null}"#).unwrap();
        assert_eq!(
            v.get("a").unwrap().as_arr().unwrap()[0].as_f64(),
            Some(1.0)
        );
        assert_eq!(
            v.get("a").unwrap().as_arr().unwrap()[1]
                .get("b")
                .unwrap()
                .as_str(),
            Some("x\ny")
        );
        assert_eq!(v.get("c"), Some(&Json::Null));
    }

    #[test]
    fn rejects_trailing() {
        assert!(Json::parse("1 2").is_err());
        assert!(Json::parse("{").is_err());
    }

    #[test]
    fn scientific_numbers() {
        let v = Json::parse("[1e-3, -2.5E2]").unwrap();
        let a = v.as_arr().unwrap();
        assert_eq!(a[0].as_f64(), Some(1e-3));
        assert_eq!(a[1].as_f64(), Some(-250.0));
    }
}
