//! Hand-rolled CLI argument parser (clap is unavailable offline).
//!
//! Grammar: `repro <subcommand> [--flag] [--key value]... [positional]...`
//!
//! Malformed option values surface as [`Error::Config`] (rendered by
//! `main` as a clean one-line message), never as a panic.

use std::collections::BTreeMap;

use crate::{Error, Result};

/// Parsed command line.
#[derive(Debug, Clone, Default)]
pub struct Args {
    /// First non-dashed token, if any.
    pub subcommand: Option<String>,
    /// Bare `--flag` tokens.
    pub flags: Vec<String>,
    /// `--key value` / `--key=value` options.
    pub options: BTreeMap<String, String>,
    /// Remaining non-dashed tokens.
    pub positional: Vec<String>,
}

impl Args {
    /// Parse from an iterator of raw arguments (excluding argv[0]).
    ///
    /// # Examples
    ///
    /// ```
    /// use nvm_in_cache::util::cli::Args;
    ///
    /// let args = Args::parse(
    ///     ["bench", "--threads", "4", "--json"].map(String::from),
    /// );
    /// assert_eq!(args.subcommand.as_deref(), Some("bench"));
    /// assert_eq!(args.get_usize("threads", 1).unwrap(), 4);
    /// assert!(args.flag("json"));
    /// ```
    pub fn parse<I: IntoIterator<Item = String>>(raw: I) -> Args {
        let mut args = Args::default();
        let mut iter = raw.into_iter().peekable();
        // First non-dashed token is the subcommand.
        if let Some(first) = iter.peek() {
            if !first.starts_with('-') {
                args.subcommand = iter.next();
            }
        }
        while let Some(tok) = iter.next() {
            if let Some(name) = tok.strip_prefix("--") {
                // `--key=value`, `--key value`, or bare `--flag`.
                if let Some((k, v)) = name.split_once('=') {
                    args.options.insert(k.to_string(), v.to_string());
                } else if iter
                    .peek()
                    .map(|next| !next.starts_with("--"))
                    .unwrap_or(false)
                {
                    let v = iter.next().unwrap();
                    args.options.insert(name.to_string(), v);
                } else {
                    args.flags.push(name.to_string());
                }
            } else {
                args.positional.push(tok);
            }
        }
        args
    }

    /// Parse the process command line.
    pub fn from_env() -> Args {
        Args::parse(std::env::args().skip(1))
    }

    /// Was `--name` given (as a flag or an option)?
    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name) || self.options.contains_key(name)
    }

    /// Raw option value for `--name`.
    pub fn get(&self, name: &str) -> Option<&str> {
        self.options.get(name).map(|s| s.as_str())
    }

    /// Option value with a default.
    pub fn get_or<'a>(&'a self, name: &str, default: &'a str) -> &'a str {
        self.get(name).unwrap_or(default)
    }

    /// Integer option with a default; `Error::Config` on a malformed value.
    pub fn get_usize(&self, name: &str, default: usize) -> Result<usize> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| Error::Config(format!("--{name} expects an integer, got `{v}`"))),
        }
    }

    /// Float option with a default; `Error::Config` on a malformed value.
    pub fn get_f64(&self, name: &str, default: f64) -> Result<f64> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| Error::Config(format!("--{name} expects a number, got `{v}`"))),
        }
    }

    /// u64 option with a default; `Error::Config` on a malformed value.
    pub fn get_u64(&self, name: &str, default: u64) -> Result<u64> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| Error::Config(format!("--{name} expects an integer, got `{v}`"))),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(String::from))
    }

    #[test]
    fn subcommand_and_options() {
        let a = parse("figures --fig 10 --out results/ --verbose");
        assert_eq!(a.subcommand.as_deref(), Some("figures"));
        assert_eq!(a.get("fig"), Some("10"));
        assert_eq!(a.get("out"), Some("results/"));
        assert!(a.flag("verbose"));
        assert!(!a.flag("quiet"));
    }

    #[test]
    fn key_equals_value() {
        let a = parse("serve --port=8080 --batch-size=16");
        assert_eq!(a.get_usize("port", 0).unwrap(), 8080);
        assert_eq!(a.get_usize("batch-size", 0).unwrap(), 16);
    }

    #[test]
    fn trailing_flag_without_value() {
        let a = parse("bench --all");
        assert!(a.flag("all"));
    }

    #[test]
    fn positional_args() {
        let a = parse("run input.bin output.bin --fast");
        assert_eq!(a.subcommand.as_deref(), Some("run"));
        assert_eq!(a.positional, vec!["input.bin", "output.bin"]);
    }

    #[test]
    fn typed_getters_defaults() {
        let a = parse("x");
        assert_eq!(a.get_usize("n", 7).unwrap(), 7);
        assert_eq!(a.get_f64("v", 1.5).unwrap(), 1.5);
        assert_eq!(a.get_or("mode", "tt"), "tt");
    }

    #[test]
    fn negative_number_as_value() {
        let a = parse("f --offset -3.5");
        assert_eq!(a.get_f64("offset", 0.0).unwrap(), -3.5);
    }

    #[test]
    fn malformed_values_error_instead_of_panicking() {
        let a = parse("serve --requests banana --rate 1.2.3 --seed -1");
        let e = a.get_usize("requests", 5).unwrap_err();
        assert!(e.to_string().contains("--requests expects an integer"), "{e}");
        assert!(e.to_string().contains("banana"), "{e}");
        assert!(a.get_f64("rate", 0.0).is_err());
        assert!(a.get_u64("seed", 0).is_err(), "negative u64 must be rejected");
        // Untouched keys still fall back to their defaults.
        assert_eq!(a.get_usize("other", 9).unwrap(), 9);
    }
}
