//! Descriptive statistics used throughout the figure generators and the
//! benchmark harness.

/// Summary statistics over a sample.
#[derive(Clone, Debug, PartialEq)]
pub struct Summary {
    /// Sample size.
    pub n: usize,
    /// Arithmetic mean.
    pub mean: f64,
    /// Sample standard deviation (n-1 denominator).
    pub std: f64,
    /// Smallest sample.
    pub min: f64,
    /// Largest sample.
    pub max: f64,
    /// Median (linear-interpolated).
    pub p50: f64,
    /// 95th percentile (linear-interpolated).
    pub p95: f64,
    /// 99th percentile (linear-interpolated).
    pub p99: f64,
}

impl Summary {
    /// Compute summary statistics. Returns a zeroed summary for empty input.
    pub fn of(xs: &[f64]) -> Summary {
        if xs.is_empty() {
            return Summary { n: 0, mean: 0.0, std: 0.0, min: 0.0, max: 0.0, p50: 0.0, p95: 0.0, p99: 0.0 };
        }
        let n = xs.len();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = if n > 1 {
            xs.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / (n - 1) as f64
        } else {
            0.0
        };
        let mut sorted = xs.to_vec();
        // total_cmp: same NaN hardening as LatencyHistogram::percentile —
        // one poisoned sample must not panic a whole report.
        sorted.sort_by(|a, b| a.total_cmp(b));
        Summary {
            n,
            mean,
            std: var.sqrt(),
            min: sorted[0],
            max: sorted[n - 1],
            p50: percentile_sorted(&sorted, 50.0),
            p95: percentile_sorted(&sorted, 95.0),
            p99: percentile_sorted(&sorted, 99.0),
        }
    }

    /// Coefficient of variation (σ/μ); 0 when the mean is 0.
    pub fn cv(&self) -> f64 {
        if self.mean == 0.0 { 0.0 } else { self.std / self.mean }
    }
}

/// Linear-interpolated percentile over a pre-sorted slice, `p` in [0, 100].
pub fn percentile_sorted(sorted: &[f64], p: f64) -> f64 {
    if sorted.is_empty() {
        return 0.0;
    }
    let rank = p / 100.0 * (sorted.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    if lo == hi {
        sorted[lo]
    } else {
        let frac = rank - lo as f64;
        sorted[lo] * (1.0 - frac) + sorted[hi] * frac
    }
}

/// Pearson correlation coefficient.
pub fn pearson(xs: &[f64], ys: &[f64]) -> f64 {
    assert_eq!(xs.len(), ys.len());
    let n = xs.len() as f64;
    let mx = xs.iter().sum::<f64>() / n;
    let my = ys.iter().sum::<f64>() / n;
    let mut cov = 0.0;
    let mut vx = 0.0;
    let mut vy = 0.0;
    for (x, y) in xs.iter().zip(ys) {
        cov += (x - mx) * (y - my);
        vx += (x - mx).powi(2);
        vy += (y - my).powi(2);
    }
    if vx == 0.0 || vy == 0.0 {
        return 0.0;
    }
    cov / (vx.sqrt() * vy.sqrt())
}

/// Coefficient of determination R² of `pred` against `truth`.
pub fn r_squared(truth: &[f64], pred: &[f64]) -> f64 {
    assert_eq!(truth.len(), pred.len());
    let mean = truth.iter().sum::<f64>() / truth.len() as f64;
    let ss_res: f64 = truth.iter().zip(pred).map(|(t, p)| (t - p).powi(2)).sum();
    let ss_tot: f64 = truth.iter().map(|t| (t - mean).powi(2)).sum();
    if ss_tot == 0.0 {
        return if ss_res == 0.0 { 1.0 } else { 0.0 };
    }
    1.0 - ss_res / ss_tot
}

/// Is a sequence strictly monotonically non-decreasing?
/// Used for the monotonicity checks of the linearity figures (Fig. 10/11).
pub fn is_monotonic_nondecreasing(xs: &[f64]) -> bool {
    xs.windows(2).all(|w| w[1] >= w[0])
}

/// Maximum absolute deviation from the best-fit line, as a fraction of the
/// full-scale range — the linearity metric used in Fig. 10/11 commentary.
pub fn nonlinearity_fraction(xs: &[f64], ys: &[f64]) -> f64 {
    let (slope, intercept) = super::fit::linear_fit(xs, ys);
    let fs = ys.iter().cloned().fold(f64::MIN, f64::max)
        - ys.iter().cloned().fold(f64::MAX, f64::min);
    if fs == 0.0 {
        return 0.0;
    }
    xs.iter()
        .zip(ys)
        .map(|(x, y)| ((slope * x + intercept) - y).abs())
        .fold(0.0, f64::max)
        / fs
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_basic() {
        let s = Summary::of(&[1.0, 2.0, 3.0, 4.0, 5.0]);
        assert_eq!(s.n, 5);
        assert!((s.mean - 3.0).abs() < 1e-12);
        assert!((s.std - (2.5f64).sqrt()).abs() < 1e-12);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 5.0);
        assert_eq!(s.p50, 3.0);
    }

    #[test]
    fn summary_survives_nan_sample() {
        let s = Summary::of(&[1.0, 2.0, 3.0, f64::NAN]);
        assert_eq!(s.n, 4);
        assert_eq!(s.min, 1.0);
        assert!(s.max.is_nan(), "NaN sorts to the top under total_cmp");
        assert!((s.p50 - 2.5).abs() < 1e-12);
    }

    #[test]
    fn summary_empty() {
        let s = Summary::of(&[]);
        assert_eq!(s.n, 0);
        assert_eq!(s.mean, 0.0);
    }

    #[test]
    fn percentile_interpolates() {
        let sorted = [0.0, 10.0];
        assert!((percentile_sorted(&sorted, 50.0) - 5.0).abs() < 1e-12);
        assert_eq!(percentile_sorted(&sorted, 0.0), 0.0);
        assert_eq!(percentile_sorted(&sorted, 100.0), 10.0);
    }

    #[test]
    fn pearson_perfect() {
        let xs = [1.0, 2.0, 3.0];
        let ys = [2.0, 4.0, 6.0];
        assert!((pearson(&xs, &ys) - 1.0).abs() < 1e-12);
        let neg = [6.0, 4.0, 2.0];
        assert!((pearson(&xs, &neg) + 1.0).abs() < 1e-12);
    }

    #[test]
    fn r2_perfect_and_mean() {
        let t = [1.0, 2.0, 3.0];
        assert!((r_squared(&t, &t) - 1.0).abs() < 1e-12);
        let mean_pred = [2.0, 2.0, 2.0];
        assert!(r_squared(&t, &mean_pred).abs() < 1e-12);
    }

    #[test]
    fn monotonic_check() {
        assert!(is_monotonic_nondecreasing(&[1.0, 1.0, 2.0]));
        assert!(!is_monotonic_nondecreasing(&[1.0, 0.5]));
    }

    #[test]
    fn nonlinearity_of_line_is_zero() {
        let xs: Vec<f64> = (0..16).map(|i| i as f64).collect();
        let ys: Vec<f64> = xs.iter().map(|x| 3.0 * x + 1.0).collect();
        assert!(nonlinearity_fraction(&xs, &ys) < 1e-9);
    }
}
