//! Offline-build utility layer: PRNG, statistics, fitting, emitters, CLI,
//! and a micro-benchmark harness.
//!
//! The build is fully offline with zero external dependencies, so the
//! usual ecosystem crates (`rand`, `serde`, `clap`, `criterion`,
//! `proptest`) are unavailable; these modules are small, tested
//! replacements.

pub mod rng;
pub mod stats;
pub mod fit;
pub mod csv;
pub mod json;
pub mod cli;
pub mod bench;

pub use rng::Pcg64;
pub use stats::Summary;
