//! Quantized transformer block workload (the second model family).
//!
//! A small pre-LN encoder: per block a fused QKV projection,
//! scaled-dot-product attention with an integer-friendly softmax, an
//! output projection, and a 2-layer ReLU FFN, each wrapped in
//! residual + layernorm; a mean-pool + linear head produces logits.
//! Every *weight-stationary* matmul (QKV, output projection, both FFN
//! layers, the head) compiles to [`crate::pim::program::CompiledLinear`]
//! prepared banks via [`crate::pim::attn::CompiledTransformer`] —
//! exactly the `ResNet` → `CompiledNet` story. The two *dynamic*
//! attention matmuls (Q·Kᵀ and A·V, whose operands are both produced at
//! inference time) execute digitally in every mode: the 6T-2R banks are
//! weight-stationary, so there is nothing to prepare and the
//! steady-state zero-prepare guarantee extends to attention unchanged.
//!
//! [`softmax_rows`] is the integer-friendly piece: its outputs live in
//! [0, 1], so the unsigned 4-bit activation quantizer
//! ([`crate::pim::quant::quantize_acts`]) sees attention weights at
//! full dynamic range without a signed split. Bank *inputs* that can go
//! negative (layernorm outputs, attention context, the pooled head
//! input) are clipped at 0 by the unsigned activation lane — the same
//! `max(0.0)` the compiled CNN path applies — which the digital-exact
//! specification [`crate::pim::attn::spec_attn`] replicates bit for bit.

use std::collections::BTreeMap;

use crate::pim::attn::CompiledTransformer;
use crate::pim::parallel::Parallelism;
use crate::util::rng::Pcg64;
use crate::Result;

use super::resnet::Params;
use super::tensor::Tensor;
use super::ForwardMode;

/// Transformer geometry. All matmul shapes derive from this; the
/// defaults mirror the registered fleet tenants (`tfm-tiny-d64`,
/// `tfm-base-d128`).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct TfmConfig {
    /// Tokens per sequence.
    pub seq_len: usize,
    /// Residual-stream width.
    pub d_model: usize,
    /// Attention heads (`d_model % n_heads == 0`).
    pub n_heads: usize,
    /// FFN hidden width (2·d_model for the standard tenants).
    pub d_ff: usize,
    /// Encoder blocks.
    pub n_blocks: usize,
    /// Classifier outputs from the mean-pooled head.
    pub n_classes: usize,
    /// Apply a causal (lower-triangular) attention mask.
    pub causal: bool,
}

impl TfmConfig {
    /// The `tfm-tiny-d64` tenant geometry: 16 tokens, d_model 64,
    /// 4 heads, 2 blocks.
    pub fn tiny() -> TfmConfig {
        TfmConfig {
            seq_len: 16,
            d_model: 64,
            n_heads: 4,
            d_ff: 128,
            n_blocks: 2,
            n_classes: 10,
            causal: false,
        }
    }

    /// The `tfm-base-d128` tenant geometry: 16 tokens, d_model 128,
    /// 8 heads, 2 blocks.
    pub fn base() -> TfmConfig {
        TfmConfig { d_model: 128, n_heads: 8, d_ff: 256, ..Self::tiny() }
    }

    /// Per-head key/query width.
    pub fn head_dim(&self) -> usize {
        self.d_model / self.n_heads
    }

    /// Input elements per sequence (`seq_len · d_model`).
    pub fn input_elems(&self) -> usize {
        self.seq_len * self.d_model
    }
}

/// Row-wise layernorm over the last dimension: `rows` rows of width `d`,
/// f64 mean/variance accumulation (same numeric style as
/// [`crate::nn::layers::group_norm`]), epsilon 1e-5, per-feature
/// gamma/beta. Shared verbatim by the compiled transformer and
/// [`crate::pim::attn::spec_attn`], so the normalization itself can
/// never be a parity divergence.
pub fn layer_norm(x: &[f32], rows: usize, d: usize, gamma: &[f32], beta: &[f32]) -> Vec<f32> {
    assert_eq!(x.len(), rows * d);
    assert_eq!(gamma.len(), d);
    assert_eq!(beta.len(), d);
    let mut out = vec![0.0f32; rows * d];
    for r in 0..rows {
        let row = &x[r * d..(r + 1) * d];
        let mut sum = 0.0f64;
        for &v in row {
            sum += v as f64;
        }
        let mean = sum / d as f64;
        let mut sq = 0.0f64;
        for &v in row {
            let dv = v as f64 - mean;
            sq += dv * dv;
        }
        let inv = 1.0 / (sq / d as f64 + 1e-5).sqrt();
        for j in 0..d {
            out[r * d + j] =
                ((row[j] as f64 - mean) * inv) as f32 * gamma[j] + beta[j];
        }
    }
    out
}

/// In-place row softmax with integer-friendly, NaN-safe semantics:
/// per row subtract the `total_cmp` max, exponentiate, normalize. Rows
/// whose max is not finite — fully `-inf`-masked rows (e.g. the causal
/// mask on a single-token prefix) or NaN-poisoned rows — and rows whose
/// exp-sum fails to normalize fall back to the uniform `1/cols`
/// distribution instead of emitting NaN, mirroring the defined-result
/// policy of [`crate::pim::program::logits_to_classes`]. Outputs always
/// lie in [0, 1], the full range of the unsigned 4-bit activation
/// quantizer.
pub fn softmax_rows(scores: &mut [f32], cols: usize) {
    assert!(cols > 0 && scores.len() % cols == 0);
    for row in scores.chunks_mut(cols) {
        let max = row.iter().copied().max_by(f32::total_cmp).unwrap();
        if !max.is_finite() {
            row.fill(1.0 / cols as f32);
            continue;
        }
        let mut sum = 0.0f32;
        for v in row.iter_mut() {
            *v = (*v - max).exp();
            sum += *v;
        }
        if sum.is_finite() && sum > 0.0 {
            for v in row.iter_mut() {
                *v /= sum;
            }
        } else {
            row.fill(1.0 / cols as f32);
        }
    }
}

/// The transformer model: a parameter store plus its geometry, mirroring
/// [`crate::nn::ResNet`]. Compile once with [`Self::compile`] for
/// serving; [`Self::forward`] is the one-shot convenience.
pub struct Transformer {
    /// Weights and norm parameters (names `t{block}/…`, `head/…`).
    pub params: Params,
    /// Geometry the parameter shapes are validated against at compile.
    pub cfg: TfmConfig,
    /// Worker-pool width every forward matmul is tiled over (serial by
    /// default; output is bit-identical at any width).
    pub parallelism: Parallelism,
}

impl Transformer {
    /// Wrap a parameter store.
    pub fn new(params: Params, cfg: TfmConfig) -> Transformer {
        assert_eq!(cfg.d_model % cfg.n_heads, 0, "d_model must split across heads");
        Transformer { params, cfg, parallelism: Parallelism::serial() }
    }

    /// Set the worker-pool width used by [`Self::forward`].
    pub fn with_parallelism(mut self, par: Parallelism) -> Transformer {
        self.parallelism = par;
        self
    }

    /// Compile every weight-stationary layer once — dense weights plus
    /// prepared quantized banks — into a
    /// [`CompiledTransformer`] that executes any [`ForwardMode`] with
    /// zero further weight preparation
    /// (`rust/tests/transformer_parity.rs`).
    pub fn compile(&self) -> Result<CompiledTransformer> {
        CompiledTransformer::compile(self)
    }

    /// Forward pass: x `[N, seq_len, d_model]` (or any layout with
    /// `N·seq_len·d_model` elements) → logits `[N, n_classes]`.
    ///
    /// One-shot compile-then-run over [`Self::compile`]; serving loops
    /// should compile once and call
    /// [`CompiledTransformer::forward_par`] instead.
    pub fn forward(&self, x: &Tensor, mode: ForwardMode, seed: u64) -> Result<Tensor> {
        use crate::pim::program::ScratchPool;
        // Compile only what the mode reads, like `ResNet::forward_par`.
        let program = match mode {
            ForwardMode::PimHw | ForwardMode::PimHwNoise(_) => self.compile()?,
            _ => CompiledTransformer::compile_dense(self)?,
        };
        Ok(program.forward_par(x, mode, seed, self.parallelism, &mut ScratchPool::new()))
    }

    /// Argmax classification over [`Self::forward`] logits.
    pub fn classify(&self, x: &Tensor, mode: ForwardMode, seed: u64) -> Result<Vec<u8>> {
        let logits = self.forward(x, mode, seed)?;
        Ok(crate::pim::program::logits_to_classes(&logits))
    }
}

/// Synthetic transformer params for tests (He-like init, deterministic)
/// — the transformer sibling of
/// [`crate::nn::resnet::test_params`]. Linear weights draw
/// `N(0, √(2/fan_in))`, the head `N(0, √(1/d_model))`, biases a small
/// `N(0, 0.02)` so the bias-add paths are exercised, gammas 1, betas 0.
pub fn test_tfm_params(cfg: TfmConfig, seed: u64) -> Params {
    let mut rng = Pcg64::seeded(seed);
    let mut tensors = BTreeMap::new();
    let d = cfg.d_model;
    let lin = |rng: &mut Pcg64, k: usize, n: usize| {
        let std = (2.0 / k as f64).sqrt();
        Tensor::from_vec(&[k, n], (0..k * n).map(|_| rng.normal(0.0, std) as f32).collect())
    };
    let bias = |rng: &mut Pcg64, n: usize| {
        Tensor::from_vec(&[n], (0..n).map(|_| rng.normal(0.0, 0.02) as f32).collect())
    };
    for b in 0..cfg.n_blocks {
        let pre = format!("t{b}");
        tensors.insert(format!("{pre}/wqkv"), lin(&mut rng, d, 3 * d));
        tensors.insert(format!("{pre}/bqkv"), bias(&mut rng, 3 * d));
        tensors.insert(format!("{pre}/wo"), lin(&mut rng, d, d));
        tensors.insert(format!("{pre}/bo"), bias(&mut rng, d));
        tensors.insert(format!("{pre}/g1"), Tensor::from_vec(&[d], vec![1.0; d]));
        tensors.insert(format!("{pre}/b1"), Tensor::from_vec(&[d], vec![0.0; d]));
        tensors.insert(format!("{pre}/wf1"), lin(&mut rng, d, cfg.d_ff));
        tensors.insert(format!("{pre}/bf1"), bias(&mut rng, cfg.d_ff));
        tensors.insert(format!("{pre}/wf2"), lin(&mut rng, cfg.d_ff, d));
        tensors.insert(format!("{pre}/bf2"), bias(&mut rng, d));
        tensors.insert(format!("{pre}/g2"), Tensor::from_vec(&[d], vec![1.0; d]));
        tensors.insert(format!("{pre}/b2"), Tensor::from_vec(&[d], vec![0.0; d]));
    }
    tensors.insert(
        "head/w".into(),
        Tensor::from_vec(
            &[d, cfg.n_classes],
            (0..d * cfg.n_classes)
                .map(|_| rng.normal(0.0, (1.0 / d as f64).sqrt()) as f32)
                .collect(),
        ),
    );
    tensors.insert(
        "head/b".into(),
        Tensor::from_vec(&[cfg.n_classes], vec![0.0; cfg.n_classes]),
    );
    Params { tensors }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn softmax_rows_is_a_distribution() {
        let mut s = vec![1.0f32, 2.0, 3.0, -1.0, 0.0, 1.0];
        softmax_rows(&mut s, 3);
        for row in s.chunks(3) {
            let sum: f32 = row.iter().sum();
            assert!((sum - 1.0).abs() < 1e-6);
            assert!(row.iter().all(|&v| (0.0..=1.0).contains(&v)));
        }
        // Larger score → larger weight.
        assert!(s[2] > s[1] && s[1] > s[0]);
    }

    #[test]
    fn softmax_all_equal_rows_are_uniform() {
        let mut s = vec![5.0f32; 8];
        softmax_rows(&mut s, 4);
        for &v in &s {
            assert!((v - 0.25).abs() < 1e-6);
        }
    }

    #[test]
    fn softmax_neg_inf_masking_zeroes_positions() {
        let mut s = vec![0.0f32, f32::NEG_INFINITY, 0.0];
        softmax_rows(&mut s, 3);
        assert_eq!(s[1], 0.0);
        assert!((s[0] - 0.5).abs() < 1e-6 && (s[2] - 0.5).abs() < 1e-6);
    }

    #[test]
    fn softmax_fully_masked_and_nan_rows_fall_back_to_uniform() {
        let mut masked = vec![f32::NEG_INFINITY; 4];
        softmax_rows(&mut masked, 4);
        assert!(masked.iter().all(|&v| (v - 0.25).abs() < 1e-6));
        let mut poisoned = vec![1.0f32, f32::NAN, 2.0, 0.5];
        softmax_rows(&mut poisoned, 4);
        assert!(poisoned.iter().all(|v| v.is_finite()), "NaN must not escape");
        assert!(poisoned.iter().all(|&v| (v - 0.25).abs() < 1e-6));
    }

    #[test]
    fn softmax_single_token_rows_are_one() {
        let mut s = vec![-3.2f32, 9.9, f32::NEG_INFINITY];
        softmax_rows(&mut s, 1);
        // Width-1 rows: finite scores normalize to exactly 1; a fully
        // masked single token takes the uniform fallback, also 1.
        assert_eq!(s, vec![1.0, 1.0, 1.0]);
    }

    #[test]
    fn layer_norm_zero_mean_unit_var() {
        let x: Vec<f32> = (0..32).map(|i| i as f32 * 0.3 - 4.0).collect();
        let g = vec![1.0f32; 16];
        let b = vec![0.0f32; 16];
        let y = layer_norm(&x, 2, 16, &g, &b);
        for row in y.chunks(16) {
            let mean: f32 = row.iter().sum::<f32>() / 16.0;
            let var: f32 = row.iter().map(|v| (v - mean) * (v - mean)).sum::<f32>() / 16.0;
            assert!(mean.abs() < 1e-5);
            assert!((var - 1.0).abs() < 1e-3);
        }
    }

    #[test]
    fn test_params_cover_all_blocks() {
        let cfg = TfmConfig::tiny();
        let p = test_tfm_params(cfg, 1);
        for b in 0..cfg.n_blocks {
            for suffix in ["wqkv", "bqkv", "wo", "bo", "g1", "b1", "wf1", "bf1", "wf2", "bf2", "g2", "b2"]
            {
                assert!(p.tensors.contains_key(&format!("t{b}/{suffix}")), "t{b}/{suffix}");
            }
        }
        assert_eq!(p.get("head/w").unwrap().shape, vec![64, 10]);
        assert_eq!(p.get("t0/wqkv").unwrap().shape, vec![64, 192]);
    }

    #[test]
    fn forward_shapes_all_modes() {
        let cfg = TfmConfig { seq_len: 4, d_model: 16, n_heads: 2, d_ff: 32, ..TfmConfig::tiny() };
        let t = Transformer::new(test_tfm_params(cfg, 3), cfg);
        let mut rng = Pcg64::seeded(7);
        let x = Tensor::from_vec(
            &[2, cfg.seq_len, cfg.d_model],
            (0..2 * cfg.input_elems()).map(|_| rng.f64() as f32).collect(),
        );
        for mode in [
            ForwardMode::Baseline,
            ForwardMode::Pim,
            ForwardMode::PimNoise(0.3),
            ForwardMode::PimHw,
            ForwardMode::PimHwNoise(0.3),
        ] {
            let y = t.forward(&x, mode, 11).unwrap();
            assert_eq!(y.shape, vec![2, cfg.n_classes]);
            assert!(y.data.iter().all(|v| v.is_finite()), "{mode:?}");
        }
    }
}
