//! dataset.bin loader (format written by `python/compile/data.py`):
//! u32 magic 'NVMC', u32 n, u32 h, u32 w, u32 c, f32 images, u8 labels.

use std::path::Path;

use crate::{Error, Result};

use super::tensor::Tensor;

const MAGIC: u32 = 0x4E56_4D43;

/// Loaded evaluation dataset.
#[derive(Clone, Debug)]
pub struct Dataset {
    /// All images, [n, h, w, c].
    pub images: Tensor,
    /// Class label per image.
    pub labels: Vec<u8>,
    /// Number of images.
    pub n: usize,
    /// Image height.
    pub h: usize,
    /// Image width.
    pub w: usize,
    /// Channels.
    pub c: usize,
}

fn read_u32(buf: &[u8], off: usize) -> u32 {
    u32::from_le_bytes(buf[off..off + 4].try_into().unwrap())
}

impl Dataset {
    /// Load a dataset.bin file.
    pub fn load(path: &Path) -> Result<Dataset> {
        let buf = std::fs::read(path)?;
        if buf.len() < 20 || read_u32(&buf, 0) != MAGIC {
            return Err(Error::Artifact(format!("{path:?}: bad dataset magic")));
        }
        let n = read_u32(&buf, 4) as usize;
        let h = read_u32(&buf, 8) as usize;
        let w = read_u32(&buf, 12) as usize;
        let c = read_u32(&buf, 16) as usize;
        let img_bytes = n * h * w * c * 4;
        let expected = 20 + img_bytes + n;
        if buf.len() != expected {
            return Err(Error::Artifact(format!(
                "{path:?}: size {} != expected {expected}",
                buf.len()
            )));
        }
        let mut data = Vec::with_capacity(n * h * w * c);
        for i in 0..(n * h * w * c) {
            let off = 20 + i * 4;
            data.push(f32::from_le_bytes(buf[off..off + 4].try_into().unwrap()));
        }
        let labels = buf[20 + img_bytes..].to_vec();
        Ok(Dataset { images: Tensor::from_vec(&[n, h, w, c], data), labels, n, h, w, c })
    }

    /// Slice a batch [start, start+len) as its own tensor.
    pub fn batch(&self, start: usize, len: usize) -> (Tensor, &[u8]) {
        let end = (start + len).min(self.n);
        let stride = self.h * self.w * self.c;
        let data = self.images.data[start * stride..end * stride].to_vec();
        (
            Tensor::from_vec(&[end - start, self.h, self.w, self.c], data),
            &self.labels[start..end],
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn write_tiny(path: &Path) {
        let n = 3usize;
        let (h, w, c) = (2usize, 2usize, 1usize);
        let mut buf = Vec::new();
        for v in [MAGIC, n as u32, h as u32, w as u32, c as u32] {
            buf.extend_from_slice(&v.to_le_bytes());
        }
        for i in 0..(n * h * w * c) {
            buf.extend_from_slice(&(i as f32 * 0.1).to_le_bytes());
        }
        buf.extend_from_slice(&[0u8, 1, 2]);
        std::fs::write(path, buf).unwrap();
    }

    #[test]
    fn roundtrip() {
        let path = std::env::temp_dir().join("nvm_dataset_test.bin");
        write_tiny(&path);
        let ds = Dataset::load(&path).unwrap();
        assert_eq!(ds.n, 3);
        assert_eq!(ds.labels, vec![0, 1, 2]);
        assert!((ds.images.data[5] - 0.5).abs() < 1e-6);
        let (batch, labels) = ds.batch(1, 2);
        assert_eq!(batch.shape, vec![2, 2, 2, 1]);
        assert_eq!(labels, &[1, 2]);
        assert!((batch.data[0] - 0.4).abs() < 1e-6);
    }

    #[test]
    fn rejects_bad_magic() {
        let path = std::env::temp_dir().join("nvm_dataset_bad.bin");
        std::fs::write(&path, [0u8; 24]).unwrap();
        assert!(Dataset::load(&path).is_err());
    }
}
