//! Minimal NHWC tensor.

/// Dense f32 tensor, row-major over its shape.
#[derive(Clone, Debug, PartialEq)]
pub struct Tensor {
    /// Dimension sizes.
    pub shape: Vec<usize>,
    /// Elements, row-major.
    pub data: Vec<f32>,
}

impl Tensor {
    /// Zero-filled tensor of the given shape.
    pub fn zeros(shape: &[usize]) -> Tensor {
        Tensor { shape: shape.to_vec(), data: vec![0.0; shape.iter().product()] }
    }

    /// Tensor from existing data (length must match the shape product).
    pub fn from_vec(shape: &[usize], data: Vec<f32>) -> Tensor {
        assert_eq!(data.len(), shape.iter().product::<usize>(), "shape {shape:?}");
        Tensor { shape: shape.to_vec(), data }
    }

    /// Total element count.
    pub fn len(&self) -> usize {
        self.data.len()
    }

    /// Whether the tensor has no elements.
    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    /// Size of dimension `i`.
    pub fn dim(&self, i: usize) -> usize {
        self.shape[i]
    }

    /// NHWC index.
    #[inline]
    pub fn at4(&self, n: usize, h: usize, w: usize, c: usize) -> f32 {
        let (_, hh, ww, cc) = (self.shape[0], self.shape[1], self.shape[2], self.shape[3]);
        self.data[((n * hh + h) * ww + w) * cc + c]
    }

    /// Mutable NHWC index.
    #[inline]
    pub fn at4_mut(&mut self, n: usize, h: usize, w: usize, c: usize) -> &mut f32 {
        let (hh, ww, cc) = (self.shape[1], self.shape[2], self.shape[3]);
        &mut self.data[((n * hh + h) * ww + w) * cc + c]
    }

    /// Elementwise map (consuming).
    pub fn map(mut self, f: impl Fn(f32) -> f32) -> Tensor {
        for x in self.data.iter_mut() {
            *x = f(*x);
        }
        self
    }

    /// Elementwise add (shapes must match).
    pub fn add(mut self, other: &Tensor) -> Tensor {
        assert_eq!(self.shape, other.shape);
        for (a, b) in self.data.iter_mut().zip(&other.data) {
            *a += b;
        }
        self
    }

    /// Elementwise max(x, 0).
    pub fn relu(self) -> Tensor {
        self.map(|x| x.max(0.0))
    }

    /// Max |a−b| against another tensor.
    pub fn max_abs_diff(&self, other: &Tensor) -> f32 {
        assert_eq!(self.shape, other.shape);
        self.data
            .iter()
            .zip(&other.data)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0, f32::max)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn indexing_nhwc() {
        let mut t = Tensor::zeros(&[2, 3, 4, 5]);
        *t.at4_mut(1, 2, 3, 4) = 7.0;
        assert_eq!(t.at4(1, 2, 3, 4), 7.0);
        assert_eq!(t.data[t.len() - 1], 7.0);
    }

    #[test]
    fn relu_and_add() {
        let a = Tensor::from_vec(&[1, 1, 1, 2], vec![-1.0, 2.0]);
        let b = Tensor::from_vec(&[1, 1, 1, 2], vec![0.5, 0.5]);
        let r = a.relu().add(&b);
        assert_eq!(r.data, vec![0.5, 2.5]);
    }

    #[test]
    #[should_panic]
    fn bad_shape_rejected() {
        Tensor::from_vec(&[2, 2], vec![1.0]);
    }
}
