//! Rust-native inference stack.
//!
//! Mirrors `python/compile/model.py` exactly (same topology, GroupNorm,
//! padding rules, quantization): the fp32 path is the digital baseline, and
//! the PIM path routes every conv/fc matmul through
//! [`crate::pim::PimEngine`] — so Table II can be regenerated natively and
//! cross-checked against the PJRT-executed JAX artifacts.
//!
//! * [`tensor`] — minimal NHWC tensor.
//! * [`layers`] — conv (im2col), GroupNorm, ReLU, global-avg-pool, linear.
//! * [`resnet`] — the ResNet-18-topology network + weights.bin loading.
//! * [`transformer`] — the second workload family: a small quantized
//!   pre-LN encoder (fused QKV, multi-head attention with an
//!   integer-friendly softmax, 2-layer FFN) whose weight-stationary
//!   matmuls compile to prepared banks via
//!   [`crate::pim::attn::CompiledTransformer`].
//! * [`dataset`] — dataset.bin loading.
//!
//! Execution follows the compile-once / execute-many split of
//! [`crate::pim::program`]: [`ResNet::compile`] builds a
//! [`crate::pim::program::CompiledNet`] once (dense im2col weights +
//! prepared quantized banks), and the one-shot `forward`/`conv2d`/`linear`
//! entry points are thin compile-then-run wrappers over it — bit-identical
//! either way (`rust/tests/program_parity.rs`).

pub mod dataset;
pub mod layers;
pub mod resnet;
pub mod tensor;
pub mod transformer;

pub use dataset::Dataset;
pub use resnet::{ForwardMode, ResNet};
pub use tensor::Tensor;
pub use transformer::{TfmConfig, Transformer};
