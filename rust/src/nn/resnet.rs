//! ResNet-18-topology network (BasicBlocks [2,2,2,2], width 16) matching
//! `python/compile/model.py` layer-for-layer, plus weights.bin parsing.

use std::collections::BTreeMap;
use std::path::Path;

use crate::pim::parallel::Parallelism;
use crate::pim::program::{CompiledNet, ScratchPool};
use crate::util::rng::Pcg64;
use crate::{Error, Result};

use super::tensor::Tensor;

const MAGIC: u32 = 0x4E56_4D57;
/// Block counts per stage (ResNet-18).
pub const STAGES: [usize; 4] = [2, 2, 2, 2];

/// Forward mode, mirroring model.py's variants.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum ForwardMode {
    /// Dense fp32.
    Baseline,
    /// The paper's §V-E Table II emulation: exact convs + per-layer 6-bit
    /// signed ADC transfer (matches python mode "pim").
    Pim,
    /// Emulation + Gaussian ADC noise (sigma in code units; python
    /// "pim_noise").
    PimNoise(f64),
    /// Hardware-true pipeline: 4-bit quantized matmuls with per-block,
    /// per-plane conversions (python "pim_hw" / the L1 kernel).
    PimHw,
    /// Hardware-true + per-conversion noise.
    PimHwNoise(f64),
}

/// Parameter store: flat name → tensor (names as in model.flatten_params).
#[derive(Clone, Debug)]
pub struct Params {
    /// All parameters by flat name (e.g. `s0b1/w1`, `fc/b`).
    pub tensors: BTreeMap<String, Tensor>,
}

impl Params {
    /// Parse weights.bin (format in model.py::write_weights_bin).
    pub fn load(path: &Path) -> Result<Params> {
        let buf = std::fs::read(path)?;
        let rd_u32 =
            |off: usize| u32::from_le_bytes(buf[off..off + 4].try_into().unwrap());
        if buf.len() < 8 || rd_u32(0) != MAGIC {
            return Err(Error::Artifact(format!("{path:?}: bad weights magic")));
        }
        let count = rd_u32(4) as usize;
        let mut off = 8;
        let mut tensors = BTreeMap::new();
        for _ in 0..count {
            let name_len = rd_u32(off) as usize;
            off += 4;
            let name = String::from_utf8(buf[off..off + name_len].to_vec())
                .map_err(|e| Error::Artifact(e.to_string()))?;
            off += name_len;
            let ndim = rd_u32(off) as usize;
            off += 4;
            let mut shape = Vec::with_capacity(ndim);
            for _ in 0..ndim {
                shape.push(rd_u32(off) as usize);
                off += 4;
            }
            let len: usize = shape.iter().product::<usize>().max(1);
            let mut data = Vec::with_capacity(len);
            for i in 0..len {
                data.push(f32::from_le_bytes(
                    buf[off + i * 4..off + i * 4 + 4].try_into().unwrap(),
                ));
            }
            off += len * 4;
            // 0-dim scalars get shape [1].
            let shape = if shape.is_empty() { vec![1] } else { shape };
            tensors.insert(name, Tensor::from_vec(&shape, data));
        }
        if off != buf.len() {
            return Err(Error::Artifact(format!("{path:?}: trailing bytes")));
        }
        Ok(Params { tensors })
    }

    /// Look up a parameter by name.
    pub fn get(&self, name: &str) -> Result<&Tensor> {
        self.tensors
            .get(name)
            .ok_or_else(|| Error::Artifact(format!("missing param `{name}`")))
    }
}

/// The network.
pub struct ResNet {
    /// Weights and norm parameters.
    pub params: Params,
    /// Stem width (channels after the first conv).
    pub width: usize,
    /// Worker-pool width every [`ResNet::forward`] matmul is tiled over
    /// (serial by default; output is bit-identical at any width).
    pub parallelism: Parallelism,
}

impl ResNet {
    /// Wrap a parameter store (width inferred from the stem conv).
    pub fn new(params: Params) -> ResNet {
        let width = params
            .tensors
            .get("stem/w")
            .map(|t| t.shape[3])
            .unwrap_or(16);
        ResNet { params, width, parallelism: Parallelism::serial() }
    }

    /// Load from a weights.bin file.
    pub fn load(path: &Path) -> Result<ResNet> {
        Ok(Self::new(Params::load(path)?))
    }

    /// Set the worker-pool width used by [`ResNet::forward`].
    pub fn with_parallelism(mut self, par: Parallelism) -> ResNet {
        self.parallelism = par;
        self
    }

    /// Compile every layer once — dense im2col weights plus prepared
    /// quantized banks — into a [`CompiledNet`] that executes any
    /// [`ForwardMode`] with zero further weight preparation. The compiled
    /// forward is bit-identical to [`ResNet::forward`] in every mode at
    /// any thread count (`rust/tests/program_parity.rs`).
    pub fn compile(&self) -> Result<CompiledNet> {
        CompiledNet::compile(self)
    }

    /// Forward pass: x [N,16,16,3] → logits [N,10]. Runs conv/fc matmuls
    /// on [`ResNet::parallelism`].
    ///
    /// One-shot compile-then-run over [`ResNet::compile`]; serving loops
    /// should compile once and call [`CompiledNet::forward_par`] instead.
    pub fn forward(&self, x: &Tensor, mode: ForwardMode, seed: u64) -> Result<Tensor> {
        self.forward_par(x, mode, seed, self.parallelism)
    }

    /// [`ResNet::forward`] on an explicit worker-pool width — every conv
    /// and fc matmul (dense or PIM) is tiled over the
    /// [`crate::pim::parallel`] pool; logits are bit-identical at any
    /// thread count.
    pub fn forward_par(
        &self,
        x: &Tensor,
        mode: ForwardMode,
        seed: u64,
        par: Parallelism,
    ) -> Result<Tensor> {
        // Compile only what the mode reads: the fp32/emulation forwards
        // never touch the quantized banks, so the one-shot path skips
        // preparing them (same cost profile as the pre-program engine).
        let program = match mode {
            ForwardMode::PimHw | ForwardMode::PimHwNoise(_) => CompiledNet::compile(self)?,
            _ => CompiledNet::compile_dense(self)?,
        };
        Ok(program.forward_par(x, mode, seed, par, &mut ScratchPool::new()))
    }

    /// Classify a batch: argmax over logits (`total_cmp` ordering, same
    /// tie/NaN semantics as [`crate::pim::program::logits_to_classes`]).
    pub fn classify(&self, x: &Tensor, mode: ForwardMode, seed: u64) -> Result<Vec<u8>> {
        let logits = self.forward(x, mode, seed)?;
        Ok(crate::pim::program::logits_to_classes(&logits))
    }
}

/// Synthetic params for tests (He-like init, deterministic).
pub fn test_params(width: usize, n_classes: usize, seed: u64) -> Params {
    let mut rng = Pcg64::seeded(seed);
    let mut tensors = BTreeMap::new();
    let conv = |rng: &mut Pcg64, kh: usize, kw: usize, cin: usize, cout: usize| {
        let fan_in = (kh * kw * cin) as f64;
        let std = (2.0 / fan_in).sqrt();
        Tensor::from_vec(
            &[kh, kw, cin, cout],
            (0..kh * kw * cin * cout)
                .map(|_| rng.normal(0.0, std) as f32)
                .collect(),
        )
    };
    tensors.insert("stem/w".into(), conv(&mut rng, 3, 3, 3, width));
    tensors.insert("stem/gamma".into(), Tensor::from_vec(&[width], vec![1.0; width]));
    tensors.insert("stem/beta".into(), Tensor::from_vec(&[width], vec![0.0; width]));
    let mut cin = width;
    for (s, &nblocks) in STAGES.iter().enumerate() {
        let cout = width << s;
        for b in 0..nblocks {
            let pre = format!("s{s}b{b}");
            tensors.insert(format!("{pre}/w1"), conv(&mut rng, 3, 3, cin, cout));
            tensors.insert(format!("{pre}/g1"), Tensor::from_vec(&[cout], vec![1.0; cout]));
            tensors.insert(format!("{pre}/b1"), Tensor::from_vec(&[cout], vec![0.0; cout]));
            tensors.insert(format!("{pre}/w2"), conv(&mut rng, 3, 3, cout, cout));
            tensors.insert(format!("{pre}/g2"), Tensor::from_vec(&[cout], vec![1.0; cout]));
            tensors.insert(format!("{pre}/b2"), Tensor::from_vec(&[cout], vec![0.0; cout]));
            let st = if b == 0 && s > 0 { 2 } else { 1 };
            if st != 1 || cin != cout {
                tensors.insert(format!("{pre}/wd"), conv(&mut rng, 1, 1, cin, cout));
            }
            cin = cout;
        }
    }
    tensors.insert(
        "fc/w".into(),
        Tensor::from_vec(
            &[cin, n_classes],
            (0..cin * n_classes)
                .map(|_| rng.normal(0.0, (1.0 / cin as f64).sqrt()) as f32)
                .collect(),
        ),
    );
    tensors.insert("fc/b".into(), Tensor::from_vec(&[n_classes], vec![0.0; n_classes]));
    Params { tensors }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_input(n: usize, seed: u64) -> Tensor {
        let mut rng = Pcg64::seeded(seed);
        Tensor::from_vec(
            &[n, 16, 16, 3],
            (0..n * 16 * 16 * 3).map(|_| rng.f64() as f32).collect(),
        )
    }

    #[test]
    fn forward_shapes() {
        let net = ResNet::new(test_params(8, 10, 1));
        let x = tiny_input(2, 2);
        let y = net.forward(&x, ForwardMode::Baseline, 0).unwrap();
        assert_eq!(y.shape, vec![2, 10]);
        assert!(y.data.iter().all(|v| v.is_finite()));
    }

    #[test]
    fn pim_forward_tracks_baseline() {
        let net = ResNet::new(test_params(8, 10, 3));
        let x = tiny_input(2, 4);
        let base = net.forward(&x, ForwardMode::Baseline, 0).unwrap();
        let pim = net.forward(&x, ForwardMode::Pim, 0).unwrap();
        // Random untrained nets diverge under quantization, but outputs
        // must stay finite and of comparable magnitude.
        assert!(pim.data.iter().all(|v| v.is_finite()));
        let b_scale = base.data.iter().map(|v| v.abs()).fold(0.0f32, f32::max);
        let p_scale = pim.data.iter().map(|v| v.abs()).fold(0.0f32, f32::max);
        assert!(p_scale < 50.0 * b_scale.max(0.1));
    }

    #[test]
    fn noise_mode_deterministic_by_seed() {
        let net = ResNet::new(test_params(8, 10, 5));
        let x = tiny_input(1, 6);
        let a = net.forward(&x, ForwardMode::PimNoise(0.3), 42).unwrap();
        let b = net.forward(&x, ForwardMode::PimNoise(0.3), 42).unwrap();
        let c = net.forward(&x, ForwardMode::PimNoise(0.3), 43).unwrap();
        assert_eq!(a.data, b.data);
        assert_ne!(a.data, c.data);
    }

    #[test]
    fn forward_par_bit_identical_all_modes() {
        let net = ResNet::new(test_params(8, 10, 11));
        let x = tiny_input(2, 12);
        for mode in [
            ForwardMode::Baseline,
            ForwardMode::Pim,
            ForwardMode::PimNoise(0.3),
            ForwardMode::PimHw,
            ForwardMode::PimHwNoise(0.3),
        ] {
            let serial = net.forward(&x, mode, 5).unwrap();
            for t in [2usize, 7] {
                let par = net.forward_par(&x, mode, 5, Parallelism::threads(t)).unwrap();
                assert_eq!(serial.data, par.data, "{mode:?} threads={t}");
            }
        }
    }

    #[test]
    fn classify_argmax() {
        let net = ResNet::new(test_params(8, 10, 7));
        let x = tiny_input(3, 8);
        let preds = net.classify(&x, ForwardMode::Baseline, 0).unwrap();
        assert_eq!(preds.len(), 3);
        assert!(preds.iter().all(|&p| p < 10));
    }

    #[test]
    fn params_roundtrip_via_file() {
        // Write a weights.bin in the python format and re-load it.
        let p = test_params(8, 10, 9);
        let path = std::env::temp_dir().join("nvm_weights_test.bin");
        let mut buf = Vec::new();
        buf.extend_from_slice(&MAGIC.to_le_bytes());
        buf.extend_from_slice(&(p.tensors.len() as u32).to_le_bytes());
        for (name, t) in &p.tensors {
            buf.extend_from_slice(&(name.len() as u32).to_le_bytes());
            buf.extend_from_slice(name.as_bytes());
            buf.extend_from_slice(&(t.shape.len() as u32).to_le_bytes());
            for d in &t.shape {
                buf.extend_from_slice(&(*d as u32).to_le_bytes());
            }
            for v in &t.data {
                buf.extend_from_slice(&v.to_le_bytes());
            }
        }
        std::fs::write(&path, buf).unwrap();
        let loaded = Params::load(&path).unwrap();
        assert_eq!(loaded.tensors.len(), p.tensors.len());
        assert_eq!(loaded.get("stem/w").unwrap().data, p.get("stem/w").unwrap().data);
    }
}
