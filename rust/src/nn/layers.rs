//! Inference layers, matched operation-for-operation to
//! `python/compile/model.py`.
//!
//! The conv/linear entry points follow the compile-once / execute-many
//! split of [`crate::pim::program`]: [`CompiledConv`] /
//! [`crate::pim::program::CompiledLinear`] hold a layer's reordered dense
//! weights plus (optionally) the prepared quantized banks, and the
//! historical one-shot functions below run the same prepared core,
//! re-preparing per call — so both paths are bit-identical.

use crate::pim::parallel::Parallelism;
use crate::pim::program::{CompiledConv, ScratchPool};
use crate::pim::PimEngine;
use crate::util::rng::Pcg64;

use super::tensor::Tensor;

/// XLA/TF 'SAME' padding split: total = max((ow−1)·s + k − w, 0),
/// lo = total/2, hi = total − lo.
///
/// Degenerate inputs are defined, not panics: a zero-width input (the
/// only way `ow` can reach 0 for `stride ≥ 1`) yields `(0, 0, 0)` — an
/// empty output plane with no padding.
pub fn same_padding(w: usize, k: usize, stride: usize) -> (usize, usize, usize) {
    let ow = w.div_ceil(stride);
    if ow == 0 {
        return (0, 0, 0);
    }
    let total = ((ow - 1) * stride + k).saturating_sub(w);
    (ow, total / 2, total - total / 2)
}

/// im2col: NHWC input → [N·OH·OW, C·K·K] patches with channel-major
/// feature order (c·K·K + ky·K + kx), matching
/// `jax.lax.conv_general_dilated_patches` as used in model.py.
pub fn im2col(x: &Tensor, k: usize, stride: usize) -> (Tensor, usize, usize) {
    let mut buf = Vec::new();
    let (rows, oh, ow) = im2col_into(x, k, stride, &mut buf);
    let kdim = x.shape[3] * k * k;
    (Tensor::from_vec(&[rows, kdim], buf), oh, ow)
}

/// [`im2col`] into a caller-owned buffer (cleared, zero-filled, and
/// resized to `rows × C·K·K`) — the scratch-pool form the compiled
/// execution path reuses across layers and batches. Returns
/// `(rows, oh, ow)` with `rows = N·OH·OW`.
pub fn im2col_into(
    x: &Tensor,
    k: usize,
    stride: usize,
    out: &mut Vec<f32>,
) -> (usize, usize, usize) {
    let (n, h, w, c) = (x.shape[0], x.shape[1], x.shape[2], x.shape[3]);
    let (oh, pad_lo_h, _) = same_padding(h, k, stride);
    let (ow, pad_lo_w, _) = same_padding(w, k, stride);
    let kdim = c * k * k;
    let rows = n * oh * ow;
    out.clear();
    out.resize(rows * kdim, 0.0);
    for ni in 0..n {
        for oy in 0..oh {
            for ox in 0..ow {
                let row = (ni * oh + oy) * ow + ox;
                let base = row * kdim;
                for ci in 0..c {
                    for ky in 0..k {
                        let iy = (oy * stride + ky) as isize - pad_lo_h as isize;
                        if iy < 0 || iy >= h as isize {
                            continue;
                        }
                        for kx in 0..k {
                            let ix = (ox * stride + kx) as isize - pad_lo_w as isize;
                            if ix < 0 || ix >= w as isize {
                                continue;
                            }
                            out[base + ci * k * k + ky * k + kx] =
                                x.at4(ni, iy as usize, ix as usize, ci);
                        }
                    }
                }
            }
        }
    }
    (rows, oh, ow)
}

/// Reorder HWIO conv weights to the im2col layout [C·K·K, OC].
pub fn weights_to_matrix(w_hwio: &Tensor) -> Tensor {
    let (kh, kw, cin, cout) = (w_hwio.shape[0], w_hwio.shape[1], w_hwio.shape[2], w_hwio.shape[3]);
    let mut m = Tensor::zeros(&[cin * kh * kw, cout]);
    for ky in 0..kh {
        for kx in 0..kw {
            for ci in 0..cin {
                for co in 0..cout {
                    let src = ((ky * kw + kx) * cin + ci) * cout + co;
                    let dst = (ci * kh * kw + ky * kw + kx) * cout + co;
                    m.data[dst] = w_hwio.data[src];
                }
            }
        }
    }
    m
}

/// Dense fp32 matmul: [m,k] × [k,n] → [m,n].
pub fn matmul(a: &Tensor, b: &Tensor) -> Tensor {
    matmul_par(a, b, Parallelism::serial())
}

/// [`matmul`] with rows fanned over the persistent
/// [`crate::pim::parallel`] pool (no per-call thread spawns) —
/// bit-identical to the serial result at any thread count.
pub fn matmul_par(a: &Tensor, b: &Tensor, par: Parallelism) -> Tensor {
    let (m, k) = (a.shape[0], a.shape[1]);
    let (k2, n) = (b.shape[0], b.shape[1]);
    assert_eq!(k, k2);
    Tensor::from_vec(&[m, n], PimEngine::par_exact_matmul(&a.data, m, k, &b.data, n, par))
}

/// Convolution. `engine = None` ⇒ dense fp32; otherwise the quantized PIM
/// pipeline (with optional per-conversion noise RNG). Runs on the engine's
/// own [`Parallelism`] (dense path: serial); see [`conv2d_par`].
pub fn conv2d(
    x: &Tensor,
    w_hwio: &Tensor,
    stride: usize,
    engine: Option<&PimEngine>,
    rng: Option<&mut Pcg64>,
) -> Tensor {
    let par = engine.map(|e| e.parallelism).unwrap_or_default();
    conv2d_par(x, w_hwio, stride, engine, rng, par)
}

/// [`conv2d`] on an explicit worker-pool width (both the dense and the
/// PIM path); output is bit-identical at any thread count.
///
/// One-shot compile-then-run over [`CompiledConv`]: re-reorders (and, on
/// the PIM path, re-quantizes + re-packs) the weights every call.
/// Execute-many callers should compile once ([`CompiledConv::compile`])
/// and call [`CompiledConv::forward`].
pub fn conv2d_par(
    x: &Tensor,
    w_hwio: &Tensor,
    stride: usize,
    engine: Option<&PimEngine>,
    rng: Option<&mut Pcg64>,
    par: Parallelism,
) -> Tensor {
    let compiled = CompiledConv::compile(w_hwio, stride, x.shape[2], engine.is_some());
    compiled.forward(x, engine, rng, par, &mut ScratchPool::new())
}

/// GroupNorm over NHWC with `groups = min(8, c)` (matches model.py).
pub fn group_norm(x: &Tensor, gamma: &[f32], beta: &[f32], eps: f32) -> Tensor {
    let (n, h, w, c) = (x.shape[0], x.shape[1], x.shape[2], x.shape[3]);
    let g = 8.min(c);
    assert_eq!(c % g, 0, "channels {c} not divisible by groups {g}");
    let cg = c / g;
    let mut out = x.clone();
    for ni in 0..n {
        for gi in 0..g {
            // Mean/var over (h, w, channels-in-group).
            let mut sum = 0.0f64;
            let mut sq = 0.0f64;
            for hi in 0..h {
                for wi in 0..w {
                    for cj in 0..cg {
                        let v = x.at4(ni, hi, wi, gi * cg + cj) as f64;
                        sum += v;
                        sq += v * v;
                    }
                }
            }
            let cnt = (h * w * cg) as f64;
            let mean = sum / cnt;
            let var = (sq / cnt - mean * mean).max(0.0);
            let inv = 1.0 / (var + eps as f64).sqrt();
            for hi in 0..h {
                for wi in 0..w {
                    for cj in 0..cg {
                        let ci = gi * cg + cj;
                        let v = out.at4_mut(ni, hi, wi, ci);
                        *v = (((*v as f64 - mean) * inv) as f32) * gamma[ci] + beta[ci];
                    }
                }
            }
        }
    }
    out
}

/// Global average pool NHWC → [N, C].
pub fn global_avg_pool(x: &Tensor) -> Tensor {
    let (n, h, w, c) = (x.shape[0], x.shape[1], x.shape[2], x.shape[3]);
    let mut out = Tensor::zeros(&[n, c]);
    let scale = 1.0 / (h * w) as f32;
    for ni in 0..n {
        for hi in 0..h {
            for wi in 0..w {
                for ci in 0..c {
                    out.data[ni * c + ci] += x.at4(ni, hi, wi, ci) * scale;
                }
            }
        }
    }
    out
}

/// The §V-E Table II ADC emulation, applied per layer output (mirrors
/// `model.py::make_adc_emulate` exactly): activations are mapped into the
/// 6-bit *signed* range, pushed through the continuous nonlinear transfer,
/// rounded, and inversely mapped; optional Gaussian code noise.
pub fn adc_emulate(
    y: &Tensor,
    transfer: &crate::pim::TransferModel,
    sigma_codes: Option<f64>,
    rng: Option<&mut Pcg64>,
) -> Tensor {
    const HALF: f64 = 31.0; // ADC_SIGNED_MAX
    let fullscale = crate::pim::transfer::MAC_FULLSCALE as f64;
    let max = y.data.iter().map(|v| v.abs()).fold(0.0f32, f32::max).max(1e-6) as f64;
    let s = max / HALF;
    let mut out = y.clone();
    let mut rng = rng;
    for v in out.data.iter_mut() {
        let u = *v as f64 / s;
        let mac = u.abs() * (fullscale / HALF);
        let u_nl = u.signum() * transfer.transfer_continuous(mac) * (HALF / fullscale);
        let mut code = u_nl.round().clamp(-HALF - 1.0, HALF);
        if let (Some(sig), Some(r)) = (sigma_codes, rng.as_deref_mut()) {
            code += r.normal(0.0, sig);
        }
        *v = (code * s) as f32;
    }
    out
}

/// Linear layer [N, K] × [K, C] + bias, optionally through the PIM engine
/// (inputs passed through ReLU first in the PIM path, matching model.py).
pub fn linear(
    x: &Tensor,
    w: &Tensor,
    bias: &[f32],
    engine: Option<&PimEngine>,
    rng: Option<&mut Pcg64>,
) -> Tensor {
    let par = engine.map(|e| e.parallelism).unwrap_or_default();
    linear_par(x, w, bias, engine, rng, par)
}

/// [`linear`] on an explicit worker-pool width; bit-identical at any
/// thread count.
///
/// One-shot: the PIM path re-prepares `w` internally on every call (via
/// [`PimEngine::par_matmul`]), without copying the dense weights the way
/// a throwaway [`crate::pim::program::CompiledLinear`] would.
/// Execute-many callers should compile once
/// ([`crate::pim::program::CompiledLinear::compile`]) and call
/// [`crate::pim::program::CompiledLinear::forward`].
pub fn linear_par(
    x: &Tensor,
    w: &Tensor,
    bias: &[f32],
    engine: Option<&PimEngine>,
    rng: Option<&mut Pcg64>,
    par: Parallelism,
) -> Tensor {
    let (n, k) = (x.shape[0], x.shape[1]);
    let c = w.shape[1];
    let mut out = match engine {
        None => matmul_par(x, w, par),
        Some(eng) => {
            let relu_x: Vec<f32> = x.data.iter().map(|v| v.max(0.0)).collect();
            Tensor::from_vec(&[n, c], eng.par_matmul(&relu_x, n, k, &w.data, c, rng, par))
        }
    };
    for ni in 0..n {
        for ci in 0..c {
            out.data[ni * c + ci] += bias[ci];
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn same_padding_rules() {
        assert_eq!(same_padding(16, 3, 1), (16, 1, 1));
        assert_eq!(same_padding(16, 3, 2), (8, 0, 1));
        assert_eq!(same_padding(16, 1, 1), (16, 0, 0));
        assert_eq!(same_padding(8, 3, 2), (4, 0, 1));
    }

    #[test]
    fn same_padding_degenerate_inputs_defined() {
        // w == 0 used to underflow (ow − 1 on ow == 0) and panic in debug
        // builds; it must return the empty-but-defined result instead.
        assert_eq!(same_padding(0, 3, 1), (0, 0, 0));
        assert_eq!(same_padding(0, 1, 4), (0, 0, 0));
        // stride > w stays defined: a single output column.
        assert_eq!(same_padding(2, 3, 5), (1, 0, 1));
        assert_eq!(same_padding(1, 3, 7), (1, 1, 1));
    }

    #[test]
    fn im2col_into_reuses_buffer_bit_identically() {
        let mut rng = Pcg64::seeded(31);
        let x1 = Tensor::from_vec(
            &[1, 6, 6, 2],
            (0..72).map(|_| rng.range(-1.0, 1.0) as f32).collect(),
        );
        let x2 = Tensor::from_vec(
            &[1, 4, 4, 3],
            (0..48).map(|_| rng.range(-1.0, 1.0) as f32).collect(),
        );
        let mut buf = Vec::new();
        // Dirty the buffer with a larger problem first, then shrink.
        let _ = im2col_into(&x1, 3, 1, &mut buf);
        let (rows, oh, ow) = im2col_into(&x2, 3, 2, &mut buf);
        let (fresh, oh2, ow2) = im2col(&x2, 3, 2);
        assert_eq!((oh, ow), (oh2, ow2));
        assert_eq!(buf.len(), rows * 3 * 3 * 3);
        assert_eq!(buf, fresh.data, "reused buffer must match a fresh im2col");
    }

    #[test]
    fn conv_identity_kernel() {
        // 1×1 identity conv preserves the input.
        let x = Tensor::from_vec(&[1, 2, 2, 2], (0..8).map(|i| i as f32).collect());
        let mut w = Tensor::zeros(&[1, 1, 2, 2]);
        w.data[0] = 1.0; // (0,0,c0,o0)
        w.data[3] = 1.0; // (0,0,c1,o1)
        let y = conv2d(&x, &w, 1, None, None);
        assert_eq!(y.data, x.data);
    }

    #[test]
    fn conv_3x3_manual_check() {
        // Single channel 3×3 input, all-ones 3×3 kernel: center output is
        // the full sum; corners see 4 values (SAME zero padding).
        let x = Tensor::from_vec(&[1, 3, 3, 1], (1..=9).map(|i| i as f32).collect());
        let w = Tensor::from_vec(&[3, 3, 1, 1], vec![1.0; 9]);
        let y = conv2d(&x, &w, 1, None, None);
        assert_eq!(y.shape, vec![1, 3, 3, 1]);
        assert_eq!(y.at4(0, 1, 1, 0), 45.0);
        assert_eq!(y.at4(0, 0, 0, 0), 1.0 + 2.0 + 4.0 + 5.0);
    }

    #[test]
    fn conv_stride2_shape() {
        let x = Tensor::zeros(&[2, 16, 16, 3]);
        let w = Tensor::zeros(&[3, 3, 3, 8]);
        let y = conv2d(&x, &w, 2, None, None);
        assert_eq!(y.shape, vec![2, 8, 8, 8]);
    }

    #[test]
    fn group_norm_normalizes() {
        let mut x = Tensor::zeros(&[1, 2, 2, 8]);
        for (i, v) in x.data.iter_mut().enumerate() {
            *v = i as f32;
        }
        let y = group_norm(&x, &[1.0; 8], &[0.0; 8], 1e-5);
        // Each group (1 channel here, g=8) has zero mean across h,w.
        for c in 0..8 {
            let vals: Vec<f32> = (0..2)
                .flat_map(|h| (0..2).map(move |w| (h, w)))
                .map(|(h, w)| y.at4(0, h, w, c))
                .collect();
            let mean: f32 = vals.iter().sum::<f32>() / 4.0;
            assert!(mean.abs() < 1e-5, "c={c} mean={mean}");
            let var: f32 = vals.iter().map(|v| (v - mean) * (v - mean)).sum::<f32>() / 4.0;
            assert!((var - 1.0).abs() < 1e-2, "c={c} var={var}");
        }
    }

    #[test]
    fn gap_averages() {
        let x = Tensor::from_vec(&[1, 2, 2, 1], vec![1.0, 2.0, 3.0, 4.0]);
        let y = global_avg_pool(&x);
        assert_eq!(y.data, vec![2.5]);
    }

    #[test]
    fn linear_with_bias() {
        let x = Tensor::from_vec(&[1, 2], vec![1.0, 2.0]);
        let w = Tensor::from_vec(&[2, 2], vec![1.0, 0.0, 0.0, 1.0]);
        let y = linear(&x, &w, &[10.0, 20.0], None, None);
        assert_eq!(y.data, vec![11.0, 22.0]);
    }

    #[test]
    fn conv2d_par_bit_identical_both_paths() {
        let mut rng = Pcg64::seeded(17);
        let x = Tensor::from_vec(
            &[2, 8, 8, 4],
            (0..512).map(|_| rng.range(0.0, 1.0) as f32).collect(),
        );
        let w = Tensor::from_vec(
            &[3, 3, 4, 8],
            (0..288).map(|_| rng.range(-0.3, 0.3) as f32).collect(),
        );
        // Dense path.
        let dense = conv2d(&x, &w, 1, None, None);
        let dense_par = conv2d_par(&x, &w, 1, None, None, Parallelism::threads(3));
        assert_eq!(dense.data, dense_par.data);
        // PIM path.
        let eng = PimEngine::tt();
        let pim = conv2d(&x, &w, 1, Some(&eng), None);
        let pim_par = conv2d_par(&x, &w, 1, Some(&eng), None, Parallelism::threads(3));
        assert_eq!(pim.data, pim_par.data);
    }

    #[test]
    fn pim_conv_close_to_dense() {
        let mut rng = Pcg64::seeded(3);
        let x = Tensor::from_vec(
            &[1, 8, 8, 4],
            (0..256).map(|_| rng.range(0.0, 1.0) as f32).collect(),
        );
        let w = Tensor::from_vec(
            &[3, 3, 4, 8],
            (0..288).map(|_| rng.range(-0.3, 0.3) as f32).collect(),
        );
        let dense = conv2d(&x, &w, 1, None, None);
        let eng = PimEngine::tt();
        let pim = conv2d(&x, &w, 1, Some(&eng), None);
        assert_eq!(dense.shape, pim.shape);
        let scale = dense.data.iter().map(|v| v.abs()).fold(0.0f32, f32::max);
        let err = dense.max_abs_diff(&pim);
        assert!(err < 0.5 * scale, "err {err} scale {scale}");
    }
}
