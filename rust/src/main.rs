//! `repro` — the NVM-in-Cache reproduction CLI (L3 leader entrypoint).
//!
//! Subcommands:
//!   figures   --all | --fig {9a,9b,10,11,12,13,14,scalars} [--out DIR] [--mc N]
//!   table1    [--artifacts DIR] [--out DIR]
//!   table2    [--artifacts DIR] [--out DIR]           (manifest accuracies)
//!   e2e       [--artifacts DIR] [--variant V] [--limit N]
//!             re-measures Table II through the runtime backend on dataset.bin
//!   serve     [--artifacts DIR] [--requests N] [--batch B] [--native]
//!             [--threads T] [--continuous]
//!             demo serving run with the dynamic batcher + bank scheduler;
//!             T sizes the executor's persistent pim::parallel worker
//!             pool (0 = auto-size from available_parallelism);
//!             --continuous merges requests into in-flight executions at
//!             layer boundaries instead of drain batching
//!   serve-sim [--replicas N] [--requests N] [--seed S] [--threads T]
//!             [--arrival {poisson,diurnal,burst}] [--policy {shed,delay}]
//!             [--discipline {both,drain,continuous}] [--queue-cap N]
//!             [--max-batch B] [--out DIR]
//!             continuous-batching front-door simulation: open-loop
//!             offered-load sweep on a fixed fleet, latency/throughput
//!             knee + per-component bottleneck attribution, M/D/c
//!             analytic cross-check, and the merged-wave demo on the
//!             real stepped executor (writes DIR/serve_sim.json)
//!   fleet-sim [--slices N] [--tenants N] [--requests N] [--seed S]
//!             [--campaign-at FRAC] [--live] [--no-wide] [--no-tfm]
//!             [--threads T] [--out DIR]
//!             multi-tenant fleet simulation: placement (replica- or
//!             shard-parallel per tenant), campaigns, QoS, wear, and
//!             shard-chain transfer attribution. By default the fleet
//!             includes an over-capacity wide-ResNet tenant served as a
//!             pipelined shard chain (--no-wide restores the
//!             replica-only fleet; --slices defaults to 8 so the chain
//!             has room) AND the two quantized transformer tenants
//!             (tfm-tiny-d64, tfm-base-d128) so mixed CNN+transformer
//!             serving with per-tenant attribution is the standard
//!             scenario (--no-tfm restores the CNN-only fleet). Writes
//!             DIR/fleet_sim.json; campaigns fire at FRAC of each
//!             tenant's traffic horizon; T parallelizes the --live
//!             executors (0 = auto)
//!   bench     [--quick] [--threads T] [--json [FILE]]
//!             hot-path micro-benchmarks, serial vs T-thread tiled execution
//!             (engine matmul + ResNet-18 stub inference; T=0 auto-sizes), the
//!             simd_vs_scalar MAC-kernel race (word-wide bit-plane
//!             popcount vs the historical scalar kernel, parity + speedup),
//!             the prepare_vs_execute section (one-time weight-program
//!             compile cost vs steady-state prepared execution,
//!             amortization ratios), the serve section (front-door knee
//!             determinism, M/D/c cross-check, merged-execution parity),
//!             the shard section (pipelined shard-executor parity,
//!             over-capacity placement, hop-transfer attribution),
//!             the transformer section (compiled attention block vs
//!             spec_attn parity across kernels/threads/modes, mixed
//!             CNN+transformer fleet gate, attention steady-state
//!             zero-prepare gate),
//!             the hotpath section (persistent-pool dispatch vs
//!             spawn-per-call, pool/zero-skip parity, steady-state
//!             zero-alloc + spawn-once gates),
//!             + fleet-sim summary; --json writes the machine-readable
//!             perf-trajectory record (BENCH_PR10.json, or FILE when
//!             given) — see PERFORMANCE.md
//!   info      print headline perf model numbers

use std::path::PathBuf;

use nvm_in_cache::cache::addr::Geometry;
use nvm_in_cache::cache::controller::PimIntegration;
use nvm_in_cache::coordinator::server::{Executor, NativeExecutor, RuntimeExecutor};
use nvm_in_cache::coordinator::{
    BankScheduler, BatcherConfig, InferenceRequest, Server, ServerConfig,
};
use nvm_in_cache::figures;
use nvm_in_cache::nn::{Dataset, ForwardMode, ResNet};
use nvm_in_cache::perf::MacroModel;
use nvm_in_cache::pim::parallel::Parallelism;
use nvm_in_cache::runtime::{default_runtime, default_runtime_par, ArtifactDir, ModelVariant};
use nvm_in_cache::util::cli::Args;

fn main() {
    let args = Args::from_env();
    let result = match args.subcommand.as_deref() {
        Some("figures") => cmd_figures(&args),
        Some("table1") => cmd_table1(&args),
        Some("table2") => cmd_table2(&args),
        Some("e2e") => cmd_e2e(&args),
        Some("serve") => cmd_serve(&args),
        Some("cache-sim") => cmd_cache_sim(&args),
        Some("fleet-sim") => cmd_fleet_sim(&args),
        Some("serve-sim") => cmd_serve_sim(&args),
        Some("bench") => cmd_bench(&args),
        Some("info") => cmd_info(),
        _ => {
            eprintln!(
                "usage: repro <figures|table1|table2|e2e|serve|cache-sim|fleet-sim|serve-sim|\
                 bench|info> [options]\n\
                 see rust/src/main.rs header for options"
            );
            std::process::exit(2);
        }
    };
    if let Err(e) = result {
        eprintln!("error: {e}");
        std::process::exit(1);
    }
}

fn out_dir(args: &Args) -> PathBuf {
    PathBuf::from(args.get_or("out", "results"))
}

fn artifacts(args: &Args) -> nvm_in_cache::Result<ArtifactDir> {
    ArtifactDir::open(args.get_or("artifacts", "artifacts"))
}

/// Parse `--threads` into a [`Parallelism`]: absent → `default` threads,
/// an explicit `0` → [`Parallelism::auto()`] (sized from
/// `std::thread::available_parallelism()`), anything else taken literally.
fn parallelism_arg(args: &Args, default: usize) -> nvm_in_cache::Result<Parallelism> {
    let t = args.get_usize("threads", default)?;
    Ok(if t == 0 { Parallelism::auto() } else { Parallelism::threads(t) })
}

fn cmd_figures(args: &Args) -> nvm_in_cache::Result<()> {
    let out = out_dir(args);
    std::fs::create_dir_all(&out)?;
    let mc = args.get_usize("mc", 200)?;
    if args.flag("all") || args.get("fig").is_none() {
        figures::generate_all(&out, mc)?;
        return Ok(());
    }
    match args.get("fig").unwrap() {
        "9a" => {
            figures::device_figs::fig9a_rram_iv(&out)?;
        }
        "9b" | "9c" | "9d" | "9bcd" => {
            figures::device_figs::fig9bcd_snm(&out)?;
        }
        "scalars" => figures::device_figs::section_vb_scalars(&out)?,
        "10" => {
            figures::linearity::fig10_weight_voltage(&out)?;
        }
        "11" => figures::linearity::fig11_weight_current(&out)?,
        "12" => figures::linearity::fig12_adc_transfer(&out)?,
        "13" => {
            figures::linearity::fig13_monte_carlo(&out, mc)?;
        }
        "14" => figures::scaling::fig14_scaling(&out)?,
        other => {
            return Err(nvm_in_cache::Error::Config(format!("unknown figure `{other}`")))
        }
    }
    Ok(())
}

fn cmd_table1(args: &Args) -> nvm_in_cache::Result<()> {
    let out = out_dir(args);
    std::fs::create_dir_all(&out)?;
    let acc = artifacts(args)
        .ok()
        .and_then(|d| d.manifest.accuracy("pim_finetuned_noise"));
    figures::tables::table1(&out, acc)?;
    Ok(())
}

fn cmd_table2(args: &Args) -> nvm_in_cache::Result<()> {
    let out = out_dir(args);
    std::fs::create_dir_all(&out)?;
    let dir = artifacts(args)?;
    figures::tables::table2_from_manifest(&out, &dir.manifest)?;
    Ok(())
}

/// Re-measure Table II through the runtime backend (the e2e proof that all
/// layers compose: artifacts → runtime → batched inference → accuracy).
fn cmd_e2e(args: &Args) -> nvm_in_cache::Result<()> {
    let dir = artifacts(args)?;
    let ds = Dataset::load(&dir.path("dataset.bin")?)?;
    let batch = dir.eval_batch();
    let limit = args.get_usize("limit", ds.n)?.min(ds.n);
    let mut rt = default_runtime(batch)?;
    println!("platform: {}", rt.platform());
    let variants: Vec<ModelVariant> = match args.get("variant") {
        Some("baseline") => vec![ModelVariant::Baseline],
        Some("pim") => vec![ModelVariant::Pim],
        Some("pim_noise") => vec![ModelVariant::PimNoise],
        Some("pim_hw") => vec![ModelVariant::PimHw],
        Some("all") => ModelVariant::ALL.to_vec(),
        _ => vec![ModelVariant::Baseline, ModelVariant::Pim, ModelVariant::PimNoise],
    };
    for variant in variants {
        let t0 = std::time::Instant::now();
        rt.load_variant(&dir, variant)?;
        let compile_s = t0.elapsed().as_secs_f64();
        let mut correct = 0usize;
        let mut total = 0usize;
        let mut infer_s = 0.0;
        let mut batch_idx = 0u32;
        while total < limit {
            let start = total;
            let n = batch.min(limit - start).min(ds.n - start);
            if n == 0 {
                break;
            }
            let (x, labels) = ds.batch(start, batch.min(ds.n - start));
            let mut images = x.data.clone();
            images.resize(batch * ds.h * ds.w * ds.c, 0.0);
            batch_idx += 1;
            let key = Some([0xC0FFEE, batch_idx]);
            let t = std::time::Instant::now();
            let preds = rt.classify(variant, &images, (ds.h, ds.w, ds.c), 10, key)?;
            infer_s += t.elapsed().as_secs_f64();
            for (p, l) in preds.iter().zip(labels.iter()).take(n) {
                correct += (p == l) as usize;
                total += 1;
            }
        }
        println!(
            "{variant:?}: accuracy {:.2}% ({correct}/{total}) | compile {compile_s:.1}s, \
             infer {:.3}s ({:.1} img/s)",
            100.0 * correct as f64 / total as f64,
            infer_s,
            total as f64 / infer_s
        );
    }
    Ok(())
}

fn cmd_serve(args: &Args) -> nvm_in_cache::Result<()> {
    let n_requests = args.get_usize("requests", 500)?;
    let par = parallelism_arg(args, 1)?;
    let scheduler = BankScheduler::new(
        BankScheduler::resnet18_layers(16),
        Geometry::default(),
        PimIntegration::Retained,
    )
    .expect("network fits the default slice");
    let dir = artifacts(args)?;
    let ds = Dataset::load(&dir.path("dataset.bin")?)?;
    let dims = (ds.h, ds.w, ds.c);
    let native = args.flag("native");
    let eval_batch = dir.eval_batch();
    let max_batch = args.get_usize("batch", eval_batch)?.min(eval_batch);
    let batch_cfg = if args.flag("continuous") {
        BatcherConfig::continuous(
            max_batch,
            std::time::Duration::from_millis(args.get_u64("max-wait-ms", 5)?),
        )
    } else {
        BatcherConfig::sized(
            max_batch,
            std::time::Duration::from_millis(args.get_u64("max-wait-ms", 5)?),
        )
    };
    let weights = dir.path("weights_ft.bin")?;
    let dir2 = ArtifactDir::open(dir.root.clone())?;
    let factory: nvm_in_cache::coordinator::server::ExecutorFactory = if native {
        Box::new(move || {
            // Compile-once: the weight program is built here, before the
            // serving loop; every batch after this is prepared execution.
            Ok(Box::new(NativeExecutor::new(
                &ResNet::load(&weights)?.with_parallelism(par),
                ForwardMode::Pim,
                dims,
                1,
            )?) as Box<dyn Executor>)
        })
    } else {
        Box::new(move || {
            let mut rt = default_runtime_par(dir2.eval_batch(), par)?;
            rt.load_variant(&dir2, ModelVariant::Pim)?;
            Ok(Box::new(RuntimeExecutor {
                runtime: rt,
                variant: ModelVariant::Pim,
                dims,
                n_classes: 10,
                key_counter: 0,
                parallelism: par,
            }) as Box<dyn Executor>)
        })
    };
    let server = Server::start(factory, Some(scheduler), ServerConfig { batcher: batch_cfg });
    println!("submitting {n_requests} requests…");
    let stride = ds.h * ds.w * ds.c;
    for i in 0..n_requests {
        let idx = i % ds.n;
        let img = ds.images.data[idx * stride..(idx + 1) * stride].to_vec();
        server.submit(InferenceRequest::new(i as u64, img));
    }
    let mut correct = 0usize;
    for _ in 0..n_requests {
        let r = server
            .responses
            .recv_timeout(std::time::Duration::from_secs(600))
            .map_err(|e| nvm_in_cache::Error::Runtime(e.to_string()))?;
        if r.predicted == ds.labels[(r.id as usize) % ds.n] {
            correct += 1;
        }
    }
    let m = server.shutdown();
    println!(
        "accuracy over served requests: {:.2}%",
        100.0 * correct as f64 / n_requests as f64
    );
    println!("{}", m.report());
    Ok(())
}

/// Multi-tenant fleet simulation (EXPERIMENTS.md E12/E16): endurance-aware
/// placement (replica- or shard-parallel per tenant), mixed traffic,
/// mid-run programming campaigns, QoS + wear + shard-chain transfer
/// report. Fully offline and deterministic for a given seed.
fn cmd_fleet_sim(args: &Args) -> nvm_in_cache::Result<()> {
    use nvm_in_cache::fleet::{FleetSim, FleetSimConfig};
    let defaults = FleetSimConfig::default();
    let config = FleetSimConfig {
        n_slices: args.get_usize("slices", defaults.n_slices)?,
        tenants: args.get_usize("tenants", defaults.tenants)?,
        seed: args.get_u64("seed", defaults.seed)?,
        requests_per_tenant: args.get_usize("requests", defaults.requests_per_tenant)?,
        campaign_at_frac: args.get_f64("campaign-at", defaults.campaign_at_frac)?,
        live_serving: args.flag("live"),
        parallelism: parallelism_arg(args, 1)?,
        wide_tenant: !args.flag("no-wide"),
        transformer_tenants: !args.flag("no-tfm"),
    };
    let report = FleetSim::run(&config)?;
    print!("{}", report.render());
    let out = out_dir(args);
    std::fs::create_dir_all(&out)?;
    let path = out.join("fleet_sim.json");
    std::fs::write(&path, report.to_json().to_string())?;
    println!("wrote {}", path.display());
    Ok(())
}

/// Outcome of the merged-wave continuous-batching demo on the real
/// stepped executor ([`nvm_in_cache::pim::program::CompiledNet::step`]).
struct MergedDemo {
    /// Merged stepped logits bit-identical to solo forwards, noiseless
    /// and noisy.
    parity: bool,
    /// `prepare_count()` unchanged across every boundary step — merging
    /// never recompiles weights.
    prepares_flat: bool,
    /// Layer boundaries the two groups shared in flight.
    boundaries_shared: usize,
}

/// Run the merged-wave demo at one thread count: group A (batch 2)
/// enters, computes two layer boundaries, then group B (batch 1) merges
/// mid-flight and both step to completion interleaved. Because
/// `quantize_acts` scales per tensor, each group keeps its own tensor
/// and RNG — so the merged run must be *bit-identical* to two solo
/// `forward_par` calls, in PimHw and noisy PimHwNoise modes alike, at
/// zero weight prepares.
fn merged_wave_demo(threads: usize) -> nvm_in_cache::Result<MergedDemo> {
    use nvm_in_cache::nn::resnet::test_params;
    use nvm_in_cache::nn::Tensor;
    use nvm_in_cache::pim::program::{self, ScratchPool};
    use nvm_in_cache::util::rng::Pcg64;

    let net = ResNet::new(test_params(16, 10, 1));
    let prog = net.compile()?;
    let par = Parallelism::threads(threads);
    let dims = 16 * 16 * 3;
    let mut rng = Pcg64::seeded(31);
    let xa: Vec<f32> = (0..2 * dims).map(|_| rng.f64() as f32).collect();
    let xb: Vec<f32> = (0..dims).map(|_| rng.f64() as f32).collect();
    let ta = Tensor::from_vec(&[2, 16, 16, 3], xa);
    let tb = Tensor::from_vec(&[1, 16, 16, 3], xb);
    let mut parity = true;
    let mut prepares_flat = true;
    let mut boundaries_shared = 0usize;
    for mode in [ForwardMode::PimHw, ForwardMode::PimHwNoise(0.4)] {
        let mut scratch = ScratchPool::new();
        let solo_a = prog.forward_par(&ta, mode, 11, par, &mut scratch);
        let solo_b = prog.forward_par(&tb, mode, 12, par, &mut scratch);
        let before = program::prepare_count();
        let mut run_a = prog.begin(&ta, 11);
        let mut done_a = prog.step(&mut run_a, mode, par, &mut scratch);
        if !done_a {
            done_a = prog.step(&mut run_a, mode, par, &mut scratch);
        }
        // B merges while A is two boundaries deep.
        let mut run_b = prog.begin(&tb, 12);
        let mut done_b = false;
        while !done_a || !done_b {
            if !done_a {
                done_a = prog.step(&mut run_a, mode, par, &mut scratch);
            }
            if !done_b {
                done_b = prog.step(&mut run_b, mode, par, &mut scratch);
                if !done_a {
                    boundaries_shared += 1;
                }
            }
        }
        prepares_flat &= program::prepare_count() == before;
        parity &= run_a.into_logits() == solo_a && run_b.into_logits() == solo_b;
    }
    Ok(MergedDemo { parity, prepares_flat, boundaries_shared })
}

/// Serving front-door simulation: open-loop offered-load sweep over a
/// fixed fleet, both batch disciplines, knee + bottleneck attribution,
/// the M/D/c analytic cross-check, and the merged-wave demo on the real
/// stepped executor. Writes `DIR/serve_sim.json`.
fn cmd_serve_sim(args: &Args) -> nvm_in_cache::Result<()> {
    use nvm_in_cache::coordinator::frontdoor::{self, ArrivalProcess, Discipline, OverloadPolicy};
    use nvm_in_cache::util::json::Json;

    let replicas = args.get_usize("replicas", 4)?.max(1);
    let requests = args.get_usize("requests", 3000)?.max(1);
    let seed = args.get_u64("seed", 42)?;
    let threads = parallelism_arg(args, 4)?.thread_count();
    let queue_cap = args.get_usize("queue-cap", 64)?.max(1);
    let max_batch = args.get_usize("max-batch", 16)?.max(1);
    let arrival = match args.get_or("arrival", "poisson") {
        "poisson" => ArrivalProcess::Poisson { rate_rps: 1.0 },
        "diurnal" => ArrivalProcess::Diurnal { mean_rps: 1.0, swing: 0.6, period_s: 2.0 },
        "burst" => {
            ArrivalProcess::Burst { base_rps: 1.0, burst_mult: 4.0, period_s: 0.5, duty: 0.25 }
        }
        other => {
            return Err(nvm_in_cache::Error::Config(format!("unknown arrival `{other}`")))
        }
    };
    let policy = match args.get_or("policy", "shed") {
        "shed" => OverloadPolicy::Shed,
        "delay" => OverloadPolicy::Delay,
        other => return Err(nvm_in_cache::Error::Config(format!("unknown policy `{other}`"))),
    };

    let make = |discipline: Discipline| {
        let mut door = frontdoor::resnet_front_door(16, replicas);
        door.config.discipline = discipline;
        door.config.policy = policy;
        door.config.seed = seed;
        door.config.requests = requests;
        door.config.queue_cap = queue_cap;
        door.config.max_batch = max_batch;
        door.config.arrival = arrival;
        door
    };
    let fractions = [0.3, 0.6, 0.85, 1.0, 1.15];
    let which = args.get_or("discipline", "both");
    let mut sweeps = Vec::new();
    if which == "both" || which == "drain" {
        sweeps.push(make(Discipline::DrainBatch).sweep(&fractions));
    }
    if which == "both" || which == "continuous" {
        sweeps.push(make(Discipline::Continuous).sweep(&fractions));
    }
    for s in &sweeps {
        print!("{}", s.render());
        println!();
    }

    // Analytic pin: validation-mode simulator vs closed-form M/D/c.
    let service = make(Discipline::DrainBatch).config.service_total_s();
    let cc = frontdoor::queueing_crosscheck(service, replicas, 0.8, 20_000, seed);
    println!(
        "M/D/c cross-check (rho 0.8, c {}): sim p50/p99 {:.3}/{:.3} ms vs analytic \
         {:.3}/{:.3} ms — within 10%: {}",
        replicas,
        cc.sim_p50_s * 1e3,
        cc.sim_p99_s * 1e3,
        cc.analytic_p50_s * 1e3,
        cc.analytic_p99_s * 1e3,
        cc.within(0.10),
    );

    // The live twin: continuous batching on the real stepped executor.
    let demo = merged_wave_demo(threads)?;
    println!(
        "merged-wave demo (t{threads}): {} shared boundaries, bit-identical to solo: {}, \
         zero prepares while merging: {}",
        demo.boundaries_shared, demo.parity, demo.prepares_flat,
    );

    let out = out_dir(args);
    std::fs::create_dir_all(&out)?;
    let path = out.join("serve_sim.json");
    let doc = Json::obj(vec![
        ("replicas", Json::Num(replicas as f64)),
        ("requests", Json::Num(requests as f64)),
        ("seed", Json::Num(seed as f64)),
        ("sweeps", Json::Arr(sweeps.iter().map(|s| s.to_json()).collect())),
        ("crosscheck", cc.to_json(0.10)),
        (
            "merged_demo",
            Json::obj(vec![
                ("threads", Json::Num(threads as f64)),
                ("parity_bit_identical", Json::Bool(demo.parity)),
                ("zero_prepares", Json::Bool(demo.prepares_flat)),
                ("boundaries_shared", Json::Num(demo.boundaries_shared as f64)),
            ]),
        ),
    ]);
    std::fs::write(&path, doc.to_string())?;
    println!("wrote {}", path.display());
    Ok(())
}

/// Hot-path micro-benchmarks — each parallelizable stage serial vs
/// `--threads T` tiled execution — plus the simd_vs_scalar MAC-kernel
/// microbench, the prepare_vs_execute section (compile-once cost vs
/// steady-state prepared execution), the shard section (pipelined
/// shard-executor parity, over-capacity placement, hop-transfer
/// attribution), the hotpath section (persistent-pool dispatch vs
/// spawn-per-call plus the pool/zero-skip/zero-alloc/spawn-once gates),
/// and the fleet-sim summary; `--json` additionally writes the
/// machine-readable perf-trajectory record (BENCH_PR10.json; see
/// PERFORMANCE.md for the format and trajectory).
fn cmd_bench(args: &Args) -> nvm_in_cache::Result<()> {
    use nvm_in_cache::consts::{ARRAY_ROWS, ARRAY_WORDS};
    use nvm_in_cache::fleet::{FleetSim, FleetSimConfig};
    use nvm_in_cache::nn::resnet::test_params;
    use nvm_in_cache::nn::Tensor;
    use nvm_in_cache::pim::parallel;
    use nvm_in_cache::pim::quant::quantize_acts;
    use nvm_in_cache::pim::{program, MacKernel, PimEngine};
    use nvm_in_cache::runtime::{Runtime, StubRuntime};
    use nvm_in_cache::util::bench::Bencher;
    use nvm_in_cache::util::json::Json;
    use nvm_in_cache::util::rng::Pcg64;

    let par = parallelism_arg(args, 4)?;
    let threads = par.thread_count();
    let mut b = if args.flag("quick") { Bencher::quick() } else { Bencher::default() };
    let mut rng = Pcg64::seeded(1);

    // Hot path 1: the PIM engine matmul (the E8 macro-workload shape),
    // serial vs the tiled worker pool, with the bit-exactness of the
    // parallel path asserted on the same inputs.
    let (m, k, n) = (256usize, 256usize, 128usize);
    let a: Vec<f32> = (0..m * k).map(|_| rng.range(0.0, 1.0) as f32).collect();
    let w: Vec<f32> = (0..k * n).map(|_| rng.range(-0.5, 0.5) as f32).collect();
    let eng = PimEngine::tt();
    let parity_engine =
        eng.pim_matmul(&a, m, k, &w, n, None) == eng.par_matmul(&a, m, k, &w, n, None, par);
    // With --threads 1 the _tN names would collide with _t1 (duplicate
    // comparison keys, degenerate speedup), so the threaded twins and the
    // speedups only exist for threads ≥ 2.
    let run_par = threads >= 2;
    let name_eng_t1 = format!("engine_pim_matmul_{m}x{k}x{n}_t1");
    let name_eng_tn = format!("engine_pim_matmul_{m}x{k}x{n}_t{threads}");
    b.bench_with_items(&name_eng_t1, (m * k * n) as f64, || {
        eng.pim_matmul(&a, m, k, &w, n, None)
    });
    if run_par {
        b.bench_with_items(&name_eng_tn, (m * k * n) as f64, || {
            eng.par_matmul(&a, m, k, &w, n, None, par)
        });
    }

    // prepare_vs_execute §1 (engine level): one-time weight-program cost
    // vs steady-state prepared matmul; the one-shot pim_matmul above pays
    // both every call. The parity gate compares against the independent
    // straight-line specification (pim::program::spec_matmul) — not the
    // one-shot wrapper, which shares the prepared core and would make
    // the verdict vacuous.
    let engine_program = eng.prepare(&w, k, n);
    let parity_prepared_engine =
        eng.matmul_prepared(&a, m, &engine_program, None) == program::spec_matmul(&a, m, k, &w, n);
    let name_eng_prepare = format!("engine_prepare_{k}x{n}");
    b.bench_with_items(&name_eng_prepare, (k * n) as f64, || eng.prepare(&w, k, n));
    let name_eng_prepared = format!("engine_matmul_prepared_{m}x{k}x{n}_t1");
    b.bench_with_items(&name_eng_prepared, (m * k * n) as f64, || {
        eng.matmul_prepared(&a, m, &engine_program, None)
    });

    // Hot path 1b: the MAC inner kernel itself — word-wide AND/popcount
    // (MacKernel::BitPlane, the default) vs the historical scalar kernel
    // on one fully-populated sub-array tile (128 rows × 128 word columns,
    // m = 128 output rows), measured through the prepared single-bank
    // path so nothing but the lane fill differs. The parity verdict races
    // the kernels noiseless AND noisy (trailing RNG state included); the
    // exhaustive differential suite is rust/tests/simd_parity.rs. See
    // PERFORMANCE.md §8.
    let (sm, sk, sn) = (ARRAY_ROWS, ARRAY_ROWS, ARRAY_WORDS);
    let tile_a: Vec<f32> = (0..sm * sk).map(|_| rng.range(0.0, 1.0) as f32).collect();
    let tile_w: Vec<f32> = (0..sk * sn).map(|_| rng.range(-0.5, 0.5) as f32).collect();
    let eng_scalar = PimEngine::tt().with_kernel(MacKernel::Scalar);
    let tile_program = eng.prepare(&tile_w, sk, sn);
    let tile_qa = quantize_acts(&tile_a, sm, sk);
    let parity_simd_scalar = {
        let noiseless = eng.bank_mac_prepared(&tile_qa, &tile_program.pos, None)
            == eng_scalar.bank_mac_prepared(&tile_qa, &tile_program.pos, None);
        let ne_simd = PimEngine::tt().with_noise(0.4);
        let ne_scalar = ne_simd.clone().with_kernel(MacKernel::Scalar);
        let (mut r1, mut r2) = (Pcg64::seeded(9), Pcg64::seeded(9));
        let noisy = ne_simd.matmul_prepared(&tile_a, sm, &tile_program, Some(&mut r1))
            == ne_scalar.matmul_prepared(&tile_a, sm, &tile_program, Some(&mut r2))
            && r1.next_u64() == r2.next_u64();
        noiseless && noisy
    };
    let name_mac_simd = format!("mac_kernel_simd_{sm}x{sk}x{sn}");
    let name_mac_scalar = format!("mac_kernel_scalar_{sm}x{sk}x{sn}");
    b.bench_with_items(&name_mac_simd, (sm * sk * sn) as f64, || {
        eng.bank_mac_prepared(&tile_qa, &tile_program.pos, None)
    });
    b.bench_with_items(&name_mac_scalar, (sm * sk * sn) as f64, || {
        eng_scalar.bank_mac_prepared(&tile_qa, &tile_program.pos, None)
    });

    // Hot path 2: cell-accurate sub-array full 4b MAC.
    let mut sa = nvm_in_cache::array::SubArray::new(nvm_in_cache::device::Corner::TT);
    let weights: Vec<u8> =
        (0..ARRAY_ROWS * ARRAY_WORDS).map(|_| rng.below(16) as u8).collect();
    sa.load_weights(&weights);
    let ia: Vec<u8> = (0..ARRAY_ROWS).map(|_| rng.below(16) as u8).collect();
    b.bench_with_items("subarray_pim_mac_4b", (ARRAY_ROWS * ARRAY_WORDS) as f64, || {
        sa.pim_mac_4b(&ia, None)
    });

    // Hot path 3: the scheduler's per-batch cost model.
    let mut sched = BankScheduler::new(
        BankScheduler::resnet18_layers(16),
        Geometry::default(),
        PimIntegration::Retained,
    )
    .expect("network fits the default slice");
    sched.program_network();
    b.bench("scheduler_batch_cost_retained", || sched.batch_cost(8));

    // Hot path 4: end-to-end ResNet-18 inference through the stub runtime
    // (hardware-true PimHw forward, batch 8), serial vs the worker pool —
    // the headline serving-throughput trajectory point.
    let batch = 8usize;
    let dims = (16usize, 16usize, 3usize);
    let images: Vec<f32> = {
        let mut r = Pcg64::seeded(2);
        (0..batch * dims.0 * dims.1 * dims.2).map(|_| r.f64() as f32).collect()
    };
    let mut rt_serial = StubRuntime::new(batch);
    rt_serial.load_variant_params(ModelVariant::PimHw, test_params(16, 10, 1))?;
    let mut rt_par = StubRuntime::new(batch).with_parallelism(par);
    rt_par.load_variant_params(ModelVariant::PimHw, test_params(16, 10, 1))?;
    let parity_resnet = rt_serial
        .forward(ModelVariant::PimHw, &images, dims, None)?
        == rt_par.forward(ModelVariant::PimHw, &images, dims, None)?;
    let name_rn_t1 = format!("resnet18_stub_infer_b{batch}_t1");
    let name_rn_tn = format!("resnet18_stub_infer_b{batch}_t{threads}");
    b.bench_with_items(&name_rn_t1, batch as f64, || {
        rt_serial.classify(ModelVariant::PimHw, &images, dims, 10, None).unwrap()
    });
    if run_par {
        b.bench_with_items(&name_rn_tn, batch as f64, || {
            rt_par.classify(ModelVariant::PimHw, &images, dims, 10, None).unwrap()
        });
    }

    // prepare_vs_execute §2 (network level): whole-ResNet compile cost vs
    // steady-state prepared forward vs the one-shot compile-then-run
    // forward — and the acceptance check that steady-state serving does
    // zero weight quantization/packing after compile.
    let net18 = nvm_in_cache::nn::ResNet::new(test_params(16, 10, 1));
    b.bench("resnet18_compile_w16", || net18.compile().unwrap());
    let xt = Tensor::from_vec(&[batch, dims.0, dims.1, dims.2], images.clone());
    // Steady-state comparand: the same forward on the same tensor, minus
    // only the compile step — NOT the stub classify (whose argmax/padding
    // overhead would bias the saving).
    let rn_program = net18.compile()?;
    let mut rn_scratch = program::ScratchPool::new();
    let name_rn_prepared = format!("resnet18_forward_prepared_b{batch}");
    b.bench_with_items(&name_rn_prepared, batch as f64, || {
        rn_program.forward_par(&xt, ForwardMode::PimHw, 0, Parallelism::serial(), &mut rn_scratch)
    });
    let name_rn_oneshot = format!("resnet18_forward_oneshot_b{batch}");
    b.bench_with_items(&name_rn_oneshot, batch as f64, || {
        net18.forward(&xt, ForwardMode::PimHw, 0).unwrap()
    });
    let prepares_before = program::prepare_count();
    let _ = rt_serial.forward(ModelVariant::PimHw, &images, dims, None)?;
    let steady_state_zero_prepares = program::prepare_count() == prepares_before;

    // Hot path 5: job dispatch through the persistent worker pool vs the
    // historical spawn-per-call path — the fixed cost the pool amortizes
    // away (PERFORMANCE.md §12). The per-unit work is trivially cheap on
    // purpose: this isolates dispatch overhead, not compute.
    b.bench("pool_dispatch_t4_256u", || {
        parallel::run_units(4, 256, |u| (u as u64).wrapping_mul(3))
    });
    b.bench("unpooled_dispatch_t4_256u", || {
        parallel::run_units_unpooled(4, 256, |u| (u as u64).wrapping_mul(3))
    });

    // Hot path 6: the whole fleet simulation (small config, shared with
    // the cargo-bench fleet section). The run is deterministic, so the
    // last bench iteration's report IS the report — no extra run needed.
    let fleet_cfg = FleetSimConfig::bench_quick();
    let mut fleet_report = None;
    b.bench(&fleet_cfg.bench_label(), || {
        fleet_report = Some(FleetSim::run(&fleet_cfg).unwrap());
    });
    b.report();

    let mean = |name: &str| {
        b.results.iter().find(|r| r.name == name).map(|r| r.summary.mean)
    };
    let speedup_engine = if run_par {
        mean(&name_eng_t1).zip(mean(&name_eng_tn)).map(|(s, p)| s / p)
    } else {
        None
    };
    let speedup_resnet = if run_par {
        mean(&name_rn_t1).zip(mean(&name_rn_tn)).map(|(s, p)| s / p)
    } else {
        None
    };
    if let (Some(se), Some(sr)) = (speedup_engine, speedup_resnet) {
        println!(
            "speedup @ {threads} threads: engine matmul {se:.2}x, \
             resnet18 stub inference {sr:.2}x (bit-identical: engine {parity_engine}, \
             resnet {parity_resnet})"
        );
    }
    let speedup_simd = mean(&name_mac_scalar).zip(mean(&name_mac_simd)).map(|(s, p)| s / p);
    if let Some(s) = speedup_simd {
        println!(
            "simd_vs_scalar: word-wide bit-plane kernel {s:.2}x over scalar on the \
             {sm}x{sk}x{sn} tile MAC (bit-identical incl. noise + rng state: \
             {parity_simd_scalar})"
        );
    }

    // prepare_vs_execute summary: how many steady-state calls amortize
    // the one-time compile (compile_cost / per-call saving of prepared vs
    // one-shot execution).
    let amortize = |compile: Option<f64>, oneshot: Option<f64>, prepared: Option<f64>| {
        compile.zip(oneshot.zip(prepared)).and_then(|(c, (o, p))| {
            let saving = o - p;
            (saving > 0.0).then_some(c / saving)
        })
    };
    let engine_prepare_s = mean(&name_eng_prepare);
    let engine_prepared_s = mean(&name_eng_prepared);
    let engine_oneshot_s = mean(&name_eng_t1);
    let amortize_engine = amortize(engine_prepare_s, engine_oneshot_s, engine_prepared_s);
    let resnet_compile_s = mean("resnet18_compile_w16");
    let resnet_prepared_s = mean(&name_rn_prepared);
    let resnet_oneshot_s = mean(&name_rn_oneshot);
    let amortize_resnet = amortize(resnet_compile_s, resnet_oneshot_s, resnet_prepared_s);
    println!(
        "prepare_vs_execute: engine amortizes after {} calls, resnet18 after {} batches \
         (prepared bit-identical: {parity_prepared_engine}; steady-state zero prepares: \
         {steady_state_zero_prepares})",
        amortize_engine.map_or("n/a".into(), |x| format!("{x:.1}")),
        amortize_resnet.map_or("n/a".into(), |x| format!("{x:.1}")),
    );

    let fleet_report = fleet_report.expect("bench ran at least once");
    print!("{}", fleet_report.render());

    // Serve section: the continuous-batching front door on the simulated
    // clock (deterministic — everything here is a comparison gate, not a
    // wall-clock measurement), plus the merged-wave stepped-execution
    // demo across thread counts.
    let serve_json = {
        use nvm_in_cache::coordinator::frontdoor::{self, Discipline};
        let make = |discipline: Discipline| {
            let mut door = frontdoor::resnet_front_door(16, 4);
            door.config.discipline = discipline;
            door.config.requests = 3000;
            door
        };
        let fractions = [0.3, 0.6, 0.85, 1.0, 1.15];
        let drain = make(Discipline::DrainBatch).sweep(&fractions);
        let cont = make(Discipline::Continuous).sweep(&fractions);
        let knee_deterministic = cont.to_json().to_string()
            == make(Discipline::Continuous).sweep(&fractions).to_json().to_string();
        let service = make(Discipline::DrainBatch).config.service_total_s();
        let cc = frontdoor::queueing_crosscheck(service, 4, 0.8, 20_000, 42);
        let mut merged_parity = true;
        let mut merged_zero_prepares = true;
        for t in [1usize, 2, 7] {
            let demo = merged_wave_demo(t)?;
            merged_parity &= demo.parity;
            merged_zero_prepares &= demo.prepares_flat;
        }
        let mean_batch_above_knee =
            cont.points.last().map(|p| p.mean_batch).unwrap_or(0.0);
        println!(
            "serve: drain knee {:.0} rps, continuous knee {:.0} rps (capacity {:.0} vs \
             {:.0}); crosscheck within 10%: {}; merged parity t{{1,2,7}}: {}; zero \
             prepares: {}",
            drain.knee_rps,
            cont.knee_rps,
            drain.capacity_rps,
            cont.capacity_rps,
            cc.within(0.10),
            merged_parity,
            merged_zero_prepares,
        );
        Json::obj(vec![
            ("knee_deterministic", Json::Bool(knee_deterministic)),
            ("queueing_crosscheck_within_tol", Json::Bool(cc.within(0.10))),
            (
                "continuous_knee_at_or_beyond_drain",
                Json::Bool(cont.knee_rps >= drain.knee_rps),
            ),
            ("mean_batch_gt_1_above_knee", Json::Bool(mean_batch_above_knee > 1.0)),
            ("merged_parity_bit_identical", Json::Bool(merged_parity)),
            ("steady_state_zero_prepares_continuous", Json::Bool(merged_zero_prepares)),
            ("crosscheck", cc.to_json(0.10)),
            ("drain", drain.to_json()),
            ("continuous", cont.to_json()),
        ])
    };

    // Shard section: model-parallel pipelined execution across slices
    // (PERFORMANCE.md §10). Three deterministic gates: (1) the pipelined
    // shard executor is bit-identical (logits + trailing RNG state) to
    // the solo forward across shard/thread counts and noise modes;
    // (2) the default fleet places AND serves the over-capacity tenant
    // as a shard chain; (3) the hop-staged front door's per-component
    // attribution — transfer included — reassembles mean latency. Plus
    // the analytic w24 chain numbers (fill latency, cadence, hop share).
    let shard_json = {
        use nvm_in_cache::coordinator::frontdoor::{FrontDoor, FrontDoorConfig};
        use nvm_in_cache::fleet::ShardPlan;
        use nvm_in_cache::pim::ShardedExecutor;

        let net = nvm_in_cache::nn::ResNet::new(test_params(8, 10, 3)).compile()?;
        let mut srng = Pcg64::seeded(88);
        let shard_inputs: Vec<(Tensor, u64)> = (0..3)
            .map(|i| {
                let n = 1 + (i % 2);
                let x: Vec<f32> = (0..n * 16 * 16 * 3).map(|_| srng.f64() as f32).collect();
                (Tensor::from_vec(&[n, 16, 16, 3], x), 700 + i as u64)
            })
            .collect();
        let mut shard_parity = true;
        for shards in [2usize, 3] {
            let ex = ShardedExecutor::balanced(&net, shards)?;
            for t in [1usize, 2] {
                let par_t = Parallelism::threads(t);
                for mode in [ForwardMode::PimHw, ForwardMode::PimHwNoise(0.4)] {
                    let mut scratch = program::ScratchPool::new();
                    let (runs, trace) =
                        ex.forward_pipelined(&shard_inputs, mode, par_t, &mut scratch);
                    shard_parity &= trace.max_concurrent == shards;
                    for ((x, seed), run) in shard_inputs.iter().zip(runs) {
                        let solo = net.forward_run(x, mode, *seed, par_t, &mut scratch);
                        shard_parity &= run.rng_fingerprint() == solo.rng_fingerprint();
                        let (got, want) = (run.into_logits(), solo.into_logits());
                        shard_parity &= got
                            .data
                            .iter()
                            .zip(want.data.iter())
                            .all(|(p, q)| p.to_bits() == q.to_bits());
                    }
                }
            }
        }

        // Gate 2 reads the fleet bench report above (default config, so
        // the wide tenant is present).
        let wide = fleet_report.tenants.iter().find(|t| t.name == "resnet18-w24");
        let overcapacity_placed = wide.is_some_and(|t| t.shards >= 2 && t.served > 0);

        // Gate 3 + chain numbers: the w24 partition's committed stage and
        // hop costs dropped into the hop-staged front door at 70% load.
        let geom = Geometry::default();
        let plan = ShardPlan::partition(&BankScheduler::resnet18_layers(24), &geom, 4)?;
        let cost = plan.pipeline_cost(&geom, PimIntegration::Retained, 1)?;
        let groups: Vec<Vec<f64>> = cost.stages.iter().map(|s| vec![s.latency_s]).collect();
        let hops: Vec<f64> = cost.links.iter().map(|l| l.latency_s).collect();
        let mut door = FrontDoor::new(FrontDoorConfig::for_shard_pipeline(&groups, &hops, 2));
        door.config.requests = 2000;
        let point = door.run_point_at(0.7 * door.capacity_rps());
        let bd = &point.breakdown;
        let components = bd.batcher_s + bd.router_s + bd.adc_s + bd.transfer_s + bd.pipeline_s;
        let attribution_sums = bd.transfer_s > 0.0
            && point.served > 0
            && (components - point.latency.mean).abs() <= 1e-9 * point.latency.mean.max(1e-12);

        println!(
            "shard: pipeline parity s{{2,3}}×t{{1,2}} (noiseless+noisy): {shard_parity}; \
             over-capacity tenant placed+served: {overcapacity_placed}; w24 chain {} shards, \
             fill {:.3} ms, cadence {:.3} ms, hop share {:.2}%; transfer attribution sums: \
             {attribution_sums}",
            plan.shards(),
            cost.latency_s * 1e3,
            cost.cycle_s * 1e3,
            100.0 * cost.transfer_latency_s / cost.latency_s,
        );
        Json::obj(vec![
            ("shard_parity_bit_identical", Json::Bool(shard_parity)),
            ("overcapacity_tenant_placed", Json::Bool(overcapacity_placed)),
            ("pipeline_transfer_attribution_sums", Json::Bool(attribution_sums)),
            ("w24_shards", Json::Num(plan.shards() as f64)),
            ("w24_fill_latency_s", Json::Num(cost.latency_s)),
            ("w24_cycle_s", Json::Num(cost.cycle_s)),
            ("w24_transfer_latency_s", Json::Num(cost.transfer_latency_s)),
            ("w24_transfer_energy_j", Json::Num(cost.transfer_energy_j)),
            ("frontdoor_transfer_s", Json::Num(bd.transfer_s)),
        ])
    };

    // Transformer section: the quantized attention-block workload on
    // prepared banks (PERFORMANCE.md §11, EXPERIMENTS.md E17). Three
    // deterministic gates: (1) the compiled transformer is bit-identical
    // — logits and trailing RNG state — across MAC kernels
    // {BitPlane, Scalar} × threads {1, 2}, noiseless and noisy (every
    // forward here IS the stepped begin/step path), and matches the
    // straight-line `spec_attn` in the noiseless hardware mode;
    // (2) the default fleet report above serves both transformer tenants
    // alongside the CNNs with per-tenant attribution; (3) attention
    // steady state performs zero weight prepares — the dynamic Q·Kᵀ/A·V
    // matmuls are digital and never touch the banks.
    let transformer_json = {
        use nvm_in_cache::nn::transformer::test_tfm_params;
        use nvm_in_cache::nn::{TfmConfig, Transformer};
        use nvm_in_cache::pim::program::ScratchPool;
        use nvm_in_cache::pim::spec_attn;

        let cfg = TfmConfig::tiny();
        let tfm = Transformer::new(test_tfm_params(cfg, 5), cfg);
        let prog = tfm.compile()?;
        let mut trng = Pcg64::seeded(21);
        let x: Vec<f32> = (0..2 * cfg.input_elems()).map(|_| trng.f64() as f32).collect();
        let xt = Tensor::from_vec(&[2, cfg.seq_len, cfg.d_model], x);
        let spec = spec_attn(&tfm, &xt)?;
        let bits = |t: &Tensor, u: &Tensor| {
            t.data.len() == u.data.len()
                && t.data.iter().zip(u.data.iter()).all(|(p, q)| p.to_bits() == q.to_bits())
        };

        let mut attn_parity = true;
        let mut attn_zero_prepares = true;
        for mode in [ForwardMode::PimHw, ForwardMode::PimHwNoise(0.4)] {
            let mut reference: Option<(Tensor, u64)> = None;
            for kernel in [MacKernel::BitPlane, MacKernel::Scalar] {
                MacKernel::set_thread_default(kernel);
                for t in [1usize, 2] {
                    let par_t = Parallelism::threads(t);
                    let mut scratch = ScratchPool::new();
                    let before = program::prepare_count();
                    let run = prog.forward_run(&xt, mode, 33, par_t, &mut scratch);
                    attn_zero_prepares &= program::prepare_count() == before;
                    let fp = run.rng_fingerprint();
                    let logits = run.into_logits();
                    match &reference {
                        None => reference = Some((logits, fp)),
                        Some((want, want_fp)) => {
                            attn_parity &= bits(&logits, want) && fp == *want_fp;
                        }
                    }
                }
            }
            if mode == ForwardMode::PimHw {
                if let Some((want, _)) = &reference {
                    attn_parity &= bits(want, &spec);
                }
            }
        }
        MacKernel::set_thread_default(MacKernel::BitPlane);

        let tfm_tenants: Vec<_> =
            fleet_report.tenants.iter().filter(|t| t.name.starts_with("tfm-")).collect();
        let mixed_fleet_served = tfm_tenants.len() == 2
            && tfm_tenants.iter().all(|t| t.served > 0)
            && fleet_report
                .tenants
                .iter()
                .any(|t| !t.name.starts_with("tfm-") && t.served > 0);

        println!(
            "transformer: attn parity k{{bitplane,scalar}}×t{{1,2}} (noiseless+noisy, \
             stepped, vs spec): {attn_parity}; mixed CNN+transformer fleet served \
             ({} tfm tenants): {mixed_fleet_served}; attention steady-state zero \
             prepares: {attn_zero_prepares}",
            tfm_tenants.len(),
        );
        Json::obj(vec![
            ("attn_parity_bit_identical", Json::Bool(attn_parity)),
            ("mixed_fleet_served", Json::Bool(mixed_fleet_served)),
            ("steady_state_zero_prepares_attn", Json::Bool(attn_zero_prepares)),
            ("d_model", Json::Num(cfg.d_model as f64)),
            ("n_heads", Json::Num(cfg.n_heads as f64)),
            ("boundaries", Json::Num(prog.boundaries() as f64)),
        ])
    };

    // Hotpath section (PERFORMANCE.md §12, EXPERIMENTS.md E18): the
    // persistent worker pool, zero-word skipping, and allocation-free
    // steady state, each pinned by a deterministic gate. The exhaustive
    // differential suite is rust/tests/hotpath_parity.rs; these are the
    // trajectory-record versions.
    let (hotpath_json, hotpath_skip_fraction) = {
        let (hm, hk, hn) = (5usize, 200usize, 133usize);
        let mut hrng = Pcg64::seeded(14);
        let ha: Vec<f32> = (0..hm * hk).map(|_| hrng.range(0.0, 1.0) as f32).collect();
        let hw: Vec<f32> = (0..hk * hn).map(|_| hrng.range(-0.5, 0.5) as f32).collect();
        let heng = PimEngine::tt();
        let hprog = heng.prepare(&hw, hk, hn);
        let bits_eq = |x: &[f32], y: &[f32]| {
            x.len() == y.len()
                && x.iter().zip(y.iter()).all(|(p, q)| p.to_bits() == q.to_bits())
        };

        // Gate 1: pooled execution is bit-identical (values + trailing
        // RNG state) to the serial path across widths {1,2,7} with the
        // same pools reused call after call, and `run_units` matches the
        // historical spawn-per-call `run_units_unpooled`.
        let noisy_eng = PimEngine::tt().with_noise(0.4);
        let want = heng.matmul_prepared(&ha, hm, &hprog, None);
        let mut wrng = Pcg64::seeded(5);
        let want_noisy = noisy_eng.matmul_prepared(&ha, hm, &hprog, Some(&mut wrng));
        let want_tail = wrng.next_u64();
        let mut pool_parity = true;
        for t in [1usize, 2, 7] {
            let par_t = Parallelism::threads(t);
            for _ in 0..3 {
                pool_parity &=
                    bits_eq(&heng.par_matmul_prepared(&ha, hm, &hprog, None, par_t), &want);
                let mut r = Pcg64::seeded(5);
                pool_parity &= bits_eq(
                    &noisy_eng.par_matmul_prepared(&ha, hm, &hprog, Some(&mut r), par_t),
                    &want_noisy,
                ) && r.next_u64() == want_tail;
            }
        }
        let mix = |u: usize| (u as u64).wrapping_mul(0x9E37_79B9);
        pool_parity &= parallel::run_units(4, 37, mix) == parallel::run_units_unpooled(4, 37, mix);

        // Gate 2: zero-word skipping is output-neutral. Alternate
        // activation rows are entirely zero (ReLU-like), so whole k-word
        // groups vanish; the bit-plane kernel must still match the scalar
        // kernel and the straight-line spec bit-for-bit while SkipStats
        // reports real skips.
        let sparse_a: Vec<f32> = (0..hm * hk)
            .map(|i| if (i / hk) % 2 == 0 { 0.0 } else { hrng.range(0.05, 1.0) as f32 })
            .collect();
        heng.skip_stats().reset();
        let skip_out = heng.matmul_prepared(&sparse_a, hm, &hprog, None);
        let hp_visited = heng.skip_stats().words_visited();
        let hp_skipped = heng.skip_stats().act_words_skipped();
        let skip_fraction = heng.skip_stats().act_skip_fraction();
        let scalar_eng = PimEngine::tt().with_kernel(MacKernel::Scalar);
        let zero_skip_parity = hp_skipped > 0
            && hp_visited > hp_skipped
            && bits_eq(&skip_out, &scalar_eng.matmul_prepared(&sparse_a, hm, &hprog, None))
            && bits_eq(&skip_out, &program::spec_matmul(&sparse_a, hm, hk, &hw, hn));

        // Gate 3: after one warm-up forward, steady-state CompiledNet
        // execution performs zero MAC-path heap allocations (counter —
        // same pattern as the prepare_count gate above).
        let hnet = ResNet::new(test_params(8, 10, 1));
        let hprogram = hnet.compile()?;
        let hx = Tensor::from_vec(
            &[1, 16, 16, 3],
            (0..16 * 16 * 3).map(|_| hrng.f64() as f32).collect(),
        );
        let mut hscratch = program::ScratchPool::new();
        let _ = hprogram.forward_par(
            &hx,
            ForwardMode::PimHw,
            0,
            Parallelism::serial(),
            &mut hscratch,
        );
        let allocs_before = program::mac_alloc_count();
        for seed in 1..3u64 {
            let _ = hprogram.forward_par(
                &hx,
                ForwardMode::PimHw,
                seed,
                Parallelism::serial(),
                &mut hscratch,
            );
        }
        let steady_state_zero_allocs = program::mac_alloc_count() == allocs_before;

        // Gate 4: each pool width spawns its workers exactly once per
        // process — gate 1 already drove the width-7 pool nine times, so
        // after five more dispatches the spawn counter must still be 7.
        for _ in 0..5 {
            let _ = parallel::run_units(7, 16, |u| u as u64);
        }
        let pool_spawns_once = parallel::pool_spawned_for(7) == 7;

        println!(
            "hotpath: pool parity t{{1,2,7}}×3 reuses: {pool_parity}; zero-skip parity \
             ({hp_skipped}/{hp_visited} act words skipped): {zero_skip_parity}; \
             steady-state zero MAC allocs: {steady_state_zero_allocs}; width-7 pool \
             spawned exactly once: {pool_spawns_once}"
        );
        (
            Json::obj(vec![
                ("pool_parity_bit_identical", Json::Bool(pool_parity)),
                ("zero_skip_parity_bit_identical", Json::Bool(zero_skip_parity)),
                ("steady_state_zero_allocs", Json::Bool(steady_state_zero_allocs)),
                ("pool_spawns_once", Json::Bool(pool_spawns_once)),
            ]),
            skip_fraction,
        )
    };
    let pool_dispatch_s = mean("pool_dispatch_t4_256u");
    let unpooled_dispatch_s = mean("unpooled_dispatch_t4_256u");
    let spawn_amortization = pool_dispatch_s
        .zip(unpooled_dispatch_s)
        .and_then(|(p, u)| (p > 0.0).then_some(u / p));
    if let Some(x) = spawn_amortization {
        println!(
            "hotpath dispatch: persistent pool {x:.1}x lower per-call overhead than \
             spawn-per-call (t4, 256 trivial units)"
        );
    }

    if args.flag("json") {
        let path = std::path::PathBuf::from(args.get_or("json", "BENCH_PR10.json"));
        // Two sections (PERFORMANCE.md): `comparison` holds only
        // deterministic fields (workload descriptors, parity verdicts, the
        // simulated-clock fleet report) so trajectory files diff cleanly
        // across PRs; `measured` holds the wall-clock numbers.
        let comparison = Json::obj(vec![
            ("threads", Json::Num(threads as f64)),
            ("workloads", b.comparison_json()),
            ("parity_engine_bit_identical", Json::Bool(parity_engine)),
            ("parity_resnet_bit_identical", Json::Bool(parity_resnet)),
            ("parity_prepared_engine_bit_identical", Json::Bool(parity_prepared_engine)),
            ("steady_state_zero_prepares", Json::Bool(steady_state_zero_prepares)),
            (
                "simd_vs_scalar",
                Json::obj(vec![
                    ("parity_simd_scalar_bit_identical", Json::Bool(parity_simd_scalar)),
                    (
                        "kernel_default_is_bit_plane",
                        Json::Bool(MacKernel::thread_default() == MacKernel::BitPlane),
                    ),
                ]),
            ),
            ("fleet_sim", fleet_report.to_json()),
            ("serve", serve_json),
            ("shard", shard_json),
            ("transformer", transformer_json),
            ("hotpath", hotpath_json),
        ]);
        let mut measured = vec![("benches", b.to_json())];
        if let Some(s) = speedup_engine {
            measured.push(("speedup_engine_par_matmul", Json::Num(s)));
        }
        if let Some(s) = speedup_resnet {
            measured.push(("speedup_resnet18_stub_infer", Json::Num(s)));
        }
        let mut pve: Vec<(&str, Json)> = Vec::new();
        for (key, v) in [
            ("engine_prepare_s", engine_prepare_s),
            ("engine_matmul_prepared_s", engine_prepared_s),
            ("engine_matmul_oneshot_s", engine_oneshot_s),
            ("engine_amortize_calls", amortize_engine),
            ("resnet_compile_s", resnet_compile_s),
            ("resnet_forward_prepared_s", resnet_prepared_s),
            ("resnet_forward_oneshot_s", resnet_oneshot_s),
            ("resnet_amortize_batches", amortize_resnet),
        ] {
            if let Some(v) = v {
                pve.push((key, Json::Num(v)));
            }
        }
        measured.push(("prepare_vs_execute", Json::obj(pve)));
        let mut svs: Vec<(&str, Json)> = Vec::new();
        for (key, v) in [
            ("mac_kernel_scalar_s", mean(&name_mac_scalar)),
            ("mac_kernel_simd_s", mean(&name_mac_simd)),
            ("speedup_simd_vs_scalar", speedup_simd),
        ] {
            if let Some(v) = v {
                svs.push((key, Json::Num(v)));
            }
        }
        measured.push(("simd_vs_scalar", Json::obj(svs)));
        let mut hp: Vec<(&str, Json)> = Vec::new();
        for (key, v) in [
            ("pool_dispatch_s", pool_dispatch_s),
            ("unpooled_dispatch_s", unpooled_dispatch_s),
            ("spawn_amortization_x", spawn_amortization),
        ] {
            if let Some(v) = v {
                hp.push((key, Json::Num(v)));
            }
        }
        hp.push(("act_skip_fraction_sparse", Json::Num(hotpath_skip_fraction)));
        measured.push(("hotpath", Json::obj(hp)));
        let doc = Json::obj(vec![
            ("pr", Json::Num(10.0)),
            ("comparison", comparison),
            ("measured", Json::obj(measured)),
        ]);
        std::fs::write(&path, doc.to_string())?;
        println!("wrote {}", path.display());
    }
    Ok(())
}

fn cmd_info() -> nvm_in_cache::Result<()> {
    let h = MacroModel::default().headline();
    println!("NVM-in-Cache macro model (paper §V-D anchors):");
    println!("  raw throughput      : {:.2} GOPS (paper: 25.6)", h.ops_per_s / 1e9);
    println!("  raw efficiency      : {:.2} TOPS/W (paper: 30.73)", h.ops_per_w / 1e12);
    println!("  norm throughput     : {:.4} TOPS (paper: 0.4)", h.norm_ops_per_s / 1e12);
    println!("  norm efficiency     : {:.1} TOPS/W (paper: 491.78)", h.norm_ops_per_w / 1e12);
    println!("  norm compute density: {:.2} TOPS/mm² (paper: 4.37)", h.norm_tops_per_mm2);
    let (array, adc, wcc, dig) = MacroModel::default().energy_breakdown();
    println!(
        "  energy breakdown    : array {:.0}%, ADC {:.0}%, WCC {:.0}%, digital {:.0}%",
        array * 100.0,
        adc * 100.0,
        wcc * 100.0,
        dig * 100.0
    );
    Ok(())
}

/// PIM-interference study: hit-rate/AMAT vs PIM intensity per trace kind,
/// retained vs flush/reload (the quantified §I motivation).
fn cmd_cache_sim(args: &Args) -> nvm_in_cache::Result<()> {
    use nvm_in_cache::cache::workload;
    let out = out_dir(args);
    std::fs::create_dir_all(&out)?;
    let sweep = workload::interference_sweep(args.get_u64("seed", 42)?);
    let mut csv = nvm_in_cache::util::csv::CsvWriter::new(vec![
        "trace", "mode", "pim_per_1k", "hit_rate", "amat_ns", "lines_moved",
    ]);
    println!(
        "{:<12} {:<13} {:>9} {:>9} {:>9} {:>12}",
        "trace", "mode", "pim/1k", "hit%", "AMAT ns", "lines moved"
    );
    for r in &sweep {
        let mode = match r.mode {
            nvm_in_cache::cache::PimIntegration::Retained => "retained",
            nvm_in_cache::cache::PimIntegration::FlushReload => "flush_reload",
        };
        println!(
            "{:<12} {:<13} {:>9} {:>8.1}% {:>9.3} {:>12}",
            r.trace.name(),
            mode,
            r.pim_intensity,
            r.hit_rate * 100.0,
            r.amat * 1e9,
            r.lines_moved
        );
        csv.row(vec![
            r.trace.name().to_string(),
            mode.to_string(),
            r.pim_intensity.to_string(),
            format!("{:.4}", r.hit_rate),
            format!("{:.4}", r.amat * 1e9),
            r.lines_moved.to_string(),
        ]);
    }
    csv.write(&out.join("cache_interference.csv"))?;
    println!("wrote {}", out.join("cache_interference.csv").display());
    Ok(())
}
