//! Cache controller: arbitrates SRAM-mode traffic against PIM campaigns.
//!
//! Implements the paper's headline architectural property — PIM with
//! **cache-data retention** — and the flush/reload baseline of prior
//! 6T-SRAM PIM ([22], [23]) as an ablation mode. See the
//! `bench_retention_ablation` bench and the `cache_retention` example.

use crate::cell::timing::{EnergyLedger, OpKind};
use crate::consts::ARRAY_ROWS;

use super::addr::{Address, Geometry};
use super::slice::{AccessResult, LlcSlice};

/// How PIM coexists with cached data.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PimIntegration {
    /// This paper: 6T-2R computes on the RRAM layer; SRAM data stays put.
    Retained,
    /// Prior 6T PIM: weights must occupy the SRAM cells, so resident lines
    /// are flushed before and reloaded after every PIM campaign.
    FlushReload,
}

/// Result of one PIM campaign execution.
#[derive(Clone, Debug)]
pub struct CampaignStats {
    /// Number of MAC invocations executed.
    pub mac_ops: u64,
    /// Cache lines moved (flush + reload) to make the campaign possible.
    pub lines_moved: u64,
    /// Wall-clock latency including data movement (s).
    pub latency: f64,
    /// Energy including data movement (J).
    pub energy: f64,
}

/// The controller for one slice.
pub struct CacheController {
    /// The slice being arbitrated.
    pub slice: LlcSlice,
    /// PIM integration mode.
    pub mode: PimIntegration,
    /// Simulated wall-clock (s).
    pub now: f64,
}

impl CacheController {
    /// Controller over a fresh slice.
    pub fn new(geom: Geometry, mode: PimIntegration) -> CacheController {
        CacheController { slice: LlcSlice::new(geom), mode, now: 0.0 }
    }

    /// Serve a read; misses are filled from "memory" with a fixed pattern
    /// (the workload generator owns real contents).
    pub fn read(&mut self, addr: Address) -> [u8; 64] {
        match self.slice.read(addr) {
            (AccessResult::Hit, Some(d)) => d,
            _ => {
                let data = Self::memory_pattern(addr);
                self.slice.fill(addr, data);
                data
            }
        }
    }

    /// Serve a write (write-allocate).
    pub fn write(&mut self, addr: Address, data: [u8; 64]) {
        self.slice.write(addr, data);
    }

    fn memory_pattern(addr: Address) -> [u8; 64] {
        let mut d = [0u8; 64];
        for (i, b) in d.iter_mut().enumerate() {
            *b = (addr.raw as u8).wrapping_mul(31).wrapping_add(i as u8);
        }
        d
    }

    /// Program a weight matrix into one sub-array, honoring the mode's data
    /// discipline (dirty lines are written back first in both modes —
    /// programming is destructive, §III-A).
    pub fn program_campaign(&mut self, bank: usize, sa: usize, weights: Vec<u8>) -> CampaignStats {
        let mut ledger = EnergyLedger::new();
        let resident = self.slice.banks[bank].subarrays[sa].resident_lines() as u64;
        // Writeback anything resident (conservative: assume dirty).
        ledger.record_n(OpKind::CacheLineMove, resident);
        self.slice.banks[bank].program_weights(sa, weights, &mut ledger);
        // Wall-clock: line moves serial, then programming pulses applied
        // row-parallel (not the ledger's serial per-cell sum).
        let latency = resident as f64 * OpKind::CacheLineMove.cost().0
            + 3.0 * crate::consts::T_PROGRAM * (ARRAY_ROWS * 128) as f64 / 128.0;
        // Energy is the full ledger: writebacks + every programming pulse
        // and verify read.
        let energy = ledger.total_energy();
        self.slice.ledger.merge(&ledger);
        CampaignStats { mac_ops: 0, lines_moved: resident, latency, energy }
    }

    /// Execute `n_macs` full-array 4-bit MAC operations on (bank, sa).
    ///
    /// Retained: the array computes in place; resident lines stay valid.
    /// FlushReload: every campaign flushes resident lines, "borrows" the
    /// SRAM cells for weights, computes, then reloads — the prior-work
    /// cost structure this paper eliminates.
    pub fn pim_campaign(&mut self, bank: usize, sa: usize, n_macs: u64) -> CampaignStats {
        let mut ledger = EnergyLedger::new();
        let mut lines_moved = 0u64;
        if self.mode == PimIntegration::FlushReload {
            // Actually evict: the SRAM cells are about to hold weights, so
            // every resident line in this array is flushed (tags
            // invalidated — subsequent accesses miss and refill).
            let flushed = self.slice.invalidate_subarray(bank, sa) as u64;
            // Flush out + weight-load writes + (eventual) reload back.
            lines_moved = 2 * flushed + ARRAY_ROWS as u64;
            ledger.record_n(OpKind::CacheLineMove, 2 * flushed);
            ledger.record_n(OpKind::SramWrite, ARRAY_ROWS as u64);
        }
        // The MAC pipeline costs (per full 4b MAC: 8 array cycles, 8×128
        // conversions — see cell::timing).
        ledger.record_n(OpKind::PimArrayCycle, 8 * n_macs);
        ledger.record_n(OpKind::WccSample, 8 * 128 * n_macs);
        ledger.record_n(OpKind::AdcConversion, 8 * 128 * n_macs);
        ledger.record_n(OpKind::DigitalPostOp, 4 * 128 * n_macs);
        // Wall-clock: data movement serial + ADC-pipelined MACs.
        let move_time = lines_moved as f64 * OpKind::CacheLineMove.cost().0;
        let mac_time = n_macs as f64 * 8.0 * crate::consts::T_ADC_CONVERSION;
        let latency = move_time + mac_time;
        self.slice.banks[bank].reserve(sa, self.now, latency);
        self.now += latency;
        let energy = ledger.total_energy();
        self.slice.ledger.merge(&ledger);
        CampaignStats { mac_ops: n_macs, lines_moved, latency, energy }
    }

    /// Snapshot the resident lines of one sub-array: (row, data) pairs —
    /// the set a destructive programming campaign must reload afterwards.
    pub fn resident_snapshot(&self, bank: usize, sa: usize) -> Vec<(usize, [u8; 64])> {
        self.slice.banks[bank].subarrays[sa]
            .lines
            .iter()
            .enumerate()
            .filter_map(|(row, l)| l.map(|d| (row, d)))
            .collect()
    }

    /// Rewarm a sub-array after a destructive programming campaign:
    /// reload the snapshot taken beforehand (metered as line moves +
    /// writes — the drain→program→rewarm cost a fleet campaign pays
    /// before a replica returns to service). Residency is restored, so a
    /// later campaign on the same array displaces these lines again.
    pub fn rewarm_campaign(
        &mut self,
        bank: usize,
        sa: usize,
        saved: &[(usize, [u8; 64])],
    ) -> CampaignStats {
        let mut ledger = EnergyLedger::new();
        ledger.record_n(OpKind::CacheLineMove, saved.len() as u64);
        let rows = self.slice.geom.rows_per_subarray;
        for &(row, data) in saved {
            self.slice.banks[bank].write_line(sa * rows + row, data, &mut ledger);
        }
        let latency = ledger.total_time();
        let energy = ledger.total_energy();
        self.slice.ledger.merge(&ledger);
        CampaignStats { mac_ops: 0, lines_moved: saved.len() as u64, latency, energy }
    }

    /// Verify that all resident lines in a sub-array still hold their data
    /// (the retention property test hook).
    pub fn verify_retention(&mut self, bank: usize, sa: usize, expected: &[(usize, [u8; 64])]) -> bool {
        expected.iter().all(|(row, data)| {
            self.slice.banks[bank].subarrays[sa].lines[*row] == Some(*data)
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ctl(mode: PimIntegration) -> CacheController {
        CacheController::new(Geometry::tiny(), mode)
    }

    fn warm_lines(c: &mut CacheController, bank: usize, sa: usize, n: usize) -> Vec<(usize, [u8; 64])> {
        // Write lines directly into the target sub-array for the retention
        // experiments (bypassing address mapping for determinism).
        let mut out = Vec::new();
        for row in 0..n {
            let mut d = [0u8; 64];
            d[0] = row as u8;
            d[63] = 0xA5;
            let li = sa * c.slice.geom.rows_per_subarray + row;
            let mut led = EnergyLedger::new();
            c.slice.banks[bank].write_line(li, d, &mut led);
            out.push((row, d));
        }
        out
    }

    #[test]
    fn retained_mode_keeps_data_and_moves_nothing() {
        let mut c = ctl(PimIntegration::Retained);
        let expected = warm_lines(&mut c, 0, 0, 50);
        c.program_campaign(0, 1, vec![0u8; 128 * 128]); // weights in sa 1
        let stats = c.pim_campaign(0, 1, 100);
        assert_eq!(stats.lines_moved, 0);
        assert!(c.verify_retention(0, 0, &expected));
    }

    #[test]
    fn flush_reload_moves_lines_every_campaign() {
        let mut c = ctl(PimIntegration::FlushReload);
        // Addressed traffic into bank 0 (tiny geometry: sets ≡ 0 mod 4 are
        // bank 0, and their first ways land in sub-array 0).
        let g = c.slice.geom;
        let n = 40;
        let mut addrs = Vec::new();
        for i in 0..n as u64 {
            let set = (i as usize * g.banks_per_slice) % g.sets_per_slice;
            let tag_part = i as usize / (g.sets_per_slice / g.banks_per_slice);
            let a = Address::new(
                (tag_part * g.sets_per_slice * g.line_bytes + set * g.line_bytes) as u64,
            );
            assert_eq!(a.bank_index(&g), 0);
            c.read(a);
            addrs.push(a);
        }
        let resident_in_target: usize = (0..g.sets_per_slice)
            .filter(|s| s % g.banks_per_slice == 0)
            .map(|_| 0) // placeholder; we use the invalidation count below
            .sum();
        let _ = resident_in_target;
        let s1 = c.pim_campaign(0, 0, 10);
        // Everything we touched sat in (bank 0, sa 0): flushed 2× + reload.
        assert!(s1.lines_moved as usize >= ARRAY_ROWS, "{}", s1.lines_moved);
        assert!(s1.lines_moved as usize > ARRAY_ROWS, "some lines must flush");
        assert!(s1.latency > 0.0 && s1.energy > 0.0);
        // Post-campaign: previously-hitting addresses now miss.
        let hits_before = c.slice.hits;
        let misses_before = c.slice.misses;
        c.read(addrs[0]);
        assert_eq!(c.slice.hits, hits_before);
        assert_eq!(c.slice.misses, misses_before + 1);
    }

    #[test]
    fn retained_beats_flush_reload_on_cost() {
        let macs = 4;
        let mut a = ctl(PimIntegration::Retained);
        let mut b = ctl(PimIntegration::FlushReload);
        warm_lines(&mut a, 0, 0, 100);
        warm_lines(&mut b, 0, 0, 100);
        let sa = a.pim_campaign(0, 0, macs);
        let sb = b.pim_campaign(0, 0, macs);
        assert!(sb.latency > sa.latency, "{} !> {}", sb.latency, sa.latency);
        assert!(sb.energy > sa.energy);
    }

    #[test]
    fn programming_is_destructive_but_metered() {
        let mut c = ctl(PimIntegration::Retained);
        let expected = warm_lines(&mut c, 0, 0, 30);
        let stats = c.program_campaign(0, 0, vec![7u8; 128 * 128]);
        assert_eq!(stats.lines_moved, 30, "resident lines written back");
        assert!(!c.verify_retention(0, 0, &expected), "programming clobbers latches");
        // Energy covers the programming pulses themselves (65,536 cells ×
        // ~0.46 pJ ≈ 30 nJ), not just the 30-line writeback (~0.6 nJ).
        let writeback = 30.0 * OpKind::CacheLineMove.cost().1;
        assert!(stats.energy > 10.0 * writeback, "programming energy metered: {}", stats.energy);
        // Latency stays row-parallel: microseconds, not the ~260 µs a
        // serial per-cell pulse sum would give.
        assert!(stats.latency < 1e-5, "row-parallel programming: {}", stats.latency);
    }

    #[test]
    fn rewarm_restores_displaced_residency() {
        let mut c = ctl(PimIntegration::Retained);
        let warmed = warm_lines(&mut c, 0, 0, 20);
        let saved = c.resident_snapshot(0, 0);
        assert_eq!(saved.len(), 20);
        let prog = c.program_campaign(0, 0, vec![1u8; 128 * 128]);
        assert_eq!(prog.lines_moved, 20);
        assert_eq!(c.slice.banks[0].subarrays[0].resident_lines(), 0);
        let rewarm = c.rewarm_campaign(0, 0, &saved);
        assert_eq!(rewarm.lines_moved, 20);
        let (t, e) = OpKind::CacheLineMove.cost();
        assert!(rewarm.latency >= 20.0 * t);
        assert!(rewarm.energy >= 20.0 * e);
        // Residency and contents are actually restored, so a later
        // campaign on this array displaces these lines again.
        assert_eq!(c.slice.banks[0].subarrays[0].resident_lines(), 20);
        assert!(c.verify_retention(0, 0, &warmed));
        let prog2 = c.program_campaign(0, 0, vec![2u8; 128 * 128]);
        assert_eq!(prog2.lines_moved, 20, "second campaign displaces the reloaded lines");
    }

    #[test]
    fn read_miss_fill_hit_path() {
        let mut c = ctl(PimIntegration::Retained);
        let a = Address::new(0x7700);
        let d1 = c.read(a);
        let d2 = c.read(a);
        assert_eq!(d1, d2);
        assert_eq!(c.slice.misses, 1);
        assert_eq!(c.slice.hits, 1);
    }

    #[test]
    fn busy_tracking_reserves_array() {
        let mut c = ctl(PimIntegration::Retained);
        let t0 = c.now;
        c.pim_campaign(0, 0, 10);
        assert!(c.slice.banks[0].is_busy(0, t0));
        assert!(!c.slice.banks[0].is_busy(0, c.now + 1.0));
    }
}
