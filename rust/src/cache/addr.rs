//! Physical address decomposition for the modeled LLC.
//!
//! Geometry defaults follow the paper's reference organization (§II-B):
//! 2.5 MB slice, 20 ways, 64 B lines, banks of 32 KB built from 8 KB
//! (128×512-bit) sub-arrays — i.e. each sub-array row holds one 64 B line.

/// LLC geometry parameters.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Geometry {
    /// Cache-line size (bytes).
    pub line_bytes: usize,
    /// Set associativity.
    pub ways: usize,
    /// Sets per slice.
    pub sets_per_slice: usize,
    /// Banks per slice.
    pub banks_per_slice: usize,
    /// 8 KB sub-arrays per bank.
    pub subarrays_per_bank: usize,
    /// Rows (= cache lines) per sub-array.
    pub rows_per_subarray: usize,
}

impl Default for Geometry {
    fn default() -> Self {
        // 2.5 MB / 64 B / 20 ways = 2048 sets; 80 banks × 32 KB;
        // 4 × 8 KB sub-arrays per bank; 128 rows (lines) per sub-array.
        Geometry {
            line_bytes: 64,
            ways: 20,
            sets_per_slice: 2048,
            banks_per_slice: 80,
            subarrays_per_bank: 4,
            rows_per_subarray: 128,
        }
    }
}

impl Geometry {
    /// A small geometry for fast tests.
    pub fn tiny() -> Geometry {
        Geometry {
            line_bytes: 64,
            ways: 4,
            sets_per_slice: 64,
            banks_per_slice: 4,
            subarrays_per_bank: 2,
            rows_per_subarray: 128,
        }
    }

    /// Total slice capacity (bytes).
    pub fn slice_bytes(&self) -> usize {
        self.sets_per_slice * self.ways * self.line_bytes
    }

    /// Lines that one bank can hold.
    pub fn lines_per_bank(&self) -> usize {
        self.subarrays_per_bank * self.rows_per_subarray
    }
}

/// Decomposed physical address.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct Address {
    /// The raw physical address.
    pub raw: u64,
}

impl Address {
    /// Wrap a raw physical address.
    pub fn new(raw: u64) -> Address {
        Address { raw }
    }

    /// Byte offset within the cache line.
    pub fn line_offset(&self, g: &Geometry) -> usize {
        (self.raw as usize) & (g.line_bytes - 1)
    }

    /// Set index within the slice.
    pub fn set_index(&self, g: &Geometry) -> usize {
        ((self.raw as usize) / g.line_bytes) % g.sets_per_slice
    }

    /// Tag bits above the set index.
    pub fn tag(&self, g: &Geometry) -> u64 {
        self.raw / (g.line_bytes * g.sets_per_slice) as u64
    }

    /// Bank selection: sets interleave across banks.
    pub fn bank_index(&self, g: &Geometry) -> usize {
        self.set_index(g) % g.banks_per_slice
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_geometry_is_2_5_mb() {
        let g = Geometry::default();
        assert_eq!(g.slice_bytes(), 2_621_440); // 2.5 MB
        assert_eq!(g.lines_per_bank() * g.line_bytes, 32_768); // 32 KB banks
    }

    #[test]
    fn decomposition_roundtrips() {
        let g = Geometry::default();
        let a = Address::new(0xDEAD_BEEF_40);
        let reconstructed = a.tag(&g) * (g.line_bytes * g.sets_per_slice) as u64
            + (a.set_index(&g) * g.line_bytes) as u64
            + a.line_offset(&g) as u64;
        assert_eq!(reconstructed, a.raw);
    }

    #[test]
    fn same_set_same_bank() {
        let g = Geometry::default();
        let stride = (g.line_bytes * g.sets_per_slice) as u64;
        let a = Address::new(0x1000);
        let b = Address::new(0x1000 + stride); // same set, different tag
        assert_eq!(a.set_index(&g), b.set_index(&g));
        assert_eq!(a.bank_index(&g), b.bank_index(&g));
        assert_ne!(a.tag(&g), b.tag(&g));
    }

    #[test]
    fn adjacent_lines_spread_over_banks() {
        let g = Geometry::default();
        let a = Address::new(0);
        let b = Address::new(g.line_bytes as u64);
        assert_ne!(a.bank_index(&g), b.bank_index(&g));
    }
}
