//! Cache hierarchy substrate (§II-B, Fig. 1).
//!
//! Models the LLC organization the paper targets: slices → banks →
//! sub-arrays, with tag/valid/LRU state and a controller that arbitrates
//! conventional cache traffic against PIM windows. The controller supports
//! two PIM integration modes:
//!
//! * **Retained** (this paper): PIM runs in place; cache lines stay valid
//!   (the 6T-2R property). Requests to a busy array stall only for the
//!   current PIM step.
//! * **FlushReload** (prior 6T PIM, refs [22]/[23]): the array's lines are
//!   flushed before a PIM campaign and reloaded after — the ablation
//!   baseline quantifying the paper's motivation.

pub mod addr;
pub mod bank;
pub mod controller;
pub mod lru;
pub mod slice;
pub mod tag;
pub mod workload;

pub use addr::Address;
pub use controller::{CacheController, PimIntegration};
pub use slice::LlcSlice;
