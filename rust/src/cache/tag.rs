//! Tag / valid / dirty state for one cache set (the "1-way tag, cache-valid
//! (CV) bits, state" structures of §II-B).

/// One way's tag entry.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct TagEntry {
    /// Tag bits.
    pub tag: u64,
    /// Cache-valid bit.
    pub valid: bool,
    /// Dirty (modified) bit.
    pub dirty: bool,
}

impl TagEntry {
    /// An empty (invalid) entry.
    pub fn invalid() -> TagEntry {
        TagEntry { tag: 0, valid: false, dirty: false }
    }
}

/// Tag array for one set.
#[derive(Clone, Debug)]
pub struct TagSet {
    /// Per-way entries.
    pub ways: Vec<TagEntry>,
}

impl TagSet {
    /// An empty set with `ways` ways.
    pub fn new(ways: usize) -> TagSet {
        TagSet { ways: vec![TagEntry::invalid(); ways] }
    }

    /// Look up a tag; returns the hitting way.
    pub fn lookup(&self, tag: u64) -> Option<usize> {
        self.ways
            .iter()
            .position(|e| e.valid && e.tag == tag)
    }

    /// Install a tag into a way (on fill).
    pub fn fill(&mut self, way: usize, tag: u64) {
        self.ways[way] = TagEntry { tag, valid: true, dirty: false };
    }

    /// Invalidate a way, returning its previous entry.
    pub fn invalidate(&mut self, way: usize) -> TagEntry {
        std::mem::replace(&mut self.ways[way], TagEntry::invalid())
    }

    /// Set the dirty bit of a (valid) way.
    pub fn mark_dirty(&mut self, way: usize) {
        debug_assert!(self.ways[way].valid);
        self.ways[way].dirty = true;
    }

    /// Number of valid ways.
    pub fn valid_count(&self) -> usize {
        self.ways.iter().filter(|e| e.valid).count()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lookup_miss_on_empty() {
        let t = TagSet::new(4);
        assert_eq!(t.lookup(42), None);
    }

    #[test]
    fn fill_then_hit() {
        let mut t = TagSet::new(4);
        t.fill(2, 42);
        assert_eq!(t.lookup(42), Some(2));
        assert_eq!(t.valid_count(), 1);
    }

    #[test]
    fn invalidate_returns_old_state() {
        let mut t = TagSet::new(2);
        t.fill(0, 7);
        t.mark_dirty(0);
        let old = t.invalidate(0);
        assert!(old.dirty && old.valid && old.tag == 7);
        assert_eq!(t.lookup(7), None);
    }

    #[test]
    fn distinct_tags_coexist() {
        let mut t = TagSet::new(4);
        t.fill(0, 1);
        t.fill(1, 2);
        assert_eq!(t.lookup(1), Some(0));
        assert_eq!(t.lookup(2), Some(1));
    }
}
