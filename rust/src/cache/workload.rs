//! Trace-driven cache workload generation + the PIM-interference study.
//!
//! The paper's §I motivation is that prior 6T PIM forces flush/reload,
//! "introducing additional latency and energy due to extra data movement".
//! This module quantifies that architecturally: synthetic-but-structured
//! access traces (sequential scans, zipf-like hot sets, strided walks) run
//! against the controller while PIM campaigns execute at a configurable
//! intensity, measuring hit-rate and AMAT degradation in both integration
//! modes.

use crate::util::rng::Pcg64;

use super::addr::{Address, Geometry};
use super::controller::{CacheController, PimIntegration};

/// Trace shapes.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum TraceKind {
    /// Repeated sequential scan over a working set.
    SequentialScan,
    /// Hot-set dominated (80/20) re-reference.
    HotSet,
    /// Strided walk (conflict-prone).
    Strided,
}

impl TraceKind {
    /// Every trace shape.
    pub const ALL: [TraceKind; 3] =
        [TraceKind::SequentialScan, TraceKind::HotSet, TraceKind::Strided];

    /// Snake-case label for CSV emission.
    pub fn name(&self) -> &'static str {
        match self {
            TraceKind::SequentialScan => "sequential",
            TraceKind::HotSet => "hot_set",
            TraceKind::Strided => "strided",
        }
    }
}

/// Generate `n` line addresses for a trace over `working_set_lines`.
pub fn generate_trace(
    kind: TraceKind,
    working_set_lines: usize,
    n: usize,
    rng: &mut Pcg64,
) -> Vec<Address> {
    let line = 64u64;
    match kind {
        TraceKind::SequentialScan => (0..n)
            .map(|i| Address::new((i % working_set_lines) as u64 * line))
            .collect(),
        TraceKind::HotSet => {
            let hot = (working_set_lines / 5).max(1);
            (0..n)
                .map(|_| {
                    let idx = if rng.f64() < 0.8 {
                        rng.below(hot)
                    } else {
                        hot + rng.below((working_set_lines - hot).max(1))
                    };
                    Address::new(idx as u64 * line)
                })
                .collect()
        }
        TraceKind::Strided => {
            // Stride of one set-stride: maximally conflict-prone.
            (0..n)
                .map(|i| Address::new((i % working_set_lines) as u64 * line * 17))
                .collect()
        }
    }
}

/// Result of one interference run.
#[derive(Clone, Debug)]
pub struct InterferenceResult {
    /// Trace shape used.
    pub trace: TraceKind,
    /// PIM integration mode.
    pub mode: PimIntegration,
    /// PIM campaigns per 1000 accesses.
    pub pim_intensity: usize,
    /// Post-warmup cache hit rate.
    pub hit_rate: f64,
    /// Average memory-access time (s): hit pays the 6T-2R read, miss adds
    /// a line fill.
    pub amat: f64,
    /// Cache lines moved by PIM campaigns (flush + reload).
    pub lines_moved: u64,
}

/// Run a trace against a slice while PIM campaigns fire every
/// `1000/pim_intensity` accesses in rotating banks.
pub fn run_interference(
    trace: TraceKind,
    mode: PimIntegration,
    pim_intensity: usize,
    seed: u64,
) -> InterferenceResult {
    let geom = Geometry::tiny();
    let mut ctl = CacheController::new(geom, mode);
    let mut rng = Pcg64::seeded(seed);
    let n = 6000;
    let accesses = generate_trace(trace, 160, n, &mut rng);
    // Warm up.
    for a in accesses.iter().take(1000) {
        ctl.read(*a);
    }
    for bank in 0..geom.banks_per_slice {
        ctl.program_campaign(bank, 0, vec![3u8; 128 * 128]);
    }
    ctl.slice.hits = 0;
    ctl.slice.misses = 0;
    let mut lines_moved = 0u64;
    let every = if pim_intensity == 0 { usize::MAX } else { 1000 / pim_intensity.max(1) };
    let mut bank = 0usize;
    for (i, a) in accesses.iter().enumerate().skip(1000) {
        ctl.read(*a);
        if i % every == 0 {
            let s = ctl.pim_campaign(bank, 0, 4);
            lines_moved += s.lines_moved;
            bank = (bank + 1) % geom.banks_per_slice;
        }
    }
    let hits = ctl.slice.hits as f64;
    let misses = ctl.slice.misses as f64;
    let (t_hit, _) = crate::cell::timing::OpKind::SramRead6t2r.cost();
    let (t_fill, _) = crate::cell::timing::OpKind::CacheLineMove.cost();
    let amat = (hits * t_hit + misses * (t_hit + t_fill)) / (hits + misses);
    InterferenceResult {
        trace,
        mode,
        pim_intensity,
        hit_rate: ctl.slice.hit_rate(),
        amat,
        lines_moved,
    }
}

/// The full sweep used by `repro cache-sim`: every trace × both modes ×
/// PIM intensities.
pub fn interference_sweep(seed: u64) -> Vec<InterferenceResult> {
    let mut out = Vec::new();
    for trace in TraceKind::ALL {
        for mode in [PimIntegration::Retained, PimIntegration::FlushReload] {
            for intensity in [0usize, 10, 50, 200] {
                out.push(run_interference(trace, mode, intensity, seed));
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn traces_have_expected_shapes() {
        let mut rng = Pcg64::seeded(1);
        let seq = generate_trace(TraceKind::SequentialScan, 10, 25, &mut rng);
        assert_eq!(seq[0], seq[10]);
        let hot = generate_trace(TraceKind::HotSet, 100, 2000, &mut rng);
        let hot_hits = hot
            .iter()
            .filter(|a| (a.raw / 64) < 20)
            .count() as f64
            / 2000.0;
        assert!(hot_hits > 0.7, "80/20 skew: {hot_hits}");
    }

    #[test]
    fn zero_intensity_modes_identical() {
        let a = run_interference(TraceKind::HotSet, PimIntegration::Retained, 0, 5);
        let b = run_interference(TraceKind::HotSet, PimIntegration::FlushReload, 0, 5);
        assert!((a.hit_rate - b.hit_rate).abs() < 1e-12, "no PIM ⇒ identical");
        assert_eq!(b.lines_moved, 0);
    }

    #[test]
    fn flush_reload_degrades_with_intensity() {
        let lo = run_interference(TraceKind::HotSet, PimIntegration::FlushReload, 10, 5);
        let hi = run_interference(TraceKind::HotSet, PimIntegration::FlushReload, 200, 5);
        assert!(hi.hit_rate < lo.hit_rate, "{} !< {}", hi.hit_rate, lo.hit_rate);
        assert!(hi.lines_moved > lo.lines_moved);
        assert!(hi.amat > lo.amat);
    }

    #[test]
    fn retained_mode_immune_to_intensity() {
        let lo = run_interference(TraceKind::HotSet, PimIntegration::Retained, 0, 5);
        let hi = run_interference(TraceKind::HotSet, PimIntegration::Retained, 200, 5);
        assert!((hi.hit_rate - lo.hit_rate).abs() < 0.01);
        assert_eq!(hi.lines_moved, 0);
    }

    #[test]
    fn sweep_covers_matrix() {
        let sweep = interference_sweep(3);
        assert_eq!(sweep.len(), 3 * 2 * 4);
        // The headline: at max intensity, retained beats flush/reload on
        // hit rate for every trace kind.
        for trace in TraceKind::ALL {
            let ret = sweep
                .iter()
                .find(|r| r.trace == trace && r.mode == PimIntegration::Retained && r.pim_intensity == 200)
                .unwrap();
            let fr = sweep
                .iter()
                .find(|r| r.trace == trace && r.mode == PimIntegration::FlushReload && r.pim_intensity == 200)
                .unwrap();
            assert!(
                ret.hit_rate >= fr.hit_rate,
                "{}: {} vs {}",
                trace.name(),
                ret.hit_rate,
                fr.hit_rate
            );
        }
    }
}
