//! One LLC slice: tag array + LRU + banks (Fig. 1b/c).

use crate::cell::timing::EnergyLedger;

use super::addr::{Address, Geometry};
use super::bank::Bank;
use super::lru::LruSet;
use super::tag::TagSet;

/// Access outcome.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum AccessResult {
    /// Tag matched a valid way.
    Hit,
    /// No matching way.
    Miss,
    /// Miss that evicted a dirty victim (writeback needed).
    MissDirtyEvict,
}

/// One slice.
pub struct LlcSlice {
    /// Slice geometry.
    pub geom: Geometry,
    /// Per-set tag arrays.
    pub tags: Vec<TagSet>,
    /// Per-set LRU state.
    pub lru: Vec<LruSet>,
    /// Data banks.
    pub banks: Vec<Bank>,
    /// Access cost accounting.
    pub ledger: EnergyLedger,
    /// Hit counter.
    pub hits: u64,
    /// Miss counter.
    pub misses: u64,
}

impl LlcSlice {
    /// Empty slice with the given geometry.
    pub fn new(geom: Geometry) -> LlcSlice {
        LlcSlice {
            geom,
            tags: (0..geom.sets_per_slice).map(|_| TagSet::new(geom.ways)).collect(),
            lru: (0..geom.sets_per_slice).map(|_| LruSet::new(geom.ways)).collect(),
            banks: (0..geom.banks_per_slice)
                .map(|_| Bank::new(geom.subarrays_per_bank, geom.rows_per_subarray))
                .collect(),
            ledger: EnergyLedger::new(),
            hits: 0,
            misses: 0,
        }
    }

    /// Bank-local line index for (set, way): sets stripe across banks, the
    /// per-bank stream packs (set/banks, way).
    fn line_index(&self, set: usize, way: usize) -> usize {
        let local_set = set / self.geom.banks_per_slice;
        (local_set * self.geom.ways + way) % (self.geom.lines_per_bank())
    }

    /// Read access. Returns (result, data-if-hit).
    pub fn read(&mut self, addr: Address) -> (AccessResult, Option<[u8; 64]>) {
        let set = addr.set_index(&self.geom);
        let tag = addr.tag(&self.geom);
        let bank_i = addr.bank_index(&self.geom);
        match self.tags[set].lookup(tag) {
            Some(way) => {
                self.hits += 1;
                self.lru[set].touch(way);
                let li = self.line_index(set, way);
                let data = self.banks[bank_i].read_line(li, &mut self.ledger);
                (AccessResult::Hit, data)
            }
            None => {
                self.misses += 1;
                (AccessResult::Miss, None)
            }
        }
    }

    /// Fill a line after a miss; returns the evicted (addr-tag, data) if a
    /// dirty victim was displaced.
    pub fn fill(&mut self, addr: Address, data: [u8; 64]) -> AccessResult {
        let set = addr.set_index(&self.geom);
        let tag = addr.tag(&self.geom);
        let bank_i = addr.bank_index(&self.geom);
        let way = match self.tags[set].lookup(tag) {
            Some(w) => w,
            None => self.lru[set].victim(),
        };
        let old = self.tags[set].invalidate(way);
        let li = self.line_index(set, way);
        let result = if old.valid && old.dirty {
            AccessResult::MissDirtyEvict
        } else {
            AccessResult::Miss
        };
        self.banks[bank_i].evict_line(li);
        self.tags[set].fill(way, tag);
        self.lru[set].touch(way);
        self.banks[bank_i].write_line(li, data, &mut self.ledger);
        result
    }

    /// Write access (write-back): hit updates in place and marks dirty.
    pub fn write(&mut self, addr: Address, data: [u8; 64]) -> AccessResult {
        let set = addr.set_index(&self.geom);
        let tag = addr.tag(&self.geom);
        let bank_i = addr.bank_index(&self.geom);
        match self.tags[set].lookup(tag) {
            Some(way) => {
                self.hits += 1;
                self.lru[set].touch(way);
                self.tags[set].mark_dirty(way);
                let li = self.line_index(set, way);
                self.banks[bank_i].write_line(li, data, &mut self.ledger);
                AccessResult::Hit
            }
            None => {
                self.misses += 1;
                let r = self.fill(addr, data);
                let set_tags = &mut self.tags[set];
                let way = set_tags.lookup(tag).unwrap();
                set_tags.mark_dirty(way);
                r
            }
        }
    }

    /// Invalidate every resident line that physically lives in the given
    /// (bank, sub-array) — the flush a 6T-SRAM PIM campaign forces.
    /// Returns the number of lines invalidated.
    pub fn invalidate_subarray(&mut self, bank: usize, sa: usize) -> usize {
        let rows = self.geom.rows_per_subarray;
        let mut n = 0;
        for set in 0..self.geom.sets_per_slice {
            if set % self.geom.banks_per_slice != bank {
                continue;
            }
            for way in 0..self.geom.ways {
                if !self.tags[set].ways[way].valid {
                    continue;
                }
                let li = self.line_index(set, way);
                if li / rows == sa {
                    self.tags[set].invalidate(way);
                    self.banks[bank].evict_line(li);
                    n += 1;
                }
            }
        }
        n
    }

    /// Fraction of accesses that hit (0 when no accesses yet).
    pub fn hit_rate(&self) -> f64 {
        let total = self.hits + self.misses;
        if total == 0 {
            0.0
        } else {
            self.hits as f64 / total as f64
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn slice() -> LlcSlice {
        LlcSlice::new(Geometry::tiny())
    }

    #[test]
    fn miss_then_fill_then_hit() {
        let mut s = slice();
        let a = Address::new(0x4000);
        let (r, _) = s.read(a);
        assert_eq!(r, AccessResult::Miss);
        s.fill(a, [9u8; 64]);
        let (r, d) = s.read(a);
        assert_eq!(r, AccessResult::Hit);
        assert_eq!(d, Some([9u8; 64]));
        assert_eq!(s.hits, 1);
        assert_eq!(s.misses, 1);
    }

    #[test]
    fn write_allocates_and_dirties() {
        let mut s = slice();
        let a = Address::new(0x8000);
        assert_eq!(s.write(a, [3u8; 64]), AccessResult::Miss);
        let set = a.set_index(&s.geom);
        let way = s.tags[set].lookup(a.tag(&s.geom)).unwrap();
        assert!(s.tags[set].ways[way].dirty);
        // Re-write hits.
        assert_eq!(s.write(a, [4u8; 64]), AccessResult::Hit);
        assert_eq!(s.read(a).1, Some([4u8; 64]));
    }

    #[test]
    fn eviction_on_conflict() {
        let mut s = slice();
        let g = s.geom;
        let set_stride = (g.line_bytes * g.sets_per_slice) as u64;
        // Fill ways+1 conflicting lines in one set.
        let addrs: Vec<Address> =
            (0..g.ways as u64 + 1).map(|i| Address::new(0x100 * 64 + i * set_stride)).collect();
        for a in &addrs {
            s.fill(*a, [0u8; 64]);
        }
        // The first line was LRU-evicted.
        let (r, _) = s.read(addrs[0]);
        assert_eq!(r, AccessResult::Miss);
        // The last is resident.
        let (r, _) = s.read(addrs[g.ways]);
        assert_eq!(r, AccessResult::Hit);
    }

    #[test]
    fn dirty_eviction_reported() {
        let mut s = slice();
        let g = s.geom;
        let set_stride = (g.line_bytes * g.sets_per_slice) as u64;
        let a0 = Address::new(0);
        s.write(a0, [1u8; 64]); // dirty
        for i in 1..=g.ways as u64 {
            let r = s.fill(Address::new(i * set_stride), [0u8; 64]);
            if i == g.ways as u64 {
                assert_eq!(r, AccessResult::MissDirtyEvict);
            }
        }
    }

    #[test]
    fn hit_rate_tracks() {
        let mut s = slice();
        let a = Address::new(0x40);
        s.read(a);
        s.fill(a, [0u8; 64]);
        s.read(a);
        s.read(a);
        assert!((s.hit_rate() - 2.0 / 3.0).abs() < 1e-12);
    }
}
