//! One cache data bank: a set of 6T-2R sub-arrays holding 64 B lines
//! (one line per sub-array row), plus PIM occupancy state.
//!
//! The bank tracks *where data lives* and *what the RRAM layer holds*;
//! electrical behavior is owned by [`crate::array::SubArray`] (validated
//! there) — the bank level accounts occupancy, conflicts, and costs, which
//! is what the architecture-level experiments need.

use crate::cell::timing::{EnergyLedger, OpKind};

/// State of one sub-array inside a bank.
#[derive(Clone, Debug)]
pub struct SubArraySlot {
    /// Cache line data per row (None = not resident).
    pub lines: Vec<Option<[u8; 64]>>,
    /// 4-bit weights resident in the RRAM layer (None = unprogrammed).
    pub weights: Option<Vec<u8>>,
    /// Busy-until timestamp (s) — PIM occupancy.
    pub busy_until: f64,
}

impl SubArraySlot {
    /// Empty slot with `rows` line positions.
    pub fn new(rows: usize) -> SubArraySlot {
        SubArraySlot { lines: vec![None; rows], weights: None, busy_until: 0.0 }
    }

    /// Number of resident cache lines.
    pub fn resident_lines(&self) -> usize {
        self.lines.iter().filter(|l| l.is_some()).count()
    }
}

/// One 32 KB bank.
#[derive(Clone, Debug)]
pub struct Bank {
    /// Sub-array slots.
    pub subarrays: Vec<SubArraySlot>,
    /// Rows (lines) per sub-array.
    pub rows: usize,
}

impl Bank {
    /// Empty bank of `subarrays` slots × `rows` lines.
    pub fn new(subarrays: usize, rows: usize) -> Bank {
        Bank {
            subarrays: (0..subarrays).map(|_| SubArraySlot::new(rows)).collect(),
            rows,
        }
    }

    /// Map a bank-local line index to (subarray, row).
    pub fn locate(&self, line_idx: usize) -> (usize, usize) {
        (line_idx / self.rows, line_idx % self.rows)
    }

    /// Read a resident line (metered).
    pub fn read_line(&self, line_idx: usize, ledger: &mut EnergyLedger) -> Option<[u8; 64]> {
        let (sa, row) = self.locate(line_idx);
        ledger.record(OpKind::SramRead6t2r);
        self.subarrays[sa].lines[row]
    }

    /// Write a line (metered).
    pub fn write_line(&mut self, line_idx: usize, data: [u8; 64], ledger: &mut EnergyLedger) {
        let (sa, row) = self.locate(line_idx);
        ledger.record(OpKind::SramWrite);
        self.subarrays[sa].lines[row] = Some(data);
    }

    /// Remove and return a line (no cost — bookkeeping only).
    pub fn evict_line(&mut self, line_idx: usize) -> Option<[u8; 64]> {
        let (sa, row) = self.locate(line_idx);
        self.subarrays[sa].lines[row].take()
    }

    /// Program weights into a sub-array's RRAM layer. Destructive to the
    /// SRAM data in that array (§III-A) — resident lines are lost unless
    /// the controller flushed them first; returns how many were destroyed.
    pub fn program_weights(
        &mut self,
        sa: usize,
        weights: Vec<u8>,
        ledger: &mut EnergyLedger,
    ) -> usize {
        // Two LRS cycles + one HRS cycle worth of pulses per cell, at 512
        // cells per row... we meter per-word granularity: rows × words
        // pulses (each 4-bit word programmed as a unit across cycles).
        let n_cells = weights.len() * 4;
        ledger.record_n(OpKind::ProgramPulse, n_cells as u64);
        ledger.record_n(OpKind::NvmRead, n_cells as u64); // program-verify
        let slot = &mut self.subarrays[sa];
        let destroyed = slot.resident_lines();
        for l in slot.lines.iter_mut() {
            *l = None; // programming clobbers the latches
        }
        slot.weights = Some(weights);
        destroyed
    }

    /// Is the sub-array reserved by a PIM window at time `now`?
    pub fn is_busy(&self, sa: usize, now: f64) -> bool {
        self.subarrays[sa].busy_until > now
    }

    /// Reserve a sub-array for a PIM window.
    pub fn reserve(&mut self, sa: usize, now: f64, duration: f64) {
        let slot = &mut self.subarrays[sa];
        slot.busy_until = slot.busy_until.max(now) + duration;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn line_roundtrip() {
        let mut b = Bank::new(4, 128);
        let mut led = EnergyLedger::new();
        let data = [7u8; 64];
        b.write_line(300, data, &mut led);
        assert_eq!(b.read_line(300, &mut led), Some(data));
        assert_eq!(b.locate(300), (2, 44));
    }

    #[test]
    fn programming_destroys_resident_lines() {
        let mut b = Bank::new(2, 128);
        let mut led = EnergyLedger::new();
        b.write_line(5, [1u8; 64], &mut led);
        b.write_line(200, [2u8; 64], &mut led); // other sub-array
        let destroyed = b.program_weights(0, vec![0u8; 128 * 128], &mut led);
        assert_eq!(destroyed, 1);
        assert_eq!(b.read_line(5, &mut led), None);
        assert_eq!(b.read_line(200, &mut led), Some([2u8; 64]));
        assert!(b.subarrays[0].weights.is_some());
    }

    #[test]
    fn reservation_blocks_until_expiry() {
        let mut b = Bank::new(1, 128);
        b.reserve(0, 0.0, 1.0e-6);
        assert!(b.is_busy(0, 0.5e-6));
        assert!(!b.is_busy(0, 1.5e-6));
    }

    #[test]
    fn back_to_back_reservations_queue() {
        let mut b = Bank::new(1, 128);
        b.reserve(0, 0.0, 1.0e-6);
        b.reserve(0, 0.0, 1.0e-6); // queued behind the first
        assert!(b.is_busy(0, 1.5e-6));
        assert!(!b.is_busy(0, 2.5e-6));
    }
}
