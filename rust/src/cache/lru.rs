//! True-LRU replacement state per set (the paper's slice keeps "least
//! recently used (LRU) structures" alongside the tag array, §II-B).

/// LRU tracker for one set of `ways` ways. Stores a recency ordering:
/// `order[0]` is the MRU way, `order[last]` the LRU victim.
#[derive(Clone, Debug)]
pub struct LruSet {
    order: Vec<u8>,
}

impl LruSet {
    /// Fresh tracker: way 0 is MRU, the last way is the victim.
    pub fn new(ways: usize) -> LruSet {
        assert!(ways > 0 && ways <= 256);
        LruSet { order: (0..ways as u8).collect() }
    }

    /// Mark a way as most-recently used.
    pub fn touch(&mut self, way: usize) {
        let pos = self.order.iter().position(|&w| w as usize == way).unwrap();
        let w = self.order.remove(pos);
        self.order.insert(0, w);
    }

    /// The current victim (least-recently used way).
    pub fn victim(&self) -> usize {
        *self.order.last().unwrap() as usize
    }

    /// The most-recently-used way.
    pub fn mru(&self) -> usize {
        self.order[0] as usize
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn initial_victim_is_last_way() {
        let l = LruSet::new(4);
        assert_eq!(l.victim(), 3);
    }

    #[test]
    fn touch_moves_to_mru() {
        let mut l = LruSet::new(4);
        l.touch(2);
        assert_eq!(l.mru(), 2);
        assert_eq!(l.victim(), 3);
        l.touch(3);
        l.touch(1);
        l.touch(0);
        // 2 is now the least recently used.
        assert_eq!(l.victim(), 2);
    }

    #[test]
    fn repeated_touch_is_stable() {
        let mut l = LruSet::new(3);
        l.touch(1);
        l.touch(1);
        assert_eq!(l.mru(), 1);
        assert_eq!(l.victim(), 2);
    }

    #[test]
    fn full_access_sequence() {
        let mut l = LruSet::new(2);
        l.touch(0); // order: 0, 1
        l.touch(1); // order: 1, 0
        assert_eq!(l.victim(), 0);
    }
}
