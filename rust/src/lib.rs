//! # NVM-in-Cache — full-system reproduction
//!
//! Reproduction of *"NVM-in-Cache: Repurposing Commodity 6T SRAM Cache into
//! NVM Analog Processing-in-Memory Engine using a Novel Compute-on-Powerline
//! Scheme"* (Chakraborty et al., 2025).
//!
//! The crate is organized bottom-up, mirroring the hardware stack:
//!
//! * [`util`] — PRNG, statistics, least-squares fits, CSV/JSON emitters,
//!   CLI parsing and a micro-benchmark harness (the build is fully offline,
//!   so these replace `rand`/`serde`/`criterion`/`clap`).
//! * [`device`] — compact device models: bipolar filamentary RRAM
//!   (Jiang et al. SISPAD'14 family), corner-aware MOSFETs, Monte-Carlo
//!   process variation.
//! * [`cell`] — the 6T-2R bit-cell: NVM programming, SRAM hold/read/write,
//!   the two-cycle compute-on-powerline PIM dot-product, static noise
//!   margins, and a per-operation latency/energy ledger.
//! * [`array`] — the 128×512 sub-array: powerline current accumulation,
//!   the 8:4:2:1 weighted-configuration circuit (WCC), sample-and-hold,
//!   6-bit SAR ADC, and the PIM control FSM.
//! * [`pim`] — quantization + the end-to-end analog transfer model
//!   (weight → current → voltage → ADC code) and the PIM execution engine
//!   that runs quantized CNN layers on simulated arrays; `pim::parallel`
//!   tiles the MAC hot path across cores with bit-identical output
//!   (PERFORMANCE.md), and `pim::program` is the compile-once /
//!   execute-many weight-program layer (prepared banks, compiled
//!   networks) mirroring one-time RRAM programming (ARCHITECTURE.md
//!   §program).
//! * [`cache`] — the LLC substrate: slices, banks, tags, LRU, and the
//!   controller that arbitrates SRAM-mode traffic against PIM windows
//!   while *retaining* cache data (the paper's headline architectural
//!   property), plus a flush-reload baseline for ablation.
//! * [`mapping`] — CNN→array mapping: IFM-reuse conv mapping, bit-serial
//!   multi-bit scheduling, digital shift-add / positive-negative-bank
//!   subtraction post-processing.
//! * [`nn`] — a small digital-exact inference stack (tensors, conv/bn/fc,
//!   the ResNet-18 topology, and a quantized transformer encoder —
//!   `nn::transformer`) used as the fp32 baseline and as the ground
//!   truth every runtime backend is cross-checked against.
//! * [`runtime`] — the model-execution seam: the [`runtime::Runtime`]
//!   trait, the in-tree [`runtime::StubRuntime`] backend (digital-exact
//!   [`nn::ResNet`] forward + [`pim::TransferModel`] emulation, zero
//!   dependencies), and a feature-gated (`pjrt`) slot where the original
//!   xla-crate PJRT client can be re-attached.
//! * [`coordinator`] — the serving layer: request router, dynamic batcher,
//!   bank scheduler, metrics. std::thread + mpsc (offline build, no tokio).
//! * [`fleet`] — the multi-tenant serving fabric above the coordinator:
//!   model registry, endurance-aware wear-leveling placer, campaign
//!   scheduler (drain → program → rewarm), fleet router + admission
//!   control, and the deterministic `repro fleet-sim` simulator.
//! * [`perf`] — the analytic throughput/energy/area model that reproduces
//!   Table I and the Fig. 14 scaling study.
//! * [`figures`] — one generator per paper table/figure.
//!
//! See README.md for the quickstart, ARCHITECTURE.md for the layer-by-layer
//! data flow, EXPERIMENTS.md for the experiment ids (E1–E14, §Perf, A1–A3)
//! cited throughout the code, and PERFORMANCE.md for the tiled parallel
//! engine, the word-wide bit-plane MAC kernel, and the cross-PR perf
//! trajectory.

#![warn(missing_docs)]

pub mod util;
pub mod device;
pub mod cell;
pub mod array;
pub mod pim;
pub mod cache;
pub mod mapping;
pub mod nn;
pub mod runtime;
pub mod coordinator;
pub mod fleet;
pub mod perf;
pub mod figures;

/// Shared physical and architectural constants from the paper.
pub mod consts {
    /// Nominal supply voltage (V). §III: "VDD1 and VDD2 are maintained at 0.8 V".
    pub const VDD: f64 = 0.8;
    /// Wordline overdrive voltage used during NVM programming (V). §III-A.
    pub const V_OVERDRIVE: f64 = 2.0;
    /// RRAM SET threshold (V). Fig. 9(a).
    pub const V_SET: f64 = 1.2;
    /// RRAM RESET threshold (V). Fig. 9(a).
    pub const V_RESET: f64 = -1.2;
    /// Low-resistance state (Ω). §V-B: "LRS, ~25 kΩ".
    pub const R_LRS: f64 = 25.0e3;
    /// High-resistance state (Ω). §V-B: "HRS, ~1.2 MΩ".
    pub const R_HRS: f64 = 1.2e6;
    /// Programming pulse width (s). §III-A / §V-B: 4 ns per SET/RESET pulse.
    pub const T_PROGRAM: f64 = 4.0e-9;
    /// PIM cycle time (s). §III-C: each PIM cycle lasts 3.5 ns.
    pub const T_PIM_CYCLE: f64 = 3.5e-9;
    /// PIM settle sub-phase (s): VDD line driven to WCC reference. §III-C.
    pub const T_PIM_SETTLE: f64 = 1.5e-9;
    /// PIM sample sub-phase (s): IA applied on the wordline. §III-C.
    pub const T_PIM_SAMPLE: f64 = 1.0e-9;
    /// PIM restore sub-phase (s): supplies restored to nominal. §III-C.
    pub const T_PIM_RESTORE: f64 = 1.0e-9;
    /// SAR ADC clock (Hz). §IV-B: 50 MHz.
    pub const ADC_CLOCK_HZ: f64 = 50.0e6;
    /// SAR ADC conversion latency (s). §V-D: 160 ns (8 clock cycles @50 MHz).
    pub const T_ADC_CONVERSION: f64 = 160.0e-9;
    /// ADC resolution in bits. §IV-B.
    pub const ADC_BITS: u32 = 6;
    /// Sub-array geometry: rows. §IV-A.
    pub const ARRAY_ROWS: usize = 128;
    /// Sub-array geometry: 1-bit columns (= 128 × 4-bit words). §IV-A.
    pub const ARRAY_COLS: usize = 512;
    /// Word width in bits (weights are 4-bit). §IV-B.
    pub const WORD_BITS: usize = 4;
    /// 4-bit words per sub-array row.
    pub const ARRAY_WORDS: usize = ARRAY_COLS / WORD_BITS;
    /// Calibrated SAR ADC positive reference (V). Fig. 12 caption
    /// (the §V-C body text says 820/260 mV — see EXPERIMENTS.md E6 for the
    /// discrepancy note; the caption values are consistent with the reported
    /// uncalibrated code span 7–48, so we use them).
    pub const V_REFP_CAL: f64 = 0.660;
    /// Calibrated SAR ADC negative reference (V). Fig. 12 caption.
    pub const V_REFN_CAL: f64 = 0.090;
    /// Uncalibrated SAR ADC reference (V): full-scale VDD. §V-C.
    pub const V_REF_UNCAL: f64 = 0.800;
    /// Baseline 6T read latency anchor (s). §V-B: 660 ps.
    pub const T_READ_6T: f64 = 660.0e-12;
    /// 6T-2R read latency anchor (s). §V-B: 686 ps.
    pub const T_READ_6T2R: f64 = 686.0e-12;
    /// 512-bit row read energy, conventional 6T (J). §V-B: 2.23 fJ.
    pub const E_READ_ROW_6T: f64 = 2.23e-15;
    /// 512-bit row read energy, 6T-2R (J). §V-B: 3.34 fJ.
    pub const E_READ_ROW_6T2R: f64 = 3.34e-15;
}

/// Crate-wide error type.
///
/// Hand-rolled `Display`/`Error`/`From` impls (the `thiserror` crate is
/// unavailable in the offline build).
#[derive(Debug)]
pub enum Error {
    /// A required artifact (weights, dataset, manifest) is missing or
    /// malformed.
    Artifact(String),
    /// A runtime backend failed (variant not loaded, shape mismatch, …).
    Runtime(String),
    /// Bad user-supplied configuration (CLI options, geometry, …).
    Config(String),
    /// Cache-substrate invariant violation.
    Cache(String),
    /// Underlying I/O failure.
    Io(std::io::Error),
}

impl std::fmt::Display for Error {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            Error::Artifact(m) => write!(f, "artifact error: {m}"),
            Error::Runtime(m) => write!(f, "runtime error: {m}"),
            Error::Config(m) => write!(f, "config error: {m}"),
            Error::Cache(m) => write!(f, "cache error: {m}"),
            Error::Io(e) => write!(f, "io: {e}"),
        }
    }
}

impl std::error::Error for Error {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            Error::Io(e) => Some(e),
            _ => None,
        }
    }
}

impl From<std::io::Error> for Error {
    fn from(e: std::io::Error) -> Error {
        Error::Io(e)
    }
}

/// Crate-wide result alias.
pub type Result<T> = std::result::Result<T, Error>;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn error_display_and_source() {
        let e = Error::Artifact("weights.bin missing".into());
        assert_eq!(e.to_string(), "artifact error: weights.bin missing");
        let io: Error = std::io::Error::new(std::io::ErrorKind::Other, "boom").into();
        assert!(io.to_string().contains("boom"));
        assert!(std::error::Error::source(&io).is_some());
        assert!(std::error::Error::source(&e).is_none());
    }
}
