//! CNN → 6T-2R array mapping (§IV-C, Fig. 7).
//!
//! * [`conv_mapper`] — the IFM-reuse mapping of Peng et al. [33]: a
//!   K×K×D×N kernel becomes K² submatrices of shape [D, N], each tiled
//!   onto 128×128 sub-array banks; input pixels stream along wordlines and
//!   are reused by neighboring banks as the window slides.
//! * [`bit_serial`] — the multi-bit schedule: activation bit-planes ×
//!   2 powerline sides × weight nibbles, with conversion counts/latency.
//! * [`digital`] — the digital periphery: shift-add recombination,
//!   positive/negative bank subtraction, output registers.
//! * [`layout`] — placement of a whole network's tiles onto the cache's
//!   banks/sub-arrays (consumed by the coordinator's scheduler).

pub mod bit_serial;
pub mod conv_mapper;
pub mod digital;
pub mod layout;

pub use conv_mapper::{ConvMapping, ConvShape};
pub use layout::{NetworkLayout, TilePlacement};
