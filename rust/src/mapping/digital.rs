//! Digital periphery (§IV-B end): shift-and-add units, the
//! positive/negative-bank subtractor, and output registers — "these digital
//! operations can be implemented outside the cache array".

use super::bit_serial::BitSerialSchedule;

/// Shift-add recombination of per-(plane, nibble) dequantized partial sums.
/// `partials[a][n]` is the ADC-estimated MAC for activation plane `a` and
/// weight nibble `n`.
pub fn shift_add(schedule: &BitSerialSchedule, partials: &[Vec<f64>]) -> f64 {
    assert_eq!(partials.len(), schedule.act_bits as usize);
    partials
        .iter()
        .enumerate()
        .map(|(a, nibbles)| {
            assert_eq!(nibbles.len(), schedule.weight_nibbles as usize);
            nibbles
                .iter()
                .enumerate()
                .map(|(n, v)| v * (1u64 << schedule.shift_for(a as u32, n as u32)) as f64)
                .sum::<f64>()
        })
        .sum()
}

/// Positive/negative bank subtraction (§IV-C).
pub fn subtract_banks(pos: f64, neg: f64) -> f64 {
    pos - neg
}

/// Saturating output register with configurable width (the accumulators
/// downstream of the subtractor; paper reports 6-bit output precision per
/// conversion but wider accumulation).
#[derive(Clone, Copy, Debug)]
pub struct OutputRegister {
    /// Register width (bits, two's complement).
    pub bits: u32,
    /// Current accumulated value.
    pub value: i64,
}

impl OutputRegister {
    /// Zeroed register of the given width.
    pub fn new(bits: u32) -> OutputRegister {
        OutputRegister { bits, value: 0 }
    }

    /// Largest representable value.
    pub fn max(&self) -> i64 {
        (1i64 << (self.bits - 1)) - 1
    }

    /// Smallest representable value.
    pub fn min(&self) -> i64 {
        -(1i64 << (self.bits - 1))
    }

    /// Accumulate with saturation; returns the post-saturation value.
    pub fn accumulate(&mut self, x: i64) -> i64 {
        self.value = (self.value + x).clamp(self.min(), self.max());
        self.value
    }

    /// Clear the accumulator.
    pub fn reset(&mut self) {
        self.value = 0;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shift_add_4x4() {
        let s = BitSerialSchedule::default_4x4();
        // Partial per plane = 1.0 ⇒ result = 1+2+4+8 = 15.
        let partials = vec![vec![1.0]; 4];
        assert_eq!(shift_add(&s, &partials), 15.0);
    }

    #[test]
    fn shift_add_8bit_weights() {
        let s = BitSerialSchedule::new(2, 8);
        // plane 0: nibbles (low=3, high=1) ⇒ 3 + 16; plane 1: (0,0) ⇒ ×2 of 0.
        let partials = vec![vec![3.0, 1.0], vec![0.0, 0.0]];
        assert_eq!(shift_add(&s, &partials), 3.0 + 16.0);
    }

    #[test]
    fn bank_subtraction() {
        assert_eq!(subtract_banks(10.0, 4.0), 6.0);
        assert_eq!(subtract_banks(4.0, 10.0), -6.0);
    }

    #[test]
    fn register_saturates_both_ways() {
        let mut r = OutputRegister::new(8);
        assert_eq!(r.max(), 127);
        r.accumulate(100);
        assert_eq!(r.accumulate(100), 127);
        r.reset();
        r.accumulate(-200);
        assert_eq!(r.value, -128);
    }

    #[test]
    fn register_accumulates_exactly_in_range() {
        let mut r = OutputRegister::new(16);
        for _ in 0..100 {
            r.accumulate(10);
        }
        assert_eq!(r.value, 1000);
    }
}
