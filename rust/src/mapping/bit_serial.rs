//! Bit-serial multi-bit schedule (§IV-B).
//!
//! 4-bit inputs are streamed LSB→MSB (4 cycles); 4-bit weights occupy the
//! four bit-columns of a word and are WCC-combined in analog. Higher
//! precisions (Fig. 14d) extend this: extra input bits add bit-plane
//! cycles, extra weight bits add word columns ("multiple column outputs
//! can be shifted and added in the digital domain", §IV-C).

use crate::consts::{T_ADC_CONVERSION, WORD_BITS};

/// A multi-bit PIM schedule for one sub-array invocation.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct BitSerialSchedule {
    /// Input-activation precision (bits).
    pub act_bits: u32,
    /// Weight precision (bits).
    pub weight_bits: u32,
    /// Words consumed per logical output ("nibbles" per weight).
    pub weight_nibbles: u32,
    /// Total analog side-cycles (planes × sides × nibbles).
    pub side_cycles: u32,
    /// ADC conversions per word column.
    pub conversions_per_word: u32,
}

impl BitSerialSchedule {
    /// Schedule for the given activation/weight precisions.
    pub fn new(act_bits: u32, weight_bits: u32) -> BitSerialSchedule {
        assert!(act_bits >= 1 && weight_bits >= 1);
        let nibbles = weight_bits.div_ceil(WORD_BITS as u32);
        let side_cycles = act_bits * 2 * nibbles;
        BitSerialSchedule {
            act_bits,
            weight_bits,
            weight_nibbles: nibbles,
            side_cycles,
            conversions_per_word: side_cycles,
        }
    }

    /// The paper's default 4b×4b schedule.
    pub fn default_4x4() -> BitSerialSchedule {
        Self::new(4, 4)
    }

    /// Wall-clock latency (ADC-dominated, §V-D): side-cycles × 160 ns.
    pub fn latency(&self) -> f64 {
        self.side_cycles as f64 * T_ADC_CONVERSION
    }

    /// Digital shift amount for (act plane `a`, weight nibble `n`).
    pub fn shift_for(&self, a: u32, nibble: u32) -> u32 {
        debug_assert!(a < self.act_bits && nibble < self.weight_nibbles);
        a + nibble * WORD_BITS as u32
    }

    /// Effective logical ops per physical op, for 1-bit normalization
    /// (Table I note a: metrics normalize by input×weight precision).
    pub fn precision_product(&self) -> u32 {
        self.act_bits * self.weight_bits
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_default_latency_1280ns() {
        let s = BitSerialSchedule::default_4x4();
        assert_eq!(s.side_cycles, 8);
        assert!((s.latency() - 1280.0e-9).abs() < 1e-15);
    }

    #[test]
    fn eight_bit_weights_take_two_nibbles() {
        let s = BitSerialSchedule::new(8, 8);
        assert_eq!(s.weight_nibbles, 2);
        assert_eq!(s.side_cycles, 8 * 2 * 2);
        assert_eq!(s.precision_product(), 64);
    }

    #[test]
    fn shift_amounts() {
        let s = BitSerialSchedule::new(4, 8);
        assert_eq!(s.shift_for(0, 0), 0);
        assert_eq!(s.shift_for(3, 0), 3);
        assert_eq!(s.shift_for(0, 1), 4);
        assert_eq!(s.shift_for(3, 1), 7);
    }

    #[test]
    fn one_bit_minimum() {
        let s = BitSerialSchedule::new(1, 1);
        assert_eq!(s.side_cycles, 2); // both powerline sides still needed
    }
}
