//! Network placement: assign every conv/fc layer's weight tiles to
//! physical (bank, sub-array) slots across the cache (consumed by the
//! coordinator's scheduler).
//!
//! Positive and negative weight banks get separate sub-arrays (§IV-C), so
//! each logical tile occupies two physical arrays.

use super::conv_mapper::{ConvMapping, ConvShape};

/// One placed tile.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct TilePlacement {
    /// Owning layer index.
    pub layer: usize,
    /// Kernel-position submatrix index (ky*K + kx); 0 for FC.
    pub submatrix: usize,
    /// Row-block index over D.
    pub d_tile: usize,
    /// Word-block index over N.
    pub n_tile: usize,
    /// Physical slot for the positive bank.
    pub pos_slot: (usize, usize),
    /// Physical slot for the negative bank.
    pub neg_slot: (usize, usize),
}

/// The whole network's placement.
#[derive(Clone, Debug)]
pub struct NetworkLayout {
    /// Every placed tile.
    pub placements: Vec<TilePlacement>,
    /// Banks available.
    pub banks: usize,
    /// Sub-array slots per bank.
    pub subarrays_per_bank: usize,
    /// Slots consumed (2 per logical tile).
    pub slots_used: usize,
}

impl NetworkLayout {
    /// Round-robin placement of all layers' tiles over the available slots.
    /// Errors (None) if capacity is insufficient.
    pub fn place(
        layers: &[ConvShape],
        banks: usize,
        subarrays_per_bank: usize,
    ) -> Option<NetworkLayout> {
        Self::place_from(layers, banks, subarrays_per_bank, 0)
    }

    /// Like [`NetworkLayout::place`], but allocation starts at linear slot
    /// `start` (slot = bank·subarrays_per_bank + subarray). Lets several
    /// networks pack onto one physical slice without overlapping, and lets
    /// a wear-leveling placer rotate which banks a model lands on.
    /// `slots_used` counts only the slots this placement consumed.
    ///
    /// # Examples
    ///
    /// Pack two copies of a one-tile layer onto the same slice without
    /// overlap by starting the second placement at the first's end slot:
    ///
    /// ```
    /// use nvm_in_cache::mapping::{ConvShape, NetworkLayout};
    ///
    /// let layers = [ConvShape { k: 1, d: 64, n: 64, w: 8, stride: 1 }];
    /// let a = NetworkLayout::place_from(&layers, 8, 4, 0).unwrap();
    /// let b = NetworkLayout::place_from(&layers, 8, 4, a.next_slot()).unwrap();
    /// assert_eq!(a.slots_used, 2); // one logical tile = pos + neg slot
    /// assert_ne!(a.placements[0].pos_slot, b.placements[0].pos_slot);
    /// ```
    pub fn place_from(
        layers: &[ConvShape],
        banks: usize,
        subarrays_per_bank: usize,
        start: usize,
    ) -> Option<NetworkLayout> {
        let capacity = banks * subarrays_per_bank;
        let mut placements = Vec::new();
        let mut next = start;
        let alloc = |next: &mut usize| -> Option<(usize, usize)> {
            if *next >= capacity {
                return None;
            }
            let slot = (*next / subarrays_per_bank, *next % subarrays_per_bank);
            *next += 1;
            Some(slot)
        };
        for (li, shape) in layers.iter().enumerate() {
            let m = ConvMapping::plan(*shape);
            for sm in 0..m.submatrices {
                for dt in 0..m.d_tiles {
                    for nt in 0..m.n_tiles {
                        let pos = alloc(&mut next)?;
                        let neg = alloc(&mut next)?;
                        placements.push(TilePlacement {
                            layer: li,
                            submatrix: sm,
                            d_tile: dt,
                            n_tile: nt,
                            pos_slot: pos,
                            neg_slot: neg,
                        });
                    }
                }
            }
        }
        Some(NetworkLayout {
            placements,
            banks,
            subarrays_per_bank,
            slots_used: next - start,
        })
    }

    /// First linear slot *after* this placement (where a subsequent
    /// placement on the same slice may begin). Only meaningful right after
    /// [`NetworkLayout::place_from`]; `None` for an empty layout.
    ///
    /// Prefer [`NetworkLayout::next_slot`] when chaining placements — it
    /// handles the empty-layout edge without an `unwrap`.
    pub fn end_slot(&self) -> Option<usize> {
        self.placements
            .iter()
            .flat_map(|p| [p.pos_slot, p.neg_slot])
            .map(|(b, s)| b * self.subarrays_per_bank + s + 1)
            .max()
    }

    /// Non-`Option` sibling of [`NetworkLayout::end_slot`] for chained
    /// `place_from` calls (shard segments packing onto one slice): the
    /// first linear slot a subsequent placement may begin at, or `0` for
    /// an empty layout (an empty placement consumed nothing, so the whole
    /// slice is still free from slot 0).
    pub fn next_slot(&self) -> usize {
        self.end_slot().unwrap_or(0)
    }

    /// Tiles belonging to one layer.
    pub fn layer_tiles(&self, layer: usize) -> Vec<&TilePlacement> {
        self.placements.iter().filter(|p| p.layer == layer).collect()
    }

    /// Fraction of available slots used.
    pub fn occupancy(&self) -> f64 {
        self.slots_used as f64 / (self.banks * self.subarrays_per_bank) as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn small_net() -> Vec<ConvShape> {
        vec![
            ConvShape { k: 3, d: 16, n: 16, w: 16, stride: 1 },
            ConvShape { k: 3, d: 16, n: 32, w: 16, stride: 2 },
            ConvShape { k: 1, d: 32, n: 10, w: 1, stride: 1 }, // FC as 1×1
        ]
    }

    #[test]
    fn placement_covers_all_tiles() {
        let layers = small_net();
        let l = NetworkLayout::place(&layers, 80, 4).unwrap();
        // 9 + 9 + 1 = 19 logical tiles, ×2 banks.
        assert_eq!(l.placements.len(), 19);
        assert_eq!(l.slots_used, 38);
        assert_eq!(l.layer_tiles(0).len(), 9);
        assert_eq!(l.layer_tiles(2).len(), 1);
    }

    #[test]
    fn pos_neg_slots_distinct() {
        let l = NetworkLayout::place(&small_net(), 80, 4).unwrap();
        for p in &l.placements {
            assert_ne!(p.pos_slot, p.neg_slot);
        }
    }

    #[test]
    fn no_slot_double_booked() {
        let l = NetworkLayout::place(&small_net(), 80, 4).unwrap();
        let mut seen = std::collections::HashSet::new();
        for p in &l.placements {
            assert!(seen.insert(p.pos_slot));
            assert!(seen.insert(p.neg_slot));
        }
    }

    #[test]
    fn insufficient_capacity_rejected() {
        assert!(NetworkLayout::place(&small_net(), 2, 4).is_none());
    }

    #[test]
    fn occupancy_fraction() {
        let l = NetworkLayout::place(&small_net(), 80, 4).unwrap();
        assert!((l.occupancy() - 38.0 / 320.0).abs() < 1e-12);
    }

    #[test]
    fn offset_placement_disjoint_from_base() {
        let a = NetworkLayout::place(&small_net(), 80, 4).unwrap();
        let b = NetworkLayout::place_from(&small_net(), 80, 4, a.next_slot()).unwrap();
        assert_eq!(a.slots_used, b.slots_used);
        let mut seen = std::collections::HashSet::new();
        for p in a.placements.iter().chain(b.placements.iter()) {
            assert!(seen.insert(p.pos_slot));
            assert!(seen.insert(p.neg_slot));
        }
        assert_eq!(b.next_slot(), a.slots_used + b.slots_used);
    }

    #[test]
    fn empty_layout_next_slot_is_zero() {
        let l = NetworkLayout::place(&[], 80, 4).unwrap();
        assert_eq!(l.placements.len(), 0);
        assert_eq!(l.slots_used, 0);
        assert_eq!(l.end_slot(), None);
        assert_eq!(l.next_slot(), 0);
        // A non-empty layout agrees with end_slot().
        let a = NetworkLayout::place(&small_net(), 80, 4).unwrap();
        assert_eq!(a.next_slot(), a.end_slot().unwrap());
    }

    #[test]
    fn offset_placement_respects_capacity() {
        // 38 slots needed; starting at 320-10 leaves only 10.
        assert!(NetworkLayout::place_from(&small_net(), 80, 4, 310).is_none());
    }
}
