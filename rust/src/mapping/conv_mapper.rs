//! IFM-reuse convolution mapping (§IV-C, Fig. 7, after Peng et al. [33]).
//!
//! A K×K×D×N convolution is decomposed into K² weight submatrices of shape
//! [D, N] — one per kernel position. Each submatrix is tiled over 128×128
//! sub-arrays (`⌈D/128⌉ × ⌈N/128⌉` tiles). For each output pixel, the K²
//! kernel positions consume the corresponding input pixels (row vectors of
//! length D) and their partial sums accumulate digitally. Sliding by one
//! stride reuses K·(K−stride) of the K² input pixels — neighboring banks
//! forward them instead of refetching (the "IFM reuse" the paper adopts).

use crate::consts::{ARRAY_ROWS, ARRAY_WORDS};

/// Convolution layer shape (square input, 'same' padding).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ConvShape {
    /// Kernel size K.
    pub k: usize,
    /// Input depth D.
    pub d: usize,
    /// Output features N.
    pub n: usize,
    /// Input spatial width W (square).
    pub w: usize,
    /// Convolution stride.
    pub stride: usize,
}

impl ConvShape {
    /// Output spatial width under 'same' padding.
    pub fn output_width(&self) -> usize {
        // 'same' padding.
        self.w.div_ceil(self.stride)
    }

    /// MACs for the whole layer (out_pixels × K²·D·N).
    pub fn total_macs(&self) -> u64 {
        let ow = self.output_width() as u64;
        ow * ow * (self.k * self.k * self.d * self.n) as u64
    }
}

/// The physical mapping plan for one conv layer.
#[derive(Clone, Debug, PartialEq)]
pub struct ConvMapping {
    /// The layer being mapped.
    pub shape: ConvShape,
    /// K² kernel-position submatrices.
    pub submatrices: usize,
    /// Row-block tiles over D per submatrix.
    pub d_tiles: usize,
    /// Word-block tiles over N per submatrix.
    pub n_tiles: usize,
    /// Total 128×128 sub-arrays required.
    pub total_subarrays: usize,
    /// Row utilization of the last D tile (1.0 = full 128 rows).
    pub row_utilization: f64,
    /// Word utilization of the last N tile.
    pub word_utilization: f64,
}

impl ConvMapping {
    /// Plan the tiling of `shape` onto 128×128 sub-arrays.
    pub fn plan(shape: ConvShape) -> ConvMapping {
        let d_tiles = shape.d.div_ceil(ARRAY_ROWS);
        let n_tiles = shape.n.div_ceil(ARRAY_WORDS);
        let submatrices = shape.k * shape.k;
        let total = submatrices * d_tiles * n_tiles;
        let last_rows = shape.d - (d_tiles - 1) * ARRAY_ROWS;
        let last_words = shape.n - (n_tiles - 1) * ARRAY_WORDS;
        ConvMapping {
            shape,
            submatrices,
            d_tiles,
            n_tiles,
            total_subarrays: total,
            row_utilization: last_rows as f64 / ARRAY_ROWS as f64,
            word_utilization: last_words as f64 / ARRAY_WORDS as f64,
        }
    }

    /// Mean utilization of allocated cells (drives Fig. 14's efficiency
    /// scaling: bigger K/D/N fill the arrays better).
    pub fn mean_utilization(&self) -> f64 {
        let row_u =
            ((self.d_tiles - 1) as f64 + self.row_utilization) / self.d_tiles as f64;
        let word_u =
            ((self.n_tiles - 1) as f64 + self.word_utilization) / self.n_tiles as f64;
        row_u * word_u
    }

    /// Input pixels freshly fetched per output step (after IFM reuse):
    /// sliding by `stride` reuses K·(K−stride) of the K² window pixels.
    pub fn fresh_inputs_per_step(&self) -> usize {
        let k = self.shape.k;
        let s = self.shape.stride.min(k);
        k * s
    }

    /// Reuse factor: fraction of window inputs served by neighbor
    /// forwarding instead of refetch.
    pub fn reuse_fraction(&self) -> f64 {
        let k2 = (self.shape.k * self.shape.k) as f64;
        1.0 - self.fresh_inputs_per_step() as f64 / k2
    }

    /// Full-array MAC invocations to produce the whole output feature map
    /// (each invocation covers all N word columns of one tile for one
    /// output pixel's one kernel position).
    pub fn mac_invocations(&self) -> u64 {
        let ow = self.shape.output_width() as u64;
        ow * ow * (self.submatrices * self.d_tiles * self.n_tiles) as u64
    }

    /// Data-independent execution units one batched im2col run of this
    /// layer fans out to — the (output row × 128-row block × 128-word
    /// output tile) grid `PimEngine::par_matmul` schedules over the
    /// [`crate::pim::parallel`] worker pool. `m_rows` is the im2col row
    /// count (batch × output pixels); the im2col reduction dimension is
    /// D·K², so its row blocks fold the K² submatrices and the D tiles of
    /// this plan into one axis. The units are only joined by the digital
    /// shift-add reduce, which is what makes row-parallel execution both
    /// legal and bit-exact (PERFORMANCE.md). Delegates to
    /// [`crate::pim::PimEngine::unit_count`], the grid's single owner.
    pub fn engine_units(&self, m_rows: usize) -> usize {
        let k_im2col = self.shape.d * self.shape.k * self.shape.k;
        crate::pim::PimEngine::unit_count(m_rows, k_im2col, self.shape.n)
    }

    /// For output pixel (oy, ox) and kernel position (ky, kx), the input
    /// pixel coordinate that feeds the submatrix — None if padding.
    pub fn input_coord(
        &self,
        oy: usize,
        ox: usize,
        ky: usize,
        kx: usize,
    ) -> Option<(usize, usize)> {
        let k = self.shape.k as isize;
        let pad = (k - 1) / 2;
        let iy = oy as isize * self.shape.stride as isize + ky as isize - pad;
        let ix = ox as isize * self.shape.stride as isize + kx as isize - pad;
        if iy < 0 || ix < 0 || iy >= self.shape.w as isize || ix >= self.shape.w as isize {
            None
        } else {
            Some((iy as usize, ix as usize))
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn shape3x3() -> ConvShape {
        ConvShape { k: 3, d: 64, n: 128, w: 16, stride: 1 }
    }

    #[test]
    fn plan_counts_tiles() {
        let m = ConvMapping::plan(shape3x3());
        assert_eq!(m.submatrices, 9);
        assert_eq!(m.d_tiles, 1);
        assert_eq!(m.n_tiles, 1);
        assert_eq!(m.total_subarrays, 9);
        assert!((m.row_utilization - 0.5).abs() < 1e-12);
        assert!((m.word_utilization - 1.0).abs() < 1e-12);
    }

    #[test]
    fn large_depth_splits_rows() {
        let m = ConvMapping::plan(ConvShape { k: 3, d: 300, n: 64, w: 8, stride: 1 });
        assert_eq!(m.d_tiles, 3);
        assert!((m.row_utilization - 44.0 / 128.0).abs() < 1e-12);
    }

    #[test]
    fn utilization_improves_with_depth() {
        // Fig. 14(b): larger D ⇒ better utilization ⇒ better efficiency.
        let u32_ = ConvMapping::plan(ConvShape { k: 3, d: 32, n: 128, w: 8, stride: 1 })
            .mean_utilization();
        let u128 = ConvMapping::plan(ConvShape { k: 3, d: 128, n: 128, w: 8, stride: 1 })
            .mean_utilization();
        assert!(u128 > u32_, "{u128} !> {u32_}");
    }

    #[test]
    fn ifm_reuse_fraction() {
        let m = ConvMapping::plan(shape3x3());
        // stride 1, K=3: fresh 3 of 9 ⇒ 2/3 reused.
        assert_eq!(m.fresh_inputs_per_step(), 3);
        assert!((m.reuse_fraction() - 2.0 / 3.0).abs() < 1e-12);
        let m2 = ConvMapping::plan(ConvShape { k: 3, d: 64, n: 64, w: 16, stride: 3 });
        assert_eq!(m2.reuse_fraction(), 0.0, "stride = K ⇒ no reuse");
    }

    #[test]
    fn same_padding_coords() {
        let m = ConvMapping::plan(shape3x3());
        // Center kernel position maps output (0,0) to input (0,0).
        assert_eq!(m.input_coord(0, 0, 1, 1), Some((0, 0)));
        // Top-left kernel position at output (0,0) reads padding.
        assert_eq!(m.input_coord(0, 0, 0, 0), None);
        // Interior is in-bounds.
        assert_eq!(m.input_coord(5, 5, 0, 0), Some((4, 4)));
    }

    #[test]
    fn output_width_same_padding() {
        assert_eq!(ConvShape { k: 3, d: 1, n: 1, w: 16, stride: 1 }.output_width(), 16);
        assert_eq!(ConvShape { k: 3, d: 1, n: 1, w: 16, stride: 2 }.output_width(), 8);
        assert_eq!(ConvShape { k: 3, d: 1, n: 1, w: 15, stride: 2 }.output_width(), 8);
    }

    #[test]
    fn engine_units_cover_the_layer() {
        // 3×3×64 kernel → im2col k = 576 = 4.5 blocks → 5; n = 128 → 1
        // tile; 10 im2col rows ⇒ 50 independent units for the pool.
        let m = ConvMapping::plan(shape3x3());
        assert_eq!(m.engine_units(10), 10 * 5);
        // Wider outputs add tiles: n = 130 spans 2 output tiles.
        let wide = ConvMapping::plan(ConvShape { k: 1, d: 64, n: 130, w: 8, stride: 1 });
        assert_eq!(wide.engine_units(4), 4 * 1 * 2);
    }

    #[test]
    fn total_macs() {
        let s = ConvShape { k: 3, d: 16, n: 32, w: 8, stride: 1 };
        assert_eq!(s.total_macs(), 64 * (9 * 16 * 32) as u64);
    }
}
