//! The serving coordinator (L3): request router, dynamic batcher, bank
//! scheduler, metrics, and the threaded server loop.
//!
//! The NVM-in-Cache deployment story: inference requests arrive from cores;
//! the coordinator batches them, schedules their layer MACs onto the LLC's
//! PIM-capable banks (weights resident in the RRAM layer, cache data
//! retained), executes the model forward — through any
//! [`crate::runtime::Runtime`] backend or the native engine — and accounts
//! hardware-simulated latency/energy alongside real wall-clock.
//!
//! Offline build ⇒ std::thread + mpsc rather than tokio.

pub mod batcher;
pub mod frontdoor;
pub mod metrics;
pub mod request;
pub mod router;
pub mod scheduler;
pub mod server;

pub use batcher::{Batch, BatchMode, Batcher, BatcherConfig};
pub use frontdoor::{
    ArrivalProcess, Discipline, FrontDoor, FrontDoorConfig, OverloadPolicy, SweepReport,
};
pub use metrics::Metrics;
pub use request::{InferenceRequest, InferenceResponse};
pub use router::Router;
pub use scheduler::BankScheduler;
pub use server::{Executor, FinishedGroup, NativeExecutor, RuntimeExecutor, Server, ServerConfig};
