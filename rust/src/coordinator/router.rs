//! Request router: distributes requests across model replicas.
//!
//! With the network weights resident in multiple LLC slices (one replica
//! per slice), the router picks the least-loaded replica — the same
//! shape as a vLLM-style router, scaled to the in-cache setting.

/// One replica's load state.
#[derive(Clone, Debug, Default)]
pub struct ReplicaState {
    /// Batches currently executing.
    pub inflight: usize,
    /// Batches completed.
    pub served: u64,
    /// Simulated busy-until (s, scheduler clock).
    pub busy_until: f64,
}

/// Least-loaded router.
pub struct Router {
    /// Replica states, indexed by replica id.
    pub replicas: Vec<ReplicaState>,
}

impl Router {
    /// Router over `n_replicas` idle replicas.
    pub fn new(n_replicas: usize) -> Router {
        assert!(n_replicas > 0);
        Router { replicas: vec![ReplicaState::default(); n_replicas] }
    }

    /// Choose a replica for the next batch: min inflight, ties by
    /// earliest busy_until, then by index (deterministic).
    ///
    /// `total_cmp` on `busy_until`: a NaN-poisoned replica (e.g. a cost
    /// model dividing by a zero batch) sorts *after* every finite value
    /// and is simply never preferred — the old `partial_cmp().unwrap()`
    /// panicked the serving thread instead.
    pub fn route(&mut self) -> usize {
        let idx = (0..self.replicas.len())
            .min_by(|&a, &b| {
                let ra = &self.replicas[a];
                let rb = &self.replicas[b];
                ra.inflight
                    .cmp(&rb.inflight)
                    .then(ra.busy_until.total_cmp(&rb.busy_until))
                    .then(a.cmp(&b))
            })
            .unwrap();
        self.replicas[idx].inflight += 1;
        idx
    }

    /// Mark a batch complete on a replica.
    pub fn complete(&mut self, idx: usize, hw_latency: f64) {
        let r = &mut self.replicas[idx];
        r.inflight = r.inflight.saturating_sub(1);
        r.served += 1;
        r.busy_until += hw_latency;
    }

    /// Total served across replicas.
    pub fn total_served(&self) -> u64 {
        self.replicas.iter().map(|r| r.served).sum()
    }

    /// Load imbalance: max/min served ratio (1.0 = perfectly balanced).
    pub fn imbalance(&self) -> f64 {
        let max = self.replicas.iter().map(|r| r.served).max().unwrap_or(0);
        let min = self.replicas.iter().map(|r| r.served).min().unwrap_or(0);
        if min == 0 {
            if max == 0 {
                1.0
            } else {
                f64::INFINITY
            }
        } else {
            max as f64 / min as f64
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn round_robins_when_symmetric() {
        let mut r = Router::new(3);
        let a = r.route();
        let b = r.route();
        let c = r.route();
        let mut seen = vec![a, b, c];
        seen.sort();
        assert_eq!(seen, vec![0, 1, 2]);
    }

    #[test]
    fn prefers_idle_replica() {
        let mut r = Router::new(2);
        let first = r.route(); // 0 busy now
        let second = r.route();
        assert_ne!(first, second);
        r.complete(first, 1.0);
        // first has served 1 and is free; second still inflight.
        assert_eq!(r.route(), first);
    }

    #[test]
    fn nan_poisoned_replica_never_panics_and_loses_ties() {
        // Regression: a replica whose busy_until went NaN used to panic
        // the `partial_cmp().unwrap()` in route(). Under total_cmp a
        // positive NaN orders after every finite busy_until, so routing
        // keeps working and prefers the healthy replicas.
        let mut r = Router::new(3);
        r.replicas[1].busy_until = f64::NAN;
        for _ in 0..6 {
            let idx = r.route();
            r.complete(idx, 0.001);
        }
        assert_eq!(r.total_served(), 6);
        // All replicas have equal inflight at each route() call, so the
        // busy_until tie-break applies: the NaN replica only gets picked
        // once the healthy replicas carry more inflight — with
        // route-then-complete it never does.
        assert_eq!(r.replicas[1].served, 0, "NaN replica must lose ties");
    }

    #[test]
    fn balances_over_many_batches() {
        let mut r = Router::new(4);
        for _ in 0..400 {
            let idx = r.route();
            r.complete(idx, 0.001);
        }
        assert_eq!(r.total_served(), 400);
        assert!(r.imbalance() < 1.05, "imbalance = {}", r.imbalance());
    }
}
