//! Bank scheduler: places the network on the cache's PIM-capable banks and
//! computes, per batch, the simulated hardware execution cost (latency,
//! energy, ops) using the mapping + perf models, while arbitrating PIM
//! windows against background cache traffic.

use crate::cache::addr::Geometry;
use crate::cache::controller::{CacheController, PimIntegration};
use crate::consts::WORD_BITS;
use crate::mapping::bit_serial::BitSerialSchedule;
use crate::mapping::conv_mapper::{ConvMapping, ConvShape};
use crate::mapping::layout::NetworkLayout;
use crate::perf::model::MacroModel;

/// Per-batch simulated execution cost.
#[derive(Clone, Copy, Debug, Default)]
pub struct ExecutionCost {
    /// MAC ops (2 ops per MAC).
    pub ops: f64,
    /// Simulated wall-clock on the hardware (s) — layers serial, tiles of
    /// one layer parallel, images pipelined through the ADC windows.
    pub latency_s: f64,
    /// Simulated energy (J).
    pub energy_j: f64,
    /// Cache lines moved for PIM (0 in retained mode after programming).
    pub lines_moved: u64,
}

/// The scheduler.
pub struct BankScheduler {
    /// Network layers in execution order.
    pub layers: Vec<ConvShape>,
    /// Physical tile placement.
    pub layout: NetworkLayout,
    /// The cache being arbitrated.
    pub controller: CacheController,
    /// Analytic cost model.
    pub model: MacroModel,
    /// Weights programmed into the arrays?
    pub programmed: bool,
}

impl BankScheduler {
    /// Place `layers` onto a cache with the given geometry/mode.
    pub fn new(
        layers: Vec<ConvShape>,
        geom: Geometry,
        mode: PimIntegration,
    ) -> Option<BankScheduler> {
        let layout =
            NetworkLayout::place(&layers, geom.banks_per_slice, geom.subarrays_per_bank)?;
        Some(BankScheduler {
            layers,
            layout,
            controller: CacheController::new(geom, mode),
            model: MacroModel::default(),
            programmed: false,
        })
    }

    /// The ResNet-18-topology layer list used by the e2e example
    /// (16×16 input, width 16; FC folded as a 1×1 conv).
    pub fn resnet18_layers(width: usize) -> Vec<ConvShape> {
        let mut layers = vec![ConvShape { k: 3, d: 3, n: width, w: 16, stride: 1 }];
        let mut cin = width;
        let mut spatial = 16;
        for s in 0..4usize {
            let cout = width << s;
            let stride = if s == 0 { 1 } else { 2 };
            for b in 0..2usize {
                let st = if b == 0 { stride } else { 1 };
                layers.push(ConvShape { k: 3, d: cin, n: cout, w: spatial, stride: st });
                if st != 1 {
                    spatial = spatial.div_ceil(2);
                }
                layers.push(ConvShape { k: 3, d: cout, n: cout, w: spatial, stride: 1 });
                if st != 1 || cin != cout {
                    layers.push(ConvShape { k: 1, d: cin, n: cout, w: spatial, stride: 1 });
                }
                cin = cout;
            }
        }
        layers.push(ConvShape { k: 1, d: cin, n: 10, w: 1, stride: 1 }); // FC
        layers
    }

    /// The transformer-block layer-cost profile: the weight-stationary
    /// matmuls of [`crate::nn::transformer::TfmConfig`]-shaped encoder
    /// blocks (fused QKV, attention output projection, and the 2-layer
    /// FFN with `d_ff = 2·d_model`, per block, then the pooled
    /// classifier head), folded as 1×1 convs the conv mapper places
    /// like any FC layer. The 16-token sequence is framed 4×4 so each
    /// token is one output pixel — 16 bit-serial invocation chains per
    /// sequence, matching `ow²` in [`Self::layer_costs`].
    ///
    /// The two dynamic attention matmuls (Q·Kᵀ, A·V) are deliberately
    /// absent: they have no stationary operand, execute digitally in
    /// every mode ([`crate::pim::attn`]), and therefore occupy no banks
    /// and pay no bit-serial windows.
    pub fn transformer_layers(d_model: usize, n_blocks: usize) -> Vec<ConvShape> {
        let d_ff = 2 * d_model;
        let mut layers = Vec::with_capacity(4 * n_blocks + 1);
        for _ in 0..n_blocks {
            layers.push(ConvShape { k: 1, d: d_model, n: 3 * d_model, w: 4, stride: 1 }); // QKV
            layers.push(ConvShape { k: 1, d: d_model, n: d_model, w: 4, stride: 1 }); // Wo
            layers.push(ConvShape { k: 1, d: d_model, n: d_ff, w: 4, stride: 1 }); // FF1
            layers.push(ConvShape { k: 1, d: d_ff, n: d_model, w: 4, stride: 1 }); // FF2
        }
        layers.push(ConvShape { k: 1, d: d_model, n: 10, w: 1, stride: 1 }); // head
        layers
    }

    /// Program all layer weights into their assigned arrays (one-time cost;
    /// destructive to resident cache data — metered by the controller).
    pub fn program_network(&mut self) -> f64 {
        let mut total_latency = 0.0;
        let placements: Vec<_> = self.layout.placements.clone();
        for p in &placements {
            for slot in [p.pos_slot, p.neg_slot] {
                let stats = self.controller.program_campaign(
                    slot.0,
                    slot.1,
                    vec![0u8; crate::consts::ARRAY_ROWS * crate::consts::ARRAY_WORDS],
                );
                total_latency += stats.latency;
            }
        }
        self.programmed = true;
        total_latency
    }

    /// Per-layer simulated execution cost of `batch` images, in network
    /// order, with no cache-arbitration side effects.
    ///
    /// Each layer has its *own* weight-stationary arrays, so these are
    /// the tandem pipeline-stage service times the continuous-batching
    /// front door ([`crate::coordinator::frontdoor`]) schedules against:
    /// while one wave occupies layer *j*, the arrays of every other layer
    /// are idle and can serve a later wave. [`Self::batch_cost`] is
    /// exactly the sum of these stages (layers serial on one request).
    pub fn layer_costs(&self, batch: usize) -> Vec<ExecutionCost> {
        assert!(self.programmed, "program_network() first");
        let sched = BitSerialSchedule::new(self.model.act_bits, self.model.weight_bits);
        self.layers
            .iter()
            .map(|&shape| {
                let m = ConvMapping::plan(shape);
                let ow = shape.output_width();
                // Per image: ow² output pixels; per pixel one invocation per
                // (submatrix-position) chain — tiles run in parallel so the
                // pixel latency is one schedule; pixels stream back-to-back
                // (pipelined through the ADC windows).
                let invocations_serial = (batch * ow * ow) as f64;
                let lat = invocations_serial * sched.latency();
                // Ops actually computed (×2 for pos/neg banks at equal time —
                // both banks convert in parallel on different arrays).
                let ops = 2.0 * shape.total_macs() as f64 * batch as f64;
                // Energy: every (tile × pixel × side-cycle) step pays the step
                // energy on both banks, scaled by row utilization.
                let tiles = m.submatrices * m.d_tiles * m.n_tiles;
                let rows_mean = (m.mean_utilization() * 128.0).max(1.0) as usize;
                let e_step = self.model.step_energy(rows_mean);
                let energy = invocations_serial
                    * tiles as f64
                    * 2.0 // pos + neg banks
                    * sched.side_cycles as f64
                    * e_step;
                ExecutionCost { ops, latency_s: lat, energy_j: energy, lines_moved: 0 }
            })
            .collect()
    }

    /// Simulated hardware cost of running `batch` images through the whole
    /// network. Layers execute serially; a layer's tiles run in parallel;
    /// each output pixel of each image is one bit-serial invocation chain.
    pub fn batch_cost(&mut self, batch: usize) -> ExecutionCost {
        let per_layer = self.layer_costs(batch);
        let mut cost = ExecutionCost::default();
        for (shape, lc) in self.layers.clone().into_iter().zip(per_layer) {
            cost.ops += lc.ops;
            cost.latency_s += lc.latency_s;
            cost.energy_j += lc.energy_j;
            // Reserve the placed arrays for the window (cache arbitration).
            for p in self.layout.layer_tiles(self.layers.iter().position(|l| *l == shape).unwrap()) {
                self.controller.slice.banks[p.pos_slot.0].reserve(p.pos_slot.1, 0.0, lc.latency_s);
                self.controller.slice.banks[p.neg_slot.0].reserve(p.neg_slot.1, 0.0, lc.latency_s);
            }
        }
        // Flush/reload mode pays line movement per campaign (per batch).
        if self.controller.mode == PimIntegration::FlushReload {
            let per_array = 2 * crate::consts::ARRAY_ROWS as u64;
            let arrays = self.layout.slots_used as u64;
            cost.lines_moved = per_array * arrays;
            let (t, e) = crate::cell::timing::OpKind::CacheLineMove.cost();
            cost.latency_s += cost.lines_moved as f64 * t;
            cost.energy_j += cost.lines_moved as f64 * e;
        }
        cost
    }

    /// Total weight storage bits resident in RRAM.
    pub fn weight_bits_resident(&self) -> u64 {
        self.layout.slots_used as u64
            * (crate::consts::ARRAY_ROWS * crate::consts::ARRAY_WORDS * WORD_BITS) as u64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sched(mode: PimIntegration) -> BankScheduler {
        BankScheduler::new(
            BankScheduler::resnet18_layers(16),
            Geometry::default(),
            mode,
        )
        .expect("default LLC slice must fit the width-16 network")
    }

    #[test]
    fn resnet_layers_fit_default_slice() {
        let s = sched(PimIntegration::Retained);
        assert!(s.layout.occupancy() <= 1.0);
        assert!(s.layout.placements.len() > 20, "ResNet-18 has many tiles");
    }

    #[test]
    fn transformer_layers_fit_and_cost() {
        let layers = BankScheduler::transformer_layers(64, 2);
        assert_eq!(layers.len(), 4 * 2 + 1);
        let mut s = BankScheduler::new(layers, Geometry::default(), PimIntegration::Retained)
            .expect("default LLC slice must fit the tiny transformer");
        assert!(s.layout.occupancy() <= 1.0);
        s.program_network();
        let per_layer = s.layer_costs(1);
        assert!(per_layer.iter().all(|c| c.latency_s > 0.0 && c.energy_j > 0.0));
        // QKV is the widest matmul of a block, so it must dominate the
        // block's per-stage cost profile.
        assert!(per_layer[0].ops > per_layer[1].ops);
        // The wider geometry costs strictly more per sequence.
        let mut b = BankScheduler::new(
            BankScheduler::transformer_layers(128, 2),
            Geometry::default(),
            PimIntegration::Retained,
        )
        .expect("default LLC slice must fit the base transformer");
        b.program_network();
        assert!(b.batch_cost(1).ops > s.batch_cost(1).ops);
    }

    #[test]
    fn batch_cost_scales_linearly() {
        let mut s = sched(PimIntegration::Retained);
        s.program_network();
        let c1 = s.batch_cost(1);
        let c4 = s.batch_cost(4);
        assert!((c4.ops / c1.ops - 4.0).abs() < 1e-9);
        assert!((c4.latency_s / c1.latency_s - 4.0).abs() < 0.01);
        assert_eq!(c1.lines_moved, 0, "retained mode moves nothing");
    }

    #[test]
    fn flush_reload_pays_movement() {
        let mut a = sched(PimIntegration::Retained);
        let mut b = sched(PimIntegration::FlushReload);
        a.program_network();
        b.program_network();
        let ca = a.batch_cost(1);
        let cb = b.batch_cost(1);
        assert!(cb.lines_moved > 0);
        assert!(cb.latency_s > ca.latency_s);
        assert!(cb.energy_j > ca.energy_j);
    }

    #[test]
    fn programming_required_before_execution() {
        let mut s = sched(PimIntegration::Retained);
        let t = s.program_network();
        assert!(t > 0.0);
        assert!(s.programmed);
    }

    #[test]
    fn layer_costs_sum_to_batch_cost() {
        let mut s = sched(PimIntegration::Retained);
        s.program_network();
        let per_layer = s.layer_costs(3);
        assert_eq!(per_layer.len(), s.layers.len());
        let total = s.batch_cost(3);
        let sum_lat: f64 = per_layer.iter().map(|c| c.latency_s).sum();
        let sum_ops: f64 = per_layer.iter().map(|c| c.ops).sum();
        assert_eq!(sum_lat, total.latency_s, "stage sum must equal the serial cost");
        assert_eq!(sum_ops, total.ops);
        // The pipeline's bottleneck stage is what continuous batching
        // pays per admitted wave — strictly less than the serial total.
        let max_lat = per_layer.iter().map(|c| c.latency_s).fold(0.0, f64::max);
        assert!(max_lat < 0.5 * total.latency_s, "no single stage dominates");
    }

    #[test]
    fn efficiency_in_plausible_band() {
        // The end-to-end simulated efficiency should be within an order of
        // magnitude of the macro headline (utilization drags it down).
        let mut s = sched(PimIntegration::Retained);
        s.program_network();
        let c = s.batch_cost(8);
        let tops_w = c.ops / c.energy_j / 1e12;
        assert!(tops_w > 1.0 && tops_w < 40.0, "TOPS/W = {tops_w}");
    }
}
