//! Dynamic batcher: size-or-deadline and continuous (iteration-level)
//! batching of inference requests.
//!
//! Classic serving tradeoff: larger batches amortize the per-invocation
//! PIM pipeline (the 1280 ns windows are independent of how many requests
//! share the weight-resident arrays), smaller deadlines bound tail
//! latency. [`BatchMode::Continuous`] sidesteps the tradeoff: requests
//! merge into the in-flight execution at its next layer boundary
//! ([`Batcher::take_merge`]) instead of waiting for the batch to drain.
//! Pure data structure — the server thread drives the clock, so
//! everything is unit-testable without sleeping.

use std::collections::VecDeque;
use std::time::{Duration, Instant};

use super::request::InferenceRequest;

/// How batches are formed.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum BatchMode {
    /// Classic drain batching: hold requests until the batch fills or the
    /// oldest request hits `max_wait`, then execute the whole batch to
    /// completion before the next one forms.
    #[default]
    SizeOrDeadline,
    /// Continuous (iteration-level) batching: requests never wait for
    /// formation — whenever the in-flight execution reaches a layer
    /// boundary with spare capacity, a merge group is cut immediately
    /// ([`Batcher::take_merge`]) and joins the run. `max_wait` survives
    /// only as a starvation bound when capacity is exhausted.
    Continuous,
}

/// Batching policy.
#[derive(Clone, Copy, Debug)]
pub struct BatcherConfig {
    /// Preferred (maximum) batch size. In continuous mode this caps the
    /// total requests co-resident in the in-flight execution.
    pub max_batch: usize,
    /// Max time the oldest request may wait before forcing a flush.
    pub max_wait: Duration,
    /// Formation discipline.
    pub mode: BatchMode,
}

impl Default for BatcherConfig {
    fn default() -> Self {
        BatcherConfig {
            max_batch: 50,
            max_wait: Duration::from_millis(5),
            mode: BatchMode::SizeOrDeadline,
        }
    }
}

impl BatcherConfig {
    /// Size-or-deadline (drain) policy.
    pub fn sized(max_batch: usize, max_wait: Duration) -> BatcherConfig {
        BatcherConfig { max_batch, max_wait, mode: BatchMode::SizeOrDeadline }
    }

    /// Continuous (iteration-level) policy.
    pub fn continuous(max_batch: usize, max_wait: Duration) -> BatcherConfig {
        BatcherConfig { max_batch, max_wait, mode: BatchMode::Continuous }
    }
}

/// A formed batch.
#[derive(Debug)]
pub struct Batch {
    /// The batched requests, FIFO order.
    pub requests: Vec<InferenceRequest>,
    /// When the batch was cut.
    pub formed_at: Instant,
}

impl Batch {
    /// Number of requests in the batch.
    pub fn len(&self) -> usize {
        self.requests.len()
    }

    /// Whether the batch is empty.
    pub fn is_empty(&self) -> bool {
        self.requests.is_empty()
    }
}

/// The batcher queue.
pub struct Batcher {
    /// Batching policy.
    pub config: BatcherConfig,
    queue: VecDeque<InferenceRequest>,
}

impl Batcher {
    /// Empty batcher with the given policy.
    pub fn new(config: BatcherConfig) -> Batcher {
        Batcher { config, queue: VecDeque::new() }
    }

    /// Enqueue a request.
    pub fn push(&mut self, req: InferenceRequest) {
        self.queue.push_back(req);
    }

    /// Requests waiting to be batched.
    pub fn pending(&self) -> usize {
        self.queue.len()
    }

    /// Oldest request's wait time as of `now`.
    pub fn oldest_wait(&self, now: Instant) -> Option<Duration> {
        self.queue.front().map(|r| now.duration_since(r.enqueued))
    }

    /// Should a batch be cut right now?
    pub fn ready(&self, now: Instant) -> bool {
        self.queue.len() >= self.config.max_batch
            || self
                .oldest_wait(now)
                .map(|w| w >= self.config.max_wait && !self.queue.is_empty())
                .unwrap_or(false)
    }

    /// Cut a batch if policy says so (or `force` to drain).
    pub fn take(&mut self, now: Instant, force: bool) -> Option<Batch> {
        if self.queue.is_empty() || (!force && !self.ready(now)) {
            return None;
        }
        let n = self.queue.len().min(self.config.max_batch);
        let requests = self.queue.drain(..n).collect();
        Some(Batch { requests, formed_at: now })
    }

    /// Continuous-mode cut: a merge group of up to `room` requests
    /// (further capped by `max_batch`), taken from the queue front so
    /// global — and therefore per-tenant — FIFO order is preserved.
    ///
    /// Unlike [`Self::take`], no formation wait applies: the in-flight
    /// execution just reached a layer boundary with `room` spare slots,
    /// and holding requests back would only add latency (the weight-
    /// stationary arrays idle either way). Returns `None` when the queue
    /// is empty or `room == 0` — the latter is the only way a request
    /// waits in continuous mode, bounded by the capacity freed at the
    /// next boundary.
    pub fn take_merge(&mut self, now: Instant, room: usize) -> Option<Batch> {
        let n = self.queue.len().min(self.config.max_batch).min(room);
        if n == 0 {
            return None;
        }
        let requests = self.queue.drain(..n).collect();
        Some(Batch { requests, formed_at: now })
    }

    /// Time until the deadline of the oldest request (for the server's
    /// poll timeout). None when the queue is empty.
    pub fn next_deadline(&self, now: Instant) -> Option<Duration> {
        self.oldest_wait(now)
            .map(|w| self.config.max_wait.saturating_sub(w))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn req(id: u64) -> InferenceRequest {
        InferenceRequest::new(id, vec![0.0; 4])
    }

    #[test]
    fn cuts_at_max_batch() {
        let mut b = Batcher::new(BatcherConfig::sized(3, Duration::from_secs(10)));
        let now = Instant::now();
        b.push(req(1));
        b.push(req(2));
        assert!(b.take(now, false).is_none());
        b.push(req(3));
        let batch = b.take(now, false).unwrap();
        assert_eq!(batch.len(), 3);
        assert_eq!(b.pending(), 0);
    }

    #[test]
    fn cuts_at_deadline() {
        let mut b = Batcher::new(BatcherConfig::sized(100, Duration::from_millis(1)));
        b.push(req(1));
        let later = Instant::now() + Duration::from_millis(5);
        assert!(b.ready(later));
        let batch = b.take(later, false).unwrap();
        assert_eq!(batch.len(), 1);
    }

    #[test]
    fn force_drains_partial() {
        let mut b = Batcher::new(BatcherConfig::default());
        b.push(req(1));
        b.push(req(2));
        let batch = b.take(Instant::now(), true).unwrap();
        assert_eq!(batch.len(), 2);
    }

    #[test]
    fn oversize_queue_cuts_in_chunks() {
        let mut b = Batcher::new(BatcherConfig::sized(2, Duration::ZERO));
        for i in 0..5 {
            b.push(req(i));
        }
        let now = Instant::now();
        assert_eq!(b.take(now, false).unwrap().len(), 2);
        assert_eq!(b.take(now, false).unwrap().len(), 2);
        assert_eq!(b.take(now, true).unwrap().len(), 1);
        assert!(b.take(now, true).is_none());
    }

    #[test]
    fn take_merge_respects_room_and_max_batch() {
        let mut b =
            Batcher::new(BatcherConfig::continuous(3, Duration::from_millis(5)));
        for i in 0..10 {
            b.push(req(i));
        }
        let now = Instant::now();
        // room below max_batch wins …
        assert_eq!(b.take_merge(now, 2).unwrap().len(), 2);
        // … max_batch caps a generous room …
        assert_eq!(b.take_merge(now, 100).unwrap().len(), 3);
        // … zero room never cuts.
        assert!(b.take_merge(now, 0).is_none());
        assert_eq!(b.pending(), 5);
    }

    #[test]
    fn take_merge_cuts_immediately_without_formation_wait() {
        // Continuous mode must not hold a lone request for max_wait.
        let mut b =
            Batcher::new(BatcherConfig::continuous(8, Duration::from_secs(10)));
        b.push(req(1));
        let now = Instant::now();
        assert!(!b.ready(now), "size-or-deadline criteria are not met …");
        let cut = b.take_merge(now, 8).unwrap();
        assert_eq!(cut.len(), 1, "… but the merge cut happens anyway");
    }

    #[test]
    fn preserves_fifo_order() {
        let mut b = Batcher::new(BatcherConfig::sized(3, Duration::ZERO));
        for i in 0..3 {
            b.push(req(i));
        }
        let ids: Vec<u64> = b
            .take(Instant::now(), false)
            .unwrap()
            .requests
            .iter()
            .map(|r| r.id)
            .collect();
        assert_eq!(ids, vec![0, 1, 2]);
    }
}
