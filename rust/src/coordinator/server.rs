//! The server loop: ingest thread → dynamic batcher → executor →
//! responses, with metrics and simulated-hardware accounting.
//!
//! std::thread + mpsc (offline build; no tokio). One executor thread — the
//! testbed has one core, and runtime backends (e.g. PJRT executables) need
//! not be Sync — with the batcher amortizing per-invocation cost exactly
//! like the hardware's shared PIM windows do. The executor's matmuls fan
//! out on the persistent `pim::parallel` pool, so steady-state serving
//! spawns zero threads per batch (PERFORMANCE.md §12).

use std::sync::mpsc;
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use crate::Result;

use super::batcher::{BatchMode, Batcher, BatcherConfig};
use super::metrics::Metrics;
use super::request::{InferenceRequest, InferenceResponse};
use super::scheduler::BankScheduler;

/// A merge group that completed at a layer boundary of a stepped
/// ([`BatchMode::Continuous`]) execution.
#[derive(Clone, Debug)]
pub struct FinishedGroup {
    /// Group handle returned by [`Executor::begin_group`].
    pub group: u64,
    /// Predicted classes, one per image in the group.
    pub preds: Vec<u8>,
}

/// Pluggable inference backend.
///
/// Not `Send`: runtime handles (PJRT in particular) are thread-affine, so
/// the server constructs its executor *inside* the worker thread from a
/// `Send` factory.
pub trait Executor {
    /// Classify `n` images (flattened, n × image_elems). Returns `n`
    /// predicted classes.
    fn classify(&mut self, images: &[f32], n: usize) -> Result<Vec<u8>>;
    /// Elements per image (h·w·c).
    fn image_elems(&self) -> usize;

    /// Open an in-flight merge group of `n` images for continuous
    /// batching. Returns a group handle, or `None` when this executor
    /// cannot execute iteration-level (fixed-batch runtime backends keep
    /// the default) — the server then degrades that group to classic
    /// drain execution.
    fn begin_group(&mut self, _images: &[f32], _n: usize) -> Result<Option<u64>> {
        Ok(None)
    }

    /// Advance every in-flight group one layer boundary and return the
    /// groups that completed at it. New groups admitted between calls
    /// join the pipeline at the *next* boundary — that is the merge.
    fn step_groups(&mut self) -> Result<Vec<FinishedGroup>> {
        Ok(Vec::new())
    }

    /// Images currently co-resident across in-flight groups.
    fn inflight_requests(&self) -> usize {
        0
    }
}

/// Factory that builds the executor on the server thread.
pub type ExecutorFactory = Box<dyn FnOnce() -> Result<Box<dyn Executor>> + Send>;

/// Server configuration.
#[derive(Clone, Debug, Default)]
pub struct ServerConfig {
    /// Dynamic-batching policy.
    pub batcher: BatcherConfig,
}

enum Event {
    Request(InferenceRequest),
    Shutdown,
}

/// A running server.
///
/// # Examples
///
/// Serve a mock executor end-to-end (submit → batch → classify → respond):
///
/// ```
/// use nvm_in_cache::coordinator::server::{Executor, Server, ServerConfig};
/// use nvm_in_cache::coordinator::InferenceRequest;
///
/// struct Echo;
/// impl Executor for Echo {
///     fn classify(&mut self, images: &[f32], n: usize) -> nvm_in_cache::Result<Vec<u8>> {
///         Ok((0..n).map(|i| images[i] as u8).collect())
///     }
///     fn image_elems(&self) -> usize {
///         1
///     }
/// }
///
/// let server = Server::start(
///     Box::new(|| Ok(Box::new(Echo) as Box<dyn Executor>)),
///     None,
///     ServerConfig::default(),
/// );
/// server.submit(InferenceRequest::new(0, vec![7.0]));
/// let response = server.responses.recv().unwrap();
/// assert_eq!(response.predicted, 7);
/// let metrics = server.shutdown();
/// assert_eq!(metrics.responses, 1);
/// ```
pub struct Server {
    tx: mpsc::Sender<Event>,
    /// Completed responses, in execution order.
    pub responses: mpsc::Receiver<InferenceResponse>,
    /// Live metrics (shared with the worker thread).
    pub metrics: Arc<Mutex<Metrics>>,
    handle: Option<JoinHandle<()>>,
}

impl Server {
    /// Start the server thread. `scheduler` (optional) provides the
    /// simulated-hardware cost accounting per batch.
    pub fn start(
        executor_factory: ExecutorFactory,
        mut scheduler: Option<BankScheduler>,
        config: ServerConfig,
    ) -> Server {
        let (tx, rx) = mpsc::channel::<Event>();
        let (resp_tx, resp_rx) = mpsc::channel::<InferenceResponse>();
        let metrics = Arc::new(Mutex::new(Metrics::new()));
        let metrics_thread = metrics.clone();
        if let Some(s) = scheduler.as_mut() {
            if !s.programmed {
                s.program_network();
            }
        }
        let handle = std::thread::spawn(move || {
            let mut executor = match executor_factory() {
                Ok(e) => e,
                Err(e) => {
                    eprintln!("executor construction failed: {e}");
                    return;
                }
            };
            let continuous = config.batcher.mode == BatchMode::Continuous;
            let mut batcher = Batcher::new(config.batcher);
            // Continuous mode: requests of every in-flight merge group,
            // keyed by the executor's group handle, with the group's
            // execution-start instant.
            let mut groups: std::collections::HashMap<u64, (Vec<InferenceRequest>, Instant)> =
                std::collections::HashMap::new();
            let mut inflight_reqs = 0usize;
            let mut running = true;
            while running || batcher.pending() > 0 || !groups.is_empty() {
                // Block for new work only when there is nothing to step and
                // nothing queued; with an in-flight pipeline we poll
                // non-blockingly so boundaries keep advancing.
                let idle = groups.is_empty() && (!continuous || batcher.pending() == 0);
                if running {
                    if idle {
                        let timeout = batcher
                            .next_deadline(Instant::now())
                            .unwrap_or(Duration::from_millis(50));
                        match rx.recv_timeout(timeout) {
                            Ok(Event::Request(r)) => {
                                metrics_thread.lock().unwrap().requests += 1;
                                batcher.push(r);
                            }
                            Ok(Event::Shutdown) => running = false,
                            Err(mpsc::RecvTimeoutError::Timeout) => {}
                            Err(mpsc::RecvTimeoutError::Disconnected) => running = false,
                        }
                    }
                    // Drain everything already queued in the channel before
                    // making a batching decision — otherwise a slow executor
                    // turns every backlog into singleton batches.
                    while running {
                        match rx.try_recv() {
                            Ok(Event::Request(r)) => {
                                metrics_thread.lock().unwrap().requests += 1;
                                batcher.push(r);
                            }
                            Ok(Event::Shutdown) => running = false,
                            Err(_) => break,
                        }
                    }
                }
                if continuous {
                    // Admit merge groups at this layer boundary, up to the
                    // co-residency cap, then advance the pipeline one
                    // boundary and answer whatever completed at it.
                    let now = Instant::now();
                    let mut room = config.batcher.max_batch.saturating_sub(inflight_reqs);
                    while room > 0 {
                        let Some(batch) = batcher.take_merge(now, room) else { break };
                        let n = batch.len();
                        let images = Self::concat_images(&batch.requests, executor.image_elems());
                        match executor.begin_group(&images, n) {
                            Ok(Some(gid)) => {
                                groups.insert(gid, (batch.requests, Instant::now()));
                                inflight_reqs += n;
                                room = config.batcher.max_batch.saturating_sub(inflight_reqs);
                            }
                            Ok(None) => {
                                // Executor cannot step (fixed-batch runtime
                                // backend): degrade this group to drain
                                // execution, still prepare-free.
                                Self::execute_batch(
                                    batch.requests,
                                    &mut *executor,
                                    scheduler.as_mut(),
                                    &metrics_thread,
                                    &resp_tx,
                                );
                            }
                            Err(e) => {
                                eprintln!("executor error: {e}");
                                let exec_start = Instant::now();
                                let n = batch.requests.len();
                                Self::complete_group(
                                    batch.requests,
                                    vec![0u8; n],
                                    exec_start,
                                    scheduler.as_mut(),
                                    &metrics_thread,
                                    &resp_tx,
                                );
                            }
                        }
                    }
                    if !groups.is_empty() {
                        let finished = match executor.step_groups() {
                            Ok(f) => f,
                            Err(e) => {
                                eprintln!("executor error: {e}");
                                // Fail every in-flight group with zeroed
                                // predictions rather than wedging callers.
                                groups
                                    .keys()
                                    .map(|&gid| FinishedGroup {
                                        group: gid,
                                        preds: vec![0u8; groups[&gid].0.len()],
                                    })
                                    .collect()
                            }
                        };
                        for fg in finished {
                            if let Some((requests, exec_start)) = groups.remove(&fg.group) {
                                inflight_reqs -= requests.len();
                                Self::complete_group(
                                    requests,
                                    fg.preds,
                                    exec_start,
                                    scheduler.as_mut(),
                                    &metrics_thread,
                                    &resp_tx,
                                );
                            }
                        }
                    }
                } else {
                    let force = !running;
                    while let Some(batch) = batcher.take(Instant::now(), force) {
                        Self::execute_batch(
                            batch.requests,
                            &mut *executor,
                            scheduler.as_mut(),
                            &metrics_thread,
                            &resp_tx,
                        );
                    }
                }
            }
        });
        Server { tx, responses: resp_rx, metrics, handle: Some(handle) }
    }

    fn concat_images(requests: &[InferenceRequest], elems: usize) -> Vec<f32> {
        let mut images = Vec::with_capacity(requests.len() * elems);
        for r in requests {
            assert_eq!(r.image.len(), elems, "request {} wrong image size", r.id);
            images.extend_from_slice(&r.image);
        }
        images
    }

    fn execute_batch(
        requests: Vec<InferenceRequest>,
        executor: &mut dyn Executor,
        scheduler: Option<&mut BankScheduler>,
        metrics: &Arc<Mutex<Metrics>>,
        resp_tx: &mpsc::Sender<InferenceResponse>,
    ) {
        let n = requests.len();
        let images = Self::concat_images(&requests, executor.image_elems());
        let exec_start = Instant::now();
        let preds = match executor.classify(&images, n) {
            Ok(p) => p,
            Err(e) => {
                eprintln!("executor error: {e}");
                vec![0u8; n]
            }
        };
        Self::complete_group(requests, preds, exec_start, scheduler, metrics, resp_tx);
    }

    /// Account and answer one executed group (a drain batch or a
    /// continuous merge group): simulated hardware cost, latency records,
    /// responses.
    fn complete_group(
        requests: Vec<InferenceRequest>,
        preds: Vec<u8>,
        exec_start: Instant,
        scheduler: Option<&mut BankScheduler>,
        metrics: &Arc<Mutex<Metrics>>,
        resp_tx: &mpsc::Sender<InferenceResponse>,
    ) {
        let n = requests.len();
        // Simulated hardware cost for this group.
        let (hw_lat, hw_ops, hw_energy) = match scheduler {
            Some(s) => {
                let c = s.batch_cost(n);
                (c.latency_s, c.ops, c.energy_j)
            }
            None => (0.0, 0.0, 0.0),
        };
        let mut m = metrics.lock().unwrap();
        m.record_batch(n, hw_ops, hw_energy, hw_lat);
        for (r, p) in requests.into_iter().zip(preds) {
            let e2e = r.enqueued.elapsed().as_secs_f64();
            let queue = exec_start.duration_since(r.enqueued).as_secs_f64();
            m.e2e_latency.record(e2e);
            m.queue_latency.record(queue);
            m.responses += 1;
            let _ = resp_tx.send(InferenceResponse {
                id: r.id,
                predicted: p,
                latency_s: e2e,
                hw_latency_s: hw_lat / n as f64,
            });
        }
    }

    /// Enqueue a request (non-blocking).
    pub fn submit(&self, req: InferenceRequest) {
        let _ = self.tx.send(Event::Request(req));
    }

    /// Graceful shutdown: drains the queue, joins the thread, returns the
    /// final metrics snapshot.
    pub fn shutdown(mut self) -> Metrics {
        let _ = self.tx.send(Event::Shutdown);
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
        let m = self.metrics.lock().unwrap();
        m.clone()
    }
}

impl Drop for Server {
    fn drop(&mut self) {
        let _ = self.tx.send(Event::Shutdown);
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

/// Native-engine executor (no runtime backend): serves any compiled
/// weight program behind [`crate::pim::program::SteppedProgram`] — a
/// [`crate::pim::program::CompiledNet`] by default, or a
/// [`crate::pim::attn::CompiledTransformer`] via
/// [`NativeExecutor::from_program`] — in a fixed forward mode. The
/// program is compiled **once** (at construction, or shared in via
/// `from_program` — e.g. across campaign rewarms in `fleet::sim`) and
/// every batch is pure prepared execution over the executor's reusable
/// scratch pool; the worker-pool width rides on the program
/// ([`crate::pim::program::SteppedProgram::parallelism`]).
///
/// Also the reference stepped executor: it implements
/// [`Executor::begin_group`]/[`Executor::step_groups`] over
/// [`crate::pim::program::InflightRun`], so a [`BatchMode::Continuous`]
/// server merges new requests into the in-flight execution at layer
/// boundaries — each group bit-identical to its solo `classify()` run
/// and still prepare-free at every boundary. Both workload families
/// ride the same merge loop: a transformer executor's `dims` are
/// `(seq_len, d_model, 1)` and each "image" is one token sequence.
pub struct NativeExecutor<P: crate::pim::program::SteppedProgram = crate::pim::program::CompiledNet>
{
    /// The compiled weight program (shareable across executors/threads).
    pub program: std::sync::Arc<P>,
    /// Forward mode (baseline / PIM emulation / hardware-true).
    pub mode: crate::nn::ForwardMode,
    /// Image dimensions (h, w, c).
    pub dims: (usize, usize, usize),
    /// Noise seed, bumped per batch (and per continuous merge group, so
    /// a group stepped to completion reproduces the classify() numerics
    /// of the same submission order exactly).
    pub seed: u64,
    scratch: crate::pim::program::ScratchPool,
    /// In-flight continuous-batching groups, boundary-interleaved by
    /// [`Executor::step_groups`].
    inflight: Vec<(u64, crate::pim::program::InflightRun)>,
    next_group: u64,
}

impl NativeExecutor {
    /// Compile `net` once and wrap it in an executor. Mode-aware: only
    /// the hardware-true modes read the quantized banks, so the other
    /// modes compile dense-only and skip the bank quantize/pack (and its
    /// resident memory) entirely.
    pub fn new(
        net: &crate::nn::ResNet,
        mode: crate::nn::ForwardMode,
        dims: (usize, usize, usize),
        seed: u64,
    ) -> Result<NativeExecutor> {
        use crate::nn::ForwardMode;
        use crate::pim::program::CompiledNet;
        let program = match mode {
            ForwardMode::PimHw | ForwardMode::PimHwNoise(_) => net.compile()?,
            _ => CompiledNet::compile_dense(net)?,
        };
        Ok(Self::from_program(std::sync::Arc::new(program), mode, dims, seed))
    }
}

impl<P: crate::pim::program::SteppedProgram> NativeExecutor<P> {
    /// Wrap an already-compiled program — the execute-many form: the same
    /// `Arc` can back many executors and survive server teardown/rewarm
    /// without recompiling. Generic over [`SteppedProgram`]
    /// implementations, so transformer programs serve through the exact
    /// same front door as CNNs.
    ///
    /// Debug builds reject a hardware-true mode paired with a dense-only
    /// program up front: that combination would silently re-prepare every
    /// layer on every batch (the exact pathology the program layer
    /// removes).
    ///
    /// [`SteppedProgram`]: crate::pim::program::SteppedProgram
    pub fn from_program(
        program: std::sync::Arc<P>,
        mode: crate::nn::ForwardMode,
        dims: (usize, usize, usize),
        seed: u64,
    ) -> NativeExecutor<P> {
        use crate::nn::ForwardMode;
        debug_assert!(
            !matches!(mode, ForwardMode::PimHw | ForwardMode::PimHwNoise(_))
                || program.fully_prepared(),
            "hardware-true NativeExecutor requires a fully prepared program \
             (compile with bank preparation, not compile_dense)"
        );
        NativeExecutor {
            program,
            mode,
            dims,
            seed,
            scratch: crate::pim::program::ScratchPool::new(),
            inflight: Vec::new(),
            next_group: 0,
        }
    }
}

impl<P: crate::pim::program::SteppedProgram> Executor for NativeExecutor<P> {
    fn classify(&mut self, images: &[f32], n: usize) -> Result<Vec<u8>> {
        let (h, w, c) = self.dims;
        let x = crate::nn::Tensor::from_vec(&[n, h, w, c], images.to_vec());
        self.seed = self.seed.wrapping_add(1);
        // Unconditional: a correctly constructed executor (any mode) is
        // prepare-free per batch — from_program rejects the hardware-true
        // + dense-only mismatch, and the non-hw modes never read banks.
        let before = crate::pim::program::prepare_count();
        let preds = self.program.classify(&x, self.mode, self.seed, &mut self.scratch);
        debug_assert_eq!(
            crate::pim::program::prepare_count(),
            before,
            "steady-state serving must not re-prepare weights"
        );
        Ok(preds)
    }

    fn image_elems(&self) -> usize {
        self.dims.0 * self.dims.1 * self.dims.2
    }

    fn begin_group(&mut self, images: &[f32], n: usize) -> Result<Option<u64>> {
        let (h, w, c) = self.dims;
        let x = crate::nn::Tensor::from_vec(&[n, h, w, c], images.to_vec());
        // Same per-submission seed bump as classify(): a merge group
        // admitted k-th reproduces the k-th solo batch bit-exactly.
        self.seed = self.seed.wrapping_add(1);
        let run = self.program.begin(&x, self.seed);
        let gid = self.next_group;
        self.next_group += 1;
        self.inflight.push((gid, run));
        Ok(Some(gid))
    }

    fn step_groups(&mut self) -> Result<Vec<FinishedGroup>> {
        let before = crate::pim::program::prepare_count();
        let mut done = Vec::new();
        let mut keep = Vec::with_capacity(self.inflight.len());
        for (gid, mut run) in std::mem::take(&mut self.inflight) {
            let par = self.program.parallelism();
            let finished = self.program.step(&mut run, self.mode, par, &mut self.scratch);
            if finished {
                let logits = run.into_logits();
                done.push(FinishedGroup {
                    group: gid,
                    preds: crate::pim::program::logits_to_classes(&logits),
                });
            } else {
                keep.push((gid, run));
            }
        }
        self.inflight = keep;
        debug_assert_eq!(
            crate::pim::program::prepare_count(),
            before,
            "continuous batching must stay prepare-free at every boundary"
        );
        Ok(done)
    }

    fn inflight_requests(&self) -> usize {
        self.inflight.iter().map(|(_, run)| run.batch()).sum()
    }
}

/// Executor over any [`crate::runtime::Runtime`] backend with a loaded
/// fixed-batch model variant; short batches are zero-padded up to the
/// backend's batch size.
///
/// `Runtime::load_variant` is the compile step: the backend holds one
/// compiled program per model config across requests (the stub caches a
/// [`crate::pim::program::CompiledNet`] per weights file), so the
/// steady-state loop here is pure prepared execution.
pub struct RuntimeExecutor {
    /// The backend (stub by default; PJRT behind the `pjrt` feature).
    pub runtime: Box<dyn crate::runtime::Runtime>,
    /// Which loaded variant this executor serves.
    pub variant: crate::runtime::ModelVariant,
    /// Image dimensions (h, w, c).
    pub dims: (usize, usize, usize),
    /// Number of output classes.
    pub n_classes: usize,
    /// Per-batch counter feeding the PimNoise key (fresh noise per batch,
    /// reproducible per counter value).
    pub key_counter: u32,
    /// Worker-pool width pushed to the backend before every batch —
    /// predictions are bit-identical at any width
    /// ([`crate::pim::parallel`]), so this only changes throughput and may
    /// be retuned between batches.
    pub parallelism: crate::pim::parallel::Parallelism,
}

impl Executor for RuntimeExecutor {
    fn classify(&mut self, images: &[f32], n: usize) -> Result<Vec<u8>> {
        self.runtime.set_parallelism(self.parallelism);
        let (h, w, c) = self.dims;
        let elems = h * w * c;
        let b = self.runtime.batch();
        assert!(n <= b, "batch {n} exceeds compiled batch {b}");
        let mut padded = images.to_vec();
        padded.resize(b * elems, 0.0);
        self.key_counter += 1;
        let key = if self.variant == crate::runtime::ModelVariant::PimNoise {
            Some([0xC0FFEE, self.key_counter])
        } else {
            None
        };
        let mut preds = self.runtime.classify(self.variant, &padded, self.dims, self.n_classes, key)?;
        preds.truncate(n);
        Ok(preds)
    }

    fn image_elems(&self) -> usize {
        self.dims.0 * self.dims.1 * self.dims.2
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Deterministic test executor: predicts image[0] as the class.
    struct MockExecutor {
        elems: usize,
        calls: Arc<Mutex<Vec<usize>>>,
    }

    impl Executor for MockExecutor {
        fn classify(&mut self, images: &[f32], n: usize) -> Result<Vec<u8>> {
            self.calls.lock().unwrap().push(n);
            Ok((0..n).map(|i| images[i * self.elems] as u8).collect())
        }

        fn image_elems(&self) -> usize {
            self.elems
        }
    }

    #[test]
    fn serves_and_batches() {
        let calls = Arc::new(Mutex::new(Vec::new()));
        let exec = MockExecutor { elems: 4, calls: calls.clone() };
        let server = Server::start(
            Box::new(move || Ok(Box::new(exec) as Box<dyn Executor>)),
            None,
            ServerConfig {
                batcher: BatcherConfig::sized(4, Duration::from_millis(2)),
            },
        );
        for i in 0..10u64 {
            server.submit(InferenceRequest::new(i, vec![(i % 10) as f32; 4]));
        }
        let mut responses = Vec::new();
        for _ in 0..10 {
            responses.push(server.responses.recv_timeout(Duration::from_secs(5)).unwrap());
        }
        let m = server.shutdown();
        assert_eq!(m.responses, 10);
        // Predictions reflect payloads (mock rule).
        for r in &responses {
            assert_eq!(r.predicted as u64, r.id % 10);
        }
        // Batching actually happened (at least one batch > 1).
        let sizes = calls.lock().unwrap().clone();
        assert!(sizes.iter().any(|&s| s > 1), "batch sizes: {sizes:?}");
        assert_eq!(sizes.iter().sum::<usize>(), 10);
    }

    #[test]
    fn continuous_mode_degrades_for_non_stepping_executor() {
        // MockExecutor keeps the default begin_group() → None, so a
        // continuous-mode server must fall back to drain execution and
        // still answer everything.
        let calls = Arc::new(Mutex::new(Vec::new()));
        let exec = MockExecutor { elems: 2, calls: calls.clone() };
        let server = Server::start(
            Box::new(move || Ok(Box::new(exec) as Box<dyn Executor>)),
            None,
            ServerConfig {
                batcher: BatcherConfig::continuous(4, Duration::from_millis(2)),
            },
        );
        for i in 0..9u64 {
            server.submit(InferenceRequest::new(i, vec![(i % 10) as f32; 2]));
        }
        let mut got = 0;
        while got < 9 {
            let r = server.responses.recv_timeout(Duration::from_secs(5)).unwrap();
            assert_eq!(r.predicted as u64, r.id % 10);
            got += 1;
        }
        let m = server.shutdown();
        assert_eq!(m.responses, 9);
    }

    #[test]
    fn shutdown_drains_pending() {
        let calls = Arc::new(Mutex::new(Vec::new()));
        let exec = MockExecutor { elems: 1, calls: calls.clone() };
        let server = Server::start(
            Box::new(move || Ok(Box::new(exec) as Box<dyn Executor>)),
            None,
            ServerConfig {
                batcher: BatcherConfig::sized(100, Duration::from_secs(10)),
            },
        );
        for i in 0..5u64 {
            server.submit(InferenceRequest::new(i, vec![0.0]));
        }
        // Deadline far away + batch never filled ⇒ only shutdown drains.
        let m = server.shutdown();
        assert_eq!(m.responses, 5);
    }

    #[test]
    fn metrics_latencies_recorded() {
        let exec = MockExecutor { elems: 1, calls: Arc::new(Mutex::new(Vec::new())) };
        let server = Server::start(
            Box::new(move || Ok(Box::new(exec) as Box<dyn Executor>)),
            None,
            ServerConfig::default(),
        );
        server.submit(InferenceRequest::new(1, vec![3.0]));
        let r = server.responses.recv_timeout(Duration::from_secs(5)).unwrap();
        assert_eq!(r.predicted, 3);
        assert!(r.latency_s >= 0.0);
        let m = server.shutdown();
        assert_eq!(m.e2e_latency.count, 1);
    }
}
