//! Request/response types.

use std::time::Instant;

/// One inference request (a single image).
#[derive(Clone, Debug)]
pub struct InferenceRequest {
    /// Caller-assigned request id (echoed in the response).
    pub id: u64,
    /// Tenant this request belongs to (0 for single-tenant servers).
    /// The batcher's merge cut preserves FIFO order *per tenant*.
    pub tenant: u32,
    /// Flattened NHWC image, h×w×c f32.
    pub image: Vec<f32>,
    /// Arrival timestamp (set by [`InferenceRequest::new`]).
    pub enqueued: Instant,
}

impl InferenceRequest {
    /// A request enqueued now (tenant 0).
    pub fn new(id: u64, image: Vec<f32>) -> InferenceRequest {
        InferenceRequest { id, tenant: 0, image, enqueued: Instant::now() }
    }

    /// Tag the request with a tenant id (builder style).
    pub fn with_tenant(mut self, tenant: u32) -> InferenceRequest {
        self.tenant = tenant;
        self
    }
}

/// The response for one request.
#[derive(Clone, Debug, PartialEq)]
pub struct InferenceResponse {
    /// Request id this answers.
    pub id: u64,
    /// Predicted class.
    pub predicted: u8,
    /// End-to-end latency (s).
    pub latency_s: f64,
    /// Simulated hardware latency of the PIM execution (s).
    pub hw_latency_s: f64,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn request_records_enqueue_time() {
        let r = InferenceRequest::new(7, vec![0.0; 4]);
        assert_eq!(r.id, 7);
        assert!(r.enqueued.elapsed().as_secs_f64() < 1.0);
    }
}
