//! The serving front door: open-loop load generation, admission control
//! with backpressure, and continuous batching — on the simulated clock.
//!
//! This is the deployment-side sibling of [`crate::pim::parallel`]: a
//! dependency-free event loop (hand-rolled heap, seeded
//! [`Pcg64`] arrivals, pure `f64` time) that answers the question the
//! kernel work cannot: *where is the knee of the latency/throughput
//! curve, and which component is the bottleneck there?*
//!
//! Three pieces:
//!
//! 1. **Open-loop arrival processes** ([`ArrivalProcess`]): Poisson,
//!    diurnal (sinusoidal-rate, thinned), and bursty (square-wave rate)
//!    traces. Open-loop means arrivals do *not* slow down when the
//!    system congests — the population of simulated users is far larger
//!    than the fleet, so offered load is independent of latency. This is
//!    the regime where queueing knees actually appear; closed-loop
//!    replay (what `fleet-sim` did before this module) self-throttles
//!    and hides them.
//! 2. **Admission control with backpressure** ([`OverloadPolicy`]): a
//!    bounded per-replica queue sheds overflow outright, and the `Shed`
//!    policy additionally rejects requests whose projected completion
//!    would blow the tenant's QoS deadline — shedding early instead of
//!    serving answers nobody is waiting for.
//! 3. **Continuous batching** ([`Discipline::Continuous`]): each layer
//!    of the network owns its weight-stationary arrays
//!    ([`BankScheduler::layer_costs`]), so while one wave occupies layer
//!    *j* every other layer's banks idle. The simulator models each
//!    replica as a tandem pipeline of layer stages: a new request enters
//!    at the next stage-0 boundary instead of waiting for the whole
//!    batch to drain, lifting per-replica throughput from `1/Σdₗ`
//!    (drain batching — the hardware latency model is linear in batch
//!    size, so classic batching buys *nothing*) to `1/max dₗ`. The live
//!    twin of this model is [`super::server::Executor::step_groups`]
//!    over [`crate::pim::program::InflightRun`]. A shard-parallel
//!    replica ([`crate::fleet::shard`]) drops straight into the same
//!    tandem model: its inter-slice activation hops are extra stages
//!    ([`FrontDoorConfig::for_shard_pipeline`]) whose time is attributed
//!    to `transfer` rather than `adc` in the component split.
//!
//! The simulator is pinned against closed-form M/D/c queueing theory
//! ([`mdc`], Crommelin's embedded recursion + Franx's waiting-time
//! formula): in validation mode (`max_batch = 1`, admission off) the
//! simulated p50/p99 must land within tolerance of the analytic values —
//! a deterministic bench gate (`comparison.serve.*`), not a plot.

use std::cmp::Reverse;
use std::collections::{BinaryHeap, VecDeque};

use crate::util::json::Json;
use crate::util::rng::Pcg64;
use crate::util::stats::Summary;

use super::scheduler::BankScheduler;

/// Epsilon for simulated-clock comparisons that must tolerate float
/// round-trip through event times.
const EPS: f64 = 1e-12;

/// Latency multiple (vs the lightest-load p99) that defines the knee.
pub const KNEE_FACTOR: f64 = 3.0;

/// An open-loop arrival process. All variants generate event times via
/// exponential inter-arrivals at the peak rate, thinned to the
/// instantaneous rate — one accept/reject draw per candidate, so a given
/// (process, seed) pair is a fixed trace.
#[derive(Clone, Copy, Debug)]
pub enum ArrivalProcess {
    /// Homogeneous Poisson arrivals.
    Poisson {
        /// Mean arrival rate (requests/s).
        rate_rps: f64,
    },
    /// Sinusoidal day/night swing around a mean rate.
    Diurnal {
        /// Mean arrival rate (requests/s).
        mean_rps: f64,
        /// Relative swing amplitude in [0, 1): rate varies in
        /// `mean·(1 ± swing)`.
        swing: f64,
        /// Period of one simulated "day" (s).
        period_s: f64,
    },
    /// Square-wave bursts: `burst_mult × base` for the first
    /// `duty` fraction of every period, `base` otherwise.
    Burst {
        /// Off-burst arrival rate (requests/s).
        base_rps: f64,
        /// Rate multiplier during a burst.
        burst_mult: f64,
        /// Burst period (s).
        period_s: f64,
        /// Fraction of the period spent bursting, in (0, 1).
        duty: f64,
    },
}

impl ArrivalProcess {
    /// Instantaneous arrival rate at simulated time `t`.
    pub fn rate_at(&self, t: f64) -> f64 {
        match *self {
            ArrivalProcess::Poisson { rate_rps } => rate_rps,
            ArrivalProcess::Diurnal { mean_rps, swing, period_s } => {
                mean_rps * (1.0 + swing * (2.0 * std::f64::consts::PI * t / period_s).sin())
            }
            ArrivalProcess::Burst { base_rps, burst_mult, period_s, duty } => {
                if t.rem_euclid(period_s) < duty * period_s {
                    base_rps * burst_mult
                } else {
                    base_rps
                }
            }
        }
    }

    /// Peak instantaneous rate (the thinning envelope).
    pub fn peak_rate(&self) -> f64 {
        match *self {
            ArrivalProcess::Poisson { rate_rps } => rate_rps,
            ArrivalProcess::Diurnal { mean_rps, swing, .. } => mean_rps * (1.0 + swing),
            ArrivalProcess::Burst { base_rps, burst_mult, .. } => base_rps * burst_mult,
        }
    }

    /// Long-run mean rate (requests/s).
    pub fn mean_rate(&self) -> f64 {
        match *self {
            ArrivalProcess::Poisson { rate_rps } => rate_rps,
            ArrivalProcess::Diurnal { mean_rps, .. } => mean_rps,
            ArrivalProcess::Burst { base_rps, burst_mult, duty, .. } => {
                base_rps * (1.0 + (burst_mult - 1.0) * duty)
            }
        }
    }

    /// The same temporal shape rescaled to a new long-run mean rate —
    /// what the offered-load sweep varies.
    pub fn with_mean(&self, mean_rps: f64) -> ArrivalProcess {
        match *self {
            ArrivalProcess::Poisson { .. } => ArrivalProcess::Poisson { rate_rps: mean_rps },
            ArrivalProcess::Diurnal { swing, period_s, .. } => {
                ArrivalProcess::Diurnal { mean_rps, swing, period_s }
            }
            ArrivalProcess::Burst { burst_mult, period_s, duty, .. } => ArrivalProcess::Burst {
                base_rps: mean_rps / (1.0 + (burst_mult - 1.0) * duty),
                burst_mult,
                period_s,
                duty,
            },
        }
    }

    /// Next arrival strictly after `t` (thinning / Lewis-Shedler): step by
    /// an exponential at the peak rate, accept with probability
    /// `rate(t)/peak`.
    pub fn next(&self, mut t: f64, rng: &mut Pcg64) -> f64 {
        let peak = self.peak_rate();
        assert!(peak > 0.0, "arrival process needs a positive rate");
        loop {
            t += -(1.0 - rng.f64()).ln() / peak;
            if rng.f64() * peak <= self.rate_at(t) {
                return t;
            }
        }
    }
}

/// One tenant class at the front door: a traffic share and a QoS
/// deadline the admission controller projects against.
#[derive(Clone, Debug)]
pub struct TenantClass {
    /// Display name.
    pub name: String,
    /// Relative traffic weight (normalized across classes).
    pub weight: f64,
    /// End-to-end QoS deadline (s); `f64::INFINITY` disables
    /// deadline-based shedding for this class.
    pub deadline_s: f64,
}

/// What to do with a request that cannot meet its class deadline.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum OverloadPolicy {
    /// Reject it at admission (projected-deadline shed). Bounded-queue
    /// overflow sheds under either policy.
    Shed,
    /// Admit it anyway and let it run late; only queue overflow sheds.
    Delay,
}

/// Batch formation discipline of the simulated replicas — mirrors
/// [`super::batcher::BatchMode`] on the simulated clock.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Discipline {
    /// Size-or-deadline batches executed to completion (`n·Σdₗ` each).
    DrainBatch,
    /// Continuous batching: per-replica tandem pipeline of layer stages;
    /// requests enter at stage-0 boundaries, `max_batch` caps
    /// co-residency.
    Continuous,
}

/// Front-door configuration.
#[derive(Clone, Debug)]
pub struct FrontDoorConfig {
    /// Identical replicas in the fixed fleet.
    pub replicas: usize,
    /// Per-layer single-image service times (s) — the pipeline stage
    /// profile, from [`BankScheduler::layer_costs`]. For a shard-chain
    /// replica this also contains the inter-slice transfer hops, flagged
    /// by `hop_stages`.
    pub layer_latencies_s: Vec<f64>,
    /// Indices into `layer_latencies_s` that are inter-slice activation
    /// *hops* of a shard chain rather than compute stages. Hops behave as
    /// ordinary tandem stages (the interconnect serializes like an array
    /// does), but their time is attributed to `transfer` instead of `adc`
    /// in the [`ComponentBreakdown`]. Empty for unsharded replicas.
    pub hop_stages: Vec<usize>,
    /// Max requests co-resident per replica (continuous) or per batch
    /// (drain).
    pub max_batch: usize,
    /// Drain-mode formation deadline (s).
    pub max_wait_s: f64,
    /// Bounded-queue depth per replica; admitted-but-unstarted requests
    /// beyond this are shed (backpressure).
    pub queue_cap: usize,
    /// Batch formation discipline.
    pub discipline: Discipline,
    /// Overload policy.
    pub policy: OverloadPolicy,
    /// Tenant classes sharing the door.
    pub classes: Vec<TenantClass>,
    /// Arrival-trace shape (rescaled per sweep point).
    pub arrival: ArrivalProcess,
    /// Trace seed.
    pub seed: u64,
    /// Arrivals simulated per load point.
    pub requests: usize,
    /// Per-user request rate (requests/s) — maps offered load to a
    /// simulated user population for reporting.
    pub user_rps: f64,
}

impl FrontDoorConfig {
    /// Sensible defaults for a network with the given per-layer service
    /// profile on `replicas` replicas: continuous batching, shed policy,
    /// QoS deadline at 10× the unloaded service time.
    pub fn for_network(layer_latencies_s: Vec<f64>, replicas: usize) -> FrontDoorConfig {
        let total: f64 = layer_latencies_s.iter().sum();
        FrontDoorConfig {
            replicas,
            layer_latencies_s,
            hop_stages: Vec::new(),
            max_batch: 16,
            max_wait_s: 1e-3,
            queue_cap: 64,
            discipline: Discipline::Continuous,
            policy: OverloadPolicy::Shed,
            classes: vec![TenantClass {
                name: "default".into(),
                weight: 1.0,
                deadline_s: 10.0 * total,
            }],
            arrival: ArrivalProcess::Poisson { rate_rps: 1.0 },
            seed: 42,
            requests: 3000,
            user_rps: 0.013, // ~1.1k requests/day/user
        }
    }

    /// A shard-chain replica's front door: each shard's per-layer stage
    /// profile in `stage_groups`, with the inter-slice activation-hop
    /// latencies `hops_s` (one per adjacent pair, e.g. from
    /// [`crate::fleet::shard::TransferLink::latency_s`]) interleaved as
    /// extra tandem stages flagged in `hop_stages`.
    pub fn for_shard_pipeline(
        stage_groups: &[Vec<f64>],
        hops_s: &[f64],
        replicas: usize,
    ) -> FrontDoorConfig {
        assert!(!stage_groups.is_empty(), "a shard chain needs at least one segment");
        assert_eq!(
            hops_s.len() + 1,
            stage_groups.len(),
            "one hop per adjacent segment pair"
        );
        let mut stages = Vec::new();
        let mut hop_stages = Vec::new();
        for (g, group) in stage_groups.iter().enumerate() {
            stages.extend_from_slice(group);
            if g + 1 < stage_groups.len() {
                hop_stages.push(stages.len());
                stages.push(hops_s[g]);
            }
        }
        let base = Self::for_network(stages, replicas);
        FrontDoorConfig { hop_stages, ..base }
    }

    /// Whole-network single-image service time `Σdₗ` (s), hops included.
    pub fn service_total_s(&self) -> f64 {
        self.layer_latencies_s.iter().sum()
    }

    /// Compute-only service time: `Σdₗ` over non-hop stages (s).
    pub fn service_compute_s(&self) -> f64 {
        self.layer_latencies_s
            .iter()
            .enumerate()
            .filter(|(l, _)| !self.hop_stages.contains(l))
            .map(|(_, &dl)| dl)
            .sum()
    }

    /// Inter-slice hop time per request: `Σdₗ` over hop stages (s).
    pub fn service_hops_s(&self) -> f64 {
        self.hop_stages.iter().map(|&l| self.layer_latencies_s[l]).sum()
    }

    /// Bottleneck stage `max dₗ` (s).
    pub fn service_bottleneck_s(&self) -> f64 {
        self.layer_latencies_s.iter().cloned().fold(0.0, f64::max)
    }
}

/// Mean seconds spent per served request in each serving component —
/// the bottleneck attribution of one load point.
#[derive(Clone, Copy, Debug, Default)]
pub struct ComponentBreakdown {
    /// Waiting for co-residency room at a layer boundary (continuous) or
    /// for batch formation (drain).
    pub batcher_s: f64,
    /// Waiting for a replica / its stage-0 arrays to free up.
    pub router_s: f64,
    /// Pure compute: the ADC-window service time over compute stages.
    pub adc_s: f64,
    /// Inter-slice activation hops of a shard chain (0 unsharded).
    pub transfer_s: f64,
    /// Inter-stage blocking inside the pipeline beyond pure service
    /// (continuous only).
    pub pipeline_s: f64,
}

impl ComponentBreakdown {
    /// The dominant component by mean time. Defined for any inputs:
    /// `total_cmp` gives a total order, so a NaN component (which sorts
    /// above every finite value) is *reported* as the bottleneck rather
    /// than poisoning the comparison — the caller sees the broken number
    /// instead of a panic or an arbitrary answer.
    pub fn bottleneck(&self) -> &'static str {
        let pairs = [
            ("batcher", self.batcher_s),
            ("router", self.router_s),
            ("adc", self.adc_s),
            ("transfer", self.transfer_s),
            ("pipeline", self.pipeline_s),
        ];
        pairs
            .iter()
            .max_by(|a, b| a.1.total_cmp(&b.1))
            .expect("pairs is a non-empty fixed array")
            .0
    }
}

/// Per-class outcome counters at one load point.
#[derive(Clone, Debug)]
pub struct ClassOutcome {
    /// Class name.
    pub name: String,
    /// Requests admitted and served.
    pub served: u64,
    /// Requests shed (projected-deadline or queue overflow).
    pub shed: u64,
    /// Served requests that still missed the class deadline.
    pub deadline_misses: u64,
}

/// One point of the offered-load sweep.
#[derive(Clone, Debug)]
pub struct LoadPoint {
    /// Offered arrival rate (requests/s).
    pub offered_rps: f64,
    /// Simulated user population this rate corresponds to.
    pub users: u64,
    /// Requests served.
    pub served: u64,
    /// Requests shed at admission.
    pub shed: u64,
    /// Served requests past their class deadline.
    pub deadline_misses: u64,
    /// Served throughput over the simulated horizon (requests/s).
    pub throughput_rps: f64,
    /// End-to-end latency summary (s) over served requests.
    pub latency: Summary,
    /// Mean co-resident requests per execution (continuous) or mean cut
    /// batch size (drain).
    pub mean_batch: f64,
    /// Mean per-request component times.
    pub breakdown: ComponentBreakdown,
    /// Per-class outcomes.
    pub classes: Vec<ClassOutcome>,
}

/// The swept latency/throughput curve with its knee.
#[derive(Clone, Debug)]
pub struct SweepReport {
    /// Discipline the sweep ran under.
    pub discipline: Discipline,
    /// Analytic capacity of the fleet under that discipline (requests/s).
    pub capacity_rps: f64,
    /// The sweep points, in offered-rate order.
    pub points: Vec<LoadPoint>,
    /// The knee: the highest offered rate whose p99 stays within
    /// [`KNEE_FACTOR`]× of the lightest-load p99 (0 when even the first
    /// point blows it).
    pub knee_rps: f64,
    /// Index of the knee point in `points`, if any.
    pub knee_index: Option<usize>,
    /// Dominant component at the first post-knee point (or the last
    /// point when nothing is past the knee).
    pub bottleneck_past_knee: &'static str,
}

// ---------------------------------------------------------------------
// Event loop
// ---------------------------------------------------------------------

#[derive(Clone, Copy, Debug)]
enum Ev {
    Arrival { class: usize },
    Flush,
    Free,
}

#[derive(Clone, Copy, Debug)]
struct Event {
    t: f64,
    seq: u64,
    ev: Ev,
}

impl PartialEq for Event {
    fn eq(&self, other: &Self) -> bool {
        self.cmp(other) == std::cmp::Ordering::Equal
    }
}
impl Eq for Event {}
impl PartialOrd for Event {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}
impl Ord for Event {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        // total_cmp keeps the heap deterministic even if a time went
        // NaN; seq breaks exact-time ties in push order.
        self.t.total_cmp(&other.t).then(self.seq.cmp(&other.seq))
    }
}

/// Accumulators shared by both disciplines.
#[derive(Default)]
struct PointStats {
    latencies: Vec<f64>,
    batch_samples: Vec<f64>,
    batcher_s: f64,
    router_s: f64,
    adc_s: f64,
    transfer_s: f64,
    pipeline_s: f64,
    shed: u64,
    served_per_class: Vec<u64>,
    shed_per_class: Vec<u64>,
    miss_per_class: Vec<u64>,
    max_completion: f64,
}

/// The front door simulator.
pub struct FrontDoor {
    /// Configuration.
    pub config: FrontDoorConfig,
}

impl FrontDoor {
    /// A front door over `config`.
    pub fn new(config: FrontDoorConfig) -> FrontDoor {
        assert!(config.replicas > 0 && config.max_batch > 0);
        assert!(!config.layer_latencies_s.is_empty());
        assert!(!config.classes.is_empty());
        FrontDoor { config }
    }

    /// Analytic capacity (requests/s) of the fleet under the configured
    /// discipline: `c / max dₗ` for the continuous pipeline (capped by
    /// co-residency), `c / Σdₗ` for drain batching — the hardware cost
    /// model is linear in batch size, so classic batching adds no
    /// throughput, only formation latency.
    pub fn capacity_rps(&self) -> f64 {
        let c = self.config.replicas as f64;
        let total = self.config.service_total_s();
        match self.config.discipline {
            Discipline::DrainBatch => c / total,
            Discipline::Continuous => {
                let per_pipe =
                    (1.0 / self.config.service_bottleneck_s()).min(self.config.max_batch as f64 / total);
                c * per_pipe
            }
        }
    }

    fn pick_class(&self, rng: &mut Pcg64) -> usize {
        if self.config.classes.len() == 1 {
            return 0;
        }
        let total: f64 = self.config.classes.iter().map(|c| c.weight).sum();
        let mut x = rng.f64() * total;
        for (i, c) in self.config.classes.iter().enumerate() {
            x -= c.weight;
            if x <= 0.0 {
                return i;
            }
        }
        self.config.classes.len() - 1
    }

    /// The seeded arrival trace for `process`: `(time, class)` pairs in
    /// time order. Open-loop: generated up front, independent of any
    /// serving state.
    fn gen_arrivals(&self, process: &ArrivalProcess) -> Vec<(f64, usize)> {
        let mut rng = Pcg64::new(self.config.seed, 0x5e7e_d00d);
        let mut class_rng = rng.fork(7);
        let mut t = 0.0;
        (0..self.config.requests)
            .map(|_| {
                t = process.next(t, &mut rng);
                (t, self.pick_class(&mut class_rng))
            })
            .collect()
    }

    /// Simulate one load point at the configured arrival shape rescaled
    /// to `offered_rps`.
    pub fn run_point_at(&self, offered_rps: f64) -> LoadPoint {
        let process = self.config.arrival.with_mean(offered_rps);
        self.run_point(&process)
    }

    /// Simulate one load point for an explicit arrival process.
    pub fn run_point(&self, process: &ArrivalProcess) -> LoadPoint {
        let arrivals = self.gen_arrivals(process);
        let nclasses = self.config.classes.len();
        let mut stats = PointStats {
            served_per_class: vec![0; nclasses],
            shed_per_class: vec![0; nclasses],
            miss_per_class: vec![0; nclasses],
            ..PointStats::default()
        };
        match self.config.discipline {
            Discipline::Continuous => self.run_continuous(&arrivals, &mut stats),
            Discipline::DrainBatch => self.run_drain(&arrivals, &mut stats),
        }
        let served = stats.latencies.len() as u64;
        let horizon = stats
            .max_completion
            .max(arrivals.last().map(|a| a.0).unwrap_or(0.0))
            .max(EPS);
        let latency = Summary::of(&stats.latencies);
        let mean_batch = if stats.batch_samples.is_empty() {
            0.0
        } else {
            stats.batch_samples.iter().sum::<f64>() / stats.batch_samples.len() as f64
        };
        let per = |x: f64| if served == 0 { 0.0 } else { x / served as f64 };
        LoadPoint {
            offered_rps: process.mean_rate(),
            users: (process.mean_rate() / self.config.user_rps).round() as u64,
            served,
            shed: stats.shed,
            deadline_misses: stats.miss_per_class.iter().sum(),
            throughput_rps: served as f64 / horizon,
            latency,
            mean_batch,
            breakdown: ComponentBreakdown {
                batcher_s: per(stats.batcher_s),
                router_s: per(stats.router_s),
                adc_s: per(stats.adc_s),
                transfer_s: per(stats.transfer_s),
                pipeline_s: per(stats.pipeline_s),
            },
            classes: self
                .config
                .classes
                .iter()
                .enumerate()
                .map(|(i, c)| ClassOutcome {
                    name: c.name.clone(),
                    served: stats.served_per_class[i],
                    shed: stats.shed_per_class[i],
                    deadline_misses: stats.miss_per_class[i],
                })
                .collect(),
        }
    }

    /// Continuous discipline: per-replica tandem pipeline of layer
    /// stages. Everything resolves analytically at each arrival — entry,
    /// per-stage starts, completion — so no event heap is needed.
    fn run_continuous(&self, arrivals: &[(f64, usize)], stats: &mut PointStats) {
        let d = &self.config.layer_latencies_s;
        let d_total: f64 = d.iter().sum();
        let d_hops = self.config.service_hops_s();
        let d_compute = d_total - d_hops;
        let nl = d.len();
        struct Pipe {
            stage_free: Vec<f64>,
            /// start-0 times of admitted requests, FIFO (backpressure).
            starts: VecDeque<f64>,
            /// last `max_batch` completion times, ascending (occupancy).
            comps: VecDeque<f64>,
        }
        let mut pipes: Vec<Pipe> = (0..self.config.replicas)
            .map(|_| Pipe {
                stage_free: vec![0.0; nl],
                starts: VecDeque::new(),
                comps: VecDeque::new(),
            })
            .collect();
        for &(t, class) in arrivals {
            // Projected stage-0 entry per replica: free arrays, then
            // co-residency room.
            let entry = |p: &Pipe| -> (f64, f64) {
                let base = t.max(p.stage_free[0]);
                let occ_gate = if p.comps.len() >= self.config.max_batch {
                    p.comps[p.comps.len() - self.config.max_batch]
                } else {
                    0.0
                };
                (base, base.max(occ_gate))
            };
            let r = (0..pipes.len())
                .min_by(|&a, &b| entry(&pipes[a]).1.total_cmp(&entry(&pipes[b]).1).then(a.cmp(&b)))
                .expect("new() asserts replicas > 0, so pipes is non-empty");
            let (base, start0) = entry(&pipes[r]);
            // Backpressure: admitted-but-unstarted requests on the chosen
            // replica form its bounded queue.
            let pipe = &mut pipes[r];
            while pipe.starts.front().is_some_and(|&s| s <= t + EPS) {
                pipe.starts.pop_front();
            }
            if pipe.starts.len() >= self.config.queue_cap {
                stats.shed += 1;
                stats.shed_per_class[class] += 1;
                continue;
            }
            // Shed policy: projected completion vs the class deadline.
            let deadline = self.config.classes[class].deadline_s;
            if self.config.policy == OverloadPolicy::Shed
                && (start0 - t) + d_total > deadline
            {
                stats.shed += 1;
                stats.shed_per_class[class] += 1;
                continue;
            }
            // Occupancy sample: requests still in flight when this one
            // enters (+1 for itself).
            let occupancy =
                pipe.comps.iter().filter(|&&cmp| cmp > start0 + EPS).count() as f64 + 1.0;
            // Walk the tandem stages.
            let mut a = start0;
            for (l, &dl) in d.iter().enumerate() {
                let s = a.max(pipe.stage_free[l]);
                pipe.stage_free[l] = s + dl;
                a = s + dl;
            }
            let completion = a;
            pipe.starts.push_back(start0);
            pipe.comps.push_back(completion);
            if pipe.comps.len() > self.config.max_batch {
                pipe.comps.pop_front();
            }
            let e2e = completion - t;
            stats.latencies.push(e2e);
            stats.batch_samples.push(occupancy);
            stats.router_s += base - t;
            stats.batcher_s += start0 - base;
            stats.adc_s += d_compute;
            stats.transfer_s += d_hops;
            stats.pipeline_s += (completion - start0) - d_total;
            stats.served_per_class[class] += 1;
            if e2e > deadline {
                stats.miss_per_class[class] += 1;
            }
            stats.max_completion = stats.max_completion.max(completion);
        }
    }

    /// Drain discipline: central size-or-deadline batcher over `c`
    /// whole-batch replicas, driven by an event heap (arrivals, flush
    /// deadlines, replica-free events).
    fn run_drain(&self, arrivals: &[(f64, usize)], stats: &mut PointStats) {
        let d_total = self.config.service_total_s();
        let d_hops = self.config.service_hops_s();
        let d_compute = d_total - d_hops;
        let max_wait = self.config.max_wait_s;
        struct Queued {
            arrive: f64,
            class: usize,
        }
        let mut heap: BinaryHeap<Reverse<Event>> = BinaryHeap::new();
        let mut seq = 0u64;
        for &(t, class) in arrivals {
            heap.push(Reverse(Event { t, seq, ev: Ev::Arrival { class } }));
            seq += 1;
        }
        let mut queue: VecDeque<Queued> = VecDeque::new();
        let mut busy = vec![0.0f64; self.config.replicas];
        let cap = self.config.queue_cap.saturating_mul(self.config.replicas);

        // Cut as many batches as policy + free replicas allow at `now`.
        // (A macro, not a closure: it mutably borrows `heap` while the
        // caller's `while let … heap.pop()` loop also owns it.)
        macro_rules! try_cut {
            ($now:expr, $force:expr) => {{
                let now: f64 = $now;
                loop {
                    if queue.is_empty() {
                        break;
                    }
                    let Some(r) = (0..busy.len()).find(|&r| busy[r] <= now + EPS) else {
                        break;
                    };
                    let due = now >= queue[0].arrive + max_wait;
                    if !$force && queue.len() < self.config.max_batch && !due {
                        break;
                    }
                    let n = queue.len().min(self.config.max_batch);
                    // Formation-ready time: when the cut criteria were
                    // first satisfiable (batch filled, or oldest hit its
                    // flush deadline). Time past `ready` waited on a
                    // replica, not on formation.
                    let ready = if n == self.config.max_batch {
                        queue[n - 1].arrive
                    } else {
                        (queue[0].arrive + max_wait).min(now)
                    }
                    .min(now);
                    let service = n as f64 * d_total;
                    let completion = now + service;
                    busy[r] = completion;
                    heap.push(Reverse(Event { t: completion, seq, ev: Ev::Free }));
                    seq += 1;
                    for q in queue.drain(..n) {
                        let e2e = completion - q.arrive;
                        stats.latencies.push(e2e);
                        stats.batch_samples.push(n as f64);
                        let form_end = ready.max(q.arrive);
                        stats.batcher_s += form_end - q.arrive;
                        stats.router_s += now - form_end;
                        stats.adc_s += n as f64 * d_compute;
                        stats.transfer_s += n as f64 * d_hops;
                        stats.served_per_class[q.class] += 1;
                        if e2e > self.config.classes[q.class].deadline_s {
                            stats.miss_per_class[q.class] += 1;
                        }
                    }
                    stats.max_completion = stats.max_completion.max(completion);
                }
            }};
        }

        while let Some(Reverse(ev)) = heap.pop() {
            let now = ev.t;
            match ev.ev {
                Ev::Arrival { class } => {
                    if queue.len() >= cap {
                        stats.shed += 1;
                        stats.shed_per_class[class] += 1;
                        continue;
                    }
                    if self.config.policy == OverloadPolicy::Shed {
                        // Projection: wait for the earliest replica, plus
                        // a full-batch service per max_batch requests
                        // already queued ahead, plus own batch service.
                        let earliest = busy.iter().cloned().fold(f64::INFINITY, f64::min);
                        let batches_ahead = (queue.len() / self.config.max_batch) as f64;
                        let proj = (earliest.max(now) - now)
                            + batches_ahead * self.config.max_batch as f64 * d_total
                            + d_total;
                        if proj > self.config.classes[class].deadline_s {
                            stats.shed += 1;
                            stats.shed_per_class[class] += 1;
                            continue;
                        }
                    }
                    queue.push_back(Queued { arrive: now, class });
                    heap.push(Reverse(Event { t: now + max_wait, seq, ev: Ev::Flush }));
                    seq += 1;
                    try_cut!(now, false);
                }
                Ev::Flush | Ev::Free => try_cut!(now, false),
            }
        }
        // Drain stragglers (possible only with max_wait = ∞-ish configs).
        while !queue.is_empty() {
            let r = (0..busy.len())
                .min_by(|&a, &b| busy[a].total_cmp(&busy[b]).then(a.cmp(&b)))
                .expect("new() asserts replicas > 0, so busy is non-empty");
            let now = busy[r].max(stats.max_completion.max(queue[0].arrive));
            try_cut!(now, true);
        }
    }

    /// Sweep offered load at `fractions` of [`Self::capacity_rps`] and
    /// identify the knee.
    pub fn sweep(&self, fractions: &[f64]) -> SweepReport {
        let cap = self.capacity_rps();
        let points: Vec<LoadPoint> =
            fractions.iter().map(|f| self.run_point_at(f * cap)).collect();
        let base_p99 = points.first().map(|p| p.latency.p99).unwrap_or(0.0);
        let mut knee_index = None;
        for (i, p) in points.iter().enumerate() {
            if p.latency.p99 <= KNEE_FACTOR * base_p99 {
                knee_index = Some(i);
            } else {
                break;
            }
        }
        let knee_rps = knee_index.map(|i| points[i].offered_rps).unwrap_or(0.0);
        let past = knee_index
            .map(|i| (i + 1).min(points.len() - 1))
            .unwrap_or(points.len() - 1);
        SweepReport {
            discipline: self.config.discipline,
            capacity_rps: cap,
            knee_rps,
            knee_index,
            bottleneck_past_knee: points[past].breakdown.bottleneck(),
            points,
        }
    }
}

impl SweepReport {
    /// Human-readable sweep table with knee and attribution.
    pub fn render(&self) -> String {
        let mut out = String::new();
        out.push_str(&format!(
            "discipline={:?} capacity={:.0} rps knee={:.0} rps bottleneck_past_knee={}\n",
            self.discipline, self.capacity_rps, self.knee_rps, self.bottleneck_past_knee
        ));
        out.push_str(
            "offered_rps     users   served    shed  p50_ms  p99_ms  thru_rps  mean_batch  bottleneck\n",
        );
        for p in &self.points {
            out.push_str(&format!(
                "{:>11.0} {:>9} {:>8} {:>7} {:>7.3} {:>7.3} {:>9.0} {:>11.2}  {}\n",
                p.offered_rps,
                p.users,
                p.served,
                p.shed,
                p.latency.p50 * 1e3,
                p.latency.p99 * 1e3,
                p.throughput_rps,
                p.mean_batch,
                p.breakdown.bottleneck(),
            ));
        }
        out
    }

    /// Deterministic JSON (sorted keys) for the bench trajectory.
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("discipline", Json::Str(format!("{:?}", self.discipline))),
            ("capacity_rps", Json::Num(self.capacity_rps)),
            ("knee_rps", Json::Num(self.knee_rps)),
            (
                "knee_index",
                self.knee_index.map(|i| Json::Num(i as f64)).unwrap_or(Json::Null),
            ),
            ("bottleneck_past_knee", Json::Str(self.bottleneck_past_knee.into())),
            (
                "points",
                Json::Arr(
                    self.points
                        .iter()
                        .map(|p| {
                            Json::obj(vec![
                                ("offered_rps", Json::Num(p.offered_rps)),
                                ("users", Json::Num(p.users as f64)),
                                ("served", Json::Num(p.served as f64)),
                                ("shed", Json::Num(p.shed as f64)),
                                ("deadline_misses", Json::Num(p.deadline_misses as f64)),
                                ("p50_s", Json::Num(p.latency.p50)),
                                ("p99_s", Json::Num(p.latency.p99)),
                                ("throughput_rps", Json::Num(p.throughput_rps)),
                                ("mean_batch", Json::Num(p.mean_batch)),
                                ("batcher_s", Json::Num(p.breakdown.batcher_s)),
                                ("router_s", Json::Num(p.breakdown.router_s)),
                                ("adc_s", Json::Num(p.breakdown.adc_s)),
                                ("transfer_s", Json::Num(p.breakdown.transfer_s)),
                                ("pipeline_s", Json::Num(p.breakdown.pipeline_s)),
                                (
                                    "bottleneck",
                                    Json::Str(p.breakdown.bottleneck().into()),
                                ),
                            ])
                        })
                        .collect(),
                ),
            ),
        ])
    }
}

/// The front door for the e2e ResNet-18 profile: stage latencies from
/// [`BankScheduler::layer_costs`] on the default slice geometry.
pub fn resnet_front_door(width: usize, replicas: usize) -> FrontDoor {
    let mut sched = BankScheduler::new(
        BankScheduler::resnet18_layers(width),
        crate::cache::addr::Geometry::default(),
        crate::cache::controller::PimIntegration::Retained,
    )
    .expect("default slice fits the serving network");
    sched.program_network();
    let stages: Vec<f64> = sched.layer_costs(1).iter().map(|c| c.latency_s).collect();
    FrontDoor::new(FrontDoorConfig::for_network(stages, replicas))
}

// ---------------------------------------------------------------------
// M/D/c analytic cross-check
// ---------------------------------------------------------------------

/// Closed-form M/D/c waiting-time distribution: Crommelin's embedded
/// recursion for the stationary queue-length distribution and Franx's
/// finite-sum formula for `P(W ≤ x)` — the analytic pin for the
/// simulator's validation mode (see [`queueing_crosscheck`]).
pub mod mdc {
    /// Stationary distribution of the number in system observed at
    /// multiples of the (deterministic) service time `d`: with `c`
    /// servers every customer in service at `t` departs by `t + d` and
    /// nobody who starts after `t` does, so `L' = (L − c)⁺ + A` with
    /// `A ~ Poisson(λd)` (Crommelin, 1932). Iterated to a fixed point.
    pub fn stationary(lambda: f64, d: f64, c: usize) -> Vec<f64> {
        let rho = lambda * d / c as f64;
        assert!(rho < 1.0, "M/D/c requires rho < 1 (rho = {rho})");
        // Poisson(λd) pmf, truncated at a negligible tail.
        let mean = lambda * d;
        let mut a = vec![(-mean).exp()];
        let mut cum = a[0];
        while 1.0 - cum > 1e-14 && a.len() < 2048 {
            let j = a.len();
            let next = a[j - 1] * mean / j as f64;
            a.push(next);
            cum += next;
        }
        let mut p = vec![1.0f64];
        for _ in 0..200_000 {
            let mut next = vec![0.0f64; p.len() + a.len()];
            for (i, &pi) in p.iter().enumerate() {
                if pi <= 0.0 {
                    continue;
                }
                let shift = i.saturating_sub(c);
                for (j, &aj) in a.iter().enumerate() {
                    next[shift + j] += pi * aj;
                }
            }
            // Truncate the (geometric) tail so the state space stays
            // bounded; renormalize to keep a proper distribution.
            while next.len() > 1 && *next.last().unwrap() < 1e-16 {
                next.pop();
            }
            if next.len() > 4096 {
                next.truncate(4096);
            }
            let mass: f64 = next.iter().sum();
            for x in next.iter_mut() {
                *x /= mass;
            }
            let diff: f64 = next
                .iter()
                .zip(p.iter().chain(std::iter::repeat(&0.0)))
                .map(|(x, y)| (x - y).abs())
                .sum();
            p = next;
            if diff < 1e-13 {
                break;
            }
        }
        p
    }

    /// `P(W ≤ x)` for the queueing delay `W` of M/D/c (Franx, 2001):
    /// with `k` such that `(k−1)d ≤ x < kd`,
    /// `P(W ≤ x) = Σ_{j=0}^{kc−1} Q^q_{kc−1−j} e^{−λ(kd−x)} (λ(kd−x))^j / j!`
    /// where `Q^q_n = P((L−c)⁺ ≤ n) = P(L ≤ n+c)` is the stationary CDF
    /// of the *queue length* (waiting customers), derived from the
    /// system-size distribution `p` of [`stationary`].
    ///
    /// Derivation sketch: a customer arriving at `t` waits ≤ x iff at
    /// most `c−1` predecessors remain at `t+x`. Observing the
    /// predecessor-only process at epochs `s + jd` from `s = t−(kd−x)`,
    /// each epoch removes exactly `min(·, c)` predecessors (deterministic
    /// service), and all predecessor arrivals after `s` fall in the first
    /// epoch — Poisson with mean `λ(kd−x)`. The condition collapses to
    /// `(L(s)−c)⁺ + A ≤ kc−1`. (Sanity pins: continuity at `x = d` via
    /// the stationary recursion, and `P(W ≤ 0) = P(L < c)` via PASTA.)
    pub fn wait_cdf(lambda: f64, d: f64, c: usize, x: f64, p: &[f64]) -> f64 {
        if x < 0.0 {
            return 0.0;
        }
        let q = |n: usize| -> f64 {
            if n + 1 >= p.len() {
                1.0
            } else {
                p[..=n].iter().sum()
            }
        };
        let k = (x / d).floor() as usize + 1;
        let y = lambda * (k as f64 * d - x);
        let mut term = (-y).exp(); // j = 0
        let mut sum = 0.0;
        for j in 0..k * c {
            // Queue-length CDF at kc−1−j = system-size CDF at kc−1−j+c.
            sum += q(k * c + c - 1 - j) * term;
            term *= y / (j + 1) as f64;
        }
        sum.clamp(0.0, 1.0)
    }

    /// `q`-quantile (0 < q < 1) of the *sojourn* time `W + d` by
    /// bisection on [`wait_cdf`].
    pub fn latency_percentile(lambda: f64, d: f64, c: usize, q: f64) -> f64 {
        let p = stationary(lambda, d, c);
        let mut hi = d;
        while wait_cdf(lambda, d, c, hi, &p) < q {
            hi *= 2.0;
            assert!(hi < 1e9 * d, "quantile bisection diverged");
        }
        let mut lo = 0.0;
        for _ in 0..200 {
            let mid = 0.5 * (lo + hi);
            if wait_cdf(lambda, d, c, mid, &p) < q {
                lo = mid;
            } else {
                hi = mid;
            }
        }
        0.5 * (lo + hi) + d
    }
}

/// The simulator-vs-theory comparison at one utilization.
#[derive(Clone, Copy, Debug)]
pub struct QueueCrossCheck {
    /// Target utilization λ·D/c.
    pub rho: f64,
    /// Servers.
    pub replicas: usize,
    /// Deterministic service time (s).
    pub service_s: f64,
    /// Simulated sojourn p50 (s).
    pub sim_p50_s: f64,
    /// Simulated sojourn p99 (s).
    pub sim_p99_s: f64,
    /// Analytic M/D/c sojourn p50 (s).
    pub analytic_p50_s: f64,
    /// Analytic M/D/c sojourn p99 (s).
    pub analytic_p99_s: f64,
}

impl QueueCrossCheck {
    /// Are both percentiles within `tol` relative error of theory?
    pub fn within(&self, tol: f64) -> bool {
        let rel = |s: f64, a: f64| (s - a).abs() / a;
        rel(self.sim_p50_s, self.analytic_p50_s) <= tol
            && rel(self.sim_p99_s, self.analytic_p99_s) <= tol
    }

    /// Deterministic JSON for the bench trajectory.
    pub fn to_json(&self, tol: f64) -> Json {
        Json::obj(vec![
            ("rho", Json::Num(self.rho)),
            ("replicas", Json::Num(self.replicas as f64)),
            ("service_s", Json::Num(self.service_s)),
            ("sim_p50_s", Json::Num(self.sim_p50_s)),
            ("sim_p99_s", Json::Num(self.sim_p99_s)),
            ("analytic_p50_s", Json::Num(self.analytic_p50_s)),
            ("analytic_p99_s", Json::Num(self.analytic_p99_s)),
            ("tolerance", Json::Num(tol)),
            ("within_tolerance", Json::Bool(self.within(tol))),
        ])
    }
}

/// Pin the simulator against closed-form M/D/c: validation mode strips
/// every serving feature the theory does not model — `max_batch = 1`,
/// zero formation wait, admission disabled — leaving exactly `c`
/// deterministic servers behind a FIFO queue under Poisson(λ) arrivals
/// (greedy earliest-free assignment is FIFO-equivalent when all service
/// times are the same constant). The simulated sojourn percentiles must
/// then match Franx's formula.
pub fn queueing_crosscheck(
    service_s: f64,
    replicas: usize,
    rho: f64,
    requests: usize,
    seed: u64,
) -> QueueCrossCheck {
    let lambda = rho * replicas as f64 / service_s;
    let door = FrontDoor::new(FrontDoorConfig {
        replicas,
        layer_latencies_s: vec![service_s],
        hop_stages: Vec::new(),
        max_batch: 1,
        max_wait_s: 0.0,
        queue_cap: usize::MAX / 4,
        discipline: Discipline::DrainBatch,
        policy: OverloadPolicy::Delay,
        classes: vec![TenantClass {
            name: "validation".into(),
            weight: 1.0,
            deadline_s: f64::INFINITY,
        }],
        arrival: ArrivalProcess::Poisson { rate_rps: lambda },
        seed,
        requests,
        user_rps: 1.0,
    });
    let point = door.run_point_at(lambda);
    QueueCrossCheck {
        rho,
        replicas,
        service_s,
        sim_p50_s: point.latency.p50,
        sim_p99_s: point.latency.p99,
        analytic_p50_s: mdc::latency_percentile(lambda, service_s, replicas, 0.50),
        analytic_p99_s: mdc::latency_percentile(lambda, service_s, replicas, 0.99),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn toy_config(discipline: Discipline) -> FrontDoorConfig {
        // Bottleneck stage = 1/6 of the total: continuous capacity is 6×
        // the drain capacity.
        let mut cfg = FrontDoorConfig::for_network(vec![4e-4, 4e-4, 4e-4, 4e-4, 4e-4, 4e-4], 4);
        cfg.discipline = discipline;
        cfg.requests = 1200;
        cfg
    }

    #[test]
    fn mdc_matches_md1_mean_wait() {
        // M/D/1 with rho = 0.7: E[Wq] = rho·D / (2(1 − rho)).
        let (lambda, d) = (0.7, 1.0);
        let p = mdc::stationary(lambda, d, 1);
        let expected = 0.7 * d / (2.0 * 0.3);
        // E[Wq] = ∫ (1 − F(x)) dx, trapezoid.
        let (mut mean, step) = (0.0, d / 200.0);
        let mut x = 0.0;
        while x < 60.0 * d {
            let f0 = 1.0 - mdc::wait_cdf(lambda, d, 1, x, &p);
            let f1 = 1.0 - mdc::wait_cdf(lambda, d, 1, x + step, &p);
            mean += 0.5 * (f0 + f1) * step;
            x += step;
        }
        assert!(
            (mean - expected).abs() / expected < 0.02,
            "E[Wq] = {mean}, Pollaczek–Khinchine says {expected}"
        );
    }

    #[test]
    fn mdc_cdf_is_monotone_and_proper() {
        let p = mdc::stationary(3.0, 1.0, 4); // rho = 0.75
        let mut prev = 0.0;
        for i in 0..400 {
            let x = i as f64 * 0.05;
            let f = mdc::wait_cdf(3.0, 1.0, 4, x, &p);
            assert!((0.0..=1.0).contains(&f));
            assert!(f + 1e-12 >= prev, "cdf must be monotone at x = {x}");
            prev = f;
        }
        assert!(prev > 0.999, "cdf must approach 1 (got {prev})");
    }

    #[test]
    fn crosscheck_simulation_matches_theory() {
        let cc = queueing_crosscheck(2e-3, 4, 0.8, 12_000, 42);
        assert!(
            cc.within(0.10),
            "sim (p50 {}, p99 {}) vs analytic (p50 {}, p99 {})",
            cc.sim_p50_s,
            cc.sim_p99_s,
            cc.analytic_p50_s,
            cc.analytic_p99_s
        );
    }

    #[test]
    fn continuous_knee_beyond_drain_knee() {
        let drain = FrontDoor::new(toy_config(Discipline::DrainBatch));
        let cont = FrontDoor::new(toy_config(Discipline::Continuous));
        let fr = [0.3, 0.6, 0.8, 0.9, 1.05];
        let rd = drain.sweep(&fr);
        let rc = cont.sweep(&fr);
        assert!(rc.capacity_rps > 4.0 * rd.capacity_rps, "pipeline capacity ≈ 6×");
        assert!(
            rc.knee_rps > rd.knee_rps,
            "continuous knee {} must sit beyond drain knee {}",
            rc.knee_rps,
            rd.knee_rps
        );
        // Above the knee the pipeline actually holds multiple requests.
        let last = rc.points.last().unwrap();
        assert!(last.mean_batch > 1.0, "mean co-residency = {}", last.mean_batch);
    }

    #[test]
    fn sweep_is_deterministic() {
        let door = FrontDoor::new(toy_config(Discipline::Continuous));
        let a = door.sweep(&[0.5, 0.9]).to_json().to_string();
        let b = door.sweep(&[0.5, 0.9]).to_json().to_string();
        assert_eq!(a, b);
    }

    #[test]
    fn shed_policy_bounds_the_tail_at_overload() {
        let mut cfg = toy_config(Discipline::Continuous);
        cfg.classes[0].deadline_s = 4.0 * cfg.service_total_s();
        let door = FrontDoor::new(cfg);
        let p = door.run_point_at(1.6 * door.capacity_rps());
        assert!(p.shed > 0, "overload must shed");
        // Everything served was projected (and landed) near the deadline.
        let bound = 4.0 * door.config.service_total_s() + door.config.service_total_s();
        assert!(p.latency.p99 <= bound, "p99 {} vs bound {bound}", p.latency.p99);
    }

    #[test]
    fn delay_policy_overflow_backpressure() {
        let mut cfg = toy_config(Discipline::Continuous);
        cfg.policy = OverloadPolicy::Delay;
        cfg.queue_cap = 4;
        let door = FrontDoor::new(cfg);
        let p = door.run_point_at(2.0 * door.capacity_rps());
        assert!(p.shed > 0, "bounded queue must shed overflow under 2× load");
        assert!(p.served > 0);
    }

    #[test]
    fn arrival_processes_hit_their_mean_rate() {
        for proc in [
            ArrivalProcess::Poisson { rate_rps: 500.0 },
            ArrivalProcess::Diurnal { mean_rps: 500.0, swing: 0.6, period_s: 2.0 },
            ArrivalProcess::Burst { base_rps: 250.0, burst_mult: 5.0, period_s: 0.5, duty: 0.25 },
        ] {
            let mut rng = Pcg64::new(7, 1);
            let mut t = 0.0;
            let n = 4000;
            for _ in 0..n {
                t = proc.next(t, &mut rng);
            }
            let empirical = n as f64 / t;
            let mean = proc.mean_rate();
            assert!(
                (empirical - mean).abs() / mean < 0.1,
                "{proc:?}: empirical {empirical} vs mean {mean}"
            );
        }
    }

    #[test]
    fn with_mean_preserves_shape_and_rescales() {
        let b = ArrivalProcess::Burst { base_rps: 100.0, burst_mult: 4.0, period_s: 1.0, duty: 0.5 };
        let b2 = b.with_mean(1000.0);
        assert!((b2.mean_rate() - 1000.0).abs() < 1e-9);
        assert!((b2.peak_rate() / b2.mean_rate() - b.peak_rate() / b.mean_rate()).abs() < 1e-9);
    }

    #[test]
    fn attribution_sums_to_mean_latency() {
        let door = FrontDoor::new(toy_config(Discipline::Continuous));
        let p = door.run_point_at(0.9 * door.capacity_rps());
        let sum = p.breakdown.batcher_s + p.breakdown.router_s + p.breakdown.adc_s
            + p.breakdown.transfer_s + p.breakdown.pipeline_s;
        assert!(
            (sum - p.latency.mean).abs() < 1e-9 * p.latency.mean.max(1e-12),
            "components {sum} must reassemble the mean {}",
            p.latency.mean
        );
        assert_eq!(p.breakdown.transfer_s, 0.0, "no hops without a shard chain");
    }

    #[test]
    fn shard_pipeline_attributes_transfer_hops() {
        // Two shard segments of two stages each, one hop between them.
        let groups = vec![vec![4e-4, 4e-4], vec![4e-4, 4e-4]];
        let hops = vec![1e-4];
        let cfg = FrontDoorConfig::for_shard_pipeline(&groups, &hops, 2);
        assert_eq!(cfg.layer_latencies_s, vec![4e-4, 4e-4, 1e-4, 4e-4, 4e-4]);
        assert_eq!(cfg.hop_stages, vec![2]);
        assert!((cfg.service_compute_s() - 16e-4).abs() < 1e-15);
        assert!((cfg.service_hops_s() - 1e-4).abs() < 1e-15);
        let door = FrontDoor::new(cfg);
        let p = door.run_point_at(0.8 * door.capacity_rps());
        assert!(p.served > 0);
        // Every served request walks the hop exactly once.
        assert!(
            (p.breakdown.transfer_s - 1e-4).abs() < 1e-12,
            "per-request transfer {} must equal the hop latency",
            p.breakdown.transfer_s
        );
        assert!((p.breakdown.adc_s - 16e-4).abs() < 1e-12);
        let sum = p.breakdown.batcher_s + p.breakdown.router_s + p.breakdown.adc_s
            + p.breakdown.transfer_s + p.breakdown.pipeline_s;
        assert!(
            (sum - p.latency.mean).abs() < 1e-9 * p.latency.mean.max(1e-12),
            "hop-staged components {sum} must reassemble the mean {}",
            p.latency.mean
        );
        // Drain discipline splits the same way (whole-batch service).
        let mut drain_cfg = FrontDoorConfig::for_shard_pipeline(&groups, &hops, 2);
        drain_cfg.discipline = Discipline::DrainBatch;
        let dp = FrontDoor::new(drain_cfg).run_point_at(10.0);
        assert!(dp.breakdown.transfer_s > 0.0, "drain mode must also attribute hops");
    }

    #[test]
    fn event_ordering_survives_nan_times() {
        // A NaN event time must not wedge or panic the heap: total_cmp
        // gives Event a genuine total order (NaN sorts above every finite
        // time), so the heap drains deterministically.
        let mut heap: BinaryHeap<Reverse<Event>> = BinaryHeap::new();
        for (seq, t) in [(0u64, 1.0f64), (1, f64::NAN), (2, 0.5), (3, f64::NAN)] {
            heap.push(Reverse(Event { t, seq, ev: Ev::Free }));
        }
        let order: Vec<u64> = std::iter::from_fn(|| heap.pop().map(|Reverse(e)| e.seq)).collect();
        assert_eq!(order, vec![2, 0, 1, 3], "finite times first, NaNs last in seq order");
        // And the ordering is consistent (Ord contract): reflexive
        // equality even for NaN-carrying events.
        let e = Event { t: f64::NAN, seq: 9, ev: Ev::Flush };
        assert_eq!(e, e);
        assert_eq!(e.cmp(&e), std::cmp::Ordering::Equal);
    }

    #[test]
    fn bottleneck_is_defined_for_nan_components() {
        let b = ComponentBreakdown {
            batcher_s: 1.0,
            router_s: f64::NAN,
            adc_s: 2.0,
            transfer_s: 0.0,
            pipeline_s: 3.0,
        };
        // NaN sorts above every finite value under total_cmp, so the
        // broken component is surfaced rather than panicking.
        assert_eq!(b.bottleneck(), "router");
        let ok = ComponentBreakdown {
            batcher_s: 1.0,
            router_s: 0.5,
            adc_s: 2.0,
            transfer_s: 4.0,
            pipeline_s: 3.0,
        };
        assert_eq!(ok.bottleneck(), "transfer");
    }
}
