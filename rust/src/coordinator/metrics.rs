//! Serving metrics: latency histograms, counters, throughput/efficiency
//! accounting.

use std::time::Instant;

use crate::util::rng::Pcg64;

/// Log-bucketed latency histogram (1 µs … ~100 s, 4 buckets/decade).
#[derive(Clone, Debug)]
pub struct LatencyHistogram {
    buckets: Vec<u64>,
    /// Raw samples kept for exact percentiles (uniform reservoir).
    samples: Vec<f64>,
    max_samples: usize,
    /// Reservoir-replacement RNG (fixed stream: the histogram stays
    /// deterministic for a given record sequence).
    rng: Pcg64,
    /// Total samples recorded.
    pub count: u64,
    /// Sum of all recorded latencies (s).
    pub sum_s: f64,
}

const BUCKETS_PER_DECADE: usize = 4;
const N_DECADES: usize = 8; // 1e-6 .. 1e2 s

impl LatencyHistogram {
    /// Empty histogram.
    pub fn new() -> LatencyHistogram {
        Self::with_max_samples(65_536)
    }

    /// Empty histogram retaining at most `max_samples` raw samples for the
    /// exact-percentile reservoir.
    pub fn with_max_samples(max_samples: usize) -> LatencyHistogram {
        assert!(max_samples > 0);
        LatencyHistogram {
            buckets: vec![0; BUCKETS_PER_DECADE * N_DECADES],
            samples: Vec::new(),
            max_samples,
            rng: Pcg64::new(0x5eed_1a7e, 0x9e37),
            count: 0,
            sum_s: 0.0,
        }
    }

    fn bucket_of(latency_s: f64) -> usize {
        let log = (latency_s.max(1e-6) / 1e-6).log10();
        ((log * BUCKETS_PER_DECADE as f64) as usize).min(BUCKETS_PER_DECADE * N_DECADES - 1)
    }

    /// Record one latency sample (s).
    pub fn record(&mut self, latency_s: f64) {
        self.buckets[Self::bucket_of(latency_s)] += 1;
        self.count += 1;
        self.sum_s += latency_s;
        if self.samples.len() < self.max_samples {
            self.samples.push(latency_s);
        } else {
            // Reservoir sampling (Algorithm R): the i-th sample replaces a
            // random slot with probability k/i, so the reservoir stays a
            // uniform sample of the whole stream — not a recency-biased
            // window, which would skew exact percentiles after the wrap.
            let j = self.rng.below(self.count as usize);
            if j < self.max_samples {
                self.samples[j] = latency_s;
            }
        }
    }

    /// Mean latency (s), 0 when empty.
    pub fn mean(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.sum_s / self.count as f64
        }
    }

    /// Percentile `p` (0–100) over the retained samples.
    ///
    /// `total_cmp` sort: a single NaN sample (e.g. an upstream 0/0 in a
    /// latency computation) sorts to the top instead of panicking the
    /// metrics thread mid-report, so every other percentile stays
    /// readable.
    pub fn percentile(&self, p: f64) -> f64 {
        if self.samples.is_empty() {
            return 0.0;
        }
        let mut s = self.samples.clone();
        s.sort_by(|a, b| a.total_cmp(b));
        crate::util::stats::percentile_sorted(&s, p)
    }
}

impl Default for LatencyHistogram {
    fn default() -> Self {
        Self::new()
    }
}

/// Aggregated serving metrics.
#[derive(Clone, Debug)]
pub struct Metrics {
    /// Server start time (throughput denominator).
    pub started: Instant,
    /// Requests ingested.
    pub requests: u64,
    /// Responses delivered.
    pub responses: u64,
    /// Batches executed.
    pub batches: u64,
    /// Sum of batch sizes (for the mean).
    pub batch_size_sum: u64,
    /// End-to-end (enqueue → response) latency.
    pub e2e_latency: LatencyHistogram,
    /// Queue (enqueue → execution start) latency.
    pub queue_latency: LatencyHistogram,
    /// Simulated hardware MAC ops executed.
    pub hw_ops: f64,
    /// Simulated hardware energy (J).
    pub hw_energy_j: f64,
    /// Simulated hardware busy time (s).
    pub hw_time_s: f64,
}

impl Metrics {
    /// Fresh metrics anchored at now.
    pub fn new() -> Metrics {
        Metrics {
            started: Instant::now(),
            requests: 0,
            responses: 0,
            batches: 0,
            batch_size_sum: 0,
            e2e_latency: LatencyHistogram::new(),
            queue_latency: LatencyHistogram::new(),
            hw_ops: 0.0,
            hw_energy_j: 0.0,
            hw_time_s: 0.0,
        }
    }

    /// Record one executed batch and its simulated hardware cost.
    pub fn record_batch(&mut self, size: usize, hw_ops: f64, hw_energy: f64, hw_time: f64) {
        self.batches += 1;
        self.batch_size_sum += size as u64;
        self.hw_ops += hw_ops;
        self.hw_energy_j += hw_energy;
        self.hw_time_s += hw_time;
    }

    /// Mean executed batch size.
    pub fn mean_batch_size(&self) -> f64 {
        if self.batches == 0 {
            0.0
        } else {
            self.batch_size_sum as f64 / self.batches as f64
        }
    }

    /// Wall-clock request throughput (req/s).
    pub fn request_throughput(&self) -> f64 {
        let dt = self.started.elapsed().as_secs_f64().max(1e-9);
        self.responses as f64 / dt
    }

    /// Simulated hardware efficiency (OPS/W).
    pub fn hw_ops_per_w(&self) -> f64 {
        if self.hw_energy_j <= 0.0 {
            0.0
        } else {
            self.hw_ops / self.hw_energy_j
        }
    }

    /// Simulated hardware throughput (OPS).
    pub fn hw_ops_per_s(&self) -> f64 {
        if self.hw_time_s <= 0.0 {
            0.0
        } else {
            self.hw_ops / self.hw_time_s
        }
    }

    /// Render a human-readable summary block.
    pub fn report(&self) -> String {
        format!(
            "requests={} responses={} batches={} mean_batch={:.2}\n\
             e2e: mean {:.3} ms p50 {:.3} ms p95 {:.3} ms p99 {:.3} ms\n\
             queue: mean {:.3} ms p95 {:.3} ms\n\
             hw: {:.3e} ops, {:.3} GOPS busy, {:.2} TOPS/W",
            self.requests,
            self.responses,
            self.batches,
            self.mean_batch_size(),
            self.e2e_latency.mean() * 1e3,
            self.e2e_latency.percentile(50.0) * 1e3,
            self.e2e_latency.percentile(95.0) * 1e3,
            self.e2e_latency.percentile(99.0) * 1e3,
            self.queue_latency.mean() * 1e3,
            self.queue_latency.percentile(95.0) * 1e3,
            self.hw_ops,
            self.hw_ops_per_s() / 1e9,
            self.hw_ops_per_w() / 1e12,
        )
    }
}

impl Default for Metrics {
    fn default() -> Self {
        Self::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn histogram_percentiles() {
        let mut h = LatencyHistogram::new();
        for i in 1..=100 {
            h.record(i as f64 * 1e-3);
        }
        assert_eq!(h.count, 100);
        assert!((h.mean() - 0.0505).abs() < 1e-6);
        let p50 = h.percentile(50.0);
        assert!(p50 > 0.045 && p50 < 0.056, "p50 = {p50}");
        let p99 = h.percentile(99.0);
        assert!(p99 > 0.095, "p99 = {p99}");
    }

    #[test]
    fn reservoir_percentiles_unbiased_after_wrap() {
        // Regression for the old `count % max_samples` overwrite, which
        // retained only the most recent window once the ring wrapped: an
        // ascending stream then reported a p50 near the stream's *end*.
        let k = 512;
        let n = 20_000u64;
        let mut h = LatencyHistogram::with_max_samples(k);
        for i in 0..n {
            h.record(i as f64 * 1e-6);
        }
        assert_eq!(h.count, n);
        let true_p50 = (n / 2) as f64 * 1e-6;
        let p50 = h.percentile(50.0);
        // A uniform 512-sample reservoir puts the median estimate well
        // within ±25 % of the true median (seeded RNG ⇒ deterministic).
        assert!(
            (p50 - true_p50).abs() < 0.25 * true_p50,
            "p50 = {p50}, true = {true_p50}"
        );
        // The recency-window failure mode sat in the top ~2.5 % of the
        // stream; make sure we are nowhere near it.
        assert!(p50 < 0.75 * (n as f64 * 1e-6), "p50 biased toward recent samples");
    }

    #[test]
    fn nan_sample_does_not_panic_percentiles() {
        // Regression: one NaN latency used to panic the
        // partial_cmp().unwrap() sort inside percentile(), taking the
        // whole metrics report down. total_cmp sorts NaN above every
        // finite sample, so mid-range percentiles stay exact.
        let mut h = LatencyHistogram::new();
        for i in 1..=99 {
            h.record(i as f64 * 1e-3);
        }
        h.record(f64::NAN);
        let p50 = h.percentile(50.0);
        assert!(p50 > 0.045 && p50 < 0.056, "p50 = {p50}");
        // The poisoned sample surfaces only at the extreme tail.
        assert!(h.percentile(100.0).is_nan());
    }

    #[test]
    fn bucket_bounds() {
        assert_eq!(LatencyHistogram::bucket_of(1e-7), 0);
        assert!(LatencyHistogram::bucket_of(1e3) == BUCKETS_PER_DECADE * N_DECADES - 1);
    }

    #[test]
    fn metrics_accounting() {
        let mut m = Metrics::new();
        m.record_batch(8, 1e6, 1e-6, 1e-3);
        m.record_batch(4, 1e6, 1e-6, 1e-3);
        assert_eq!(m.mean_batch_size(), 6.0);
        assert!((m.hw_ops_per_w() - 1e12).abs() / 1e12 < 1e-9);
        assert!((m.hw_ops_per_s() - 1e9).abs() / 1e9 < 1e-9);
    }
}
