//! Analytic performance model (§V-D, Table I, Fig. 14).
//!
//! * [`model`] — the macro-level throughput/energy/area model, built from
//!   the per-op costs in [`crate::cell::timing`]; reproduces the paper's
//!   headline row (25.6 GOPS, 30.73 TOPS/W at 4b/4b; 0.4096 TOPS and
//!   491.78 TOPS/W normalized to 1 bit; ~0.1 mm² with the ADC ≈70 %) and
//!   the Fig. 14 scaling trends.
//! * [`comparison`] — Table I prior-work rows (constants from the cited
//!   papers) + our computed row.

pub mod comparison;
pub mod model;

pub use model::MacroModel;
