//! Table I: comparison with prior PIM designs.
//!
//! Prior-work rows are constants transcribed from the paper's Table I
//! (they are citations, not things we can re-measure); the "This Work" row
//! is *computed* from [`super::model::MacroModel`] so the bench verifies
//! our model regenerates the paper's own numbers.

use super::model::MacroModel;

/// One Table I row.
#[derive(Clone, Debug)]
pub struct ComparisonRow {
    /// Design name / citation.
    pub name: &'static str,
    /// Process technology.
    pub technology: &'static str,
    /// Array capacity.
    pub array_size: &'static str,
    /// Compute domain (current/charge/time).
    pub domain: &'static str,
    /// Bit-cell / memory type.
    pub memory_type: &'static str,
    /// Does the design retain cache data during PIM?
    pub cache_retention: bool,
    /// Reported CIFAR-10 accuracy (%), if any.
    pub accuracy_pct: Option<f64>,
    /// (input, weight) precision in bits.
    pub in_w_precision: (u32, u32),
    /// Output precision description.
    pub output_precision: &'static str,
    /// Raw throughput (GOPS).
    pub throughput_gops: f64,
    /// Raw efficiency (TOPS/W).
    pub efficiency_tops_w: f64,
    /// 1-bit-normalized throughput (TOPS).
    pub norm_throughput_tops: f64,
    /// 1-bit-normalized efficiency (TOPS/W).
    pub norm_efficiency_tops_w: f64,
    /// 1-bit-normalized compute density (TOPS/mm²).
    pub norm_density_tops_mm2: f64,
}

/// The prior-work rows (Table I constants).
pub fn prior_work() -> Vec<ComparisonRow> {
    vec![
        ComparisonRow {
            name: "TCASII'24 [35]",
            technology: "180nm CMOS",
            array_size: "8Kb",
            domain: "Time",
            memory_type: "6T SRAM + 9T",
            cache_retention: false,
            accuracy_pct: Some(86.1),
            in_w_precision: (8, 8),
            output_precision: "14-16 (TDC)",
            throughput_gops: 0.07,
            efficiency_tops_w: 0.291,
            norm_throughput_tops: 0.2,
            norm_efficiency_tops_w: 768.7,
            norm_density_tops_mm2: 0.9,
        },
        ComparisonRow {
            name: "ISSCC'23 [36]",
            technology: "28nm FDSOI",
            array_size: "16Kb",
            domain: "Charge",
            memory_type: "10T1C SRAM",
            cache_retention: false,
            accuracy_pct: None,
            in_w_precision: (8, 8),
            output_precision: "8",
            throughput_gops: 7.65,
            efficiency_tops_w: 16.02,
            norm_throughput_tops: 0.49,
            norm_efficiency_tops_w: 1025.2,
            norm_density_tops_mm2: 1.19,
        },
        ComparisonRow {
            name: "ISSCC'22 [37]",
            technology: "22nm FDSOI",
            array_size: "256Kb",
            domain: "Current",
            memory_type: "1T1R RRAM",
            cache_retention: false,
            accuracy_pct: Some(91.74),
            in_w_precision: (8, 8),
            output_precision: "19",
            throughput_gops: 142.2,
            efficiency_tops_w: 0.96,
            norm_throughput_tops: 5.1,
            norm_efficiency_tops_w: 61.8,
            norm_density_tops_mm2: 7.9,
        },
        ComparisonRow {
            name: "TCASI'23 [38]",
            technology: "65nm CMOS",
            array_size: "101Kb",
            domain: "Charge",
            memory_type: "10T1C SRAM",
            cache_retention: false,
            accuracy_pct: Some(88.6),
            in_w_precision: (8, 8),
            output_precision: "8",
            throughput_gops: 12.8,
            efficiency_tops_w: 10.3,
            norm_throughput_tops: 3.28,
            norm_efficiency_tops_w: 659.2,
            norm_density_tops_mm2: 1.52,
        },
        ComparisonRow {
            name: "TCASI'23 [39]",
            technology: "28nm FDSOI",
            array_size: "16Kb",
            domain: "Charge",
            memory_type: "6T SRAM",
            cache_retention: false,
            accuracy_pct: Some(85.07),
            in_w_precision: (4, 4),
            output_precision: "4",
            throughput_gops: 12.8,
            efficiency_tops_w: 16.1,
            norm_throughput_tops: 0.2,
            norm_efficiency_tops_w: 257.6,
            norm_density_tops_mm2: 3.59,
        },
        ComparisonRow {
            name: "JSSCC'24 [40]",
            technology: "22nm FDSOI",
            array_size: "256Kb",
            domain: "Current",
            memory_type: "1T1R MRAM",
            cache_retention: false,
            accuracy_pct: Some(90.25),
            in_w_precision: (4, 4),
            output_precision: "6",
            throughput_gops: 54.3,
            efficiency_tops_w: 5.26,
            norm_throughput_tops: 0.87,
            norm_efficiency_tops_w: 84.2,
            norm_density_tops_mm2: 10.9,
        },
    ]
}

/// The computed "This Work" row. `accuracy_pct` comes from the measured
/// Table II run (passed in from the artifact manifest when available).
pub fn this_work(accuracy_pct: Option<f64>) -> ComparisonRow {
    let h = MacroModel::default().headline();
    ComparisonRow {
        name: "This Work",
        technology: "22nm FDSOI (modeled)",
        array_size: "64Kb",
        domain: "Current",
        memory_type: "6T-2R SRAM+RRAM",
        cache_retention: true,
        accuracy_pct,
        in_w_precision: (4, 4),
        output_precision: "6",
        throughput_gops: h.ops_per_s / 1e9,
        efficiency_tops_w: h.ops_per_w / 1e12,
        norm_throughput_tops: h.norm_ops_per_s / 1e12,
        norm_efficiency_tops_w: h.norm_ops_per_w / 1e12,
        norm_density_tops_mm2: h.norm_tops_per_mm2,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn only_this_work_retains_cache_data() {
        // The paper's qualitative headline: every prior design loses the
        // cache contents; ours does not.
        assert!(prior_work().iter().all(|r| !r.cache_retention));
        assert!(this_work(None).cache_retention);
    }

    #[test]
    fn this_work_matches_paper_numbers() {
        let tw = this_work(Some(91.27));
        assert!((tw.throughput_gops - 25.6).abs() < 0.1);
        assert!((tw.norm_throughput_tops - 0.4096).abs() < 0.001);
        assert!((tw.norm_efficiency_tops_w - 491.78).abs() < 40.0);
    }

    #[test]
    fn normalization_rule_consistent() {
        // Table I note a: normalized = raw × in_bits × w_bits. Row [35] is
        // additionally technology-scaled to 28 nm by its authors (note b),
        // so the simple rule does not apply to it.
        for row in prior_work().iter().filter(|r| !r.name.contains("[35]")) {
            let (i, w) = row.in_w_precision;
            let expect = row.throughput_gops * (i * w) as f64 / 1000.0;
            // Prior rows were normalized by the original authors with
            // additional tech scaling in some cases — allow slack, but the
            // order of magnitude must hold.
            assert!(
                row.norm_throughput_tops / expect < 8.0
                    && expect / row.norm_throughput_tops < 8.0,
                "{}: {} vs {}",
                row.name,
                row.norm_throughput_tops,
                expect
            );
        }
    }
}
