//! The macro performance model.
//!
//! Structure (derived in EXPERIMENTS.md E8): one full 4b×4b MAC over a
//! 128×512 sub-array takes `2·bits` ADC windows of 160 ns (ADC-bound,
//! §V-D) and costs, per side×plane step:
//!
//!   E_step = E_array(active rows) + 128·(E_adc + E_wcc)
//!
//! with E_array ∝ active rows. The Fig. 14 trends all fall out of this:
//! throughput ∝ active rows × word columns per window; efficiency rises as
//! row/word utilization amortizes the conversion-fixed energy; larger
//! kernels amortize input streaming through IFM reuse; higher precision
//! amortizes the fixed per-invocation digital/streaming overhead in the
//! 1-bit-normalized metrics.

use crate::cell::timing::OpKind;
use crate::consts::{ARRAY_ROWS, ARRAY_WORDS, T_ADC_CONVERSION};
use crate::mapping::bit_serial::BitSerialSchedule;
use crate::mapping::conv_mapper::{ConvMapping, ConvShape};

/// Headline metrics for one macro configuration.
#[derive(Clone, Copy, Debug)]
pub struct MacroPerf {
    /// Raw throughput at the configured precision (OPS; MAC = 2 ops).
    pub ops_per_s: f64,
    /// Raw power (W).
    pub power_w: f64,
    /// Raw efficiency (OPS/W = OPS/J·s).
    pub ops_per_w: f64,
    /// Normalized-to-1-bit throughput (OPS · in_bits · w_bits).
    pub norm_ops_per_s: f64,
    /// Normalized efficiency.
    pub norm_ops_per_w: f64,
    /// Macro area (mm²).
    pub area_mm2: f64,
    /// Normalized compute density (TOPS/mm² · precision product · 1e-12).
    pub norm_tops_per_mm2: f64,
}

/// The analytic macro model.
#[derive(Clone, Copy, Debug)]
pub struct MacroModel {
    /// Input-activation precision (bits).
    pub act_bits: u32,
    /// Weight precision (bits).
    pub weight_bits: u32,
    /// Active rows per sub-array invocation (≤128).
    pub rows: usize,
    /// Active word columns (≤128).
    pub words: usize,
    /// Input-streaming overhead coefficient (per fresh input row per word
    /// of time relative to the MAC window) — calibrated so the Fig. 14(a)
    /// K: 3→7 throughput gain lands at ≈1.8× (see fig14 tests).
    pub io_overhead: f64,
    /// Fixed per-invocation digital/control energy as a fraction of the
    /// full-array step energy (amortized by precision, Fig. 14d).
    pub fixed_invocation_frac: f64,
}

impl Default for MacroModel {
    fn default() -> Self {
        MacroModel {
            act_bits: 4,
            weight_bits: 4,
            rows: ARRAY_ROWS,
            words: ARRAY_WORDS,
            io_overhead: 10.5,
            fixed_invocation_frac: 0.08,
        }
    }
}

/// Area model: §V-D — total macro ≈0.1 mm².
pub const AREA_MACRO_MM2: f64 = 0.1;
/// ADC share of the macro area (§V-D: ≈70 %).
pub const AREA_ADC_FRAC: f64 = 0.70;

impl MacroModel {
    /// Default model at a different input/weight precision.
    pub fn with_precision(act_bits: u32, weight_bits: u32) -> MacroModel {
        MacroModel { act_bits, weight_bits, ..Default::default() }
    }

    /// Energy of one side×plane step with `rows` active rows (J).
    pub fn step_energy(&self, rows: usize) -> f64 {
        let e_array_full = OpKind::PimArrayCycle.cost().1;
        let e_conv = OpKind::AdcConversion.cost().1 + OpKind::WccSample.cost().1;
        e_array_full * rows as f64 / ARRAY_ROWS as f64 + self.words as f64 * e_conv
    }

    /// One full multi-bit MAC over the sub-array: (latency s, energy J,
    /// ops done). Ops = rows × words × 2 (MAC = 2 ops) at the configured
    /// precision.
    pub fn full_mac(&self) -> (f64, f64, f64) {
        let sched = BitSerialSchedule::new(self.act_bits, self.weight_bits);
        let steps = sched.side_cycles as f64;
        let latency = steps * T_ADC_CONVERSION;
        let energy = steps * self.step_energy(self.rows)
            * (1.0 + self.fixed_invocation_frac / steps * 8.0);
        let ops = (self.rows * self.words) as f64 * 2.0 / sched.weight_nibbles as f64;
        (latency, energy, ops)
    }

    /// Headline metrics (Table I row "This Work" when defaults are used).
    pub fn headline(&self) -> MacroPerf {
        let (latency, energy, ops) = self.full_mac();
        let ops_per_s = ops / latency;
        let power = energy / latency;
        let ops_per_w = ops / energy;
        let precision = (self.act_bits * self.weight_bits) as f64;
        let norm_t = ops_per_s * precision;
        let norm_e = ops_per_w * precision;
        MacroPerf {
            ops_per_s,
            power_w: power,
            ops_per_w,
            norm_ops_per_s: norm_t,
            norm_ops_per_w: norm_e,
            area_mm2: AREA_MACRO_MM2,
            norm_tops_per_mm2: norm_t / AREA_MACRO_MM2 / 1e12,
        }
    }

    /// Energy breakdown fractions (array, adc, wcc, digital).
    pub fn energy_breakdown(&self) -> (f64, f64, f64, f64) {
        let e_array = OpKind::PimArrayCycle.cost().1 * self.rows as f64 / ARRAY_ROWS as f64;
        let e_adc = self.words as f64 * OpKind::AdcConversion.cost().1;
        let e_wcc = self.words as f64 * OpKind::WccSample.cost().1;
        let e_dig = (e_array + e_adc + e_wcc) * self.fixed_invocation_frac;
        let total = e_array + e_adc + e_wcc + e_dig;
        (e_array / total, e_adc / total, e_wcc / total, e_dig / total)
    }

    // ------------------------------------------------- Fig. 14 scaling

    /// Fig. 14(a): throughput/efficiency vs kernel size (IFM reuse
    /// amortizes the input-streaming overhead: fresh inputs per output
    /// step = K·stride of K² window pixels).
    pub fn fig14_kernel(&self, k: usize, d: usize) -> (f64, f64) {
        let shape = ConvShape { k, d, n: self.words, w: 16, stride: 1 };
        let m = ConvMapping::plan(shape);
        let (lat, energy, ops) = self.full_mac();
        // Input streaming stretches the effective window; reuse shrinks it.
        let fresh_frac = 1.0 - m.reuse_fraction();
        let t_eff = lat * (1.0 + self.io_overhead * fresh_frac / k as f64);
        // Input-movement energy per window: dominated by off-array fetch at
        // small K (this is the memory-wall premise of the paper's §I), and
        // amortized by IFM reuse at large K. The 20× multiplier on the
        // fresh fraction is calibrated so 3×3 → 7×7 gives the paper's ≈2×
        // efficiency gain.
        let e_io = energy * 20.0 * fresh_frac;
        (ops / t_eff, ops / (energy + e_io))
    }

    /// Fig. 14(b): vs input depth D — throughput ∝ active rows, efficiency
    /// amortizes the conversion-fixed energy over the active rows.
    pub fn fig14_depth(&self, d: usize) -> (f64, f64) {
        let tiles = d.div_ceil(ARRAY_ROWS);
        let sched = BitSerialSchedule::new(self.act_bits, self.weight_bits);
        let steps = sched.side_cycles as f64;
        let lat = steps * T_ADC_CONVERSION;
        // All tiles run in parallel (their conversions overlap): one window
        // completes D×words MACs.
        let ops = (d * self.words) as f64 * 2.0 / sched.weight_nibbles as f64;
        let mut energy = 0.0;
        let mut rem = d;
        for _ in 0..tiles {
            let rows = rem.min(ARRAY_ROWS);
            energy += steps * self.step_energy(rows);
            rem -= rows;
        }
        (ops / lat, ops / energy)
    }

    /// Fig. 14(c): vs output features N — throughput ∝ word columns,
    /// efficiency amortizes per-invocation fixed digital/streaming energy.
    pub fn fig14_features(&self, n: usize) -> (f64, f64) {
        let sched = BitSerialSchedule::new(self.act_bits, self.weight_bits);
        let steps = sched.side_cycles as f64;
        let lat = steps * T_ADC_CONVERSION;
        let words_total = n.div_ceil(4); // 4-bit words across tiles
        let ops = (self.rows * words_total) as f64 * 2.0 / sched.weight_nibbles as f64;
        let e_conv = OpKind::AdcConversion.cost().1 + OpKind::WccSample.cost().1;
        let e_array_share =
            OpKind::PimArrayCycle.cost().1 * (words_total as f64 / ARRAY_WORDS as f64);
        // Fixed per-invocation overhead does NOT scale with N — this is
        // what drives the efficiency gain.
        let e_fixed = self.step_energy(self.rows) * self.fixed_invocation_frac * 8.0;
        let energy = steps * (e_array_share + words_total as f64 * e_conv) + e_fixed;
        (ops / lat, ops / energy)
    }

    /// Fig. 14(d): vs input/weight precision, *normalized-to-1-bit*
    /// metrics at the multi-sub-array level.
    ///
    /// At the macro level alone, 8b/8b is normalized-neutral (4× the
    /// windows and 2× the word columns exactly cancel the 4× precision
    /// credit). The figure's gain comes from the *system-level fixed
    /// overhead* (input streaming across sub-arrays, digital collection)
    /// that is independent of precision and therefore amortized over p²
    /// normalized ops — modeled here as a fixed time/energy adder equal to
    /// `SYS_FIXED_MULT`× the 4b full-MAC cost (documented assumption; the
    /// paper's axis is unitless).
    pub fn fig14_precision(&self, bits: u32) -> (f64, f64) {
        const SYS_FIXED_MULT: f64 = 3.0;
        let base = MacroModel::default();
        let (lat4, e4, _) = base.full_mac();
        let m = MacroModel { act_bits: bits, weight_bits: bits, ..*self };
        let (lat, energy, ops) = m.full_mac();
        let p2 = (bits * bits) as f64;
        let thr = p2 * ops / (lat + SYS_FIXED_MULT * lat4);
        let eff = p2 * ops / (energy + SYS_FIXED_MULT * e4);
        (thr, eff)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn headline_matches_paper_table1_row() {
        let h = MacroModel::default().headline();
        // §V-D / Table I "This Work": 25.6 GOPS, ~30.73 TOPS/W raw;
        // 0.4096 TOPS and ~491.78 TOPS/W normalized to 1 bit.
        assert!((h.ops_per_s / 1e9 - 25.6).abs() < 0.01, "GOPS = {}", h.ops_per_s / 1e9);
        assert!(
            (h.ops_per_w / 1e12 - 30.73).abs() < 2.5,
            "TOPS/W = {}",
            h.ops_per_w / 1e12
        );
        assert!((h.norm_ops_per_s / 1e12 - 0.4096).abs() < 1e-4);
        assert!(
            (h.norm_ops_per_w / 1e12 - 491.78).abs() < 40.0,
            "norm TOPS/W = {}",
            h.norm_ops_per_w / 1e12
        );
        // Compute density ≈ 4.1–4.4 TOPS/mm² (paper: 4.37).
        assert!(h.norm_tops_per_mm2 > 3.8 && h.norm_tops_per_mm2 < 4.6);
    }

    #[test]
    fn energy_breakdown_array_dominates() {
        // §V-D: "the 6T-2R array … accounts for approximately 60 % of the
        // total energy, followed by the ADC and the WCC block".
        let (array, adc, wcc, _dig) = MacroModel::default().energy_breakdown();
        assert!((array - 0.60).abs() < 0.08, "array = {array}");
        assert!(adc < array && adc > wcc, "adc = {adc}, wcc = {wcc}");
    }

    #[test]
    fn fig14a_kernel_scaling() {
        // 3×3 → 7×7: ≈1.8× throughput, ≈2× efficiency (paper numbers).
        let m = MacroModel::default();
        let (t3, e3) = m.fig14_kernel(3, 64);
        let (t7, e7) = m.fig14_kernel(7, 64);
        let tr = t7 / t3;
        let er = e7 / e3;
        assert!(tr > 1.5 && tr < 2.2, "thr ratio = {tr}");
        assert!(er > 1.6 && er < 2.4, "eff ratio = {er}");
    }

    #[test]
    fn fig14b_depth_scaling() {
        // D: 32 → 256: throughput ≈8×, efficiency more than doubles.
        let m = MacroModel::default();
        let (t32, e32) = m.fig14_depth(32);
        let (t256, e256) = m.fig14_depth(256);
        assert!((t256 / t32 - 8.0).abs() < 0.01, "thr ratio = {}", t256 / t32);
        let er = e256 / e32;
        assert!(er > 2.0 && er < 3.2, "eff ratio = {er}");
    }

    #[test]
    fn fig14c_features_scaling() {
        // N: throughput almost linear; efficiency up to ≈2.7×.
        let m = MacroModel::default();
        let (t32, e32) = m.fig14_features(32);
        let (t256, e256) = m.fig14_features(256);
        assert!((t256 / t32 - 8.0).abs() < 0.2, "thr ratio = {}", t256 / t32);
        let er = e256 / e32;
        assert!(er > 1.3 && er < 3.2, "eff ratio = {er}");
    }

    #[test]
    fn fig14d_precision_scaling() {
        // 4/4 → 8/8 improves both normalized metrics (modestly, via
        // fixed-overhead amortization).
        let m = MacroModel::default();
        let (t4, e4) = m.fig14_precision(4);
        let (t8, e8) = m.fig14_precision(8);
        let tr = t8 / t4;
        let er = e8 / e4;
        assert!(tr > 1.0 && tr < 1.6, "thr ratio {tr}");
        assert!(er > 1.0 && er < 1.6, "eff ratio {er}");
    }

    #[test]
    fn monotone_depth_efficiency() {
        let m = MacroModel::default();
        let mut prev = 0.0;
        for d in [32, 64, 96, 128] {
            let (_, e) = m.fig14_depth(d);
            assert!(e > prev, "d={d}");
            prev = e;
        }
    }
}
