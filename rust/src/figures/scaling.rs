//! E9: Fig. 14 — multi-sub-array throughput/efficiency scaling vs kernel
//! size, depth, features, and precision (normalized, as in the paper).

use std::path::Path;

use crate::perf::MacroModel;
use crate::util::csv::CsvWriter;

use super::emit;

/// All four panels; values normalized to each panel's first point (the
/// paper's y-axes are unitless "normalized" values).
pub fn fig14_scaling(out_dir: &Path) -> crate::Result<()> {
    let m = MacroModel::default();

    // (a) kernel size 3/5/7 at D = 64.
    let mut a = CsvWriter::new(vec!["kernel", "norm_throughput", "norm_efficiency"]);
    let (t0, e0) = m.fig14_kernel(3, 64);
    for k in [3usize, 5, 7] {
        let (t, e) = m.fig14_kernel(k, 64);
        a.row_f64(&[k as f64, t / t0, e / e0]);
    }
    emit(&a, out_dir, "fig14a_kernel.csv")?;

    // (b) depth D = 32..256.
    let mut b = CsvWriter::new(vec!["depth", "norm_throughput", "norm_efficiency"]);
    let (t0, e0) = m.fig14_depth(32);
    for d in [32usize, 64, 128, 192, 256] {
        let (t, e) = m.fig14_depth(d);
        b.row_f64(&[d as f64, t / t0, e / e0]);
    }
    emit(&b, out_dir, "fig14b_depth.csv")?;

    // (c) features N = 32..256.
    let mut c = CsvWriter::new(vec!["features", "norm_throughput", "norm_efficiency"]);
    let (t0, e0) = m.fig14_features(32);
    for n in [32usize, 64, 128, 192, 256] {
        let (t, e) = m.fig14_features(n);
        c.row_f64(&[n as f64, t / t0, e / e0]);
    }
    emit(&c, out_dir, "fig14c_features.csv")?;

    // (d) precision 4/4 vs 8/8.
    let mut d = CsvWriter::new(vec!["bits", "norm_throughput", "norm_efficiency"]);
    let (t0, e0) = m.fig14_precision(4);
    for bits in [4u32, 8] {
        let (t, e) = m.fig14_precision(bits);
        d.row_f64(&[bits as f64, t / t0, e / e0]);
    }
    emit(&d, out_dir, "fig14d_precision.csv")?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn generates_all_panels() {
        let dir = std::env::temp_dir().join("nvm_fig14");
        std::fs::create_dir_all(&dir).unwrap();
        fig14_scaling(&dir).unwrap();
        for f in ["fig14a_kernel.csv", "fig14b_depth.csv", "fig14c_features.csv", "fig14d_precision.csv"] {
            let text = std::fs::read_to_string(dir.join(f)).unwrap();
            assert!(text.lines().count() >= 3, "{f}: {text}");
            // First data row is the normalization anchor = 1.0.
            let row1: Vec<&str> = text.lines().nth(1).unwrap().split(',').collect();
            assert_eq!(row1[1], "1");
            assert_eq!(row1[2], "1");
        }
    }
}
