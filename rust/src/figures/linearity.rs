//! E4–E7: linearity and ADC characterization figures (Fig. 10–13).
//!
//! These run both the closed-form [`TransferModel`] and (for Fig. 13) the
//! cell-accurate Monte-Carlo sub-array; Fig. 10/11 use the transfer model
//! directly — the sub-array is calibrated against it (see
//! `array::subarray` tests), which is exactly the relationship between a
//! trimmed silicon macro and its characterization curve.

use std::path::Path;

use crate::consts::ARRAY_ROWS;
use crate::device::{Corner, VariationModel};
use crate::pim::transfer::{TransferModel, MAC_FULLSCALE};
use crate::util::csv::CsvWriter;
use crate::util::rng::Pcg64;
use crate::util::stats::Summary;

use super::emit;

/// Fig. 10: weight → accumulated voltage (a: before S&H, b: after S&H) for
/// 128-row activation across corners. Both are linear transforms of the
/// line current; the S&H adds no nonlinearity (asserted in tests).
pub fn fig10_weight_voltage(out_dir: &Path) -> crate::Result<CsvWriter> {
    let mut csv = CsvWriter::new(vec!["corner", "weight", "v_accumulated", "v_sampled"]);
    for corner in Corner::ALL {
        let m = TransferModel::new(corner);
        for w in 0..=15u32 {
            let mac = (w * ARRAY_ROWS as u32) as f64;
            // "Accumulated" voltage: the droop across the line before the
            // S&H (∝ current); "sampled": the held output V0 − R_ti·I.
            let v_acc = crate::consts::VDD - m.line_current(mac) * m.r_ti * 0.5;
            let v_samp = m.sampled_voltage(mac);
            csv.row(vec![
                corner.name().to_string(),
                w.to_string(),
                format!("{v_acc:.5}"),
                format!("{v_samp:.5}"),
            ]);
        }
    }
    emit(&csv, out_dir, "fig10_weight_voltage.csv")?;
    Ok(csv)
}

/// Fig. 11(a): weight → accumulated current per corner; (b) current vs
/// number of activated rows at weight 15.
pub fn fig11_weight_current(out_dir: &Path) -> crate::Result<()> {
    let mut a = CsvWriter::new(vec!["corner", "weight", "i_ua"]);
    for corner in Corner::ALL {
        let m = TransferModel::new(corner);
        for w in 0..=15u32 {
            let mac = (w * ARRAY_ROWS as u32) as f64;
            a.row(vec![
                corner.name().to_string(),
                w.to_string(),
                format!("{:.3}", m.line_current(mac) * 1e6),
            ]);
        }
    }
    emit(&a, out_dir, "fig11a_weight_current.csv")?;
    let mut b = CsvWriter::new(vec!["rows", "i_ua", "delta_i_ua"]);
    let m = TransferModel::tt();
    let mut prev = 0.0;
    for rows in (8..=ARRAY_ROWS).step_by(8) {
        let i = m.line_current((rows as u32 * 15) as f64) * 1e6;
        b.row_f64(&[rows as f64, i, i - prev]);
        prev = i;
    }
    emit(&b, out_dir, "fig11b_current_vs_rows.csv")?;
    Ok(())
}

/// Fig. 12: (a) weight → ADC code, calibrated vs uncalibrated;
/// (b) ADC output vs accumulated MAC value.
pub fn fig12_adc_transfer(out_dir: &Path) -> crate::Result<()> {
    let m = TransferModel::tt();
    let mut a = CsvWriter::new(vec!["weight", "code_calibrated", "code_uncalibrated"]);
    for w in 0..=15u32 {
        let mac = (w * ARRAY_ROWS as u32) as f64;
        let v = m.sampled_voltage(mac);
        a.row_f64(&[w as f64, m.adc_code(v, true) as f64, m.adc_code(v, false) as f64]);
    }
    emit(&a, out_dir, "fig12a_adc_transfer.csv")?;
    let mut b = CsvWriter::new(vec!["mac", "code_calibrated", "mac_estimate"]);
    for mac in (0..=MAC_FULLSCALE).step_by(16) {
        let code = m.adc_code(m.sampled_voltage(mac as f64), true);
        b.row_f64(&[mac as f64, code as f64, m.mac_estimate(code)]);
    }
    emit(&b, out_dir, "fig12b_adc_vs_mac.csv")?;
    Ok(())
}

/// Fig. 13: Monte-Carlo spread of the 128-row output voltage/current for a
/// 1-LSB weight step, on one cell-accurate word column (4 bit-columns ×
/// 128 rows with per-cell sampled variation, WCC-combined and sampled).
pub fn fig13_monte_carlo(out_dir: &Path, n_samples: usize) -> crate::Result<(Summary, Summary)> {
    use crate::array::sample_hold::SampleHold;
    use crate::array::wcc::Wcc;
    use crate::cell::bitcell::{BitCell, Side};

    let var = VariationModel::default();
    let wcc = Wcc::new(Corner::TT);
    let sh = SampleHold::new(&TransferModel::tt(), &var);
    let ia = vec![true; ARRAY_ROWS];
    let mut v_samples = Vec::with_capacity(n_samples);
    let mut i_samples = Vec::with_capacity(n_samples);
    let mut csv = CsvWriter::new(vec![
        "sample", "i_w14_ua", "i_w15_ua", "delta_i_ua", "v_w14", "v_w15", "delta_v_mv",
    ]);
    let word_cols = |w: u8, rng: &mut Pcg64| -> Vec<Vec<BitCell>> {
        (0..crate::consts::WORD_BITS)
            .map(|b| {
                (0..ARRAY_ROWS)
                    .map(|_| {
                        let mut c = BitCell::with_variation(Corner::TT, var.sample_cell(rng));
                        c.set_weight_bit((w >> b) & 1 == 1);
                        c.q = true; // left side active
                        c
                    })
                    .collect()
            })
            .collect()
    };
    for s in 0..n_samples {
        let mut rng = Pcg64::seeded(1000 + s as u64);
        let cols14 = word_cols(14, &mut rng);
        // Same devices, one LSB up: flip the LSB column to LRS.
        let mut cols15 = cols14.clone();
        for c in cols15[0].iter_mut() {
            c.set_weight_bit(true);
        }
        let i14 = wcc.weighted_current(&cols14, &ia, Side::Left);
        let i15 = wcc.weighted_current(&cols15, &ia, Side::Left);
        let mut srng = rng.fork(7);
        let v14 = sh.sample(i14, 0.0, Some(&mut srng));
        let v15 = sh.sample(i15, 0.0, Some(&mut srng));
        csv.row_f64(&[
            s as f64,
            i14 * 1e6,
            i15 * 1e6,
            (i15 - i14) * 1e6,
            v14,
            v15,
            (v14 - v15) * 1e3,
        ]);
        v_samples.push(v15);
        i_samples.push(i15 * 1e6);
    }
    emit(&csv, out_dir, "fig13_monte_carlo.csv")?;
    let vs = Summary::of(&v_samples);
    let is = Summary::of(&i_samples);
    println!(
        "  V(w=15): μ={:.1} mV σ={:.2} mV | I(w=15): μ={:.1} µA σ={:.2} µA (n={})",
        vs.mean * 1e3,
        vs.std * 1e3,
        is.mean,
        is.std,
        n_samples
    );
    Ok((vs, is))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::stats;

    fn tmp() -> std::path::PathBuf {
        let d = std::env::temp_dir().join("nvm_figs_lin");
        std::fs::create_dir_all(&d).unwrap();
        d
    }

    #[test]
    fn fig10_monotone_decreasing_voltage() {
        fig10_weight_voltage(&tmp()).unwrap();
        // Sampled voltage decreases with weight at every corner (V = VDD−MAC).
        for corner in Corner::ALL {
            let m = TransferModel::new(corner);
            let vs: Vec<f64> = (0..=15u32)
                .map(|w| -m.sampled_voltage((w * 128) as f64))
                .collect();
            assert!(stats::is_monotonic_nondecreasing(&vs), "{corner:?}");
        }
    }

    #[test]
    fn fig13_lsb_separable() {
        // Fig. 13's point: the 1-LSB step remains distinguishable under MC.
        let (_vs, _is) = fig13_monte_carlo(&tmp(), 40).unwrap();
        let text = std::fs::read_to_string(tmp().join("fig13_monte_carlo.csv")).unwrap();
        let deltas: Vec<f64> = text
            .lines()
            .skip(1)
            .map(|l| l.split(',').nth(3).unwrap().parse::<f64>().unwrap())
            .collect();
        let s = Summary::of(&deltas);
        assert!(s.mean > 0.0, "mean ΔI must be positive");
        assert!(s.mean > 2.0 * s.std, "1 LSB must exceed 2σ: {s:?}");
    }

    #[test]
    fn fig12_files_written() {
        fig12_adc_transfer(&tmp()).unwrap();
        assert!(tmp().join("fig12a_adc_transfer.csv").exists());
        assert!(tmp().join("fig12b_adc_vs_mac.csv").exists());
    }
}
