//! Figure/table generators — one per paper artifact (see DESIGN.md §4).
//!
//! Each generator returns the data series *and* writes a CSV under the
//! output directory, so every plot in the paper can be regenerated. The
//! benches drive the same functions for timing.

pub mod device_figs;
pub mod linearity;
pub mod scaling;
pub mod tables;

use std::path::Path;

use crate::util::csv::CsvWriter;

/// Write a CSV and report the path.
pub fn emit(csv: &CsvWriter, out_dir: &Path, name: &str) -> std::io::Result<()> {
    let path = out_dir.join(name);
    csv.write(&path)?;
    println!("  wrote {} ({} rows)", path.display(), csv.n_rows());
    Ok(())
}

/// Run every generator (the `repro figures --all` path).
pub fn generate_all(out_dir: &Path, mc_samples: usize) -> crate::Result<()> {
    println!("[fig 9a] RRAM I–V hysteresis");
    device_figs::fig9a_rram_iv(out_dir)?;
    println!("[fig 9b-d] SNM butterflies (hold/read/write)");
    device_figs::fig9bcd_snm(out_dir)?;
    println!("[scalars] §V-B read latency/energy + programming");
    device_figs::section_vb_scalars(out_dir)?;
    println!("[fig 10] weight → voltage linearity across corners");
    linearity::fig10_weight_voltage(out_dir)?;
    println!("[fig 11] weight → current linearity + row scaling");
    linearity::fig11_weight_current(out_dir)?;
    println!("[fig 12] ADC transfer, calibrated vs uncalibrated");
    linearity::fig12_adc_transfer(out_dir)?;
    println!("[fig 13] Monte-Carlo output variation ({mc_samples} samples)");
    linearity::fig13_monte_carlo(out_dir, mc_samples)?;
    println!("[fig 14] multi-sub-array scaling");
    scaling::fig14_scaling(out_dir)?;
    println!("[table 1] comparison table");
    tables::table1(out_dir, None)?;
    Ok(())
}
