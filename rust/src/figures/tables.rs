//! E8/E10/E11: Table I (comparison) and Table II (accuracy).

use std::path::Path;

use crate::perf::comparison::{prior_work, this_work, ComparisonRow};
use crate::perf::MacroModel;
use crate::runtime::Manifest;
use crate::util::csv::CsvWriter;

use super::emit;

/// Table I: prior work + our computed row. `accuracy` (0..1) optionally
/// from the measured Table II run.
pub fn table1(out_dir: &Path, accuracy: Option<f64>) -> crate::Result<Vec<ComparisonRow>> {
    let mut rows = prior_work();
    rows.push(this_work(accuracy.map(|a| a * 100.0)));
    let mut csv = CsvWriter::new(vec![
        "design", "technology", "array", "domain", "memory", "cache_retention",
        "accuracy_pct", "in_w_bits", "out_bits", "gops", "tops_w",
        "norm_tops", "norm_tops_w", "norm_tops_mm2",
    ]);
    for r in &rows {
        csv.row(vec![
            r.name.to_string(),
            r.technology.to_string(),
            r.array_size.to_string(),
            r.domain.to_string(),
            r.memory_type.to_string(),
            if r.cache_retention { "Yes" } else { "No" }.to_string(),
            r.accuracy_pct.map(|a| format!("{a:.2}")).unwrap_or_else(|| "NA".into()),
            format!("{}/{}", r.in_w_precision.0, r.in_w_precision.1),
            r.output_precision.to_string(),
            format!("{:.2}", r.throughput_gops),
            format!("{:.2}", r.efficiency_tops_w),
            format!("{:.3}", r.norm_throughput_tops),
            format!("{:.1}", r.norm_efficiency_tops_w),
            format!("{:.2}", r.norm_density_tops_mm2),
        ]);
    }
    emit(&csv, out_dir, "table1_comparison.csv")?;
    // Console render.
    println!("  {:<16} {:>8} {:>9} {:>10} {:>11} {:>10}", "design", "GOPS", "TOPS/W", "normTOPS", "normTOPS/W", "retention");
    for r in &rows {
        println!(
            "  {:<16} {:>8.2} {:>9.2} {:>10.3} {:>11.1} {:>10}",
            r.name,
            r.throughput_gops,
            r.efficiency_tops_w,
            r.norm_throughput_tops,
            r.norm_efficiency_tops_w,
            if r.cache_retention { "Yes" } else { "No" }
        );
    }
    // Energy/area breakdown sidecar (§V-D prose numbers).
    let (array, adc, wcc, dig) = MacroModel::default().energy_breakdown();
    let mut bd = CsvWriter::new(vec!["component", "energy_fraction", "area_fraction"]);
    bd.row(vec!["array".into(), format!("{array:.3}"), "0.20".to_string()]);
    bd.row(vec!["adc".into(), format!("{adc:.3}"), format!("{:.2}", crate::perf::model::AREA_ADC_FRAC)]);
    bd.row(vec!["wcc".into(), format!("{wcc:.3}"), "0.07".to_string()]);
    bd.row(vec!["digital".into(), format!("{dig:.3}"), "0.03".to_string()]);
    emit(&bd, out_dir, "table1_breakdown.csv")?;
    Ok(rows)
}

/// One Table II row: configuration + measured accuracy.
#[derive(Clone, Debug)]
pub struct Table2Row {
    /// Configuration description (Table II row label).
    pub config: String,
    /// Measured accuracy (fraction in [0,1]).
    pub accuracy: f64,
    /// The paper's corresponding number (%), for side-by-side reporting.
    pub paper_pct: Option<f64>,
}

/// Table II from the artifact manifest (accuracies measured at build time
/// by the training protocol; the e2e example re-measures them through the
/// runtime backend and must agree — that's the runtime_crosscheck).
pub fn table2_from_manifest(out_dir: &Path, manifest: &Manifest) -> crate::Result<Vec<Table2Row>> {
    let rows = vec![
        Table2Row {
            config: "Baseline (no ADC nonlinearity or noise)".into(),
            accuracy: manifest.accuracy("baseline").unwrap_or(f64::NAN),
            paper_pct: Some(91.84),
        },
        Table2Row {
            config: "ADC nonlinearity only (fine-tuned)".into(),
            accuracy: manifest.accuracy("pim_finetuned").unwrap_or(f64::NAN),
            paper_pct: Some(91.55),
        },
        Table2Row {
            config: "ADC nonlinearity + noise (fine-tuned)".into(),
            accuracy: manifest.accuracy("pim_finetuned_noise").unwrap_or(f64::NAN),
            paper_pct: Some(91.27),
        },
        Table2Row {
            config: "No fine-tuning (nonlinearity + noise)".into(),
            accuracy: manifest.accuracy("pim_noise_no_finetune").unwrap_or(f64::NAN),
            paper_pct: Some(77.0),
        },
        Table2Row {
            config: "Hardware-true block pipeline, no fine-tune (extra ablation)".into(),
            accuracy: manifest.accuracy("pim_hw_no_finetune").unwrap_or(f64::NAN),
            paper_pct: None,
        },
        Table2Row {
            config: "Hardware-true block pipeline, fine-tuned weights (extra ablation)".into(),
            accuracy: manifest.accuracy("pim_hw_finetuned").unwrap_or(f64::NAN),
            paper_pct: None,
        },
    ];
    let mut csv = CsvWriter::new(vec!["configuration", "accuracy_pct", "paper_pct"]);
    for r in &rows {
        csv.row(vec![
            r.config.clone(),
            format!("{:.2}", r.accuracy * 100.0),
            r.paper_pct.map(|p| format!("{p:.2}")).unwrap_or_else(|| "-".into()),
        ]);
    }
    emit(&csv, out_dir, "table2_accuracy.csv")?;
    for r in &rows {
        println!(
            "  {:<62} {:>6.2}%  (paper: {})",
            r.config,
            r.accuracy * 100.0,
            r.paper_pct.map(|p| format!("{p:.2}%")).unwrap_or_else(|| "—".into())
        );
    }
    Ok(rows)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp() -> std::path::PathBuf {
        let d = std::env::temp_dir().join("nvm_tables");
        std::fs::create_dir_all(&d).unwrap();
        d
    }

    #[test]
    fn table1_has_seven_rows() {
        let rows = table1(&tmp(), Some(0.9127)).unwrap();
        assert_eq!(rows.len(), 7);
        assert_eq!(rows.last().unwrap().name, "This Work");
        assert_eq!(rows.last().unwrap().accuracy_pct, Some(91.27));
    }

    #[test]
    fn table2_renders_from_manifest() {
        let m = Manifest::parse(
            "acc_baseline=0.9260\nacc_pim_finetuned=0.9230\nacc_pim_finetuned_noise=0.9200\n\
             acc_pim_noise_no_finetune=0.9100\nacc_pim_hw_no_finetune=0.1210\nacc_pim_hw_finetuned=0.2000\n",
        );
        let rows = table2_from_manifest(&tmp(), &m).unwrap();
        assert_eq!(rows.len(), 6);
        assert!((rows[0].accuracy - 0.926).abs() < 1e-9);
        // Ordering property the paper reports: baseline ≥ ft ≥ ft+noise.
        assert!(rows[0].accuracy >= rows[1].accuracy);
        assert!(rows[1].accuracy >= rows[2].accuracy);
    }
}
