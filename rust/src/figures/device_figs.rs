//! E1–E3: Fig. 9(a) RRAM I–V hysteresis, Fig. 9(b–d) SNM butterflies, and
//! the §V-B scalar anchors.

use std::path::Path;

use crate::cell::snm::{self, CellFlavor, SnmKind};
use crate::cell::timing::OpKind;
use crate::cell::BitCell;
use crate::device::rram::{iv_sweep, Rram};
use crate::device::{Corner, RramState};
use crate::util::csv::CsvWriter;

use super::emit;

/// Fig. 9(a): quasi-static I–V sweep 0 → +1.5 → 0 → −1.5 → 0 V.
/// Returns (V, I) points; also emits fig9a_rram_iv.csv.
pub fn fig9a_rram_iv(out_dir: &Path) -> crate::Result<Vec<(f64, f64)>> {
    let mut dev = Rram::new();
    // 1 ms dwell per point ⇒ quasi-static: SET fires just past +1.2 V.
    let pts = iv_sweep(&mut dev, 1.5, 300, 1.0e-3);
    let mut csv = CsvWriter::new(vec!["v", "i_a", "abs_i_a"]);
    for (v, i) in &pts {
        csv.row_f64(&[*v, *i, i.abs().max(1e-15)]);
    }
    emit(&csv, out_dir, "fig9a_rram_iv.csv")?;
    // Console summary: first forward-leg point where the device reads
    // LRS-like (R < 100 kΩ) = the observed SET voltage.
    let set_v = pts
        .iter()
        .take(300) // forward leg only
        .find(|(v, i)| *v > 0.5 && (*v / i.abs().max(1e-15)) < 1.0e5)
        .map(|(v, _)| *v);
    match set_v {
        Some(v) => println!("  observed SET at ≈{v:.2} V (paper: +1.2 V)"),
        None => println!("  SET completed between sweep points near the +1.2 V threshold"),
    }
    Ok(pts)
}

/// Fig. 9(b–d): hold/read/write butterflies for 6T vs 6T-2R.
pub fn fig9bcd_snm(out_dir: &Path) -> crate::Result<Vec<(String, f64)>> {
    let mut summary = Vec::new();
    let mut csv = CsvWriter::new(vec!["kind", "flavor", "corner", "snm_mv"]);
    let mut curves = CsvWriter::new(vec!["kind", "flavor", "vin", "vout_a", "vout_b_mirrored"]);
    for kind in [SnmKind::Hold, SnmKind::Read, SnmKind::Write] {
        for (fname, flavor) in [
            ("6T", CellFlavor::Conventional6t),
            ("6T2R_LRS", CellFlavor::SixT2r(RramState::Lrs)),
            ("6T2R_HRS", CellFlavor::SixT2r(RramState::Hrs)),
        ] {
            let r = snm::snm(kind, flavor, Corner::TT);
            csv.row(vec![
                kind.name().to_string(),
                fname.to_string(),
                "TT".to_string(),
                format!("{:.2}", r.snm * 1e3),
            ]);
            summary.push((format!("{}/{}", kind.name(), fname), r.snm));
            for ((vin, va), (_, vb)) in r.vtc_a.iter().zip(r.vtc_b.iter()) {
                curves.row(vec![
                    kind.name().to_string(),
                    fname.to_string(),
                    format!("{vin:.4}"),
                    format!("{va:.4}"),
                    format!("{vb:.4}"),
                ]);
            }
        }
    }
    emit(&csv, out_dir, "fig9bcd_snm.csv")?;
    emit(&curves, out_dir, "fig9bcd_butterflies.csv")?;
    for (name, v) in &summary {
        println!("  {name}: {:.1} mV", v * 1e3);
    }
    Ok(summary)
}

/// §V-B scalars: read latency 660→686 ps, row read energy 2.23→3.34 fJ,
/// 4 ns programming with verify.
pub fn section_vb_scalars(out_dir: &Path) -> crate::Result<()> {
    let mut csv = CsvWriter::new(vec!["metric", "conventional_6t", "proposed_6t2r", "paper_6t", "paper_6t2r"]);
    let (t6, e6) = OpKind::SramRead6t.cost();
    let (t2, e2) = OpKind::SramRead6t2r.cost();
    csv.row(vec![
        "read_latency_ps".to_string(),
        format!("{:.0}", t6 * 1e12),
        format!("{:.0}", t2 * 1e12),
        "660".to_string(),
        "686".to_string(),
    ]);
    csv.row(vec![
        "row_read_energy_fJ".to_string(),
        format!("{:.2}", e6 * 1e15),
        format!("{:.2}", e2 * 1e15),
        "2.23".to_string(),
        "3.34".to_string(),
    ]);
    // Programming: measure pulses needed on a nominal cell.
    let mut cell = BitCell::new(Corner::TT);
    let mut ledger = crate::cell::timing::EnergyLedger::new();
    let out = cell.program_lrs(crate::cell::Side::Left, &mut ledger);
    csv.row(vec![
        "set_pulses_4ns".to_string(),
        "-".to_string(),
        format!("{}", out.pulses),
        "-".to_string(),
        "1".to_string(),
    ]);
    let hrs = cell.program_hrs(&mut ledger);
    csv.row(vec![
        "reset_pulses_4ns".to_string(),
        "-".to_string(),
        format!("{}", hrs.pulses),
        "-".to_string(),
        "1".to_string(),
    ]);
    emit(&csv, out_dir, "section_vb_scalars.csv")?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp() -> std::path::PathBuf {
        let d = std::env::temp_dir().join("nvm_figs_test");
        std::fs::create_dir_all(&d).unwrap();
        d
    }

    #[test]
    fn fig9a_shows_hysteresis() {
        let pts = fig9a_rram_iv(&tmp()).unwrap();
        assert!(pts.len() >= 1000);
        // Branch currents at +0.8 V differ by >10× between legs.
        let branch: Vec<f64> = pts
            .iter()
            .filter(|(v, _)| (*v - 0.8).abs() < 0.01)
            .map(|(v, i)| (v / i).abs())
            .collect();
        let rmin = branch.iter().cloned().fold(f64::MAX, f64::min);
        let rmax = branch.iter().cloned().fold(f64::MIN, f64::max);
        assert!(rmax / rmin > 10.0);
    }

    #[test]
    fn snm_summary_ordering() {
        let s = fig9bcd_snm(&tmp()).unwrap();
        let get = |k: &str| s.iter().find(|(n, _)| n == k).unwrap().1;
        // Fig. 9 qualitative content.
        assert!(get("read/6T") < get("hold/6T"));
        assert!(get("read/6T2R_LRS") <= get("read/6T") * 1.001);
        assert!((get("hold/6T2R_LRS") - get("hold/6T")).abs() / get("hold/6T") < 0.1);
    }

    #[test]
    fn scalars_csv_written() {
        section_vb_scalars(&tmp()).unwrap();
        let text = std::fs::read_to_string(tmp().join("section_vb_scalars.csv")).unwrap();
        assert!(text.contains("686"));
        assert!(text.contains("3.34"));
    }
}
