//! Compact device models — the SPICE-level substrate of the reproduction.
//!
//! The paper simulates the 6T-2R cell in GlobalFoundries 22 nm FDSOI with a
//! Verilog-A RRAM compact model (Jiang et al., SISPAD'14). We do not have a
//! PDK or a SPICE engine, so this module provides behavioral equivalents
//! (see DESIGN.md §2 for the substitution argument):
//!
//! * [`rram`] — bipolar filamentary RRAM: gap-state dynamics, I–V with
//!   `sinh` conduction, SET/RESET thresholds at ±1.2 V, 4 ns programming,
//!   HRS ≈ 1.2 MΩ / LRS ≈ 25 kΩ at read bias (paper §V-B, Fig. 9a).
//! * [`fet`] — alpha-power-law MOSFET I–V with corner-dependent (SS/TT/FF)
//!   threshold and drive, used for inverter VTCs (SNM), access-transistor
//!   dividers, and the FF-corner nonlinearity of the PIM transfer curve.
//! * [`corner`] — SS/TT/FF process corner parameter sets.
//! * [`variation`] — Monte-Carlo mismatch sampling (local Vth/β/R σ), used
//!   by Fig. 13 and the Table II noise model.

pub mod corner;
pub mod fet;
pub mod reliability;
pub mod rram;
pub mod variation;

pub use corner::{Corner, CornerParams};
pub use fet::{Fet, FetKind};
pub use rram::{Rram, RramParams, RramState};
pub use variation::{CellVariation, VariationModel};
