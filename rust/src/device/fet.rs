//! Alpha-power-law MOSFET model (Sakurai–Newton) with process corners.
//!
//! Used for (i) inverter voltage-transfer curves in the SNM analysis
//! (Fig. 9b–d), (ii) access/gated-GND transistor resistive dividers during
//! read and PIM, and (iii) the corner-dependent series resistance of the
//! PMOS in the RRAM current path, which produces the FF-corner compression
//! of the linearity curves (Fig. 10/11).
//!
//! The model is intentionally compact: saturation current
//! `Id = β·(Vgs−Vth)^α`, a quadratic-blend triode region below
//! `Vdsat = Kd·(Vgs−Vth)`, channel-length modulation, and an exponential
//! subthreshold tail. All parameters are per-[`Corner`] via
//! [`CornerParams`], with optional per-device Monte-Carlo deltas.

use super::corner::{Corner, CornerParams};

/// Device polarity.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FetKind {
    /// N-channel device.
    Nmos,
    /// P-channel device.
    Pmos,
}

/// One FET instance (per-device MC deltas baked in).
#[derive(Clone, Copy, Debug)]
pub struct Fet {
    /// Device polarity.
    pub kind: FetKind,
    /// Transconductance coefficient β (A/V^α) after corner + width scaling.
    pub beta: f64,
    /// Threshold voltage magnitude (V) after corner + MC shift.
    pub vth: f64,
    /// Velocity-saturation exponent α.
    pub alpha: f64,
    /// Vdsat coefficient: Vdsat = kd·(Vgs−Vth).
    pub kd: f64,
    /// Channel-length modulation (1/V).
    pub lambda: f64,
    /// Subthreshold swing factor n (Id ∝ exp(Vov/(n·vT))).
    pub n_sub: f64,
    /// Leakage prefactor at Vov = 0 (A).
    pub i_leak0: f64,
}

/// Thermal voltage at 300 K (V).
pub const VT_300K: f64 = 0.02585;

/// Nominal (TT, unit-width) device parameters, representative of a 22 nm
/// FDSOI low-Vt logic transistor sized for a dense SRAM bit-cell.
#[derive(Clone, Copy, Debug)]
pub struct FetNominal {
    /// NMOS transconductance coefficient (A/V^α).
    pub beta_n: f64,
    /// PMOS transconductance coefficient (A/V^α).
    pub beta_p: f64,
    /// NMOS threshold voltage (V).
    pub vth_n: f64,
    /// PMOS threshold-voltage magnitude (V).
    pub vth_p: f64,
    /// Velocity-saturation exponent α.
    pub alpha: f64,
    /// Vdsat coefficient: Vdsat = kd·(Vgs−Vth).
    pub kd: f64,
    /// Channel-length modulation (1/V).
    pub lambda: f64,
    /// Subthreshold swing factor n.
    pub n_sub: f64,
    /// Leakage prefactor at Vov = 0 (A).
    pub i_leak0: f64,
}

impl Default for FetNominal {
    fn default() -> Self {
        FetNominal {
            // β chosen so the on-resistance of a minimum cell transistor at
            // Vgs = VDD = 0.8 V is a few kΩ — small against R_LRS = 25 kΩ,
            // consistent with the paper's near-linear TT transfer curves.
            beta_n: 5.2e-4,
            beta_p: 3.2e-4, // PMOS mobility deficit ≈ 0.6×
            vth_n: 0.26,
            vth_p: 0.27,
            alpha: 1.35,
            kd: 0.55,
            lambda: 0.06,
            n_sub: 1.35,
            i_leak0: 1.0e-9,
        }
    }
}

impl Fet {
    /// Build a device at a given corner with a width multiplier (SRAM cells
    /// size pull-down > access > pull-up; callers pass the ratio).
    pub fn new(kind: FetKind, corner: Corner, width: f64) -> Fet {
        Self::with_deltas(kind, corner, width, 0.0, 1.0)
    }

    /// Build with per-device Monte-Carlo deltas: additive Vth shift and
    /// multiplicative β scaling (from [`super::variation`]).
    pub fn with_deltas(
        kind: FetKind,
        corner: Corner,
        width: f64,
        vth_delta: f64,
        beta_mult: f64,
    ) -> Fet {
        let nom = FetNominal::default();
        let CornerParams { beta_scale, vth_shift, leak_scale } = corner.params();
        let (beta0, vth0) = match kind {
            FetKind::Nmos => (nom.beta_n, nom.vth_n),
            FetKind::Pmos => (nom.beta_p, nom.vth_p),
        };
        Fet {
            kind,
            beta: beta0 * beta_scale * width * beta_mult,
            vth: (vth0 + vth_shift + vth_delta).max(0.05),
            alpha: nom.alpha,
            kd: nom.kd,
            lambda: nom.lambda,
            n_sub: nom.n_sub,
            i_leak0: nom.i_leak0 * leak_scale * width,
        }
    }

    /// Drain current magnitude for *overdrive-domain* terminal voltages:
    /// `vgs` and `vds` are the gate-source and drain-source magnitudes in
    /// the device's own polarity (callers flip signs for PMOS).
    pub fn id(&self, vgs: f64, vds: f64) -> f64 {
        let vds = vds.max(0.0);
        let vov = vgs - self.vth;
        if vov <= 0.0 {
            // Subthreshold: exponential in overdrive, linear-ish saturation in Vds.
            let sub = self.i_leak0 * (vov / (self.n_sub * VT_300K)).exp();
            return sub * (1.0 - (-vds / VT_300K).exp());
        }
        let idsat = self.beta * vov.powf(self.alpha) * (1.0 + self.lambda * vds);
        let vdsat = self.kd * vov;
        if vds >= vdsat {
            idsat
        } else {
            // Quadratic blend to zero at Vds = 0, continuous at Vdsat.
            let x = vds / vdsat;
            idsat * x * (2.0 - x)
        }
    }

    /// Small-signal on-resistance at a bias point (numeric dId/dVds)⁻¹.
    pub fn r_on(&self, vgs: f64, vds: f64) -> f64 {
        let dv = 1e-4;
        let di = self.id(vgs, vds + dv) - self.id(vgs, (vds - dv).max(0.0));
        let denom = di / (2.0 * dv).min(vds + dv);
        if denom <= 0.0 {
            1e12
        } else {
            1.0 / denom
        }
    }

    /// Effective large-signal resistance `vds/id` (used in series-divider
    /// solves where the FET is deep in triode).
    pub fn r_eff(&self, vgs: f64, vds: f64) -> f64 {
        let vds = vds.max(1e-6);
        let i = self.id(vgs, vds);
        if i <= 0.0 {
            1e12
        } else {
            vds / i
        }
    }

    /// Saturation drain current at the given overdrive (convenience).
    pub fn idsat(&self, vgs: f64) -> f64 {
        let vov = vgs - self.vth;
        if vov <= 0.0 {
            0.0
        } else {
            self.beta * vov.powf(self.alpha)
        }
    }
}

/// CMOS inverter voltage-transfer curve, solved pointwise by balancing the
/// pull-up and pull-down currents with bisection on Vout. `vdd_eff` allows
/// the 6T-2R case where the inverter's supply is reached through an RRAM
/// (series IR drop handled by the caller via `r_pullup_series`).
pub fn inverter_vtc(
    nmos: &Fet,
    pmos: &Fet,
    vdd_eff: f64,
    r_pullup_series: f64,
    r_pulldown_series: f64,
    vin: f64,
) -> f64 {
    // Solve for vout ∈ [0, vdd_eff] such that I_p(vout) = I_n(vout), where
    // each current accounts for its series resistance via a nested solve.
    let f = |vout: f64| -> f64 {
        let i_n = current_through_nmos(nmos, vin, vout, r_pulldown_series);
        let i_p = current_through_pmos(pmos, vin, vout, vdd_eff, r_pullup_series);
        i_p - i_n
    };
    bisect(f, 0.0, vdd_eff, 60)
}

/// Current into the output node through the NMOS + series R to ground.
fn current_through_nmos(nmos: &Fet, vin: f64, vout: f64, r_s: f64) -> f64 {
    if r_s <= 1e-3 {
        return nmos.id(vin, vout);
    }
    // Source degeneration: find i with vs = i·r_s, i = Id(vin−vs, vout−vs).
    let mut i = nmos.id(vin, vout);
    for _ in 0..20 {
        let vs = (i * r_s).min(vout);
        i = 0.5 * i + 0.5 * nmos.id(vin - vs, (vout - vs).max(0.0));
    }
    i
}

/// Current into the output node through the PMOS + series R to VDD.
fn current_through_pmos(pmos: &Fet, vin: f64, vout: f64, vdd: f64, r_s: f64) -> f64 {
    // PMOS magnitudes: vgs = vdd_node − vin, vds = vdd_node − vout, where
    // vdd_node = vdd − i·r_s (IR drop across the RRAM on the powerline).
    let mut i = pmos.id(vdd - vin, (vdd - vout).max(0.0));
    if r_s <= 1e-3 {
        return i;
    }
    for _ in 0..20 {
        let vnode = (vdd - i * r_s).max(vout);
        i = 0.5 * i + 0.5 * pmos.id(vnode - vin, (vnode - vout).max(0.0));
    }
    i
}

/// Bisection root-finder for a decreasing `f` (f(lo) ≥ 0 ≥ f(hi)); clamps to
/// the bracket endpoint when the sign condition fails (rail-stuck output).
fn bisect<F: Fn(f64) -> f64>(f: F, lo: f64, hi: f64, iters: usize) -> f64 {
    let (mut lo, mut hi) = (lo, hi);
    let flo = f(lo);
    let fhi = f(hi);
    if flo <= 0.0 {
        return lo;
    }
    if fhi >= 0.0 {
        return hi;
    }
    for _ in 0..iters {
        let mid = 0.5 * (lo + hi);
        if f(mid) > 0.0 {
            lo = mid;
        } else {
            hi = mid;
        }
    }
    0.5 * (lo + hi)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::consts::VDD;

    #[test]
    fn cutoff_below_threshold() {
        let n = Fet::new(FetKind::Nmos, Corner::TT, 1.0);
        assert!(n.id(0.0, VDD) < 1e-9, "leakage should be sub-nA at Vgs=0");
        assert!(n.id(n.vth - 0.1, VDD) < n.id(n.vth + 0.1, VDD) / 100.0);
    }

    #[test]
    fn monotone_in_vgs_and_vds() {
        let n = Fet::new(FetKind::Nmos, Corner::TT, 1.0);
        let mut prev = 0.0;
        for i in 0..=16 {
            let vgs = i as f64 * 0.05;
            let id = n.id(vgs, VDD);
            assert!(id >= prev);
            prev = id;
        }
        let mut prev = 0.0;
        for i in 0..=16 {
            let vds = i as f64 * 0.05;
            let id = n.id(VDD, vds);
            assert!(id >= prev - 1e-15, "triode→sat must be non-decreasing");
            prev = id;
        }
    }

    #[test]
    fn corner_drive_ordering() {
        for kind in [FetKind::Nmos, FetKind::Pmos] {
            let ss = Fet::new(kind, Corner::SS, 1.0).idsat(VDD);
            let tt = Fet::new(kind, Corner::TT, 1.0).idsat(VDD);
            let ff = Fet::new(kind, Corner::FF, 1.0).idsat(VDD);
            assert!(ss < tt && tt < ff, "{kind:?}: {ss} {tt} {ff}");
        }
    }

    #[test]
    fn on_resistance_plausible() {
        // A unit-width NMOS at full gate drive should be a few kΩ in triode —
        // small against R_LRS = 25 kΩ (required for near-linear PIM currents).
        let n = Fet::new(FetKind::Nmos, Corner::TT, 1.0);
        let r = n.r_eff(VDD, 0.05);
        assert!(r > 500.0 && r < 10_000.0, "r_on = {r}");
    }

    #[test]
    fn vtc_rails_and_midpoint() {
        let n = Fet::new(FetKind::Nmos, Corner::TT, 1.0);
        let p = Fet::new(FetKind::Pmos, Corner::TT, 1.0);
        let v_lo = inverter_vtc(&n, &p, VDD, 0.0, 0.0, VDD);
        let v_hi = inverter_vtc(&n, &p, VDD, 0.0, 0.0, 0.0);
        assert!(v_lo < 0.05, "output low = {v_lo}");
        assert!(v_hi > VDD - 0.05, "output high = {v_hi}");
        // Switching threshold near mid-rail.
        let vm = (0..=80)
            .map(|i| i as f64 * 0.01)
            .find(|&vin| inverter_vtc(&n, &p, VDD, 0.0, 0.0, vin) < vin)
            .unwrap();
        assert!((vm - 0.4).abs() < 0.15, "Vm = {vm}");
    }

    #[test]
    fn vtc_with_series_rram_still_swings() {
        // Hold-mode insight of the paper (Fig. 4): with *no* DC current the
        // RRAM drop is zero, so even HRS on the powerline must not destroy
        // logic levels (only leakage flows).
        let n = Fet::new(FetKind::Nmos, Corner::TT, 1.0);
        let p = Fet::new(FetKind::Pmos, Corner::TT, 1.0);
        let v_hi = inverter_vtc(&n, &p, VDD, crate::consts::R_HRS, 0.0, 0.0);
        assert!(v_hi > VDD - 0.1, "high level with HRS supply = {v_hi}");
    }
}
