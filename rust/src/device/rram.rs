//! Bipolar filamentary RRAM compact model.
//!
//! Rust port of the model family used by the paper (Jiang et al.,
//! SISPAD'14 Verilog-A compact model for oxide RRAM): the device state is a
//! tunneling-gap distance `g`; conduction is `I = I0·exp(−g/g0)·sinh(V/V0)`;
//! the gap evolves with a strongly field-accelerated rate
//! `dg/dt = −v0·exp(|V|−Vth)/Vk` (sign by polarity), giving
//!
//! * abrupt SET at `V ≥ +1.2 V` (HRS → LRS),
//! * abrupt RESET at `V ≤ −1.2 V` (LRS → HRS),
//! * 4 ns programming pulses (paper §V-B),
//! * no read disturb at 0.8–1.05 V / 1–2 ns windows (paper §V-B),
//! * HRS ≈ 1.2 MΩ and LRS ≈ 25 kΩ at read bias.

use crate::consts::{R_HRS, R_LRS, V_RESET, V_SET};

/// Discrete logical state (the analog gap is the ground truth; this is the
/// thresholded view used by the array logic).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum RramState {
    /// Low-resistance state — logical weight bit 1.
    Lrs,
    /// High-resistance state — logical weight bit 0.
    Hrs,
}

/// Model parameters (defaults tuned to the paper's reported device, see
/// module docs). Exposed so tests/ablations can build faster/slower devices.
#[derive(Clone, Copy, Debug)]
pub struct RramParams {
    /// Minimum gap (fully-formed filament, LRS), nm.
    pub g_min: f64,
    /// Maximum gap (ruptured filament, HRS), nm.
    pub g_max: f64,
    /// `sinh` voltage scale V0 for the HRS (tunneling) branch, V.
    pub v0: f64,
    /// Ohmic LRS resistance (metallic filament), Ω.
    pub r_lrs: f64,
    /// HRS `sinh` prefactor I0h, A — calibrated so R_HRS(0.1 V) = 1.2 MΩ.
    pub i0h: f64,
    /// Gap velocity prefactor, nm/s.
    pub nu0: f64,
    /// Exponential voltage acceleration scale, V.
    pub vk: f64,
    /// SET threshold (gap shrinks above this forward bias), V.
    pub v_set: f64,
    /// RESET threshold magnitude (gap grows beyond this reverse bias), V.
    pub v_reset: f64,
}

impl Default for RramParams {
    fn default() -> Self {
        // Calibration (see `calibrated_resistances` test) at the standard
        // 0.1 V read bias: R_LRS = 25 kΩ (ohmic filament) and
        // R_HRS(0.1 V) = 1.2 MΩ via the sinh tunneling branch.
        let g_min = 0.10;
        let g_max = 1.70;
        let v0 = 0.35;
        let vr = 0.1;
        let i0h = (vr / R_HRS) / (vr / v0).sinh();
        RramParams {
            g_min,
            g_max,
            v0,
            r_lrs: R_LRS,
            i0h,
            // nu0/vk tuned so a ≥1.5 V, 4 ns pulse fully switches while a
            // 1.05 V, 2 ns read moves the gap by ~1e-8 nm (no disturb even
            // after 10⁶ reads — §V-B's non-destructive read window). The
            // small vk makes the field acceleration steep, giving the
            // abrupt SET/RESET transitions of Fig. 9(a).
            nu0: 100.0, // nm/s at threshold
            vk: 0.015,
            v_set: V_SET,
            v_reset: V_RESET.abs(),
        }
    }
}

/// One RRAM device instance with analog gap state.
#[derive(Clone, Debug)]
pub struct Rram {
    /// Model parameters.
    pub params: RramParams,
    /// Tunneling gap, nm. Smaller gap ⇒ lower resistance.
    pub gap: f64,
    /// Multiplicative Monte-Carlo resistance spread (1.0 = nominal).
    pub r_mult: f64,
    /// Cumulative SET+RESET events (endurance bookkeeping).
    pub cycles: u64,
}

impl Rram {
    /// Fresh device in HRS (as assumed at the start of §III-A).
    pub fn new() -> Rram {
        Self::with_params(RramParams::default())
    }

    /// Fresh device in HRS with explicit parameters.
    pub fn with_params(params: RramParams) -> Rram {
        Rram { params, gap: params.g_max, r_mult: 1.0, cycles: 0 }
    }

    /// Construct directly in a logical state (for array initialization).
    pub fn in_state(state: RramState) -> Rram {
        let mut d = Rram::new();
        d.force_state(state);
        d
    }

    /// Set the gap to the extreme of a logical state without electrical
    /// programming (used when loading pre-programmed weight arrays).
    pub fn force_state(&mut self, state: RramState) {
        self.gap = match state {
            RramState::Lrs => self.params.g_min,
            RramState::Hrs => self.params.g_max,
        };
    }

    /// Thresholded logical state (mid-gap decision boundary).
    pub fn state(&self) -> RramState {
        if self.gap < 0.5 * (self.params.g_min + self.params.g_max) {
            RramState::Lrs
        } else {
            RramState::Hrs
        }
    }

    /// Instantaneous current at applied voltage `v` (signed; positive =
    /// SET polarity, top electrode positive).
    ///
    /// Conduction blends two branches by filament completeness
    /// `w = (g_max − gap)/(g_max − g_min)`: the fully-formed filament (LRS)
    /// conducts ohmically (metallic), while the ruptured gap (HRS) conducts
    /// by `sinh` tunneling — the standard two-branch structure of
    /// filamentary compact models.
    pub fn current(&self, v: f64) -> f64 {
        let p = &self.params;
        let w = ((p.g_max - self.gap) / (p.g_max - p.g_min)).clamp(0.0, 1.0);
        let i_lrs = v / p.r_lrs;
        let i_hrs = p.i0h * (v / p.v0).sinh();
        (w * i_lrs + (1.0 - w) * i_hrs) / self.r_mult
    }

    /// Effective resistance at a bias point (|v| should be > 0).
    pub fn resistance(&self, v: f64) -> f64 {
        let v = if v.abs() < 1e-6 { 1e-6 } else { v };
        (v / self.current(v)).abs()
    }

    /// Small-signal resistance at the standard 0.1 V read bias.
    pub fn read_resistance(&self) -> f64 {
        self.resistance(0.1)
    }

    /// Conductance at read bias (S) — the "weight" seen by the PIM MAC.
    pub fn read_conductance(&self) -> f64 {
        1.0 / self.read_resistance()
    }

    /// Evolve the gap under voltage `v` for duration `dt` seconds,
    /// sub-stepped for stability. Returns the gap change.
    pub fn apply_voltage(&mut self, v: f64, dt: f64) -> f64 {
        let p = self.params;
        let before = self.gap;
        let state_before = self.state();
        // Field-accelerated gap velocity; exponential in the overdrive past
        // the polarity's threshold, negligible below it.
        let steps = 64;
        let h = dt / steps as f64;
        for _ in 0..steps {
            let rate = if v > 0.0 {
                // SET polarity: gap shrinks.
                -p.nu0 * ((v - p.v_set) / p.vk).exp()
            } else if v < 0.0 {
                // RESET polarity: gap grows.
                p.nu0 * ((-v - p.v_reset) / p.vk).exp()
            } else {
                0.0
            };
            self.gap = (self.gap + rate * h).clamp(p.g_min, p.g_max);
        }
        if self.state() != state_before {
            self.cycles += 1;
        }
        self.gap - before
    }

    /// Apply a programming pulse of amplitude `v` for `width` seconds and
    /// report whether the device ended in the expected state.
    pub fn program_pulse(&mut self, v: f64, width: f64) -> RramState {
        self.apply_voltage(v, width);
        self.state()
    }
}

impl Default for Rram {
    fn default() -> Self {
        Self::new()
    }
}

/// Quasi-static I–V sweep for the hysteresis curve of Fig. 9(a):
/// 0 → +v_max → 0 → −v_max → 0, `points` samples per leg, holding each bias
/// for `dwell` seconds. Returns (V, I) pairs.
pub fn iv_sweep(dev: &mut Rram, v_max: f64, points: usize, dwell: f64) -> Vec<(f64, f64)> {
    let mut out = Vec::with_capacity(4 * points);
    let legs: [(f64, f64); 4] = [(0.0, v_max), (v_max, 0.0), (0.0, -v_max), (-v_max, 0.0)];
    for (from, to) in legs {
        for i in 0..points {
            let v = from + (to - from) * i as f64 / (points - 1) as f64;
            dev.apply_voltage(v, dwell);
            out.push((v, dev.current(v)));
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::consts::{R_HRS, R_LRS, T_PROGRAM};

    #[test]
    fn calibrated_resistances() {
        let lrs = Rram::in_state(RramState::Lrs);
        let hrs = Rram::in_state(RramState::Hrs);
        let r_lrs = lrs.read_resistance();
        let r_hrs = hrs.read_resistance();
        assert!((r_lrs - R_LRS).abs() / R_LRS < 0.05, "R_LRS = {r_lrs}");
        assert!((r_hrs - R_HRS).abs() / R_HRS < 0.05, "R_HRS = {r_hrs}");
        // Paper: "high ratio between HRS and LRS" — ~48×.
        assert!(r_hrs / r_lrs > 30.0);
    }

    #[test]
    fn set_in_4ns_at_2v() {
        // §III-A: SET with 2 V applied, 4 ns pulse (voltage across the
        // device is at least V_set = 1.2 V; we apply a conservative 1.5 V
        // to represent the divider drop through the access path).
        let mut d = Rram::new();
        assert_eq!(d.state(), RramState::Hrs);
        let s = d.program_pulse(1.5, T_PROGRAM);
        assert_eq!(s, RramState::Lrs, "gap = {}", d.gap);
        assert_eq!(d.cycles, 1);
    }

    #[test]
    fn reset_in_4ns() {
        let mut d = Rram::in_state(RramState::Lrs);
        let s = d.program_pulse(-1.5, T_PROGRAM);
        assert_eq!(s, RramState::Hrs, "gap = {}", d.gap);
    }

    #[test]
    fn no_read_disturb() {
        // §V-B: "0.8–1.05 V read voltage … 1–2 ns read window … sufficient
        // to measure the conductance without altering the memory state".
        let mut d = Rram::in_state(RramState::Hrs);
        let g_before = d.gap;
        for _ in 0..1_000_000 {
            // A million 2 ns reads at the worst-case 1.05 V.
            d.apply_voltage(1.05, 2.0e-9);
            if (d.gap - g_before).abs() > 1e-4 {
                break;
            }
        }
        assert!((d.gap - g_before).abs() < 1e-3, "gap drifted: {}", d.gap - g_before);
        assert_eq!(d.state(), RramState::Hrs);
    }

    #[test]
    fn below_threshold_no_switching() {
        let mut d = Rram::new();
        d.apply_voltage(1.0, 100.0e-9); // long pulse below V_set
        assert_eq!(d.state(), RramState::Hrs);
    }

    #[test]
    fn hysteresis_sweep_shape() {
        let mut d = Rram::new();
        let pts = iv_sweep(&mut d, 1.5, 200, 0.1e-9);
        // Forward leg: device must switch to LRS somewhere past +1.2 V.
        let set_leg = &pts[..200];
        let before_thresh: Vec<f64> = set_leg
            .iter()
            .filter(|(v, _)| *v > 0.3 && *v < 1.1)
            .map(|(v, i)| (v / i).abs())
            .collect();
        assert!(before_thresh.iter().all(|r| *r > 2.0e5), "pre-SET should be HRS-like");
        // After full sweep positive leg the device is LRS.
        let r_after_set = {
            let (v, i) = pts[399]; // end of the +v→0 leg, near 0 V
            let _ = (v, i);
            d.clone()
        };
        drop(r_after_set);
        // Reverse leg returns the device to HRS.
        assert_eq!(d.state(), RramState::Hrs);
        // And the sweep must contain both low- and high-resistance branches
        // at the same |V| — the hysteresis signature.
        let r_at = |target: f64| -> Vec<f64> {
            pts.iter()
                .filter(|(v, _)| (*v - target).abs() < 0.02)
                .map(|(v, i)| (v / i).abs())
                .collect()
        };
        let branch = r_at(0.8);
        let rmin = branch.iter().cloned().fold(f64::MAX, f64::min);
        let rmax = branch.iter().cloned().fold(f64::MIN, f64::max);
        assert!(rmax / rmin > 10.0, "no hysteresis: {rmin}..{rmax}");
    }

    #[test]
    fn nonlinear_sinh_conduction_in_hrs() {
        // sinh tunneling: HRS effective resistance drops with bias, while
        // the metallic LRS filament stays ohmic.
        let h = Rram::in_state(RramState::Hrs);
        assert!(h.resistance(0.8) < 0.5 * h.resistance(0.05));
        let l = Rram::in_state(RramState::Lrs);
        assert!((l.resistance(0.8) - l.resistance(0.05)).abs() / l.resistance(0.05) < 0.01);
    }

    #[test]
    fn mc_multiplier_scales_resistance() {
        let mut d = Rram::in_state(RramState::Lrs);
        let r0 = d.read_resistance();
        d.r_mult = 1.10;
        assert!((d.read_resistance() / r0 - 1.10).abs() < 1e-9);
    }
}
