//! Monte-Carlo process variation.
//!
//! The paper's Fig. 13 reports the Monte-Carlo spread of the 128-row PIM
//! output voltage/current for a 1-LSB input change, and §V-E injects
//! "Gaussian noise with variable standard deviations estimated from Monte
//! Carlo simulations" into the ADC output for the accuracy study. This
//! module is the source of those σ values: it samples per-device local
//! mismatch and provides the derived per-cell current spread.

use crate::util::rng::Pcg64;

/// Global variation model: σ values for each mismatch source.
///
/// Magnitudes are representative of 22 nm FDSOI local (within-die) mismatch
/// for minimum devices plus typical filamentary-RRAM cycle-to-cycle /
/// device-to-device spread (LRS tighter than HRS, as universally reported).
#[derive(Clone, Copy, Debug)]
pub struct VariationModel {
    /// FET threshold-voltage local mismatch σ (V).
    pub sigma_vth: f64,
    /// FET β (drive) multiplicative mismatch σ (fraction).
    pub sigma_beta: f64,
    /// LRS resistance multiplicative σ (fraction).
    pub sigma_r_lrs: f64,
    /// HRS resistance multiplicative σ (fraction).
    pub sigma_r_hrs: f64,
    /// ADC comparator input-referred offset σ (V).
    pub sigma_cmp_offset: f64,
    /// Sample-and-hold kT/C + switch noise σ (V).
    pub sigma_sh: f64,
}

impl Default for VariationModel {
    fn default() -> Self {
        VariationModel {
            sigma_vth: 0.018,
            sigma_beta: 0.03,
            sigma_r_lrs: 0.05,
            sigma_r_hrs: 0.08,
            sigma_cmp_offset: 0.002,
            sigma_sh: 0.0008,
        }
    }
}

impl VariationModel {
    /// No-variation model (nominal corners only).
    pub fn none() -> Self {
        VariationModel {
            sigma_vth: 0.0,
            sigma_beta: 0.0,
            sigma_r_lrs: 0.0,
            sigma_r_hrs: 0.0,
            sigma_cmp_offset: 0.0,
            sigma_sh: 0.0,
        }
    }

    /// Sample one cell's mismatch.
    pub fn sample_cell(&self, rng: &mut Pcg64) -> CellVariation {
        CellVariation {
            vth_delta: rng.normal(0.0, self.sigma_vth),
            beta_mult: (1.0 + rng.normal(0.0, self.sigma_beta)).max(0.5),
            r_lrs_mult: (1.0 + rng.normal(0.0, self.sigma_r_lrs)).max(0.5),
            r_hrs_mult: (1.0 + rng.normal(0.0, self.sigma_r_hrs)).max(0.5),
        }
    }

    /// Sample a comparator offset (per ADC instance).
    pub fn sample_cmp_offset(&self, rng: &mut Pcg64) -> f64 {
        rng.normal(0.0, self.sigma_cmp_offset)
    }

    /// Sample one S&H noise realization (per conversion).
    pub fn sample_sh_noise(&self, rng: &mut Pcg64) -> f64 {
        rng.normal(0.0, self.sigma_sh)
    }
}

/// Per-cell sampled mismatch, consumed by the cell/array models.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct CellVariation {
    /// Additive Vth shift applied to all six transistors of the cell (V).
    pub vth_delta: f64,
    /// Multiplicative drive spread.
    pub beta_mult: f64,
    /// Multiplicative R_LRS spread for both RRAMs of the cell.
    pub r_lrs_mult: f64,
    /// Multiplicative R_HRS spread.
    pub r_hrs_mult: f64,
}

impl CellVariation {
    /// Zero-mismatch (nominal) cell.
    pub fn nominal() -> CellVariation {
        CellVariation { vth_delta: 0.0, beta_mult: 1.0, r_lrs_mult: 1.0, r_hrs_mult: 1.0 }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn nominal_model_is_deterministic() {
        let m = VariationModel::none();
        let mut rng = Pcg64::seeded(1);
        let c = m.sample_cell(&mut rng);
        assert_eq!(c, CellVariation::nominal());
        assert_eq!(m.sample_cmp_offset(&mut rng), 0.0);
    }

    #[test]
    fn sampled_spread_matches_sigma() {
        let m = VariationModel::default();
        let mut rng = Pcg64::seeded(2);
        let n = 20_000;
        let vths: Vec<f64> = (0..n).map(|_| m.sample_cell(&mut rng).vth_delta).collect();
        let mean = vths.iter().sum::<f64>() / n as f64;
        let std =
            (vths.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / (n - 1) as f64).sqrt();
        assert!(mean.abs() < 1e-3);
        assert!((std - m.sigma_vth).abs() / m.sigma_vth < 0.05, "std = {std}");
    }

    #[test]
    fn multipliers_positive() {
        let m = VariationModel::default();
        let mut rng = Pcg64::seeded(3);
        for _ in 0..10_000 {
            let c = m.sample_cell(&mut rng);
            assert!(c.beta_mult > 0.0 && c.r_lrs_mult > 0.0 && c.r_hrs_mult > 0.0);
        }
    }

    #[test]
    fn reproducible_with_same_seed() {
        let m = VariationModel::default();
        let a = m.sample_cell(&mut Pcg64::seeded(7));
        let b = m.sample_cell(&mut Pcg64::seeded(7));
        assert_eq!(a, b);
    }
}
