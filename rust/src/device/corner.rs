//! Process corners.
//!
//! The paper sweeps SS / TT / FF in the linearity study (Fig. 10, Fig. 11a)
//! and attributes the FF-corner nonlinearity to "stronger transistor drive
//! … which reduces the effective voltage swing across the RRAM stack"
//! (§V-C). The corner parameters below scale FET drive (β) and shift
//! threshold voltage (Vth) in the conventional slow/typical/fast pattern.

/// Process corner.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Corner {
    /// Slow-slow: weak drive, high Vth.
    SS,
    /// Typical-typical.
    TT,
    /// Fast-fast: strong drive, low Vth.
    FF,
}

impl Corner {
    /// All corners, slow to fast.
    pub const ALL: [Corner; 3] = [Corner::SS, Corner::TT, Corner::FF];

    /// Canonical two-letter name.
    pub fn name(&self) -> &'static str {
        match self {
            Corner::SS => "SS",
            Corner::TT => "TT",
            Corner::FF => "FF",
        }
    }

    /// Parse a (case-insensitive) corner name.
    pub fn from_name(s: &str) -> Option<Corner> {
        match s.to_ascii_uppercase().as_str() {
            "SS" => Some(Corner::SS),
            "TT" => Some(Corner::TT),
            "FF" => Some(Corner::FF),
            _ => None,
        }
    }

    /// Corner parameter multipliers/shifts relative to TT.
    pub fn params(&self) -> CornerParams {
        match self {
            // ±Vth shift and drive scaling chosen to be representative of a
            // 22 nm FDSOI global-corner spread (≈ ±40 mV Vth, ∓20/+25 % β).
            Corner::SS => CornerParams { beta_scale: 0.80, vth_shift: 0.040, leak_scale: 0.4 },
            Corner::TT => CornerParams { beta_scale: 1.00, vth_shift: 0.000, leak_scale: 1.0 },
            Corner::FF => CornerParams { beta_scale: 1.25, vth_shift: -0.040, leak_scale: 2.5 },
        }
    }
}

/// Per-corner FET parameter modifiers.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct CornerParams {
    /// Transconductance scaling relative to TT.
    pub beta_scale: f64,
    /// Threshold-voltage shift relative to TT (V); applied with matching
    /// sign convention to NMOS and PMOS (FF = lower |Vth| on both).
    pub vth_shift: f64,
    /// Subthreshold-leakage scaling relative to TT.
    pub leak_scale: f64,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ordering_of_drive() {
        let ss = Corner::SS.params();
        let tt = Corner::TT.params();
        let ff = Corner::FF.params();
        assert!(ss.beta_scale < tt.beta_scale && tt.beta_scale < ff.beta_scale);
        assert!(ss.vth_shift > tt.vth_shift && tt.vth_shift > ff.vth_shift);
        assert!(ff.leak_scale > tt.leak_scale);
    }

    #[test]
    fn names_roundtrip() {
        for c in Corner::ALL {
            assert_eq!(Corner::from_name(c.name()), Some(c));
        }
        assert_eq!(Corner::from_name("tt"), Some(Corner::TT));
        assert_eq!(Corner::from_name("xx"), None);
    }
}
