//! RRAM reliability: endurance cycling and retention drift.
//!
//! §I notes NVMs "suffer from higher write latency and limited endurance";
//! the paper's deployment argument (§III-A) is that inference reads vastly
//! outnumber programming events. This module quantifies that: an endurance
//! model (window closure with SET/RESET cycling) and a retention model
//! (thermally-activated gap relaxation), plus the derived
//! "inference-years per reprogram" budget.

use crate::device::rram::{Rram, RramState};

/// Endurance model parameters.
#[derive(Clone, Copy, Debug)]
pub struct EnduranceModel {
    /// Cycles at which the resistance window has closed to 50 % (the
    /// usual endurance criterion). HfOx-class devices: 1e6–1e9.
    pub cycles_50pct: f64,
    /// Weibull-ish shape of window closure vs cycles.
    pub shape: f64,
}

impl Default for EnduranceModel {
    fn default() -> Self {
        EnduranceModel { cycles_50pct: 1.0e7, shape: 1.2 }
    }
}

impl EnduranceModel {
    /// Remaining HRS/LRS window fraction after `cycles` SET+RESET pairs
    /// (1.0 = fresh, 0.5 = endurance criterion, → 0 = stuck).
    pub fn window_fraction(&self, cycles: f64) -> f64 {
        let x = (cycles / self.cycles_50pct).max(0.0);
        (0.5f64).powf(x.powf(self.shape))
    }

    /// Is the device still usable (window above fraction `min_window`)?
    pub fn usable(&self, cycles: f64, min_window: f64) -> bool {
        self.window_fraction(cycles) >= min_window
    }

    /// Max weight-update campaigns before the window crosses `min_window`.
    pub fn max_campaigns(&self, min_window: f64) -> f64 {
        // Invert window_fraction: x = (log2(1/w))^(1/shape).
        let lg = (1.0 / min_window).log2();
        self.cycles_50pct * lg.powf(1.0 / self.shape)
    }

    /// Campaigns still available to a bank that has already absorbed
    /// `cycles` write cycles, under the `min_window` criterion (0 when the
    /// budget is exhausted). The fleet placer uses this headroom to refuse
    /// placements that would over-commit a bank's endurance.
    pub fn remaining_campaigns(&self, cycles: f64, min_window: f64) -> f64 {
        (self.max_campaigns(min_window) - cycles).max(0.0)
    }
}

/// Retention model: thermally-activated gap relaxation toward HRS.
#[derive(Clone, Copy, Debug)]
pub struct RetentionModel {
    /// Gap drift rate at 85 °C (nm per decade of seconds past t0).
    pub drift_per_decade: f64,
    /// Reference time t0 (s).
    pub t0: f64,
}

impl Default for RetentionModel {
    fn default() -> Self {
        // Tuned for the usual "10-year retention at 85 °C" spec: total
        // drift over 10 years ≈ 0.25 nm ≪ the 0.8 nm decision margin.
        RetentionModel { drift_per_decade: 0.028, t0: 1.0 }
    }
}

impl RetentionModel {
    /// Gap drift after `t` seconds in LRS (filament relaxes, gap grows).
    pub fn gap_drift(&self, t: f64) -> f64 {
        if t <= self.t0 {
            0.0
        } else {
            self.drift_per_decade * (t / self.t0).log10()
        }
    }

    /// Apply retention aging to a device.
    pub fn age(&self, dev: &mut Rram, t: f64) {
        if dev.state() == RramState::Lrs {
            dev.gap = (dev.gap + self.gap_drift(t)).min(dev.params.g_max);
        }
    }

    /// Does a fresh-LRS device still read as LRS after `t` seconds?
    pub fn retains(&self, t: f64) -> bool {
        let mut d = Rram::in_state(RramState::Lrs);
        self.age(&mut d, t);
        d.state() == RramState::Lrs
    }
}

/// Deployment budget (§III-A's "reads far outweigh programming"):
/// inferences possible per weight campaign given the endurance budget and
/// a model lifetime.
pub fn inferences_per_reprogram(
    inference_rate_per_s: f64,
    reprogram_interval_s: f64,
) -> f64 {
    inference_rate_per_s * reprogram_interval_s
}

#[cfg(test)]
mod tests {
    use super::*;

    const YEAR_S: f64 = 365.25 * 24.0 * 3600.0;

    #[test]
    fn fresh_device_full_window() {
        let e = EnduranceModel::default();
        assert!((e.window_fraction(0.0) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn endurance_criterion_at_spec() {
        let e = EnduranceModel::default();
        assert!((e.window_fraction(e.cycles_50pct) - 0.5).abs() < 1e-9);
        assert!(e.usable(1e5, 0.8), "early life must be healthy");
        assert!(!e.usable(1e9, 0.5), "deep wear-out fails the criterion");
    }

    #[test]
    fn max_campaigns_inverts_window() {
        let e = EnduranceModel::default();
        let c = e.max_campaigns(0.5);
        assert!((e.window_fraction(c) - 0.5).abs() < 1e-6);
        assert!(e.max_campaigns(0.8) < c, "stricter window ⇒ fewer campaigns");
    }

    #[test]
    fn remaining_campaigns_headroom() {
        let e = EnduranceModel::default();
        let max = e.max_campaigns(0.8);
        assert!((e.remaining_campaigns(0.0, 0.8) - max).abs() < 1e-6);
        assert!((e.remaining_campaigns(max / 2.0, 0.8) - max / 2.0).abs() < 1e-6);
        assert_eq!(e.remaining_campaigns(max * 2.0, 0.8), 0.0, "clamped at zero");
    }

    #[test]
    fn ten_year_retention() {
        let r = RetentionModel::default();
        assert!(r.retains(10.0 * YEAR_S), "10-year spec");
        // Drift is monotone in time and log-shaped.
        assert!(r.gap_drift(1e6) > r.gap_drift(1e3));
        assert!(r.gap_drift(1e6) - r.gap_drift(1e3) < 2.0 * (r.gap_drift(1e3) - r.gap_drift(1.0)) + 1e-9);
    }

    #[test]
    fn aging_only_affects_lrs() {
        let r = RetentionModel::default();
        let mut hrs = Rram::in_state(RramState::Hrs);
        let g = hrs.gap;
        r.age(&mut hrs, 1e9);
        assert_eq!(hrs.gap, g, "HRS is the relaxed state — no drift modeled");
    }

    #[test]
    fn deployment_budget_dominates_endurance() {
        // §III-A's argument quantified: daily reprogramming for 10 years is
        // 3653 campaigns — 4 orders of magnitude inside the 1e7 endurance —
        // while serving ~500 img/s between reprograms.
        let e = EnduranceModel::default();
        let campaigns_10yr_daily = 10.0 * 365.25;
        assert!(e.usable(campaigns_10yr_daily, 0.95));
        let inf = inferences_per_reprogram(500.0, 24.0 * 3600.0);
        assert!(inf > 4e7, "reads outweigh programming by >1e7×: {inf}");
    }
}
