//! The in-tree, dependency-free runtime backend.
//!
//! [`StubRuntime`] implements [`Runtime`] by routing every
//! [`ModelVariant`] through the digital-exact [`ResNet`] forward with the
//! [`crate::pim::TransferModel`] ADC emulation — the same math the AOT
//! JAX/Pallas pipeline bakes into its HLO exports — and the standalone
//! 128×128 MAC tile through [`PimEngine`]. It therefore reproduces
//! Table II natively, keeps `rust/tests/runtime_crosscheck.rs` meaningful
//! (backend output vs. ground-truth math), and needs nothing beyond the
//! weight/dataset artifacts; the MAC tile needs no artifacts at all.
//!
//! Variant → forward-mode mapping (mirrors `python/compile/model.py`):
//!
//! | [`ModelVariant`] | weights            | [`ForwardMode`]        |
//! |------------------|--------------------|------------------------|
//! | `Baseline`       | `weights.bin`      | `Baseline` (fp32)      |
//! | `Pim`            | `weights_ft.bin`   | `Pim` (ADC emulation)  |
//! | `PimNoise`       | `weights_ft.bin`   | `PimNoise(σ)`          |
//! | `PimHw`          | `weights_ft.bin`   | `PimHw` (4-bit kernel) |
//!
//! The noise sigma σ (in ADC code units) comes from the artifact
//! manifest's `noise_sigma` key when present, else the training default
//! 0.5 (`python/compile/model.py::resnet_forward`).

use std::cell::RefCell;
use std::collections::HashMap;
use std::collections::HashSet;
use std::rc::Rc;

use crate::nn::resnet::Params;
use crate::nn::{ForwardMode, ResNet, Tensor, Transformer};
use crate::pim::attn::CompiledTransformer;
use crate::pim::parallel::Parallelism;
use crate::pim::program::{CompiledNet, ScratchPool};
use crate::pim::quant::QuantizedActs;
use crate::pim::PimEngine;
use crate::{Error, Result};

use super::artifact::ArtifactDir;
use super::{ModelVariant, Runtime};

/// Default per-conversion ADC noise sigma in code units — the value
/// `python/compile/model.py` trains the `pim_noise` variant with.
pub const DEFAULT_NOISE_SIGMA: f64 = 0.5;

/// Kernel artifacts the stub knows how to emulate.
const KNOWN_KERNELS: [&str; 1] = ["pim_mac.hlo.txt"];

/// Dependency-free [`Runtime`] backend over the native [`ResNet`] +
/// [`PimEngine`] stack.
///
/// [`Runtime::load_variant`] is the compile step: weights are parsed and
/// compiled into a [`CompiledNet`] **once per model config** (weights
/// file) at the depth the variant reads — quantized + packed banks for
/// the hardware-true variant, dense-only for the fp32/emulation variants
/// — then every forward is pure prepared execution: zero weight
/// quantization/packing per batch (`rust/tests/program_parity.rs`).
pub struct StubRuntime {
    batch: usize,
    models: HashMap<ModelVariant, Rc<CompiledNet>>,
    /// Transformer programs, loaded via
    /// [`Self::load_transformer_params`] — the second workload family,
    /// served through the same variant → mode mapping.
    tfm_models: HashMap<ModelVariant, Rc<CompiledTransformer>>,
    /// Compiled programs keyed by weights file, so the three PIM variants
    /// sharing `weights_ft.bin` parse, quantize, and pack it once.
    by_file: HashMap<&'static str, Rc<CompiledNet>>,
    kernels: HashSet<String>,
    engine: PimEngine,
    /// Worker-pool width applied to every forward and MAC tile
    /// ([`Runtime::set_parallelism`]); the persistent `pim::parallel`
    /// pool for that width is spawned on first use and reused across
    /// batches. Outputs are bit-identical at any width, so this only
    /// changes throughput.
    parallelism: Parallelism,
    /// Reusable per-layer buffers shared by every compiled forward
    /// (single executor thread; never borrowed reentrantly).
    scratch: RefCell<ScratchPool>,
    noise_sigma: f64,
    /// Set by [`Self::with_noise_sigma`]; a manifest `noise_sigma` never
    /// overrides an explicit caller choice.
    noise_sigma_overridden: bool,
}

impl StubRuntime {
    /// A stub runtime executing at a fixed `batch` size. Infallible: the
    /// backend has no client/device to initialize.
    pub fn new(batch: usize) -> StubRuntime {
        StubRuntime {
            batch,
            models: HashMap::new(),
            tfm_models: HashMap::new(),
            by_file: HashMap::new(),
            kernels: HashSet::new(),
            engine: PimEngine::tt(),
            parallelism: Parallelism::serial(),
            scratch: RefCell::new(ScratchPool::new()),
            noise_sigma: DEFAULT_NOISE_SIGMA,
            noise_sigma_overridden: false,
        }
    }

    /// Override the [`ModelVariant::PimNoise`] sigma (code units). Takes
    /// precedence over any manifest `noise_sigma`.
    pub fn with_noise_sigma(mut self, sigma_codes: f64) -> StubRuntime {
        self.noise_sigma = sigma_codes;
        self.noise_sigma_overridden = true;
        self
    }

    /// Builder form of [`Runtime::set_parallelism`].
    pub fn with_parallelism(mut self, par: Parallelism) -> StubRuntime {
        Runtime::set_parallelism(&mut self, par);
        self
    }

    /// Load a variant from in-memory parameters instead of an artifact
    /// directory — lets tests and the quickstart example exercise the full
    /// runtime path with synthetic weights, no artifacts required.
    /// Compiles the network immediately (the same compile-once step
    /// [`Runtime::load_variant`] performs, at the same mode-aware depth).
    pub fn load_variant_params(&mut self, variant: ModelVariant, params: Params) -> Result<()> {
        let program = Rc::new(Self::compile_for(&ResNet::new(params), variant)?);
        self.models.insert(variant, program);
        Ok(())
    }

    /// Does this variant execute through the hardware-true engine (and
    /// therefore read the prepared quantized banks)?
    fn needs_prepared(variant: ModelVariant) -> bool {
        variant == ModelVariant::PimHw
    }

    /// Compile at the depth the variant reads: full (banks included) for
    /// the hardware-true variant, dense-only for the fp32/emulation
    /// variants — mirroring `NativeExecutor::new` / `ResNet::forward_par`.
    fn compile_for(net: &ResNet, variant: ModelVariant) -> Result<CompiledNet> {
        if Self::needs_prepared(variant) {
            net.compile()
        } else {
            CompiledNet::compile_dense(net)
        }
    }

    /// Load a transformer variant from an in-memory model — the
    /// transformer counterpart of [`Self::load_variant_params`], at the
    /// same mode-aware compile depth (prepared banks only for the
    /// hardware-true variant).
    pub fn load_transformer_params(
        &mut self,
        variant: ModelVariant,
        t: &Transformer,
    ) -> Result<()> {
        let program = if Self::needs_prepared(variant) {
            t.compile()?
        } else {
            CompiledTransformer::compile_dense(t)?
        };
        self.tfm_models.insert(variant, Rc::new(program));
        Ok(())
    }

    /// Forward one fixed-size batch of token sequences through a loaded
    /// transformer variant. `tokens` is `batch × seq_len × d_model`
    /// flattened; returns `batch × n_classes` logits. The variant → mode
    /// mapping, key/seed handling, and prepared-execution guarantees are
    /// exactly those of [`Runtime::forward`].
    pub fn forward_transformer(
        &self,
        variant: ModelVariant,
        tokens: &[f32],
        key: Option<[u32; 2]>,
    ) -> Result<Vec<f32>> {
        let program = self
            .tfm_models
            .get(&variant)
            .ok_or_else(|| Error::Runtime(format!("transformer {variant:?} not loaded")))?;
        let cfg = program.cfg;
        if tokens.len() != self.batch * cfg.input_elems() {
            return Err(Error::Runtime(format!(
                "batch shape mismatch: {} elements for batch {} × {}×{}",
                tokens.len(),
                self.batch,
                cfg.seq_len,
                cfg.d_model
            )));
        }
        let mode = match variant {
            ModelVariant::Baseline => ForwardMode::Baseline,
            ModelVariant::Pim => ForwardMode::Pim,
            ModelVariant::PimNoise => {
                if key.is_none() {
                    return Err(Error::Runtime("PimNoise requires a key".into()));
                }
                ForwardMode::PimNoise(self.noise_sigma)
            }
            ModelVariant::PimHw => ForwardMode::PimHw,
        };
        let x = Tensor::from_vec(
            &[self.batch, cfg.seq_len, cfg.d_model],
            tokens.to_vec(),
        );
        Ok(program
            .forward_par(
                &x,
                mode,
                Self::seed_from_key(key),
                self.parallelism,
                &mut self.scratch.borrow_mut(),
            )
            .data)
    }

    /// Register an emulated kernel without an artifact directory — the
    /// artifact-free counterpart of [`Runtime::load_kernel`], same
    /// known-kernel validation.
    pub fn load_kernel_emulated(&mut self, file: &str) -> Result<()> {
        if !KNOWN_KERNELS.contains(&file) {
            return Err(Error::Artifact(format!(
                "stub runtime has no emulation for kernel `{file}`"
            )));
        }
        self.kernels.insert(file.to_string());
        Ok(())
    }

    fn seed_from_key(key: Option<[u32; 2]>) -> u64 {
        key.map(|k| ((k[0] as u64) << 32) | k[1] as u64).unwrap_or(0)
    }
}

impl Runtime for StubRuntime {
    fn platform(&self) -> String {
        "stub (native digital-exact emulation)".to_string()
    }

    fn batch(&self) -> usize {
        self.batch
    }

    fn set_parallelism(&mut self, par: Parallelism) {
        self.parallelism = par;
        self.engine.parallelism = par;
    }

    fn load_variant(&mut self, dir: &ArtifactDir, variant: ModelVariant) -> Result<()> {
        if self.models.contains_key(&variant) {
            return Ok(());
        }
        if !self.noise_sigma_overridden {
            if let Some(sigma) = dir.manifest.get_f64("noise_sigma") {
                self.noise_sigma = sigma;
            }
        }
        let file = variant.weights_file();
        // Reuse the per-file program; if this variant needs the prepared
        // banks and the cached compile was dense-only, upgrade in place
        // from the already-reordered dense matrices (no weights
        // re-parse) and re-point every variant sharing the old program,
        // so exactly one copy of the network stays resident.
        let program = match self.by_file.get(file).cloned() {
            Some(shared) if !Self::needs_prepared(variant) || shared.fully_prepared() => shared,
            Some(dense) => {
                let upgraded = Rc::new(dense.prepare_banks());
                for held in self.models.values_mut() {
                    if Rc::ptr_eq(held, &dense) {
                        *held = upgraded.clone();
                    }
                }
                self.by_file.insert(file, upgraded.clone());
                upgraded
            }
            None => {
                let net = ResNet::load(&dir.path(file)?)?;
                let compiled = Rc::new(Self::compile_for(&net, variant)?);
                self.by_file.insert(file, compiled.clone());
                compiled
            }
        };
        self.models.insert(variant, program);
        Ok(())
    }

    fn load_kernel(&mut self, _dir: &ArtifactDir, file: &str) -> Result<()> {
        self.load_kernel_emulated(file)
    }

    fn forward(
        &self,
        variant: ModelVariant,
        images: &[f32],
        dims: (usize, usize, usize),
        key: Option<[u32; 2]>,
    ) -> Result<Vec<f32>> {
        let program = self
            .models
            .get(&variant)
            .ok_or_else(|| Error::Runtime(format!("{variant:?} not loaded")))?;
        let (h, w, c) = dims;
        if images.len() != self.batch * h * w * c {
            return Err(Error::Runtime(format!(
                "batch shape mismatch: {} elements for batch {} × {h}×{w}×{c}",
                images.len(),
                self.batch
            )));
        }
        let mode = match variant {
            ModelVariant::Baseline => ForwardMode::Baseline,
            ModelVariant::Pim => ForwardMode::Pim,
            ModelVariant::PimNoise => {
                if key.is_none() {
                    return Err(Error::Runtime("PimNoise requires a key".into()));
                }
                ForwardMode::PimNoise(self.noise_sigma)
            }
            ModelVariant::PimHw => ForwardMode::PimHw,
        };
        let x = Tensor::from_vec(&[self.batch, h, w, c], images.to_vec());
        // Pure prepared execution: the program was quantized and packed at
        // load time, so this allocates/prepares no weight state.
        Ok(program
            .forward_par(
                &x,
                mode,
                Self::seed_from_key(key),
                self.parallelism,
                &mut self.scratch.borrow_mut(),
            )
            .data)
    }

    fn pim_mac_tile(&self, a: &[f32], w: &[f32]) -> Result<Vec<f32>> {
        // Enforce the load-before-use contract even though the emulation
        // needs no artifact — otherwise code written against the stub
        // would break on a backend that actually compiles the kernel.
        if !self.kernels.contains("pim_mac.hlo.txt") {
            return Err(Error::Runtime("pim_mac kernel not loaded".into()));
        }
        const TILE: usize = 128;
        if a.len() != TILE * TILE || w.len() != TILE * TILE {
            return Err(Error::Runtime(format!(
                "pim_mac tile must be {TILE}×{TILE}, got a:{} w:{}",
                a.len(),
                w.len()
            )));
        }
        // Values outside the 4-bit range would index past the engine's
        // 16-entry spread LUT (activations) or overflow the 16-bit
        // per-plane packing (weights) — reject instead.
        let to_nibbles = |xs: &[f32], name: &str| -> Result<Vec<u8>> {
            xs.iter()
                .map(|&x| {
                    if (0.0..=15.0).contains(&x) {
                        Ok(x as u8)
                    } else {
                        Err(Error::Runtime(format!(
                            "pim_mac {name} values must be in 0..=15, got {x}"
                        )))
                    }
                })
                .collect()
        };
        let qa = QuantizedActs {
            data: to_nibbles(a, "activation")?,
            m: TILE,
            k: TILE,
            scale: 1.0,
        };
        let bank = to_nibbles(w, "weight")?;
        Ok(self.engine.bank_mac(&qa, &bank, TILE, None))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nn::resnet::test_params;
    use crate::util::rng::Pcg64;

    fn images(batch: usize, seed: u64) -> Vec<f32> {
        let mut rng = Pcg64::seeded(seed);
        (0..batch * 16 * 16 * 3).map(|_| rng.f64() as f32).collect()
    }

    #[test]
    fn forward_requires_loaded_variant() {
        let rt = StubRuntime::new(1);
        let err = rt.forward(ModelVariant::Baseline, &images(1, 1), (16, 16, 3), None);
        assert!(err.is_err());
    }

    #[test]
    fn forward_and_classify_via_params() {
        let mut rt = StubRuntime::new(2);
        rt.load_variant_params(ModelVariant::Baseline, test_params(8, 10, 1)).unwrap();
        let x = images(2, 2);
        let logits = rt.forward(ModelVariant::Baseline, &x, (16, 16, 3), None).unwrap();
        assert_eq!(logits.len(), 2 * 10);
        assert!(logits.iter().all(|v| v.is_finite()));
        let preds = rt.classify(ModelVariant::Baseline, &x, (16, 16, 3), 10, None).unwrap();
        assert_eq!(preds.len(), 2);
        assert!(preds.iter().all(|&p| p < 10));
    }

    #[test]
    fn transformer_forward_via_params() {
        use crate::nn::transformer::{test_tfm_params, TfmConfig};
        let cfg = TfmConfig { seq_len: 4, d_model: 16, n_heads: 2, d_ff: 32, ..TfmConfig::tiny() };
        let t = Transformer::new(test_tfm_params(cfg, 11), cfg);
        let mut rt = StubRuntime::new(2);
        rt.load_transformer_params(ModelVariant::PimHw, &t).unwrap();
        let mut rng = Pcg64::seeded(12);
        let x: Vec<f32> = (0..2 * cfg.input_elems()).map(|_| rng.f64() as f32).collect();
        let logits = rt.forward_transformer(ModelVariant::PimHw, &x, None).unwrap();
        assert_eq!(logits.len(), 2 * cfg.n_classes);
        assert!(logits.iter().all(|v| v.is_finite()));
        // Unloaded variant and wrong shapes error.
        assert!(rt.forward_transformer(ModelVariant::Baseline, &x, None).is_err());
        assert!(rt.forward_transformer(ModelVariant::PimHw, &x[1..], None).is_err());
    }

    #[test]
    fn shape_mismatch_rejected() {
        let mut rt = StubRuntime::new(2);
        rt.load_variant_params(ModelVariant::Baseline, test_params(8, 10, 1)).unwrap();
        let x = images(1, 3); // half the expected batch
        assert!(rt.forward(ModelVariant::Baseline, &x, (16, 16, 3), None).is_err());
    }

    #[test]
    fn noise_requires_key_and_is_deterministic_in_it() {
        let mut rt = StubRuntime::new(1);
        rt.load_variant_params(ModelVariant::PimNoise, test_params(8, 10, 5)).unwrap();
        let x = images(1, 4);
        assert!(rt.forward(ModelVariant::PimNoise, &x, (16, 16, 3), None).is_err());
        let a = rt.forward(ModelVariant::PimNoise, &x, (16, 16, 3), Some([1, 2])).unwrap();
        let b = rt.forward(ModelVariant::PimNoise, &x, (16, 16, 3), Some([1, 2])).unwrap();
        let c = rt.forward(ModelVariant::PimNoise, &x, (16, 16, 3), Some([3, 4])).unwrap();
        assert_eq!(a, b, "same key ⇒ identical logits");
        assert_ne!(a, c, "different key ⇒ different noise");
    }

    #[test]
    fn parallelism_is_a_pure_throughput_knob() {
        // Same variant, same inputs: a threaded stub must produce
        // bit-identical logits and predictions to the serial stub.
        let x = images(2, 9);
        let mut serial = StubRuntime::new(2);
        serial.load_variant_params(ModelVariant::PimHw, test_params(8, 10, 3)).unwrap();
        let mut threaded = StubRuntime::new(2).with_parallelism(Parallelism::threads(4));
        threaded.load_variant_params(ModelVariant::PimHw, test_params(8, 10, 3)).unwrap();
        let a = serial.forward(ModelVariant::PimHw, &x, (16, 16, 3), None).unwrap();
        let b = threaded.forward(ModelVariant::PimHw, &x, (16, 16, 3), None).unwrap();
        assert_eq!(a, b);
        // The MAC-tile kernel path follows the configured width too.
        serial.load_kernel_emulated("pim_mac.hlo.txt").unwrap();
        threaded.load_kernel_emulated("pim_mac.hlo.txt").unwrap();
        let tile = vec![1.0f32; 128 * 128];
        assert_eq!(
            serial.pim_mac_tile(&tile, &tile).unwrap(),
            threaded.pim_mac_tile(&tile, &tile).unwrap()
        );
    }

    #[test]
    fn unknown_kernel_rejected() {
        let mut rt = StubRuntime::new(1);
        let dir = {
            // Per-process path: /tmp is shared across users/CI jobs.
            let d = std::env::temp_dir()
                .join(format!("nvm_stub_kernel_test_{}", std::process::id()));
            std::fs::create_dir_all(&d).unwrap();
            std::fs::write(d.join("manifest.txt"), "eval_batch=1\n").unwrap();
            ArtifactDir::open(&d).unwrap()
        };
        assert!(rt.load_kernel(&dir, "pim_mac.hlo.txt").is_ok());
        assert!(rt.load_kernel(&dir, "nonsense.hlo.txt").is_err());
        let _ = std::fs::remove_dir_all(&dir.root);
    }

    #[test]
    fn mac_tile_requires_load() {
        let rt = StubRuntime::new(1);
        let a = vec![1.0f32; 128 * 128];
        assert!(rt.pim_mac_tile(&a, &a).is_err(), "unloaded kernel must error");
    }

    #[test]
    fn mac_tile_rejects_out_of_range_values() {
        let mut rt = StubRuntime::new(1);
        rt.load_kernel_emulated("pim_mac.hlo.txt").unwrap();
        let ok = vec![1.0f32; 128 * 128];
        let mut bad = ok.clone();
        bad[0] = 16.0;
        assert!(rt.pim_mac_tile(&bad, &ok).is_err(), "activation 16 must error");
        assert!(rt.pim_mac_tile(&ok, &bad).is_err(), "weight 16 must error");
        let mut neg = ok.clone();
        neg[5] = -1.0;
        assert!(rt.pim_mac_tile(&neg, &ok).is_err(), "negative value must error");
    }

    #[test]
    fn mac_tile_matches_engine() {
        let mut rt = StubRuntime::new(1);
        rt.load_kernel_emulated("pim_mac.hlo.txt").unwrap();
        let mut rng = Pcg64::seeded(7);
        let a_int: Vec<u8> = (0..128 * 128).map(|_| rng.below(16) as u8).collect();
        let w_int: Vec<u8> = (0..128 * 128).map(|_| rng.below(16) as u8).collect();
        let a_f: Vec<f32> = a_int.iter().map(|&x| x as f32).collect();
        let w_f: Vec<f32> = w_int.iter().map(|&x| x as f32).collect();
        let got = rt.pim_mac_tile(&a_f, &w_f).unwrap();
        let want = PimEngine::tt().bank_mac(
            &QuantizedActs { data: a_int, m: 128, k: 128, scale: 1.0 },
            &w_int,
            128,
            None,
        );
        assert_eq!(got, want);
    }
}
