//! The in-tree, dependency-free runtime backend.
//!
//! [`StubRuntime`] implements [`Runtime`] by routing every
//! [`ModelVariant`] through the digital-exact [`ResNet`] forward with the
//! [`crate::pim::TransferModel`] ADC emulation — the same math the AOT
//! JAX/Pallas pipeline bakes into its HLO exports — and the standalone
//! 128×128 MAC tile through [`PimEngine`]. It therefore reproduces
//! Table II natively, keeps `rust/tests/runtime_crosscheck.rs` meaningful
//! (backend output vs. ground-truth math), and needs nothing beyond the
//! weight/dataset artifacts; the MAC tile needs no artifacts at all.
//!
//! Variant → forward-mode mapping (mirrors `python/compile/model.py`):
//!
//! | [`ModelVariant`] | weights            | [`ForwardMode`]        |
//! |------------------|--------------------|------------------------|
//! | `Baseline`       | `weights.bin`      | `Baseline` (fp32)      |
//! | `Pim`            | `weights_ft.bin`   | `Pim` (ADC emulation)  |
//! | `PimNoise`       | `weights_ft.bin`   | `PimNoise(σ)`          |
//! | `PimHw`          | `weights_ft.bin`   | `PimHw` (4-bit kernel) |
//!
//! The noise sigma σ (in ADC code units) comes from the artifact
//! manifest's `noise_sigma` key when present, else the training default
//! 0.5 (`python/compile/model.py::resnet_forward`).

use std::collections::HashMap;
use std::collections::HashSet;
use std::rc::Rc;

use crate::nn::resnet::Params;
use crate::nn::{ForwardMode, ResNet, Tensor};
use crate::pim::parallel::Parallelism;
use crate::pim::quant::QuantizedActs;
use crate::pim::PimEngine;
use crate::{Error, Result};

use super::artifact::ArtifactDir;
use super::{ModelVariant, Runtime};

/// Default per-conversion ADC noise sigma in code units — the value
/// `python/compile/model.py` trains the `pim_noise` variant with.
pub const DEFAULT_NOISE_SIGMA: f64 = 0.5;

/// Kernel artifacts the stub knows how to emulate.
const KNOWN_KERNELS: [&str; 1] = ["pim_mac.hlo.txt"];

/// Dependency-free [`Runtime`] backend over the native [`ResNet`] +
/// [`PimEngine`] stack.
pub struct StubRuntime {
    batch: usize,
    models: HashMap<ModelVariant, Rc<ResNet>>,
    /// Loaded networks keyed by weights file, so the three PIM variants
    /// sharing `weights_ft.bin` parse and hold it once.
    by_file: HashMap<&'static str, Rc<ResNet>>,
    kernels: HashSet<String>,
    engine: PimEngine,
    /// Worker-pool width applied to every forward and MAC tile
    /// ([`Runtime::set_parallelism`]); outputs are bit-identical at any
    /// width, so this only changes throughput.
    parallelism: Parallelism,
    noise_sigma: f64,
    /// Set by [`Self::with_noise_sigma`]; a manifest `noise_sigma` never
    /// overrides an explicit caller choice.
    noise_sigma_overridden: bool,
}

impl StubRuntime {
    /// A stub runtime executing at a fixed `batch` size. Infallible: the
    /// backend has no client/device to initialize.
    pub fn new(batch: usize) -> StubRuntime {
        StubRuntime {
            batch,
            models: HashMap::new(),
            by_file: HashMap::new(),
            kernels: HashSet::new(),
            engine: PimEngine::tt(),
            parallelism: Parallelism::serial(),
            noise_sigma: DEFAULT_NOISE_SIGMA,
            noise_sigma_overridden: false,
        }
    }

    /// Override the [`ModelVariant::PimNoise`] sigma (code units). Takes
    /// precedence over any manifest `noise_sigma`.
    pub fn with_noise_sigma(mut self, sigma_codes: f64) -> StubRuntime {
        self.noise_sigma = sigma_codes;
        self.noise_sigma_overridden = true;
        self
    }

    /// Builder form of [`Runtime::set_parallelism`].
    pub fn with_parallelism(mut self, par: Parallelism) -> StubRuntime {
        Runtime::set_parallelism(&mut self, par);
        self
    }

    /// Load a variant from in-memory parameters instead of an artifact
    /// directory — lets tests and the quickstart example exercise the full
    /// runtime path with synthetic weights, no artifacts required.
    pub fn load_variant_params(&mut self, variant: ModelVariant, params: Params) {
        self.models.insert(variant, Rc::new(ResNet::new(params)));
    }

    /// Register an emulated kernel without an artifact directory — the
    /// artifact-free counterpart of [`Runtime::load_kernel`], same
    /// known-kernel validation.
    pub fn load_kernel_emulated(&mut self, file: &str) -> Result<()> {
        if !KNOWN_KERNELS.contains(&file) {
            return Err(Error::Artifact(format!(
                "stub runtime has no emulation for kernel `{file}`"
            )));
        }
        self.kernels.insert(file.to_string());
        Ok(())
    }

    fn seed_from_key(key: Option<[u32; 2]>) -> u64 {
        key.map(|k| ((k[0] as u64) << 32) | k[1] as u64).unwrap_or(0)
    }
}

impl Runtime for StubRuntime {
    fn platform(&self) -> String {
        "stub (native digital-exact emulation)".to_string()
    }

    fn batch(&self) -> usize {
        self.batch
    }

    fn set_parallelism(&mut self, par: Parallelism) {
        self.parallelism = par;
        self.engine.parallelism = par;
    }

    fn load_variant(&mut self, dir: &ArtifactDir, variant: ModelVariant) -> Result<()> {
        if self.models.contains_key(&variant) {
            return Ok(());
        }
        if !self.noise_sigma_overridden {
            if let Some(sigma) = dir.manifest.get_f64("noise_sigma") {
                self.noise_sigma = sigma;
            }
        }
        let file = variant.weights_file();
        let net = match self.by_file.get(file).cloned() {
            Some(shared) => shared,
            None => {
                let loaded = Rc::new(ResNet::load(&dir.path(file)?)?);
                self.by_file.insert(file, loaded.clone());
                loaded
            }
        };
        self.models.insert(variant, net);
        Ok(())
    }

    fn load_kernel(&mut self, _dir: &ArtifactDir, file: &str) -> Result<()> {
        self.load_kernel_emulated(file)
    }

    fn forward(
        &self,
        variant: ModelVariant,
        images: &[f32],
        dims: (usize, usize, usize),
        key: Option<[u32; 2]>,
    ) -> Result<Vec<f32>> {
        let net = self
            .models
            .get(&variant)
            .ok_or_else(|| Error::Runtime(format!("{variant:?} not loaded")))?;
        let (h, w, c) = dims;
        if images.len() != self.batch * h * w * c {
            return Err(Error::Runtime(format!(
                "batch shape mismatch: {} elements for batch {} × {h}×{w}×{c}",
                images.len(),
                self.batch
            )));
        }
        let mode = match variant {
            ModelVariant::Baseline => ForwardMode::Baseline,
            ModelVariant::Pim => ForwardMode::Pim,
            ModelVariant::PimNoise => {
                if key.is_none() {
                    return Err(Error::Runtime("PimNoise requires a key".into()));
                }
                ForwardMode::PimNoise(self.noise_sigma)
            }
            ModelVariant::PimHw => ForwardMode::PimHw,
        };
        let x = Tensor::from_vec(&[self.batch, h, w, c], images.to_vec());
        Ok(net
            .forward_par(&x, mode, Self::seed_from_key(key), self.parallelism)?
            .data)
    }

    fn pim_mac_tile(&self, a: &[f32], w: &[f32]) -> Result<Vec<f32>> {
        // Enforce the load-before-use contract even though the emulation
        // needs no artifact — otherwise code written against the stub
        // would break on a backend that actually compiles the kernel.
        if !self.kernels.contains("pim_mac.hlo.txt") {
            return Err(Error::Runtime("pim_mac kernel not loaded".into()));
        }
        const TILE: usize = 128;
        if a.len() != TILE * TILE || w.len() != TILE * TILE {
            return Err(Error::Runtime(format!(
                "pim_mac tile must be {TILE}×{TILE}, got a:{} w:{}",
                a.len(),
                w.len()
            )));
        }
        // Values outside the 4-bit range would index past the engine's
        // 16-entry spread LUT (activations) or overflow the 16-bit
        // per-plane packing (weights) — reject instead.
        let to_nibbles = |xs: &[f32], name: &str| -> Result<Vec<u8>> {
            xs.iter()
                .map(|&x| {
                    if (0.0..=15.0).contains(&x) {
                        Ok(x as u8)
                    } else {
                        Err(Error::Runtime(format!(
                            "pim_mac {name} values must be in 0..=15, got {x}"
                        )))
                    }
                })
                .collect()
        };
        let qa = QuantizedActs {
            data: to_nibbles(a, "activation")?,
            m: TILE,
            k: TILE,
            scale: 1.0,
        };
        let bank = to_nibbles(w, "weight")?;
        Ok(self.engine.bank_mac(&qa, &bank, TILE, None))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nn::resnet::test_params;
    use crate::util::rng::Pcg64;

    fn images(batch: usize, seed: u64) -> Vec<f32> {
        let mut rng = Pcg64::seeded(seed);
        (0..batch * 16 * 16 * 3).map(|_| rng.f64() as f32).collect()
    }

    #[test]
    fn forward_requires_loaded_variant() {
        let rt = StubRuntime::new(1);
        let err = rt.forward(ModelVariant::Baseline, &images(1, 1), (16, 16, 3), None);
        assert!(err.is_err());
    }

    #[test]
    fn forward_and_classify_via_params() {
        let mut rt = StubRuntime::new(2);
        rt.load_variant_params(ModelVariant::Baseline, test_params(8, 10, 1));
        let x = images(2, 2);
        let logits = rt.forward(ModelVariant::Baseline, &x, (16, 16, 3), None).unwrap();
        assert_eq!(logits.len(), 2 * 10);
        assert!(logits.iter().all(|v| v.is_finite()));
        let preds = rt.classify(ModelVariant::Baseline, &x, (16, 16, 3), 10, None).unwrap();
        assert_eq!(preds.len(), 2);
        assert!(preds.iter().all(|&p| p < 10));
    }

    #[test]
    fn shape_mismatch_rejected() {
        let mut rt = StubRuntime::new(2);
        rt.load_variant_params(ModelVariant::Baseline, test_params(8, 10, 1));
        let x = images(1, 3); // half the expected batch
        assert!(rt.forward(ModelVariant::Baseline, &x, (16, 16, 3), None).is_err());
    }

    #[test]
    fn noise_requires_key_and_is_deterministic_in_it() {
        let mut rt = StubRuntime::new(1);
        rt.load_variant_params(ModelVariant::PimNoise, test_params(8, 10, 5));
        let x = images(1, 4);
        assert!(rt.forward(ModelVariant::PimNoise, &x, (16, 16, 3), None).is_err());
        let a = rt.forward(ModelVariant::PimNoise, &x, (16, 16, 3), Some([1, 2])).unwrap();
        let b = rt.forward(ModelVariant::PimNoise, &x, (16, 16, 3), Some([1, 2])).unwrap();
        let c = rt.forward(ModelVariant::PimNoise, &x, (16, 16, 3), Some([3, 4])).unwrap();
        assert_eq!(a, b, "same key ⇒ identical logits");
        assert_ne!(a, c, "different key ⇒ different noise");
    }

    #[test]
    fn parallelism_is_a_pure_throughput_knob() {
        // Same variant, same inputs: a threaded stub must produce
        // bit-identical logits and predictions to the serial stub.
        let x = images(2, 9);
        let mut serial = StubRuntime::new(2);
        serial.load_variant_params(ModelVariant::PimHw, test_params(8, 10, 3));
        let mut threaded = StubRuntime::new(2).with_parallelism(Parallelism::threads(4));
        threaded.load_variant_params(ModelVariant::PimHw, test_params(8, 10, 3));
        let a = serial.forward(ModelVariant::PimHw, &x, (16, 16, 3), None).unwrap();
        let b = threaded.forward(ModelVariant::PimHw, &x, (16, 16, 3), None).unwrap();
        assert_eq!(a, b);
        // The MAC-tile kernel path follows the configured width too.
        serial.load_kernel_emulated("pim_mac.hlo.txt").unwrap();
        threaded.load_kernel_emulated("pim_mac.hlo.txt").unwrap();
        let tile = vec![1.0f32; 128 * 128];
        assert_eq!(
            serial.pim_mac_tile(&tile, &tile).unwrap(),
            threaded.pim_mac_tile(&tile, &tile).unwrap()
        );
    }

    #[test]
    fn unknown_kernel_rejected() {
        let mut rt = StubRuntime::new(1);
        let dir = {
            // Per-process path: /tmp is shared across users/CI jobs.
            let d = std::env::temp_dir()
                .join(format!("nvm_stub_kernel_test_{}", std::process::id()));
            std::fs::create_dir_all(&d).unwrap();
            std::fs::write(d.join("manifest.txt"), "eval_batch=1\n").unwrap();
            ArtifactDir::open(&d).unwrap()
        };
        assert!(rt.load_kernel(&dir, "pim_mac.hlo.txt").is_ok());
        assert!(rt.load_kernel(&dir, "nonsense.hlo.txt").is_err());
        let _ = std::fs::remove_dir_all(&dir.root);
    }

    #[test]
    fn mac_tile_requires_load() {
        let rt = StubRuntime::new(1);
        let a = vec![1.0f32; 128 * 128];
        assert!(rt.pim_mac_tile(&a, &a).is_err(), "unloaded kernel must error");
    }

    #[test]
    fn mac_tile_rejects_out_of_range_values() {
        let mut rt = StubRuntime::new(1);
        rt.load_kernel_emulated("pim_mac.hlo.txt").unwrap();
        let ok = vec![1.0f32; 128 * 128];
        let mut bad = ok.clone();
        bad[0] = 16.0;
        assert!(rt.pim_mac_tile(&bad, &ok).is_err(), "activation 16 must error");
        assert!(rt.pim_mac_tile(&ok, &bad).is_err(), "weight 16 must error");
        let mut neg = ok.clone();
        neg[5] = -1.0;
        assert!(rt.pim_mac_tile(&neg, &ok).is_err(), "negative value must error");
    }

    #[test]
    fn mac_tile_matches_engine() {
        let mut rt = StubRuntime::new(1);
        rt.load_kernel_emulated("pim_mac.hlo.txt").unwrap();
        let mut rng = Pcg64::seeded(7);
        let a_int: Vec<u8> = (0..128 * 128).map(|_| rng.below(16) as u8).collect();
        let w_int: Vec<u8> = (0..128 * 128).map(|_| rng.below(16) as u8).collect();
        let a_f: Vec<f32> = a_int.iter().map(|&x| x as f32).collect();
        let w_f: Vec<f32> = w_int.iter().map(|&x| x as f32).collect();
        let got = rt.pim_mac_tile(&a_f, &w_f).unwrap();
        let want = PimEngine::tt().bank_mac(
            &QuantizedActs { data: a_int, m: 128, k: 128, scale: 1.0 },
            &w_int,
            128,
            None,
        );
        assert_eq!(got, want);
    }
}
