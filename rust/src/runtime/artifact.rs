//! Artifact directory + manifest handling.
//!
//! `make artifacts` populates `artifacts/` (see DESIGN.md §5); this module
//! locates and validates the pieces the runtime needs.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use crate::{Error, Result};

/// Parsed key=value manifest (written by `python/compile/aot.py`).
#[derive(Clone, Debug, Default)]
pub struct Manifest {
    pub entries: BTreeMap<String, String>,
}

impl Manifest {
    pub fn parse(text: &str) -> Manifest {
        let entries = text
            .lines()
            .filter_map(|l| {
                let l = l.trim();
                if l.is_empty() || l.starts_with('#') {
                    return None;
                }
                l.split_once('=')
                    .map(|(k, v)| (k.trim().to_string(), v.trim().to_string()))
            })
            .collect();
        Manifest { entries }
    }

    pub fn load(path: &Path) -> Result<Manifest> {
        Ok(Self::parse(&std::fs::read_to_string(path)?))
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.entries.get(key).map(|s| s.as_str())
    }

    pub fn get_f64(&self, key: &str) -> Option<f64> {
        self.get(key)?.parse().ok()
    }

    pub fn get_usize(&self, key: &str) -> Option<usize> {
        self.get(key)?.parse().ok()
    }

    /// Reported accuracy for a Table II row (fraction in [0,1]).
    pub fn accuracy(&self, row: &str) -> Option<f64> {
        self.get_f64(&format!("acc_{row}"))
    }
}

/// The artifact directory with existence checks.
#[derive(Clone, Debug)]
pub struct ArtifactDir {
    pub root: PathBuf,
    pub manifest: Manifest,
}

impl ArtifactDir {
    pub fn open<P: Into<PathBuf>>(root: P) -> Result<ArtifactDir> {
        let root = root.into();
        let manifest_path = root.join("manifest.txt");
        if !manifest_path.exists() {
            return Err(Error::Artifact(format!(
                "{} missing — run `make artifacts` first",
                manifest_path.display()
            )));
        }
        Ok(ArtifactDir { root: root.clone(), manifest: Manifest::load(&manifest_path)? })
    }

    pub fn path(&self, name: &str) -> Result<PathBuf> {
        let p = self.root.join(name);
        if !p.exists() {
            return Err(Error::Artifact(format!("missing artifact {}", p.display())));
        }
        Ok(p)
    }

    pub fn eval_batch(&self) -> usize {
        self.manifest.get_usize("eval_batch").unwrap_or(50)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_manifest() {
        let m = Manifest::parse("a=1\n# comment\n  key = value \n\nacc_baseline=0.9234\n");
        assert_eq!(m.get("a"), Some("1"));
        assert_eq!(m.get("key"), Some("value"));
        assert_eq!(m.accuracy("baseline"), Some(0.9234));
        assert_eq!(m.get("missing"), None);
    }

    #[test]
    fn open_missing_dir_fails_helpfully() {
        let err = ArtifactDir::open("/nonexistent_artifacts").unwrap_err();
        assert!(err.to_string().contains("make artifacts"));
    }

    #[test]
    fn artifact_dir_roundtrip() {
        let dir = std::env::temp_dir().join("nvm_artifacts_test");
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(dir.join("manifest.txt"), "eval_batch=25\n").unwrap();
        std::fs::write(dir.join("x.hlo.txt"), "HloModule x").unwrap();
        let ad = ArtifactDir::open(&dir).unwrap();
        assert_eq!(ad.eval_batch(), 25);
        assert!(ad.path("x.hlo.txt").is_ok());
        assert!(ad.path("missing.hlo.txt").is_err());
    }
}
