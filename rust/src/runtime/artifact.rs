//! Artifact directory + manifest handling.
//!
//! The build-time pipeline (`python/compile/aot.py`) populates
//! `artifacts/` with weights, the evaluation dataset, HLO exports, and a
//! key=value manifest; this module locates and validates the pieces the
//! runtime needs. See EXPERIMENTS.md E10.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use crate::{Error, Result};

/// Parsed key=value manifest (written by `python/compile/aot.py`).
#[derive(Clone, Debug, Default)]
pub struct Manifest {
    /// All key=value entries, sorted by key.
    pub entries: BTreeMap<String, String>,
}

impl Manifest {
    /// Parse manifest text: `key=value` lines, `#` comments, blank lines.
    pub fn parse(text: &str) -> Manifest {
        let entries = text
            .lines()
            .filter_map(|l| {
                let l = l.trim();
                if l.is_empty() || l.starts_with('#') {
                    return None;
                }
                l.split_once('=')
                    .map(|(k, v)| (k.trim().to_string(), v.trim().to_string()))
            })
            .collect();
        Manifest { entries }
    }

    /// Load + parse a manifest file.
    pub fn load(path: &Path) -> Result<Manifest> {
        Ok(Self::parse(&std::fs::read_to_string(path)?))
    }

    /// Raw string value for `key`.
    pub fn get(&self, key: &str) -> Option<&str> {
        self.entries.get(key).map(|s| s.as_str())
    }

    /// `key` parsed as f64 (None when absent or unparsable).
    pub fn get_f64(&self, key: &str) -> Option<f64> {
        self.get(key)?.parse().ok()
    }

    /// `key` parsed as usize (None when absent or unparsable).
    pub fn get_usize(&self, key: &str) -> Option<usize> {
        self.get(key)?.parse().ok()
    }

    /// Reported accuracy for a Table II row (fraction in [0,1]).
    pub fn accuracy(&self, row: &str) -> Option<f64> {
        self.get_f64(&format!("acc_{row}"))
    }
}

/// The artifact directory with existence checks.
#[derive(Clone, Debug)]
pub struct ArtifactDir {
    /// Directory root.
    pub root: PathBuf,
    /// The parsed `manifest.txt`.
    pub manifest: Manifest,
}

impl ArtifactDir {
    /// Open an artifact directory, requiring its `manifest.txt`.
    pub fn open<P: Into<PathBuf>>(root: P) -> Result<ArtifactDir> {
        let root = root.into();
        let manifest_path = root.join("manifest.txt");
        if !manifest_path.exists() {
            return Err(Error::Artifact(format!(
                "{} missing — artifacts not built (see python/compile/aot.py)",
                manifest_path.display()
            )));
        }
        Ok(ArtifactDir { root: root.clone(), manifest: Manifest::load(&manifest_path)? })
    }

    /// Absolute path of artifact `name`, verified to exist.
    pub fn path(&self, name: &str) -> Result<PathBuf> {
        let p = self.root.join(name);
        if !p.exists() {
            return Err(Error::Artifact(format!("missing artifact {}", p.display())));
        }
        Ok(p)
    }

    /// The batch size every model variant was exported at.
    pub fn eval_batch(&self) -> usize {
        self.manifest.get_usize("eval_batch").unwrap_or(50)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_manifest() {
        let m = Manifest::parse("a=1\n# comment\n  key = value \n\nacc_baseline=0.9234\n");
        assert_eq!(m.get("a"), Some("1"));
        assert_eq!(m.get("key"), Some("value"));
        assert_eq!(m.accuracy("baseline"), Some(0.9234));
        assert_eq!(m.get("missing"), None);
    }

    #[test]
    fn open_missing_dir_fails_helpfully() {
        let err = ArtifactDir::open("/nonexistent_artifacts").unwrap_err();
        assert!(err.to_string().contains("artifacts not built"));
    }

    #[test]
    fn artifact_dir_roundtrip() {
        let dir = std::env::temp_dir().join("nvm_artifacts_test");
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(dir.join("manifest.txt"), "eval_batch=25\n").unwrap();
        std::fs::write(dir.join("x.hlo.txt"), "HloModule x").unwrap();
        let ad = ArtifactDir::open(&dir).unwrap();
        assert_eq!(ad.eval_batch(), 25);
        assert!(ad.path("x.hlo.txt").is_ok());
        assert!(ad.path("missing.hlo.txt").is_err());
    }
}
