//! xla-crate (PJRT CPU) wrapper.
//!
//! Interchange is HLO *text* — `HloModuleProto::from_text_file` reassigns
//! instruction ids, avoiding the 64-bit-id protos that xla_extension 0.5.1
//! rejects (see /opt/xla-example/README.md).

use std::collections::HashMap;
use std::path::Path;

use crate::{Error, Result};

use super::artifact::ArtifactDir;

/// Which exported model variant to execute.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum ModelVariant {
    /// fp32 baseline forward.
    Baseline,
    /// Table II emulation: per-layer ADC nonlinearity (no noise).
    Pim,
    /// Table II emulation + ADC noise (takes a u32[2] threefry key).
    PimNoise,
    /// Hardware-true pipeline with the pallas kernel lowered in.
    PimHw,
}

impl ModelVariant {
    pub fn file(&self) -> &'static str {
        match self {
            ModelVariant::Baseline => "model_baseline.hlo.txt",
            ModelVariant::Pim => "model_pim.hlo.txt",
            ModelVariant::PimNoise => "model_pim_noise.hlo.txt",
            ModelVariant::PimHw => "model_pim_hw.hlo.txt",
        }
    }

    pub const ALL: [ModelVariant; 4] = [
        ModelVariant::Baseline,
        ModelVariant::Pim,
        ModelVariant::PimNoise,
        ModelVariant::PimHw,
    ];
}

/// PJRT runtime with a cache of compiled executables.
pub struct Runtime {
    client: xla::PjRtClient,
    executables: HashMap<ModelVariant, xla::PjRtLoadedExecutable>,
    kernels: HashMap<String, xla::PjRtLoadedExecutable>,
    pub batch: usize,
}

impl Runtime {
    pub fn new(batch: usize) -> Result<Runtime> {
        Ok(Runtime {
            client: xla::PjRtClient::cpu()?,
            executables: HashMap::new(),
            kernels: HashMap::new(),
            batch,
        })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    fn compile_file(client: &xla::PjRtClient, path: &Path) -> Result<xla::PjRtLoadedExecutable> {
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str()
                .ok_or_else(|| Error::Artifact("non-utf8 path".into()))?,
        )?;
        let comp = xla::XlaComputation::from_proto(&proto);
        Ok(client.compile(&comp)?)
    }

    /// Load + compile a model variant (idempotent).
    pub fn load_variant(&mut self, dir: &ArtifactDir, variant: ModelVariant) -> Result<()> {
        if self.executables.contains_key(&variant) {
            return Ok(());
        }
        let path = dir.path(variant.file())?;
        let exe = Self::compile_file(&self.client, &path)?;
        self.executables.insert(variant, exe);
        Ok(())
    }

    /// Load + compile an arbitrary kernel artifact by file name.
    pub fn load_kernel(&mut self, dir: &ArtifactDir, file: &str) -> Result<()> {
        if self.kernels.contains_key(file) {
            return Ok(());
        }
        let exe = Self::compile_file(&self.client, &dir.path(file)?)?;
        self.kernels.insert(file.to_string(), exe);
        Ok(())
    }

    fn run_exe(
        exe: &xla::PjRtLoadedExecutable,
        inputs: &[xla::Literal],
    ) -> Result<Vec<f32>> {
        let result = exe.execute::<xla::Literal>(inputs)?[0][0].to_literal_sync()?;
        // Exports lower with return_tuple=True ⇒ unwrap the 1-tuple.
        let out = result.to_tuple1()?;
        Ok(out.to_vec::<f32>()?)
    }

    /// Run a model variant on a batch of images (flattened NHWC f32,
    /// exactly `batch × h × w × c` long). Returns flattened logits.
    pub fn forward(
        &self,
        variant: ModelVariant,
        images: &[f32],
        dims: (usize, usize, usize),
        key: Option<[u32; 2]>,
    ) -> Result<Vec<f32>> {
        let exe = self
            .executables
            .get(&variant)
            .ok_or_else(|| Error::Runtime(format!("{variant:?} not loaded")))?;
        let (h, w, c) = dims;
        assert_eq!(images.len(), self.batch * h * w * c, "batch shape mismatch");
        let x = xla::Literal::vec1(images).reshape(&[
            self.batch as i64,
            h as i64,
            w as i64,
            c as i64,
        ])?;
        let inputs: Vec<xla::Literal> = match (variant, key) {
            (ModelVariant::PimNoise, Some(k)) => {
                vec![x, xla::Literal::vec1(&k[..])]
            }
            (ModelVariant::PimNoise, None) => {
                return Err(Error::Runtime("PimNoise requires a key".into()))
            }
            (_, _) => vec![x],
        };
        Self::run_exe(exe, &inputs)
    }

    /// Run the standalone L1 kernel tile: a,w are 128×128 f32 (integer
    /// values 0..=15); returns the 128×128 dequantized MAC estimates.
    pub fn pim_mac_tile(&self, a: &[f32], w: &[f32]) -> Result<Vec<f32>> {
        let exe = self
            .kernels
            .get("pim_mac.hlo.txt")
            .ok_or_else(|| Error::Runtime("pim_mac kernel not loaded".into()))?;
        let la = xla::Literal::vec1(a).reshape(&[128, 128])?;
        let lw = xla::Literal::vec1(w).reshape(&[128, 128])?;
        Self::run_exe(exe, &[la, lw])
    }

    /// Argmax classification over the forward logits.
    pub fn classify(
        &self,
        variant: ModelVariant,
        images: &[f32],
        dims: (usize, usize, usize),
        n_classes: usize,
        key: Option<[u32; 2]>,
    ) -> Result<Vec<u8>> {
        let logits = self.forward(variant, images, dims, key)?;
        Ok(logits
            .chunks(n_classes)
            .map(|row| {
                row.iter()
                    .enumerate()
                    .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                    .unwrap()
                    .0 as u8
            })
            .collect())
    }
}

// PJRT-dependent tests live in rust/tests/runtime_crosscheck.rs (they need
// built artifacts); here we only test pure logic.
#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn variant_files() {
        assert_eq!(ModelVariant::Baseline.file(), "model_baseline.hlo.txt");
        assert_eq!(ModelVariant::ALL.len(), 4);
    }
}
