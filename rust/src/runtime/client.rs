//! xla-crate (PJRT CPU) wrapper — the real-hardware-compiler backend.
//!
//! Compiled only with `--features pjrt`: the `xla` crate is not vendored
//! in the offline build, so this module is the documented seam where a
//! PJRT backend re-attaches (add the `xla` dependency to rust/Cargo.toml,
//! enable the feature, and every `Runtime` call site picks it up through
//! [`super::default_runtime`]).
//!
//! Interchange is HLO *text* — `HloModuleProto::from_text_file` reassigns
//! instruction ids, avoiding the 64-bit-id protos that xla_extension 0.5.1
//! rejects.

use std::collections::HashMap;
use std::path::Path;

use crate::{Error, Result};

use super::artifact::ArtifactDir;
use super::{ModelVariant, Runtime};

impl From<xla::Error> for Error {
    fn from(e: xla::Error) -> Error {
        Error::Runtime(e.to_string())
    }
}

/// PJRT runtime with a cache of compiled executables.
pub struct PjrtRuntime {
    client: xla::PjRtClient,
    executables: HashMap<ModelVariant, xla::PjRtLoadedExecutable>,
    kernels: HashMap<String, xla::PjRtLoadedExecutable>,
    batch: usize,
}

impl PjrtRuntime {
    /// Initialize the PJRT CPU client at a fixed batch size.
    pub fn new(batch: usize) -> Result<PjrtRuntime> {
        Ok(PjrtRuntime {
            client: xla::PjRtClient::cpu()?,
            executables: HashMap::new(),
            kernels: HashMap::new(),
            batch,
        })
    }

    fn compile_file(client: &xla::PjRtClient, path: &Path) -> Result<xla::PjRtLoadedExecutable> {
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str()
                .ok_or_else(|| Error::Artifact("non-utf8 path".into()))?,
        )?;
        let comp = xla::XlaComputation::from_proto(&proto);
        Ok(client.compile(&comp)?)
    }

    fn run_exe(
        exe: &xla::PjRtLoadedExecutable,
        inputs: &[xla::Literal],
    ) -> Result<Vec<f32>> {
        let result = exe.execute::<xla::Literal>(inputs)?[0][0].to_literal_sync()?;
        // Exports lower with return_tuple=True ⇒ unwrap the 1-tuple.
        let out = result.to_tuple1()?;
        Ok(out.to_vec::<f32>()?)
    }
}

impl Runtime for PjrtRuntime {
    fn platform(&self) -> String {
        self.client.platform_name()
    }

    fn batch(&self) -> usize {
        self.batch
    }

    fn load_variant(&mut self, dir: &ArtifactDir, variant: ModelVariant) -> Result<()> {
        if self.executables.contains_key(&variant) {
            return Ok(());
        }
        let path = dir.path(variant.file())?;
        let exe = Self::compile_file(&self.client, &path)?;
        self.executables.insert(variant, exe);
        Ok(())
    }

    fn load_kernel(&mut self, dir: &ArtifactDir, file: &str) -> Result<()> {
        if self.kernels.contains_key(file) {
            return Ok(());
        }
        let exe = Self::compile_file(&self.client, &dir.path(file)?)?;
        self.kernels.insert(file.to_string(), exe);
        Ok(())
    }

    fn forward(
        &self,
        variant: ModelVariant,
        images: &[f32],
        dims: (usize, usize, usize),
        key: Option<[u32; 2]>,
    ) -> Result<Vec<f32>> {
        let exe = self
            .executables
            .get(&variant)
            .ok_or_else(|| Error::Runtime(format!("{variant:?} not loaded")))?;
        let (h, w, c) = dims;
        assert_eq!(images.len(), self.batch * h * w * c, "batch shape mismatch");
        let x = xla::Literal::vec1(images).reshape(&[
            self.batch as i64,
            h as i64,
            w as i64,
            c as i64,
        ])?;
        let inputs: Vec<xla::Literal> = match (variant, key) {
            (ModelVariant::PimNoise, Some(k)) => {
                vec![x, xla::Literal::vec1(&k[..])]
            }
            (ModelVariant::PimNoise, None) => {
                return Err(Error::Runtime("PimNoise requires a key".into()))
            }
            (_, _) => vec![x],
        };
        Self::run_exe(exe, &inputs)
    }

    fn pim_mac_tile(&self, a: &[f32], w: &[f32]) -> Result<Vec<f32>> {
        let exe = self
            .kernels
            .get("pim_mac.hlo.txt")
            .ok_or_else(|| Error::Runtime("pim_mac kernel not loaded".into()))?;
        let la = xla::Literal::vec1(a).reshape(&[128, 128])?;
        let lw = xla::Literal::vec1(w).reshape(&[128, 128])?;
        Self::run_exe(exe, &[la, lw])
    }
}
