//! PJRT runtime: loads the AOT-compiled HLO artifacts and executes them
//! from the Rust hot path. Python never runs at serve time.
//!
//! * [`artifact`] — manifest parsing + artifact directory handling.
//! * [`client`] — the xla-crate (PJRT C API) wrapper: HLO text →
//!   `HloModuleProto` → compile → execute (one compiled executable per
//!   model variant, reused across requests).

pub mod artifact;
pub mod client;

pub use artifact::{ArtifactDir, Manifest};
pub use client::{ModelVariant, Runtime};
