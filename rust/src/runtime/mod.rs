//! Model-execution runtime: the seam between the serving stack and
//! whatever actually runs the network.
//!
//! The [`Runtime`] trait abstracts "execute a [`ModelVariant`] on a batch
//! of images"; everything above it (CLI `e2e`/`serve`, the coordinator's
//! executor, the examples, the cross-check tests) programs against the
//! trait, so backends are interchangeable:
//!
//! * [`stub`] — [`StubRuntime`], the in-tree, dependency-free backend:
//!   routes variants through the digital-exact [`crate::nn::ResNet`]
//!   forward with the [`crate::pim::TransferModel`] ADC emulation, and the
//!   standalone MAC-tile kernel through [`crate::pim::PimEngine`]. This is
//!   the default (and, offline, the only) backend.
//! * [`client`] — the original xla-crate (PJRT C API) wrapper that loads
//!   AOT-compiled `artifacts/*.hlo.txt` and executes them on the XLA CPU
//!   client. Feature-gated behind `pjrt` because the `xla` crate is not
//!   vendored in the offline build; the module is kept as the re-attachment
//!   point for a real PJRT backend (see ARCHITECTURE.md §Runtime).
//! * [`artifact`] — manifest parsing + artifact directory handling, shared
//!   by every backend.
//!
//! `rust/tests/runtime_crosscheck.rs` pins the contract: any backend's
//! outputs must agree with the Rust-native ground truth ([`crate::nn`] +
//! [`crate::pim`]).

use crate::pim::parallel::Parallelism;
use crate::{Error, Result};

pub mod artifact;
#[cfg(feature = "pjrt")]
pub mod client;
pub mod stub;

pub use artifact::{ArtifactDir, Manifest};
#[cfg(feature = "pjrt")]
pub use client::PjrtRuntime;
pub use stub::StubRuntime;

/// Which exported model variant to execute.
///
/// The four variants mirror `python/compile/model.py`'s forward modes and
/// Table II's rows (see EXPERIMENTS.md E10).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum ModelVariant {
    /// fp32 baseline forward.
    Baseline,
    /// Table II emulation: per-layer ADC nonlinearity (no noise).
    Pim,
    /// Table II emulation + ADC noise (takes a u32[2] threefry key).
    PimNoise,
    /// Hardware-true pipeline with the pallas kernel lowered in.
    PimHw,
}

impl ModelVariant {
    /// HLO artifact file name for this variant (PJRT backend).
    pub fn file(&self) -> &'static str {
        match self {
            ModelVariant::Baseline => "model_baseline.hlo.txt",
            ModelVariant::Pim => "model_pim.hlo.txt",
            ModelVariant::PimNoise => "model_pim_noise.hlo.txt",
            ModelVariant::PimHw => "model_pim_hw.hlo.txt",
        }
    }

    /// Weights artifact this variant runs on (stub backend): the baseline
    /// uses the pre-fine-tuning weights, every PIM variant the fine-tuned
    /// ones (Table II's "fine-tuned" rows).
    pub fn weights_file(&self) -> &'static str {
        match self {
            ModelVariant::Baseline => "weights.bin",
            _ => "weights_ft.bin",
        }
    }

    /// Every variant, in Table II row order.
    pub const ALL: [ModelVariant; 4] = [
        ModelVariant::Baseline,
        ModelVariant::Pim,
        ModelVariant::PimNoise,
        ModelVariant::PimHw,
    ];
}

/// A model-execution backend.
///
/// Implementations hold one compiled/loaded executable per
/// [`ModelVariant`] at a fixed batch size, plus any standalone kernels.
/// All methods are object-safe; the serving stack holds a
/// `Box<dyn Runtime>`.
pub trait Runtime {
    /// Human-readable backend/platform name (for logs).
    fn platform(&self) -> String;

    /// The fixed batch size every loaded variant executes at. Shorter
    /// inputs must be zero-padded by the caller (see
    /// [`crate::coordinator::server::RuntimeExecutor`]).
    fn batch(&self) -> usize;

    /// Configure the worker-pool width used by subsequent forwards.
    /// Outputs are bit-identical at any width ([`crate::pim::parallel`]),
    /// so this is purely a throughput knob; backends without a native
    /// thread pool (e.g. PJRT, which threads internally) may ignore it.
    fn set_parallelism(&mut self, _par: Parallelism) {}

    /// Load and compile a model variant from the artifact directory.
    /// Idempotent. This is the compile-once step of the
    /// compile-once / execute-many contract: backends hold one compiled
    /// program per model config (the stub caches a
    /// [`crate::pim::program::CompiledNet`] per weights file; PJRT holds
    /// an AOT executable), so [`Runtime::forward`] performs no weight
    /// preparation per batch.
    fn load_variant(&mut self, dir: &ArtifactDir, variant: ModelVariant) -> Result<()>;

    /// Load an arbitrary standalone kernel artifact by file name.
    /// Idempotent.
    fn load_kernel(&mut self, dir: &ArtifactDir, file: &str) -> Result<()>;

    /// Run a model variant on a batch of images (flattened NHWC f32,
    /// exactly `batch × h × w × c` long). Returns flattened logits.
    /// `key` seeds the ADC noise for [`ModelVariant::PimNoise`] (required
    /// there, ignored elsewhere): same key ⇒ identical logits.
    fn forward(
        &self,
        variant: ModelVariant,
        images: &[f32],
        dims: (usize, usize, usize),
        key: Option<[u32; 2]>,
    ) -> Result<Vec<f32>>;

    /// Run the standalone L1 kernel tile: `a`,`w` are 128×128 f32 (integer
    /// values 0..=15); returns the 128×128 dequantized MAC estimates.
    fn pim_mac_tile(&self, a: &[f32], w: &[f32]) -> Result<Vec<f32>>;

    /// Argmax classification over the forward logits.
    fn classify(
        &self,
        variant: ModelVariant,
        images: &[f32],
        dims: (usize, usize, usize),
        n_classes: usize,
        key: Option<[u32; 2]>,
    ) -> Result<Vec<u8>> {
        let logits = self.forward(variant, images, dims, key)?;
        // total_cmp: identical tie/NaN argmax semantics as the native
        // paths (ResNet::classify, program::logits_to_classes), so the
        // runtime crosscheck can never diverge on an exact logit tie.
        Ok(logits
            .chunks(n_classes)
            .map(|row| {
                row.iter()
                    .enumerate()
                    .max_by(|a, b| a.1.total_cmp(b.1))
                    .unwrap()
                    .0 as u8
            })
            .collect())
    }
}

/// Construct the default backend for this build: [`PjrtRuntime`] when the
/// `pjrt` feature is enabled, [`StubRuntime`] otherwise.
pub fn default_runtime(batch: usize) -> Result<Box<dyn Runtime>> {
    if batch == 0 {
        return Err(Error::Config("runtime batch must be ≥ 1".into()));
    }
    #[cfg(feature = "pjrt")]
    return Ok(Box::new(client::PjrtRuntime::new(batch)?));
    #[cfg(not(feature = "pjrt"))]
    Ok(Box::new(StubRuntime::new(batch)))
}

/// [`default_runtime`] with the worker-pool width applied up front (the
/// `repro serve`/`repro bench --threads` path).
pub fn default_runtime_par(batch: usize, par: Parallelism) -> Result<Box<dyn Runtime>> {
    let mut rt = default_runtime(batch)?;
    rt.set_parallelism(par);
    Ok(rt)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn variant_files() {
        assert_eq!(ModelVariant::Baseline.file(), "model_baseline.hlo.txt");
        assert_eq!(ModelVariant::Baseline.weights_file(), "weights.bin");
        assert_eq!(ModelVariant::Pim.weights_file(), "weights_ft.bin");
        assert_eq!(ModelVariant::ALL.len(), 4);
    }

    #[test]
    fn default_runtime_rejects_zero_batch() {
        assert!(default_runtime(0).is_err());
        assert!(default_runtime(4).is_ok());
    }
}
