//! The fleet simulator: mixed multi-tenant traffic over many slices, with
//! programming campaigns interleaved — `repro fleet-sim`.
//!
//! The simulation runs on a *simulated* clock: seeded Poisson arrivals per
//! tenant, deterministic routing ([`super::router::FleetRouter`]), and
//! per-request service times from each tenant's placed
//! [`crate::coordinator::BankScheduler`] cost model — so a given seed
//! reproduces the report bit-for-bit (pinned by `rust/tests/fleet.rs`).
//! Optionally it also drives real [`crate::coordinator::Server`] instances
//! (threads + mpsc) to exercise the live serving stack.

use crate::cache::addr::Geometry;
use crate::cache::controller::{CacheController, PimIntegration};
use crate::consts::{ARRAY_ROWS, ARRAY_WORDS};
use crate::coordinator::BankScheduler;
use crate::util::json::Json;
use crate::util::rng::Pcg64;
use crate::util::stats::Summary;
use crate::Result;

use super::campaign::{CampaignReport, CampaignScheduler};
use super::placer::{BankWear, EndurancePlacer, EndurancePolicy, FleetPlacement};
use super::registry::ModelRegistry;
use super::router::{AdmissionController, FleetRouter, ReplicaHealth};

/// Fleet simulation configuration.
#[derive(Clone, Debug)]
pub struct FleetSimConfig {
    /// Slices in the fleet.
    pub n_slices: usize,
    /// Synthetic tenants to generate.
    pub tenants: usize,
    /// Arrival-process seed.
    pub seed: u64,
    /// Requests offered per tenant.
    pub requests_per_tenant: usize,
    /// When reprogramming campaigns start, as a fraction of the expected
    /// traffic horizon (so they interleave with live traffic).
    pub campaign_at_frac: f64,
    /// Also push a small request wave through real
    /// [`crate::coordinator::Server`] instances (threads; wall-clock, so
    /// excluded from the deterministic report fields).
    pub live_serving: bool,
    /// Worker-pool width for the live pass's executors (`fleet-sim
    /// --threads`). The simulated-clock report is analytic and unaffected;
    /// live-pass predictions are bit-identical at any width
    /// ([`crate::pim::parallel`]), so this only changes live throughput.
    pub parallelism: crate::pim::parallel::Parallelism,
}

impl Default for FleetSimConfig {
    fn default() -> Self {
        FleetSimConfig {
            n_slices: 4,
            tenants: 3,
            seed: 42,
            requests_per_tenant: 400,
            campaign_at_frac: 0.5,
            live_serving: false,
            parallelism: crate::pim::parallel::Parallelism::serial(),
        }
    }
}

impl FleetSimConfig {
    /// The small fixed configuration shared by `repro bench` and the
    /// `cargo bench` fleet section (one definition, so the benchmarked
    /// config and its label cannot drift apart).
    pub fn bench_quick() -> FleetSimConfig {
        FleetSimConfig { requests_per_tenant: 150, ..FleetSimConfig::default() }
    }

    /// Stable benchmark label derived from the config, so relabeling can
    /// never lag a config change.
    pub fn bench_label(&self) -> String {
        format!(
            "fleet_sim_{}t_{}s_{}req",
            self.tenants, self.n_slices, self.requests_per_tenant
        )
    }
}

/// Per-tenant outcome.
#[derive(Clone, Debug)]
pub struct TenantReport {
    /// Tenant id.
    pub tenant: usize,
    /// Tenant name.
    pub name: String,
    /// Replicas placed.
    pub replicas: usize,
    /// Requests served.
    pub served: u64,
    /// Requests shed by the admission controller.
    pub rejected: u64,
    /// Served requests that missed the deadline.
    pub violations: u64,
    /// Median simulated latency (s).
    pub p50_s: f64,
    /// 99th-percentile simulated latency (s).
    pub p99_s: f64,
    /// Mean simulated latency (s).
    pub mean_s: f64,
    /// Simulated hardware energy attributed to this tenant (J).
    pub energy_j: f64,
    /// MAC ops executed for this tenant.
    pub ops: f64,
    /// QoS deadline (s), echoed for the report.
    pub deadline_s: f64,
}

impl TenantReport {
    /// Did the tenant meet its violation budget?
    pub fn qos_met(&self, max_violation_frac: f64) -> bool {
        self.served > 0 && self.violations as f64 <= max_violation_frac * self.served as f64
    }
}

/// Summary of the optional live-serving pass.
#[derive(Clone, Copy, Debug)]
pub struct LiveSummary {
    /// Requests submitted across all tenants' servers.
    pub requests: u64,
    /// Responses received.
    pub responses: u64,
    /// Batches executed.
    pub batches: u64,
    /// Weight programs compiled — exactly one per *serving* (tenant,
    /// replica) (replicas with an empty request share skip compiling);
    /// the compiled program is retained across campaign rewarm segments.
    pub compilations: u64,
    /// Serving segments executed (each segment tears the server down and
    /// rebuilds it from the retained program, like a campaign rewarm;
    /// empty segments build no server and are not counted).
    pub segments: u64,
}

/// The full fleet-simulation report.
#[derive(Clone, Debug)]
pub struct FleetReport {
    /// Per-tenant outcomes, in tenant order.
    pub tenants: Vec<TenantReport>,
    /// Simulated makespan (s).
    pub horizon_s: f64,
    /// Aggregate served throughput (req per simulated second).
    pub throughput_rps: f64,
    /// Total simulated energy: serving + programming (J).
    pub total_energy_j: f64,
    /// Total MAC ops.
    pub total_ops: f64,
    /// Campaigns executed mid-traffic.
    pub campaigns: Vec<CampaignReport>,
    /// Total campaign downtime across replicas (s).
    pub downtime_s: f64,
    /// Final per-slice bank wear.
    pub wear: Vec<BankWear>,
    /// All banks within the endurance budget?
    pub wear_ok: bool,
    /// Distinct slices hosting replicas.
    pub slices_used: usize,
    /// Every tenant inside its violation budget?
    pub qos_ok: bool,
    /// The endurance policy `wear_ok` (and the rendered per-slice window
    /// fractions) were judged against.
    pub policy: EndurancePolicy,
    /// Live-serving pass summary (when enabled).
    pub live: Option<LiveSummary>,
}

impl FleetReport {
    /// Human-readable report block.
    pub fn render(&self) -> String {
        use std::fmt::Write as _;
        let mut s = String::new();
        let _ = writeln!(
            s,
            "fleet: {} tenants on {} slices | horizon {:.3} s | {:.1} req/s served | \
             {:.3} mJ | qos {} | wear {}",
            self.tenants.len(),
            self.slices_used,
            self.horizon_s,
            self.throughput_rps,
            self.total_energy_j * 1e3,
            if self.qos_ok { "OK" } else { "VIOLATED" },
            if self.wear_ok { "OK" } else { "EXCEEDED" },
        );
        let _ = writeln!(
            s,
            "{:<14} {:>4} {:>7} {:>6} {:>5} {:>10} {:>10} {:>10} {:>10}",
            "tenant", "reps", "served", "shed", "viol", "p50 ms", "p99 ms", "ddl ms", "energy mJ"
        );
        for t in &self.tenants {
            let _ = writeln!(
                s,
                "{:<14} {:>4} {:>7} {:>6} {:>5} {:>10.3} {:>10.3} {:>10.1} {:>10.3}",
                t.name,
                t.replicas,
                t.served,
                t.rejected,
                t.violations,
                t.p50_s * 1e3,
                t.p99_s * 1e3,
                t.deadline_s * 1e3,
                t.energy_j * 1e3,
            );
        }
        let _ = writeln!(
            s,
            "campaigns: {} | downtime {:.3} ms total",
            self.campaigns.len(),
            self.downtime_s * 1e3
        );
        for c in &self.campaigns {
            let _ = writeln!(
                s,
                "  tenant {} replica {} @ slice {}: drain {:.3} ms, program {:.3} ms, \
                 rewarm {:.3} ms, {} lines displaced",
                c.tenant,
                c.replica,
                c.slice,
                c.drain_s * 1e3,
                c.program_s * 1e3,
                c.rewarm_s * 1e3,
                c.lines_displaced
            );
        }
        for (i, w) in self.wear.iter().enumerate() {
            let programmed = w.cycles.iter().filter(|&&c| c > 0.0).count();
            let _ = writeln!(
                s,
                "slice {i}: {} of {} banks programmed, max {} cycles, min window {:.4}",
                programmed,
                w.cycles.len(),
                w.max_cycles(),
                w.min_window_fraction(&self.policy.model),
            );
        }
        if let Some(live) = &self.live {
            let _ = writeln!(
                s,
                "live pass: {} requests → {} responses in {} batches | \
                 {} programs compiled once, reused over {} rewarm segments",
                live.requests, live.responses, live.batches, live.compilations, live.segments
            );
        }
        s
    }

    /// Machine-readable summary (for `BENCH_*.json` accumulation).
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("slices_used", Json::Num(self.slices_used as f64)),
            ("horizon_s", Json::Num(self.horizon_s)),
            ("throughput_rps", Json::Num(self.throughput_rps)),
            ("total_energy_j", Json::Num(self.total_energy_j)),
            ("total_ops", Json::Num(self.total_ops)),
            ("campaigns", Json::Num(self.campaigns.len() as f64)),
            ("downtime_s", Json::Num(self.downtime_s)),
            ("qos_ok", Json::Bool(self.qos_ok)),
            ("wear_ok", Json::Bool(self.wear_ok)),
            (
                "max_bank_cycles",
                Json::Num(self.wear.iter().map(|w| w.max_cycles()).fold(0.0, f64::max)),
            ),
            (
                "tenants",
                Json::Arr(
                    self.tenants
                        .iter()
                        .map(|t| {
                            Json::obj(vec![
                                ("name", Json::Str(t.name.clone())),
                                ("served", Json::Num(t.served as f64)),
                                ("rejected", Json::Num(t.rejected as f64)),
                                ("violations", Json::Num(t.violations as f64)),
                                ("p50_s", Json::Num(t.p50_s)),
                                ("p99_s", Json::Num(t.p99_s)),
                                ("mean_s", Json::Num(t.mean_s)),
                                ("energy_j", Json::Num(t.energy_j)),
                            ])
                        })
                        .collect(),
                ),
            ),
        ])
    }
}

/// The fleet simulator.
pub struct FleetSim;

impl FleetSim {
    /// Campaign-rewarm serving segments each live-pass replica runs: the
    /// server (threads, batcher, executor) is torn down and rebuilt
    /// between segments while the compiled weight program is retained —
    /// so, when every replica has requests to serve,
    /// `compilations == Σ replicas` while
    /// `segments == LIVE_SEGMENTS · Σ replicas`. Replicas or segments
    /// whose request share is empty neither compile nor count.
    pub const LIVE_SEGMENTS: usize = 2;

    /// Run the full simulation for `config`.
    pub fn run(config: &FleetSimConfig) -> Result<FleetReport> {
        if config.tenants == 0 {
            return Err(crate::Error::Config("fleet-sim needs at least 1 tenant".into()));
        }
        if config.n_slices == 0 {
            return Err(crate::Error::Config("fleet-sim needs at least 1 slice".into()));
        }
        let geom = Geometry::default();
        let registry = ModelRegistry::synthetic(config.tenants);
        // Per-tenant service cost model (layers placed on a reference
        // slice; batch cost is linear in batch, so batch-1 cost is the
        // per-request unit).
        let mut svc_s = Vec::new();
        let mut energy_req = Vec::new();
        let mut ops_req = Vec::new();
        for tenant in &registry.tenants {
            let mut sched =
                BankScheduler::new(tenant.layers(), geom, PimIntegration::Retained)
                    .ok_or_else(|| {
                        crate::Error::Config(format!(
                            "tenant {} does not fit the reference slice",
                            tenant.id
                        ))
                    })?;
            sched.program_network();
            let c1 = sched.batch_cost(1);
            svc_s.push(c1.latency_s);
            energy_req.push(c1.energy_j);
            ops_req.push(c1.ops);
        }

        // Endurance-aware placement.
        let placer = EndurancePlacer::new(geom, config.n_slices);
        let mut fleet = placer.place(&registry)?;

        // Physical slices + initial weight programming (wear for this is
        // already recorded by the placer).
        let mut controllers: Vec<CacheController> = (0..config.n_slices)
            .map(|_| CacheController::new(geom, PimIntegration::Retained))
            .collect();
        let mut total_energy = 0.0;
        for r in &fleet.replicas {
            for tile in &r.layout.placements {
                for (bank, sa) in [tile.pos_slot, tile.neg_slot] {
                    let stats = controllers[r.slice].program_campaign(
                        bank,
                        sa,
                        vec![0u8; ARRAY_ROWS * ARRAY_WORDS],
                    );
                    total_energy += stats.energy;
                }
            }
        }
        // Warm each slice with deterministic background cache traffic so
        // mid-run campaigns displace real resident lines — otherwise the
        // rewarm phase of drain → program → rewarm is structurally zero.
        for (si, ctl) in controllers.iter_mut().enumerate() {
            let mut rng = Pcg64::new(config.seed, 500 + si as u64);
            for _ in 0..4096 {
                ctl.read(crate::cache::Address::new(rng.next_u64() % (1u64 << 24)));
            }
        }

        // Seeded arrival processes (Poisson per tenant).
        let mut arrivals: Vec<(f64, usize)> = Vec::new();
        let mut rates = Vec::new();
        for tenant in &registry.tenants {
            let rate = tenant.utilization * tenant.replicas as f64 / svc_s[tenant.id];
            rates.push(rate);
            let mut rng = Pcg64::new(config.seed, 100 + tenant.id as u64);
            let mut t = 0.0;
            for _ in 0..config.requests_per_tenant {
                t += -(1.0 - rng.f64()).ln() / rate;
                arrivals.push((t, tenant.id));
            }
        }
        arrivals.sort_by(|a, b| a.0.total_cmp(&b.0).then(a.1.cmp(&b.1)));
        // Each tenant's campaign fires midway through *its own* traffic
        // horizon, so every campaign interleaves with that tenant's load.
        let campaign_time: Vec<f64> = registry
            .tenants
            .iter()
            .map(|t| config.campaign_at_frac * config.requests_per_tenant as f64 / rates[t.id])
            .collect();

        // Deterministic traffic + campaign event loop.
        let mut router =
            FleetRouter::new(&registry.tenants.iter().map(|t| t.replicas).collect::<Vec<_>>());
        let mut admission = AdmissionController::new(
            svc_s.clone(),
            registry.tenants.iter().map(|t| t.qos.deadline_s).collect(),
        );
        let mut latencies: Vec<Vec<f64>> = vec![Vec::new(); registry.len()];
        let mut violations = vec![0u64; registry.len()];
        let mut tenant_energy = vec![0.0f64; registry.len()];
        let mut tenant_ops = vec![0.0f64; registry.len()];
        let mut campaigns: Vec<CampaignReport> = Vec::new();
        let mut max_completion = 0.0f64;
        let mut fired = vec![false; registry.len()];
        // Replica 0 of tenant t stays ReplicaHealth::Programming until
        // restore_at[t]; the event loop flips it back to Serving once the
        // simulated clock passes that point, so admission/routing actually
        // observe the outage.
        let mut restore_at: Vec<Option<f64>> = vec![None; registry.len()];
        for &(time, tenant) in &arrivals {
            for t in 0..registry.len() {
                if !fired[t] && time >= campaign_time[t] {
                    fired[t] = true;
                    let report = Self::fire_campaign(
                        t,
                        &mut fleet,
                        &mut controllers,
                        &mut router,
                        campaign_time[t],
                    );
                    total_energy += report.energy_j;
                    let end = campaign_time[t] + report.downtime_s();
                    restore_at[t] = Some(end);
                    max_completion = max_completion.max(end);
                    campaigns.push(report);
                }
                if let Some(end) = restore_at[t] {
                    if time >= end {
                        router.set_health(t, 0, ReplicaHealth::Serving);
                        restore_at[t] = None;
                    }
                }
            }
            if !admission.admit(&router, tenant, time) {
                continue;
            }
            // admit() guarantees a Serving replica exists, so assign()
            // cannot return None here.
            if let Some((_replica, _start, completion)) =
                router.assign(tenant, time, svc_s[tenant])
            {
                let latency = completion - time;
                latencies[tenant].push(latency);
                // 1 ns slack absorbs the association difference between
                // the admission projection and this exact latency.
                violations[tenant] +=
                    (latency > registry.tenants[tenant].qos.deadline_s + 1e-9) as u64;
                tenant_energy[tenant] += energy_req[tenant];
                tenant_ops[tenant] += ops_req[tenant];
                max_completion = max_completion.max(completion);
            }
        }
        // Fire any campaign whose trigger time fell past the last arrival
        // (tiny request counts), so every tenant gets reprogrammed; restore
        // every replica still marked Programming.
        for t in 0..registry.len() {
            if !fired[t] {
                fired[t] = true;
                let report =
                    Self::fire_campaign(t, &mut fleet, &mut controllers, &mut router, campaign_time[t]);
                total_energy += report.energy_j;
                max_completion = max_completion.max(campaign_time[t] + report.downtime_s());
                campaigns.push(report);
            }
            router.set_health(t, 0, ReplicaHealth::Serving);
        }

        // Assemble the report.
        let mut tenants = Vec::new();
        let mut total_ops = 0.0;
        for t in &registry.tenants {
            let stats = Summary::of(&latencies[t.id]);
            total_energy += tenant_energy[t.id];
            total_ops += tenant_ops[t.id];
            tenants.push(TenantReport {
                tenant: t.id,
                name: t.name.clone(),
                replicas: t.replicas,
                served: stats.n as u64,
                rejected: admission.rejected[t.id],
                violations: violations[t.id],
                p50_s: stats.p50,
                p99_s: stats.p99,
                mean_s: stats.mean,
                energy_j: tenant_energy[t.id],
                ops: tenant_ops[t.id],
                deadline_s: t.qos.deadline_s,
            });
        }
        let qos_ok = tenants
            .iter()
            .zip(&registry.tenants)
            .all(|(rep, t)| rep.qos_met(t.qos.max_violation_frac));
        let wear_ok = fleet.wear.iter().all(|w| w.within(&placer.policy));
        let downtime_s = campaigns.iter().map(|c| c.downtime_s()).sum();
        let horizon_s = max_completion.max(1e-12);
        let total_served: u64 = tenants.iter().map(|t| t.served).sum();
        let live = if config.live_serving {
            Some(Self::live_pass(
                &registry,
                config.requests_per_tenant.min(64),
                config.parallelism,
            )?)
        } else {
            None
        };
        Ok(FleetReport {
            slices_used: fleet.slices_used(),
            throughput_rps: total_served as f64 / horizon_s,
            horizon_s,
            total_energy_j: total_energy,
            total_ops,
            campaigns,
            downtime_s,
            wear: fleet.wear,
            wear_ok,
            qos_ok,
            policy: placer.policy,
            tenants,
            live,
        })
    }

    /// Take one tenant's replica 0 into its drain → program → rewarm
    /// campaign at simulated time `now`, while its siblings keep serving.
    ///
    /// On return the replica is left in [`ReplicaHealth::Programming`]
    /// (the drain itself completes within this call — its duration is the
    /// queued work, already accounted in the report); the caller restores
    /// it to Serving once the clock passes `now + downtime`.
    fn fire_campaign(
        tenant: usize,
        fleet: &mut FleetPlacement,
        controllers: &mut [CacheController],
        router: &mut FleetRouter,
        now: f64,
    ) -> CampaignReport {
        let placement = fleet
            .replicas
            .iter()
            .find(|r| r.tenant == tenant && r.replica == 0)
            .cloned()
            .expect("replica 0 placed");
        // The drain phase completes within this synchronous call (its
        // duration is the queued work, reported as drain_s), so the
        // replica goes straight to Programming; the Draining state is for
        // drivers whose drain spans real routing decisions.
        let busy = router.tenants[tenant][0].state.busy_until;
        let drain = (busy - now).max(0.0);
        router.set_health(tenant, 0, ReplicaHealth::Programming);
        let report = CampaignScheduler::run(
            &mut controllers[placement.slice],
            &placement,
            &mut fleet.wear[placement.slice],
            drain,
        );
        // Unavailable until the campaign completes — both via health (the
        // router skips Programming replicas) and via busy_until (anything
        // assigned right after restoration queues behind the rewarm).
        router.tenants[tenant][0].state.busy_until = now + report.downtime_s();
        report
    }

    /// Drive a small request wave through real
    /// [`crate::coordinator::Server`] instances — one per (tenant,
    /// replica) per rewarm segment — each running a hardware-true
    /// PimHw-mode [`crate::coordinator::NativeExecutor`] over a synthetic
    /// network, so the wave serves *from the prepared quantized banks*
    /// on `parallelism` workers (threads + mpsc; wall-clock, so the
    /// numbers are integration evidence, not part of the deterministic
    /// report).
    ///
    /// The compile-once / execute-many contract runs end to end here:
    /// each serving (tenant, replica) compiles its weight program
    /// **once** (mirroring one-time RRAM programming), then the program
    /// is reused across [`Self::LIVE_SEGMENTS`] campaign-rewarm segments
    /// — the server is torn down and rebuilt between segments, the
    /// `Arc`'d program is not. `rust/tests/fleet.rs` pins
    /// `compilations == Σ replicas < segments` for waves large enough
    /// that every replica serves.
    fn live_pass(
        registry: &ModelRegistry,
        requests_per_tenant: usize,
        parallelism: crate::pim::parallel::Parallelism,
    ) -> Result<LiveSummary> {
        use std::sync::Arc;

        use crate::coordinator::server::{Executor, NativeExecutor, Server, ServerConfig};
        use crate::coordinator::{BatcherConfig, InferenceRequest};
        use crate::nn::resnet::test_params;
        use crate::nn::{ForwardMode, ResNet};

        const DIMS: (usize, usize, usize) = (16, 16, 3);
        let elems = DIMS.0 * DIMS.1 * DIMS.2;
        let mut summary =
            LiveSummary { requests: 0, responses: 0, batches: 0, compilations: 0, segments: 0 };
        for tenant in &registry.tenants {
            let tenant_seed = tenant.id as u64;
            let wave = requests_per_tenant;
            let cells = tenant.replicas * Self::LIVE_SEGMENTS;
            let mut img_rng = Pcg64::new(0xA11CE, tenant_seed);
            let mut next_id = (tenant.id * wave) as u64;
            let mut cell = 0usize;
            for _replica in 0..tenant.replicas {
                // This replica's request share per rewarm segment,
                // decided up front: a replica with nothing to serve
                // neither compiles nor counts segments (tiny waves).
                let shares: Vec<usize> = (0..Self::LIVE_SEGMENTS)
                    .map(|_| {
                        let s = wave / cells + usize::from(cell < wave % cells);
                        cell += 1;
                        s
                    })
                    .collect();
                if shares.iter().sum::<usize>() == 0 {
                    continue;
                }
                // Compile once per serving (tenant, replica) — the
                // software mirror of programming this replica's RRAM
                // banks.
                let program = Arc::new(
                    ResNet::new(test_params(8, 10, 1 + tenant_seed))
                        .with_parallelism(parallelism)
                        .compile()?,
                );
                summary.compilations += 1;
                for &n_req in &shares {
                    if n_req == 0 {
                        // An empty segment builds no server and counts
                        // as no rewarm.
                        continue;
                    }
                    summary.segments += 1;
                    let seg_program = program.clone();
                    // PimHw: every batch is served from the prepared
                    // banks (NativeExecutor debug-asserts the loop stays
                    // prepare-free).
                    let server = Server::start(
                        Box::new(move || {
                            Ok(Box::new(NativeExecutor::from_program(
                                seg_program,
                                ForwardMode::PimHw,
                                DIMS,
                                1,
                            )) as Box<dyn Executor>)
                        }),
                        None,
                        ServerConfig {
                            // Continuous batching end-to-end: the live pass
                            // exercises the merged stepped-execution path
                            // (per-group sub-batches, prepare-free steady
                            // state) rather than drain batching.
                            batcher: BatcherConfig::continuous(
                                8,
                                std::time::Duration::from_millis(1),
                            ),
                        },
                    );
                    for _ in 0..n_req {
                        let image: Vec<f32> =
                            (0..elems).map(|_| img_rng.f64() as f32).collect();
                        server.submit(InferenceRequest::new(next_id, image));
                        next_id += 1;
                    }
                    let mut got = 0u64;
                    for _ in 0..n_req {
                        match server
                            .responses
                            .recv_timeout(std::time::Duration::from_secs(30))
                        {
                            Ok(_) => got += 1,
                            Err(_) => break,
                        }
                    }
                    let metrics = server.shutdown();
                    summary.requests += n_req as u64;
                    summary.responses += got;
                    summary.batches += metrics.batches;
                }
            }
        }
        Ok(summary)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn quick_config() -> FleetSimConfig {
        FleetSimConfig { requests_per_tenant: 120, ..FleetSimConfig::default() }
    }

    #[test]
    fn sim_serves_all_tenants() {
        let report = FleetSim::run(&quick_config()).unwrap();
        assert_eq!(report.tenants.len(), 3);
        assert!(report.slices_used >= 4);
        for t in &report.tenants {
            assert!(t.served > 0, "tenant {} served nothing", t.tenant);
            assert!(t.p99_s >= t.p50_s);
            assert!(t.energy_j > 0.0);
        }
        assert!(report.throughput_rps > 0.0);
    }

    #[test]
    fn sim_runs_campaigns_with_downtime() {
        let report = FleetSim::run(&quick_config()).unwrap();
        assert_eq!(report.campaigns.len(), 3, "one campaign per tenant");
        assert!(report.downtime_s > 0.0);
        for c in &report.campaigns {
            assert!(c.program_s > 0.0);
            assert_eq!(c.replica, 0);
        }
        // The warmed caches make the rewarm phase real: campaigns displace
        // resident lines and pay to reload them.
        assert!(
            report.campaigns.iter().all(|c| c.lines_displaced > 0 && c.rewarm_s > 0.0),
            "campaigns must displace warmed lines: {:?}",
            report.campaigns.iter().map(|c| c.lines_displaced).collect::<Vec<_>>()
        );
        // Reprogramming bumped wear past the initial programming.
        assert!(report.wear.iter().map(|w| w.max_cycles()).fold(0.0, f64::max) >= 2.0);
        assert!(report.wear_ok);
    }

    #[test]
    fn sim_report_renders_and_serializes() {
        let report = FleetSim::run(&quick_config()).unwrap();
        let text = report.render();
        assert!(text.contains("fleet: 3 tenants"));
        assert!(text.contains("campaigns: 3"));
        let json = report.to_json();
        assert!(json.get("throughput_rps").unwrap().as_f64().unwrap() > 0.0);
        assert_eq!(json.get("campaigns").unwrap().as_f64(), Some(3.0));
    }
}
